//! Batch server demo: submit a mixed bag of factorization requests —
//! different sizes, priorities, driver families, a deadline, and a
//! cancellation — to one [`malleable_lu::serve::LuServer`] over a shared
//! malleable pool, then render the multi-problem trace.
//!
//! ```bash
//! cargo run --release --example batch_server
//! ```

use malleable_lu::factor::DriverFamily;
use malleable_lu::matrix::{naive, Matrix};
use malleable_lu::serve::{LuRequest, LuServer, ServeConfig};
use malleable_lu::trace;
use std::time::Duration;

fn main() {
    let cfg = ServeConfig {
        workers: 3,
        bo: 48,
        bi: 16,
        ..Default::default()
    };
    let server = LuServer::new(cfg);
    let rec = trace::start();

    // Three ordinary requests of mixed sizes and priorities, alternating
    // driver families: even indices take the WS+ET look-ahead driver,
    // odd ones the tile-DAG runtime (DESIGN.md §17) — floaters donated
    // to a DAG request attach as extra DAG executors instead of crew
    // members, and both families produce identical bits.
    let sizes = [256usize, 160, 320];
    let originals: Vec<Matrix> = sizes
        .iter()
        .enumerate()
        .map(|(i, &n)| Matrix::random(n, n, 7 + i as u64))
        .collect();
    let handles: Vec<_> = originals
        .iter()
        .enumerate()
        .map(|(i, a)| {
            let family = if i % 2 == 0 {
                DriverFamily::Lookahead
            } else {
                DriverFamily::Dag
            };
            server.submit(
                LuRequest::new(a.clone())
                    .with_priority(i as u8)
                    .with_driver(family),
            )
        })
        .collect();

    // A request with an impossible deadline: ET cancels it at a panel
    // checkpoint and its crew flows back to the others.
    let doomed = server.submit(
        LuRequest::new(Matrix::random(512, 512, 99)).with_deadline(Duration::from_millis(1)),
    );
    // A superseded request, cancelled outright.
    let superseded = server.submit(LuRequest::new(Matrix::random(384, 384, 100)));
    superseded.cancel();

    for (i, (h, a0)) in handles.into_iter().zip(&originals).enumerate() {
        let res = h.wait();
        let r = naive::lu_residual(a0, &res.a, &res.ipiv);
        let family = if i % 2 == 0 { "lookahead" } else { "dag" };
        println!(
            "req{} n={} [{family}]: done in {:.3}s, residual {r:.3e}",
            res.id,
            a0.rows(),
            res.secs
        );
        assert!(r < 1e-10, "bad residual");
    }
    let d = doomed.wait();
    println!(
        "req{} (1 ms deadline): cancelled={} after {} of 512 columns",
        d.id, d.cancelled, d.cols_done
    );
    let s = superseded.wait();
    println!(
        "req{} (superseded): cancelled={} cols_done={}",
        s.id, s.cancelled, s.cols_done
    );

    server.shutdown();
    trace::stop();
    let spans = rec.spans();
    println!("\nper-request timeline (one lane per problem):");
    print!("{}", trace::ascii_gantt_requests(&spans, 100));
    println!("\nper-worker timeline:");
    print!("{}", trace::ascii_gantt(&spans, 100));
    println!("OK");
}
