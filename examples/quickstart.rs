//! Quickstart: factorize a matrix with the full WS+ET pipeline and verify
//! the factorization.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use malleable_lu::blis::BlisParams;
use malleable_lu::lu::{factorize, residual, LuConfig, Variant};
use malleable_lu::matrix::Matrix;
use malleable_lu::util::{gflops, lu_flops, timed};

fn main() {
    let n = 768;
    let a0 = Matrix::random(n, n, 42);

    let cfg = LuConfig {
        variant: Variant::EarlyTerm, // look-ahead + malleable BLAS + ET
        bo: 128,
        bi: 32,
        threads: 4,
        t_pf: 1,
        params: BlisParams::default(),
        ..Default::default()
    };

    let mut f = a0.clone();
    let (secs, out) = timed(|| factorize(&mut f, &cfg, None));
    let r = residual(&a0, &f, &out.ipiv);

    println!(
        "LU_ET factorized {n}x{n} in {secs:.3}s ({:.2} GFLOPS wall)",
        gflops(lu_flops(n, n), secs)
    );
    println!("residual ‖PA−LU‖_F/‖A‖_F = {r:.3e}");
    let stats = out.la_stats.expect("look-ahead stats");
    println!(
        "look-ahead iterations: {} | ET cuts: {} | forward WS iters: {}",
        stats.iters, stats.et_cuts, stats.ws_forward
    );
    assert!(r < 1e-12, "factorization must be backward stable");
    println!("OK");
}
