//! The AOT Pallas/JAX → PJRT → Rust pipeline, end to end:
//! load the artifact store, run the Pallas GEPP kernel and the full LU
//! model from Rust, and cross-validate against the Rust-native malleable
//! BLIS substrate.
//!
//! ```bash
//! make artifacts && cargo run --release --example xla_offload
//! ```

use malleable_lu::matrix::{naive, Matrix};
use malleable_lu::runtime::{self, xla_lu, Runtime};
use malleable_lu::util::timed;

fn main() {
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts missing ({e:#}); run `make artifacts`");
            std::process::exit(1);
        }
    };
    println!("{} artifacts available:", rt.available().len());
    for name in rt.available() {
        let meta = rt.meta(&name).unwrap();
        println!("  {:24} kind={:6} inputs={:?}", name, meta.kind, meta.input_shapes);
    }

    // 1. The L1 Pallas kernel, straight from Rust.
    let (m, n, k) = (128, 128, 64);
    let c0 = Matrix::random(m, n, 1);
    let a = Matrix::random(m, k, 2);
    let b = Matrix::random(k, n, 3);
    let (secs, outs) = timed(|| {
        rt.run(
            &format!("gepp_{m}x{n}x{k}"),
            &[
                runtime::matrix_to_literal(&c0).unwrap(),
                runtime::matrix_to_literal(&a).unwrap(),
                runtime::matrix_to_literal(&b).unwrap(),
            ],
        )
        .expect("gepp artifact")
    });
    let c_xla = runtime::literal_to_matrix(&outs[0], m, n).unwrap();
    let mut c_rust = c0.clone();
    let mut crew = malleable_lu::pool::Crew::new();
    malleable_lu::blis::gemm(
        &mut crew,
        &malleable_lu::blis::BlisParams::default(),
        -1.0,
        a.view(),
        b.view(),
        c_rust.view_mut(),
    );
    println!(
        "\nPallas GEPP {m}x{n}x{k} via PJRT: {:.1} ms (incl. first-call compile), \
         max|Δ vs rust BLIS| = {:.2e}",
        secs * 1e3,
        c_rust.max_abs_diff(&c_xla)
    );

    // 2. The full L2 model (panel loop + Pallas updates) as one artifact.
    let n_lu = 512;
    let bo = 128;
    let a0 = Matrix::random(n_lu, n_lu, 7);
    let (secs, res) = timed(|| xla_lu::factorize_full(&rt, &a0, bo));
    let (lu, piv) = res.expect("lu artifact");
    let r = naive::lu_residual(&a0, &lu, &piv);
    println!("LU_XLA (full graph) n={n_lu} bo={bo}: {:.2}s, residual {r:.2e}", secs);

    // 3. Stepped mode: Rust drives the loop, one executable per kernel.
    let (secs2, res2) = timed(|| xla_lu::factorize_stepped(&rt, &a0, bo));
    let (lu2, piv2) = res2.expect("stepped LU");
    assert_eq!(piv, piv2, "stepped and full-graph pivots agree");
    println!(
        "LU_XLA (stepped)    n={n_lu} bo={bo}: {:.2}s, max|Δ vs full| = {:.2e}",
        secs2,
        lu.max_abs_diff(&lu2)
    );

    // 4. Cross-validation against the Rust-native substrate.
    let (diff, piv_eq) = xla_lu::cross_validate(&rt, &a0, bo, 32).expect("cross-validate");
    println!("cross-validation vs rust BLIS LU: max|Δ|={diff:.2e}, pivots equal: {piv_eq}");
    assert!(piv_eq && diff < 1e-9 && r < 1e-12);
    println!("xla_offload OK — python was never on this path");
}
