//! Regenerate the paper's trace figures (Figs. 5, 8, 9, 11) as ASCII
//! Gantt timelines on the simulated 6-core testbed, plus a real-mode
//! logical trace of a small factorization on this host.
//!
//! ```bash
//! cargo run --release --example trace_timeline
//! ```

use malleable_lu::sim::{simulate, HwModel, SimVariant};
use malleable_lu::trace;

fn show(title: &str, v: SimVariant, n: usize) {
    let hw = HwModel::default();
    let out = simulate(&hw, v, n, 256, 32, 6, 1, true);
    println!("\n=== {title} ===");
    println!(
        "[sim 6-core Xeon] {} n={n} b_o=256 b_i=32: {:.3}s virtual, {:.1} GFLOPS",
        v.name(),
        out.time,
        out.gflops
    );
    // Show roughly the first four iterations like the paper's figures:
    // clip spans to the leading ~20% of the timeline.
    let clip = out.time * 0.2;
    let head: Vec<_> = out
        .spans
        .iter()
        .filter(|s| s.t0 < clip)
        .cloned()
        .map(|mut s| {
            s.t1 = s.t1.min(clip);
            s
        })
        .collect();
    print!("{}", trace::ascii_gantt(&head, 110));
}

fn main() {
    // Fig. 5 — plain blocked RL LU: the PANEL (P) dominates lane 0 while
    // the other lanes idle.
    show("Fig. 5: LU (BDP only), n=10000", SimVariant::Lu, 10_000);

    // Fig. 8 — look-ahead, large n: T_PF (lane 0) finishes early and
    // idles ('.') — the waste WS will reclaim.
    show("Fig. 8: LU_LA, n=10000 (panel cheaper)", SimVariant::La, 10_000);

    // Fig. 9 — look-ahead, small n: T_PF dominates, the RU lanes idle.
    show("Fig. 9: LU_LA, n=2000 (panel dominates)", SimVariant::La, 2_000);

    // Fig. 11 — malleable BLIS: after PF3 the panel thread joins RU2's
    // GEMM (lane 0 shows G where Fig. 8 showed '.').
    show("Fig. 11: LU_MB, n=10000 (worker sharing)", SimVariant::Mb, 10_000);

    // Real-mode logical trace (1-core host: overlap is logical, not
    // physical — see DESIGN.md §3).
    println!("\n=== real-mode logical trace: LU_MB, n=512, 3 threads ===");
    let rec = trace::start();
    let mut a = malleable_lu::matrix::Matrix::random(512, 512, 9);
    let cfg = malleable_lu::lu::LuConfig {
        variant: malleable_lu::lu::Variant::Malleable,
        bo: 128,
        bi: 32,
        threads: 3,
        ..Default::default()
    };
    let out = malleable_lu::lu::factorize(&mut a, &cfg, None);
    trace::stop();
    print!("{}", trace::ascii_gantt(&rec.spans(), 110));
    let stats = out.la_stats.unwrap();
    println!(
        "iters={} ws_forward={} (worker 0 enlisting into the RU crew)",
        stats.iters, stats.ws_forward
    );
}
