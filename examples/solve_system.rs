//! End-to-end driver (DESIGN.md deliverable): solve dense linear systems
//! through the full coordinator stack with **every** variant — the plain
//! blocked LU, the three look-ahead refinements, the task-runtime
//! baseline, and (when artifacts are built) the XLA/PJRT "rigid vendor
//! BLAS" baseline — reporting wall time, GFLOPS and the solution error
//! for each. This is the workload the paper's introduction motivates:
//! `P A = L U`, then forward/back substitution.
//!
//! ```bash
//! make artifacts && cargo run --release --example solve_system
//! ```

use malleable_lu::blis::BlisParams;
use malleable_lu::lu::{self, LuConfig, Variant};
use malleable_lu::matrix::Matrix;
use malleable_lu::runtime::{xla_lu, Runtime};
use malleable_lu::util::{gflops, lu_flops, timed};

fn main() {
    let n = 512;
    let bo = 128;
    let a0 = Matrix::random_dd(n, 2026);
    // Right-hand side with known solution.
    let x_true: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
    let mut b = vec![0.0; n];
    for j in 0..n {
        for i in 0..n {
            b[i] += a0[(i, j)] * x_true[j];
        }
    }

    println!("solving {n}x{n} diag-dominant system with every variant (bo={bo}):");
    println!(
        "{:>10} {:>9} {:>9} {:>12} {:>12}",
        "variant", "secs", "GFLOPS", "residual", "max|x-x*|"
    );

    for &v in Variant::all() {
        let cfg = LuConfig {
            variant: v,
            bo,
            bi: 32,
            threads: 4,
            params: BlisParams::default(),
            ..Default::default()
        };
        let mut f = a0.clone();
        let (secs, out) = timed(|| lu::factorize(&mut f, &cfg, None));
        let r = lu::residual(&a0, &f, &out.ipiv);
        let x = lu::solve(&f, &out.ipiv, &b);
        let err = x
            .iter()
            .zip(&x_true)
            .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()));
        println!(
            "{:>10} {:>9.3} {:>9.2} {:>12.3e} {:>12.3e}",
            v.name(),
            secs,
            gflops(lu_flops(n, n), secs),
            r,
            err
        );
        assert!(r < 1e-12 && err < 1e-9, "{} failed", v.name());
    }

    // The rigid-library baseline via AOT XLA artifacts, if present.
    match Runtime::open("artifacts") {
        Ok(rt) if rt.has(&format!("lu_{n}x{bo}")) => {
            let (secs, result) = timed(|| xla_lu::factorize_full(&rt, &a0, bo));
            let (f, piv) = result.expect("LU_XLA");
            let r = malleable_lu::matrix::naive::lu_residual(&a0, &f, &piv);
            let x = lu::solve(&f, &piv, &b);
            let err = x
                .iter()
                .zip(&x_true)
                .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()));
            println!(
                "{:>10} {:>9.3} {:>9.2} {:>12.3e} {:>12.3e}  (AOT Pallas/XLA, incl. compile)",
                "LU_XLA",
                secs,
                gflops(lu_flops(n, n), secs),
                r,
                err
            );
            assert!(r < 1e-12 && err < 1e-9, "LU_XLA failed");
        }
        Ok(_) => println!("(skipping LU_XLA: no lu_{n}x{bo} artifact — rerun `make artifacts`)"),
        Err(_) => println!("(skipping LU_XLA: run `make artifacts` first)"),
    }
    println!("all variants agree: OK");
}
