//! Regenerate every performance figure of the paper's evaluation
//! (Figs. 14–17) on the simulated testbed and write CSVs to `figures/`.
//!
//! ```bash
//! cargo run --release --example figures [-- --paper]
//! ```
//!
//! `--paper` uses the paper's full grids (n = 500..12000 step 500,
//! b_o = 32..512 step 32); the default quick grids cover the same ranges
//! more coarsely.

use malleable_lu::cli::{render_table, Args};
use malleable_lu::sim::figures::{
    fig14_gepp, fig14_ratio, fig15_optimal_b, fig16_variants, fig17_et_vs_os, Grids,
};
use malleable_lu::sim::HwModel;

fn main() {
    let args = Args::from_env();
    let grids = if args.has("paper") {
        Grids::paper()
    } else {
        Grids::quick()
    };
    let hw = HwModel::default();
    std::fs::create_dir_all("figures").expect("mkdir figures");

    let tables = vec![
        ("fig14_gepp.csv", fig14_gepp(&hw, &grids)),
        ("fig14_ratio.csv", fig14_ratio(&hw, &grids)),
        ("fig15_optimal_b.csv", fig15_optimal_b(&hw, &grids, 6)),
        ("fig16_variants.csv", fig16_variants(&hw, &grids, 6)),
        ("fig17_et_vs_os.csv", fig17_et_vs_os(&hw, &grids, 6)),
    ];
    for (file, table) in &tables {
        print!("\n{}", render_table(table));
        let path = format!("figures/{file}");
        std::fs::write(&path, table.to_csv()).expect("write csv");
        println!("→ wrote {path}");
    }

    // Headline checks (the paper's qualitative claims).
    let f16 = &tables[3].1;
    let (lu, la, mb, et) = (f16.col("LU"), f16.col("LU_LA"), f16.col("LU_MB"), f16.col("LU_ET"));
    let last = f16.rows.last().unwrap();
    println!("\nheadline checks @ n={}:", last[0]);
    println!(
        "  LU={:.1} LA={:.1} MB={:.1} ET={:.1}  (expect ET ≈ MB > LA ≳ LU)",
        last[lu], last[la], last[mb], last[et]
    );
    assert!(last[et] >= last[mb] * 0.99 && last[mb] > last[la]);
    let f17 = &tables[4].1;
    // The fixed-block robustness claim applies once ET has iterations to
    // adapt (n ≳ 1500; below that the non-adaptive first panel dominates).
    let worst_et_pen = f17
        .rows
        .iter()
        .filter(|r| r[0] >= 1500.0)
        .map(|r| 1.0 - r[f17.col("ET(b=192)")] / r[f17.col("ET(b_opt)")])
        .fold(0.0f64, f64::max);
    println!(
        "  worst ET fixed-block penalty (n>=1500): {:.1}% (paper: \"minor impact\")",
        100.0 * worst_et_pen
    );
    assert!(worst_et_pen < 0.12, "ET fixed-block penalty too large");
    println!("figures OK");
}
