"""AOT pipeline: lower the L2/L1 computations to HLO **text** and write
``artifacts/*.hlo.txt`` + ``artifacts/manifest.json``.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (shapes fixed at export; the Rust runtime picks by name):
- ``gepp_{m}x{n}x{k}``     : (C, A, B) -> (C - A@B,)           [Pallas L1]
- ``panel_{m}x{b}``        : (P,)      -> (LU_panel, piv_i32)
- ``trsm_{b}x{n}``         : (A11, A12)-> (TRILU(A11)^-1 A12,)
- ``laswp_{m}x{n}x{b}``    : (X, piv)  -> (P X,)
- ``lu_{n}x{b}``           : (A,)      -> (LU, piv_i32)        [full model]

Default shape set serves the ``LU_XLA`` demo at n=512, b_o=128, plus a
small n=192/b=64 set for fast integration tests.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f64(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def artifact_specs(n: int, b: int):
    """The artifact set for one (n, b_o) factorization configuration."""
    specs = []
    # Full-model artifact.
    specs.append(
        dict(
            name=f"lu_{n}x{b}",
            kind="lu",
            fn=functools.partial(model.lu_blocked, bo=b),
            args=[f64(n, n)],
            outputs=["lu_f64", "piv_i32"],
        )
    )
    # Per-step artifacts for the iteration-driven LU_XLA loop.
    k = 0
    seen = set()
    while k < n:
        bb = min(b, n - k)
        m_panel = n - k
        if ("panel", m_panel, bb) not in seen:
            seen.add(("panel", m_panel, bb))
            specs.append(
                dict(
                    name=f"panel_{m_panel}x{bb}",
                    kind="panel",
                    fn=model.panel_factor,
                    args=[f64(m_panel, bb)],
                    outputs=["lu_f64", "piv_i32"],
                )
            )
        rest = n - k - bb
        if rest + k > 0 and ("laswp", m_panel, rest + k, bb) not in seen:
            seen.add(("laswp", m_panel, rest + k, bb))
            specs.append(
                dict(
                    name=f"laswp_{m_panel}x{rest + k}x{bb}",
                    kind="laswp",
                    fn=model.apply_pivots,
                    args=[f64(m_panel, rest + k), i32(bb)],
                    outputs=["x_f64"],
                )
            )
        if rest > 0:
            if ("trsm", bb, rest) not in seen:
                seen.add(("trsm", bb, rest))
                specs.append(
                    dict(
                        name=f"trsm_{bb}x{rest}",
                        kind="trsm",
                        fn=model.trsm_llu,
                        args=[f64(bb, bb), f64(bb, rest)],
                        outputs=["x_f64"],
                    )
                )
            mm = n - k - bb
            if ("gepp", mm, rest, bb) not in seen:
                seen.add(("gepp", mm, rest, bb))
                specs.append(
                    dict(
                        name=f"gepp_{mm}x{rest}x{bb}",
                        kind="gepp",
                        fn=model.gepp,
                        args=[f64(mm, rest), f64(mm, bb), f64(bb, rest)],
                        outputs=["c_f64"],
                    )
                )
        k += bb
    return specs


def export(out_dir: str, configs):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "dtype": "f64", "artifacts": []}
    done = set()
    for n, b in configs:
        for spec in artifact_specs(n, b):
            if spec["name"] in done:
                continue
            done.add(spec["name"])
            lowered = jax.jit(spec["fn"]).lower(*spec["args"])
            text = to_hlo_text(lowered)
            path = f"{spec['name']}.hlo.txt"
            with open(os.path.join(out_dir, path), "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": spec["name"],
                    "kind": spec["kind"],
                    "file": path,
                    "inputs": [
                        {"shape": list(a.shape), "dtype": a.dtype.name}
                        for a in spec["args"]
                    ],
                    "outputs": spec["outputs"],
                }
            )
            print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--configs",
        default="192:64,512:128",
        help="comma-separated n:b pairs to export",
    )
    args = ap.parse_args()
    configs = []
    for part in args.configs.split(","):
        n, b = part.split(":")
        configs.append((int(n), int(b)))
    export(args.out_dir, configs)


if __name__ == "__main__":
    main()
