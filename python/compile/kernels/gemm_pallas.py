"""L1 — the Pallas GEPP kernel: the paper's compute hot spot.

The trailing update ``C += alpha * A @ B`` (RL3/RU2, with ``k = b_o``)
expressed as a tiled Pallas kernel:

- the grid is ``(m/bm, n/bn, k/bk)`` with ``k`` innermost, so each
  ``(i, j)`` output tile stays resident while the ``k`` axis streams
  through — the HBM<->VMEM schedule mirrors what BLIS does with the
  packed ``A_c``/``B_c`` cache buffers (DESIGN.md §Hardware-Adaptation);
- each grid step multiplies a ``(bm, bk)`` by a ``(bk, bn)`` tile —
  on a real TPU this feeds the MXU; under ``interpret=True`` (mandatory
  for CPU-PJRT execution, see /opt/xla-example/README.md) it executes
  with jnp semantics and bit-matching numerics.

VMEM footprint per step = (bm*bk + bk*bn + 2*bm*bn) * 8 bytes
(f64; the default 128x128x128 tiles use 512 KiB -- comfortably under a
TPU core's ~16 MiB VMEM, leaving room for double-buffering).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _gepp_kernel(alpha, c_in_ref, a_ref, b_ref, o_ref):
    """One grid step: o[i,j] (+)= alpha * a[i,k] @ b[k,j]."""
    # First k-step seeds the output tile with C's original values.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = c_in_ref[...]

    o_ref[...] += alpha * jnp.dot(a_ref[...], b_ref[...])


def _pad_to(x, rows, cols):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(
    jax.jit, static_argnames=("alpha", "bm", "bn", "bk", "interpret")
)
def gepp_update(
    c,
    a,
    b,
    *,
    alpha=-1.0,
    bm=DEFAULT_BM,
    bn=DEFAULT_BN,
    bk=DEFAULT_BK,
    interpret=True,
):
    """``C + alpha * A @ B`` with ``C: (m,n)``, ``A: (m,k)``, ``B: (k,n)``.

    Shapes need not divide the tile sizes: operands are zero-padded to
    tile multiples (exact for a linear update) and the result sliced back.
    """
    m, n = c.shape
    k = a.shape[1]
    assert a.shape[0] == m and b.shape == (k, n), (c.shape, a.shape, b.shape)
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    mp = -(-m // bm_) * bm_
    np_ = -(-n // bn_) * bn_
    kp = -(-k // bk_) * bk_
    cp = _pad_to(c, mp, np_)
    ap = _pad_to(a, mp, kp)
    bp = _pad_to(b, kp, np_)

    grid = (mp // bm_, np_ // bn_, kp // bk_)
    out = pl.pallas_call(
        functools.partial(_gepp_kernel, alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),  # C (seed)
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),  # A
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),  # B
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), c.dtype),
        interpret=interpret,
    )(cp, ap, bp)
    return out[:m, :n]


def vmem_bytes(bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK, itemsize=8):
    """Estimated VMEM working set of one grid step (C-in, A, B, O tiles)."""
    return (bm * bk + bk * bn + 2 * bm * bn) * itemsize


def mxu_utilization_estimate(bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Fraction of MXU-shaped work per step: tiles that are multiples of
    the 128x128 systolic array run at full occupancy."""
    eff = 1.0
    for d in (bm, bn, bk):
        eff *= min(d, 128) / 128.0 if d < 128 else 1.0
    return eff
