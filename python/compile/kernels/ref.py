"""Pure-jnp correctness oracles for the Pallas kernels and the L2 model.

These are the build-time analogue of ``rust/src/matrix/naive.rs``: simple,
auditable definitions that the Pallas kernels and the AOT-exported HLO are
validated against (pytest + hypothesis).
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def gemm_ref(c, a, b, alpha=1.0):
    """``C + alpha * A @ B`` — the GEPP-shaped trailing update (RU2/RL3)."""
    return c + alpha * (a @ b)


def trsm_llu_ref(a, b):
    """``TRILU(A)^{-1} @ B``: left solve with the *unit* lower triangle of
    ``A`` (strictly-lower entries used, diagonal treated as 1)."""
    l = jnp.tril(a, k=-1) + jnp.eye(a.shape[0], dtype=a.dtype)
    return jax.scipy.linalg.solve_triangular(l, b, lower=True, unit_diagonal=True)


def lu_panel_ref(a):
    """Unblocked right-looking LU with partial pivoting of an ``m x n``
    panel. Returns ``(LU_packed, piv)`` with ``piv`` in LAPACK convention
    (row ``k`` swapped with ``piv[k] >= k``). Mirrors
    ``rust/src/lu/unblocked.rs`` (reciprocal-multiply scaling)."""
    m, n = a.shape
    kmax = min(m, n)
    a = jnp.asarray(a)
    piv = []
    for k in range(kmax):
        p = k + jnp.argmax(jnp.abs(a[k:, k]))
        piv.append(p)
        a = a.at[[k, p], :].set(a[[p, k], :])
        akk = a[k, k]
        scale = jnp.where(akk != 0.0, 1.0 / akk, 0.0)
        a = a.at[k + 1 :, k].multiply(scale)
        a = a.at[k + 1 :, k + 1 :].add(-jnp.outer(a[k + 1 :, k], a[k, k + 1 :]))
    return a, jnp.array(piv, dtype=jnp.int32)


def apply_pivots_ref(b, piv):
    """Apply LAPACK-style pivots to the rows of ``b``."""
    b = jnp.asarray(b)
    for k in range(piv.shape[0]):
        p = int(piv[k])
        b = b.at[[k, p], :].set(b[[p, k], :])
    return b


def lu_blocked_ref(a, bo):
    """Blocked right-looking LU with partial pivoting (paper Fig. 3 right)
    — the oracle for the L2 model. Returns ``(LU_packed, piv_absolute)``."""
    a = jnp.asarray(a)
    m, n = a.shape
    kmax = min(m, n)
    pivs = []
    k = 0
    while k < kmax:
        b = min(bo, kmax - k)
        panel, piv = lu_panel_ref(a[k:, k : k + b])
        a = a.at[k:, k : k + b].set(panel)
        piv = piv + k
        pivs.append(piv)
        # Apply interchanges to the left and right of the panel.
        for i in range(b):
            p = int(piv[i])
            r = k + i
            if p != r:
                left = a[:, :k]
                right = a[:, k + b :]
                left = left.at[[r, p], :].set(left[[p, r], :])
                right = right.at[[r, p], :].set(right[[p, r], :])
                a = a.at[:, :k].set(left).at[:, k + b :].set(right)
        if k + b < n:
            a12 = trsm_llu_ref(a[k : k + b, k : k + b], a[k : k + b, k + b :])
            a = a.at[k : k + b, k + b :].set(a12)
            if k + b < m:
                a = a.at[k + b :, k + b :].add(-a[k + b :, k : k + b] @ a12)
        k += b
    return a, jnp.concatenate(pivs) if pivs else jnp.zeros((0,), jnp.int32)


def lu_residual_ref(a0, lu_packed, piv):
    """Relative residual ||P A - L U||_F / ||A||_F."""
    m, n = a0.shape
    kk = min(m, n)
    l = jnp.tril(lu_packed[:, :kk], k=-1) + jnp.eye(m, kk, dtype=a0.dtype)
    u = jnp.triu(lu_packed[:kk, :])
    pa = apply_pivots_ref(a0, piv)
    return jnp.linalg.norm(pa - l @ u) / jnp.linalg.norm(a0)
