"""L2 — the JAX compute graph of the blocked LU factorization.

The building blocks of the paper's Fig. 3 (right), written as traceable
JAX functions over fixed shapes so they AOT-export to single HLO modules:

- :func:`panel_factor` — unblocked RL panel LU with partial pivoting
  (``lax.fori_loop``; pivot search/swap/scale/rank-1 per column);
- :func:`apply_pivots` — LAPACK-style row interchanges;
- :func:`lu_step_update` — swaps + TRSM + the **Pallas** GEPP update of
  the trailing submatrix (this is where L1 enters the graph);
- :func:`lu_blocked` — the full factorization (panel loop unrolled at
  trace time — shapes are static per artifact).

These are the computations the Rust runtime loads as the "rigid vendor
library" baseline ``LU_XLA`` (DESIGN.md §2): shape-specialized, compiled,
and **non-malleable**, exactly the kind of black box the paper argues
malleable libraries should replace.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.gemm_pallas import gepp_update

jax.config.update("jax_enable_x64", True)


def panel_factor(a):
    """Unblocked right-looking LU with partial pivoting of an ``(m, b)``
    panel. Returns ``(LU_packed, piv)``, ``piv`` int32 LAPACK-style."""
    m, b = a.shape
    kmax = min(m, b)
    rows = jnp.arange(m)
    cols = jnp.arange(b)

    def body(k, carry):
        a, piv = carry
        colk = jax.lax.dynamic_slice_in_dim(a, k, 1, axis=1)[:, 0]
        masked = jnp.where(rows >= k, jnp.abs(colk), -jnp.inf)
        p = jnp.argmax(masked).astype(jnp.int32)
        piv = piv.at[k].set(p)
        # Swap rows k and p (gathers happen before either scatter).
        rk = a[k, :]
        rp = a[p, :]
        a = a.at[k, :].set(rp).at[p, :].set(rk)
        akk = a[k, k]
        scale = jnp.where(akk != 0.0, 1.0 / akk, 0.0)
        colk = a[:, k]
        colk = jnp.where(rows > k, colk * scale, colk)
        a = a.at[:, k].set(colk)
        # Rank-1 update of the strictly-trailing block.
        x = jnp.where(rows > k, a[:, k], 0.0)
        y = jnp.where(cols > k, a[k, :], 0.0)
        a = a - jnp.outer(x, y)
        return a, piv

    piv0 = jnp.zeros((kmax,), jnp.int32)
    a, piv = jax.lax.fori_loop(0, kmax, body, (a, piv0))
    return a, piv


def apply_pivots(b, piv):
    """Row interchanges ``b[k] <-> b[piv[k]]`` in order (LASWP)."""

    def body(k, b):
        p = piv[k]
        rk = b[k, :]
        rp = b[p, :]
        return b.at[k, :].set(rp).at[p, :].set(rk)

    return jax.lax.fori_loop(0, piv.shape[0], body, b)


def trsm_llu(a11, a12):
    """``TRILU(a11)^{-1} @ a12`` (RL2) — forward substitution in pure jnp.

    Deliberately NOT ``jax.scipy.linalg.solve_triangular``: on CPU that
    lowers to a LAPACK custom-call with API_VERSION_TYPED_FFI, which the
    runtime's xla_extension 0.5.1 rejects. Row ``i`` of the solution only
    reads already-final rows ``< i`` (strict lower triangle), so a
    ``fori_loop`` of mat-vecs is exact."""
    l_strict = jnp.tril(a11, k=-1)

    def body(i, x):
        return x.at[i, :].add(-(l_strict[i, :] @ x))

    return jax.lax.fori_loop(0, a11.shape[0], body, a12)


def lu_step_update(a11, rest, piv, *, interpret=True):
    """Everything the trailing matrix needs from one factored panel:
    ``rest`` is the ``(m, n_rest)`` block right of the panel (rows aligned
    with the panel top); applies the panel's swaps, the TRSM on the top
    ``b`` rows, and the Pallas GEPP update below. Returns updated
    ``rest``."""
    b = a11.shape[0]
    rest = apply_pivots(rest, piv)
    top = trsm_llu(a11, rest[:b, :])
    return rest.at[:b, :].set(top), top


def gepp(c, a, b, *, interpret=True):
    """Exported alias of the L1 kernel: ``C - A @ B``."""
    return gepp_update(c, a, b, alpha=-1.0, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bo", "interpret"))
def lu_blocked(a, *, bo, interpret=True):
    """Blocked right-looking LU with partial pivoting of a square matrix
    (paper Fig. 3 right). The panel loop is unrolled at trace time; the
    trailing update is the Pallas kernel. Returns ``(LU, piv)``."""
    n = a.shape[0]
    assert a.shape == (n, n)
    pivs = []
    k = 0
    while k < n:
        b = min(bo, n - k)
        panel, piv = panel_factor(a[k:, k : k + b])
        a = a.at[k:, k : k + b].set(panel)
        # Interchanges left and right of the panel (absolute row base k).
        left_right = jnp.concatenate([a[k:, :k], a[k:, k + b :]], axis=1)
        left_right = apply_pivots(left_right, piv)
        a = a.at[k:, :k].set(left_right[:, :k])
        a = a.at[k:, k + b :].set(left_right[:, k:])
        pivs.append(piv + k)
        rest = n - k - b
        if rest > 0:
            a12 = trsm_llu(a[k : k + b, k : k + b], a[k : k + b, k + b :])
            a = a.at[k : k + b, k + b :].set(a12)
            c = gepp_update(
                a[k + b :, k + b :],
                a[k + b :, k : k + b],
                a12,
                alpha=-1.0,
                interpret=interpret,
            )
            a = a.at[k + b :, k + b :].set(c)
        k += b
    piv = jnp.concatenate(pivs) if pivs else jnp.zeros((0,), jnp.int32)
    return a, piv
