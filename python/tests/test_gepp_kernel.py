"""L1 correctness: the Pallas GEPP kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, tile sizes, dtypes and alpha — the CORE
correctness signal for the kernel that every artifact embeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gemm_pallas import (
    gepp_update,
    mxu_utilization_estimate,
    vmem_bytes,
)
from compile.kernels.ref import gemm_ref

jax.config.update("jax_enable_x64", True)


def rand(rng, *shape, dtype=np.float64):
    return jnp.asarray(rng.uniform(size=shape), dtype=dtype)


def check(m, n, k, alpha, bm, bn, bk, dtype=np.float64, seed=0):
    rng = np.random.default_rng(seed)
    c = rand(rng, m, n, dtype=dtype)
    a = rand(rng, m, k, dtype=dtype)
    b = rand(rng, k, n, dtype=dtype)
    got = gepp_update(c, a, b, alpha=alpha, bm=bm, bn=bn, bk=bk)
    want = gemm_ref(c, a, b, alpha=alpha)
    tol = 1e-12 * k if dtype == np.float64 else 1e-3 * k
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)
    assert got.dtype == c.dtype


def test_exact_tile_multiples():
    check(256, 256, 128, -1.0, 128, 128, 128)


def test_ragged_edges():
    check(130, 67, 33, -1.0, 64, 32, 16)


def test_tiny():
    check(1, 1, 1, -1.0, 128, 128, 128)


def test_alpha_plus_one():
    check(64, 64, 32, 1.0, 32, 32, 32)


def test_f32_dtype():
    check(96, 80, 40, -1.0, 32, 32, 32, dtype=np.float32)


def test_single_k_tile_seeds_output():
    # k smaller than bk: exactly one k-step; output must include C.
    rng = np.random.default_rng(1)
    c = rand(rng, 32, 32)
    a = jnp.zeros((32, 8))
    b = jnp.zeros((8, 32))
    got = gepp_update(c, a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(c))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 140),
    n=st.integers(1, 140),
    k=st.integers(1, 96),
    bm=st.sampled_from([16, 32, 64, 128]),
    bn=st.sampled_from([16, 32, 64, 128]),
    bk=st.sampled_from([16, 32, 64]),
    alpha=st.sampled_from([-1.0, 1.0, 0.5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shape_sweep(m, n, k, bm, bn, bk, alpha, seed):
    check(m, n, k, alpha, bm, bn, bk, seed=seed)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 100),
    n=st.integers(1, 100),
    k=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_f32(m, n, k, seed):
    check(m, n, k, -1.0, 32, 32, 32, dtype=np.float32, seed=seed)


def test_vmem_estimate_under_budget():
    # DESIGN.md §9: default tiles fit comfortably in a 16 MiB VMEM.
    assert vmem_bytes() == (128 * 128 + 128 * 128 + 2 * 128 * 128) * 8
    assert vmem_bytes() < 16 * 2**20 / 4


def test_mxu_estimate():
    assert mxu_utilization_estimate() == 1.0
    assert mxu_utilization_estimate(bm=64) == pytest.approx(0.5)
