"""L2 correctness: panel factorization, pivot application, TRSM and the
full blocked LU graph vs the jnp oracles and scipy."""

import jax
import jax.numpy as jnp
import numpy as np
import scipy.linalg
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def rand(n, m=None, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(size=(n, m or n)))


# ---------- panel_factor ----------

def test_panel_factor_matches_ref():
    a = rand(24, 8, seed=1)
    lu, piv = model.panel_factor(a)
    lu_r, piv_r = ref.lu_panel_ref(a)
    np.testing.assert_array_equal(np.asarray(piv), np.asarray(piv_r))
    np.testing.assert_allclose(np.asarray(lu), np.asarray(lu_r), atol=1e-13)


def test_panel_factor_matches_scipy_pivots():
    a = rand(16, 16, seed=2)
    _, piv = model.panel_factor(a)
    _, piv_s = scipy.linalg.lu_factor(np.asarray(a))
    np.testing.assert_array_equal(np.asarray(piv), piv_s)


def test_panel_residual():
    a = rand(40, 16, seed=3)
    lu, piv = model.panel_factor(a)
    r = ref.lu_residual_ref(a, lu, piv)
    assert float(r) < 1e-13


def test_panel_growth_bounded():
    a = rand(32, 12, seed=4)
    lu, _ = model.panel_factor(a)
    l_strict = np.tril(np.asarray(lu)[:, :12], k=-1)
    assert np.abs(l_strict).max() <= 1.0 + 1e-12


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(2, 40),
    bw=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_panel(m, bw, seed):
    b = min(bw, m)
    a = rand(m, b, seed=seed)
    lu, piv = model.panel_factor(a)
    r = ref.lu_residual_ref(a, lu, piv)
    assert float(r) < 1e-12
    piv_np = np.asarray(piv)
    assert (piv_np >= np.arange(len(piv_np))).all()


# ---------- apply_pivots / trsm ----------

def test_apply_pivots_matches_ref():
    a = rand(10, 6, seed=5)
    piv = jnp.asarray([3, 1, 9, 3], dtype=jnp.int32)
    got = model.apply_pivots(a, piv)
    want = ref.apply_pivots_ref(a, piv)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_trsm_llu_solves():
    a11 = rand(12, 12, seed=6)
    x0 = rand(12, 5, seed=7)
    l = jnp.tril(a11, k=-1) + jnp.eye(12)
    b = l @ x0
    got = model.trsm_llu(a11, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x0), atol=1e-12)


# ---------- full blocked LU ----------

def test_lu_blocked_matches_scipy():
    n, bo = 96, 32
    a = rand(n, seed=8)
    lu, piv = model.lu_blocked(a, bo=bo)
    lu_s, piv_s = scipy.linalg.lu_factor(np.asarray(a))
    np.testing.assert_array_equal(np.asarray(piv), piv_s)
    np.testing.assert_allclose(np.asarray(lu), lu_s, atol=1e-11)


def test_lu_blocked_residual_various_blocks():
    n = 64
    a = rand(n, seed=9)
    for bo in (8, 16, 64, 100):
        lu, piv = model.lu_blocked(a, bo=bo)
        r = ref.lu_residual_ref(a, lu, piv)
        assert float(r) < 1e-12, f"bo={bo}: {r}"


def test_lu_blocked_matches_blocked_ref():
    n, bo = 48, 16
    a = rand(n, seed=10)
    lu, piv = model.lu_blocked(a, bo=bo)
    lu_r, piv_r = ref.lu_blocked_ref(a, bo)
    np.testing.assert_array_equal(np.asarray(piv), np.asarray(piv_r))
    np.testing.assert_allclose(np.asarray(lu), np.asarray(lu_r), atol=1e-12)


def test_lu_step_update_consistency():
    # One manual outer iteration == the blocked reference's first step.
    n, b = 40, 8
    a = rand(n, seed=11)
    panel, piv = model.panel_factor(a[:, :b])
    rest, _top = model.lu_step_update(panel[:b, :b], a[:, b:], piv)
    c = model.gepp(rest[b:, :], panel[b:, :b], rest[:b, :])
    # Compare against the oracle's state after its first iteration.
    lu_r, piv_r = ref.lu_blocked_ref(a, b)
    np.testing.assert_array_equal(np.asarray(piv), np.asarray(piv_r[:b]))
    np.testing.assert_allclose(
        np.asarray(rest[:b, :]), np.asarray(lu_r[:b, b:]), atol=1e-12
    )


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(4, 72),
    bo=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_lu_blocked(n, bo, seed):
    a = rand(n, seed=seed)
    lu, piv = model.lu_blocked(a, bo=bo)
    r = ref.lu_residual_ref(a, lu, piv)
    assert float(r) < 1e-11
