"""AOT pipeline tests: HLO text export is parseable, deterministic, and
numerically faithful (executed back through XLA from the text form)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def roundtrip_run(fn, *args):
    """Lower fn to HLO text (exactly what aot.py exports) and execute the
    same lowered computation; the text->compile->execute leg is exercised
    by the Rust runtime integration tests (rust/tests/runtime_roundtrip)."""
    lowered = jax.jit(fn).lower(
        *[jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args]
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "f64" in text
    outs = lowered.compile()(*args)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    flat = []
    for o in outs:
        flat.extend(o if isinstance(o, (tuple, list)) else [o])
    return [np.asarray(o) for o in flat], text


def test_gepp_artifact_roundtrip():
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.uniform(size=(48, 40)))
    a = jnp.asarray(rng.uniform(size=(48, 16)))
    b = jnp.asarray(rng.uniform(size=(16, 40)))
    outs, text = roundtrip_run(model.gepp, c, a, b)
    want = ref.gemm_ref(c, a, b, alpha=-1.0)
    np.testing.assert_allclose(outs[0], np.asarray(want), atol=1e-12)
    assert "ENTRY" in text


def test_panel_artifact_roundtrip():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.uniform(size=(32, 8)))
    outs, _ = roundtrip_run(model.panel_factor, a)
    lu_r, piv_r = ref.lu_panel_ref(a)
    np.testing.assert_allclose(outs[0], np.asarray(lu_r), atol=1e-12)
    np.testing.assert_array_equal(outs[1], np.asarray(piv_r))


def test_export_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.export(out, [(48, 16)])
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"
    names = {a["name"] for a in manifest["artifacts"]}
    assert f"lu_48x16" in names
    assert any(n.startswith("gepp_") for n in names)
    assert any(n.startswith("panel_") for n in names)
    assert any(n.startswith("trsm_") for n in names)
    # Every artifact file exists and looks like HLO text.
    for a in manifest["artifacts"]:
        p = os.path.join(out, a["file"])
        assert os.path.exists(p), a["file"]
        head = open(p).read(4000)
        assert "HloModule" in head, a["file"]


def test_export_is_deterministic(tmp_path):
    out1 = str(tmp_path / "a1")
    out2 = str(tmp_path / "a2")
    aot.export(out1, [(32, 16)])
    aot.export(out2, [(32, 16)])
    t1 = open(os.path.join(out1, "lu_32x16.hlo.txt")).read()
    t2 = open(os.path.join(out2, "lu_32x16.hlo.txt")).read()
    assert t1 == t2


def test_artifact_specs_cover_all_iterations():
    specs = aot.artifact_specs(64, 16)
    names = [s["name"] for s in specs]
    # 4 iterations: panels at rows 64,48,32,16; gepp for the first 3.
    for m in (64, 48, 32, 16):
        assert f"panel_{m}x16" in names
    for mm, rest in ((48, 48), (32, 32), (16, 16)):
        assert f"gepp_{mm}x{rest}x16" in names
