//! Steal-on vs steal-off throughput on deliberately imbalanced
//! wide-and-short trailing updates (ISSUE 5, DESIGN.md §13).
//!
//! The shape is the look-ahead trailing update once the panel narrows:
//! tall `C` (many Loop-5 micro-panel rows), few Loop-4 columns — the
//! grid where a static partition leaves stragglers whenever the roster
//! is uneven. Imbalance is injected two ways:
//!
//! - a *churn* lane where members enlist under short quota leases,
//!   leave, and rejoin mid-GEMM, so the roster at arm time rarely
//!   matches the roster that finishes the job (the WS / serve-lease
//!   resize scenario the hybrid scheduler exists for);
//! - a *steady* lane with a fixed roster as the contention baseline.
//!
//! Emits machine-readable `BENCH_steal.json` (same schema family as
//! `BENCH_blis.json`) with per-lane GFLOPS for `off` / `auto` / fully
//! static, plus the headline `steal_on_over_off` aggregate ratio on the
//! imbalanced lane. A soft ≥ 0.9× floor guards against the hybrid path
//! regressing; the real ratio is what CI archives.
//!
//! Usage: `cargo bench --bench bench_steal -- [--quick] [--out FILE]`

use malleable_lu::blis::{gemm, BlisParams, StealPolicy};
use malleable_lu::cli::Args;
use malleable_lu::matrix::Matrix;
use malleable_lu::pool::{Crew, EntryPolicy};
use malleable_lu::util::json::Value;
use malleable_lu::util::stats::bench_seconds;
use malleable_lu::util::{gemm_flops, gflops};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

struct Report {
    records: Vec<Value>,
}

impl Report {
    fn push(&mut self, name: &str, shape: &[usize], members: usize, steal: &str, gf: f64) {
        self.records.push(Value::obj([
            ("name", Value::Str(name.to_string())),
            (
                "shape",
                Value::Arr(shape.iter().map(|&d| Value::Num(d as f64)).collect()),
            ),
            ("members", Value::Num(members as f64)),
            ("steal", Value::Str(steal.to_string())),
            ("gflops", Value::Num(gf)),
        ]));
    }
}

/// Measure repeated `C += A·B` on a crew with `members` enlisted
/// helpers. With `churn`, the helpers cycle through short quota leases
/// instead of staying enlisted — the imbalanced lane.
fn bench_lane(
    report: &mut Report,
    name: &str,
    (m, n, k): (usize, usize, usize),
    members: usize,
    churn: bool,
    steal: StealPolicy,
) -> f64 {
    let params = BlisParams::auto().with_steal(steal);
    let a = Matrix::random(m, k, 1);
    let b = Matrix::random(k, n, 2);
    let mut c = Matrix::zeros(m, n);
    let mut crew = Crew::new();
    let shared = crew.shared();
    let stop = Arc::new(AtomicBool::new(false));
    let helpers: Vec<_> = (0..members)
        .map(|i| {
            let s = Arc::clone(&shared);
            let st = Arc::clone(&stop);
            std::thread::spawn(move || {
                if churn {
                    while !st.load(Ordering::Acquire) {
                        let quota = AtomicUsize::new(0);
                        let st2 = Arc::clone(&st);
                        s.member_loop_while(EntryPolicy::JobBoundary, move || {
                            quota.fetch_add(1, Ordering::Relaxed) < 64 + 32 * i
                                && !st2.load(Ordering::Acquire)
                        });
                    }
                } else {
                    let st2 = Arc::clone(&st);
                    s.member_loop_while(EntryPolicy::JobBoundary, move || {
                        !st2.load(Ordering::Acquire)
                    });
                }
            })
        })
        .collect();
    if !churn {
        while crew.members() < members {
            std::thread::yield_now();
        }
    }
    let st = bench_seconds(1, 3, || {
        gemm(&mut crew, &params, 1.0, a.view(), b.view(), c.view_mut());
    });
    stop.store(true, Ordering::Release);
    crew.disband();
    for h in helpers {
        h.join().unwrap();
    }
    let gf = gflops(gemm_flops(m, n, k), st.median);
    println!(
        "{name} {m}x{n}x{k} members={members} steal={}: {gf:.2} GFLOPS",
        steal.name()
    );
    report.push(name, &[m, n, k], members, &steal.name(), gf);
    gf
}

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    let out_path = args.get_str("out", "BENCH_steal.json");
    let mut report = Report {
        records: Vec::new(),
    };

    // Wide-and-short trailing-update shapes: tall C, narrow Loop 4.
    let shape = if quick { (768, 24, 64) } else { (3072, 48, 128) };
    let members = 3;

    // Imbalanced lane: roster churns mid-GEMM.
    let churn_off = bench_lane(
        &mut report,
        "trailing_churn",
        shape,
        members,
        true,
        StealPolicy::Off,
    );
    let churn_auto = bench_lane(
        &mut report,
        "trailing_churn",
        shape,
        members,
        true,
        StealPolicy::Auto,
    );
    let _ = bench_lane(
        &mut report,
        "trailing_churn",
        shape,
        members,
        true,
        StealPolicy::Fraction(1000),
    );

    // Steady-roster lane: contention baseline.
    let steady_off = bench_lane(
        &mut report,
        "trailing_steady",
        shape,
        members,
        false,
        StealPolicy::Off,
    );
    let steady_auto = bench_lane(
        &mut report,
        "trailing_steady",
        shape,
        members,
        false,
        StealPolicy::Auto,
    );

    let ratio_churn = churn_auto / churn_off.max(1e-9);
    let ratio_steady = steady_auto / steady_off.max(1e-9);
    println!("imbalanced lane steal-on/off ratio: {ratio_churn:.3}");
    println!("steady lane steal-on/off ratio:     {ratio_steady:.3}");

    if out_path != "-" {
        let doc = Value::obj([
            ("bench", Value::Str("steal".into())),
            ("quick", Value::Bool(quick)),
            ("steal_on_over_off", Value::Num(ratio_churn)),
            ("steal_on_over_off_steady", Value::Num(ratio_steady)),
            ("records", Value::Arr(report.records)),
        ]);
        std::fs::write(&out_path, doc.dump()).expect("write bench json");
        println!("wrote {out_path}");
    }

    // Anti-regression floor: the hybrid schedule runs the identical tile
    // set, so it must stay within noise of the central ticket even on a
    // 1-core container (where both serialize); the win shows up as
    // ratio > 1 on real multi-core hosts with churn. The floor is only
    // *asserted* on full (non-quick) runs — the CI smoke lane's tiny
    // shapes on an oversubscribed shared runner are too noisy for a
    // hard gate, so there the ratio is archived and merely warned on.
    if ratio_churn <= 0.9 {
        let msg = format!("steal-on imbalanced lane ratio {ratio_churn:.3} below 0.9 floor");
        assert!(quick, "{msg}");
        println!("warning: {msg} (quick mode: not enforced)");
    }
}
