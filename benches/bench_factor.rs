//! Factorization-family throughput: LU, Cholesky, and QR driven through
//! the *same* generic WS+ET look-ahead driver, measured per kind and
//! emitted as machine-readable `BENCH_factor.json` so the trajectory is
//! tracked PR over PR (the factorization-family counterpart of
//! `bench_lu_variants`).
//!
//! Absolute numbers on the CI container are 1-core numbers; what this
//! harness guards is (a) all three kinds complete through one driver,
//! (b) their relative throughput stays in the right ballpark (Cholesky
//! does half the flops of LU, QR twice), and (c) the JSON artifact keeps
//! flowing for the perf-smoke trend.

use malleable_lu::blis::BlisParams;
use malleable_lu::cli::Args;
use malleable_lu::factor::{factorize_lookahead, FactorKind, LaOpts};
use malleable_lu::matrix::{naive, Matrix};
use malleable_lu::pool::Pool;
use malleable_lu::util::json::Value;
use malleable_lu::util::{gflops, timed};

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    let out_path = args.get_str("out", "BENCH_factor.json");
    let sizes: Vec<usize> = if quick { vec![96] } else { vec![256, 384] };
    let reps = if quick { 1 } else { 3 };
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 4);
    let (bo, bi) = if quick { (32, 8) } else { (64, 16) };
    let pool = Pool::new(threads - 1);
    let params = BlisParams::auto();
    let opts = LaOpts {
        malleable: true,
        early_term: true,
        ..Default::default()
    };

    let mut records = Vec::new();
    for &n in &sizes {
        for &kind in FactorKind::all() {
            let a0 = match kind {
                FactorKind::Chol => Matrix::random_spd(n, n as u64),
                _ => Matrix::random(n, n, n as u64),
            };
            let mut best = f64::INFINITY;
            let mut last = None;
            for _ in 0..reps {
                let mut f = a0.clone();
                let (secs, out) = timed(|| {
                    factorize_lookahead(kind, &pool, &params, &mut f, bo, bi, &opts, None)
                });
                assert!(!out.cancelled);
                assert_eq!(out.cols_done, n, "{} n={n}", kind.name());
                best = best.min(secs);
                last = Some((f, out));
            }
            // Correctness gate: a bench that factorizes garbage measures
            // nothing.
            let (f, out) = last.unwrap();
            let r = match kind {
                FactorKind::Lu => naive::lu_residual(&a0, &f, &out.ipiv),
                FactorKind::Chol => naive::chol_residual(&a0, &f),
                FactorKind::Qr => naive::qr_residual(&a0, &f, &out.tau),
            };
            assert!(r < 1e-10, "{} n={n}: residual {r}", kind.name());
            let g = gflops(kind.flops(n, n), best);
            println!("{:<5} n={n:<5} {best:.4}s  {g:.2} GFLOPS", kind.name());
            records.push(Value::obj([
                ("kind", Value::Str(kind.name().into())),
                ("n", Value::Num(n as f64)),
                ("secs", Value::Num(best)),
                ("gflops", Value::Num(g)),
            ]));
        }
    }

    if out_path != "-" {
        let doc = Value::obj([
            ("bench", Value::Str("factor".into())),
            ("quick", Value::Bool(quick)),
            ("threads", Value::Num(threads as f64)),
            ("bo", Value::Num(bo as f64)),
            ("bi", Value::Num(bi as f64)),
            ("records", Value::Arr(records)),
        ]);
        std::fs::write(&out_path, doc.dump()).expect("write bench json");
        println!("wrote {out_path}");
    }
    println!("bench_factor OK");
}
