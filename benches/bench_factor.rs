//! Factorization-family throughput: LU, Cholesky, and QR measured per
//! kind, per precision (`f32` + `f64` lanes), **and per driver family**
//! — the WS+ET look-ahead driver against the tile-DAG dataflow runtime
//! (DESIGN.md §17), head-to-head on the same pool, kernels, and block
//! sizes — emitted as machine-readable `BENCH_factor.json` so the
//! trajectory is tracked PR over PR.
//!
//! Absolute numbers on the CI container are 1-core numbers; what this
//! harness guards is (a) all three kinds complete through both driver
//! families in both precisions, (b) their relative throughput stays in
//! the right ballpark (Cholesky does half the flops of LU, QR twice),
//! and (c) the JSON artifact keeps flowing for the perf-smoke trend,
//! with `prec` and `driver` fields on every record.
//!
//! `--driver lookahead|dag|both` (default `both`) selects the lanes —
//! the CI `dag` smoke lane runs `--quick --driver dag` for one cheap
//! DAG point per kind.

use malleable_lu::blis::BlisParams;
use malleable_lu::cli::Args;
use malleable_lu::factor::{factorize_lookahead, DriverFamily, FactorCtl, FactorKind, LaOpts};
use malleable_lu::matrix::{naive, Mat};
use malleable_lu::pool::Pool;
use malleable_lu::scalar::Scalar;
use malleable_lu::tilert::factorize_dag;
use malleable_lu::util::json::Value;
use malleable_lu::util::{gflops, timed};

/// Bench one `(driver, kind, n)` cell in precision `S`; returns the
/// JSON record.
#[allow(clippy::too_many_arguments)]
fn bench_cell<S: Scalar>(
    pool: &Pool,
    params: &BlisParams,
    opts: &LaOpts,
    driver: DriverFamily,
    kind: FactorKind,
    n: usize,
    bo: usize,
    bi: usize,
    reps: usize,
) -> Value {
    let a0: Mat<S> = match kind {
        FactorKind::Chol => Mat::<S>::random_spd(n, n as u64),
        _ => Mat::<S>::random(n, n, n as u64),
    };
    let mut best = f64::INFINITY;
    let mut last = None;
    for _ in 0..reps {
        let mut f = a0.clone();
        let (secs, out) = match driver {
            DriverFamily::Lookahead => {
                timed(|| factorize_lookahead(kind, pool, params, &mut f, bo, bi, opts, None))
            }
            DriverFamily::Dag => {
                timed(|| factorize_dag(kind, pool, params, &mut f, bo, bi, &FactorCtl::default()))
            }
        };
        assert!(!out.cancelled);
        assert!(
            out.error.is_none(),
            "{} {} {}: {:?}",
            driver.name(),
            kind.name(),
            S::NAME,
            out.error
        );
        assert_eq!(
            out.cols_done,
            n,
            "{} {} {} n={n}",
            driver.name(),
            kind.name(),
            S::NAME
        );
        best = best.min(secs);
        last = Some((f, out));
    }
    // Correctness gate: a bench that factorizes garbage measures
    // nothing. Tolerances scale with the working precision's epsilon.
    let (f, out) = last.unwrap();
    let r = match kind {
        FactorKind::Lu => naive::lu_residual(&a0, &f, &out.ipiv),
        FactorKind::Chol => naive::chol_residual(&a0, &f),
        FactorKind::Qr => naive::qr_residual(&a0, &f, &out.tau),
    };
    let tol = 64.0 * n as f64 * S::EPSILON.to_f64();
    assert!(
        r < tol,
        "{} {} {} n={n}: residual {r} above {tol}",
        driver.name(),
        kind.name(),
        S::NAME
    );
    let g = gflops(kind.flops(n, n), best);
    println!(
        "{:<9} {:<5} {:<4} n={n:<5} {best:.4}s  {g:.2} GFLOPS",
        driver.name(),
        kind.name(),
        S::NAME
    );
    Value::obj([
        ("driver", Value::Str(driver.name().into())),
        ("kind", Value::Str(kind.name().into())),
        ("prec", Value::Str(S::NAME.into())),
        ("n", Value::Num(n as f64)),
        ("secs", Value::Num(best)),
        ("gflops", Value::Num(g)),
    ])
}

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    let out_path = args.get_str("out", "BENCH_factor.json");
    let driver_sel = args.get_str("driver", "both");
    let drivers: Vec<DriverFamily> = match driver_sel.as_str() {
        "both" => vec![DriverFamily::Lookahead, DriverFamily::Dag],
        s => match DriverFamily::parse(s) {
            Some(d) => vec![d],
            None => {
                eprintln!("unknown --driver {s:?} (expected lookahead|dag|both)");
                std::process::exit(2);
            }
        },
    };
    let sizes: Vec<usize> = if quick { vec![96] } else { vec![256, 384] };
    let reps = if quick { 1 } else { 3 };
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 4);
    let (bo, bi) = if quick { (32, 8) } else { (64, 16) };
    let pool = Pool::new(threads - 1);
    let params = BlisParams::auto();
    let opts = LaOpts {
        malleable: true,
        early_term: true,
        ..Default::default()
    };

    let mut records = Vec::new();
    for &driver in &drivers {
        for &n in &sizes {
            for &kind in FactorKind::all() {
                records.push(bench_cell::<f64>(
                    &pool, &params, &opts, driver, kind, n, bo, bi, reps,
                ));
                records.push(bench_cell::<f32>(
                    &pool, &params, &opts, driver, kind, n, bo, bi, reps,
                ));
            }
        }
    }

    if out_path != "-" {
        let doc = Value::obj([
            ("bench", Value::Str("factor".into())),
            ("quick", Value::Bool(quick)),
            ("threads", Value::Num(threads as f64)),
            ("bo", Value::Num(bo as f64)),
            ("bi", Value::Num(bi as f64)),
            ("records", Value::Arr(records)),
        ]);
        std::fs::write(&out_path, doc.dump()).expect("write bench json");
        println!("wrote {out_path}");
    }
    println!("bench_factor OK");
}
