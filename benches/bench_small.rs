//! Interleaved small-problem throughput (DESIGN.md §18): a stream of
//! tiny n×n LU factorizations through the SIMD-interleaved batch kernel
//! (problem-major `SmallBundle`, one vector lane per problem) vs the
//! same problems factorized one at a time with `lu_unblocked`.
//!
//! Both paths are charged end to end: the baseline pays a clone per
//! problem, the interleaved path pays pack, factor, and per-slot
//! unpack (pivots + lane matrix). The ratio is therefore the honest
//! "problems per second" win a serve queue would see, not a kernel-only
//! number. On AVX2+FMA the f32 bundle runs eight problems per
//! instruction stream and the headline n=16 ratio must clear 5x.

use malleable_lu::blis::micro::{active_kernel_name, simd_available};
use malleable_lu::blis::SmallBundle;
use malleable_lu::cli::Args;
use malleable_lu::lu::lu_unblocked;
use malleable_lu::matrix::Mat;
use malleable_lu::scalar::Scalar;
use malleable_lu::sim::HwModel;
use malleable_lu::util::json::Value;
use malleable_lu::util::stats::bench_seconds;
use std::hint::black_box;

/// One precision × one size: factor `count` problems both ways and
/// return (per-problem µs one-at-a-time, per-problem µs interleaved).
fn run_one<S: Scalar>(n: usize, count: usize, reps: usize) -> (f64, f64) {
    let mats: Vec<Mat<S>> = (0..count)
        .map(|i| Mat::<S>::random(n, n, 1 + i as u64))
        .collect();
    let w = SmallBundle::<S>::width();

    let st_seq = bench_seconds(1, reps, || {
        for a in &mats {
            let mut f = a.clone();
            let ipiv = lu_unblocked(f.view_mut());
            black_box((f.data()[0], ipiv[0]));
        }
    });

    let st_batch = bench_seconds(1, reps, || {
        let mut base = 0;
        while base < mats.len() {
            let take = w.min(mats.len() - base);
            let refs: Vec<&Mat<S>> = mats[base..base + take].iter().collect();
            let mut bundle = SmallBundle::pack(&refs);
            bundle.factor();
            for slot in 0..take {
                let f = bundle.lane_matrix(slot);
                let ipiv = bundle.pivots(slot);
                black_box((f.data()[0], ipiv[0]));
            }
            base += take;
        }
    });

    let us = |s: f64| s / count as f64 * 1e6;
    (us(st_seq.min), us(st_batch.min))
}

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    let out_path = args.get_str("out", "BENCH_small.json");
    let sizes: Vec<usize> = if quick { vec![16] } else { vec![8, 16, 32] };
    let count = if quick { 256 } else { 2048 };
    let reps = if quick { 2 } else { 5 };
    let hw = HwModel::default();
    let kernel = active_kernel_name();

    println!(
        "kernel {kernel} (simd_available {}), thresholds: f64 n<={} f32 n<={}",
        simd_available(),
        hw.small_threshold(SmallBundle::<f64>::width()),
        hw.small_threshold(SmallBundle::<f32>::width()),
    );

    let mut records = Vec::new();
    let mut ratio_f32_n16 = 0.0f64;
    for &n in &sizes {
        for prec in ["f64", "f32"] {
            let (seq_us, batch_us) = if prec == "f64" {
                run_one::<f64>(n, count, reps)
            } else {
                run_one::<f32>(n, count, reps)
            };
            let ratio = seq_us / batch_us;
            if prec == "f32" && n == 16 {
                ratio_f32_n16 = ratio;
            }
            println!(
                "{prec} n={n:2}: one-at-a-time {seq_us:8.3}us/problem  \
                 interleaved {batch_us:8.3}us/problem  ratio {ratio:5.2}x"
            );
            records.push(Value::obj([
                ("prec", Value::Str(prec.into())),
                ("n", Value::Num(n as f64)),
                ("per_problem_us", Value::Num(seq_us)),
                ("interleaved_us", Value::Num(batch_us)),
                ("ratio", Value::Num(ratio)),
            ]));
        }
    }

    if out_path != "-" {
        let doc = Value::obj([
            ("bench", Value::Str("small".into())),
            ("quick", Value::Bool(quick)),
            ("count", Value::Num(count as f64)),
            ("kernel", Value::Str(kernel.into())),
            ("simd_available", Value::Bool(simd_available())),
            (
                "threshold_f64",
                Value::Num(hw.small_threshold(SmallBundle::<f64>::width()) as f64),
            ),
            (
                "threshold_f32",
                Value::Num(hw.small_threshold(SmallBundle::<f32>::width()) as f64),
            ),
            ("records", Value::Arr(records)),
        ]);
        std::fs::write(&out_path, doc.dump()).expect("write bench json");
        println!("wrote {out_path}");
    }

    // Acceptance floor (ISSUE: >=5x at n=16 on AVX2). Quick mode on a
    // noisy shared runner records the ratio without asserting it; the
    // portable kernel has no lane-level win to demand.
    if !quick && simd_available() && kernel == "avx2+fma" {
        assert!(
            ratio_f32_n16 >= 5.0,
            "f32 n=16 interleaved ratio {ratio_f32_n16:.2}x below the 5x floor"
        );
    }
    println!("bench_small OK");
}
