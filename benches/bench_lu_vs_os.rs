//! Fig. 17 — LU_ET (static look-ahead + WS + ET) vs LU_OS (task runtime).
//!
//! Real-mode run of both coordinators plus the simulated comparison at
//! paper scale. Reported per size: wall time, GFLOPS, and the block-size
//! sensitivity the paper highlights (ET adapts, OS does not).

use malleable_lu::blis::BlisParams;
use malleable_lu::lu::{factorize, residual, LuConfig, Variant};
use malleable_lu::matrix::Matrix;
use malleable_lu::sim::{simulate, HwModel, SimVariant};
use malleable_lu::util::{gflops, lu_flops, timed};

fn run(n: usize, v: Variant, bo: usize) -> (f64, f64) {
    let a0 = Matrix::random(n, n, 3);
    let cfg = LuConfig {
        variant: v,
        bo,
        bi: 32,
        threads: 2,
        params: BlisParams::default(),
        ..Default::default()
    };
    let mut f = a0.clone();
    let (secs, out) = timed(|| factorize(&mut f, &cfg, None));
    let r = residual(&a0, &f, &out.ipiv);
    assert!(r < 1e-11, "{}: residual {r}", v.name());
    (secs, gflops(lu_flops(n, n), secs))
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let ns: &[usize] = if quick { &[256] } else { &[384, 768] };

    println!("# Fig17 real mode (t=2, 1-core host)");
    println!("n,bo,ET_secs,ET_gflops,OS_secs,OS_gflops");
    for &n in ns {
        for bo in [64, 128] {
            let (et_s, et_g) = run(n, Variant::EarlyTerm, bo);
            let (os_s, os_g) = run(n, Variant::OmpSs, bo);
            println!("{n},{bo},{et_s:.3},{et_g:.2},{os_s:.3},{os_g:.2}");
        }
    }

    // Paper-scale comparison on the simulated testbed.
    let hw = HwModel::default();
    println!("# Fig17 simulated 6-core testbed (fixed blocks: ET 192, OS 256)");
    println!("n,ET192_gflops,OS256_gflops");
    let mut et_wins = 0;
    let mut rows = 0;
    for n in [2000usize, 4000, 6000, 8000, 10000, 12000] {
        let et = simulate(&hw, SimVariant::Et, n, 192, 32, 6, 1, false).gflops;
        let os = simulate(&hw, SimVariant::Os, n, 256, 32, 6, 1, false).gflops;
        println!("{n},{et:.1},{os:.1}");
        et_wins += usize::from(et > os);
        rows += 1;
    }
    println!("# ET wins {et_wins}/{rows} sizes (paper: ET wins most, competitive at the top)");
    assert!(et_wins * 2 > rows);
}
