//! Fig. 17 — the WS+ET look-ahead driver vs the task-parallel runtime.
//!
//! The in-repo showdown the paper stages against OmpSs: the malleable
//! look-ahead driver ([`malleable_lu::factor::factorize_lookahead`] with
//! WS + ET enabled) against the tile-DAG dataflow runtime
//! ([`malleable_lu::tilert::factorize_dag`], DESIGN.md §17) on the same
//! pool, kernels, and block sizes. Real-mode numbers per size, plus the
//! simulated comparison at paper scale. Reported: wall time, GFLOPS, and
//! the block-size sensitivity the paper highlights (the look-ahead
//! driver adapts its panel width under ET; the DAG runtime does not).

use malleable_lu::blis::BlisParams;
use malleable_lu::factor::{factorize_lookahead, FactorCtl, FactorKind, LaOpts};
use malleable_lu::lu::residual;
use malleable_lu::matrix::Matrix;
use malleable_lu::pool::Pool;
use malleable_lu::sim::{simulate, HwModel, SimVariant};
use malleable_lu::tilert::factorize_dag;
use malleable_lu::util::{gflops, lu_flops, timed};

/// One WS+ET look-ahead run: returns (seconds, gflops).
fn run_lookahead(pool: &Pool, n: usize, bo: usize) -> (f64, f64) {
    let a0 = Matrix::random(n, n, 3);
    let params = BlisParams::default();
    let opts = LaOpts {
        malleable: true,
        early_term: true,
        ..Default::default()
    };
    let mut f = a0.clone();
    let (secs, out) = timed(|| {
        factorize_lookahead(FactorKind::Lu, pool, &params, &mut f, bo, 32, &opts, None)
    });
    let r = residual(&a0, &f, &out.ipiv);
    assert!(r < 1e-11, "lookahead: residual {r}");
    (secs, gflops(lu_flops(n, n), secs))
}

/// One tile-DAG run on the same pool: returns (seconds, gflops).
fn run_dag(pool: &Pool, n: usize, bo: usize) -> (f64, f64) {
    let a0 = Matrix::random(n, n, 3);
    let params = BlisParams::default();
    let mut f = a0.clone();
    let (secs, out) = timed(|| {
        factorize_dag(
            FactorKind::Lu,
            pool,
            &params,
            &mut f,
            bo,
            32,
            &FactorCtl::default(),
        )
    });
    assert!(out.error.is_none(), "dag: {:?}", out.error);
    let r = residual(&a0, &f, &out.ipiv);
    assert!(r < 1e-11, "dag: residual {r}");
    (secs, gflops(lu_flops(n, n), secs))
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let ns: &[usize] = if quick { &[256] } else { &[384, 768] };
    let pool = Pool::new(2);

    println!("# Fig17 real mode (t=2, 1-core host): lookahead(WS+ET) vs tile-DAG");
    println!("n,bo,LA_secs,LA_gflops,DAG_secs,DAG_gflops");
    for &n in ns {
        for bo in [64, 128] {
            let (la_s, la_g) = run_lookahead(&pool, n, bo);
            let (dag_s, dag_g) = run_dag(&pool, n, bo);
            println!("{n},{bo},{la_s:.3},{la_g:.2},{dag_s:.3},{dag_g:.2}");
        }
    }

    // Paper-scale comparison on the simulated testbed (the sim keeps the
    // paper's labels: Et = the WS+ET coordinator, Os = the task-parallel
    // runtime it was benchmarked against).
    let hw = HwModel::default();
    println!("# Fig17 simulated 6-core testbed (fixed blocks: ET 192, OS 256)");
    println!("n,ET192_gflops,OS256_gflops");
    let mut et_wins = 0;
    let mut rows = 0;
    for n in [2000usize, 4000, 6000, 8000, 10000, 12000] {
        let et = simulate(&hw, SimVariant::Et, n, 192, 32, 6, 1, false).gflops;
        let os = simulate(&hw, SimVariant::Os, n, 256, 32, 6, 1, false).gflops;
        println!("{n},{et:.1},{os:.1}");
        et_wins += usize::from(et > os);
        rows += 1;
    }
    println!("# ET wins {et_wins}/{rows} sizes (paper: ET wins most, competitive at the top)");
    assert!(et_wins * 2 > rows);
}
