//! Fig. 14 (left) — GEPP throughput as a function of `k = b_o`.
//!
//! Two outputs: the *real-mode* curve measured on this host's Rust BLIS
//! substrate (single thread — the container has one core), and the
//! *simulated* 6-thread curve from the calibrated testbed model. The
//! claim under reproduction is the shape: throughput ramps with `k`,
//! saturates around `k ≈ 144`, and dips just past `k_c = 256`.

use malleable_lu::blis::{gemm, BlisParams};
use malleable_lu::matrix::Matrix;
use malleable_lu::pool::Crew;
use malleable_lu::sim::HwModel;
use malleable_lu::util::stats::bench_seconds;
use malleable_lu::util::{gemm_flops, gflops};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (m, n) = if quick { (384, 384) } else { (768, 768) };
    let reps = if quick { 2 } else { 3 };
    let params = BlisParams::default();
    let hw = HwModel::default();

    println!("# Fig14-left: GEPP GFLOPS vs k");
    println!("k,real_1t_gflops,sim_6t_gflops");
    let mut k = 32;
    let mut real_prev = 0.0f64;
    let mut curve = Vec::new();
    while k <= 320 {
        let a = Matrix::random(m, k, 1);
        let b = Matrix::random(k, n, 2);
        let mut c = Matrix::zeros(m, n);
        let mut crew = Crew::new();
        let st = bench_seconds(1, reps, || {
            gemm(&mut crew, &params, 1.0, a.view(), b.view(), c.view_mut());
        });
        let real = gflops(gemm_flops(m, n, k), st.median);
        let sim = hw.gepp_gflops(k, 6);
        println!("{k},{real:.2},{sim:.1}");
        curve.push((k, real));
        real_prev = real_prev.max(real);
        k += 32;
    }
    // Shape check: the measured curve must ramp (k=32 clearly below the max).
    let first = curve.first().unwrap().1;
    let best = curve.iter().map(|&(_, g)| g).fold(0.0f64, f64::max);
    println!("# ramp check: gflops(k=32)={first:.2} vs best={best:.2}");
    assert!(
        first < best,
        "GEPP should gain throughput with k (thin-k is memory bound)"
    );
}
