//! Network-daemon soak: hundreds of concurrent protocol clients over a
//! Unix socket, mixed kinds (LU/Cholesky/QR/solve), precisions and
//! sizes, measuring per-request submit→response latency (p50/p99) and
//! aggregate factorization GFLOPS through the wire.
//!
//! The structural assertion matters more than the throughput number:
//! after the soak, every admitted request must have been answered
//! exactly once (`admitted == delivered + reaped`, with `reaped == 0`
//! since no client disconnects mid-request), no crew leases may remain
//! registered, and the pack arena must have every buffer back on its
//! free list — the daemon leaks nothing under concurrent load.

use malleable_lu::cli::Args;
use malleable_lu::factor::FactorKind;
use malleable_lu::matrix::{Mat, Matrix};
use malleable_lu::serve::client::{ServeClient, WireEvent};
use malleable_lu::serve::net::{BindAddr, NetConfig, ServeDaemon};
use malleable_lu::serve::proto;
use malleable_lu::serve::ServeConfig;
use malleable_lu::solve::SolvePrec;
use malleable_lu::util::{gflops, lu_flops};
use std::time::{Duration, Instant};

/// One client's tally, merged into the global stats after its thread
/// joins.
#[derive(Default)]
struct ClientTally {
    /// Submit→terminal-event seconds for every completed request.
    latencies: Vec<f64>,
    /// Factorization flops of the completed requests.
    flops: f64,
    /// Requests refused with a typed rejection (still "answered").
    rejected: usize,
}

/// Build and submit request `i` of client `c`, then block for its
/// terminal event. Returns `None` on a typed rejection.
fn one_request(client: &mut ServeClient, c: usize, i: usize) -> Option<(f64, f64)> {
    let pick = c * 7 + i;
    let n = [32usize, 48, 64, 96][pick % 4];
    let seed = pick as u64 + 1;
    let t0 = Instant::now();
    let (id, flops) = match pick % 5 {
        // A fifth of the stream exercises the solve path (always f64
        // systems; the mixed path is the interesting arithmetic).
        4 => {
            let a = Matrix::random_dd(n, seed);
            let b = vec![1.0; n];
            let req = proto::SolveReq {
                prec: SolvePrec::Mixed,
                priority: (pick % 3) as u8,
                deadline_ms: 0,
                bo: 0,
                bi: 0,
                a,
                b,
            };
            let id = client.submit_solve(&req).expect("submit solve");
            (id, lu_flops(n, n))
        }
        k => {
            let kind = FactorKind::all()[k % 3];
            let a = if pick % 2 == 0 {
                let a0 = match kind {
                    FactorKind::Chol => Matrix::random_spd(n, seed),
                    _ => Matrix::random(n, n, seed),
                };
                proto::WireMat::F64(a0)
            } else {
                let a0 = match kind {
                    FactorKind::Chol => Mat::<f32>::random_spd(n, seed),
                    _ => Mat::<f32>::random(n, n, seed),
                };
                proto::WireMat::F32(a0)
            };
            let req = proto::FactorReq {
                kind,
                priority: (pick % 3) as u8,
                deadline_ms: 0,
                bo: 0,
                bi: 0,
                a,
            };
            let id = client.submit_factor(&req).expect("submit factor");
            (id, kind.flops(n, n))
        }
    };
    match client.recv().expect("recv") {
        WireEvent::Factor { id: rid, resp } => {
            assert_eq!(rid, id, "completion order is per-request here");
            assert!(!resp.cancelled, "no deadline was set");
            Some((t0.elapsed().as_secs_f64(), flops))
        }
        WireEvent::Solve { id: rid, resp } => {
            assert_eq!(rid, id);
            assert!(resp.converged, "dd solve must converge");
            Some((t0.elapsed().as_secs_f64(), flops))
        }
        WireEvent::Rejected { id: rid, .. } => {
            assert_eq!(rid, id);
            None
        }
        WireEvent::Failed { id: rid, failure } => {
            panic!("well-posed request {rid} failed: {failure:?}");
        }
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    let out_path = args.get_str("out", "BENCH_serve_net.json");
    // Acceptance floor for the full soak: ≥256 concurrent clients.
    let clients = args.get("clients", if quick { 48usize } else { 256 });
    let per_client = args.get("reqs", if quick { 2usize } else { 3 });
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 4);

    let sock = std::env::temp_dir().join(format!("mlu-bench-net-{}.sock", std::process::id()));
    let addr = BindAddr::Unix(sock.clone());
    let mut cfg = NetConfig {
        serve: ServeConfig {
            workers,
            bo: 48,
            bi: 16,
            ..Default::default()
        },
        ..Default::default()
    };
    // One request in flight per client: a pending bound of `clients`
    // admits the whole soak, so rejections (counted, still answered)
    // only appear if the scheduler truly falls behind.
    cfg.admission.max_pending = clients;
    let daemon = ServeDaemon::bind(&addr, cfg).expect("bind unix socket");

    let wall = Instant::now();
    let handles: Vec<std::thread::JoinHandle<ClientTally>> = (0..clients)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut tally = ClientTally::default();
                let mut client = ServeClient::connect(&addr).expect("connect");
                for i in 0..per_client {
                    match one_request(&mut client, c, i) {
                        Some((secs, flops)) => {
                            tally.latencies.push(secs);
                            tally.flops += flops;
                        }
                        None => tally.rejected += 1,
                    }
                }
                client.goodbye().expect("goodbye");
                tally
            })
        })
        .collect();

    let mut latencies: Vec<f64> = Vec::new();
    let mut total_flops = 0.0;
    let mut rejected = 0usize;
    for h in handles {
        let t = h.join().expect("client thread");
        latencies.extend(t.latencies);
        total_flops += t.flops;
        rejected += t.rejected;
    }
    let secs = wall.elapsed().as_secs_f64();

    daemon.drain(Duration::from_secs(10));
    let stats = daemon.stats();
    let arena = daemon.arena_stats();
    daemon.shutdown();

    // Zero dropped-without-rejection: every submitted request produced
    // exactly one terminal event, and the daemon's own ledger agrees.
    let total = clients * per_client;
    assert_eq!(latencies.len() + rejected, total, "every request answered");
    assert_eq!(stats.conns_accepted as usize, clients);
    assert_eq!(
        stats.admission.admitted,
        stats.delivered + stats.reaped,
        "admitted requests must be delivered or reaped"
    );
    assert_eq!(stats.reaped, 0, "no client disconnected mid-request");
    assert_eq!(stats.malformed, 0);
    assert!(daemon.registry().is_empty(), "no leaked crew leases");
    assert_eq!(
        arena.free_buffers as u64, arena.allocations,
        "every arena buffer returned"
    );

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = percentile(&latencies, 50.0) * 1e3;
    let p99 = percentile(&latencies, 99.0) * 1e3;
    let agg = gflops(total_flops, secs);
    println!(
        "serve-net soak: {clients} clients x {per_client} reqs over {} in {secs:.3}s",
        daemon.local_addr()
    );
    println!(
        "  completed={} rejected={rejected} p50={p50:.2}ms p99={p99:.2}ms aggregate={agg:.2} GFLOPS",
        latencies.len()
    );

    if out_path != "-" {
        use malleable_lu::util::json::Value;
        let doc = Value::obj([
            ("bench", Value::Str("serve_net".into())),
            ("quick", Value::Bool(quick)),
            ("clients", Value::Num(clients as f64)),
            ("reqs_per_client", Value::Num(per_client as f64)),
            ("workers", Value::Num(workers as f64)),
            ("secs", Value::Num(secs)),
            ("completed", Value::Num(latencies.len() as f64)),
            ("rejected", Value::Num(rejected as f64)),
            ("p50_ms", Value::Num(p50)),
            ("p99_ms", Value::Num(p99)),
            ("aggregate_gflops", Value::Num(agg)),
            ("delivered", Value::Num(stats.delivered as f64)),
            ("reaped", Value::Num(stats.reaped as f64)),
        ]);
        std::fs::write(&out_path, doc.dump()).expect("write bench json");
        println!("wrote {out_path}");
    }
    println!("bench_serve_net OK");
}
