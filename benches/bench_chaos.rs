//! Supervision-overhead pin (DESIGN.md §15.5): the serve stack's
//! fault-handling machinery — per-request cancel flag, deadline fold,
//! lease progress accounting at every panel checkpoint, and (under
//! `--features chaos`) the disarmed fault-injection hooks — must cost
//! under 2% of raw factorization throughput. Robustness that taxes the
//! steady state would contradict the paper's thesis that malleability
//! mechanisms are cheap enough to leave on.
//!
//! Two timed paths over identical inputs on the same crew:
//!
//! - **raw**: `factorize_blocked` with a default (empty) `FactorCtl` —
//!   no cancel flag, no checkpoints, no supervision.
//! - **supervised**: the real serve-request driver
//!   (`serve::driver::drive`) with a live lease, cancel flag, and a
//!   far-future deadline, exactly as a daemon request runs.
//!
//! Best-of-`reps` timing on both sides squeezes scheduler noise out of
//! the ratio; the JSON records both rates and the overhead percentage.

use malleable_lu::blis::BlisParams;
use malleable_lu::cli::Args;
use malleable_lu::factor::{factorize_blocked, FactorCtl, FactorKind};
use malleable_lu::matrix::Matrix;
use malleable_lu::pool::Crew;
use malleable_lu::serve::driver::{drive, DriveCfg};
use malleable_lu::serve::Lease;
use malleable_lu::sim::HwModel;
use malleable_lu::util::gflops;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    let out_path = args.get_str("out", "BENCH_chaos.json");
    let n = args.get("n", if quick { 256usize } else { 512 });
    let reps = args.get("reps", if quick { 3usize } else { 7 });
    let max_overhead_pct = args.get("max-overhead-pct", 2.0f64);
    let (bo, bi) = (64usize, 16usize);

    let params = BlisParams::default();
    let hw = HwModel::default();
    let kind = FactorKind::Lu;
    let a0 = Matrix::random(n, n, 42);
    let mut crew = Crew::new();
    let cancel = AtomicBool::new(false);

    let run_raw = |crew: &mut Crew| {
        let mut a = a0.clone();
        let t0 = Instant::now();
        let out = factorize_blocked(kind, crew, &params, a.view_mut(), bo, bi, &FactorCtl::default());
        let secs = t0.elapsed().as_secs_f64();
        assert!(out.error.is_none() && !out.cancelled, "raw run failed");
        assert_eq!(out.cols_done, n);
        secs
    };
    let run_supervised = |crew: &mut Crew, cancel: &AtomicBool| {
        let lease = Arc::new(Lease::new(
            1,
            0,
            crew.shared(),
            kind.remaining_cost_prec::<f64>(&hw, n, n, 0, bo, bi),
        ));
        let cfg = DriveCfg {
            params: &params,
            hw: &hw,
            bo,
            bi,
            kind,
            lease: &lease,
            cancel,
            deadline: Some(Instant::now() + Duration::from_secs(3600)),
            client: None,
        };
        let mut a = a0.clone();
        let t0 = Instant::now();
        let out = drive(crew, a.view_mut(), &cfg);
        let secs = t0.elapsed().as_secs_f64();
        assert!(out.error.is_none() && !out.cancelled, "supervised run failed");
        assert_eq!(out.cols_done, n);
        secs
    };

    // Warm the arena and caches once per path before timing.
    run_raw(&mut crew);
    run_supervised(&mut crew, &cancel);

    let mut best_raw = f64::INFINITY;
    let mut best_sup = f64::INFINITY;
    for _ in 0..reps {
        // Alternate paths so slow drift (thermal, competing load) hits
        // both sides evenly instead of biasing one.
        best_raw = best_raw.min(run_raw(&mut crew));
        best_sup = best_sup.min(run_supervised(&mut crew, &cancel));
    }

    let flops = kind.flops(n, n);
    let raw_gf = gflops(flops, best_raw);
    let sup_gf = gflops(flops, best_sup);
    let overhead_pct = (best_sup / best_raw - 1.0) * 100.0;
    let hooks = cfg!(feature = "chaos");

    println!("chaos supervision overhead: n={n} bo={bo} bi={bi} reps={reps} hooks_compiled={hooks}");
    println!("  raw        {raw_gf:8.2} GFLOPS  ({:.1} ms)", best_raw * 1e3);
    println!("  supervised {sup_gf:8.2} GFLOPS  ({:.1} ms)", best_sup * 1e3);
    println!("  overhead   {overhead_pct:+.2}%  (limit {max_overhead_pct:.1}%)");

    if out_path != "-" {
        use malleable_lu::util::json::Value;
        let doc = Value::obj([
            ("bench", Value::Str("chaos".into())),
            ("quick", Value::Bool(quick)),
            ("n", Value::Num(n as f64)),
            ("reps", Value::Num(reps as f64)),
            ("hooks_compiled", Value::Bool(hooks)),
            ("raw_gflops", Value::Num(raw_gf)),
            ("supervised_gflops", Value::Num(sup_gf)),
            ("overhead_pct", Value::Num(overhead_pct)),
            ("max_overhead_pct", Value::Num(max_overhead_pct)),
        ]);
        std::fs::write(&out_path, doc.dump()).expect("write bench json");
        println!("wrote {out_path}");
    }

    assert!(
        overhead_pct < max_overhead_pct,
        "supervision overhead {overhead_pct:.2}% exceeds the {max_overhead_pct:.1}% budget \
         (raw {raw_gf:.2} vs supervised {sup_gf:.2} GFLOPS)"
    );
    println!("bench_chaos OK");
}
