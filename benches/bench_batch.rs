//! Batched multi-problem throughput: 8 mixed-size LU factorizations on a
//! shared malleable pool (the serve layer) vs the same problems
//! factorized one at a time, each with the full pool.
//!
//! This is the cross-problem generalization of the paper's
//! Worker-Sharing claim: a sequential full-pool run pays the panel
//! bottleneck and crew synchronization on every kernel of every problem,
//! while the batched scheduler overlaps problems so an idle worker
//! always has a starved factorization to join.

use malleable_lu::cli::Args;
use malleable_lu::lu::{self, LuConfig, Variant};
use malleable_lu::matrix::Matrix;
use malleable_lu::pool::Pool;
use malleable_lu::serve::{self, ServeConfig};
use malleable_lu::util::json::Value;
use malleable_lu::util::{gflops, lu_flops, timed};

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    let out_path = args.get_str("out", "BENCH_batch.json");
    let sizes: Vec<usize> = if quick {
        vec![96, 128, 80, 112]
    } else {
        vec![192, 256, 160, 288, 224, 320, 208, 256]
    };
    let reps = if quick { 1 } else { 3 };
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 4);
    let bo = 48;
    let bi = 16;
    let total: f64 = sizes.iter().map(|&n| lu_flops(n, n)).sum();
    let mats = || -> Vec<Matrix> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| Matrix::random(n, n, 1 + i as u64))
            .collect()
    };

    // Batched: all 8 problems multiplexed over one shared pool.
    let cfg = ServeConfig {
        workers,
        bo,
        bi,
        ..Default::default()
    };
    let mut batched = f64::INFINITY;
    for _ in 0..reps {
        let (secs, results) = timed(|| serve::factorize_batch(mats(), &cfg));
        assert_eq!(results.len(), sizes.len());
        assert!(results.iter().all(|r| !r.cancelled && r.cols_done == r.a.rows()));
        batched = batched.min(secs);
    }

    // Sequential baseline: one problem at a time, full team each.
    let pool = Pool::new(workers.saturating_sub(1));
    let lcfg = LuConfig {
        variant: Variant::BlockedRl,
        bo,
        bi,
        threads: workers,
        ..Default::default()
    };
    let mut seq = f64::INFINITY;
    for _ in 0..reps {
        let (secs, _) = timed(|| {
            for mut a in mats() {
                let _ = lu::factorize(&mut a, &lcfg, Some(&pool));
            }
        });
        seq = seq.min(secs);
    }

    let bg = gflops(total, batched);
    let sg = gflops(total, seq);
    println!(
        "batched   : {batched:.3}s  {bg:.2} aggregate GFLOPS ({} problems, {workers} workers)",
        sizes.len()
    );
    println!("sequential: {seq:.3}s  {sg:.2} aggregate GFLOPS (full pool per problem)");
    println!("speedup   : {:.2}x (batched vs sequential)", seq / batched);

    if out_path != "-" {
        let doc = Value::obj([
            ("bench", Value::Str("batch".into())),
            ("quick", Value::Bool(quick)),
            (
                "shape",
                Value::Arr(sizes.iter().map(|&n| Value::Num(n as f64)).collect()),
            ),
            ("threads", Value::Num(workers as f64)),
            (
                "records",
                Value::Arr(vec![
                    Value::obj([
                        ("name", Value::Str("batched".into())),
                        ("variant", Value::Str("serve".into())),
                        ("gflops", Value::Num(bg)),
                        ("secs", Value::Num(batched)),
                    ]),
                    Value::obj([
                        ("name", Value::Str("sequential".into())),
                        ("variant", Value::Str("full-pool".into())),
                        ("gflops", Value::Num(sg)),
                        ("secs", Value::Num(seq)),
                    ]),
                ]),
            ),
            ("speedup", Value::Num(seq / batched)),
        ]);
        std::fs::write(&out_path, doc.dump()).expect("write bench json");
        println!("wrote {out_path}");
    }

    // Regression floor: batched scheduling must never lose meaningfully
    // to sequential; on multi-core hosts it should win outright (the
    // acceptance target of the serve layer). Quick mode (CI smoke on
    // noisy shared runners, reps=1, tiny problems) asserts completion
    // only — single-rep timing there is not a regression signal.
    if !quick {
        assert!(
            bg > 0.8 * sg,
            "batched scheduling lost >20% vs sequential: {bg:.2} vs {sg:.2} GFLOPS"
        );
    }
    println!("bench_batch OK");
}
