//! Batched multi-problem throughput: 8 mixed-size LU factorizations on a
//! shared malleable pool (the serve layer) vs the same problems
//! factorized one at a time, each with the full pool.
//!
//! This is the cross-problem generalization of the paper's
//! Worker-Sharing claim: a sequential full-pool run pays the panel
//! bottleneck and crew synchronization on every kernel of every problem,
//! while the batched scheduler overlaps problems so an idle worker
//! always has a starved factorization to join.

use malleable_lu::lu::{self, LuConfig, Variant};
use malleable_lu::matrix::Matrix;
use malleable_lu::pool::Pool;
use malleable_lu::serve::{self, ServeConfig};
use malleable_lu::util::{gflops, lu_flops, timed};

fn main() {
    let sizes = [192usize, 256, 160, 288, 224, 320, 208, 256];
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 4);
    let bo = 48;
    let bi = 16;
    let total: f64 = sizes.iter().map(|&n| lu_flops(n, n)).sum();
    let mats = || -> Vec<Matrix> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| Matrix::random(n, n, 1 + i as u64))
            .collect()
    };

    // Batched: all 8 problems multiplexed over one shared pool.
    let cfg = ServeConfig {
        workers,
        bo,
        bi,
        ..Default::default()
    };
    let mut batched = f64::INFINITY;
    for _ in 0..3 {
        let (secs, results) = timed(|| serve::factorize_batch(mats(), &cfg));
        assert_eq!(results.len(), sizes.len());
        assert!(results.iter().all(|r| !r.cancelled && r.cols_done == r.a.rows()));
        batched = batched.min(secs);
    }

    // Sequential baseline: one problem at a time, full team each.
    let pool = Pool::new(workers.saturating_sub(1));
    let lcfg = LuConfig {
        variant: Variant::BlockedRl,
        bo,
        bi,
        threads: workers,
        ..Default::default()
    };
    let mut seq = f64::INFINITY;
    for _ in 0..3 {
        let (secs, _) = timed(|| {
            for mut a in mats() {
                let _ = lu::factorize(&mut a, &lcfg, Some(&pool));
            }
        });
        seq = seq.min(secs);
    }

    let bg = gflops(total, batched);
    let sg = gflops(total, seq);
    println!(
        "batched   : {batched:.3}s  {bg:.2} aggregate GFLOPS ({} problems, {workers} workers)",
        sizes.len()
    );
    println!("sequential: {seq:.3}s  {sg:.2} aggregate GFLOPS (full pool per problem)");
    println!("speedup   : {:.2}x (batched vs sequential)", seq / batched);
    // Regression floor: batched scheduling must never lose meaningfully
    // to sequential; on multi-core hosts it should win outright (the
    // acceptance target of the serve layer).
    assert!(
        bg > 0.8 * sg,
        "batched scheduling lost >20% vs sequential: {bg:.2} vs {sg:.2} GFLOPS"
    );
    println!("bench_batch OK");
}
