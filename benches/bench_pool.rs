//! Crew dispatch overhead: the cost of publishing a job, the per-chunk
//! atomics, and the enlist→first-contribution latency (DESIGN.md §9
//! targets: publication < 5 µs).

use malleable_lu::pool::{Crew, EntryPolicy, Pool};
use malleable_lu::util::stats::bench_seconds;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn main() {
    // Leader-only job publication cost.
    let mut crew = Crew::new();
    let sink = AtomicUsize::new(0);
    let st = bench_seconds(100, 10_000, || {
        crew.parallel(1, |_| {
            sink.fetch_add(1, Ordering::Relaxed);
        });
    });
    println!("publish+run 1 chunk (leader only): {:.2} µs", st.median * 1e6);

    // Per-chunk cost at higher chunk counts.
    let st64 = bench_seconds(10, 1_000, || {
        crew.parallel(64, |_| {
            sink.fetch_add(1, Ordering::Relaxed);
        });
    });
    println!(
        "64-chunk job: {:.2} µs total, {:.3} µs/chunk",
        st64.median * 1e6,
        st64.median * 1e6 / 64.0
    );

    // Enlist latency: publish jobs until a freshly submitted member
    // executes its first chunk.
    let pool = Pool::new(1);
    let mut crew2 = Crew::new();
    let mut joins = Vec::new();
    for _ in 0..50 {
        let shared = crew2.shared();
        let t0 = std::time::Instant::now();
        let h = pool.submit(0, move || shared.member_loop(EntryPolicy::Immediate));
        // Spin jobs until the member contributes.
        let hit = Arc::new(AtomicUsize::new(0));
        while crew2.members() == 0 {
            let hit2 = Arc::clone(&hit);
            crew2.parallel(4, move |_| {
                hit2.fetch_add(1, Ordering::Relaxed);
            });
        }
        joins.push(t0.elapsed().as_secs_f64());
        crew2.disband();
        h.wait();
        crew2 = Crew::new();
    }
    let st = malleable_lu::util::Stats::of(&joins);
    println!(
        "enlist→active latency: median {:.1} µs (min {:.1} µs)",
        st.median * 1e6,
        st.min * 1e6
    );

    // Throughput sanity: dispatch must be far cheaper than a macro-kernel
    // job (~100 µs at paper scale).
    assert!(st64.median / 64.0 < 50e-6, "chunk overhead too high");
    println!("pool bench OK");
}
