//! Fig. 16 — GFLOPS of LU / LU_LA / LU_MB / LU_ET at fixed `b_o`.
//!
//! Real-mode wall-clock on this host (scaled problem sizes; threads
//! oversubscribe the single container core, so the *simulated* Fig. 16
//! from `mlu fig 16` carries the performance claim — this bench proves
//! the real implementations run end-to-end and reports their wall time
//! and scheduling statistics side by side).

use malleable_lu::blis::BlisParams;
use malleable_lu::lu::{factorize, residual, LuConfig, Variant};
use malleable_lu::matrix::Matrix;
use malleable_lu::util::{gflops, lu_flops, timed};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let ns: &[usize] = if quick { &[256, 512] } else { &[256, 512, 1024] };
    let bo = 128;
    let variants = [
        Variant::BlockedRl,
        Variant::LookAhead,
        Variant::Malleable,
        Variant::EarlyTerm,
    ];
    println!("# Fig16 (real mode, bo={bo}, t=2 on 1-core host)");
    println!("n,variant,secs,gflops,et_cuts,residual");
    for &n in ns {
        let a0 = Matrix::random(n, n, n as u64);
        for v in variants {
            let cfg = LuConfig {
                variant: v,
                bo,
                bi: 32,
                threads: 2,
                params: BlisParams::default(),
                ..Default::default()
            };
            let mut f = a0.clone();
            let (secs, out) = timed(|| factorize(&mut f, &cfg, None));
            let r = residual(&a0, &f, &out.ipiv);
            let cuts = out.la_stats.as_ref().map(|s| s.et_cuts).unwrap_or(0);
            println!(
                "{n},{},{secs:.3},{:.2},{cuts},{r:.2e}",
                v.name(),
                gflops(lu_flops(n, n), secs)
            );
            assert!(r < 1e-11, "{} residual {r}", v.name());
        }
    }
}
