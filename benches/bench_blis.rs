//! Micro-benchmarks of the BLIS substrate: GEMM (SIMD vs portable vs the
//! naive triple loop, in **both sealed precisions**), TRSM, LASWP and
//! packing — the §Perf baseline numbers, emitted both human-readable and
//! as machine-readable `BENCH_blis.json` so the perf trajectory is
//! tracked PR over PR. Every record carries a `prec` field (`f32` |
//! `f64`); the headline precision comparison is the `gemm` lane pair —
//! on AVX2 the `f32` kernel's doubled lane width should deliver ≥ 1.5×
//! the `f64` GFLOPS (ISSUE 4 acceptance).
//!
//! Usage: `cargo bench --bench bench_blis -- [--quick] [--out FILE]`
//! (`--quick` shrinks sizes for CI smoke; `--out` defaults to
//! `BENCH_blis.json`, `--out -` skips the file).

use malleable_lu::blis::micro::{active_kernel_name, set_kernel, simd_available, Kernel};
use malleable_lu::blis::pack::{pack_a, pack_b, PackedA, PackedB};
use malleable_lu::blis::{gemm, laswp, trsm_llu, BlisParams};
use malleable_lu::cli::Args;
use malleable_lu::matrix::{naive, Mat, Matrix};
use malleable_lu::pool::Crew;
use malleable_lu::scalar::Scalar;
use malleable_lu::util::json::Value;
use malleable_lu::util::stats::bench_seconds;
use malleable_lu::util::{gemm_flops, gflops, trsm_flops};

/// One measurement, printed and accumulated for the JSON report.
struct Report {
    records: Vec<Value>,
}

impl Report {
    fn push(
        &mut self,
        name: &str,
        shape: &[usize],
        threads: usize,
        variant: &str,
        prec: &str,
        gf: f64,
    ) {
        self.records.push(Value::obj([
            ("name", Value::Str(name.to_string())),
            (
                "shape",
                Value::Arr(shape.iter().map(|&d| Value::Num(d as f64)).collect()),
            ),
            ("threads", Value::Num(threads as f64)),
            ("variant", Value::Str(variant.to_string())),
            ("prec", Value::Str(prec.to_string())),
            ("gflops", Value::Num(gf)),
        ]));
    }
}

/// Time one `n³` GEMM in precision `S` under the given kernel override.
fn bench_gemm_kernel<S: Scalar>(
    report: &mut Report,
    crew: &mut Crew,
    params: &BlisParams,
    n: usize,
    kernel: Kernel,
    label: &str,
) -> f64 {
    set_kernel(kernel);
    let a = Mat::<S>::random(n, n, 1);
    let b = Mat::<S>::random(n, n, 2);
    let mut c = Mat::<S>::zeros(n, n);
    let st = bench_seconds(1, 3, || {
        gemm(crew, params, S::ONE, a.view(), b.view(), c.view_mut());
    });
    set_kernel(Kernel::Auto);
    let gf = gflops(gemm_flops(n, n, n), st.median);
    println!("gemm {n}^3 [{label}, {}]: {gf:.2} GFLOPS", S::NAME);
    report.push("gemm", &[n, n, n], 1, label, S::NAME, gf);
    gf
}

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    let out_path = args.get_str("out", "BENCH_blis.json");
    let n = if quick { 256 } else { 512 };
    let params = BlisParams::auto();
    let mut crew = Crew::new();
    let mut report = Report {
        records: Vec::new(),
    };
    println!(
        "bench_blis: params={params:?} kernel={} (simd available: {})",
        active_kernel_name(),
        simd_available()
    );

    // GEMM: per-precision lanes — SIMD (when available) vs portable.
    let blis_g = bench_gemm_kernel::<f64>(&mut report, &mut crew, &params, n, Kernel::Auto, "auto");
    let blis_g32 =
        bench_gemm_kernel::<f32>(&mut report, &mut crew, &params, n, Kernel::Auto, "auto");
    if simd_available() {
        bench_gemm_kernel::<f64>(
            &mut report,
            &mut crew,
            &params,
            n,
            Kernel::Portable,
            "portable",
        );
        bench_gemm_kernel::<f32>(
            &mut report,
            &mut crew,
            &params,
            n,
            Kernel::Portable,
            "portable",
        );
    }
    let ratio = blis_g32 / blis_g.max(1e-9);
    println!("gemm {n}^3: f32/f64 throughput ratio {ratio:.2}x");
    // The acceptance shape: single-thread 1024^3 (skipped in quick mode).
    if !quick {
        bench_gemm_kernel::<f64>(&mut report, &mut crew, &params, 1024, Kernel::Auto, "auto");
        bench_gemm_kernel::<f32>(&mut report, &mut crew, &params, 1024, Kernel::Auto, "auto");
    }
    let a = Matrix::random(n, n, 1);
    let b = Matrix::random(n, n, 2);
    let mut c2 = Matrix::zeros(n, n);
    let st_naive = bench_seconds(0, 1, || {
        naive::gemm(1.0, a.view(), b.view(), c2.view_mut());
    });
    let naive_g = gflops(gemm_flops(n, n, n), st_naive.median);
    println!(
        "gemm {n}^3: blis {blis_g:.2} GFLOPS vs naive {naive_g:.2} GFLOPS ({:.1}x)",
        blis_g / naive_g
    );
    report.push("gemm_naive", &[n, n, n], 1, "naive", "f64", naive_g);

    // GEPP shape (k = 128) — the LU trailing-update workload.
    let k = 128;
    let a = Matrix::random(n, k, 3);
    let b = Matrix::random(k, n, 4);
    let mut c = Matrix::zeros(n, n);
    let st = bench_seconds(1, 3, || {
        gemm(&mut crew, &params, -1.0, a.view(), b.view(), c.view_mut());
    });
    let gepp_g = gflops(gemm_flops(n, n, k), st.median);
    println!("gepp {n}x{n}x{k}: {gepp_g:.2} GFLOPS");
    report.push("gepp", &[n, n, k], 1, "auto", "f64", gepp_g);

    // Wide-and-short GEMM: the shape the Loop-5 chunking targets.
    let (wm, wn, wk) = (8 * n, 24, 64);
    let a = Matrix::random(wm, wk, 13);
    let b = Matrix::random(wk, wn, 14);
    let mut c = Matrix::zeros(wm, wn);
    let st = bench_seconds(1, 3, || {
        gemm(&mut crew, &params, -1.0, a.view(), b.view(), c.view_mut());
    });
    let ws_g = gflops(gemm_flops(wm, wn, wk), st.median);
    println!("gemm wide-short {wm}x{wn}x{wk}: {ws_g:.2} GFLOPS");
    report.push("gemm_wide_short", &[wm, wn, wk], 1, "auto", "f64", ws_g);

    // TRSM.
    let l = Matrix::random(n, n, 5);
    let mut x = Matrix::random(n, n, 6);
    let st = bench_seconds(1, 3, || {
        trsm_llu(&mut crew, &params, l.view(), x.view_mut());
    });
    let trsm_g = gflops(trsm_flops(n, n), st.median);
    println!("trsm {n}x{n}: {trsm_g:.2} GFLOPS");
    report.push("trsm", &[n, n], 1, "auto", "f64", trsm_g);

    // LASWP bandwidth (column-strip blocked).
    let mut m = Matrix::random(n, n, 7);
    let ipiv: Vec<usize> = (0..n / 2).map(|i| n / 2 + i).collect();
    let st = bench_seconds(1, 5, || {
        laswp(&mut crew, m.view_mut(), &ipiv, 0, ipiv.len(), 0, n);
    });
    let bytes = (ipiv.len() * n * 32) as f64;
    let laswp_gbs = bytes / st.median / 1e9;
    println!("laswp {}swaps x {n}cols: {laswp_gbs:.2} GB/s", ipiv.len());
    report.push("laswp_gbs", &[ipiv.len(), n], 1, "auto", "f64", laswp_gbs);

    // Packing rates (arena-leased in the GEMM hot path; here we time the
    // copy itself on pre-allocated buffers).
    let src = Matrix::random(params.mc, params.kc, 8);
    let mut pa = PackedA::with_capacity(params.mc, params.kc);
    let st = bench_seconds(2, 5, || {
        pack_a(&mut crew, src.view(), &mut pa);
    });
    let packa_gbs = (params.mc * params.kc * 16) as f64 / st.median / 1e9;
    println!("pack_a {}x{}: {packa_gbs:.2} GB/s", params.mc, params.kc);
    report.push(
        "pack_a_gbs",
        &[params.mc, params.kc],
        1,
        "auto",
        "f64",
        packa_gbs,
    );
    let srcb = Matrix::random(params.kc, 1024, 9);
    let mut pb = PackedB::with_capacity(params.kc, 1024);
    let st = bench_seconds(2, 5, || {
        pack_b(&mut crew, srcb.view(), &mut pb);
    });
    let packb_gbs = (params.kc * 1024 * 16) as f64 / st.median / 1e9;
    println!("pack_b {}x1024: {packb_gbs:.2} GB/s", params.kc);
    report.push(
        "pack_b_gbs",
        &[params.kc, 1024],
        1,
        "auto",
        "f64",
        packb_gbs,
    );

    if out_path != "-" {
        let doc = Value::obj([
            ("bench", Value::Str("blis".into())),
            ("quick", Value::Bool(quick)),
            ("simd_available", Value::Bool(simd_available())),
            ("f32_over_f64_gemm", Value::Num(ratio)),
            ("records", Value::Arr(report.records)),
        ]);
        std::fs::write(&out_path, doc.dump()).expect("write bench json");
        println!("wrote {out_path}");
    }

    // On FMA-less x86 (or when MLU_KERNEL=portable pins the scalar
    // kernels, as the CI no-AVX2 job does) the portable path pays a
    // software fma() per multiply-accumulate to keep the cross-kernel
    // bitwise contract (DESIGN.md §9) — no perf floor is claimed there,
    // so the asserts key on the *active* kernel, not the hardware.
    if simd_available() && active_kernel_name() == "avx2+fma" {
        assert!(blis_g > naive_g, "blocked GEMM must beat the naive loop");
        // The f32 kernel runs 8 lanes against f64's 4: the ISSUE-4 target
        // is ≥ 1.5×; assert a softer 1.2× floor so a noisy CI container
        // does not flake, and report the real ratio in the JSON above.
        assert!(
            ratio > 1.2,
            "f32 GEMM should outrun f64 on AVX2 (got {ratio:.2}x)"
        );
    } else {
        println!("note: no AVX2+FMA — fused portable fallback; perf floors not asserted");
    }
}
