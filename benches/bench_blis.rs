//! Micro-benchmarks of the BLIS substrate: GEMM vs the naive triple
//! loop, TRSM, LASWP and packing — the §Perf baseline numbers
//! (EXPERIMENTS.md).

use malleable_lu::blis::pack::{pack_a, pack_b, PackedA, PackedB};
use malleable_lu::blis::{gemm, laswp, trsm_llu, BlisParams};
use malleable_lu::matrix::{naive, Matrix};
use malleable_lu::pool::Crew;
use malleable_lu::util::stats::bench_seconds;
use malleable_lu::util::{gemm_flops, gflops, trsm_flops};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n = if quick { 256 } else { 512 };
    let params = BlisParams::default();
    let mut crew = Crew::new();

    // GEMM: blocked vs naive.
    let a = Matrix::random(n, n, 1);
    let b = Matrix::random(n, n, 2);
    let mut c = Matrix::zeros(n, n);
    let st = bench_seconds(1, 3, || {
        gemm(&mut crew, &params, 1.0, a.view(), b.view(), c.view_mut());
    });
    let blis_g = gflops(gemm_flops(n, n, n), st.median);
    let mut c2 = Matrix::zeros(n, n);
    let st_naive = bench_seconds(0, 1, || {
        naive::gemm(1.0, a.view(), b.view(), c2.view_mut());
    });
    let naive_g = gflops(gemm_flops(n, n, n), st_naive.median);
    println!(
        "gemm {n}^3: blis {blis_g:.2} GFLOPS vs naive {naive_g:.2} GFLOPS ({:.1}x)",
        blis_g / naive_g
    );

    // GEPP shape (k = 128).
    let k = 128;
    let a = Matrix::random(n, k, 3);
    let b = Matrix::random(k, n, 4);
    let mut c = Matrix::zeros(n, n);
    let st = bench_seconds(1, 3, || {
        gemm(&mut crew, &params, -1.0, a.view(), b.view(), c.view_mut());
    });
    println!(
        "gepp {n}x{n}x{k}: {:.2} GFLOPS",
        gflops(gemm_flops(n, n, k), st.median)
    );

    // TRSM.
    let l = Matrix::random(n, n, 5);
    let mut x = Matrix::random(n, n, 6);
    let st = bench_seconds(1, 3, || {
        trsm_llu(&mut crew, &params, l.view(), x.view_mut());
    });
    println!(
        "trsm {n}x{n}: {:.2} GFLOPS",
        gflops(trsm_flops(n, n), st.median)
    );

    // LASWP bandwidth.
    let mut m = Matrix::random(n, n, 7);
    let ipiv: Vec<usize> = (0..n / 2).map(|i| n / 2 + i).collect();
    let st = bench_seconds(1, 5, || {
        laswp(&mut crew, m.view_mut(), &ipiv, 0, ipiv.len(), 0, n);
    });
    let bytes = (ipiv.len() * n * 32) as f64;
    println!(
        "laswp {}swaps x {n}cols: {:.2} GB/s",
        ipiv.len(),
        bytes / st.median / 1e9
    );

    // Packing rates.
    let src = Matrix::random(params.mc, params.kc, 8);
    let mut pa = PackedA::with_capacity(params.mc, params.kc);
    let st = bench_seconds(2, 5, || {
        pack_a(&mut crew, src.view(), &mut pa);
    });
    println!(
        "pack_a {}x{}: {:.2} GB/s",
        params.mc,
        params.kc,
        (params.mc * params.kc * 16) as f64 / st.median / 1e9
    );
    let srcb = Matrix::random(params.kc, 1024, 9);
    let mut pb = PackedB::with_capacity(params.kc, 1024);
    let st = bench_seconds(2, 5, || {
        pack_b(&mut crew, srcb.view(), &mut pb);
    });
    println!(
        "pack_b {}x1024: {:.2} GB/s",
        params.kc,
        (params.kc * 1024 * 16) as f64 / st.median / 1e9
    );

    assert!(blis_g > naive_g, "blocked GEMM must beat the naive loop");
}
