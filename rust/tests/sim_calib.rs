//! Cost-model drift guard (DESIGN.md §16.5): the `sim` cost model
//! prices the counterfactual sweeps of `mlu replay`, so a model that
//! has drifted from what the real BLIS substrate delivers silently
//! corrupts every policy recommendation. This suite cross-checks the
//! model against GEMM rates **measured in-process** (no `BENCH_blis.json`
//! fixture is checked in — CI produces that artifact fresh each run)
//! and pins [`HwModel::calibrate_from_gemm`], the documented
//! recalibration path.
//!
//! Tolerances, documented here once:
//!
//! - **Anchor inversion is exact** (relative error < 1e-9): calibration
//!   solves for `core_gemm_peak` in closed form, so the calibrated model
//!   must reproduce its own anchor measurement regardless of how fast
//!   the host is. This part is machine-independent.
//! - **Cross-shape agreement within a factor of 4**: after calibrating
//!   on one `k`, predictions at other `k` depend only on the model's
//!   *shape* (the `k`-ramp, width efficiency, fixed overhead). Real
//!   hosts differ from the paper's Haswell shape, and shared CI runners
//!   add timing noise on millisecond kernels, so the band is deliberately
//!   wide — it catches order-of-magnitude drift (a broken ramp, a
//!   misplaced overhead term), not percent-level miscalibration.

use malleable_lu::blis::{gemm, BlisParams};
use malleable_lu::matrix::Matrix;
use malleable_lu::pool::Crew;
use malleable_lu::sim::costmodel::HwModel;
use malleable_lu::util::stats::bench_seconds;
use malleable_lu::util::{gemm_flops, gflops};

/// Median wall seconds of `C(n×n) += A(n×k)·B(k×n)` on the leader-only
/// crew (t = 1), after one warm-up rep (first call pays arena growth).
fn measure_gemm_secs(n: usize, k: usize) -> f64 {
    let params = BlisParams::default();
    let mut crew = Crew::new();
    let a = Matrix::random(n, k, 1);
    let b = Matrix::random(k, n, 2);
    let mut c = Matrix::zeros(n, n);
    let st = bench_seconds(1, 3, || {
        gemm(&mut crew, &params, 1.0, a.view(), b.view(), c.view_mut());
    });
    st.median
}

#[test]
fn calibration_reproduces_its_anchor_measurement_exactly() {
    let (n, k) = (256, 96);
    let secs = measure_gemm_secs(n, k);
    assert!(secs > 0.0, "measurement must take time");
    let cal = HwModel::default().calibrate_from_gemm(n, n, k, 1, secs);
    let predicted = cal.gemm_time(n, n, k, 1);
    let rel = (predicted - secs).abs() / secs;
    assert!(
        rel < 1e-9,
        "calibrated model must invert its anchor: predicted {predicted:.6}s, \
         measured {secs:.6}s (rel {rel:.2e})"
    );
    // The calibrated peak is a real, positive rate for this host.
    assert!(cal.core_gemm_peak > 0.0);
    assert!(cal.machine_peak() > 0.0);
}

#[test]
fn calibrated_model_tracks_measured_gflops_across_shapes() {
    let n = 256;
    let anchor_k = 96;
    let anchor_secs = measure_gemm_secs(n, anchor_k);
    let cal = HwModel::default().calibrate_from_gemm(n, n, anchor_k, 1, anchor_secs);
    // Cross-check shapes the anchor never saw: below the ramp knee and
    // at the asymptote. Factor-4 band — see the module docs for why.
    for k in [32usize, 256] {
        let measured_secs = measure_gemm_secs(n, k);
        let measured_gf = gflops(gemm_flops(n, n, k), measured_secs);
        let predicted_gf = gflops(gemm_flops(n, n, k), cal.gemm_time(n, n, k, 1));
        let ratio = measured_gf / predicted_gf;
        assert!(
            (0.25..=4.0).contains(&ratio),
            "cost-model drift at k={k}: measured {measured_gf:.2} GFLOPS, \
             sim-predicted {predicted_gf:.2} GFLOPS (ratio {ratio:.2})"
        );
    }
}

#[test]
fn uncalibrated_model_shape_orders_measurements() {
    // Even before calibration, the model's qualitative claims must hold
    // on the real substrate: the k-ramp means a k=96 GEPP runs at a
    // higher rate than a k=8 one. This is the shape the sweeps lean on
    // when ranking steal policies.
    let n = 256;
    let gf_at = |k: usize| gflops(gemm_flops(n, n, k), measure_gemm_secs(n, k));
    let low = gf_at(8);
    let high = gf_at(96);
    assert!(
        high > low,
        "measured GEPP rate must ramp with k (k=8: {low:.2}, k=96: {high:.2} GFLOPS)"
    );
    let hw = HwModel::default();
    assert!(hw.gepp_gflops(96, 1) > hw.gepp_gflops(8, 1));
}
