//! End-to-end tests for the `mlu serve` network daemon: wire roundtrips
//! over Unix and TCP sockets, protocol robustness (malformed, truncated
//! and oversized frames, version mismatch), admission backpressure,
//! mid-request disconnects, graceful drain under load, and a
//! many-client soak.
//!
//! The recurring invariant is the daemon's ledger (DESIGN.md §14.6):
//! after the connections settle, `admitted == delivered + reaped`, the
//! crew registry is empty, and the pack arena has every buffer back on
//! its free list — nothing leaks, nothing is silently dropped.

use malleable_lu::factor::FactorKind;
use malleable_lu::matrix::{naive, Mat, Matrix};
use malleable_lu::scalar::Scalar;
use malleable_lu::serve::client::{ServeClient, WireEvent};
use malleable_lu::serve::net::{BindAddr, NetConfig, ServeDaemon};
use malleable_lu::serve::proto::{self, FailCode, ReadEvent, RejectCode};
use malleable_lu::serve::ServeConfig;
use malleable_lu::solve::SolvePrec;
use std::io::Write;
use std::time::{Duration, Instant};

fn cfg(workers: usize) -> NetConfig {
    NetConfig {
        serve: ServeConfig {
            workers,
            bo: 48,
            bi: 16,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// A collision-free Unix socket path for one test.
fn unix_addr(tag: &str) -> BindAddr {
    let p = std::env::temp_dir().join(format!("mlu-test-{tag}-{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&p);
    BindAddr::Unix(p)
}

fn factor_req(kind: FactorKind, a: proto::WireMat) -> proto::FactorReq {
    proto::FactorReq {
        kind,
        priority: 0,
        deadline_ms: 0,
        bo: 0,
        bi: 0,
        a,
    }
}

/// Poll until every admitted request has been delivered or reaped and
/// the compute layer holds no lease — the settled-ledger state every
/// test ends in.
fn await_settled(daemon: &ServeDaemon, timeout: Duration) {
    let t0 = Instant::now();
    loop {
        let s = daemon.stats();
        if s.admission.admitted == s.delivered + s.reaped && daemon.registry().is_empty() {
            return;
        }
        assert!(t0.elapsed() < timeout, "daemon did not settle: {s:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn assert_no_leaks(daemon: &ServeDaemon) {
    assert!(daemon.registry().is_empty(), "leaked crew leases");
    let a = daemon.arena_stats();
    assert_eq!(
        a.free_buffers as u64, a.allocations,
        "arena buffers not all returned"
    );
}

#[test]
fn unix_roundtrip_mixed_kinds_and_precisions() {
    let addr = unix_addr("round");
    let daemon = ServeDaemon::bind(&addr, cfg(3)).unwrap();
    let mut client = ServeClient::connect(&addr).unwrap();

    let n = 96;
    let lu0 = Matrix::random(n, n, 1);
    let ch0 = Mat::<f32>::random_spd(n, 2);
    let qr0 = Matrix::random(n, n, 3);
    let id_lu = client
        .submit_factor(&factor_req(FactorKind::Lu, proto::WireMat::F64(lu0.clone())))
        .unwrap();
    let id_ch = client
        .submit_factor(&factor_req(FactorKind::Chol, proto::WireMat::F32(ch0.clone())))
        .unwrap();
    let id_qr = client
        .submit_factor(&factor_req(FactorKind::Qr, proto::WireMat::F64(qr0.clone())))
        .unwrap();
    // Diagonally-dominant system with x* = 1 (b = A·1).
    let a = Matrix::random_dd(n, 4);
    let mut b = vec![0.0; n];
    for j in 0..n {
        for i in 0..n {
            b[i] += a[(i, j)];
        }
    }
    let id_sv = client
        .submit_solve(&proto::SolveReq {
            prec: SolvePrec::Mixed,
            priority: 1,
            deadline_ms: 0,
            bo: 0,
            bi: 0,
            a,
            b,
        })
        .unwrap();

    for _ in 0..4 {
        match client.recv().unwrap() {
            WireEvent::Factor { id, resp } => {
                assert!(!resp.cancelled);
                let ipiv: Vec<usize> = resp.ipiv.iter().map(|&p| p as usize).collect();
                if id == id_lu {
                    let proto::WireMat::F64(f) = &resp.a else {
                        panic!("precision flipped")
                    };
                    assert!(naive::lu_residual(&lu0, f, &ipiv) < 1e-10);
                } else if id == id_ch {
                    let proto::WireMat::F32(f) = &resp.a else {
                        panic!("precision flipped")
                    };
                    let tol = 16.0 * n as f64 * <f32 as Scalar>::EPSILON.to_f64();
                    assert!(naive::chol_residual(&ch0, f) < tol);
                } else if id == id_qr {
                    let proto::WireMat::F64(f) = &resp.a else {
                        panic!("precision flipped")
                    };
                    let proto::WireVec::F64(tau) = &resp.tau else {
                        panic!("tau precision flipped")
                    };
                    assert!(naive::qr_residual(&qr0, f, tau) < 1e-10);
                } else {
                    panic!("unknown factor id {id}");
                }
            }
            WireEvent::Solve { id, resp } => {
                assert_eq!(id, id_sv);
                assert!(resp.converged);
                assert!(resp.backward_error <= SolvePrec::Mixed.expected_backward_error(n));
                assert!(resp.x.iter().all(|&x| (x - 1.0).abs() < 1e-6));
            }
            other => panic!("unexpected terminal event: {other:?}"),
        }
    }
    client.goodbye().unwrap();
    daemon.drain(Duration::from_secs(30));
    let s = daemon.stats();
    assert_eq!(s.admission.admitted, 4);
    assert_eq!(s.delivered, 4);
    assert_eq!(s.reaped, 0);
    assert_no_leaks(&daemon);
    daemon.shutdown();
}

/// Bind a daemon on an ephemeral TCP port.
fn tcp_daemon(c: NetConfig) -> ServeDaemon {
    ServeDaemon::bind(&BindAddr::parse("tcp:127.0.0.1:0").unwrap(), c).unwrap()
}

#[test]
fn tcp_roundtrip_on_ephemeral_port() {
    let daemon = tcp_daemon(cfg(2));
    let addr = daemon.local_addr();
    let mut client = ServeClient::connect(&addr).unwrap();
    let n = 64;
    let a0 = Matrix::random(n, n, 7);
    let id = client
        .submit_factor(&factor_req(FactorKind::Lu, proto::WireMat::F64(a0.clone())))
        .unwrap();
    match client.recv().unwrap() {
        WireEvent::Factor { id: rid, resp } => {
            assert_eq!(rid, id);
            let proto::WireMat::F64(f) = &resp.a else {
                panic!("precision flipped")
            };
            let ipiv: Vec<usize> = resp.ipiv.iter().map(|&p| p as usize).collect();
            assert!(naive::lu_residual(&a0, f, &ipiv) < 1e-10);
        }
        other => panic!("expected factor response, got {other:?}"),
    }
    client.goodbye().unwrap();
    daemon.shutdown();
}

/// Raw-socket connect to a TCP daemon, for tests that need to write
/// hand-crafted (broken) bytes below the `ServeClient` layer.
fn raw_tcp(daemon: &ServeDaemon) -> std::net::TcpStream {
    let BindAddr::Tcp(hostport) = daemon.local_addr() else {
        panic!("expected tcp daemon")
    };
    std::net::TcpStream::connect(hostport.as_str()).unwrap()
}

#[test]
fn hello_version_mismatch_is_rejected_unsupported() {
    let daemon = tcp_daemon(cfg(2));
    let mut s = raw_tcp(&daemon);
    s.write_all(&proto::encode_hello(9, 9)).unwrap();
    match proto::read_frame(&mut s, 1 << 20, &mut |_| true) {
        ReadEvent::Frame(f) => {
            assert_eq!(f.ty, proto::T_REJECT);
            let r = proto::decode_reject(&f.payload).unwrap();
            assert_eq!(r.code, RejectCode::Unsupported);
        }
        other => panic!("expected reject, got {other:?}"),
    }
    // The daemon closes the session after a failed handshake.
    match proto::read_frame(&mut s, 1 << 20, &mut |_| true) {
        ReadEvent::Eof | ReadEvent::Closed => {}
        other => panic!("expected close, got {other:?}"),
    }
    daemon.shutdown();
}

#[test]
fn malformed_and_truncated_frames_do_not_kill_the_daemon() {
    let daemon = tcp_daemon(cfg(2));

    // Garbage bytes instead of a HELLO: Malformed reject, then close.
    {
        let mut s = raw_tcp(&daemon);
        s.write_all(b"this is not a protocol frame!!!!").unwrap();
        match proto::read_frame(&mut s, 1 << 20, &mut |_| true) {
            ReadEvent::Frame(f) => {
                assert_eq!(f.ty, proto::T_REJECT);
                let r = proto::decode_reject(&f.payload).unwrap();
                assert_eq!(r.code, RejectCode::Malformed);
            }
            other => panic!("expected reject, got {other:?}"),
        }
    }

    // A valid handshake, then a header announcing more payload than we
    // send: the reader sees a truncated stream and closes the session.
    {
        let mut s = raw_tcp(&daemon);
        s.write_all(&proto::encode_hello(proto::VERSION, proto::VERSION)).unwrap();
        match proto::read_frame(&mut s, 1 << 20, &mut |_| true) {
            ReadEvent::Frame(f) => assert_eq!(f.ty, proto::T_HELLO_ACK),
            other => panic!("expected hello ack, got {other:?}"),
        }
        let mut frame = proto::encode_frame(proto::T_FACTOR, 1, &[0u8; 1000]);
        frame.truncate(proto::HEADER_LEN + 10);
        s.write_all(&frame).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        loop {
            match proto::read_frame(&mut s, 1 << 20, &mut |_| true) {
                ReadEvent::Frame(f) if f.ty == proto::T_REJECT => continue,
                ReadEvent::Eof | ReadEvent::Closed => break,
                other => panic!("expected reject/close, got {other:?}"),
            }
        }
    }

    // The daemon survives both: a well-behaved client still works.
    let mut client = ServeClient::connect(&daemon.local_addr()).unwrap();
    let req = factor_req(FactorKind::Lu, proto::WireMat::F64(Matrix::random(32, 32, 1)));
    client.submit_factor(&req).unwrap();
    assert!(matches!(client.recv().unwrap(), WireEvent::Factor { .. }));
    client.goodbye().unwrap();

    daemon.drain(Duration::from_secs(30));
    let s = daemon.stats();
    assert!(s.malformed >= 2, "malformed counter: {}", s.malformed);
    assert_eq!(s.admission.admitted, s.delivered + s.reaped);
    assert_no_leaks(&daemon);
    daemon.shutdown();
}

#[test]
fn oversized_payload_is_rejected_and_the_stream_survives() {
    let mut c = cfg(2);
    c.max_frame = 4096; // a 64x64 f64 matrix (32 KiB) is over the cap
    let addr = unix_addr("oversize");
    let daemon = ServeDaemon::bind(&addr, c).unwrap();
    let mut client = ServeClient::connect(&addr).unwrap();

    let big = Matrix::random(64, 64, 1);
    let id_big = client
        .submit_factor(&factor_req(FactorKind::Lu, proto::WireMat::F64(big)))
        .unwrap();
    match client.recv().unwrap() {
        WireEvent::Rejected { id, reject } => {
            assert_eq!(id, id_big);
            assert_eq!(reject.code, RejectCode::TooLarge);
        }
        other => panic!("expected TooLarge reject, got {other:?}"),
    }

    // The oversized frame was drained, not buffered: the same
    // connection keeps working with an in-budget request.
    let small = Matrix::random(16, 16, 2);
    let id_small = client
        .submit_factor(&factor_req(FactorKind::Lu, proto::WireMat::F64(small)))
        .unwrap();
    match client.recv().unwrap() {
        WireEvent::Factor { id, resp } => {
            assert_eq!(id, id_small);
            assert!(!resp.cancelled);
        }
        other => panic!("expected factor response, got {other:?}"),
    }
    client.goodbye().unwrap();

    daemon.drain(Duration::from_secs(30));
    let s = daemon.stats();
    assert_eq!(s.oversized_frames, 1);
    assert_eq!(s.admission.admitted, 1);
    assert_no_leaks(&daemon);
    daemon.shutdown();
}

#[test]
fn overload_rejection_is_typed_and_nonfatal() {
    let mut c = cfg(2);
    // A zero-length pending queue refuses every request
    // deterministically — the typed-rejection path itself is what this
    // test pins down.
    c.admission.max_pending = 0;
    let addr = unix_addr("overload");
    let daemon = ServeDaemon::bind(&addr, c).unwrap();
    let mut client = ServeClient::connect(&addr).unwrap();
    let req = factor_req(FactorKind::Lu, proto::WireMat::F64(Matrix::random(32, 32, 1)));
    let id = client.submit_factor(&req).unwrap();
    match client.recv().unwrap() {
        WireEvent::Rejected { id: rid, reject } => {
            assert_eq!(rid, id);
            assert_eq!(reject.code, RejectCode::Overloaded);
            assert!(!reject.reason.is_empty());
        }
        other => panic!("expected Overloaded reject, got {other:?}"),
    }
    // Rejection is per-request, not per-connection: the session lives.
    let req = factor_req(FactorKind::Lu, proto::WireMat::F64(Matrix::random(16, 16, 2)));
    let id2 = client.submit_factor(&req).unwrap();
    match client.recv().unwrap() {
        WireEvent::Rejected { id: rid, .. } => assert_eq!(rid, id2),
        other => panic!("expected reject, got {other:?}"),
    }
    client.goodbye().unwrap();
    let s = daemon.stats();
    assert_eq!(s.admission.rejected_overloaded, 2);
    assert_eq!(s.admission.admitted, 0);
    daemon.shutdown();
}

#[test]
fn disconnect_mid_request_reaps_without_leaks() {
    let addr = unix_addr("reap");
    let daemon = ServeDaemon::bind(&addr, cfg(2)).unwrap();

    {
        let mut client = ServeClient::connect(&addr).unwrap();
        let a0 = Matrix::random(192, 192, 1);
        let req = factor_req(FactorKind::Lu, proto::WireMat::F64(a0));
        client.submit_factor(&req).unwrap();
        // Wait until the request is actually admitted (the reader may
        // not have decoded the frame yet), then vanish without reading
        // the response.
        let t0 = Instant::now();
        while daemon.stats().admission.admitted == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "never admitted");
            std::thread::sleep(Duration::from_millis(1));
        }
    } // drop = abrupt disconnect

    // The daemon must reap the orphaned request: cancel-or-finish it,
    // release its lease and admission slot, return its arena buffers.
    await_settled(&daemon, Duration::from_secs(30));
    let s = daemon.stats();
    assert_eq!(s.admission.admitted, 1);
    assert_eq!(s.delivered + s.reaped, 1);
    assert_no_leaks(&daemon);

    // And a fresh client gets full service afterwards.
    let mut client = ServeClient::connect(&addr).unwrap();
    let req = factor_req(FactorKind::Lu, proto::WireMat::F64(Matrix::random(48, 48, 2)));
    client.submit_factor(&req).unwrap();
    assert!(matches!(client.recv().unwrap(), WireEvent::Factor { .. }));
    client.goodbye().unwrap();
    daemon.shutdown();
}

#[test]
fn recv_timeout_fires_and_the_session_survives() {
    let daemon = tcp_daemon(cfg(2));
    let mut client = ServeClient::connect(&daemon.local_addr()).unwrap();

    // Nothing submitted: recv must come back with TimedOut instead of
    // blocking forever on the configured per-call budget.
    client.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    let t0 = Instant::now();
    let err = client.recv().expect_err("recv returned without a request in flight");
    assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "timeout took {:?}",
        t0.elapsed()
    );

    // An idle-boundary timeout leaves the stream framed: the same
    // session still completes a real request afterwards.
    client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let req = factor_req(FactorKind::Lu, proto::WireMat::F64(Matrix::random(32, 32, 5)));
    let id = client.submit_factor(&req).unwrap();
    match client.recv().unwrap() {
        WireEvent::Factor { id: rid, resp } => {
            assert_eq!(rid, id);
            assert!(!resp.cancelled);
        }
        other => panic!("expected factor response, got {other:?}"),
    }
    client.goodbye().unwrap();
    daemon.shutdown();
}

#[test]
fn drain_completes_despite_a_client_stalled_mid_frame() {
    let daemon = tcp_daemon(cfg(2));

    // A well-behaved handshake, then half a frame header — and silence,
    // with the socket held open. This connection holds no admission
    // slot; it must not be able to hold the drain open either.
    let mut stalled = raw_tcp(&daemon);
    stalled
        .write_all(&proto::encode_hello(proto::VERSION, proto::VERSION))
        .unwrap();
    match proto::read_frame(&mut stalled, 1 << 20, &mut |_| true) {
        ReadEvent::Frame(f) => assert_eq!(f.ty, proto::T_HELLO_ACK),
        other => panic!("expected hello ack, got {other:?}"),
    }
    let frame = proto::encode_frame(proto::T_FACTOR, 1, &[0u8; 256]);
    stalled.write_all(&frame[..7]).unwrap(); // partial header, then stall

    // Give the reader a moment to consume the partial bytes so the
    // drain genuinely catches it mid-frame.
    std::thread::sleep(Duration::from_millis(100));

    let t0 = Instant::now();
    daemon.drain(Duration::from_millis(200));
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "drain hung on the stalled client: {:?}",
        t0.elapsed()
    );
    let s = daemon.stats();
    assert_eq!(s.admission.admitted, s.delivered + s.reaped);
    daemon.shutdown();
    drop(stalled); // kept alive (stalled, not closed) through the drain
}

#[test]
fn finished_connection_threads_are_swept_while_running() {
    let daemon = tcp_daemon(cfg(2));
    for i in 0..8u64 {
        let mut client = ServeClient::connect(&daemon.local_addr()).unwrap();
        let req = factor_req(FactorKind::Lu, proto::WireMat::F64(Matrix::random(24, 24, i + 1)));
        client.submit_factor(&req).unwrap();
        assert!(matches!(client.recv().unwrap(), WireEvent::Factor { .. }));
        client.goodbye().unwrap();
    }
    // The acceptor sweeps finished reader/writer pairs on every poll:
    // with all 8 connections closed, the tracked handles must decay to
    // zero long before any drain.
    let t0 = Instant::now();
    while daemon.tracked_conn_threads() > 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "conn threads never swept: {} still tracked",
            daemon.tracked_conn_threads()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(daemon.stats().conns_accepted, 8);
    daemon.shutdown();
}

#[test]
fn drain_under_load_answers_every_admitted_request() {
    let addr = unix_addr("drain");
    let daemon = ServeDaemon::bind(&addr, cfg(3)).unwrap();
    let mut client = ServeClient::connect(&addr).unwrap();

    let k = 6;
    let mut ids = Vec::new();
    for i in 0..k {
        let a0 = Matrix::random(128, 128, i as u64 + 1);
        let req = factor_req(FactorKind::Lu, proto::WireMat::F64(a0));
        ids.push(client.submit_factor(&req).unwrap());
    }

    // Reader thread: collect every terminal event until the daemon
    // closes the connection at the end of the drain.
    let reader = std::thread::spawn(move || {
        let mut events: Vec<u64> = Vec::new();
        loop {
            match client.recv() {
                Ok(WireEvent::Factor { id, .. })
                | Ok(WireEvent::Solve { id, .. })
                | Ok(WireEvent::Rejected { id, .. })
                | Ok(WireEvent::Failed { id, .. }) => events.push(id),
                Err(_) => break, // daemon closed after the drain
            }
        }
        events
    });

    // Drain while the requests are in flight. A short grace forces the
    // ET path for whatever is still running — those clients still get
    // responses, flagged `cancelled`.
    daemon.drain(Duration::from_millis(50));
    let events = reader.join().unwrap();

    // Every event answers a request we submitted, at most once each.
    let mut seen = events.clone();
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), events.len(), "duplicate responses: {events:?}");
    assert!(events.iter().all(|id| ids.contains(id)));

    // The ledger: everything admitted was answered (or reaped, had the
    // client vanished — it did not, so reaped stays 0) and nothing
    // leaked. Events the client saw = deliveries + typed rejections.
    let s = daemon.stats();
    assert_eq!(s.admission.admitted, s.delivered + s.reaped);
    assert_eq!(s.reaped, 0);
    let rejected = s.admission.rejected_draining + s.admission.rejected_overloaded;
    assert_eq!(events.len() as u64, s.delivered + rejected);
    assert_no_leaks(&daemon);

    // Post-drain, the daemon accepts no new sessions.
    assert!(ServeClient::connect(&addr).is_err());
    daemon.shutdown();
}

#[test]
fn nan_payload_fails_typed_and_the_session_survives() {
    // f64 over a Unix socket: a NaN planted at a known column-major
    // offset must come back as FAILED{non-finite} carrying that offset,
    // count as *delivered* (not dropped, not cancelled), and leave the
    // session usable.
    let addr = unix_addr("nanpay");
    let daemon = ServeDaemon::bind(&addr, cfg(2)).unwrap();
    let mut client = ServeClient::connect(&addr).unwrap();
    let n = 32;
    let mut a = Matrix::random(n, n, 1);
    a[(2, 1)] = f64::NAN;
    let id = client
        .submit_factor(&factor_req(FactorKind::Lu, proto::WireMat::F64(a)))
        .unwrap();
    match client.recv().unwrap() {
        WireEvent::Failed { id: rid, failure } => {
            assert_eq!(rid, id);
            assert_eq!(failure.code, FailCode::NonFinite);
            assert_eq!(failure.detail, (n + 2) as u64, "column-major offset of the NaN");
            assert!(failure.reason.contains("non-finite"), "{}", failure.reason);
        }
        other => panic!("expected FAILED, got {other:?}"),
    }
    // A failed request is not a failed connection.
    let ok = Matrix::random(n, n, 2);
    client
        .submit_factor(&factor_req(FactorKind::Lu, proto::WireMat::F64(ok)))
        .unwrap();
    assert!(matches!(client.recv().unwrap(), WireEvent::Factor { .. }));
    client.goodbye().unwrap();
    daemon.drain(Duration::from_secs(30));
    let s = daemon.stats();
    assert_eq!(s.admission.admitted, 2);
    assert_eq!(s.delivered, 2, "FAILED counts as delivered");
    assert_eq!(s.reaped, 0);
    assert_no_leaks(&daemon);
    daemon.shutdown();

    // f32 over TCP, QR kind: same typed failure, offset 0.
    let daemon = tcp_daemon(cfg(2));
    let mut client = ServeClient::connect(&daemon.local_addr()).unwrap();
    let mut a = Mat::<f32>::random(n, n, 3);
    a[(0, 0)] = f32::NAN;
    let id = client
        .submit_factor(&factor_req(FactorKind::Qr, proto::WireMat::F32(a)))
        .unwrap();
    match client.recv().unwrap() {
        WireEvent::Failed { id: rid, failure } => {
            assert_eq!(rid, id);
            assert_eq!(failure.code, FailCode::NonFinite);
            assert_eq!(failure.detail, 0);
        }
        other => panic!("expected FAILED, got {other:?}"),
    }
    client.goodbye().unwrap();
    daemon.drain(Duration::from_secs(30));
    let s = daemon.stats();
    assert_eq!(s.admission.admitted, s.delivered + s.reaped);
    assert_no_leaks(&daemon);
    daemon.shutdown();
}

#[test]
fn singular_and_indefinite_inputs_fail_typed_without_leaks() {
    let addr = unix_addr("singular");
    let daemon = ServeDaemon::bind(&addr, cfg(2)).unwrap();
    let mut client = ServeClient::connect(&addr).unwrap();
    let n = 32;

    // Exactly singular LU: the all-zeros matrix pivots to zero in
    // column 0. LAPACK-info semantics — the run completes, but the wire
    // answer is the typed failure, not NaN-filled factors.
    let id_lu = client
        .submit_factor(&factor_req(FactorKind::Lu, proto::WireMat::F64(Mat::zeros(n, n))))
        .unwrap();
    // Indefinite Cholesky: a negated SPD matrix breaks down at column 0.
    let mut spd = Matrix::random_spd(n, 5);
    for j in 0..n {
        for i in 0..n {
            spd[(i, j)] = -spd[(i, j)];
        }
    }
    let id_ch = client
        .submit_factor(&factor_req(FactorKind::Chol, proto::WireMat::F64(spd)))
        .unwrap();
    // Singular solve: factorization of A = 0 cannot back-substitute.
    let id_sv = client
        .submit_solve(&proto::SolveReq {
            prec: SolvePrec::Mixed,
            priority: 0,
            deadline_ms: 0,
            bo: 0,
            bi: 0,
            a: Mat::zeros(n, n),
            b: vec![1.0; n],
        })
        .unwrap();

    for _ in 0..3 {
        match client.recv().unwrap() {
            WireEvent::Failed { id, failure } => {
                if id == id_lu {
                    assert_eq!(failure.code, FailCode::Singular);
                    assert_eq!(failure.detail, 0, "zero pivot in column 0");
                    assert!(failure.reason.contains("singular"), "{}", failure.reason);
                } else if id == id_ch {
                    assert_eq!(failure.code, FailCode::Unsupported);
                    assert!(
                        failure.reason.contains("positive definite"),
                        "{}",
                        failure.reason
                    );
                } else if id == id_sv {
                    assert_eq!(failure.code, FailCode::Singular);
                } else {
                    panic!("failure for unknown id {id}");
                }
            }
            other => panic!("expected FAILED, got {other:?}"),
        }
    }
    client.goodbye().unwrap();
    daemon.drain(Duration::from_secs(30));
    let s = daemon.stats();
    assert_eq!(s.admission.admitted, 3);
    assert_eq!(s.delivered, 3, "typed failures are delivered answers");
    assert_eq!(s.reaped, 0);
    assert_no_leaks(&daemon);
    daemon.shutdown();
}

/// 256 concurrent Unix-socket clients (the acceptance soak, sized down
/// nowhere): every request must produce exactly one terminal event.
/// The `soak_` prefix lets the TSan CI lane skip it (`--skip soak_`).
#[test]
fn soak_many_concurrent_unix_clients() {
    let clients = 256;
    let addr = unix_addr("soak");
    let mut c = cfg(3);
    c.admission.max_pending = clients;
    let daemon = ServeDaemon::bind(&addr, c).unwrap();

    let handles: Vec<_> = (0..clients)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = ServeClient::connect(&addr).unwrap();
                let n = [24usize, 32, 40][i % 3];
                let kind = FactorKind::all()[i % 3];
                let a = if i % 2 == 0 {
                    proto::WireMat::F64(match kind {
                        FactorKind::Chol => Matrix::random_spd(n, i as u64 + 1),
                        _ => Matrix::random(n, n, i as u64 + 1),
                    })
                } else {
                    proto::WireMat::F32(match kind {
                        FactorKind::Chol => Mat::<f32>::random_spd(n, i as u64 + 1),
                        _ => Mat::<f32>::random(n, n, i as u64 + 1),
                    })
                };
                let id = client.submit_factor(&factor_req(kind, a)).unwrap();
                let done = match client.recv().unwrap() {
                    WireEvent::Factor { id: rid, resp } => {
                        assert_eq!(rid, id);
                        assert!(!resp.cancelled);
                        true
                    }
                    WireEvent::Rejected { id: rid, .. } => {
                        assert_eq!(rid, id);
                        false
                    }
                    other => panic!("unexpected event {other:?}"),
                };
                client.goodbye().unwrap();
                done
            })
        })
        .collect();

    let mut completed = 0u64;
    let mut rejected = 0u64;
    for h in handles {
        if h.join().unwrap() {
            completed += 1;
        } else {
            rejected += 1;
        }
    }
    assert_eq!(completed + rejected, clients as u64);

    daemon.drain(Duration::from_secs(60));
    let s = daemon.stats();
    assert_eq!(s.conns_accepted, clients as u64);
    assert_eq!(s.delivered, completed);
    assert_eq!(s.reaped, 0);
    assert_eq!(s.admission.admitted, s.delivered + s.reaped);
    assert_no_leaks(&daemon);
    daemon.shutdown();
}
