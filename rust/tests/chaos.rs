//! Seeded chaos suite for the serve stack (DESIGN.md §15): every fault
//! family in [`malleable_lu::faultplan`] is swept across 12 seeds ×
//! {LU, Cholesky, QR} against a live daemon, and after *every* scenario
//! the same invariants must hold — the ledger balances
//! (`admitted == delivered + reaped`), the crew registry is empty, the
//! pack arena has every buffer back, and a fresh well-posed request
//! still completes. Faults degrade one request, never the daemon.
//!
//! Only built with `--features chaos` (the CI chaos lane); the default
//! `cargo test` compiles this file to nothing.
//!
//! Fault plans are armed *globally* here ([`FaultPlan::arm`]): every
//! scenario holds the arming guard for its fault window, and the one
//! test that never injects (`fault_free_runs_are_bitwise_identical`)
//! arms an inert `PoisonInput` plan so it serializes with the sweep
//! instead of racing a live global fault.

#![cfg(feature = "chaos")]

use malleable_lu::factor::FactorKind;
use malleable_lu::faultplan::{self, FaultAction, FaultPlan};
use malleable_lu::matrix::{naive, Mat, Matrix};
use malleable_lu::serve::client::{ServeClient, WireEvent};
use malleable_lu::serve::net::{BindAddr, NetConfig, ServeDaemon};
use malleable_lu::serve::proto::{self, FailCode, ReadEvent};
use malleable_lu::serve::ServeConfig;
use std::io::Write;
use std::time::{Duration, Instant};

fn cfg(workers: usize) -> NetConfig {
    NetConfig {
        serve: ServeConfig {
            workers,
            bo: 48,
            bi: 16,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn tcp_daemon(c: NetConfig) -> ServeDaemon {
    ServeDaemon::bind(&BindAddr::parse("tcp:127.0.0.1:0").unwrap(), c).unwrap()
}

/// Raw-socket connect, for the mid-frame-disconnect scenarios.
fn raw_tcp(daemon: &ServeDaemon) -> std::net::TcpStream {
    let BindAddr::Tcp(hostport) = daemon.local_addr() else {
        panic!("expected tcp daemon")
    };
    std::net::TcpStream::connect(hostport.as_str()).unwrap()
}

/// A factor request with explicit small blocks (`bo=16`, `bi=8`), so
/// even modest matrices cross several panel checkpoints and many crew
/// chunks — the places the hooks live.
fn req(kind: FactorKind, a: proto::WireMat, deadline_ms: u32) -> proto::FactorReq {
    proto::FactorReq {
        kind,
        priority: 0,
        deadline_ms,
        bo: 16,
        bi: 8,
        a,
    }
}

/// A well-posed input for `kind` (SPD for Cholesky).
fn input(kind: FactorKind, n: usize, seed: u64) -> Matrix {
    match kind {
        FactorKind::Chol => Matrix::random_spd(n, seed),
        _ => Matrix::random(n, n, seed),
    }
}

/// The recurring post-scenario invariant: the daemon settles with a
/// balanced ledger and nothing leaked.
fn settle_and_check(daemon: &ServeDaemon, ctx: &str, admitted: u64) {
    let t0 = Instant::now();
    loop {
        let s = daemon.stats();
        if s.admission.admitted == s.delivered + s.reaped && daemon.registry().is_empty() {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "{ctx}: daemon did not settle: {s:?}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    let s = daemon.stats();
    assert_eq!(s.admission.admitted, admitted, "{ctx}: {s:?}");
    assert_eq!(s.admission.admitted, s.delivered + s.reaped, "{ctx}: {s:?}");
    assert!(daemon.registry().is_empty(), "{ctx}: leaked crew leases");
    let a = daemon.arena_stats();
    assert_eq!(
        a.free_buffers as u64, a.allocations,
        "{ctx}: arena buffers not all returned"
    );
}

/// After the fault: a fresh well-posed request on a fresh connection
/// must get full, numerically correct service. Call with the plan
/// already disarmed, so an unspent plan cannot fire here.
fn follow_up(addr: &BindAddr, ctx: &str) {
    let mut client = ServeClient::connect(addr)
        .unwrap_or_else(|e| panic!("{ctx}: daemon stopped accepting: {e}"));
    let a0 = Matrix::random(48, 48, 99);
    let id = client
        .submit_factor(&req(FactorKind::Lu, proto::WireMat::F64(a0.clone()), 0))
        .unwrap();
    match client.recv().unwrap() {
        WireEvent::Factor { id: rid, resp } => {
            assert_eq!(rid, id, "{ctx}");
            assert!(!resp.cancelled, "{ctx}");
            let proto::WireMat::F64(f) = &resp.a else {
                panic!("{ctx}: precision flipped")
            };
            let ipiv: Vec<usize> = resp.ipiv.iter().map(|&p| p as usize).collect();
            let r = naive::lu_residual(&a0, f, &ipiv);
            assert!(r < 1e-10, "{ctx}: post-fault residual {r}");
        }
        other => panic!("{ctx}: daemon did not survive the fault: {other:?}"),
    }
    client.goodbye().unwrap();
}

/// One seeded scenario: derive the plan, run the fault-family-specific
/// interaction, check the shared invariants.
fn run_scenario(seed: u64, kind: FactorKind) {
    let plan = FaultPlan::from_seed(seed);
    let ctx = format!("seed {seed} ({:?}) on {}", plan.action, kind.name());
    match plan.action {
        FaultAction::PanicAtCheckpoint { .. } => leader_panic(&plan, kind, &ctx),
        FaultAction::PanicInChunk { .. } => crew_panic(&plan, kind, &ctx),
        FaultAction::StallAtCheckpoint { .. } => stalled_leader(&plan, kind, &ctx),
        FaultAction::PoisonInput => poisoned_input(&plan, kind, seed, &ctx),
        FaultAction::DropConnection { mid_frame } => {
            dropped_connection(&plan, kind, mid_frame, seed, &ctx)
        }
    }
}

/// The leader panics at a panel checkpoint: the serve loop's
/// `catch_unwind` must convert it into a typed `FAILED{internal}` —
/// delivered, not dropped — and the daemon must keep serving.
fn leader_panic(plan: &FaultPlan, kind: FactorKind, ctx: &str) {
    let guard = plan.arm();
    let daemon = tcp_daemon(cfg(2));
    let mut client = ServeClient::connect(&daemon.local_addr()).unwrap();
    let id = client
        .submit_factor(&req(kind, proto::WireMat::F64(input(kind, 96, plan.seed + 1)), 0))
        .unwrap();
    match client.recv().unwrap() {
        WireEvent::Failed { id: rid, failure } => {
            assert_eq!(rid, id, "{ctx}");
            assert_eq!(failure.code, FailCode::Internal, "{ctx}: {failure:?}");
            assert!(failure.reason.contains("panicked"), "{ctx}: {}", failure.reason);
        }
        other => panic!("{ctx}: expected FAILED(internal), got {other:?}"),
    }
    assert!(faultplan::fired(), "{ctx}: plan never fired");
    client.goodbye().unwrap();
    drop(guard);
    follow_up(&daemon.local_addr(), ctx);
    daemon.drain(Duration::from_secs(30));
    settle_and_check(&daemon, ctx, 2);
    assert_eq!(daemon.stats().reaped, 0, "{ctx}: no client vanished");
    daemon.shutdown();
}

/// A crew member panics inside a chunk: the crew is poisoned but never
/// wedged (the chunk still counts as completed), and the request comes
/// back as `FAILED{internal}`. Seeds whose chunk ordinal exceeds the
/// run's chunk count simply complete — also a valid outcome, asserted
/// consistent with `fired()`.
fn crew_panic(plan: &FaultPlan, kind: FactorKind, ctx: &str) {
    let guard = plan.arm();
    let daemon = tcp_daemon(cfg(2));
    let mut client = ServeClient::connect(&daemon.local_addr()).unwrap();
    let id = client
        .submit_factor(&req(kind, proto::WireMat::F64(input(kind, 128, plan.seed + 1)), 0))
        .unwrap();
    match client.recv().unwrap() {
        WireEvent::Failed { id: rid, failure } => {
            assert_eq!(rid, id, "{ctx}");
            assert_eq!(failure.code, FailCode::Internal, "{ctx}: {failure:?}");
            assert!(faultplan::fired(), "{ctx}: FAILED without the plan firing");
        }
        WireEvent::Factor { id: rid, resp } => {
            assert_eq!(rid, id, "{ctx}");
            assert!(!resp.cancelled, "{ctx}");
            assert!(
                !faultplan::fired(),
                "{ctx}: plan fired yet the request completed cleanly"
            );
        }
        other => panic!("{ctx}: expected FAILED or a clean response, got {other:?}"),
    }
    client.goodbye().unwrap();
    drop(guard);
    follow_up(&daemon.local_addr(), ctx);
    daemon.drain(Duration::from_secs(30));
    settle_and_check(&daemon, ctx, 2);
    daemon.shutdown();
}

/// The leader stalls (wedged-but-alive) at a checkpoint, well past the
/// request's deadline: the response must come back flagged `cancelled`,
/// and — since the stall (≥120 ms) overruns the watchdog limit (70 ms)
/// — the watchdog must have force-cancelled it while it was wedged.
fn stalled_leader(plan: &FaultPlan, kind: FactorKind, ctx: &str) {
    let guard = plan.arm();
    let mut c = cfg(2);
    c.watchdog_factor = 1;
    c.watchdog_min_ms = 70;
    let daemon = tcp_daemon(c);
    let mut client = ServeClient::connect(&daemon.local_addr()).unwrap();
    let id = client
        .submit_factor(&req(kind, proto::WireMat::F64(input(kind, 96, plan.seed + 1)), 60))
        .unwrap();
    match client.recv().unwrap() {
        WireEvent::Factor { id: rid, resp } => {
            assert_eq!(rid, id, "{ctx}");
            assert!(resp.cancelled, "{ctx}: stalled past its deadline yet not cancelled");
        }
        other => panic!("{ctx}: expected a cancelled response, got {other:?}"),
    }
    if faultplan::fired() {
        assert!(
            daemon.stats().watchdog_fired >= 1,
            "{ctx}: a {:?} stall never tripped the watchdog",
            plan.action
        );
    }
    client.goodbye().unwrap();
    drop(guard);
    follow_up(&daemon.local_addr(), ctx);
    daemon.drain(Duration::from_secs(30));
    settle_and_check(&daemon, ctx, 2);
    daemon.shutdown();
}

/// A NaN planted in the payload itself: caught by the driver's prescan,
/// answered as `FAILED{non-finite}` carrying the column-major offset.
/// Alternates precision across the sweep's `PoisonInput` seeds (3, 9,
/// ...), so both the f64 and f32 prescans get exercised.
fn poisoned_input(plan: &FaultPlan, kind: FactorKind, seed: u64, ctx: &str) {
    let guard = plan.arm();
    let daemon = tcp_daemon(cfg(2));
    let mut client = ServeClient::connect(&daemon.local_addr()).unwrap();
    let n = 64usize;
    let i = ((seed * 7 + 3) % n as u64) as usize;
    let j = ((seed * 5 + 1) % n as u64) as usize;
    let id = if (seed / 6) % 2 == 0 {
        let mut a = input(kind, n, seed + 1);
        a[(i, j)] = f64::NAN;
        client
            .submit_factor(&req(kind, proto::WireMat::F64(a), 0))
            .unwrap()
    } else {
        let mut a = match kind {
            FactorKind::Chol => Mat::<f32>::random_spd(n, seed + 1),
            _ => Mat::<f32>::random(n, n, seed + 1),
        };
        a[(i, j)] = f32::NAN;
        client
            .submit_factor(&req(kind, proto::WireMat::F32(a), 0))
            .unwrap()
    };
    match client.recv().unwrap() {
        WireEvent::Failed { id: rid, failure } => {
            assert_eq!(rid, id, "{ctx}");
            assert_eq!(failure.code, FailCode::NonFinite, "{ctx}: {failure:?}");
            assert_eq!(
                failure.detail,
                (j * n + i) as u64,
                "{ctx}: wrong NaN offset"
            );
        }
        other => panic!("{ctx}: expected FAILED(non-finite), got {other:?}"),
    }
    client.goodbye().unwrap();
    drop(guard);
    follow_up(&daemon.local_addr(), ctx);
    daemon.drain(Duration::from_secs(30));
    settle_and_check(&daemon, ctx, 2);
    daemon.shutdown();
}

/// A client that vanishes: mid-frame before admission (the framing
/// layer closes the session; nothing enters the ledger), or right after
/// submitting (the orphaned request is finished-or-cancelled, then
/// delivered into a dead socket or reaped — never leaked).
fn dropped_connection(plan: &FaultPlan, kind: FactorKind, mid_frame: bool, seed: u64, ctx: &str) {
    let guard = plan.arm();
    let daemon = tcp_daemon(cfg(2));
    if mid_frame {
        let mut s = raw_tcp(&daemon);
        s.write_all(&proto::encode_hello(proto::VERSION, proto::VERSION)).unwrap();
        match proto::read_frame(&mut s, 1 << 20, &mut |_| true) {
            ReadEvent::Frame(f) => assert_eq!(f.ty, proto::T_HELLO_ACK, "{ctx}"),
            other => panic!("{ctx}: expected hello ack, got {other:?}"),
        }
        let frame = proto::encode_frame(proto::T_FACTOR, 1, &[0u8; 512]);
        s.write_all(&frame[..proto::HEADER_LEN + 17]).unwrap();
        drop(s); // vanish mid-frame: nothing was admitted
        drop(guard);
        follow_up(&daemon.local_addr(), ctx);
        daemon.drain(Duration::from_secs(30));
        settle_and_check(&daemon, ctx, 1);
    } else {
        {
            let mut client = ServeClient::connect(&daemon.local_addr()).unwrap();
            client
                .submit_factor(&req(kind, proto::WireMat::F64(input(kind, 160, seed + 1)), 0))
                .unwrap();
            // Wait for admission, then vanish without reading the answer.
            let t0 = Instant::now();
            while daemon.stats().admission.admitted == 0 {
                assert!(
                    t0.elapsed() < Duration::from_secs(10),
                    "{ctx}: never admitted"
                );
                std::thread::sleep(Duration::from_millis(1));
            }
        } // drop = abrupt disconnect with one admitted request in flight
        drop(guard);
        follow_up(&daemon.local_addr(), ctx);
        daemon.drain(Duration::from_secs(30));
        settle_and_check(&daemon, ctx, 2);
    }
    daemon.shutdown();
}

/// The acceptance sweep: 12 consecutive seeds (twice around the 6
/// action variants, with different in-family parameters) × every
/// factorization kind — 36 scenarios, run serially in one test because
/// globally-armed plans must never overlap another scenario's requests.
#[test]
fn chaos_sweep_every_family_across_kinds() {
    for seed in 0..12u64 {
        for &kind in FactorKind::all() {
            run_scenario(seed, kind);
        }
    }
}

/// With no fault armed the chaos build must be *bitwise* identical run
/// to run: the hooks, supervision, and watchdog add observation, never
/// perturbation. Arms an inert `PoisonInput` plan (it has no in-process
/// hook) purely to serialize with the sweep above.
#[test]
fn fault_free_runs_are_bitwise_identical() {
    let inert = FaultPlan {
        seed: u64::MAX,
        action: FaultAction::PoisonInput,
    };
    let _g = inert.arm();
    for &kind in FactorKind::all() {
        let daemon = tcp_daemon(cfg(2));
        let mut client = ServeClient::connect(&daemon.local_addr()).unwrap();
        let a0 = input(kind, 96, 7);
        let mut runs: Vec<(Vec<u64>, Vec<u64>, Vec<u64>)> = Vec::new();
        for _ in 0..2 {
            let id = client
                .submit_factor(&req(kind, proto::WireMat::F64(a0.clone()), 0))
                .unwrap();
            match client.recv().unwrap() {
                WireEvent::Factor { id: rid, resp } => {
                    assert_eq!(rid, id);
                    assert!(!resp.cancelled);
                    let proto::WireMat::F64(f) = &resp.a else {
                        panic!("{}: precision flipped", kind.name())
                    };
                    let mut bits = Vec::with_capacity(96 * 96);
                    for j in 0..f.cols() {
                        for i in 0..f.rows() {
                            bits.push(f[(i, j)].to_bits());
                        }
                    }
                    let tau = match &resp.tau {
                        proto::WireVec::F64(t) => t.iter().map(|x| x.to_bits()).collect(),
                        proto::WireVec::F32(t) => t.iter().map(|x| x.to_bits() as u64).collect(),
                    };
                    let ipiv = resp.ipiv.iter().map(|&p| p as u64).collect();
                    runs.push((bits, ipiv, tau));
                }
                other => panic!("{}: expected a factor response, got {other:?}", kind.name()),
            }
        }
        assert_eq!(runs[0].1, runs[1].1, "{}: pivots differ", kind.name());
        assert_eq!(runs[0].2, runs[1].2, "{}: tau not bitwise identical", kind.name());
        assert_eq!(
            runs[0].0, runs[1].0,
            "{}: factors not bitwise identical across runs",
            kind.name()
        );
        client.goodbye().unwrap();
        daemon.drain(Duration::from_secs(30));
        daemon.shutdown();
    }
}
