//! The deterministic scheduler-test harness for hybrid static/dynamic
//! tile-stealing (ISSUE 5, DESIGN.md §13).
//!
//! The tentpole invariant: the steal-on schedule moves tile *ownership*
//! between crew members — never a tile's arithmetic — so every
//! factorization result is **bitwise identical** to the steal-off
//! (central-ticket) schedule, for every kind × precision × crew size,
//! including crews that grow and shrink mid-run. The harness *proves*
//! this rather than assuming it:
//!
//! - the generic blocked driver is the deterministic backbone (its
//!   operation sequence is schedule-independent by construction, unlike
//!   ET whose cuts are timing-dependent);
//! - crew resize events (member join / lease revocation) are injected at
//!   panel-checkpoint boundaries chosen by the property generator, so a
//!   crew is factorizing with one roster and finishes with another;
//! - a fixed exhaustive sweep covers all kinds × both precisions × crew
//!   sizes 1–6, and a quickcheck_lite property randomizes shapes, block
//!   sizes, steal fractions, and event schedules on top.

use malleable_lu::blis::{BlisParams, StealPolicy};
use malleable_lu::factor::{factorize_blocked, FactorCtl, FactorKind};
use malleable_lu::matrix::Mat;
use malleable_lu::pool::{Crew, EntryPolicy};
use malleable_lu::scalar::Scalar;
use malleable_lu::util::quickcheck_lite::{forall_res, Gen};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// A crew-resize event fired when the factorization commits column
/// `at_col`: member `member` (0-based) joins or leaves the crew.
#[derive(Copy, Clone, Debug)]
struct ResizeEvent {
    at_col: usize,
    member: usize,
    join: bool,
}

/// Bitwise signature of one factorization run: every matrix element's
/// bits, the pivots, and the tau bits.
#[derive(PartialEq, Eq, Debug)]
struct RunBits {
    a: Vec<u64>,
    ipiv: Vec<usize>,
    tau: Vec<u64>,
    cols_done: usize,
}

/// Run one blocked factorization of `a0` under the given steal policy
/// with `crew_size` total participants (leader + `crew_size - 1`
/// members), applying `events` at their column boundaries.
///
/// Members are parked threads gated by per-member `active` flags; the
/// driver's checkpoint callback flips the flags per the event schedule,
/// so joins and revocations land exactly at iteration boundaries — the
/// places a WS absorption or a serve-layer lease change would land.
fn run_schedule<S: Scalar>(
    kind: FactorKind,
    a0: &Mat<S>,
    steal: StealPolicy,
    crew_size: usize,
    bo: usize,
    events: &[ResizeEvent],
) -> RunBits {
    let params = BlisParams::tiny().with_steal(steal);
    let mut crew = Crew::new();
    let shared = crew.shared();
    let n_members = crew_size.saturating_sub(1);

    // Per-member gates: `active[i]` tells member `i` to be enlisted.
    let active: Arc<Vec<AtomicBool>> =
        Arc::new((0..n_members).map(|_| AtomicBool::new(false)).collect());
    let quit = Arc::new(AtomicBool::new(false));
    let threads: Vec<_> = (0..n_members)
        .map(|i| {
            let s = Arc::clone(&shared);
            let act = Arc::clone(&active);
            let q = Arc::clone(&quit);
            std::thread::spawn(move || {
                while !q.load(Ordering::Acquire) {
                    if act[i].load(Ordering::Acquire) {
                        let act2 = Arc::clone(&act);
                        let q2 = Arc::clone(&q);
                        s.member_loop_while(EntryPolicy::JobBoundary, move || {
                            act2[i].load(Ordering::Acquire) && !q2.load(Ordering::Acquire)
                        });
                    } else {
                        std::thread::yield_now();
                    }
                }
            })
        })
        .collect();

    // Everyone except the event-scheduled latecomers starts enlisted.
    let initially_active: Vec<bool> = (0..n_members)
        .map(|i| !events.iter().any(|e| e.member == i && e.join))
        .collect();
    for (i, &on) in initially_active.iter().enumerate() {
        active[i].store(on, Ordering::Release);
    }
    // Wait for the initial roster so the first iterations really run at
    // the requested crew size.
    let want = initially_active.iter().filter(|&&b| b).count();
    while shared.members() < want {
        std::thread::yield_now();
    }

    let cursor = AtomicUsize::new(0);
    let events_sorted: Vec<ResizeEvent> = {
        let mut v = events.to_vec();
        v.sort_by_key(|e| e.at_col);
        v
    };
    let active2 = Arc::clone(&active);
    let checkpoint = move |k: usize| {
        let mut idx = cursor.load(Ordering::Relaxed);
        while idx < events_sorted.len() && events_sorted[idx].at_col <= k {
            let e = events_sorted[idx];
            if e.member < active2.len() {
                active2[e.member].store(e.join, Ordering::Release);
            }
            idx += 1;
        }
        cursor.store(idx, Ordering::Relaxed);
    };
    let ctl = FactorCtl {
        cancel: None,
        tag: None,
        on_checkpoint: Some(&checkpoint),
    };

    let mut f = a0.clone();
    let out = factorize_blocked(kind, &mut crew, &params, f.view_mut(), bo, 4, &ctl);

    quit.store(true, Ordering::Release);
    crew.disband();
    for t in threads {
        t.join().unwrap();
    }

    RunBits {
        a: f.data().iter().map(|x| x.to_bits_u64()).collect(),
        ipiv: out.ipiv,
        tau: out.tau.iter().map(|x| x.to_bits_u64()).collect(),
        cols_done: out.cols_done,
    }
}

fn problem<S: Scalar>(kind: FactorKind, n: usize, seed: u64) -> Mat<S> {
    match kind {
        FactorKind::Chol => Mat::<S>::random_spd(n, seed),
        _ => Mat::<S>::random(n, n, seed),
    }
}

/// The exhaustive acceptance sweep: all kinds × both precisions × crew
/// sizes 1–6, each with a mid-run grow *and* shrink, steal-on compared
/// bitwise against the steal-off run of the same crew size — and
/// against the lone-leader baseline, pinning crew-size invariance too.
#[test]
fn steal_on_bitwise_equals_steal_off_all_kinds_precisions_crews() {
    fn sweep<S: Scalar>() {
        let n = 48;
        let bo = 8;
        for &kind in FactorKind::all() {
            let a0 = problem::<S>(kind, n, 0xA5 + kind.name().len() as u64);
            let baseline = run_schedule(kind, &a0, StealPolicy::Off, 1, bo, &[]);
            assert_eq!(baseline.cols_done, n);
            for crew_size in 1..=6usize {
                // Member 0 leaves after 16 columns (a genuine shrink:
                // it starts enlisted); when there is a *distinct* last
                // member, it joins after 24 (a grow). At crew_size == 2
                // the only member gets the leave alone — pairing it
                // with a join would mark it a latecomer and turn the
                // shrink into a no-op.
                let mut events: Vec<ResizeEvent> = if crew_size >= 2 {
                    vec![ResizeEvent {
                        at_col: 16,
                        member: 0,
                        join: false,
                    }]
                } else {
                    Vec::new()
                };
                if crew_size >= 3 {
                    events.push(ResizeEvent {
                        at_col: 24,
                        member: crew_size - 2,
                        join: true,
                    });
                }
                let off = run_schedule(kind, &a0, StealPolicy::Off, crew_size, bo, &events);
                for steal in [StealPolicy::Auto, StealPolicy::Fraction(1000)] {
                    let on = run_schedule(kind, &a0, steal, crew_size, bo, &events);
                    assert_eq!(
                        on, off,
                        "{}/{}: steal {steal:?} vs off, crew {crew_size}",
                        kind.name(),
                        S::NAME
                    );
                }
                assert_eq!(
                    off, baseline,
                    "{}/{}: crew {crew_size} vs lone leader",
                    kind.name(),
                    S::NAME
                );
            }
        }
    }
    sweep::<f64>();
    sweep::<f32>();
}

/// Randomized property on top of the sweep: shapes, outer blocks, steal
/// fractions, and event schedules drawn by quickcheck_lite; every drawn
/// configuration must agree bitwise with its steal-off twin.
#[test]
fn property_random_resize_schedules_agree_bitwise() {
    forall_res("steal-on ≡ steal-off under random resize", 12, |g: &mut Gen| {
        let n = g.usize_in(24, 56);
        let bo = g.choose(&[4usize, 8, 16]);
        let crew_size = g.usize_in(1, 6);
        let kind = g.choose(&[FactorKind::Lu, FactorKind::Chol, FactorKind::Qr]);
        let steal = if g.bool_with(0.5) {
            StealPolicy::Auto
        } else {
            StealPolicy::Fraction(g.usize_in(0, 1000) as u16)
        };
        let n_events = g.usize_in(0, crew_size.saturating_sub(1).min(2));
        let events: Vec<ResizeEvent> = (0..n_events)
            .map(|i| ResizeEvent {
                // Random iteration boundary: any committed-column count.
                at_col: g.usize_in(1, (n - 1).max(1)),
                member: g.usize_in(0, crew_size.saturating_sub(2)),
                join: i % 2 == 1 && g.bool_with(0.7),
            })
            .collect();
        let seed = g.seed();
        g.label(format!(
            "kind={} n={n} bo={bo} crew={crew_size} steal={steal:?} events={events:?}",
            kind.name()
        ));
        let a0 = problem::<f64>(kind, n, seed);
        let off = run_schedule(kind, &a0, StealPolicy::Off, crew_size, bo, &events);
        let on = run_schedule(kind, &a0, steal, crew_size, bo, &events);
        if on != off {
            return Err("steal-on and steal-off runs disagree bitwise".into());
        }
        if off.cols_done != n {
            return Err(format!("incomplete factorization: {}", off.cols_done));
        }
        Ok(())
    });
}

/// The f32 edge of the property (smaller, fixed sweep — the full random
/// sweep above runs in f64).
#[test]
fn f32_random_fractions_agree_bitwise() {
    forall_res("f32 steal-on ≡ steal-off", 6, |g: &mut Gen| {
        let n = g.usize_in(24, 48);
        let crew_size = g.usize_in(1, 4);
        let kind = g.choose(&[FactorKind::Lu, FactorKind::Chol, FactorKind::Qr]);
        let frac = g.usize_in(0, 1000) as u16;
        let seed = g.seed();
        g.label(format!("kind={} n={n} crew={crew_size} frac={frac}", kind.name()));
        let a0 = problem::<f32>(kind, n, seed);
        let off = run_schedule(kind, &a0, StealPolicy::Off, crew_size, 8, &[]);
        let on = run_schedule(kind, &a0, StealPolicy::Fraction(frac), crew_size, 8, &[]);
        if on != off {
            return Err("f32 steal-on and steal-off runs disagree bitwise".into());
        }
        Ok(())
    });
}
