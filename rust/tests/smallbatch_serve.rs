//! Serve-layer integration of the interleaved small-problem fast path
//! (DESIGN.md §18): a daemon flood of small requests riding beside a
//! large per-problem one must settle the admission ledger exactly
//! (`admitted == delivered + reaped`), deliver bitwise per-problem
//! results out of every bundle composition, and leave the crew
//! machinery to the large request — the fast path takes no lease and no
//! arena buffer, so the registry only ever names the big problem.
//!
//! The second test pins the capture story: bundled requests record the
//! same result digests the per-problem path would, plus one
//! environmental `BundleForm` record per member.

use malleable_lu::factor::FactorKind;
use malleable_lu::lu::lu_unblocked;
use malleable_lu::matrix::{naive, Mat, Matrix};
use malleable_lu::replay::capture::{self, DecisionKind};
use malleable_lu::replay::factor_digest;
use malleable_lu::scalar::Scalar;
use malleable_lu::serve::client::{ServeClient, WireEvent};
use malleable_lu::serve::net::{BindAddr, NetConfig, ServeDaemon};
use malleable_lu::serve::proto;
use malleable_lu::serve::{JobResult, LuRequest, LuServer, ServeConfig};
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Serializes the tests: capture is process-global, and a concurrent
/// server's records (ids are dense from 0 in every server) would bleed
/// into the digest assertions.
static LOCK: Mutex<()> = Mutex::new(());

fn net_cfg(workers: usize) -> NetConfig {
    NetConfig {
        serve: ServeConfig {
            workers,
            interleave: true,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// A collision-free Unix socket path for one test.
fn unix_addr(tag: &str) -> BindAddr {
    let p = std::env::temp_dir().join(format!("mlu-test-{tag}-{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&p);
    BindAddr::Unix(p)
}

fn lu_req(a: proto::WireMat) -> proto::FactorReq {
    proto::FactorReq {
        kind: FactorKind::Lu,
        priority: 0,
        deadline_ms: 0,
        bo: 0,
        bi: 0,
        a,
    }
}

fn ref_lu<S: Scalar>(a: &Mat<S>) -> (Mat<S>, Vec<usize>) {
    let mut f = a.clone();
    let ipiv = lu_unblocked(f.view_mut());
    (f, ipiv)
}

fn bits<S: Scalar>(m: &Mat<S>) -> Vec<u64> {
    m.data().iter().map(|v| v.to_bits_u64()).collect()
}

#[test]
fn daemon_flood_small_beside_large_settles_ledger() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let addr = unix_addr("smallbatch");
    let daemon = ServeDaemon::bind(&addr, net_cfg(2)).unwrap();
    let mut client = ServeClient::connect(&addr).unwrap();

    // One big request up front: it takes the classic per-problem path
    // and must hold a crew lease while the small flood drains beside it.
    let big = Matrix::random(320, 320, 1);
    let id_big = client
        .submit_factor(&lu_req(proto::WireMat::F64(big.clone())))
        .unwrap();

    let sizes = [4usize, 8, 12, 16, 24, 32];
    let mut smalls64: HashMap<u64, Matrix> = HashMap::new();
    let mut smalls32: HashMap<u64, Mat<f32>> = HashMap::new();
    for i in 0..24u64 {
        let n = sizes[(i as usize) % sizes.len()];
        let a = Matrix::random(n, n, 100 + i);
        let id = client
            .submit_factor(&lu_req(proto::WireMat::F64(a.clone())))
            .unwrap();
        smalls64.insert(id, a);
    }
    for i in 0..8u64 {
        let a = Mat::<f32>::random(16, 16, 300 + i);
        let id = client
            .submit_factor(&lu_req(proto::WireMat::F32(a.clone())))
            .unwrap();
        smalls32.insert(id, a);
    }

    // The interleaved path never registers a lease, so any lease we
    // observe belongs to the big request — seeing one while 32 small
    // requests are in flight is the "bundles drain beside a leased
    // crew" picture.
    let t0 = Instant::now();
    let mut saw_lease = false;
    while t0.elapsed() < Duration::from_secs(30) {
        if !daemon.registry().is_empty() {
            saw_lease = true;
            break;
        }
        std::thread::sleep(Duration::from_micros(100));
    }
    assert!(saw_lease, "big request never appeared in the crew registry");

    let mut seen = 0usize;
    while seen < 33 {
        match client.recv().unwrap() {
            WireEvent::Factor { id, resp } => {
                assert!(!resp.cancelled, "req{id} cancelled");
                let ipiv: Vec<usize> = resp.ipiv.iter().map(|&p| p as usize).collect();
                if id == id_big {
                    let proto::WireMat::F64(f) = &resp.a else {
                        panic!("precision flipped")
                    };
                    assert!(naive::lu_residual(&big, f, &ipiv) < 1e-10);
                } else if let Some(a0) = smalls64.get(&id) {
                    let proto::WireMat::F64(f) = &resp.a else {
                        panic!("precision flipped")
                    };
                    let (rf, ripiv) = ref_lu(a0);
                    assert_eq!(ipiv, ripiv, "req{id} pivots");
                    assert_eq!(bits(f), bits(&rf), "req{id} factor bits");
                } else if let Some(a0) = smalls32.get(&id) {
                    let proto::WireMat::F32(f) = &resp.a else {
                        panic!("precision flipped")
                    };
                    let (rf, ripiv) = ref_lu(a0);
                    assert_eq!(ipiv, ripiv, "req{id} pivots");
                    assert_eq!(bits(f), bits(&rf), "req{id} factor bits");
                } else {
                    panic!("unknown request id {id}");
                }
                seen += 1;
            }
            other => panic!("unexpected event: {other:?}"),
        }
    }

    client.goodbye().unwrap();
    daemon.drain(Duration::from_secs(60));
    let s = daemon.stats();
    assert_eq!(s.admission.admitted, 33);
    assert_eq!(
        s.admission.admitted,
        s.delivered + s.reaped,
        "ledger did not settle: {s:?}"
    );
    assert_eq!(s.delivered, 33);
    assert_eq!(s.reaped, 0);
    assert!(daemon.registry().is_empty(), "leaked crew leases");
    let a = daemon.arena_stats();
    assert_eq!(
        a.free_buffers as u64, a.allocations,
        "arena buffers not all returned"
    );
    daemon.shutdown();
}

#[test]
fn bundled_digests_match_per_problem_references() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    assert!(capture::start(), "another capture is active in this process");
    let server = LuServer::new(ServeConfig {
        interleave: true,
        workers: 2,
        ..Default::default()
    });
    let n = 12;
    let mats: Vec<Matrix> = (0..10).map(|i| Matrix::random(n, n, 600 + i)).collect();
    let reqs: Vec<LuRequest> = mats.iter().map(|a| LuRequest::new(a.clone())).collect();
    let results = server.factorize_batch(reqs);
    server.shutdown();
    let (decisions, records) = capture::stop().unwrap();

    for (res, a0) in results.iter().zip(&mats) {
        let (f, ipiv) = ref_lu(a0);
        // The digest a per-problem execution of the same request would
        // record (factor_digest hashes factors, pivots, tau, progress —
        // not timing).
        let reference = JobResult {
            id: res.id,
            kind: FactorKind::Lu,
            a: f,
            ipiv,
            tau: vec![],
            cols_done: n,
            cancelled: false,
            secs: 0.0,
            error: None,
        };
        let want = factor_digest(&reference);
        assert_eq!(
            factor_digest(res),
            want,
            "req{}: bundled digest diverges from the per-problem path",
            res.id
        );
        let rec = records
            .iter()
            .find(|r| r.id == res.id)
            .expect("request missing from capture");
        assert_eq!(rec.digest, want, "req{}: recorded digest", res.id);
        assert_eq!(rec.cols_done, n as u32);
        assert!(!rec.cancelled && !rec.failed);
    }

    // One environmental BundleForm per member, with a well-formed
    // packed operand; the invariant record of a bundled request stays
    // its Submit alone.
    let forms: Vec<_> = decisions
        .iter()
        .filter(|d| d.kind == DecisionKind::BundleForm)
        .collect();
    assert_eq!(forms.len(), 10, "one BundleForm per bundled member");
    for d in &forms {
        assert!(!d.kind.invariant(), "bundle formation must be environmental");
        assert_eq!(d.b & 0xff, n as u64, "packed n");
        assert_eq!((d.b >> 8) & 0xff, 0, "packed prec (f64 = 0)");
        let live = (d.b >> 16) & 0xff;
        let slot = (d.b >> 24) & 0xff;
        assert!((1..=4).contains(&live), "live {live}");
        assert!(slot < live, "slot {slot} vs live {live}");
    }
    let n_submits = decisions
        .iter()
        .filter(|d| d.kind == DecisionKind::Submit)
        .count();
    assert_eq!(n_submits, 10);
}
