//! Cross-variant integration: every coordinator computes the *same*
//! factorization, under thread-count, block-size and entry-policy
//! variation, including failure-injection and adversarial inputs.

use malleable_lu::blis::BlisParams;
use malleable_lu::lu::{factorize, residual, solve, LuConfig, Variant};
use malleable_lu::matrix::{naive, Matrix};
use malleable_lu::pool::{EntryPolicy, Pool};
use malleable_lu::util::quickcheck_lite::{forall_res, Gen};

fn cfg(v: Variant, bo: usize, bi: usize, threads: usize) -> LuConfig {
    LuConfig {
        variant: v,
        bo,
        bi,
        threads,
        params: BlisParams::tiny(),
        ..Default::default()
    }
}

#[test]
fn all_variants_same_pivots_same_solution() {
    let n = 96;
    let a0 = Matrix::random(n, n, 1);
    let b: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
    let mut reference: Option<(Vec<usize>, Vec<f64>)> = None;
    for &v in Variant::all() {
        let mut f = a0.clone();
        let out = factorize(&mut f, &cfg(v, 16, 4, 3), None);
        let r = residual(&a0, &f, &out.ipiv);
        assert!(r < 1e-11, "{}: residual {r}", v.name());
        let x = solve(&f, &out.ipiv, &b);
        match &reference {
            None => reference = Some((out.ipiv, x)),
            Some((piv0, x0)) => {
                assert_eq!(*piv0, out.ipiv, "{} pivots", v.name());
                for i in 0..n {
                    assert!((x[i] - x0[i]).abs() < 1e-9, "{} x[{i}]", v.name());
                }
            }
        }
    }
}

#[test]
fn thread_count_never_changes_results() {
    let n = 64;
    let a0 = Matrix::random(n, n, 2);
    for v in [Variant::Malleable, Variant::EarlyTerm, Variant::OmpSs] {
        let mut results = Vec::new();
        for threads in [1usize, 2, 4] {
            let mut f = a0.clone();
            let out = factorize(&mut f, &cfg(v, 16, 4, threads), None);
            results.push((out.ipiv, f));
        }
        for w in results.windows(2) {
            assert_eq!(w[0].0, w[1].0, "{} pivots vs thread count", v.name());
            let d = w[0].1.max_abs_diff(&w[1].1);
            assert!(d < 1e-10, "{} factors vs thread count: {d}", v.name());
        }
    }
}

#[test]
fn entry_policy_is_scheduling_only() {
    let n = 80;
    let a0 = Matrix::random(n, n, 3);
    let mut outs = Vec::new();
    for entry in [EntryPolicy::JobBoundary, EntryPolicy::Immediate] {
        let mut c = cfg(Variant::EarlyTerm, 16, 4, 3);
        c.entry = entry;
        let mut f = a0.clone();
        let out = factorize(&mut f, &c, None);
        assert!(residual(&a0, &f, &out.ipiv) < 1e-11);
        outs.push((out.ipiv, f));
    }
    assert_eq!(outs[0].0, outs[1].0);
    // ET cut points are timing-dependent, so operation *grouping* (and
    // hence last-ulp rounding) may differ between entry policies; the
    // factorization itself must agree to tolerance with equal pivots.
    let d = outs[0].1.max_abs_diff(&outs[1].1);
    assert!(d < 1e-10, "entry policies diverged: {d}");
}

#[test]
fn shared_pool_reused_across_factorizations() {
    // The pool survives many factorizations (no worker leakage/deadlock).
    let pool = Pool::new(2);
    for round in 0..5 {
        let n = 32 + round * 8;
        let a0 = Matrix::random(n, n, round as u64);
        let mut f = a0.clone();
        let out = factorize(&mut f, &cfg(Variant::EarlyTerm, 8, 4, 3), Some(&pool));
        assert!(residual(&a0, &f, &out.ipiv) < 1e-11, "round {round}");
    }
}

#[test]
fn adversarial_matrices() {
    // Singular, identity, rank-1, constant, and near-tie pivot matrices.
    let cases: Vec<(&str, Matrix)> = vec![
        ("zero", Matrix::zeros(24, 24)),
        ("identity", Matrix::eye(24)),
        ("rank1", {
            let mut m = Matrix::zeros(24, 24);
            for j in 0..24 {
                for i in 0..24 {
                    m[(i, j)] = (i + 1) as f64 * (j + 1) as f64;
                }
            }
            m
        }),
        ("constant", Matrix::from_fn(24, 24, |_, _| 3.25)),
        ("negated-ties", Matrix::from_fn(24, 24, |i, j| {
            if (i + j) % 2 == 0 { 1.0 } else { -1.0 }
        })),
    ];
    for (name, a0) in cases {
        for v in [Variant::BlockedRl, Variant::EarlyTerm, Variant::OmpSs] {
            let mut f = a0.clone();
            let out = factorize(&mut f, &cfg(v, 8, 4, 2), None);
            assert!(
                f.data().iter().all(|x| x.is_finite()),
                "{name}/{}: non-finite factor",
                v.name()
            );
            assert_eq!(out.ipiv.len(), 24, "{name}/{}", v.name());
            // For the nonsingular cases, check the residual too.
            if matches!(name, "identity" | "negated-ties") {
                let r = residual(&a0, &f, &out.ipiv);
                assert!(r < 1e-12, "{name}/{}: {r}", v.name());
            }
        }
    }
}

#[test]
fn et_adaptive_width_converges_not_collapses() {
    // ET must adapt the block size without collapsing to bi forever:
    // with a benign large problem the attempted width regrows.
    let n = 160;
    let a0 = Matrix::random(n, n, 9);
    let mut f = a0.clone();
    let out = factorize(&mut f, &cfg(Variant::EarlyTerm, 32, 4, 3), None);
    let stats = out.la_stats.unwrap();
    assert_eq!(stats.panel_widths.iter().sum::<usize>(), n);
    assert!(
        stats.panel_widths.iter().any(|&w| w > 4),
        "ET collapsed to the minimum width: {:?}",
        stats.panel_widths
    );
    assert!(residual(&a0, &f, &out.ipiv) < 1e-11);
}

#[test]
fn property_random_configs_all_valid() {
    forall_res("any (variant, bo, bi, t, n) factorizes", 12, |g: &mut Gen| {
        let n = g.usize_in(8, 72);
        let bo = g.choose(&[4usize, 8, 16, 32, 64]);
        let bi = g.choose(&[1usize, 2, 4, 8]);
        let threads = g.usize_in(1, 4);
        let v = g.choose(&[
            Variant::BlockedRl,
            Variant::BlockedLl,
            Variant::LookAhead,
            Variant::Malleable,
            Variant::EarlyTerm,
            Variant::OmpSs,
        ]);
        let seed = g.seed();
        g.label(format!("{} n={n} bo={bo} bi={bi} t={threads}", v.name()));
        let a0 = Matrix::random(n, n, seed);
        let mut f = a0.clone();
        let out = factorize(&mut f, &cfg(v, bo, bi, threads), None);
        let r = residual(&a0, &f, &out.ipiv);
        if r > 1e-10 {
            return Err(format!("residual {r}"));
        }
        if !naive::growth_bounded(&f) {
            return Err("|L| > 1".into());
        }
        Ok(())
    });
}
