//! Factorization-family integration: Cholesky and QR run through the
//! *same* generic WS+ET look-ahead driver as LU, validated against the
//! naive oracles and checked for bitwise cross-crew-size agreement —
//! mirroring `variants_agree.rs` for the two new kinds.

use malleable_lu::blis::BlisParams;
use malleable_lu::factor::{factorize_lookahead, FactorKind, FactorOutcome, LaOpts};
use malleable_lu::matrix::{naive, Matrix};
use malleable_lu::pool::Pool;
use malleable_lu::serve::{LuRequest, LuServer, ServeConfig};

fn input_for(kind: FactorKind, m: usize, n: usize, seed: u64) -> Matrix {
    match kind {
        FactorKind::Chol => Matrix::random_spd(n, seed),
        _ => Matrix::random(m, n, seed),
    }
}

fn run(
    kind: FactorKind,
    a0: &Matrix,
    bo: usize,
    bi: usize,
    workers: usize,
    opts: &LaOpts,
) -> (Matrix, FactorOutcome) {
    let pool = Pool::new(workers);
    let mut f = a0.clone();
    let out = factorize_lookahead(kind, &pool, &BlisParams::tiny(), &mut f, bo, bi, opts, None);
    (f, out)
}

fn residual(kind: FactorKind, a0: &Matrix, f: &Matrix, out: &FactorOutcome) -> f64 {
    match kind {
        FactorKind::Lu => naive::lu_residual(a0, f, &out.ipiv),
        FactorKind::Chol => naive::chol_residual(a0, f),
        FactorKind::Qr => naive::qr_residual(a0, f, &out.tau),
    }
}

#[test]
fn cholesky_reconstructs_through_lookahead_driver() {
    for &(n, bo, bi) in &[(48usize, 8usize, 4usize), (64, 16, 4), (33, 16, 8)] {
        let a0 = Matrix::random_spd(n, (n + bo) as u64);
        let opts = LaOpts {
            malleable: true,
            early_term: true,
            ..Default::default()
        };
        let (f, out) = run(FactorKind::Chol, &a0, bo, bi, 2, &opts);
        assert!(!out.cancelled);
        assert_eq!(out.cols_done, n);
        let r = naive::chol_residual(&a0, &f);
        assert!(r < 1e-11, "n={n} bo={bo} residual {r}");
        // The factorization also matches the naive oracle numerically.
        let mut g = a0.clone();
        naive::cholesky(g.view_mut());
        let mut worst = 0.0f64;
        for j in 0..n {
            for i in j..n {
                worst = worst.max((f[(i, j)] - g[(i, j)]).abs());
            }
        }
        assert!(worst < 1e-9, "n={n}: lower-triangle diff {worst}");
        // The upper triangle is exactly as on entry (never touched).
        for j in 1..n {
            for i in 0..j {
                assert_eq!(f[(i, j)], a0[(i, j)], "upper entry ({i},{j}) touched");
            }
        }
    }
}

#[test]
fn qr_is_orthogonal_and_reconstructs() {
    // Square, tall, and wide problems through the look-ahead driver.
    for &(m, n) in &[(48usize, 48usize), (64, 40), (40, 64)] {
        let a0 = Matrix::random(m, n, (m * 3 + n) as u64);
        let opts = LaOpts {
            malleable: true,
            early_term: true,
            ..Default::default()
        };
        let (f, out) = run(FactorKind::Qr, &a0, 16, 4, 2, &opts);
        assert!(!out.cancelled);
        assert_eq!(out.cols_done, m.min(n));
        assert_eq!(out.tau.len(), m.min(n));
        let r = naive::qr_residual(&a0, &f, &out.tau);
        assert!(r < 1e-11, "m={m} n={n}: ‖A − QR‖/‖A‖ = {r}");
        let q = naive::qr_q(&f, &out.tau);
        let o = naive::orthogonality(&q);
        assert!(o < 1e-12, "m={m} n={n}: ‖QᵀQ − I‖ = {o}");
    }
}

#[test]
fn crew_size_never_changes_bits_for_any_kind() {
    // The acceptance gate of the factorization family: for a fixed
    // schedule (WS on, ET off — ET cut points are timing-dependent),
    // the factors of every kind are bitwise identical for any crew size.
    let n = 64;
    for &kind in FactorKind::all() {
        let a0 = input_for(kind, n, n, 5);
        let opts = LaOpts {
            malleable: true,
            ..Default::default()
        };
        let mut reference: Option<(Matrix, FactorOutcome)> = None;
        for workers in [1usize, 2, 4] {
            let (f, out) = run(kind, &a0, 16, 4, workers, &opts);
            assert_eq!(out.cols_done, n, "{} w={workers}", kind.name());
            match &reference {
                None => reference = Some((f, out)),
                Some((f0, o0)) => {
                    assert_eq!(o0.ipiv, out.ipiv, "{} pivots w={workers}", kind.name());
                    for (x, y) in o0.tau.iter().zip(&out.tau) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{} tau w={workers}", kind.name());
                    }
                    for (x, y) in f0.data().iter().zip(f.data()) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{} w={workers}", kind.name());
                    }
                }
            }
        }
    }
}

#[test]
fn et_schedule_changes_not_the_math() {
    // With ET on, cut points (and thus rounding groupings) are timing-
    // dependent, but every kind must still produce a valid factorization
    // of full rank.
    let n = 72;
    for &kind in FactorKind::all() {
        let a0 = input_for(kind, n, n, 9);
        let opts = LaOpts {
            malleable: true,
            early_term: true,
            ..Default::default()
        };
        let (f, out) = run(kind, &a0, 24, 4, 2, &opts);
        assert_eq!(out.cols_done, n, "{}", kind.name());
        let stats = out.la_stats.as_ref().expect("look-ahead stats");
        assert_eq!(
            stats.panel_widths.iter().sum::<usize>(),
            n,
            "{}: every column factorized exactly once",
            kind.name()
        );
        let r = residual(kind, &a0, &f, &out);
        assert!(r < 1e-10, "{}: residual {r}", kind.name());
    }
}

#[test]
fn lookahead_equals_blocked_serve_path_bitwise() {
    // One driver, two schedules: the generic look-ahead (WS on) and the
    // serve layer's blocked driver must produce bitwise-identical
    // factors for every kind — the per-element operation chains are
    // split-invariant by construction.
    let n = 56;
    let server = LuServer::new(ServeConfig {
        workers: 2,
        bo: 16,
        bi: 4,
        params: BlisParams::tiny(),
        ..Default::default()
    });
    for &kind in FactorKind::all() {
        let a0 = input_for(kind, n, n, 13);
        let opts = LaOpts {
            malleable: true,
            ..Default::default()
        };
        let (f_la, out_la) = run(kind, &a0, 16, 4, 2, &opts);
        let res = server
            .submit(LuRequest::new(a0.clone()).with_kind(kind).with_blocks(16, 4))
            .wait();
        assert!(!res.cancelled, "{}", kind.name());
        assert_eq!(res.cols_done, n, "{}", kind.name());
        assert_eq!(out_la.ipiv, res.ipiv, "{} pivots", kind.name());
        for (x, y) in out_la.tau.iter().zip(&res.tau) {
            assert_eq!(x.to_bits(), y.to_bits(), "{} tau", kind.name());
        }
        for (x, y) in f_la.data().iter().zip(res.a.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{}", kind.name());
        }
    }
    server.shutdown();
}
