//! Cross-path equivalence suite for the interleaved small-problem fast
//! path (DESIGN.md §18) — the pin that lets the router move problems
//! between the per-problem crew driver and the SIMD-interleaved batch
//! kernel freely.
//!
//! The contract under test, in increasing strictness:
//!
//! 1. **Bitwise identity vs the unblocked leaf.** A problem factored in
//!    any lane of any bundle (full or ragged, either precision, AVX2 or
//!    portable kernel) produces *exactly* the bits `lu_unblocked` would:
//!    pivot-for-pivot and element-for-element. This is what makes bundle
//!    composition a pure placement decision.
//! 2. **EPSILON-scaled residuals.** Batched factors are backward-stable
//!    at each precision's own epsilon — the f32 path is not "f64 but
//!    sloppier", it is correct at its own scale.
//! 3. **Routing invariance.** Flipping the serve `interleave` knob (or
//!    moving the threshold) changes *where* a small problem runs, never
//!    *what* it computes.
//!
//! Random bundle compositions (sizes, ragged tails, mixed-size queues
//! that must never be bundled together) are exercised through the
//! `quickcheck_lite` property harness; failures reproduce via `QC_SEED`.

use malleable_lu::blis::micro::{set_kernel, Kernel};
use malleable_lu::blis::smallbatch::{lu_unblocked_batch, SmallBundle};
use malleable_lu::lu::lu_unblocked;
use malleable_lu::matrix::{naive, Mat, Matrix};
use malleable_lu::scalar::Scalar;
use malleable_lu::serve::{choose_strategy, LuRequest, LuServer, ServeConfig, Strategy};
use malleable_lu::sim::HwModel;
use malleable_lu::util::quickcheck_lite::{forall_res, Gen};
use std::sync::Mutex;

/// Serializes the tests in this binary: several flip the process-wide
/// kernel registry or compare results *across* whole server runs, and
/// a concurrent flip mid-run would turn a bitwise claim flaky.
static LOCK: Mutex<()> = Mutex::new(());

fn ref_lu<S: Scalar>(a: &Mat<S>) -> (Mat<S>, Vec<usize>) {
    let mut f = a.clone();
    let ipiv = lu_unblocked(f.view_mut());
    (f, ipiv)
}

fn bits<S: Scalar>(m: &Mat<S>) -> Vec<u64> {
    m.data().iter().map(|v| v.to_bits_u64()).collect()
}

/// Contract 1 for every size the router can choose, at full bundle
/// width, under both the portable and the active-best kernel.
fn sweep_full_width<S: Scalar>() {
    let w = SmallBundle::<S>::width();
    for kernel in [Kernel::Portable, Kernel::Auto] {
        set_kernel(kernel);
        for n in 1..=64usize {
            let mats: Vec<Mat<S>> = (0..w)
                .map(|l| Mat::random(n, n, (n * 131 + l) as u64))
                .collect();
            let mut batch = mats.clone();
            let pivots = lu_unblocked_batch(&mut batch);
            for ((got, piv), a0) in batch.iter().zip(&pivots).zip(&mats) {
                let (f, ipiv) = ref_lu(a0);
                assert_eq!(*piv, ipiv, "{} n={n} {kernel:?}: pivots", S::NAME);
                assert_eq!(bits(got), bits(&f), "{} n={n} {kernel:?}: factors", S::NAME);
            }
        }
    }
    set_kernel(Kernel::Auto);
}

#[test]
fn full_width_bundles_agree_bitwise_f64() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    sweep_full_width::<f64>();
}

#[test]
fn full_width_bundles_agree_bitwise_f32() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    sweep_full_width::<f32>();
}

/// Contract 1 on ragged bundles: every live count below the SIMD width,
/// with dead lanes that must never bleed into live results.
fn sweep_ragged<S: Scalar>() {
    let w = SmallBundle::<S>::width();
    for n in [1usize, 3, 8, 17, 33, 64] {
        for live in 1..=w {
            let mats: Vec<Mat<S>> = (0..live)
                .map(|l| Mat::random(n, n, (n * 977 + l) as u64))
                .collect();
            let refs: Vec<&Mat<S>> = mats.iter().collect();
            let mut bundle = SmallBundle::pack(&refs);
            bundle.factor();
            for (slot, a0) in mats.iter().enumerate() {
                let (f, ipiv) = ref_lu(a0);
                assert_eq!(bundle.pivots(slot), ipiv, "{} n={n} live={live}", S::NAME);
                assert_eq!(
                    bits(&bundle.lane_matrix(slot)),
                    bits(&f),
                    "{} n={n} live={live} slot={slot}",
                    S::NAME
                );
            }
        }
    }
}

#[test]
fn ragged_bundles_agree_bitwise_f64() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    sweep_ragged::<f64>();
}

#[test]
fn ragged_bundles_agree_bitwise_f32() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    sweep_ragged::<f32>();
}

/// Contract 2: backward error scales with the precision's own epsilon.
fn residual_sweep<S: Scalar>() {
    let w = SmallBundle::<S>::width();
    let eps = S::EPSILON.to_f64();
    for n in [8usize, 16, 32, 64] {
        let mats: Vec<Mat<S>> = (0..w)
            .map(|l| Mat::random(n, n, (n * 7 + l + 1) as u64))
            .collect();
        let mut batch = mats.clone();
        let pivots = lu_unblocked_batch(&mut batch);
        let bound = 64.0 * n as f64 * eps;
        for ((f, piv), a0) in batch.iter().zip(&pivots).zip(&mats) {
            let r = naive::lu_residual(a0, f, piv);
            assert!(r < bound, "{} n={n}: residual {r} vs {bound}", S::NAME);
            assert!(naive::growth_bounded(f), "{} n={n}", S::NAME);
        }
    }
}

#[test]
fn residuals_scale_with_own_epsilon() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    residual_sweep::<f64>();
    residual_sweep::<f32>();
}

/// Property: any bundle composition — random size, random problem count
/// (spanning several full bundles plus a ragged tail) — is bitwise
/// per-problem-exact, in both precisions.
fn composition_property<S: Scalar>(cases: usize) {
    let w = SmallBundle::<S>::width();
    forall_res(
        &format!("{} bundle composition ≡ per-problem", S::NAME),
        cases,
        |g: &mut Gen| {
            let n = g.usize_in(1, 64);
            let count = g.usize_in(1, 2 * w + 3);
            g.label(format!("n={n} count={count}"));
            let base = g.seed();
            let mats: Vec<Mat<S>> = (0..count)
                .map(|i| Mat::random(n, n, base ^ ((i as u64) << 8)))
                .collect();
            let mut batch = mats.clone();
            let pivots = lu_unblocked_batch(&mut batch);
            for (i, a0) in mats.iter().enumerate() {
                let (f, ipiv) = ref_lu(a0);
                if pivots[i] != ipiv {
                    return Err(format!("problem {i}: pivots diverge"));
                }
                if bits(&batch[i]) != bits(&f) {
                    return Err(format!("problem {i}: factor bits diverge"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn random_compositions_agree_bitwise() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    composition_property::<f64>(40);
    composition_property::<f32>(40);
}

/// Property: a queue mixing sizes (and both precisions, via interleaved
/// submissions) must group same-shape same-precision requests only —
/// a cross-shape bundle would panic the leader and surface as an
/// internal error, and a cross-composition rounding leak would break
/// the bitwise check.
#[test]
fn mixed_size_queues_are_never_bundled_together() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    forall_res("mixed-size queue routes cleanly", 6, |g: &mut Gen| {
        let count = g.usize_in(6, 12);
        let sizes: Vec<usize> = (0..count).map(|_| g.usize_in(1, 64)).collect();
        g.label(format!("sizes={sizes:?}"));
        let base = g.seed();
        let server = LuServer::new(ServeConfig {
            interleave: true,
            workers: 2,
            ..Default::default()
        });
        let mats: Vec<Matrix> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| Matrix::random(n, n, base ^ ((i as u64) << 8)))
            .collect();
        let handles: Vec<_> = mats
            .iter()
            .map(|a| server.submit(LuRequest::new(a.clone())))
            .collect();
        for (h, a0) in handles.into_iter().zip(&mats) {
            let res = h.wait();
            if res.cancelled || res.error.is_some() {
                return Err(format!(
                    "req{}: cancelled={} error={:?}",
                    res.id, res.cancelled, res.error
                ));
            }
            let (f, ipiv) = ref_lu(a0);
            if res.ipiv != ipiv || bits(&res.a) != bits(&f) {
                return Err(format!("req{} (n={}): diverges", res.id, a0.rows()));
            }
        }
        server.shutdown();
        Ok(())
    });
}

/// Contract 3: the serve `interleave` knob moves placement only. Sizes
/// where both paths share the unblocked leaf arithmetic (single-panel
/// small problems, and per-request `bi` overrides that force the
/// fallback) must come back bitwise identical under either knob
/// setting; a big per-problem request pins that the classic path is
/// untouched.
#[test]
fn interleave_knob_moves_placement_only() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let run = |interleave: bool| {
        let server = LuServer::new(ServeConfig {
            interleave,
            workers: 2,
            ..Default::default()
        });
        let mut reqs = Vec::new();
        for (i, n) in [6usize, 12, 16].into_iter().enumerate() {
            reqs.push(LuRequest::new(Matrix::random(n, n, 40 + i as u64)));
        }
        // Above bi=16 the blocked panel would regroup the arithmetic, so
        // force the unblocked fallback with a per-request block override
        // — routing is still by size, only the off-path leaf changes.
        reqs.push(LuRequest::new(Matrix::random(40, 40, 77)).with_blocks(64, 40));
        // Far above the threshold: per-problem under both settings.
        reqs.push(LuRequest::new(Matrix::random(100, 100, 99)));
        let out = server.factorize_batch(reqs);
        server.shutdown();
        out
    };
    let on = run(true);
    let off = run(false);
    assert_eq!(on.len(), off.len());
    for (a, b) in on.iter().zip(&off) {
        assert!(!a.cancelled && !b.cancelled);
        assert_eq!(a.ipiv, b.ipiv, "n={}: pivots moved with the knob", a.a.rows());
        assert_eq!(
            bits(&a.a),
            bits(&b.a),
            "n={}: factor bits moved with the knob",
            a.a.rows()
        );
    }
    // Where the per-problem path uses genuinely different (blocked)
    // arithmetic, both routes still deliver epsilon-scale backward
    // error — the knob trades placement, never correctness.
    let a0 = Matrix::random(40, 40, 123);
    for interleave in [true, false] {
        let server = LuServer::new(ServeConfig {
            interleave,
            workers: 2,
            ..Default::default()
        });
        let res = server.submit(LuRequest::new(a0.clone())).wait();
        server.shutdown();
        assert!(!res.cancelled && res.error.is_none());
        let r = naive::lu_residual(&a0, &res.a, &res.ipiv);
        assert!(r < 1e-12, "interleave={interleave}: residual {r}");
        assert!(naive::growth_bounded(&res.a));
    }
}

/// The threshold itself only flips [`Strategy`] — and since both
/// strategies are pinned bitwise-equal above, moving it can never
/// change results. This nails the routing boundary the cost model
/// derives (`HwModel::small_threshold`).
#[test]
fn threshold_is_a_pure_placement_boundary() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = ServeConfig {
        interleave: true,
        ..Default::default()
    };
    let thr = cfg.hw.small_threshold(<f64 as Scalar>::SIMD_LANES);
    assert_eq!(thr, HwModel::default().small_threshold(4));
    assert!(thr >= 16, "threshold {thr} too small to cover the suite");
    for n in [1usize, thr / 2, thr, thr + 1, 2 * thr] {
        let want = if n <= thr {
            Strategy::Interleaved
        } else {
            Strategy::PerProblem
        };
        let req = LuRequest::new(Matrix::zeros(n, n));
        assert_eq!(choose_strategy(&cfg, &req), want, "n={n}");
    }
}
