//! The tile-DAG agreement harness (DESIGN.md §17): the dataflow runtime
//! must produce **bitwise identical** factorizations to the blocked
//! driver — for every kind × precision × executor count, while
//! executors are donated and revoked mid-run, and when the serve layer
//! routes requests at it with leases being granted and revoked under a
//! live queue.
//!
//! The argument mirrors `steal_agree.rs`: DAG tasks run the blocked
//! driver's own kernels, [`Factorization::apply`] is column-split
//! invariant, panel tasks complete in `k` order, and LU's left row
//! swaps replay in a `k`-ordered epilogue — so scheduling (executor
//! count, donation timing, revocation timing) moves *ownership* of
//! work, never its arithmetic. These tests prove it rather than assume
//! it.

use malleable_lu::blis::BlisParams;
use malleable_lu::factor::{factorize_blocked, DriverFamily, FactorCtl, FactorKind};
use malleable_lu::matrix::{Mat, Matrix};
use malleable_lu::pool::{Crew, Pool};
use malleable_lu::scalar::Scalar;
use malleable_lu::serve::{LuRequest, LuServer, ServeConfig};
use malleable_lu::tilert::{factorize_dag, factorize_dag_shared, DagSlot, NO_REQ};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// Bitwise signature of one factorization run: every matrix element's
/// bits, the pivots, and the tau bits.
#[derive(PartialEq, Eq, Debug)]
struct RunBits {
    a: Vec<u64>,
    ipiv: Vec<usize>,
    tau: Vec<u64>,
    cols_done: usize,
}

fn problem<S: Scalar>(kind: FactorKind, n: usize, seed: u64) -> Mat<S> {
    match kind {
        FactorKind::Chol => Mat::<S>::random_spd(n, seed),
        _ => Mat::<S>::random(n, n, seed),
    }
}

/// The lone-leader blocked run every DAG schedule must reproduce.
fn run_blocked<S: Scalar>(kind: FactorKind, a0: &Mat<S>, bo: usize) -> RunBits {
    let params = BlisParams::tiny();
    let mut crew = Crew::new();
    let mut f = a0.clone();
    let out = factorize_blocked(
        kind,
        &mut crew,
        &params,
        f.view_mut(),
        bo,
        4,
        &FactorCtl::default(),
    );
    assert!(out.error.is_none(), "blocked: {:?}", out.error);
    RunBits {
        a: f.data().iter().map(|x| x.to_bits_u64()).collect(),
        ipiv: out.ipiv,
        tau: out.tau.iter().map(|x| x.to_bits_u64()).collect(),
        cols_done: out.cols_done,
    }
}

/// One pool-backed DAG run: the calling thread plus `workers` pool
/// executors drain the task graph.
fn run_dag_pool<S: Scalar>(kind: FactorKind, a0: &Mat<S>, bo: usize, workers: usize) -> RunBits {
    let params = BlisParams::tiny();
    let pool = Pool::new(workers);
    let mut f = a0.clone();
    let out = factorize_dag(kind, &pool, &params, &mut f, bo, 4, &FactorCtl::default());
    assert!(out.error.is_none(), "dag: {:?}", out.error);
    RunBits {
        a: f.data().iter().map(|x| x.to_bits_u64()).collect(),
        ipiv: out.ipiv,
        tau: out.tau.iter().map(|x| x.to_bits_u64()).collect(),
        cols_done: out.cols_done,
    }
}

/// An executor-roster event fired when the leader's checkpoint reaches
/// `at_col` committed columns: donor `donor` starts attaching to the
/// drain, or has its lease revoked (observed at the next task boundary).
#[derive(Copy, Clone, Debug)]
struct RosterEvent {
    at_col: usize,
    donor: usize,
    join: bool,
}

/// One slot-backed DAG run with a malleable executor roster: `n_donors`
/// donor threads attach to the published drain whenever their gate is
/// open; the leader's checkpoint callback opens and closes gates per
/// the event schedule, so donations and revocations land exactly at the
/// column boundaries a serve-layer lease change would land, and
/// revocations retire donors at task boundaries.
fn run_dag_malleable<S: Scalar>(
    kind: FactorKind,
    a0: &Mat<S>,
    bo: usize,
    n_donors: usize,
    events: &[RosterEvent],
) -> RunBits {
    let params = BlisParams::tiny();
    let slot = Arc::new(DagSlot::new());
    let active: Arc<Vec<AtomicBool>> =
        Arc::new((0..n_donors).map(|_| AtomicBool::new(false)).collect());
    let quit = Arc::new(AtomicBool::new(false));
    let donors: Vec<_> = (0..n_donors)
        .map(|i| {
            let slot = Arc::clone(&slot);
            let act = Arc::clone(&active);
            let q = Arc::clone(&quit);
            std::thread::spawn(move || {
                while !q.load(Ordering::Acquire) {
                    if act[i].load(Ordering::Acquire) {
                        let act2 = Arc::clone(&act);
                        let q2 = Arc::clone(&q);
                        // Attach returns when the drain finishes, the
                        // lease predicate turns false (revocation), or
                        // no drain is published (None).
                        let _ = slot.attach(move || {
                            act2[i].load(Ordering::Acquire) && !q2.load(Ordering::Acquire)
                        });
                    }
                    std::thread::yield_now();
                }
            })
        })
        .collect();

    // Everyone except the event-scheduled latecomers starts attached.
    for i in 0..n_donors {
        let latecomer = events.iter().any(|e| e.donor == i && e.join);
        active[i].store(!latecomer, Ordering::Release);
    }
    let mut events_sorted = events.to_vec();
    events_sorted.sort_by_key(|e| e.at_col);
    let cursor = AtomicUsize::new(0);
    let active2 = Arc::clone(&active);
    let checkpoint = move |k: usize| {
        let mut idx = cursor.load(Ordering::Relaxed);
        while idx < events_sorted.len() && events_sorted[idx].at_col <= k {
            let e = events_sorted[idx];
            if e.donor < active2.len() {
                active2[e.donor].store(e.join, Ordering::Release);
            }
            idx += 1;
        }
        cursor.store(idx, Ordering::Relaxed);
    };
    let ctl = FactorCtl {
        cancel: None,
        tag: None,
        on_checkpoint: Some(&checkpoint),
    };

    let mut f = a0.clone();
    let out = factorize_dag_shared(kind, &slot, &params, f.view_mut(), bo, 4, &ctl, NO_REQ);
    assert!(out.error.is_none(), "dag shared: {:?}", out.error);

    quit.store(true, Ordering::Release);
    for t in donors {
        t.join().unwrap();
    }

    RunBits {
        a: f.data().iter().map(|x| x.to_bits_u64()).collect(),
        ipiv: out.ipiv,
        tau: out.tau.iter().map(|x| x.to_bits_u64()).collect(),
        cols_done: out.cols_done,
    }
}

/// The exhaustive acceptance sweep: all kinds × both precisions ×
/// executor rosters 1–6 (leader + 0..=5 pool workers), each DAG run
/// compared bitwise against the lone-leader blocked run.
#[test]
fn dag_bitwise_equals_blocked_all_kinds_precisions_crews() {
    fn sweep<S: Scalar>() {
        let n = 48;
        let bo = 8;
        for &kind in FactorKind::all() {
            let a0 = problem::<S>(kind, n, 0xD1 + kind.name().len() as u64);
            let baseline = run_blocked(kind, &a0, bo);
            assert_eq!(baseline.cols_done, n);
            for crew_size in 1..=6usize {
                let dag = run_dag_pool(kind, &a0, bo, crew_size - 1);
                assert_eq!(
                    dag,
                    baseline,
                    "{}/{}: dag crew {crew_size} vs blocked lone leader",
                    kind.name(),
                    S::NAME
                );
            }
        }
    }
    sweep::<f64>();
    sweep::<f32>();
}

/// Mid-run malleability: donors join and leave the drain at column
/// boundaries chosen by an event schedule — a genuine shrink (donor 0
/// starts attached, is revoked at column 16) plus a genuine grow (the
/// last donor attaches at column 24) — and the bits still match the
/// fixed lone-leader blocked run, for every kind × both precisions.
#[test]
fn dag_grow_and_shrink_mid_run_agree_bitwise() {
    fn sweep<S: Scalar>() {
        let n = 48;
        let bo = 8;
        for &kind in FactorKind::all() {
            let a0 = problem::<S>(kind, n, 0xB7 + kind.name().len() as u64);
            let baseline = run_blocked(kind, &a0, bo);
            let events = [
                RosterEvent {
                    at_col: 16,
                    donor: 0,
                    join: false,
                },
                RosterEvent {
                    at_col: 24,
                    donor: 2,
                    join: true,
                },
            ];
            let dag = run_dag_malleable(kind, &a0, bo, 3, &events);
            assert_eq!(
                dag,
                baseline,
                "{}/{}: malleable dag roster vs blocked",
                kind.name(),
                S::NAME
            );
        }
    }
    sweep::<f64>();
    sweep::<f32>();
}

/// The serve-lease revocation scenario: more DAG-family requests than
/// workers on one server, so floaters are donated to in-flight drains
/// and then revoked (the registry epoch bumps on every register and
/// unregister while the queue drains). Every result must still match
/// its blocked reference bitwise, and a per-matrix pair of requests —
/// one per driver family — must agree with *each other*.
#[test]
fn serve_dag_requests_survive_lease_revocation_bitwise() {
    let cfg = ServeConfig {
        workers: 3,
        bo: 8,
        bi: 4,
        params: BlisParams::tiny(),
        ..Default::default()
    };
    let server = LuServer::new(cfg);
    let mats: Vec<Matrix> = (0..6).map(|i| Matrix::random(40, 40, 900 + i)).collect();
    // Two requests per matrix, one per family, interleaved so DAG
    // drains and crew kernels compete for the same floaters.
    let handles: Vec<_> = mats
        .iter()
        .enumerate()
        .flat_map(|(i, a)| {
            [
                server.submit(
                    LuRequest::new(a.clone())
                        .with_priority((i % 3) as u8)
                        .with_driver(DriverFamily::Dag),
                ),
                server.submit(
                    LuRequest::new(a.clone())
                        .with_priority(((i + 1) % 3) as u8)
                        .with_driver(DriverFamily::Lookahead),
                ),
            ]
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.wait()).collect();
    server.shutdown();
    for (i, a0) in mats.iter().enumerate() {
        let dag = &results[2 * i];
        let la = &results[2 * i + 1];
        for (label, res) in [("dag", dag), ("lookahead", la)] {
            assert!(!res.cancelled, "req {i} [{label}] cancelled");
            assert!(res.error.is_none(), "req {i} [{label}]: {:?}", res.error);
            assert_eq!(res.cols_done, 40, "req {i} [{label}]");
        }
        let reference = run_blocked(FactorKind::Lu, a0, 8);
        for (label, res) in [("dag", dag), ("lookahead", la)] {
            assert_eq!(res.ipiv, reference.ipiv, "req {i} [{label}] pivots");
            let bits: Vec<u64> = res.a.data().iter().map(|x| x.to_bits_u64()).collect();
            assert_eq!(bits, reference.a, "req {i} [{label}] factor bits");
        }
    }
}
