//! Replay-based regression suite for the capture/replay subsystem
//! (DESIGN.md §16): capture a mixed-kind, mixed-precision serve run,
//! replay it repeatedly, and certify bitwise-identical results and
//! decision streams — including across crew sizes, which is the
//! schedule-invariance property (§8/§13) doing real operational work.
//!
//! The chaos CI lane builds this suite with `--features chaos`, so the
//! capture hooks are exercised with the fault-injection hooks compiled
//! in (and disarmed): the determinism claim holds in the
//! instrumentation-heavy build too, not just the lean one.
//!
//! The capture recorder is process-global (one ordinal space), so every
//! test that arms it serializes on [`CAP_LOCK`] — `run_replay` arms it
//! internally as well, which is why the lock wraps whole test bodies.

use malleable_lu::blis::BlisParams;
use malleable_lu::factor::FactorKind;
use malleable_lu::matrix::{Mat, Matrix};
use malleable_lu::replay::{
    bundle, capture, factor_digest, run_replay, solve_digest, Bundle, BundleCfg, DecisionKind,
};
use malleable_lu::serve::{LuRequest, LuServer, ServeConfig, SolveRequest};
use malleable_lu::solve::SolvePrec;
use std::sync::Mutex;

/// Serializes use of the process-global capture recorder across tests
/// in this binary (other test binaries are separate processes).
static CAP_LOCK: Mutex<()> = Mutex::new(());

fn serve_cfg(workers: usize) -> ServeConfig {
    ServeConfig {
        workers,
        bo: 16,
        bi: 8,
        params: BlisParams::tiny(),
        ..Default::default()
    }
}

/// Run the reference mixed workload on `server`, waiting for every
/// result. Returns the per-request digests in submission order —
/// computed through the same digest functions the capture hooks use, so
/// an uncaptured run yields directly comparable values.
fn run_workload(server: &LuServer) -> Vec<u64> {
    let lu64 = Matrix::random(64, 64, 11);
    let chol = Matrix::random_spd(48, 22);
    let qr = Matrix::random(56, 40, 33);
    let lu32 = Mat::<f32>::random(64, 64, 44);
    let sa = Matrix::random(48, 48, 55);
    let sb: Vec<f64> = (0..48).map(|i| 1.0 + (i as f64) * 0.25).collect();
    let h0 = server.submit(LuRequest::new(lu64));
    let h1 = server.submit(LuRequest::new(chol).with_kind(FactorKind::Chol).with_priority(1));
    let h2 = server.submit(LuRequest::new(qr).with_kind(FactorKind::Qr));
    let h3 = server.submit(LuRequest::new(lu32).with_priority(2));
    let h4 = server.submit_solve(SolveRequest::new(sa, sb).with_prec(SolvePrec::Mixed));
    let r0 = h0.wait();
    let r1 = h1.wait();
    let r2 = h2.wait();
    let r3 = h3.wait();
    let r4 = h4.wait();
    assert!(r0.error.is_none() && !r0.cancelled, "{:?}", r0.error);
    assert!(r1.error.is_none() && !r1.cancelled, "{:?}", r1.error);
    assert!(r2.error.is_none() && !r2.cancelled, "{:?}", r2.error);
    assert!(r3.error.is_none() && !r3.cancelled, "{:?}", r3.error);
    assert!(r4.error.is_none() && !r4.cancelled, "{:?}", r4.error);
    vec![
        factor_digest(&r0),
        factor_digest(&r1),
        factor_digest(&r2),
        factor_digest(&r3),
        solve_digest(&r4),
    ]
}

/// Capture the reference workload on a fresh `workers`-worker server
/// and assemble the bundle the way `mlu serve --capture` does.
/// Caller must hold [`CAP_LOCK`].
fn captured_bundle(workers: usize) -> Bundle {
    let cfg = serve_cfg(workers);
    let bcfg = BundleCfg::from_serve(&cfg);
    assert!(capture::start(), "no capture may be active here");
    let server = LuServer::new(cfg);
    run_workload(&server);
    server.shutdown();
    let (decisions, mut requests) = capture::stop().expect("capture was armed");
    requests.sort_by_key(|r| r.id);
    Bundle {
        cfg: bcfg,
        requests,
        decisions,
    }
}

#[test]
fn capture_replay_roundtrip_certifies_three_rounds() {
    let _g = CAP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let bundle = captured_bundle(3);
    assert_eq!(bundle.requests.len(), 5);
    for r in &bundle.requests {
        assert_ne!(r.digest, 0, "request {} never got its result digest", r.id);
        assert!(!r.cancelled && !r.failed);
    }
    // Every request contributed its full invariant lifecycle.
    for kind in [
        DecisionKind::Submit,
        DecisionKind::LeaseGrant,
        DecisionKind::Checkpoint,
        DecisionKind::LeaseRevoke,
    ] {
        let n = bundle.decisions.iter().filter(|d| d.kind == kind).count();
        assert!(n >= 5, "{}: only {n} records", kind.name());
    }
    // The capture -> bundle -> capture round trip is byte-identical
    // (the tentpole's "compact versioned bundle" leg).
    let bytes = bundle::encode(&bundle);
    let back = bundle::decode(&bytes).expect("own encoding must decode");
    assert_eq!(back, bundle);
    assert_eq!(bundle::encode(&back), bytes, "re-encode must be byte-identical");
    // Replay three times on the captured crew size: bitwise results,
    // identical invariant decision streams, every round.
    let report = run_replay(&bundle, 3, None).expect("replay must run");
    assert_eq!(report.rounds, 3);
    assert_eq!(report.certified, 5);
    assert_eq!(report.skipped, 0);
    assert!(
        report.certified_ok(),
        "divergence: {}",
        report.divergence.as_ref().map(|d| d.to_string()).unwrap_or_default()
    );
    let rendered = report.render();
    assert!(rendered.contains("CERTIFIED"), "{rendered}");
}

#[test]
fn replay_certifies_across_crew_sizes() {
    let _g = CAP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let bundle = captured_bundle(2);
    for workers in [1usize, 3, 6] {
        let report = run_replay(&bundle, 1, Some(workers)).expect("replay must run");
        assert!(
            report.certified_ok(),
            "workers={workers}: {}",
            report.divergence.as_ref().map(|d| d.to_string()).unwrap_or_default()
        );
        assert_eq!(report.certified, 5, "workers={workers}");
    }
}

#[test]
fn capture_changes_no_results_and_is_deterministic() {
    let _g = CAP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // Uncaptured reference run: same digests the hooks would compute.
    let server = LuServer::new(serve_cfg(3));
    let bare = run_workload(&server);
    server.shutdown();
    // Captured run: recording must not change a single result bit —
    // the "capture overhead changes zero decisions" pin.
    let b1 = captured_bundle(3);
    let captured: Vec<u64> = b1.requests.iter().map(|r| r.digest).collect();
    assert_eq!(captured, bare, "capture mode altered a result");
    // And capture itself is deterministic: a second captured run records
    // the same request payloads and the same invariant decision stream.
    let b2 = captured_bundle(3);
    assert_eq!(b1.requests, b2.requests);
    // Per-request invariant subsequences reproduce record-for-record;
    // only the global interleaving across requests is timing-dependent.
    let inv = |b: &Bundle, id: u64| -> Vec<(DecisionKind, u64, u64)> {
        b.decisions
            .iter()
            .filter(|d| d.kind.invariant() && d.req == id)
            .map(|d| (d.kind, d.a, d.b))
            .collect()
    };
    for id in 0..5u64 {
        assert_eq!(inv(&b1, id), inv(&b2, id), "invariant stream differs for req {id}");
    }
}

#[test]
fn injected_divergence_reports_exact_ordinal_and_refuses_certification() {
    let _g = CAP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut bundle = captured_bundle(2);
    // Perturb one *invariant* record: the first checkpoint of request 0.
    let idx = bundle
        .decisions
        .iter()
        .position(|d| d.kind == DecisionKind::Checkpoint && d.req == 0)
        .expect("request 0 must have checkpoints");
    let expected_ordinal = bundle.decisions[idx].ordinal;
    bundle.decisions[idx].b ^= 1; // one ulp in the cost estimate
    let report = run_replay(&bundle, 1, None).expect("replay must run");
    assert!(!report.certified_ok(), "perturbed bundle must not certify");
    assert_eq!(report.certified, 0, "certification is refused outright");
    let d = report.divergence.expect("divergence must be reported");
    assert_eq!(
        d.ordinal, expected_ordinal,
        "first divergence must name the exact perturbed ordinal"
    );
    assert_eq!(d.req, 0);
    assert!(d.got.is_some(), "replay produced a record at that position");
    assert!(
        d.context.contains(">>"),
        "context strip must mark the culprit:\n{}",
        d.context
    );
    let rendered = format!("{d}");
    assert!(
        rendered.contains(&format!("ordinal {expected_ordinal}")),
        "{rendered}"
    );
}

#[test]
fn environmental_records_never_block_certification() {
    let _g = CAP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut bundle = captured_bundle(2);
    // Perturb every *environmental* record: steal deltas, WS joins,
    // admission verdicts are timing artifacts (§16.4) — certification
    // must not compare them.
    let mut touched = 0;
    for d in &mut bundle.decisions {
        if !d.kind.invariant() {
            d.b ^= 0xdead;
            touched += 1;
        }
    }
    assert!(touched > 0, "workload must produce environmental records");
    let report = run_replay(&bundle, 1, None).expect("replay must run");
    assert!(
        report.certified_ok(),
        "environmental perturbation must not refuse certification: {}",
        report.divergence.as_ref().map(|d| d.to_string()).unwrap_or_default()
    );
}

#[test]
fn tampered_result_digest_refuses_certification() {
    let _g = CAP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut bundle = captured_bundle(2);
    bundle.requests[1].digest ^= 1;
    let report = run_replay(&bundle, 1, None).expect("replay must run");
    assert!(!report.certified_ok(), "wrong digest must not certify");
    let d = report.divergence.expect("divergence must be reported");
    assert_eq!(d.req, 1);
    assert!(d.expected.contains("digest"), "{}", d.expected);
}

/// A DAG-family workload (DESIGN.md §17.5) must capture, roundtrip, and
/// certify like any other: the Submit decision carries the family code
/// in bits 24–31 so the replayer re-routes each request to the driver
/// family that produced it, TaskGrant records are present and
/// environmental (grant timing is scheduling context, never certified),
/// and certification holds across worker counts.
#[test]
fn dag_family_capture_replays_and_certifies() {
    use malleable_lu::factor::DriverFamily;
    let _g = CAP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = serve_cfg(3);
    let bcfg = BundleCfg::from_serve(&cfg);
    assert!(capture::start(), "no capture may be active here");
    let server = LuServer::new(cfg);
    let h0 = server
        .submit(LuRequest::new(Matrix::random(64, 64, 71)).with_driver(DriverFamily::Dag));
    let h1 = server.submit(
        LuRequest::new(Matrix::random_spd(48, 72))
            .with_kind(FactorKind::Chol)
            .with_priority(1)
            .with_driver(DriverFamily::Dag),
    );
    let h2 = server.submit(LuRequest::new(Mat::<f32>::random(56, 56, 73)));
    for (i, r) in [h0.wait(), h1.wait()].iter().enumerate() {
        assert!(r.error.is_none() && !r.cancelled, "dag req {i}: {:?}", r.error);
    }
    let r2 = h2.wait();
    assert!(r2.error.is_none() && !r2.cancelled, "{:?}", r2.error);
    server.shutdown();
    let (decisions, mut requests) = capture::stop().expect("capture was armed");
    requests.sort_by_key(|r| r.id);
    let bundle = Bundle {
        cfg: bcfg,
        requests,
        decisions,
    };
    // TaskGrant records exist for the DAG requests and are environmental
    // — a differently-paced replay machine grants in a different global
    // interleaving, so certifying them would refuse valid replays.
    assert!(!DecisionKind::TaskGrant.invariant());
    let grants = |id: u64| {
        bundle
            .decisions
            .iter()
            .filter(|d| d.kind == DecisionKind::TaskGrant && d.req == id)
            .count()
    };
    assert!(grants(0) > 0, "DAG request 0 recorded no task grants");
    assert!(grants(1) > 0, "DAG request 1 recorded no task grants");
    assert_eq!(grants(2), 0, "crew-family request must not record grants");
    // The Submit decision carries each request's family code.
    for (id, expect) in [(0u64, 1u8), (1, 1), (2, 0)] {
        let d = bundle
            .decisions
            .iter()
            .find(|d| d.kind == DecisionKind::Submit && d.req == id)
            .expect("every request records a Submit");
        assert_eq!(((d.b >> 24) & 0xff) as u8, expect, "family code of req {id}");
    }
    // The bundle (now containing tag-9 records) roundtrips bytewise.
    let bytes = bundle::encode(&bundle);
    let back = bundle::decode(&bytes).expect("own encoding must decode");
    assert_eq!(back, bundle);
    // And the replayer routes each request back through its family:
    // certification would fail on the first checkpoint if a DAG capture
    // replayed through the look-ahead driver with different column
    // accounting — and must hold across worker counts.
    for workers in [None, Some(5usize)] {
        let report = run_replay(&bundle, 1, workers).expect("replay must run");
        assert!(
            report.certified_ok(),
            "workers={workers:?}: {}",
            report.divergence.as_ref().map(|d| d.to_string()).unwrap_or_default()
        );
        assert_eq!(report.certified, 3, "workers={workers:?}");
    }
}

/// §18 fast path meets §16 capture: an interleave-on serve run records
/// one environmental `BundleForm` per bundled member, keeps each
/// bundled request's invariant stream to its Submit alone, carries the
/// knob through the bundle header (flags bit 0), and replays certified
/// — including on a different crew size, because a Submit-only
/// invariant stream is independent of how the replay's assembler
/// happens to compose bundles.
#[test]
fn interleaved_capture_replays_and_certifies() {
    let _g = CAP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = ServeConfig {
        interleave: true,
        ..serve_cfg(2)
    };
    let bcfg = BundleCfg::from_serve(&cfg);
    assert!(bcfg.interleave, "from_serve must carry the knob");
    assert!(capture::start(), "no capture may be active here");
    let server = LuServer::new(cfg);
    let sizes = [4usize, 9, 16, 12, 7, 16];
    let mut handles = Vec::new();
    for (i, &n) in sizes.iter().enumerate() {
        handles.push(server.submit(LuRequest::new(Matrix::random(n, n, 700 + i as u64))));
    }
    handles.push(server.submit(LuRequest::new(Mat::<f32>::random(10, 10, 800))));
    for h in handles {
        let r = h.wait();
        assert!(r.error.is_none() && !r.cancelled, "{:?}", r.error);
    }
    server.shutdown();
    let (decisions, mut requests) = capture::stop().expect("capture was armed");
    requests.sort_by_key(|r| r.id);
    let bundle = Bundle {
        cfg: bcfg,
        requests,
        decisions,
    };

    // Every request went through the assembler: one environmental
    // BundleForm each, and an invariant stream of Submit alone (the
    // fast path takes no lease, so no grant/checkpoint/revoke records).
    let forms = bundle
        .decisions
        .iter()
        .filter(|d| d.kind == DecisionKind::BundleForm)
        .count();
    assert_eq!(forms, 7, "one BundleForm per bundled member");
    for r in &bundle.requests {
        let inv: Vec<_> = bundle
            .decisions
            .iter()
            .filter(|d| d.req == r.id && d.kind.invariant())
            .collect();
        assert_eq!(inv.len(), 1, "req {}: invariant stream must be Submit alone", r.id);
        assert_eq!(inv[0].kind, DecisionKind::Submit);
    }

    // The knob rides header flags bit 0 through the wire format, so the
    // replay server rebuilt from the decoded config routes the same way.
    let bytes = bundle::encode(&bundle);
    let back = bundle::decode(&bytes).expect("own encoding must decode");
    assert_eq!(back, bundle);
    assert!(back.cfg.interleave, "flags bit 0 lost in the roundtrip");
    assert!(back.cfg.to_serve().interleave);

    for workers in [None, Some(4usize)] {
        let report = run_replay(&back, 2, workers).expect("replay must run");
        assert!(
            report.certified_ok(),
            "workers={workers:?}: {}",
            report.divergence.as_ref().map(|d| d.to_string()).unwrap_or_default()
        );
        assert_eq!(report.certified, 7, "workers={workers:?}");
    }
}

/// Pre-§18 bundles — and any capture taken with the knob off — replay
/// exactly as before: the header flags byte decodes to `interleave:
/// false`, the rebuilt serve config keeps the fast path off, no
/// BundleForm records appear, and certification is untouched.
#[test]
fn pre_batch_bundles_replay_unchanged() {
    let _g = CAP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let bundle = captured_bundle(2);
    assert!(!bundle.cfg.interleave, "default capture keeps the fast path off");
    assert!(
        !bundle
            .decisions
            .iter()
            .any(|d| d.kind == DecisionKind::BundleForm),
        "no assembler records without the knob"
    );
    let bytes = bundle::encode(&bundle);
    let back = bundle::decode(&bytes).expect("own encoding must decode");
    assert!(!back.cfg.interleave, "flags bit 0 must decode to off");
    assert!(!back.cfg.to_serve().interleave);
    let report = run_replay(&back, 1, None).expect("replay must run");
    assert!(
        report.certified_ok(),
        "{}",
        report.divergence.as_ref().map(|d| d.to_string()).unwrap_or_default()
    );
    assert_eq!(report.certified, 5);
}

/// The chaos build compiles the fault-injection hooks into every
/// checkpoint the capture recorder instruments; disarmed, they must not
/// cost a single decision record or result bit.
#[cfg(feature = "chaos")]
#[test]
fn capture_replay_certifies_with_chaos_hooks_compiled_in() {
    let _g = CAP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    assert!(!malleable_lu::faultplan::fired(), "no fault may be armed");
    let bundle = captured_bundle(3);
    let report = run_replay(&bundle, 2, None).expect("replay must run");
    assert!(
        report.certified_ok(),
        "chaos-instrumented build diverged: {}",
        report.divergence.as_ref().map(|d| d.to_string()).unwrap_or_default()
    );
    assert_eq!(report.certified, 5);
}
