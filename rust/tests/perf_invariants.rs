//! PR 2 acceptance invariants for the perf overhaul (ISSUE 2):
//!
//! 1. steady-state `gemm` inside a blocked LU performs **zero**
//!    packed-buffer heap allocations after warm-up (the crew-owned
//!    packing arena);
//! 2. LU results are **bitwise identical** across SIMD/portable
//!    micro-kernels (skipped gracefully on non-AVX2 hosts) and across
//!    crew sizes with the Loop-3 × Loop-4 chunked macro-kernel.
//!
//! The hybrid-scheduling PR (ISSUE 5) extends invariant 1 to steal-on
//! runs: the tile deques are armed in place and the crew's scheduler is
//! cached across jobs, so stealing adds no steady-state allocations —
//! and the packed-arena lease rules are untouched.

use malleable_lu::blis::micro::{set_kernel, simd_available, Kernel};
use malleable_lu::blis::{BlisParams, StealPolicy};
use malleable_lu::lu::{lu_blocked_rl, lu_lookahead, LaOpts};
use malleable_lu::matrix::{naive, Matrix};
use malleable_lu::pool::{Crew, EntryPolicy, Pool};

#[test]
fn blocked_lu_steady_state_performs_zero_pack_allocations() {
    let params = BlisParams::tiny();
    let mut crew = Crew::new();

    // Warm-up: the first factorization allocates every size class the
    // shape needs (the largest leases happen at the first trailing
    // update, the very first GEMMs of the run are smaller).
    let mut a = Matrix::random(96, 96, 1);
    let _ = lu_blocked_rl(&mut crew, &params, a.view_mut(), 16, 4);
    let warm = crew.arena().stats();
    assert!(warm.allocations > 0, "warm-up must have leased buffers");
    assert!(warm.free_buffers > 0, "all leases must have been returned");

    // Steady state: same shape, fresh data — every one of the hundreds
    // of gemm calls inside must be served from the arena free list.
    let mut b = Matrix::random(96, 96, 2);
    let _ = lu_blocked_rl(&mut crew, &params, b.view_mut(), 16, 4);
    let steady = crew.arena().stats();
    assert!(
        steady.leases > warm.leases + 10,
        "second LU must stream many leases (got {} -> {})",
        warm.leases,
        steady.leases
    );
    assert_eq!(
        warm.allocations, steady.allocations,
        "steady-state LU allocated packed buffers"
    );
    assert_eq!(warm.bytes_allocated, steady.bytes_allocated);
}

#[test]
fn lookahead_lu_reaches_arena_steady_state_across_iterations() {
    // The look-ahead driver spins up fresh PF/RU crews every outer
    // iteration, all sharing one arena (its allocation counters are
    // internal to the driver; the direct zero-allocation assertions live
    // in the blocked test above and in gemm/serve tests). This exercises
    // the shared-arena path under Worker Sharing and checks the result.
    let pool = Pool::new(2);
    let a0 = Matrix::random(96, 96, 3);
    let mut f = a0.clone();
    let opts = LaOpts {
        malleable: true,
        ..Default::default()
    };
    let (ipiv, stats) = lu_lookahead(&pool, &BlisParams::tiny(), &mut f, 16, 4, &opts);
    assert!(stats.iters >= 2, "must run several look-ahead iterations");
    let r = naive::lu_residual(&a0, &f, &ipiv);
    assert!(r < 1e-12, "residual {r}");
}

#[test]
fn steal_on_blocked_lu_keeps_zero_allocation_steady_state() {
    // Same structure as the test above, with the hybrid scheduler on at
    // full static fraction (the deque-heaviest configuration): warm up,
    // then assert the second factorization allocates nothing — neither
    // packed buffers (arena counters) nor per-job schedulers (the crew's
    // sched cache, observable as arena invariance + completion).
    let params = BlisParams::tiny().with_steal(StealPolicy::Fraction(1000));
    let mut crew = Crew::new();

    let mut a = Matrix::random(96, 96, 21);
    let _ = lu_blocked_rl(&mut crew, &params, a.view_mut(), 16, 4);
    let warm = crew.arena().stats();
    assert!(warm.allocations > 0, "warm-up must have leased buffers");
    assert_eq!(
        warm.free_buffers as u64, warm.allocations,
        "all leases must be back on the free list"
    );

    let mut b = Matrix::random(96, 96, 22);
    let _ = lu_blocked_rl(&mut crew, &params, b.view_mut(), 16, 4);
    let steady = crew.arena().stats();
    assert!(steady.leases > warm.leases + 10);
    assert_eq!(
        warm.allocations, steady.allocations,
        "steal-on steady-state LU allocated packed buffers"
    );
    assert_eq!(warm.bytes_allocated, steady.bytes_allocated);
    let s = crew.stats();
    assert!(s.hybrid_tiles > 0, "hybrid scheduler must have been active");
}

fn factor_bits(a0: &Matrix, members: usize) -> (Vec<usize>, Vec<u64>) {
    let mut f = a0.clone();
    let mut crew = Crew::new();
    let shared = crew.shared();
    let hs: Vec<_> = (0..members)
        .map(|_| {
            let s = std::sync::Arc::clone(&shared);
            std::thread::spawn(move || s.member_loop(EntryPolicy::Immediate))
        })
        .collect();
    let ipiv = lu_blocked_rl(&mut crew, &BlisParams::default(), f.view_mut(), 32, 8);
    crew.disband();
    for h in hs {
        h.join().unwrap();
    }
    (ipiv, f.data().iter().map(|x| x.to_bits()).collect())
}

#[test]
fn lu_bitwise_identical_across_crew_sizes_with_loop5_chunking() {
    // Default (large) params on a small matrix force the wide-and-short
    // macro-kernel shapes where Loop-5 subdivision kicks in; the
    // subdivision must not perturb a single bit.
    let a0 = Matrix::random(150, 150, 7);
    let (p0, bits0) = factor_bits(&a0, 0);
    for members in [1usize, 3] {
        let (p, bits) = factor_bits(&a0, members);
        assert_eq!(p0, p, "pivots differ with {members} members");
        assert_eq!(bits0, bits, "bits differ with {members} members");
    }
}

#[test]
fn lu_bitwise_identical_across_simd_and_portable_kernels() {
    if !simd_available() {
        eprintln!("skipping: host has no AVX2+FMA");
        return;
    }
    let a0 = Matrix::random(120, 120, 11);
    let run = |kernel: Kernel| {
        set_kernel(kernel);
        let mut f = a0.clone();
        let mut crew = Crew::new();
        let ipiv = lu_blocked_rl(&mut crew, &BlisParams::default(), f.view_mut(), 24, 8);
        set_kernel(Kernel::Auto);
        (ipiv, f)
    };
    let (p_simd, f_simd) = run(Kernel::Simd);
    let (p_port, f_port) = run(Kernel::Portable);
    assert_eq!(p_simd, p_port, "pivot sequences differ across kernels");
    for (x, y) in f_simd.data().iter().zip(f_port.data()) {
        assert_eq!(x.to_bits(), y.to_bits(), "factor bits differ across kernels");
    }
    // And the factorization is actually right.
    let r = naive::lu_residual(&a0, &f_simd, &p_simd);
    assert!(r < 1e-11, "residual {r}");
}
