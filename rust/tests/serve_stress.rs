//! Malleability-race and serve-layer stress tests: crews that grow *and
//! shrink* mid-kernel must neither lose nor double-execute a chunk, a
//! cancelled request must leave a resumable partial factorization, and a
//! cancelled request's pool must remain fully reusable.

use malleable_lu::blis::{gemm, BlisParams, StealPolicy};
use malleable_lu::lu::{lu_blocked_rl, lu_blocked_rl_ctl, lu_unblocked, BlockedCtl};
use malleable_lu::matrix::{naive, Matrix};
use malleable_lu::pool::{Crew, EntryPolicy};
use malleable_lu::serve::{factorize_batch, LuRequest, LuServer, ServeConfig};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// Determinism invariant under *churn*: members joining and leaving
/// (via revocable leases) mid-GEMM never change the result — bitwise —
/// because chunks are claimed exactly once and leases are only revoked
/// at job boundaries.
#[test]
fn gemm_is_bitwise_stable_under_member_churn() {
    let params = BlisParams::tiny();
    let (m, n, k) = (96, 80, 64);
    let a = Matrix::random(m, k, 1);
    let b = Matrix::random(k, n, 2);

    // Reference: leader alone.
    let mut c_ref = Matrix::random(m, n, 3);
    {
        let mut crew = Crew::new();
        gemm(&mut crew, &params, -1.0, a.view(), b.view(), c_ref.view_mut());
    }

    // Churn: members that repeatedly enlist under a short lease, leave,
    // and re-enlist while the leader runs the same GEMM over and over.
    let mut crew = Crew::new();
    let shared = crew.shared();
    let stop = Arc::new(AtomicBool::new(false));
    let joiners: Vec<_> = (0..3)
        .map(|i| {
            let s = Arc::clone(&shared);
            let st = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut rejoins = 0u64;
                while !st.load(Ordering::Acquire) {
                    let quota = AtomicUsize::new(0);
                    let st2 = Arc::clone(&st);
                    let policy = if i % 2 == 0 {
                        EntryPolicy::Immediate
                    } else {
                        EntryPolicy::JobBoundary
                    };
                    s.member_loop_while(policy, move || {
                        quota.fetch_add(1, Ordering::Relaxed) < 400
                            && !st2.load(Ordering::Acquire)
                    });
                    rejoins += 1;
                }
                rejoins
            })
        })
        .collect();

    for rep in 0..20 {
        let mut c = Matrix::random(m, n, 3);
        gemm(&mut crew, &params, -1.0, a.view(), b.view(), c.view_mut());
        for (x, y) in c.data().iter().zip(c_ref.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "rep {rep}");
        }
    }
    stop.store(true, Ordering::Release);
    crew.disband();
    // (rejoin counts are timing-dependent; correctness above is the
    // invariant under test)
    let total_rejoins: u64 = joiners.into_iter().map(|j| j.join().unwrap()).sum();
    let _ = total_rejoins;
}

/// A request cancelled between panel steps leaves an eagerly-updated
/// trailing block: completing it with the unblocked reference (plus the
/// tail's left swaps) must reproduce the full factorization exactly.
#[test]
fn cancelled_blocked_lu_is_resumable() {
    let n = 64;
    let a0 = Matrix::random(n, n, 9);
    let mut f = a0.clone();
    let cancel = AtomicBool::new(false);
    let steps = AtomicUsize::new(0);
    let checkpoint = |_k: usize| {
        // Cancel after the second committed panel step.
        if steps.fetch_add(1, Ordering::Relaxed) == 1 {
            cancel.store(true, Ordering::Release);
        }
    };
    let mut crew = Crew::new();
    let ctl = BlockedCtl {
        cancel: Some(&cancel),
        tag: None,
        on_checkpoint: Some(&checkpoint),
    };
    let out = lu_blocked_rl_ctl(&mut crew, &BlisParams::tiny(), f.view_mut(), 16, 4, &ctl);
    assert!(out.cancelled);
    assert_eq!(out.cols_done, 32);
    assert_eq!(out.ipiv.len(), 32);

    // Resume: factorize the trailing block, apply its swaps to the
    // committed left columns, and splice the pivots.
    let k = out.cols_done;
    let mut ipiv = out.ipiv.clone();
    let tail = lu_unblocked(f.view_mut().sub(k, k, n - k, n - k));
    for (i, &p) in tail.iter().enumerate() {
        if p != i {
            f.view_mut().swap_rows(k + i, k + p, 0, k);
        }
    }
    ipiv.extend(tail.iter().map(|p| p + k));
    let r = naive::lu_residual(&a0, &f, &ipiv);
    assert!(r < 1e-11, "resumed residual {r}");
    let mut g = a0.clone();
    assert_eq!(ipiv, naive::lu(g.view_mut()), "resumed pivots");
}

/// ET at the request level: cancelling one job must leave the server's
/// pool fully reusable for later work.
#[test]
fn cancelled_request_leaves_server_reusable() {
    let cfg = ServeConfig {
        workers: 2,
        bo: 16,
        bi: 4,
        params: BlisParams::tiny(),
        ..Default::default()
    };
    let server = LuServer::new(cfg);
    let h = server.submit(LuRequest::new(Matrix::random(128, 128, 1)));
    h.cancel();
    let res = h.wait();
    assert!(res.cancelled || res.cols_done == 128);
    assert!(server.registry().is_empty());
    for round in 0..2u64 {
        let a0 = Matrix::random(48, 48, 10 + round);
        let out = server.submit(LuRequest::new(a0.clone())).wait();
        assert!(!out.cancelled);
        let r = naive::lu_residual(&a0, &out.a, &out.ipiv);
        assert!(r < 1e-11, "round {round}: residual {r}");
    }
    server.shutdown();
}

/// Lease revocation *under stealing* (ISSUE 5): members churn through
/// revocable leases while the leader factorizes under the hybrid
/// static/dynamic schedule with a high static fraction — so when a
/// member's lease is revoked its static deque is routinely non-empty.
/// Revocation lands at the next job boundary (a member never abandons a
/// job mid-flight), the remaining participants drain the departed
/// member's tiles by stealing, and the result must stay bitwise equal to
/// the lone-leader run — with **no leaked arena blocks**: after every
/// run, every packed buffer ever allocated is back on the free list, and
/// a steady-state rerun allocates nothing (the `perf_invariants.rs`
/// accounting, reused here under churn).
#[test]
fn lease_revocation_under_stealing_completes_without_leaks() {
    // Fully-static split maximizes the tiles stranded in a revoked
    // member's deque.
    let params = BlisParams::tiny().with_steal(StealPolicy::Fraction(1000));
    let n = 96;
    let a0 = Matrix::random(n, n, 31);

    // Reference bits: leader alone, same steal policy.
    let (ipiv_ref, bits_ref) = {
        let mut f = a0.clone();
        let mut crew = Crew::new();
        let ipiv = lu_blocked_rl(&mut crew, &params, f.view_mut(), 16, 4);
        (ipiv, f.data().iter().map(|x| x.to_bits()).collect::<Vec<_>>())
    };

    let mut crew = Crew::new();
    let shared = crew.shared();
    let stop = Arc::new(AtomicBool::new(false));
    let churners: Vec<_> = (0..3)
        .map(|i| {
            let s = Arc::clone(&shared);
            let st = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !st.load(Ordering::Acquire) {
                    // Short lease: revoked after a few lease polls, i.e.
                    // a few jobs — mid-factorization, deques non-empty.
                    let quota = AtomicUsize::new(0);
                    let st2 = Arc::clone(&st);
                    let policy = if i % 2 == 0 {
                        EntryPolicy::Immediate
                    } else {
                        EntryPolicy::JobBoundary
                    };
                    s.member_loop_while(policy, move || {
                        quota.fetch_add(1, Ordering::Relaxed) < 150
                            && !st2.load(Ordering::Acquire)
                    });
                }
            })
        })
        .collect();

    // Warm-up run under churn, then assert the steady state.
    let mut f1 = a0.clone();
    let p1 = lu_blocked_rl(&mut crew, &params, f1.view_mut(), 16, 4);
    let warm = crew.arena().stats();
    assert!(warm.allocations > 0);
    assert_eq!(
        warm.free_buffers as u64, warm.allocations,
        "arena blocks leaked after churn run (leases not all returned)"
    );

    let mut f2 = a0.clone();
    let p2 = lu_blocked_rl(&mut crew, &params, f2.view_mut(), 16, 4);
    let steady = crew.arena().stats();
    assert_eq!(
        warm.allocations, steady.allocations,
        "steady-state run under churn allocated packed buffers"
    );
    assert_eq!(
        steady.free_buffers as u64, steady.allocations,
        "arena blocks leaked on the steady-state run"
    );

    stop.store(true, Ordering::Release);
    crew.disband();
    for c in churners {
        c.join().unwrap();
    }

    // Residual + bitwise agreement with the lone-leader reference.
    for (ipiv, f) in [(&p1, &f1), (&p2, &f2)] {
        assert_eq!(*ipiv, ipiv_ref);
        let r = naive::lu_residual(&a0, f, ipiv);
        assert!(r < 1e-11, "residual {r}");
        for (x, y) in f.data().iter().zip(&bits_ref) {
            assert_eq!(x.to_bits(), *y, "bits differ from lone-leader run");
        }
    }
}

/// The same revocation-under-stealing scenario at the serve layer: a
/// steal-on batch over a multi-worker server (floaters enlist into and
/// are revoked from in-flight crews as the queue drains) must produce
/// reference results and return every arena block.
#[test]
fn serve_batch_with_stealing_returns_all_arena_blocks() {
    let cfg = ServeConfig {
        workers: 3,
        bo: 16,
        bi: 4,
        params: BlisParams::tiny().with_steal(StealPolicy::Fraction(900)),
        ..Default::default()
    };
    let server = LuServer::new(cfg);
    let sizes = [48usize, 64, 40, 56];
    for round in 0..2 {
        let originals: Vec<Matrix> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| Matrix::random(n, n, 60 + round * 10 + i as u64))
            .collect();
        let reqs: Vec<LuRequest> = originals.iter().map(|a| LuRequest::new(a.clone())).collect();
        let results = server.factorize_batch(reqs);
        for (res, a0) in results.iter().zip(&originals) {
            assert!(!res.cancelled, "req{} cancelled", res.id);
            let r = naive::lu_residual(a0, &res.a, &res.ipiv);
            assert!(r < 1e-11, "req{}: residual {r}", res.id);
            let mut g = a0.clone();
            assert_eq!(res.ipiv, naive::lu(g.view_mut()), "req{} pivots", res.id);
        }
        let stats = server.arena_stats();
        assert_eq!(
            stats.free_buffers as u64, stats.allocations,
            "round {round}: arena blocks leaked under steal-on serving"
        );
    }
    server.shutdown();
}

/// The acceptance-shaped workload: 8 mixed-size problems on a shared
/// pool, every result numerically correct with reference pivots.
#[test]
fn batch_of_eight_mixed_sizes_all_correct() {
    let cfg = ServeConfig {
        workers: 3,
        bo: 16,
        bi: 4,
        params: BlisParams::tiny(),
        ..Default::default()
    };
    let sizes = [32usize, 48, 24, 64, 40, 56, 16, 72];
    let originals: Vec<Matrix> = sizes
        .iter()
        .enumerate()
        .map(|(i, &n)| Matrix::random(n, n, 40 + i as u64))
        .collect();
    let results = factorize_batch(originals.clone(), &cfg);
    assert_eq!(results.len(), sizes.len());
    for (res, a0) in results.iter().zip(&originals) {
        assert!(!res.cancelled, "req{} cancelled", res.id);
        assert_eq!(res.cols_done, a0.rows());
        let r = naive::lu_residual(a0, &res.a, &res.ipiv);
        assert!(r < 1e-11, "req{}: residual {r}", res.id);
        let mut g = a0.clone();
        assert_eq!(res.ipiv, naive::lu(g.view_mut()), "req{} pivots", res.id);
    }
}
