//! Golden-bundle compatibility pin for the `.mrb` replay format
//! (DESIGN.md §16.3).
//!
//! `fixtures/golden_v1.mrb` is a committed v1 bundle whose byte image
//! this suite pins against [`bundle::encode`] — the same discipline the
//! proto pin tests apply to the wire protocol. If either direction of
//! the codec drifts, these tests fail; the fix is never to regenerate
//! the fixture in place but to **bump [`bundle::VERSION`]** and keep
//! [`bundle::decode_v1`] reading the old image. The fixture covers all
//! three payload shapes (f64 factor, f32 factor with per-request block
//! overrides, mixed-precision solve with an rhs), the cancelled/failed
//! flag bits, a client id, and one decision record of every
//! [`DecisionKind`].

use malleable_lu::pool::StealPolicy;
use malleable_lu::replay::{bundle, Bundle, BundleCfg, Decision, DecisionKind, ReqRecord};

const GOLDEN: &[u8] = include_bytes!("fixtures/golden_v1.mrb");

fn f64le(vals: &[f64]) -> Vec<u8> {
    vals.iter().flat_map(|v| v.to_le_bytes()).collect()
}

fn f32le(vals: &[f32]) -> Vec<u8> {
    vals.iter().flat_map(|v| v.to_le_bytes()).collect()
}

/// The in-memory image of the committed fixture. Field-for-field, this
/// is the v1 format contract; the byte pin below keeps it honest.
fn golden_bundle() -> Bundle {
    Bundle {
        cfg: BundleCfg {
            workers: 2,
            bo: 8,
            bi: 4,
            mc: 16,
            kc: 8,
            nc: 12,
            steal: StealPolicy::Auto,
            // The golden fixture predates the interleaved fast path, so
            // its header flags byte is 0 — decoding must read that as
            // "off" (the byte pin below keeps this honest).
            interleave: false,
        },
        requests: vec![
            ReqRecord {
                id: 0,
                kind: bundle::REQ_LU,
                prec: 0,
                priority: 0,
                cancelled: false,
                failed: false,
                m: 3,
                n: 3,
                bo: 0,
                bi: 0,
                deadline_ms: 0,
                client: bundle::NO_CLIENT,
                cols_done: 3,
                digest: 0x0123_4567_89ab_cdef,
                data: f64le(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0]),
                rhs: vec![],
            },
            ReqRecord {
                id: 1,
                kind: bundle::REQ_SOLVE,
                prec: 2,
                priority: 1,
                cancelled: true,
                failed: false,
                m: 2,
                n: 2,
                bo: 0,
                bi: 0,
                deadline_ms: 250,
                client: 7,
                cols_done: 0,
                digest: 0,
                data: f64le(&[4.0, 1.0, 1.0, 3.0]),
                rhs: f64le(&[1.0, 2.0]),
            },
            ReqRecord {
                id: 2,
                kind: bundle::REQ_QR,
                prec: 1,
                priority: 0,
                cancelled: false,
                failed: true,
                m: 4,
                n: 2,
                bo: 8,
                bi: 4,
                deadline_ms: 0,
                client: bundle::NO_CLIENT,
                cols_done: 1,
                digest: 0xfeed_face_00c0_ffee,
                data: f32le(&[0.5, -1.5, 2.25, -3.0, 4.0, 0.125, -0.75, 8.0]),
                rhs: vec![],
            },
        ],
        decisions: vec![
            Decision {
                ordinal: 0,
                kind: DecisionKind::Submit,
                req: 0,
                a: (3 << 32) | 3,
                b: 0,
            },
            Decision {
                ordinal: 1,
                kind: DecisionKind::Admission,
                req: 0,
                a: 7,
                b: (3 << 8) | (3 << 32),
            },
            Decision {
                ordinal: 2,
                kind: DecisionKind::LeaseGrant,
                req: 0,
                a: 0,
                b: 1.5f64.to_bits(),
            },
            Decision {
                ordinal: 3,
                kind: DecisionKind::Checkpoint,
                req: 0,
                a: 1,
                b: 0.75f64.to_bits(),
            },
            Decision {
                ordinal: 4,
                kind: DecisionKind::StealDelta,
                req: 0,
                a: 1,
                b: (2 << 32) | 8,
            },
            Decision {
                ordinal: 5,
                kind: DecisionKind::WsJoin,
                req: 0,
                a: 5,
                b: 0,
            },
            Decision {
                ordinal: 6,
                kind: DecisionKind::EtTrigger,
                req: 1,
                a: 0,
                b: 1,
            },
            Decision {
                ordinal: 7,
                kind: DecisionKind::LeaseRevoke,
                req: 0,
                a: 3,
                b: 0,
            },
        ],
    }
}

#[test]
fn golden_byte_image_is_pinned() {
    let bytes = bundle::encode(&golden_bundle());
    assert_eq!(
        bytes,
        GOLDEN,
        "encoder output drifted from the committed v1 fixture — if the \
         format changed on purpose, bump bundle::VERSION and keep \
         decode_v1 reading this image"
    );
    // The layout constants are part of the same contract.
    let payloads = 72 + (32 + 16) + 32;
    assert_eq!(
        GOLDEN.len(),
        bundle::PREFIX_LEN + 3 * bundle::REQ_FIXED + payloads + 8 * bundle::DEC_LEN
    );
    assert_eq!(&GOLDEN[0..4], &bundle::MAGIC);
    assert_eq!(GOLDEN[4], bundle::VERSION);
}

#[test]
fn golden_roundtrips_through_both_decoders() {
    let want = golden_bundle();
    let via_dispatch = bundle::decode(GOLDEN).expect("golden must decode");
    assert_eq!(via_dispatch, want);
    // decode_v1 is a public, permanent entry point: future versions must
    // keep it able to read this exact image.
    let via_v1 = bundle::decode_v1(GOLDEN).expect("v1 decoder must keep reading v1");
    assert_eq!(via_v1, want);
    assert_eq!(bundle::encode(&via_v1), GOLDEN, "re-encode must be byte-identical");
}

#[test]
fn golden_fields_decode_to_the_documented_semantics() {
    let b = bundle::decode(GOLDEN).expect("golden must decode");
    assert_eq!(b.cfg.steal, StealPolicy::Auto);
    assert!(!b.requests[0].cancelled && !b.requests[0].failed);
    assert!(b.requests[1].cancelled && !b.requests[1].failed);
    assert_eq!(b.requests[1].deadline_ms, 250);
    assert_eq!(b.requests[1].client, 7);
    assert!(!b.requests[2].cancelled && b.requests[2].failed);
    assert_eq!((b.requests[2].bo, b.requests[2].bi), (8, 4));
    assert_eq!(bundle::parse_kind(b.requests[2].kind), Some(malleable_lu::factor::FactorKind::Qr));
    assert_eq!(bundle::parse_kind(b.requests[1].kind), None, "solve is not a factor kind");
    // Every decision kind appears exactly once, in tag order.
    let tags: Vec<u8> = b.decisions.iter().map(|d| d.kind.tag()).collect();
    assert_eq!(tags, vec![1, 2, 3, 4, 5, 6, 7, 8]);
    // The invariant/environmental split the replayer certifies on.
    let inv: Vec<u8> = b
        .decisions
        .iter()
        .filter(|d| d.kind.invariant())
        .map(|d| d.kind.tag())
        .collect();
    assert_eq!(inv, vec![1, 3, 4, 8]);
}

#[test]
fn unknown_version_is_rejected_not_guessed() {
    let mut bumped = GOLDEN.to_vec();
    bumped[4] = 2;
    let e = bundle::decode(&bumped).expect_err("version 2 must be rejected");
    assert!(e.0.contains("version 2"), "{e}");
    // And decode_v1 refuses to be fed the wrong version rather than
    // misparsing it.
    assert!(bundle::decode_v1(&bumped).is_err());
}

#[test]
fn truncated_golden_is_rejected() {
    for cut in [GOLDEN.len() - 1, GOLDEN.len() - bundle::DEC_LEN - 1, 20, 4] {
        assert!(bundle::decode(&GOLDEN[..cut]).is_err(), "cut at {cut} must fail");
    }
}

/// Regenerate the committed fixture from [`golden_bundle`]. Kept
/// `#[ignore]`d: run it (and commit the result) only as part of a
/// deliberate, version-bumped format change —
/// `cargo test --test replay_bundle -- --ignored regenerate`.
#[test]
#[ignore = "writes tests/fixtures/golden_v1.mrb; run only on a deliberate format change"]
fn regenerate_golden_fixture() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/golden_v1.mrb");
    std::fs::write(path, bundle::encode(&golden_bundle())).expect("write fixture");
}
