//! Integration tests over the AOT artifacts: HLO text → PJRT compile →
//! execute, cross-validated against the Rust-native substrate.
//!
//! Skipped (with a message) when `artifacts/` has not been built — run
//! `make artifacts` first.

use malleable_lu::blis::BlisParams;
use malleable_lu::lu;
use malleable_lu::matrix::{naive, Matrix};
use malleable_lu::pool::Crew;
use malleable_lu::runtime::{self, xla_lu, Runtime};

fn open_runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(Runtime::open(dir).expect("artifact store opens"))
}

#[test]
fn gepp_artifact_matches_rust_blis() {
    let Some(rt) = open_runtime() else { return };
    // gepp_128x128x64 exists in the default artifact set (n=192, b=64).
    let (m, n, k) = (128usize, 128usize, 64usize);
    let name = format!("gepp_{m}x{n}x{k}");
    assert!(rt.has(&name), "missing {name}");
    let c0 = Matrix::random(m, n, 1);
    let a = Matrix::random(m, k, 2);
    let b = Matrix::random(k, n, 3);

    let outs = rt
        .run(
            &name,
            &[
                runtime::matrix_to_literal(&c0).unwrap(),
                runtime::matrix_to_literal(&a).unwrap(),
                runtime::matrix_to_literal(&b).unwrap(),
            ],
        )
        .unwrap();
    let c_xla = runtime::literal_to_matrix(&outs[0], m, n).unwrap();

    let mut c_rust = c0.clone();
    let mut crew = Crew::new();
    malleable_lu::blis::gemm(
        &mut crew,
        &BlisParams::default(),
        -1.0,
        a.view(),
        b.view(),
        c_rust.view_mut(),
    );
    let d = c_rust.max_abs_diff(&c_xla);
    assert!(d < 1e-10 * k as f64, "GEPP mismatch: {d}");
}

#[test]
fn panel_artifact_matches_rust_unblocked() {
    let Some(rt) = open_runtime() else { return };
    let (m, b) = (192usize, 64usize);
    let a = Matrix::random(m, b, 7);
    let outs = rt
        .run(
            &format!("panel_{m}x{b}"),
            &[runtime::matrix_to_literal(&a).unwrap()],
        )
        .unwrap();
    let lu_xla = runtime::literal_to_matrix(&outs[0], m, b).unwrap();
    let piv_xla = runtime::literal_to_pivots(&outs[1]).unwrap();

    let mut lu_rust = a.clone();
    let piv_rust = lu::lu_unblocked(lu_rust.view_mut());
    assert_eq!(piv_rust, piv_xla, "pivot sequences differ");
    let d = lu_rust.max_abs_diff(&lu_xla);
    assert!(d < 1e-11, "panel factors differ by {d}");
}

#[test]
fn full_lu_artifact_valid_factorization() {
    let Some(rt) = open_runtime() else { return };
    let n = 192;
    let a = Matrix::random(n, n, 11);
    let (lu_xla, piv) = xla_lu::factorize_full(&rt, &a, 64).unwrap();
    assert_eq!(piv.len(), n);
    let r = naive::lu_residual(&a, &lu_xla, &piv);
    assert!(r < 1e-12, "residual {r}");
    assert!(naive::growth_bounded(&lu_xla));
}

#[test]
fn stepped_lu_xla_matches_full_artifact() {
    let Some(rt) = open_runtime() else { return };
    let n = 192;
    let a = Matrix::random(n, n, 13);
    let (lu_full, piv_full) = xla_lu::factorize_full(&rt, &a, 64).unwrap();
    let (lu_step, piv_step) = xla_lu::factorize_stepped(&rt, &a, 64).unwrap();
    assert_eq!(piv_full, piv_step);
    let d = lu_full.max_abs_diff(&lu_step);
    assert!(d < 1e-11, "stepped vs full differ by {d}");
}

#[test]
fn cross_validation_rust_vs_xla() {
    let Some(rt) = open_runtime() else { return };
    let n = 192;
    let a = Matrix::random(n, n, 17);
    let (diff, pivots_equal) = xla_lu::cross_validate(&rt, &a, 64, 16).unwrap();
    assert!(pivots_equal, "Rust and XLA pivot sequences differ");
    assert!(diff < 1e-10, "factor mismatch {diff}");
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(rt) = open_runtime() else { return };
    let n = 192;
    let a = Matrix::random(n, n, 19);
    assert_eq!(rt.cached(), 0);
    let _ = xla_lu::factorize_full(&rt, &a, 64).unwrap();
    let after_first = rt.cached();
    assert_eq!(after_first, 1);
    let _ = xla_lu::factorize_full(&rt, &a, 64).unwrap();
    assert_eq!(rt.cached(), after_first, "second run must hit the cache");
}

#[test]
fn solve_system_through_xla_factors() {
    let Some(rt) = open_runtime() else { return };
    let n = 192;
    let a = Matrix::random_dd(n, 23);
    let x_true: Vec<f64> = (0..n).map(|i| (i % 17) as f64 - 8.0).collect();
    let mut b = vec![0.0; n];
    for j in 0..n {
        for i in 0..n {
            b[i] += a[(i, j)] * x_true[j];
        }
    }
    let (lu_xla, piv) = xla_lu::factorize_full(&rt, &a, 64).unwrap();
    let x = lu::solve(&lu_xla, &piv, &b);
    for i in 0..n {
        assert!((x[i] - x_true[i]).abs() < 1e-8, "x[{i}] off");
    }
}
