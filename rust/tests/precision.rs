//! Precision-layer integration tests (ISSUE 4 acceptance):
//!
//! - every factorization kind runs through the generic drivers in both
//!   sealed precisions, with residuals bounded by tolerances scaled to
//!   the working type's `EPSILON` (not hard-coded 1e-12s);
//! - `f32` results are crew-size- and kernel-bitwise deterministic,
//!   mirroring the long-standing `f64` guarantees;
//! - the mixed-precision solve does its O(n³) work in `f32` yet lands at
//!   `f64`-level backward error (`‖Ax−b‖/(‖A‖‖x‖+‖b‖) < c·n·ε_f64`);
//! - `f32` and `f64` requests (and mixed solve requests) flow through
//!   one serve queue.

use malleable_lu::blis::micro::{set_kernel, simd_available, Kernel};
use malleable_lu::blis::BlisParams;
use malleable_lu::factor::{factorize_lookahead, FactorKind, LaOpts};
use malleable_lu::lu::lu_blocked_rl;
use malleable_lu::matrix::{naive, Mat, Matrix};
use malleable_lu::pool::{Crew, Pool};
use malleable_lu::scalar::Scalar;
use malleable_lu::serve::{LuRequest, LuServer, ServeConfig, SolveRequest};
use malleable_lu::solve::{lu_solve_mixed, solve_system, SolvePrec};

/// `c·n·ε` residual tolerance for working precision `S`.
fn tol<S: Scalar>(n: usize, c: f64) -> f64 {
    c * (n as f64).max(1.0) * S::EPSILON.to_f64()
}

fn input_for<S: Scalar>(kind: FactorKind, n: usize, seed: u64) -> Mat<S> {
    match kind {
        FactorKind::Chol => Mat::<S>::random_spd(n, seed),
        _ => Mat::<S>::random(n, n, seed),
    }
}

fn residual_of<S: Scalar>(
    kind: FactorKind,
    a0: &Mat<S>,
    f: &Mat<S>,
    ipiv: &[usize],
    tau: &[S],
) -> f64 {
    match kind {
        FactorKind::Lu => naive::lu_residual(a0, f, ipiv),
        FactorKind::Chol => naive::chol_residual(a0, f),
        FactorKind::Qr => naive::qr_residual(a0, f, tau),
    }
}

/// Every kind × both precisions through the generic WS+ET look-ahead
/// driver, with EPSILON-scaled tolerances.
fn lookahead_all_kinds<S: Scalar>() {
    let pool = Pool::new(2);
    let params = BlisParams::tiny();
    let opts = LaOpts {
        malleable: true,
        early_term: true,
        ..Default::default()
    };
    for &kind in FactorKind::all() {
        let n = 56;
        let a0 = input_for::<S>(kind, n, 7);
        let mut f = a0.clone();
        let out = factorize_lookahead(kind, &pool, &params, &mut f, 16, 4, &opts, None);
        assert!(!out.cancelled, "{} {}", kind.name(), S::NAME);
        assert_eq!(out.cols_done, n, "{} {}", kind.name(), S::NAME);
        let r = residual_of(kind, &a0, &f, &out.ipiv, &out.tau);
        let t = tol::<S>(n, 16.0);
        assert!(
            r < t,
            "{} {}: residual {r} above {t}",
            kind.name(),
            S::NAME
        );
    }
}

#[test]
fn lookahead_all_kinds_f64() {
    lookahead_all_kinds::<f64>();
}

#[test]
fn lookahead_all_kinds_f32() {
    lookahead_all_kinds::<f32>();
}

/// The f32 blocked LU is bitwise identical across crew sizes — the §8
/// determinism invariant holds per precision.
#[test]
fn f32_blocked_lu_bitwise_across_crew_sizes() {
    use malleable_lu::pool::EntryPolicy;
    let a0 = Mat::<f32>::random(72, 72, 9);
    let params = BlisParams::tiny();

    let mut f1 = a0.clone();
    let mut crew1 = Crew::new();
    let p1 = lu_blocked_rl(&mut crew1, &params, f1.view_mut(), 16, 4);

    let mut f2 = a0.clone();
    let mut crew2 = Crew::new();
    let shared = crew2.shared();
    let hs: Vec<_> = (0..3)
        .map(|_| {
            let s = std::sync::Arc::clone(&shared);
            std::thread::spawn(move || s.member_loop(EntryPolicy::Immediate))
        })
        .collect();
    let p2 = lu_blocked_rl(&mut crew2, &params, f2.view_mut(), 16, 4);
    crew2.disband();
    for h in hs {
        h.join().unwrap();
    }
    assert_eq!(p1, p2);
    for (x, y) in f1.data().iter().zip(f2.data()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

/// SIMD vs portable kernels give bitwise-identical f32 factorizations
/// (mirrors the f64 guarantee in `perf_invariants.rs`).
#[test]
fn f32_lu_bitwise_across_kernels() {
    if !simd_available() {
        eprintln!("skipping: host has no AVX2+FMA");
        return;
    }
    let a0 = Mat::<f32>::random(64, 64, 11);
    let params = BlisParams::tiny();
    let run = |kernel: Kernel| {
        set_kernel(kernel);
        let mut f = a0.clone();
        let mut crew = Crew::new();
        let piv = lu_blocked_rl(&mut crew, &params, f.view_mut(), 16, 4);
        set_kernel(Kernel::Auto);
        (f, piv)
    };
    let (f_simd, p_simd) = run(Kernel::Simd);
    let (f_port, p_port) = run(Kernel::Portable);
    assert_eq!(p_simd, p_port);
    for (x, y) in f_simd.data().iter().zip(f_port.data()) {
        assert_eq!(x.to_bits(), y.to_bits(), "f32 kernel mismatch");
    }
}

/// The acceptance criterion of ISSUE 4: `lu_solve_mixed` factors in f32
/// yet reaches f64-level backward error.
#[test]
fn mixed_solve_reaches_f64_backward_error() {
    let params = BlisParams::tiny();
    let mut crew = Crew::new();
    for (n, seed) in [(64usize, 3u64), (96, 4)] {
        let a = Matrix::random_dd(n, seed);
        let x_true: Vec<f64> = (0..n).map(|i| ((i % 11) as f64) - 5.0).collect();
        let mut b = vec![0.0; n];
        for j in 0..n {
            for i in 0..n {
                b[i] += a[(i, j)] * x_true[j];
            }
        }
        let out = lu_solve_mixed(&mut crew, &params, &a, &b, 16, 4);
        assert!(out.converged, "n={n}: err {}", out.backward_error);
        assert!(out.refine_iters >= 1, "refinement must run");
        // f64-level: < c·n·ε_f64, far beyond anything f32 can do alone.
        let t = tol::<f64>(n, 16.0);
        assert!(
            out.backward_error < t,
            "n={n}: backward error {} above {t}",
            out.backward_error
        );
        // And far below the f32 floor.
        assert!(out.backward_error < tol::<f32>(n, 1.0) / 100.0);
    }
}

/// Precision ladder: each path meets its own tolerance and mixed ≈ f64.
#[test]
fn solve_precision_ladder() {
    let params = BlisParams::tiny();
    let mut crew = Crew::new();
    let n = 72;
    let a = Matrix::random_dd(n, 21);
    let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).cos()).collect();
    let e32 = solve_system(&mut crew, &params, SolvePrec::F32, &a, &b, 16, 4).backward_error;
    let e64 = solve_system(&mut crew, &params, SolvePrec::F64, &a, &b, 16, 4).backward_error;
    let emx = solve_system(&mut crew, &params, SolvePrec::Mixed, &a, &b, 16, 4).backward_error;
    assert!(e32 < tol::<f32>(n, 16.0), "f32 err {e32}");
    assert!(e64 < tol::<f64>(n, 16.0), "f64 err {e64}");
    assert!(emx < tol::<f64>(n, 16.0), "mixed err {emx}");
    assert!(emx < e32, "mixed must beat pure f32");
}

/// f32, f64, and mixed-solve requests interleave in one server queue.
#[test]
fn serve_queue_is_precision_heterogeneous() {
    let server = LuServer::new(ServeConfig {
        workers: 2,
        bo: 16,
        bi: 4,
        params: BlisParams::tiny(),
        ..Default::default()
    });
    let n = 48;
    let a64 = Matrix::random(n, n, 31);
    let a32 = Mat::<f32>::random(n, n, 32);
    let spd32 = Mat::<f32>::random_spd(n, 33);
    let asys = Matrix::random_dd(n, 34);
    let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();

    let h64 = server.submit(LuRequest::new(a64.clone()));
    let h32 = server.submit(LuRequest::new(a32.clone()));
    let hch = server.submit(LuRequest::new(spd32.clone()).with_kind(FactorKind::Chol));
    let hsv = server.submit_solve(SolveRequest::new(asys.clone(), b.clone()));

    let r64 = h64.wait();
    assert!(!r64.cancelled);
    assert!(naive::lu_residual(&a64, &r64.a, &r64.ipiv) < tol::<f64>(n, 16.0));

    let r32 = h32.wait();
    assert!(!r32.cancelled);
    assert!(naive::lu_residual(&a32, &r32.a, &r32.ipiv) < tol::<f32>(n, 16.0));

    let rch = hch.wait();
    assert!(!rch.cancelled, "f32 cholesky request cancelled");
    assert!(naive::chol_residual(&spd32, &rch.a) < tol::<f32>(n, 16.0));

    let rsv = hsv.wait();
    assert!(!rsv.cancelled && rsv.converged);
    assert!(rsv.backward_error < tol::<f64>(n, 16.0));
    assert_eq!(rsv.prec, SolvePrec::Mixed);

    server.shutdown();
}

/// Cross-precision consistency: the f32 factorization of a well-
/// conditioned matrix agrees with the f64 one to f32 accuracy (same
/// pivots on the same rounded data is NOT guaranteed in general, but the
/// factors of the rounded problem must reconstruct the rounded matrix).
#[test]
fn f32_factors_reconstruct_rounded_problem() {
    let n = 80;
    let a64 = Matrix::random_dd(n, 41);
    let a32: Mat<f32> = a64.convert();
    let params = BlisParams::tiny();
    let mut f = a32.clone();
    let mut crew = Crew::new();
    let ipiv = lu_blocked_rl(&mut crew, &params, f.view_mut(), 16, 4);
    let r = naive::lu_residual(&a32, &f, &ipiv);
    assert!(r < tol::<f32>(n, 16.0), "residual {r}");
    assert!(naive::growth_bounded(&f));
}
