//! §solve — dense linear-system solvers over the precision layer,
//! including the **mixed-precision iteratively-refined solve** the
//! `Scalar` redesign exists to enable (DESIGN.md §12).
//!
//! Three paths, selected by [`SolvePrec`]:
//!
//! - [`SolvePrec::F64`] — factor and solve entirely in `f64` (the
//!   classic path).
//! - [`SolvePrec::F32`] — factor and solve entirely in `f32`; the
//!   answer carries `f32`-level backward error (fast, for tolerant
//!   consumers).
//! - [`SolvePrec::Mixed`] — [`lu_solve_mixed`]: factor once in `f32`
//!   (all O(n³) flops at the doubled SIMD width), then run classical
//!   iterative refinement with the residual computed in `f64`:
//!
//!   ```text
//!   factor P·A32 = L32·U32                 (O(n³), f32)
//!   x ← promote(solve32(b))                (O(n²), f32)
//!   repeat: r ← b − A·x                    (O(n²), f64)
//!           x ← x + promote(solve32(r))    (O(n²), f32)
//!   ```
//!
//!   **Convergence criterion** (the DESIGN.md §12 contract): stop when
//!   the normwise backward error `‖r‖∞ / (‖A‖∞·‖x‖∞ + ‖b‖∞)` drops to
//!   `≤ 2·n·ε_f64`, i.e. the solution is as backward-stable as a full
//!   `f64` factorization; give up (`converged = false`) when the error
//!   stops improving — the matrix is too ill-conditioned for `f32`
//!   factors (κ(A) ≳ 1/ε_f32) — or after [`MAX_REFINE_ITERS`] sweeps.
//!   For matrices `f32` can handle, the error contracts by ~κ(A)·ε_f32
//!   per sweep, so 2–4 iterations reach `f64` accuracy while >99% of
//!   the flops ran at `f32` speed.
//!
//! The factorization stage runs on the malleable blocked driver
//! ([`crate::lu::lu_blocked_rl_ctl`]), so solves inherit crew
//! malleability, arena-leased packing, and — through [`SolveCtl`] —
//! request-level cancellation; the serve layer exposes the whole thing
//! as a queue request kind (`LuServer::submit_solve`).

use crate::blis::{BlisParams, SmallBundle};
use crate::factor::FactorError;
use crate::lu::{lu_blocked_rl_ctl, BlockedCtl};
use crate::matrix::{Mat, Matrix};
use crate::pool::Crew;
use crate::scalar::Scalar;
use std::sync::atomic::{AtomicBool, Ordering};

/// Refinement-sweep cap: far above the 2–4 sweeps a well-conditioned
/// system needs, low enough that a hopeless (κ ≳ 1/ε_f32) system fails
/// fast.
pub const MAX_REFINE_ITERS: usize = 40;

/// Which arithmetic a solve runs in (`mlu solve --prec ...`).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum SolvePrec {
    /// Factor and solve in `f32` (single-precision backward error).
    F32,
    /// Factor and solve in `f64` (the classic path).
    F64,
    /// Factor in `f32`, refine the residual in `f64` to `f64`-level
    /// backward error ([`lu_solve_mixed`]).
    Mixed,
}

impl SolvePrec {
    /// Parse `f32` | `f64` | `mixed`.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "f32" | "single" => SolvePrec::F32,
            "f64" | "double" => SolvePrec::F64,
            "mixed" | "mp" => SolvePrec::Mixed,
            _ => return None,
        })
    }

    /// Canonical lowercase name (trace tags, bench records, CLI).
    pub fn name(&self) -> &'static str {
        match self {
            SolvePrec::F32 => "f32",
            SolvePrec::F64 => "f64",
            SolvePrec::Mixed => "mixed",
        }
    }

    /// The backward-error level this path promises for a well-conditioned
    /// system: `c·n·ε` with `ε` of the *result* precision (`f64` for the
    /// mixed path — that is its whole point).
    pub fn expected_backward_error(&self, n: usize) -> f64 {
        let eps = match self {
            SolvePrec::F32 => f32::EPSILON as f64,
            SolvePrec::F64 | SolvePrec::Mixed => f64::EPSILON,
        };
        16.0 * (n as f64).max(1.0) * eps
    }
}

/// Cooperative control for a cancellable solve (the serve layer's
/// request-level ET, threaded through the factor stage and polled
/// between refinement sweeps).
#[derive(Default)]
pub struct SolveCtl<'a> {
    /// Polled by the factor stage between panel steps and by the refiner
    /// between sweeps.
    pub cancel: Option<&'a AtomicBool>,
    /// Trace label prefix (e.g. `req3:solve:mixed`).
    pub tag: Option<&'a str>,
    /// Called with committed factor columns after every panel step.
    pub on_checkpoint: Option<&'a (dyn Fn(usize) + Sync)>,
}

/// Outcome of a [`solve_system`] / [`lu_solve_mixed`] call.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    /// The solution (always reported in `f64`, whatever the working
    /// precision).
    pub x: Vec<f64>,
    /// Refinement sweeps performed (0 for the pure-precision paths).
    pub refine_iters: usize,
    /// Final normwise backward error `‖b−Ax‖∞ / (‖A‖∞·‖x‖∞ + ‖b‖∞)`,
    /// computed in `f64`.
    pub backward_error: f64,
    /// Whether the path's convergence criterion was met (for `Mixed`:
    /// `f64`-level backward error; for the pure paths: the factor ran to
    /// completion).
    pub converged: bool,
    /// Whether a cancel flag cut the solve short.
    pub cancelled: bool,
    /// Columns of the factorization committed (== n unless cancelled).
    pub cols_done: usize,
    /// Typed numerical failure from the factorization stage, if any
    /// (exactly singular working-precision pivot, non-finite input,
    /// crew fault). Non-fatal errors — e.g. an `f32` pivot that rounds
    /// to zero — coexist with a completed factorization; the refiner
    /// then reports `converged == false` with an infinite backward
    /// error, and this field says *why*.
    pub error: Option<FactorError>,
}

fn inf_norm_vec(v: &[f64]) -> f64 {
    v.iter().fold(0.0f64, |a, &x| a.max(x.abs()))
}

fn inf_norm_mat(a: &Matrix) -> f64 {
    let (m, n) = (a.rows(), a.cols());
    let mut worst = 0.0f64;
    for i in 0..m {
        let mut row = 0.0f64;
        for j in 0..n {
            row += a[(i, j)].abs();
        }
        worst = worst.max(row);
    }
    worst
}

/// `r := b − A·x`, all in `f64`, sequential per element (deterministic).
fn residual_vec(a: &Matrix, x: &[f64], b: &[f64]) -> Vec<f64> {
    let n = a.rows();
    let mut r = b.to_vec();
    for (j, &xj) in x.iter().enumerate() {
        if xj == 0.0 {
            continue;
        }
        for (i, ri) in r.iter_mut().enumerate().take(n) {
            *ri -= a[(i, j)] * xj;
        }
    }
    r
}

/// Error from a precomputed residual. Non-finite entries anywhere in
/// `r` or `x` (an exactly-singular `f32` pivot yields inf/NaN through
/// the substitution sweep) are reported as an **infinite** error — a
/// plain `max` fold would silently drop NaNs and could declare a
/// garbage solution converged.
fn err_norm(r: &[f64], x: &[f64], anorm: f64, bnorm: f64) -> f64 {
    if !r.iter().all(|v| v.is_finite()) || !x.iter().all(|v| v.is_finite()) {
        return f64::INFINITY;
    }
    inf_norm_vec(r) / (anorm * inf_norm_vec(x) + bnorm).max(f64::MIN_POSITIVE)
}

/// Normwise backward error of a candidate solution (in `f64`; infinite
/// when the candidate contains non-finite entries).
pub fn backward_error(a: &Matrix, x: &[f64], b: &[f64]) -> f64 {
    err_norm(
        &residual_vec(a, x, b),
        x,
        inf_norm_mat(a),
        inf_norm_vec(b),
    )
}

/// Solve many same-shape small square systems `A_l · x_l = b_l` through
/// interleaved SIMD bundles (DESIGN.md §18): the matrices are packed
/// problem-major into [`SmallBundle`]s (full-width plus one ragged
/// tail), factored by the register-resident kernel, and
/// back-substituted lane-parallel. `rhs` is overwritten with the
/// solutions, bitwise identical to factoring each system with
/// [`crate::lu::lu_unblocked`] and substituting with
/// [`crate::matrix::naive::lu_solve`] one-at-a-time.
///
/// Returns one entry per problem: `None` for a clean solve, or
/// `Some(ExactlySingular)` naming the first zero pivot column — that
/// problem's `rhs` entry is then non-finite garbage (LAPACK `info`
/// semantics: the factors are fine, the substitution divided by zero).
///
/// Panics if the shapes are mixed, a matrix is not square, or
/// `rhs.len() != mats.len()` — callers group by shape first, as the
/// serve-layer batch assembler does.
pub fn lu_solve_batch<S: Scalar>(
    mats: &[Mat<S>],
    rhs: &mut [Vec<S>],
) -> Vec<Option<FactorError>> {
    assert_eq!(mats.len(), rhs.len(), "lu_solve_batch: one rhs per matrix");
    let w = SmallBundle::<S>::width();
    let mut out = Vec::with_capacity(mats.len());
    let mut base = 0;
    while base < mats.len() {
        let take = w.min(mats.len() - base);
        let refs: Vec<&Mat<S>> = mats[base..base + take].iter().collect();
        let mut bundle = SmallBundle::pack(&refs);
        bundle.factor();
        for slot in 0..take {
            out.push(
                bundle
                    .zero_pivot_col(slot)
                    .map(|col| FactorError::ExactlySingular { col }),
            );
        }
        bundle.solve(&mut rhs[base..base + take]);
        base += take;
    }
    out
}

/// Factor `a` (a copy, in precision `S`) on `crew` and back/forward
/// substitute `b`. Returns `(x, factors, ipiv, cols_done, cancelled,
/// error)` with `x` promoted to `f64` (empty when the factorization did
/// not run to completion — cancelled or stopped by a fatal typed
/// error); the factors and pivots feed the mixed-precision refiner.
#[allow(clippy::type_complexity)]
fn factor_and_solve<S: Scalar>(
    crew: &mut Crew,
    params: &BlisParams,
    a: &Matrix,
    b: &[f64],
    bo: usize,
    bi: usize,
    ctl: &SolveCtl,
) -> (Vec<f64>, Mat<S>, Vec<usize>, usize, bool, Option<FactorError>) {
    let n = a.rows();
    let mut fac: Mat<S> = a.convert();
    let bctl = BlockedCtl {
        cancel: ctl.cancel,
        tag: ctl.tag,
        on_checkpoint: ctl.on_checkpoint,
    };
    let out = lu_blocked_rl_ctl(crew, params, fac.view_mut(), bo, bi, &bctl);
    if out.cancelled || out.cols_done < n {
        return (
            Vec::new(),
            fac,
            out.ipiv,
            out.cols_done,
            out.cancelled,
            out.error,
        );
    }
    let bs: Vec<S> = b.iter().map(|&v| S::from_f64(v)).collect();
    let xs = crate::matrix::naive::lu_solve(&fac, &out.ipiv, &bs);
    let x: Vec<f64> = xs.iter().map(|v| v.to_f64()).collect();
    (x, fac, out.ipiv, out.cols_done, false, out.error)
}

/// Mixed-precision solve: `f32` factorization + `f64` iterative
/// refinement (module docs). `a` must be square and `b.len() == n`.
pub fn lu_solve_mixed(
    crew: &mut Crew,
    params: &BlisParams,
    a: &Matrix,
    b: &[f64],
    bo: usize,
    bi: usize,
) -> SolveOutcome {
    lu_solve_mixed_ctl(crew, params, a, b, bo, bi, &SolveCtl::default())
}

/// [`lu_solve_mixed`] with cooperative cancellation (see [`SolveCtl`]).
#[allow(clippy::too_many_arguments)]
pub fn lu_solve_mixed_ctl(
    crew: &mut Crew,
    params: &BlisParams,
    a: &Matrix,
    b: &[f64],
    bo: usize,
    bi: usize,
    ctl: &SolveCtl,
) -> SolveOutcome {
    let n = a.rows();
    assert_eq!(a.cols(), n, "lu_solve_mixed: square systems only");
    assert_eq!(b.len(), n, "lu_solve_mixed: rhs length");
    let (x0, fac, ipiv, cols_done, cancelled, ferr) =
        factor_and_solve::<f32>(crew, params, a, b, bo, bi, ctl);
    if cancelled || cols_done < n {
        return SolveOutcome {
            x: x0,
            refine_iters: 0,
            backward_error: f64::INFINITY,
            converged: false,
            cancelled,
            cols_done,
            error: ferr,
        };
    }
    let mut x = x0;
    let anorm = inf_norm_mat(a);
    let bnorm = inf_norm_vec(b);
    let tol = 2.0 * (n as f64).max(1.0) * f64::EPSILON;
    let mut iters = 0;
    let mut converged = false;
    let mut was_cancelled = false;
    let mut prev_err = f64::INFINITY;
    let mut err;
    loop {
        // One O(n²) residual pass per sweep: it serves both the
        // convergence test for the current x and — when another sweep
        // runs — the correction right-hand side.
        let r = residual_vec(a, &x, b);
        err = err_norm(&r, &x, anorm, bnorm);
        if err <= tol {
            converged = true;
            break;
        }
        // Stagnation: refinement contracts by ~κ·ε_f32 per sweep; once a
        // sweep stops shrinking the error the matrix is beyond what the
        // f32 factors can correct (this also catches a non-finite err
        // from an exactly-singular f32 pivot immediately).
        if err >= prev_err * 0.9 || iters >= MAX_REFINE_ITERS {
            break;
        }
        if let Some(c) = ctl.cancel {
            if c.load(Ordering::Acquire) {
                was_cancelled = true;
                break;
            }
        }
        // Correction: d solves A32·d = r with the f32 factors.
        let r32: Vec<f32> = r.iter().map(|&v| v as f32).collect();
        let d = crate::matrix::naive::lu_solve(&fac, &ipiv, &r32);
        for (xi, di) in x.iter_mut().zip(&d) {
            *xi += *di as f64;
        }
        iters += 1;
        prev_err = err;
    }
    SolveOutcome {
        x,
        refine_iters: iters,
        backward_error: err,
        converged,
        cancelled: was_cancelled,
        cols_done,
        error: ferr,
    }
}

/// Solve `A·x = b` in the requested precision (the `mlu solve --prec`
/// entry point). See the module docs for the three paths.
#[allow(clippy::too_many_arguments)]
pub fn solve_system_ctl(
    crew: &mut Crew,
    params: &BlisParams,
    prec: SolvePrec,
    a: &Matrix,
    b: &[f64],
    bo: usize,
    bi: usize,
    ctl: &SolveCtl,
) -> SolveOutcome {
    let n = a.rows();
    assert_eq!(a.cols(), n, "solve_system: square systems only");
    assert_eq!(b.len(), n, "solve_system: rhs length");
    match prec {
        SolvePrec::Mixed => lu_solve_mixed_ctl(crew, params, a, b, bo, bi, ctl),
        SolvePrec::F64 => {
            let (x, _fac, _ipiv, cols_done, cancelled, ferr) =
                factor_and_solve::<f64>(crew, params, a, b, bo, bi, ctl);
            let err = if cancelled || cols_done < n {
                f64::INFINITY
            } else {
                backward_error(a, &x, b)
            };
            SolveOutcome {
                x,
                refine_iters: 0,
                backward_error: err,
                converged: !cancelled && cols_done == n && err.is_finite(),
                cancelled,
                cols_done,
                error: ferr,
            }
        }
        SolvePrec::F32 => {
            let (x, _fac, _ipiv, cols_done, cancelled, ferr) =
                factor_and_solve::<f32>(crew, params, a, b, bo, bi, ctl);
            let err = if cancelled || cols_done < n {
                f64::INFINITY
            } else {
                backward_error(a, &x, b)
            };
            SolveOutcome {
                x,
                refine_iters: 0,
                backward_error: err,
                converged: !cancelled && cols_done == n && err.is_finite(),
                cancelled,
                cols_done,
                error: ferr,
            }
        }
    }
}

/// [`solve_system_ctl`] without cancellation plumbing.
pub fn solve_system(
    crew: &mut Crew,
    params: &BlisParams,
    prec: SolvePrec,
    a: &Matrix,
    b: &[f64],
    bo: usize,
    bi: usize,
) -> SolveOutcome {
    solve_system_ctl(crew, params, prec, a, b, bo, bi, &SolveCtl::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rhs_for(a: &Matrix, x_true: &[f64]) -> Vec<f64> {
        let n = a.rows();
        let mut b = vec![0.0; n];
        for (j, &xj) in x_true.iter().enumerate() {
            for (i, bi) in b.iter_mut().enumerate().take(n) {
                *bi += a[(i, j)] * xj;
            }
        }
        b
    }

    #[test]
    fn batched_solve_is_bitwise_one_at_a_time() {
        use crate::blis::micro::KERNEL_TEST_LOCK;
        use crate::blis::{set_kernel, Kernel};
        let _guard = KERNEL_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        for kernel in [Kernel::Portable, Kernel::Auto] {
            set_kernel(kernel);
            // 7 problems of n=10: one full f64 bundle plus a ragged tail.
            let n = 10;
            let mats: Vec<Matrix> = (0..7).map(|i| Matrix::random(n, n, 800 + i)).collect();
            let mut rhs: Vec<Vec<f64>> = (0..7)
                .map(|i| (0..n).map(|j| (i * n + j) as f64 * 0.25 - 3.0).collect())
                .collect();
            let reference: Vec<Vec<f64>> = mats
                .iter()
                .zip(&rhs)
                .map(|(a, b)| {
                    let mut f = a.clone();
                    let ipiv = crate::lu::lu_unblocked(f.view_mut());
                    crate::matrix::naive::lu_solve(&f, &ipiv, b)
                })
                .collect();
            let errs = lu_solve_batch(&mats, &mut rhs);
            assert!(errs.iter().all(Option::is_none));
            for (got, want) in rhs.iter().zip(&reference) {
                let gb: Vec<u64> = got.iter().map(|v| v.to_bits()).collect();
                let wb: Vec<u64> = want.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "kernel {kernel:?}");
            }
            // A singular member is flagged and only that member's
            // solution is garbage.
            let mats = vec![Matrix::zeros(4, 4), Matrix::random_dd(4, 9)];
            let mut rhs = vec![vec![1.0; 4], vec![1.0; 4]];
            let errs = lu_solve_batch(&mats, &mut rhs);
            assert!(matches!(
                errs[0],
                Some(FactorError::ExactlySingular { col: 0 })
            ));
            assert!(errs[1].is_none());
            assert!(rhs[1].iter().all(|v| v.is_finite()));
        }
        set_kernel(Kernel::Auto);
    }

    #[test]
    fn prec_parse_roundtrip() {
        for (s, p) in [
            ("f32", SolvePrec::F32),
            ("F64", SolvePrec::F64),
            ("mixed", SolvePrec::Mixed),
            ("mp", SolvePrec::Mixed),
            ("single", SolvePrec::F32),
        ] {
            assert_eq!(SolvePrec::parse(s), Some(p));
        }
        assert_eq!(SolvePrec::parse("f16"), None);
        assert_eq!(SolvePrec::Mixed.name(), "mixed");
    }

    #[test]
    fn mixed_reaches_f64_backward_error_on_f32_work() {
        // The ISSUE acceptance shape: O(n³) in f32, f64-level answer.
        let params = BlisParams::tiny();
        let mut crew = Crew::new();
        for n in [48usize, 96] {
            let a = Matrix::random_dd(n, 11 + n as u64);
            let x_true: Vec<f64> = (0..n).map(|i| ((i % 9) as f64) - 4.0).collect();
            let b = rhs_for(&a, &x_true);
            let out = lu_solve_mixed(&mut crew, &params, &a, &b, 16, 4);
            assert!(out.converged, "n={n}: not converged (err {})", out.backward_error);
            assert!(!out.cancelled);
            assert_eq!(out.cols_done, n);
            assert!(out.refine_iters >= 1, "refinement must actually run");
            let tol = 2.0 * n as f64 * f64::EPSILON * 16.0;
            assert!(
                out.backward_error < tol,
                "n={n}: backward error {} above f64 level {tol}",
                out.backward_error
            );
        }
    }

    #[test]
    fn mixed_beats_pure_f32_by_orders_of_magnitude() {
        let params = BlisParams::tiny();
        let mut crew = Crew::new();
        let n = 64;
        let a = Matrix::random(n, n, 5);
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin()).collect();
        let b = rhs_for(&a, &x_true);
        let f32_out = solve_system(&mut crew, &params, SolvePrec::F32, &a, &b, 16, 4);
        let mix_out = solve_system(&mut crew, &params, SolvePrec::Mixed, &a, &b, 16, 4);
        assert!(f32_out.converged && mix_out.converged);
        assert!(
            mix_out.backward_error < f32_out.backward_error / 100.0,
            "mixed {} vs f32 {}",
            mix_out.backward_error,
            f32_out.backward_error
        );
    }

    #[test]
    fn all_precisions_meet_their_own_tolerance() {
        let params = BlisParams::tiny();
        let mut crew = Crew::new();
        let n = 56;
        let a = Matrix::random_dd(n, 9);
        let x_true: Vec<f64> = (0..n).map(|i| 1.0 + (i % 5) as f64).collect();
        let b = rhs_for(&a, &x_true);
        for prec in [SolvePrec::F32, SolvePrec::F64, SolvePrec::Mixed] {
            let out = solve_system(&mut crew, &params, prec, &a, &b, 16, 4);
            assert!(out.converged, "{}", prec.name());
            let tol = prec.expected_backward_error(n);
            assert!(
                out.backward_error < tol,
                "{}: err {} tol {tol}",
                prec.name(),
                out.backward_error
            );
            // And the x itself is close for the well-conditioned system.
            for (xi, ti) in out.x.iter().zip(&x_true) {
                let xtol = if prec == SolvePrec::F32 { 1e-3 } else { 1e-8 };
                assert!((xi - ti).abs() < xtol, "{}: |Δx|", prec.name());
            }
        }
    }

    #[test]
    fn cancelled_solve_reports_cancelled() {
        let params = BlisParams::tiny();
        let mut crew = Crew::new();
        let n = 48;
        let a = Matrix::random_dd(n, 3);
        let b = vec![1.0; n];
        let cancel = AtomicBool::new(true);
        let ctl = SolveCtl {
            cancel: Some(&cancel),
            ..Default::default()
        };
        let out = solve_system_ctl(&mut crew, &params, SolvePrec::Mixed, &a, &b, 16, 4, &ctl);
        assert!(out.cancelled);
        assert!(!out.converged);
        assert!(out.cols_done < n);
    }

    #[test]
    fn f32_singular_pivot_fails_cleanly_instead_of_converging_on_nan() {
        // diag(1e-50, 1): nonsingular in f64, but the tiny pivot rounds
        // to 0.0f32 — the f32 substitution sweep produces NaN/inf. The
        // solver must report failure, not fold the NaNs away and claim
        // convergence.
        let params = BlisParams::tiny();
        let mut crew = Crew::new();
        let a = Matrix::from_rows(2, 2, &[1e-50, 0.0, 0.0, 1.0]);
        let b = vec![1e-50, 1.0];
        let out = lu_solve_mixed(&mut crew, &params, &a, &b, 16, 4);
        assert!(!out.converged, "must not converge through NaNs");
        assert!(
            !out.backward_error.is_finite(),
            "backward error {} should be infinite",
            out.backward_error
        );
        // And the *reason* is now typed: the 1e-50 pivot rounds to zero
        // in the f32 working precision.
        assert_eq!(
            out.error,
            Some(FactorError::ExactlySingular { col: 0 }),
            "singular f32 pivot must be reported as a typed error"
        );
        assert!(!out.cancelled, "typed failure is not a cancellation");
    }

    #[test]
    fn backward_error_of_exact_solution_is_zero() {
        let a = Matrix::eye(4);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(backward_error(&a, &b, &b), 0.0);
    }
}
