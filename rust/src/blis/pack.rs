//! Packing routines, generic over the sealed [`Scalar`] layer.
//!
//! GotoBLAS/BLIS copy the current `A` and `B` blocks into contiguous
//! buffers laid out exactly in the order the micro-kernel consumes them
//! (paper §2):
//!
//! - `A_c` (`m_c × k_c`) is stored as a sequence of `MR`-row micro-panels;
//!   within a micro-panel, element `(i, p)` lives at `p·MR + i`.
//! - `B_c` (`k_c × n_c`) is stored as a sequence of `NR`-column
//!   micro-panels; within a micro-panel, element `(p, j)` lives at
//!   `p·NR + j`.
//!
//! Edges are zero-padded to the full `MR`/`NR` so the micro-kernel never
//! branches on the panel interior.
//!
//! Packing is itself parallel (paper §2: "all t threads collaborate to
//! copy and re-organize"): each micro-panel is one crew chunk.
//!
//! The buffers are 64-byte-aligned [`AlignedBuf`]s leased from the
//! crew's packing arena (see [`super::arena`]) rather than fresh `Vec`s,
//! so the steady-state GEMM stream allocates nothing. The arena's lease
//! granule is `f64`; [`PackedA`]/[`PackedB`] view the same buffers as
//! their scalar type (an `f32` packing fits twice the elements per
//! granule), so one arena serves mixed-precision traffic.

use super::arena::{f64_granules, AlignedBuf};
use super::params::{MR, NR};
use crate::matrix::MatRef;
use crate::pool::Crew;
use crate::scalar::Scalar;
use std::marker::PhantomData;

/// Packed buffer for `A_c`: `ceil(m/MR)` micro-panels of `MR × k` each.
/// Backed by a 64-byte-aligned [`AlignedBuf`], usually leased from the
/// crew's [`super::arena::PackArena`] (see [`PackedA::from_buf`]).
pub struct PackedA<S: Scalar = f64> {
    /// Backing storage (`n_panels() * MR * k` elements of `S` used).
    pub buf: AlignedBuf,
    /// Rows packed by the last `pack_a` call.
    pub m: usize,
    /// Depth (columns of `A_c`) packed by the last `pack_a` call.
    pub k: usize,
    _scalar: PhantomData<S>,
}

impl<S: Scalar> PackedA<S> {
    /// Elements (of `S`) needed to pack an `mc × kc` block.
    pub fn required_elems(mc: usize, kc: usize) -> usize {
        mc.div_ceil(MR) * MR * kc
    }

    /// Allocate a private buffer for up to `mc × kc` (benches/tests; the
    /// GEMM hot path leases from the arena instead).
    pub fn with_capacity(mc: usize, kc: usize) -> Self {
        Self::from_buf(AlignedBuf::zeroed(f64_granules::<S>(Self::required_elems(
            mc, kc,
        ))))
    }

    /// Wrap a leased buffer (contents unspecified; `pack_a` overwrites
    /// every element it later reads).
    pub fn from_buf(buf: AlignedBuf) -> Self {
        Self {
            buf,
            m: 0,
            k: 0,
            _scalar: PhantomData,
        }
    }

    /// Release the backing buffer (for [`super::arena::PackArena::give_back`]).
    pub fn into_buf(self) -> AlignedBuf {
        self.buf
    }

    /// Number of `MR`-row micro-panels currently packed.
    pub fn n_panels(&self) -> usize {
        self.m.div_ceil(MR)
    }

    /// The packed elements as a typed slice.
    pub fn as_slice(&self) -> &[S] {
        self.buf.as_slice_of::<S>()
    }

    /// Slice holding micro-panel `i` (rows `i*MR .. i*MR+MR`).
    #[inline]
    pub fn panel(&self, i: usize) -> &[S] {
        let sz = MR * self.k;
        &self.buf.as_slice_of::<S>()[i * sz..(i + 1) * sz]
    }
}

/// Packed buffer for `B_c`: `ceil(n/NR)` micro-panels of `k × NR` each.
/// Backing storage as [`PackedA`].
pub struct PackedB<S: Scalar = f64> {
    /// Backing storage (`n_panels() * NR * k` elements of `S` used).
    pub buf: AlignedBuf,
    /// Depth (rows of `B_c`) packed by the last `pack_b` call.
    pub k: usize,
    /// Columns packed by the last `pack_b` call.
    pub n: usize,
    _scalar: PhantomData<S>,
}

impl<S: Scalar> PackedB<S> {
    /// Elements (of `S`) needed to pack a `kc × nc` block.
    pub fn required_elems(kc: usize, nc: usize) -> usize {
        nc.div_ceil(NR) * NR * kc
    }

    /// Allocate a private buffer for up to `kc × nc` (benches/tests; the
    /// GEMM hot path leases from the arena instead).
    pub fn with_capacity(kc: usize, nc: usize) -> Self {
        Self::from_buf(AlignedBuf::zeroed(f64_granules::<S>(Self::required_elems(
            kc, nc,
        ))))
    }

    /// Wrap a leased buffer (contents unspecified; `pack_b` overwrites
    /// every element it later reads).
    pub fn from_buf(buf: AlignedBuf) -> Self {
        Self {
            buf,
            k: 0,
            n: 0,
            _scalar: PhantomData,
        }
    }

    /// Release the backing buffer (for [`super::arena::PackArena::give_back`]).
    pub fn into_buf(self) -> AlignedBuf {
        self.buf
    }

    /// Number of `NR`-column micro-panels currently packed.
    pub fn n_panels(&self) -> usize {
        self.n.div_ceil(NR)
    }

    /// The packed elements as a typed slice.
    pub fn as_slice(&self) -> &[S] {
        self.buf.as_slice_of::<S>()
    }

    /// Slice holding micro-panel `j` (columns `j*NR .. j*NR+NR`).
    #[inline]
    pub fn panel(&self, j: usize) -> &[S] {
        let sz = NR * self.k;
        &self.buf.as_slice_of::<S>()[j * sz..(j + 1) * sz]
    }
}

/// Pack `a` (`m × k`, `m ≤` capacity) into `pa`, cooperatively on `crew`
/// (one chunk per micro-panel). Published as a single crew job, i.e. one
/// "entry point" (paper Fig. 10: the packing of `A_c` is the first thing
/// a newly merged team collaborates on).
pub fn pack_a<S: Scalar>(crew: &mut Crew, a: MatRef<S>, pa: &mut PackedA<S>) {
    let (m, k) = (a.rows(), a.cols());
    pa.m = m;
    pa.k = k;
    let n_panels = m.div_ceil(MR);
    let panel_sz = MR * k;
    debug_assert!(
        n_panels * panel_sz <= pa.buf.len_as::<S>(),
        "PackedA too small"
    );
    // Hand each chunk a disjoint &mut of the buffer via raw parts: the
    // crew closure must be Fn (shared), so we split the buffer up front.
    let base = pa.buf.as_mut_ptr_of::<S>() as usize;
    let elem = std::mem::size_of::<S>();
    crew.parallel(n_panels, |ip| {
        let dst = unsafe {
            std::slice::from_raw_parts_mut((base + ip * panel_sz * elem) as *mut S, panel_sz)
        };
        let i0 = ip * MR;
        let rows = MR.min(m - i0);
        for p in 0..k {
            let col = a.col_ptr(p);
            for i in 0..rows {
                dst[p * MR + i] = unsafe { *col.add(i0 + i) };
            }
            for i in rows..MR {
                dst[p * MR + i] = S::ZERO; // zero-pad edge
            }
        }
    });
}

/// Pack `b` (`k × n`) into `pb`, cooperatively on `crew` (one chunk per
/// `NR`-column micro-panel).
pub fn pack_b<S: Scalar>(crew: &mut Crew, b: MatRef<S>, pb: &mut PackedB<S>) {
    let (k, n) = (b.rows(), b.cols());
    pb.k = k;
    pb.n = n;
    let n_panels = n.div_ceil(NR);
    let panel_sz = NR * k;
    debug_assert!(
        n_panels * panel_sz <= pb.buf.len_as::<S>(),
        "PackedB too small"
    );
    let base = pb.buf.as_mut_ptr_of::<S>() as usize;
    let elem = std::mem::size_of::<S>();
    crew.parallel(n_panels, |jp| {
        let dst = unsafe {
            std::slice::from_raw_parts_mut((base + jp * panel_sz * elem) as *mut S, panel_sz)
        };
        let j0 = jp * NR;
        let cols = NR.min(n - j0);
        for (jj, src_col) in (0..cols).map(|jj| (jj, j0 + jj)) {
            let col = b.col_ptr(src_col);
            for p in 0..k {
                dst[p * NR + jj] = unsafe { *col.add(p) };
            }
        }
        for jj in cols..NR {
            for p in 0..k {
                dst[p * NR + jj] = S::ZERO;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{Mat, Matrix};

    #[test]
    fn pack_a_layout_exact_multiple() {
        let m = 2 * MR;
        let k = 3;
        let a = Matrix::from_fn(m, k, |i, p| (i * 100 + p) as f64);
        let mut pa = PackedA::with_capacity(m, k);
        let mut crew = Crew::new();
        pack_a(&mut crew, a.view(), &mut pa);
        assert_eq!(pa.n_panels(), 2);
        for ip in 0..2 {
            let panel = pa.panel(ip);
            for p in 0..k {
                for i in 0..MR {
                    assert_eq!(panel[p * MR + i], a[(ip * MR + i, p)]);
                }
            }
        }
    }

    #[test]
    fn pack_a_zero_pads_edge_rows() {
        let m = MR + 3;
        let k = 2;
        let a = Matrix::from_fn(m, k, |i, p| 1.0 + (i + p) as f64);
        let mut pa = PackedA::with_capacity(m, k);
        let mut crew = Crew::new();
        pack_a(&mut crew, a.view(), &mut pa);
        let last = pa.panel(1);
        for p in 0..k {
            for i in 0..3 {
                assert_eq!(last[p * MR + i], a[(MR + i, p)]);
            }
            for i in 3..MR {
                assert_eq!(last[p * MR + i], 0.0);
            }
        }
    }

    #[test]
    fn pack_b_layout_and_padding() {
        let k = 5;
        let n = NR + 1;
        let b = Matrix::from_fn(k, n, |p, j| (p * 10 + j) as f64 + 0.5);
        let mut pb = PackedB::with_capacity(k, crate::util::round_up(n, NR));
        let mut crew = Crew::new();
        pack_b(&mut crew, b.view(), &mut pb);
        assert_eq!(pb.n_panels(), 2);
        let p0 = pb.panel(0);
        for p in 0..k {
            for j in 0..NR {
                assert_eq!(p0[p * NR + j], b[(p, j)]);
            }
        }
        let p1 = pb.panel(1);
        for p in 0..k {
            assert_eq!(p1[p * NR], b[(p, NR)]);
            for j in 1..NR {
                assert_eq!(p1[p * NR + j], 0.0);
            }
        }
    }

    #[test]
    fn pack_f32_layout_and_padding() {
        // The same packing invariants hold in single precision, at two
        // elements per f64 granule.
        let m = MR + 2;
        let k = 4;
        let a = Mat::<f32>::from_fn(m, k, |i, p| (i * 10 + p) as f32 - 1.5);
        let mut pa = PackedA::<f32>::with_capacity(m, k);
        assert!(pa.buf.len_as::<f32>() >= PackedA::<f32>::required_elems(m, k));
        let mut crew = Crew::new();
        pack_a(&mut crew, a.view(), &mut pa);
        assert_eq!(pa.n_panels(), 2);
        for p in 0..k {
            for i in 0..2 {
                assert_eq!(pa.panel(1)[p * MR + i], a[(MR + i, p)]);
            }
            for i in 2..MR {
                assert_eq!(pa.panel(1)[p * MR + i], 0.0f32);
            }
        }
        let b = Mat::<f32>::from_fn(k, NR + 2, |p, j| (p + j) as f32 * 0.25);
        let mut pb = PackedB::<f32>::with_capacity(k, crate::util::round_up(NR + 2, NR));
        pack_b(&mut crew, b.view(), &mut pb);
        for p in 0..k {
            assert_eq!(pb.panel(0)[p * NR], b[(p, 0)]);
            assert_eq!(pb.panel(1)[p * NR + 1], b[(p, NR + 1)]);
            for j in 2..NR {
                assert_eq!(pb.panel(1)[p * NR + j], 0.0f32);
            }
        }
    }

    #[test]
    fn pack_of_subview_respects_stride() {
        let big = Matrix::from_fn(20, 20, |i, j| (i * 20 + j) as f64);
        let v = big.view().sub(3, 4, MR, 6);
        let mut pa = PackedA::with_capacity(MR, 6);
        let mut crew = Crew::new();
        pack_a(&mut crew, v, &mut pa);
        let panel = pa.panel(0);
        for p in 0..6 {
            for i in 0..MR {
                assert_eq!(panel[p * MR + i], big[(3 + i, 4 + p)]);
            }
        }
    }

    #[test]
    fn pack_with_members_matches_solo() {
        use crate::pool::EntryPolicy;
        let m = 7 * MR + 2;
        let k = 33;
        let a = Matrix::random(m, k, 5);

        let mut pa1 = PackedA::with_capacity(crate::util::round_up(m, MR), k);
        let mut crew1 = Crew::new();
        pack_a(&mut crew1, a.view(), &mut pa1);

        let mut pa2 = PackedA::with_capacity(crate::util::round_up(m, MR), k);
        let mut crew2 = Crew::new();
        let shared = crew2.shared();
        let hs: Vec<_> = (0..3)
            .map(|_| {
                let s = std::sync::Arc::clone(&shared);
                std::thread::spawn(move || s.member_loop(EntryPolicy::Immediate))
            })
            .collect();
        pack_a(&mut crew2, a.view(), &mut pa2);
        crew2.disband();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(pa1.as_slice(), pa2.as_slice());
    }

    #[test]
    fn packed_buffers_roundtrip_through_the_arena() {
        use crate::blis::arena::PackArena;
        let arena = PackArena::new();
        let a = Matrix::random(MR + 2, 5, 44);
        let mut crew = Crew::new();

        let mut pa = PackedA::from_buf(arena.lease(f64_granules::<f64>(
            PackedA::<f64>::required_elems(MR + 2, 5),
        )));
        pack_a(&mut crew, a.view(), &mut pa);
        let mut reference = PackedA::with_capacity(MR + 2, 5);
        pack_a(&mut crew, a.view(), &mut reference);
        let used = reference.n_panels() * MR * reference.k;
        assert_eq!(&pa.as_slice()[..used], &reference.as_slice()[..used]);
        arena.give_back(pa.into_buf());
        assert_eq!(arena.stats().free_buffers, 1);
    }
}
