//! Householder reflector helpers — the building blocks of the blocked QR
//! factorization (`geqrf`-style panel + `larfb`-style trailing update),
//! generic over the sealed [`Scalar`] layer.
//!
//! A reflector `H = I − τ·v·vᵀ` (with `v[0] = 1` implicit) annihilates a
//! column below its diagonal. The panel factorization generates and
//! applies reflectors one at a time ([`reflector`], [`apply_reflector`] —
//! level-2, crew-parallel over columns); the trailing update groups a
//! panel's reflectors into the compact WY form `Q = I − V·T·Vᵀ`
//! ([`larft`]) and applies `Qᵀ` to a block of columns with two malleable
//! [`gemm`]s plus one small triangular multiply ([`apply_block_qt`]) —
//! inheriting GEMM's Loop-3 Worker-Sharing entry points for the bulk of
//! the flops.
//!
//! Determinism: every element's reduction (the `vᵀ·c` dot products, the
//! `k` dimension of both GEMMs, the triangular multiply) is sequential,
//! so all of these kernels are bitwise identical for any crew size and
//! any join timing (DESIGN.md §8).

use super::gemm::gemm;
use super::params::BlisParams;
use crate::matrix::{Mat, MatMut, MatRef};
use crate::pool::Crew;
use crate::scalar::Scalar;
use crate::trace::{span, Kind};

/// Generate a Householder reflector from column `j` of `a` (rows `j..m`),
/// LAPACK `larfg` style.
///
/// On return `a[j, j]` holds `beta` (the resulting `R` diagonal entry),
/// `a[j+1.., j]` holds the reflector tail `v[1..]` (with `v[0] = 1`
/// implicit), and the returned `tau` satisfies `H = I − τ·v·vᵀ`. A column
/// that is already zero below the diagonal yields `tau = 0` (`H = I`).
pub fn reflector<S: Scalar>(a: MatMut<S>, j: usize) -> S {
    let m = a.rows();
    let alpha = a.at(j, j);
    let mut xnorm2 = S::ZERO;
    for i in j + 1..m {
        let x = a.at(i, j);
        xnorm2 += x * x;
    }
    if xnorm2 == S::ZERO {
        return S::ZERO;
    }
    let norm = (alpha * alpha + xnorm2).sqrt();
    let beta = if alpha >= S::ZERO { -norm } else { norm };
    let tau = (beta - alpha) / beta;
    let scale = S::ONE / (alpha - beta);
    for i in j + 1..m {
        a.update(i, j, |x| x * scale);
    }
    a.set(j, j, beta);
    tau
}

/// Apply `H = I − τ·v·vᵀ` to columns `jlo..jhi` of `a`, where `v` is the
/// reflector stored in column `v_col` with pivot row `row0` (so `v[0] = 1`
/// at row `row0` and the tail sits in `a[row0+1.., v_col]`). Rows above
/// `row0` are untouched. Crew-parallel over the target columns; each
/// column's `vᵀ·c` reduction is sequential (bitwise crew-independent).
pub fn apply_reflector<S: Scalar>(
    crew: &mut Crew,
    a: MatMut<S>,
    v_col: usize,
    row0: usize,
    tau: S,
    jlo: usize,
    jhi: usize,
) {
    if tau == S::ZERO || jlo >= jhi {
        return;
    }
    let m = a.rows();
    crew.parallel_ranges(jhi - jlo, 4, |cols| {
        for jj in cols {
            let j = jlo + jj;
            let mut w = a.at(row0, j);
            for i in row0 + 1..m {
                w += a.at(i, v_col) * a.at(i, j);
            }
            w *= tau;
            a.update(row0, j, |x| x - w);
            for i in row0 + 1..m {
                let vi = a.at(i, v_col);
                a.update(i, j, |x| x - vi * w);
            }
        }
    });
}

/// Build the upper-triangular block-reflector factor `T` (LAPACK `larft`,
/// forward/columnwise) for the `k = tau.len()` reflectors stored in the
/// columns of `v` (unit lower trapezoidal, diagonal implicit):
/// `H_0·H_1⋯H_{k−1} = I − V·T·Vᵀ`.
pub fn larft<S: Scalar>(v: MatRef<S>, tau: &[S]) -> Mat<S> {
    let k = tau.len();
    let m = v.rows();
    let mut t = Mat::<S>::zeros(k, k);
    let mut w = vec![S::ZERO; k];
    for j in 0..k {
        t[(j, j)] = tau[j];
        if tau[j] == S::ZERO {
            continue;
        }
        // w = V[:, 0..j]ᵀ · v_j (unit diagonal of v_j handled explicitly).
        for (i, wi) in w.iter_mut().enumerate().take(j) {
            let mut s = v.at(j, i);
            for r in j + 1..m {
                s += v.at(r, i) * v.at(r, j);
            }
            *wi = s;
        }
        // T[0..j, j] = −τ_j · T[0..j, 0..j] · w  (T is upper triangular).
        for i in 0..j {
            let mut s = S::ZERO;
            for p in i..j {
                s += t[(i, p)] * w[p];
            }
            t[(i, j)] = -tau[j] * s;
        }
    }
    t
}

/// Apply `Qᵀ = I − V·Tᵀ·Vᵀ` to `c` (LAPACK `larfb`, left side,
/// transpose): `C := C − V·(Tᵀ·(Vᵀ·C))`.
///
/// `v` is the clean `m × k` reflector block (unit diagonal explicit,
/// zeros above), `vt` its `k × m` transpose, `t` the `k × k` factor from
/// [`larft`]. Both rank-`k` products run on the malleable [`gemm`]; the
/// small `Tᵀ·W` multiply is crew-parallel over `W`'s columns with a
/// sequential per-element reduction.
pub fn apply_block_qt<S: Scalar>(
    crew: &mut Crew,
    params: &BlisParams,
    v: MatRef<S>,
    vt: MatRef<S>,
    t: MatRef<S>,
    c: MatMut<S>,
) {
    let k = t.rows();
    let nc = c.cols();
    if k == 0 || nc == 0 {
        return;
    }
    debug_assert_eq!(v.cols(), k);
    debug_assert_eq!(vt.rows(), k);
    debug_assert_eq!(v.rows(), c.rows());
    // W := Vᵀ · C  (k × nc).
    let mut w = Mat::<S>::zeros(k, nc);
    gemm(crew, params, S::ONE, vt, c.as_ref(), w.view_mut());
    // W := Tᵀ · W, in place. Descending row order: row i only reads rows
    // `<= i`, which are still original when `i` is processed last-to-first.
    let wv = w.view_mut();
    span(Kind::Trsm, "larfb_tmul", || {
        crew.parallel_ranges(nc, 8, |cols| {
            for j in cols {
                for i in (0..k).rev() {
                    let mut s = S::ZERO;
                    for p in 0..=i {
                        s += t.at(p, i) * wv.at(p, j);
                    }
                    wv.set(i, j, s);
                }
            }
        });
    });
    // C := C − V · W.
    gemm(crew, params, S::ZERO - S::ONE, v, w.view(), c);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{naive, Matrix};

    /// Apply the stored reflectors one by one (reference path).
    fn apply_seq(a: &Matrix, tau: &[f64], c: &mut Matrix) {
        let m = a.rows();
        for (j, &tj) in tau.iter().enumerate() {
            if tj == 0.0 {
                continue;
            }
            for col in 0..c.cols() {
                let mut w = c[(j, col)];
                for i in j + 1..m {
                    w += a[(i, j)] * c[(i, col)];
                }
                w *= tj;
                c[(j, col)] -= w;
                for i in j + 1..m {
                    c[(i, col)] -= a[(i, j)] * w;
                }
            }
        }
    }

    #[test]
    fn reflector_annihilates_below_diagonal() {
        let mut a = Matrix::random(10, 3, 1);
        let a0 = a.clone();
        let tau = reflector(a.view_mut(), 0);
        assert!(tau > 0.0 && tau < 2.0, "tau={tau}");
        // Applying H to the original column reproduces (beta, 0, ..., 0).
        let mut c = Matrix::from_fn(10, 1, |i, _| a0[(i, 0)]);
        // Column 0 of `a` now stores v; apply H to c.
        apply_seq(&a, &[tau], &mut c);
        assert!((c[(0, 0)] - a[(0, 0)]).abs() < 1e-12);
        for i in 1..10 {
            assert!(c[(i, 0)].abs() < 1e-12, "row {i} not annihilated");
        }
    }

    #[test]
    fn reflector_zero_tail_is_identity() {
        let mut a = Matrix::zeros(5, 1);
        a[(0, 0)] = 3.0;
        let tau = reflector(a.view_mut(), 0);
        assert_eq!(tau, 0.0);
        assert_eq!(a[(0, 0)], 3.0);
    }

    #[test]
    fn reflector_f32_annihilates() {
        use crate::matrix::Mat;
        let mut a = Mat::<f32>::random(12, 1, 2);
        let a0 = a.clone();
        let tau = reflector(a.view_mut(), 0);
        assert!(tau > 0.0 && tau < 2.0, "tau={tau}");
        // ‖H·a0‖ preserves the column norm to f32 accuracy.
        let beta = a[(0, 0)].abs();
        let norm0 = a0.norm_f();
        assert!(
            (beta as f64 - norm0).abs() < 16.0 * f32::EPSILON as f64 * norm0,
            "beta {beta} vs norm {norm0}"
        );
    }

    #[test]
    fn apply_reflector_matches_sequential_reference() {
        let m = 16;
        let mut panel = Matrix::random(m, 1, 2);
        let tau = reflector(panel.view_mut(), 0);
        let c0 = Matrix::random(m, 5, 3);

        let mut c1 = c0.clone();
        apply_seq(&panel, &[tau], &mut c1);

        // Stage panel and c side by side in one matrix so apply_reflector
        // can address both (v_col 0, targets 1..6).
        let mut both = Matrix::zeros(m, 6);
        for i in 0..m {
            both[(i, 0)] = panel[(i, 0)];
            for j in 0..5 {
                both[(i, j + 1)] = c0[(i, j)];
            }
        }
        let mut crew = Crew::new();
        apply_reflector(&mut crew, both.view_mut(), 0, 0, tau, 1, 6);
        for j in 0..5 {
            for i in 0..m {
                assert!(
                    (both[(i, j + 1)] - c1[(i, j)]).abs() < 1e-13,
                    "({i},{j}) differs"
                );
            }
        }
    }

    #[test]
    fn block_apply_matches_one_by_one() {
        // Factorize a small panel with raw reflectors, then check that the
        // compact WY form applies the same transformation as the
        // reflector-by-reflector reference.
        let (m, k, nc) = (20usize, 4usize, 7usize);
        let mut panel = Matrix::random(m, k, 4);
        let mut tau = Vec::new();
        let mut crew = Crew::new();
        for j in 0..k {
            let tj = reflector(panel.view_mut(), j);
            if j + 1 < k {
                apply_reflector(&mut crew, panel.view_mut(), j, j, tj, j + 1, k);
            }
            tau.push(tj);
        }

        let c0 = Matrix::random(m, nc, 5);
        let mut c_ref = c0.clone();
        apply_seq(&panel, &tau, &mut c_ref);

        // Clean V (unit diagonal, zeros above) + transpose + T.
        let mut v = Matrix::zeros(m, k);
        for j in 0..k {
            v[(j, j)] = 1.0;
            for i in j + 1..m {
                v[(i, j)] = panel[(i, j)];
            }
        }
        let vt = v.transposed();
        let t = larft(v.view(), &tau);
        let mut c = c0.clone();
        let params = BlisParams::tiny();
        apply_block_qt(
            &mut crew,
            &params,
            v.view(),
            vt.view(),
            t.view(),
            c.view_mut(),
        );
        let d = c.max_abs_diff(&c_ref);
        assert!(d < 1e-11, "block vs sequential diff {d}");
    }

    #[test]
    fn full_panel_qr_reconstructs() {
        // Reflector-by-reflector QR of a tall panel; Q·R must equal A.
        let (m, n) = (12usize, 5usize);
        let a0 = Matrix::random(m, n, 6);
        let mut f = a0.clone();
        let mut tau = Vec::new();
        let mut crew = Crew::new();
        for j in 0..n {
            let tj = reflector(f.view_mut(), j);
            if j + 1 < n {
                apply_reflector(&mut crew, f.view_mut(), j, j, tj, j + 1, n);
            }
            tau.push(tj);
        }
        let r = naive::qr_residual(&a0, &f, &tau);
        assert!(r < 1e-13, "residual {r}");
        let q = naive::qr_q(&f, &tau);
        let o = naive::orthogonality(&q);
        assert!(o < 1e-13, "orthogonality {o}");
    }
}
