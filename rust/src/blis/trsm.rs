//! Blocked triangular solve with multiple right-hand sides, generic over
//! the sealed [`Scalar`] layer.
//!
//! The LU loop body needs `B := TRILU(A)⁻¹ · B` (left side, lower
//! triangular, unit diagonal — RL2/LL1 in the paper's Fig. 3/6). The
//! blocked algorithm walks diagonal blocks of `A`: a small triangular
//! solve on the current block row of `B` (parallel over columns of `B`),
//! then a malleable [`gemm`] rank-`db` update of the remaining block rows.
//! Casting the bulk of TRSM into GEMM is the standard BLAS-3 construction
//! and inherits GEMM's malleability entry points.

use super::gemm::gemm;
use super::params::BlisParams;
use crate::matrix::{MatMut, MatRef};
use crate::pool::Crew;
use crate::scalar::Scalar;
use crate::trace::{span, Kind};

/// Diagonal block size of the blocked TRSM.
const DB: usize = 32;

/// `B := TRILU(A)⁻¹ · B` — `A` is `m × m` (only its strict lower triangle
/// is read; the diagonal is taken as ones), `B` is `m × n`.
pub fn trsm_llu<S: Scalar>(crew: &mut Crew, params: &BlisParams, a: MatRef<S>, b: MatMut<S>) {
    let m = b.rows();
    assert_eq!(a.rows(), m, "trsm: A rows");
    assert_eq!(a.cols(), m, "trsm: A cols");
    let n = b.cols();
    if m == 0 || n == 0 {
        return;
    }

    let mut k = 0;
    while k < m {
        let db = DB.min(m - k);
        // Small triangular solve on the diagonal block, parallel over the
        // columns of B (each column is independent).
        let akk = a.sub(k, k, db, db);
        let bk = b.sub(k, 0, db, n);
        span(Kind::Trsm, "trsm_diag", || {
            crew.parallel_ranges(n, 8, |cols| {
                for j in cols {
                    for i in 0..db {
                        let mut s = bk.at(i, j);
                        for p in 0..i {
                            s -= akk.at(i, p) * bk.at(p, j);
                        }
                        bk.set(i, j, s);
                    }
                }
            });
        });
        // Update the block rows below: B[k+db.., :] -= A[k+db.., k..k+db] · B[k.., :]
        let rem = m - k - db;
        if rem > 0 {
            gemm(
                crew,
                params,
                S::ZERO - S::ONE,
                a.sub(k + db, k, rem, db),
                bk.as_ref(),
                b.sub(k + db, 0, rem, n),
            );
        }
        k += db;
    }
}

/// `B := B · TRIL(A)⁻ᵀ` — right side, lower triangular, **transposed**,
/// non-unit diagonal. `A` is `n × n` (only its lower triangle, including
/// the diagonal, is read), `B` is `m × n`.
///
/// This is the Cholesky panel step `L21 := A21 · L11⁻ᵀ`. Each row of `B`
/// is an independent forward substitution (the solve couples columns, not
/// rows), so the crew parallelizes over row blocks while every element's
/// reduction stays sequential — the result is bitwise identical for any
/// crew size, matching the determinism invariant of the rest of the
/// substrate (DESIGN.md §8).
pub fn trsm_rltn<S: Scalar>(crew: &mut Crew, a: MatRef<S>, b: MatMut<S>) {
    let n = b.cols();
    assert_eq!(a.rows(), n, "trsm_rltn: A rows");
    assert_eq!(a.cols(), n, "trsm_rltn: A cols");
    let m = b.rows();
    if m == 0 || n == 0 {
        return;
    }
    span(Kind::Trsm, "trsm_rltn", || {
        crew.parallel_ranges(m, 8, |rows| {
            for i in rows {
                for j in 0..n {
                    let mut s = b.at(i, j);
                    for p in 0..j {
                        s -= a.at(j, p) * b.at(i, p);
                    }
                    b.set(i, j, s / a.at(j, j));
                }
            }
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{naive, Mat, Matrix};
    use crate::util::quickcheck_lite::{forall_res, Gen};

    fn unit_lower(n: usize, seed: u64) -> Matrix {
        let r = Matrix::random(n, n, seed);
        Matrix::from_fn(n, n, |i, j| {
            use std::cmp::Ordering::*;
            match i.cmp(&j) {
                Greater => r[(i, j)] - 0.5,
                Equal => 1.0,
                Less => 0.0,
            }
        })
    }

    #[test]
    fn matches_naive_small_and_blocked_sizes() {
        let params = BlisParams::tiny();
        for &(m, n) in &[
            (1usize, 1usize),
            (5, 3),
            (DB, 10),
            (DB + 1, 4),
            (2 * DB + 7, 33),
            (70, 70),
        ] {
            let a = unit_lower(m, (m * 100 + n) as u64);
            let mut b1 = Matrix::random(m, n, 7);
            let mut b2 = b1.clone();
            let mut crew = Crew::new();
            trsm_llu(&mut crew, &params, a.view(), b1.view_mut());
            naive::trsm_llu(a.view(), b2.view_mut());
            let d = b1.max_abs_diff(&b2);
            assert!(d < 1e-11, "m={m} n={n} diff={d}");
        }
    }

    #[test]
    fn f32_matches_naive() {
        let params = BlisParams::tiny();
        let m = DB + 9;
        let n = 11;
        let a: Mat<f32> = unit_lower(m, 77).convert();
        let mut b1 = Mat::<f32>::random(m, n, 7);
        let mut b2 = b1.clone();
        let mut crew = Crew::new();
        trsm_llu(&mut crew, &params, a.view(), b1.view_mut());
        naive::trsm_llu(a.view(), b2.view_mut());
        let d = b1.max_abs_diff(&b2);
        let tol = 32.0 * f32::EPSILON as f64 * m as f64;
        assert!(d < tol, "f32 trsm diff {d} tol {tol}");
    }

    #[test]
    fn solves_the_system() {
        // TRILU(A)·X0 = B  =>  trsm returns X0
        let params = BlisParams::tiny();
        let m = 50;
        let a = unit_lower(m, 3);
        let x0 = Matrix::random(m, 6, 4);
        let mut b = naive::matmul(&a, &x0);
        let mut crew = Crew::new();
        trsm_llu(&mut crew, &params, a.view(), b.view_mut());
        assert!(b.max_abs_diff(&x0) < 1e-10);
    }

    #[test]
    fn reads_only_strict_lower_triangle() {
        let params = BlisParams::tiny();
        let m = DB + 5;
        let mut a = unit_lower(m, 8);
        let b0 = Matrix::random(m, 3, 9);
        let mut b1 = b0.clone();
        let mut crew = Crew::new();
        trsm_llu(&mut crew, &params, a.view(), b1.view_mut());
        // Poison everything on/above the diagonal; result must not change.
        for j in 0..m {
            for i in 0..=j {
                a[(i, j)] = f64::NAN;
            }
        }
        let mut b2 = b0.clone();
        trsm_llu(&mut crew, &params, a.view(), b2.view_mut());
        assert!(b1.max_abs_diff(&b2) == 0.0);
    }

    fn lower_nonunit(n: usize, seed: u64) -> Matrix {
        let r = Matrix::random(n, n, seed);
        Matrix::from_fn(n, n, |i, j| {
            use std::cmp::Ordering::*;
            match i.cmp(&j) {
                Greater => r[(i, j)] - 0.5,
                Equal => 2.0 + r[(i, j)],
                Less => 0.0,
            }
        })
    }

    #[test]
    fn rltn_solves_right_transposed_system() {
        // X0 random; B := X0 · Lᵀ, then trsm_rltn must recover X0.
        for &(m, n) in &[(1usize, 1usize), (7, 4), (40, 13), (65, 32)] {
            let l = lower_nonunit(n, (m * 10 + n) as u64);
            let x0 = Matrix::random(m, n, 5);
            let lt = l.transposed();
            let mut b = naive::matmul(&x0, &lt);
            let mut crew = Crew::new();
            trsm_rltn(&mut crew, l.view(), b.view_mut());
            let d = b.max_abs_diff(&x0);
            assert!(d < 1e-10, "m={m} n={n} diff={d}");
        }
    }

    #[test]
    fn rltn_reads_only_lower_triangle() {
        let n = 9;
        let mut l = lower_nonunit(n, 8);
        let b0 = Matrix::random(6, n, 9);
        let mut b1 = b0.clone();
        let mut crew = Crew::new();
        trsm_rltn(&mut crew, l.view(), b1.view_mut());
        // Poison the strict upper triangle; result must not change.
        for j in 1..n {
            for i in 0..j {
                l[(i, j)] = f64::NAN;
            }
        }
        let mut b2 = b0.clone();
        trsm_rltn(&mut crew, l.view(), b2.view_mut());
        assert!(b1.max_abs_diff(&b2) == 0.0);
    }

    #[test]
    fn empty_is_noop() {
        let params = BlisParams::tiny();
        let mut crew = Crew::new();
        let a = Matrix::zeros(0, 0);
        let mut b = Matrix::zeros(0, 4);
        trsm_llu(&mut crew, &params, a.view(), b.view_mut());
    }

    #[test]
    fn property_matches_naive() {
        forall_res("blocked trsm == naive trsm", 20, |g: &mut Gen| {
            let m = g.usize_in(1, 80);
            let n = g.usize_in(1, 40);
            let seed = g.seed();
            g.label(format!("m={m} n={n}"));
            let a = unit_lower(m, seed);
            let mut b1 = Matrix::random(m, n, seed ^ 3);
            let mut b2 = b1.clone();
            let mut crew = Crew::new();
            trsm_llu(&mut crew, &BlisParams::tiny(), a.view(), b1.view_mut());
            naive::trsm_llu(a.view(), b2.view_mut());
            let d = b1.max_abs_diff(&b2);
            if d > 1e-10 {
                return Err(format!("diff {d}"));
            }
            Ok(())
        });
    }
}
