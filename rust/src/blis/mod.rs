//! A BLIS-style, cache-blocked, **malleable** BLAS substrate.
//!
//! This is the paper's §2 (the GotoBLAS/BLIS five-loop GEMM with packing
//! and a micro-kernel) plus the paper's §4 modification: the thread team
//! executing a kernel is a [`crate::pool::Crew`], and the kernel re-reads
//! the team roster at every Loop-3 (`i_c`) iteration — each packing job
//! and each macro-kernel sweep is published as a fresh crew job, so
//! workers enlisted mid-kernel start contributing at the next `i_c`
//! boundary ("entry points", paper Fig. 10).
//!
//! Layout of the five loops (paper Fig. 1):
//!
//! ```text
//! Loop 1  j_c over n in steps of n_c
//! Loop 2    p_c over k in steps of k_c     -> pack B_c (k_c × n_c)
//! Loop 3      i_c over m in steps of m_c   -> pack A_c (m_c × k_c)   [ENTRY POINT]
//! Loop 4        j_r over n_c in steps of NR     \  macro-kernel,
//! Loop 5          i_r over m_c in steps of MR   /  micro-kernel inside
//! ```
//!
//! Determinism invariant: the `k` dimension is never split across
//! workers (Loop 2 and the micro-kernel's `p` loop are sequential), so
//! results are **bitwise identical** for any crew size and any join
//! timing — malleability cannot perturb numerics (tested). Since PR 2
//! the invariant also spans kernel implementations: the AVX2+FMA and
//! portable micro-kernels share one fused-multiply-add reduction
//! contract ([`micro`]), packed buffers come from a crew-owned arena
//! ([`arena`]) so the steady-state BLAS allocates nothing, the
//! macro-kernel subdivides Loop 5 when Loop 4 is too narrow to feed the
//! team ([`gemm()`]), and the blocking parameters are derived from the
//! host cache topology ([`params`]). The factorization-family refactor
//! added the non-LU kernels: a lower-trapezoid SYRK cast into the packed
//! GEMM ([`syrk`]), a right-side transposed TRSM ([`trsm_rltn`]), and
//! Householder reflector / compact-WY helpers ([`house`]) — all obeying
//! the same determinism invariant.

//!
//! Since the precision-generic redesign (DESIGN.md §12) every kernel in
//! this module is generic over the sealed [`crate::scalar::Scalar`]
//! layer: the same five-loop GEMM, TRSM, LASWP, SYRK, and Householder
//! helpers run in `f32` and `f64`, dispatching per type to an AVX2+FMA
//! micro-kernel (8×6 in both precisions — two `f64x4` vectors or one
//! `f32x8` per column) with a shared portable fallback that is bitwise
//! identical per type. Packed buffers of both precisions lease from one
//! `f64`-granule arena.

pub mod arena;
pub mod gemm;
pub mod house;
pub mod laswp;
pub mod micro;
pub mod pack;
pub mod params;
pub mod small;
pub mod smallbatch;
pub mod syrk;
pub mod trsm;

pub use arena::{AlignedBuf, ArenaStats, PackArena};
pub use gemm::gemm;
pub use laswp::laswp;
pub use micro::{set_kernel, Kernel};
pub use params::{BlisParams, CacheInfo, StealPolicy};
pub use smallbatch::SmallBundle;
pub use syrk::syrk_ln;
pub use trsm::{trsm_llu, trsm_rltn};
