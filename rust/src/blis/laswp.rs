//! LASWP — apply a sequence of row interchanges.
//!
//! The paper notes (§3.1) that LAPACK's legacy LASWP is sequential and
//! visibly expensive in the traces (Fig. 5), but embarrassingly parallel
//! over columns: "its execution time can be expected to decrease linearly
//! with the number of cores". Our implementation splits the column range
//! into crew chunks; each chunk applies the whole pivot sequence to its
//! columns (the swaps are ordered in the row dimension, which is not
//! split, so parallelism over columns is exact).

use crate::matrix::MatMut;
use crate::pool::Crew;
use crate::trace::{span, Kind};

/// Apply pivots `ipiv[k0..k1]` to `a`: for `k` in `k0..k1` (in order),
/// swap rows `k` and `ipiv[k]`. Pivot indices are absolute row indices of
/// `a` (LAPACK convention with zero-based rows). Only columns
/// `jlo..jhi` are touched.
pub fn laswp(
    crew: &mut Crew,
    a: MatMut,
    ipiv: &[usize],
    k0: usize,
    k1: usize,
    jlo: usize,
    jhi: usize,
) {
    debug_assert!(k1 <= ipiv.len());
    debug_assert!(jhi <= a.cols());
    if k0 >= k1 || jlo >= jhi {
        return;
    }
    span(Kind::Swap, "laswp", || {
        crew.parallel_ranges(jhi - jlo, 16, |cols| {
            for k in k0..k1 {
                let p = ipiv[k];
                if p != k {
                    a.swap_rows(k, p, jlo + cols.start, jlo + cols.end);
                }
            }
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{naive, Matrix};
    use crate::pool::EntryPolicy;

    #[test]
    fn matches_sequential_reference() {
        let m = 20;
        let n = 13;
        let a0 = Matrix::random(m, n, 1);
        let ipiv: Vec<usize> = vec![5, 1, 7, 3, 19, 5, 6, 12, 8, 9];

        let mut a1 = a0.clone();
        let mut crew = Crew::new();
        laswp(&mut crew, a1.view_mut(), &ipiv, 0, ipiv.len(), 0, n);

        let mut a2 = a0.clone();
        naive::apply_pivots(a2.view_mut(), &ipiv);
        assert_eq!(a1, a2);
    }

    #[test]
    fn column_range_restriction() {
        let m = 10;
        let n = 8;
        let a0 = Matrix::random(m, n, 2);
        let ipiv = vec![3usize, 4, 2];
        let mut a = a0.clone();
        let mut crew = Crew::new();
        laswp(&mut crew, a.view_mut(), &ipiv, 0, 3, 2, 5);
        // Columns outside [2,5) untouched.
        for j in [0usize, 1, 5, 6, 7] {
            for i in 0..m {
                assert_eq!(a[(i, j)], a0[(i, j)], "col {j}");
            }
        }
        // Columns inside match the reference.
        let mut r = a0.clone();
        naive::apply_pivots(r.view_mut(), &ipiv);
        for j in 2..5 {
            for i in 0..m {
                assert_eq!(a[(i, j)], r[(i, j)]);
            }
        }
    }

    #[test]
    fn pivot_subrange() {
        // Applying ipiv[1..3] only.
        let m = 6;
        let a0 = Matrix::from_fn(m, 2, |i, j| (i * 10 + j) as f64);
        let ipiv = vec![5usize, 3, 4];
        let mut a = a0.clone();
        let mut crew = Crew::new();
        laswp(&mut crew, a.view_mut(), &ipiv, 1, 3, 0, 2);
        let mut r = a0.clone();
        r.view_mut().swap_rows(1, 3, 0, 2);
        r.view_mut().swap_rows(2, 4, 0, 2);
        assert_eq!(a, r);
    }

    #[test]
    fn parallel_matches_solo() {
        let m = 64;
        let n = 100;
        let a0 = Matrix::random(m, n, 5);
        let mut rng = crate::util::Prng::new(77);
        let ipiv: Vec<usize> = (0..m / 2).map(|k| rng.range(k, m - 1)).collect();

        let mut a1 = a0.clone();
        let mut crew1 = Crew::new();
        laswp(&mut crew1, a1.view_mut(), &ipiv, 0, ipiv.len(), 0, n);

        let mut a2 = a0.clone();
        let mut crew2 = Crew::new();
        let shared = crew2.shared();
        let hs: Vec<_> = (0..3)
            .map(|_| {
                let s = std::sync::Arc::clone(&shared);
                std::thread::spawn(move || s.member_loop(EntryPolicy::Immediate))
            })
            .collect();
        laswp(&mut crew2, a2.view_mut(), &ipiv, 0, ipiv.len(), 0, n);
        crew2.disband();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(a1, a2);
    }

    #[test]
    fn empty_ranges_are_noops() {
        let mut a = Matrix::random(4, 4, 9);
        let before = a.clone();
        let mut crew = Crew::new();
        laswp(&mut crew, a.view_mut(), &[1, 2], 1, 1, 0, 4);
        laswp(&mut crew, a.view_mut(), &[1, 2], 0, 2, 3, 3);
        assert_eq!(a, before);
    }
}
