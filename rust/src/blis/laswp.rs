//! LASWP — apply a sequence of row interchanges (any [`Scalar`] type).
//!
//! The paper notes (§3.1) that LAPACK's legacy LASWP is sequential and
//! visibly expensive in the traces (Fig. 5), but embarrassingly parallel
//! over columns: "its execution time can be expected to decrease linearly
//! with the number of cores". Our implementation splits the column range
//! into fixed-width strips of [`COL_STRIP`] columns, one crew chunk per
//! strip; each strip applies the *whole* pivot sequence before the next
//! strip is touched (the swaps are ordered in the row dimension, which is
//! not split, so parallelism over columns is exact).
//!
//! The strip blocking is a cache fix, not just a parallelization choice:
//! applying one swap across the full width of a wide trailing matrix
//! streams `2·n` cache lines per pivot and evicts everything before the
//! next pivot re-walks the same rows. Within a narrow strip, successive
//! pivots hit rows that are column-major-adjacent (the panel's row block),
//! so the strip's working set stays resident across the entire pivot
//! sequence.
//!
//! The strip width itself lives in [`super::params`] — one definition
//! shared with the look-ahead driver's base-relative swap path
//! (`factor::lu::laswp_abs`), re-exported here for compatibility.

use crate::matrix::MatMut;
use crate::pool::Crew;
use crate::scalar::Scalar;
use crate::trace::{span, Kind};

pub use super::params::COL_STRIP;

/// Run `f(lo, hi)` over each [`COL_STRIP`]-column strip of `jlo..jhi`,
/// one crew chunk per strip — the chunking shared by [`laswp`] and the
/// look-ahead driver's base-relative swap variant.
pub fn for_each_col_strip(
    crew: &mut Crew,
    jlo: usize,
    jhi: usize,
    f: impl Fn(usize, usize) + Sync,
) {
    if jlo >= jhi {
        return;
    }
    let n_strips = (jhi - jlo).div_ceil(COL_STRIP);
    crew.parallel(n_strips, |s| {
        let lo = jlo + s * COL_STRIP;
        let hi = (lo + COL_STRIP).min(jhi);
        f(lo, hi);
    });
}

/// Apply pivots `ipiv[k0..k1]` to `a`: for `k` in `k0..k1` (in order),
/// swap rows `k` and `ipiv[k]`. Pivot indices are absolute row indices of
/// `a` (LAPACK convention with zero-based rows). Only columns
/// `jlo..jhi` are touched.
pub fn laswp<S: Scalar>(
    crew: &mut Crew,
    a: MatMut<S>,
    ipiv: &[usize],
    k0: usize,
    k1: usize,
    jlo: usize,
    jhi: usize,
) {
    debug_assert!(k1 <= ipiv.len());
    debug_assert!(jhi <= a.cols());
    if k0 >= k1 || jlo >= jhi {
        return;
    }
    span(Kind::Swap, "laswp", || {
        for_each_col_strip(crew, jlo, jhi, |lo, hi| {
            for k in k0..k1 {
                let p = ipiv[k];
                if p != k {
                    a.swap_rows(k, p, lo, hi);
                }
            }
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{naive, Mat, Matrix};
    use crate::pool::EntryPolicy;

    #[test]
    fn matches_sequential_reference() {
        let m = 20;
        let n = 13;
        let a0 = Matrix::random(m, n, 1);
        let ipiv: Vec<usize> = vec![5, 1, 7, 3, 19, 5, 6, 12, 8, 9];

        let mut a1 = a0.clone();
        let mut crew = Crew::new();
        laswp(&mut crew, a1.view_mut(), &ipiv, 0, ipiv.len(), 0, n);

        let mut a2 = a0.clone();
        naive::apply_pivots(a2.view_mut(), &ipiv);
        assert_eq!(a1, a2);
    }

    #[test]
    fn f32_matches_sequential_reference() {
        let m = 16;
        let n = 9;
        let a0 = Mat::<f32>::random(m, n, 2);
        let ipiv: Vec<usize> = vec![4, 2, 9, 3, 15];
        let mut a1 = a0.clone();
        let mut crew = Crew::new();
        laswp(&mut crew, a1.view_mut(), &ipiv, 0, ipiv.len(), 0, n);
        let mut a2 = a0.clone();
        naive::apply_pivots(a2.view_mut(), &ipiv);
        assert_eq!(a1, a2);
    }

    #[test]
    fn column_range_restriction() {
        let m = 10;
        let n = 8;
        let a0 = Matrix::random(m, n, 2);
        let ipiv = vec![3usize, 4, 2];
        let mut a = a0.clone();
        let mut crew = Crew::new();
        laswp(&mut crew, a.view_mut(), &ipiv, 0, 3, 2, 5);
        // Columns outside [2,5) untouched.
        for j in [0usize, 1, 5, 6, 7] {
            for i in 0..m {
                assert_eq!(a[(i, j)], a0[(i, j)], "col {j}");
            }
        }
        // Columns inside match the reference.
        let mut r = a0.clone();
        naive::apply_pivots(r.view_mut(), &ipiv);
        for j in 2..5 {
            for i in 0..m {
                assert_eq!(a[(i, j)], r[(i, j)]);
            }
        }
    }

    #[test]
    fn pivot_subrange() {
        // Applying ipiv[1..3] only.
        let m = 6;
        let a0 = Matrix::from_fn(m, 2, |i, j| (i * 10 + j) as f64);
        let ipiv = vec![5usize, 3, 4];
        let mut a = a0.clone();
        let mut crew = Crew::new();
        laswp(&mut crew, a.view_mut(), &ipiv, 1, 3, 0, 2);
        let mut r = a0.clone();
        r.view_mut().swap_rows(1, 3, 0, 2);
        r.view_mut().swap_rows(2, 4, 0, 2);
        assert_eq!(a, r);
    }

    #[test]
    fn parallel_matches_solo() {
        let m = 64;
        let n = 100;
        let a0 = Matrix::random(m, n, 5);
        let mut rng = crate::util::Prng::new(77);
        let ipiv: Vec<usize> = (0..m / 2).map(|k| rng.range(k, m - 1)).collect();

        let mut a1 = a0.clone();
        let mut crew1 = Crew::new();
        laswp(&mut crew1, a1.view_mut(), &ipiv, 0, ipiv.len(), 0, n);

        let mut a2 = a0.clone();
        let mut crew2 = Crew::new();
        let shared = crew2.shared();
        let hs: Vec<_> = (0..3)
            .map(|_| {
                let s = std::sync::Arc::clone(&shared);
                std::thread::spawn(move || s.member_loop(EntryPolicy::Immediate))
            })
            .collect();
        laswp(&mut crew2, a2.view_mut(), &ipiv, 0, ipiv.len(), 0, n);
        crew2.disband();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(a1, a2);
    }

    #[test]
    fn strip_boundaries_cover_every_column() {
        // Widths around the strip size, including ragged last strips and
        // a jlo offset that is not strip-aligned.
        let m = 40;
        let mut rng = crate::util::Prng::new(9);
        let ipiv: Vec<usize> = (0..m / 2).map(|k| rng.range(k, m - 1)).collect();
        for w in [COL_STRIP - 1, COL_STRIP, COL_STRIP + 1, 3 * COL_STRIP + 7, 1] {
            let n = w + 5;
            let a0 = Matrix::random(m, n, w as u64);
            let mut a = a0.clone();
            let mut crew = Crew::new();
            laswp(&mut crew, a.view_mut(), &ipiv, 0, ipiv.len(), 3, 3 + w);
            let mut r = a0.clone();
            naive::apply_pivots(r.view_mut(), &ipiv);
            for j in 0..n {
                for i in 0..m {
                    let want = if (3..3 + w).contains(&j) {
                        r[(i, j)]
                    } else {
                        a0[(i, j)]
                    };
                    assert_eq!(a[(i, j)], want, "w={w} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn empty_ranges_are_noops() {
        let mut a = Matrix::random(4, 4, 9);
        let before = a.clone();
        let mut crew = Crew::new();
        laswp(&mut crew, a.view_mut(), &[1, 2], 1, 1, 0, 4);
        laswp(&mut crew, a.view_mut(), &[1, 2], 0, 2, 3, 3);
        assert_eq!(a, before);
    }
}
