//! Interleaved SIMD batching for small LU problems (DESIGN.md §18).
//!
//! Production traffic at "millions of users" scale is dominated by tiny
//! systems (n ≤ 64) where the blocked drivers, the packing arena and the
//! per-request lease machinery are pure overhead. This module factors
//! `SIMD_LANES` *independent* problems at once by laying them out
//! **problem-major**: element `(i, j)` of problem `l` lives at
//! `data[(j*m + i) * W + l]` with `W = S::SIMD_LANES` (4 for `f64`, 8 for
//! `f32`), so one 256-bit vector holds the same matrix entry of `W`
//! different problems and every scalar operation of the unblocked
//! algorithm becomes a single vector operation with **zero shuffles**.
//!
//! Bitwise contract (the same one [`crate::blis::micro`] pins for GEMM):
//! every lane replicates [`crate::blis::small::lu_step_col`] — the shared
//! per-column contract of [`crate::lu::lu_unblocked`] — exactly, so a
//! problem factored through a bundle is **bitwise identical** to the same
//! problem factored one-at-a-time, on every kernel. Two subtleties make
//! the vector kernels non-trivial:
//!
//! * `lu_step_col` *skips* the scale + rank-1 update when the pivot is
//!   exactly zero, and `ger_update` skips columns whose `y_j` is exactly
//!   zero. Computing `v - x·0.0` is **not** a bitwise no-op (`-0.0`
//!   becomes `+0.0`), so the vector kernels blend the update under a
//!   per-lane mask `(akk ≠ 0) ∧ (y_j ≠ 0)` built with unordered
//!   compares (`_CMP_NEQ_UQ`, true for NaN — matching Rust's `!=`).
//! * pivot search and row swaps stay scalar per lane: they are O(m) data
//!   movement and compares with per-lane divergent control flow, and
//!   vectorizing them buys nothing at these sizes.
//!
//! Dead lanes of a *ragged* bundle (`live < W`) are zero-padded at pack
//! time, never read back, and may rot freely — no operation in the kernel
//! mixes values across lanes.
//!
//! The serve layer's batch assembler ([`crate::serve`]) groups same-shape
//! same-precision requests into [`SmallBundle`]s; [`lu_unblocked_batch`]
//! is the standalone convenience that chunks a slice of matrices into
//! full bundles plus one ragged tail.

use crate::matrix::Mat;
use crate::scalar::Scalar;

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Portable interleaved kernel: factor `S::SIMD_LANES` problems laid out
/// problem-major in `data` (see module docs), writing pivot rows to
/// `ipiv[k * W + l]`. Each lane runs the exact
/// [`crate::blis::small::lu_step_col`] scalar chain — pivot search with
/// ties-low, full-width swap, reciprocal-multiply scale, mul-then-sub
/// rank-1 update, zero-pivot skip — so portable and vector kernels are
/// bitwise identical per lane.
pub fn small_lu_portable<S: Scalar>(data: &mut [S], m: usize, n: usize, ipiv: &mut [usize]) {
    let w = S::SIMD_LANES;
    let kmax = m.min(n);
    assert_eq!(data.len(), m * n * w);
    assert_eq!(ipiv.len(), kmax * w);
    let idx = |i: usize, j: usize, l: usize| (j * m + i) * w + l;
    for k in 0..kmax {
        for l in 0..w {
            // Pivot search over column k, rows k..m (ties resolve low).
            let mut piv = k;
            let mut best = data[idx(k, k, l)].abs();
            for i in k + 1..m {
                let v = data[idx(i, k, l)].abs();
                if v > best {
                    best = v;
                    piv = i;
                }
            }
            ipiv[k * w + l] = piv;
            if piv != k {
                for j in 0..n {
                    data.swap(idx(k, j, l), idx(piv, j, l));
                }
            }
            let akk = data[idx(k, k, l)];
            if akk != S::ZERO {
                let r = S::ONE / akk;
                for i in k + 1..m {
                    let e = idx(i, k, l);
                    data[e] = data[e] * r;
                }
                for j in k + 1..n {
                    let yj = data[idx(k, j, l)];
                    if yj == S::ZERO {
                        continue;
                    }
                    for i in k + 1..m {
                        let xi = data[idx(i, k, l)];
                        let e = idx(i, j, l);
                        data[e] = data[e] - xi * yj;
                    }
                }
            }
        }
    }
}

/// Scalar per-lane pivot search + full-width row swap for one column
/// step — shared by both AVX2 kernels (the search has per-lane divergent
/// control flow, so it stays scalar; the arithmetic below it is where
/// the vectors pay off).
#[cfg(target_arch = "x86_64")]
#[inline(always)]
fn pivot_and_swap_lanes<S: Scalar>(
    data: &mut [S],
    m: usize,
    n: usize,
    w: usize,
    k: usize,
    ipiv: &mut [usize],
) {
    let idx = |i: usize, j: usize, l: usize| (j * m + i) * w + l;
    for l in 0..w {
        let mut piv = k;
        let mut best = data[idx(k, k, l)].abs();
        for i in k + 1..m {
            let v = data[idx(i, k, l)].abs();
            if v > best {
                best = v;
                piv = i;
            }
        }
        ipiv[k * w + l] = piv;
        if piv != k {
            for j in 0..n {
                data.swap(idx(k, j, l), idx(piv, j, l));
            }
        }
    }
}

/// AVX2+FMA interleaved kernel for `f64` bundles (4 lanes). Bitwise
/// identical to [`small_lu_portable`] per lane: the scale and rank-1
/// update are blended under per-lane `(akk ≠ 0) ∧ (y_j ≠ 0)` masks
/// (unordered ≠, true for NaN like Rust `!=`), so skipped lanes keep
/// their exact bits (including `-0.0`).
///
/// # Safety
/// Caller must have verified AVX2+FMA support
/// ([`crate::blis::micro::simd_available`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn small_lu_avx2(data: &mut [f64], m: usize, n: usize, ipiv: &mut [usize]) {
    const W: usize = 4;
    let kmax = m.min(n);
    assert_eq!(data.len(), m * n * W);
    assert_eq!(ipiv.len(), kmax * W);
    let p = data.as_mut_ptr();
    for k in 0..kmax {
        pivot_and_swap_lanes(data, m, n, W, k, ipiv);
        let zero = _mm256_setzero_pd();
        let akk = _mm256_loadu_pd(p.add((k * m + k) * W));
        let nz = _mm256_cmp_pd::<_CMP_NEQ_UQ>(akk, zero);
        if _mm256_movemask_pd(nz) == 0 {
            continue; // every lane hit an exactly-zero pivot
        }
        // Reciprocal-multiply scale (lanes with akk == 0 blend back).
        let recip = _mm256_div_pd(_mm256_set1_pd(1.0), akk);
        for i in k + 1..m {
            let q = p.add((k * m + i) * W);
            let x = _mm256_loadu_pd(q);
            let sc = _mm256_mul_pd(x, recip);
            _mm256_storeu_pd(q, _mm256_blendv_pd(x, sc, nz));
        }
        // Rank-1 update: v - x·y, separate mul then sub (ger contract).
        for j in k + 1..n {
            let y = _mm256_loadu_pd(p.add((j * m + k) * W));
            let mask = _mm256_and_pd(nz, _mm256_cmp_pd::<_CMP_NEQ_UQ>(y, zero));
            if _mm256_movemask_pd(mask) == 0 {
                continue;
            }
            for i in k + 1..m {
                let x = _mm256_loadu_pd(p.add((k * m + i) * W));
                let q = p.add((j * m + i) * W);
                let v = _mm256_loadu_pd(q);
                let upd = _mm256_sub_pd(v, _mm256_mul_pd(x, y));
                _mm256_storeu_pd(q, _mm256_blendv_pd(v, upd, mask));
            }
        }
    }
}

/// AVX2+FMA interleaved kernel for `f32` bundles (8 lanes) — same
/// structure and masking discipline as [`small_lu_avx2`].
///
/// # Safety
/// Caller must have verified AVX2+FMA support
/// ([`crate::blis::micro::simd_available`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn small_lu_avx2_f32(data: &mut [f32], m: usize, n: usize, ipiv: &mut [usize]) {
    const W: usize = 8;
    let kmax = m.min(n);
    assert_eq!(data.len(), m * n * W);
    assert_eq!(ipiv.len(), kmax * W);
    let p = data.as_mut_ptr();
    for k in 0..kmax {
        pivot_and_swap_lanes(data, m, n, W, k, ipiv);
        let zero = _mm256_setzero_ps();
        let akk = _mm256_loadu_ps(p.add((k * m + k) * W));
        let nz = _mm256_cmp_ps::<_CMP_NEQ_UQ>(akk, zero);
        if _mm256_movemask_ps(nz) == 0 {
            continue;
        }
        let recip = _mm256_div_ps(_mm256_set1_ps(1.0), akk);
        for i in k + 1..m {
            let q = p.add((k * m + i) * W);
            let x = _mm256_loadu_ps(q);
            let sc = _mm256_mul_ps(x, recip);
            _mm256_storeu_ps(q, _mm256_blendv_ps(x, sc, nz));
        }
        for j in k + 1..n {
            let y = _mm256_loadu_ps(p.add((j * m + k) * W));
            let mask = _mm256_and_ps(nz, _mm256_cmp_ps::<_CMP_NEQ_UQ>(y, zero));
            if _mm256_movemask_ps(mask) == 0 {
                continue;
            }
            for i in k + 1..m {
                let x = _mm256_loadu_ps(p.add((k * m + i) * W));
                let q = p.add((j * m + i) * W);
                let v = _mm256_loadu_ps(q);
                let upd = _mm256_sub_ps(v, _mm256_mul_ps(x, y));
                _mm256_storeu_ps(q, _mm256_blendv_ps(v, upd, mask));
            }
        }
    }
}

/// A SIMD-width bundle of same-shape small problems in problem-major
/// layout, factored together by one pass of the interleaved kernel.
///
/// `live ≤ S::SIMD_LANES` problems occupy the low lanes; dead lanes of a
/// ragged bundle are zero-padded at pack time and never read back.
pub struct SmallBundle<S: Scalar> {
    m: usize,
    n: usize,
    live: usize,
    data: Vec<S>,
    ipiv: Vec<usize>,
    factored: bool,
}

impl<S: Scalar> SmallBundle<S> {
    /// The bundle width for this scalar type (4 for `f64`, 8 for `f32`).
    pub fn width() -> usize {
        S::SIMD_LANES
    }

    /// Pack `1..=width()` same-shape matrices into a fresh bundle
    /// (copies; the sources are untouched). Panics on an empty slice, on
    /// more than `width()` problems, or on mixed shapes — the batch
    /// assembler guarantees all three by construction.
    pub fn pack(mats: &[&Mat<S>]) -> Self {
        let w = Self::width();
        assert!(
            !mats.is_empty() && mats.len() <= w,
            "SmallBundle::pack: {} problems, want 1..={w}",
            mats.len()
        );
        let (m, n) = (mats[0].rows(), mats[0].cols());
        for a in mats {
            assert!(
                a.rows() == m && a.cols() == n,
                "SmallBundle::pack: mixed shapes ({m}x{n} vs {}x{})",
                a.rows(),
                a.cols()
            );
        }
        let mut data = vec![S::ZERO; m * n * w];
        for (l, a) in mats.iter().enumerate() {
            // Mat is column-major, so copy column-by-column with stride w.
            let src = a.data();
            for (e, &v) in src.iter().enumerate() {
                data[e * w + l] = v;
            }
        }
        SmallBundle {
            m,
            n,
            live: mats.len(),
            data,
            ipiv: vec![0; m.min(n) * w],
            factored: false,
        }
    }

    /// Number of live problems in the bundle.
    pub fn live(&self) -> usize {
        self.live
    }

    /// Problem shape `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }

    /// Factor all lanes in place with the interleaved kernel, dispatching
    /// AVX2+FMA vs portable exactly like [`crate::blis::micro`] (the
    /// `MLU_KERNEL` env var and [`crate::blis::set_kernel`] override both
    /// paths at once).
    pub fn factor(&mut self) {
        assert!(!self.factored, "SmallBundle::factor: already factored");
        S::small_lu_kernel(
            crate::blis::micro::use_simd(),
            &mut self.data,
            self.m,
            self.n,
            &mut self.ipiv,
        );
        self.factored = true;
    }

    /// Copy the packed LU factors of lane `slot` back out as a matrix.
    pub fn lane_matrix(&self, slot: usize) -> Mat<S> {
        assert!(slot < self.live, "SmallBundle: slot {slot} >= live {}", self.live);
        let w = Self::width();
        Mat::from_fn(self.m, self.n, |i, j| self.data[(j * self.m + i) * w + slot])
    }

    /// Pivot rows of lane `slot` (LAPACK convention, absolute indices).
    pub fn pivots(&self, slot: usize) -> Vec<usize> {
        assert!(self.factored, "SmallBundle::pivots: not factored");
        assert!(slot < self.live, "SmallBundle: slot {slot} >= live {}", self.live);
        let w = Self::width();
        (0..self.m.min(self.n)).map(|k| self.ipiv[k * w + slot]).collect()
    }

    /// First column of lane `slot` whose diagonal entry is exactly zero
    /// after factorization (LAPACK `info` semantics — the factors are
    /// still valid, only a solve would divide by zero), or `None`.
    pub fn zero_pivot_col(&self, slot: usize) -> Option<usize> {
        assert!(self.factored, "SmallBundle::zero_pivot_col: not factored");
        let w = Self::width();
        (0..self.m.min(self.n)).find(|&k| self.data[(k * self.m + k) * w + slot] == S::ZERO)
    }

    /// Batched back-substitution: solve `A_l · x_l = rhs_l` for every
    /// live lane against the factored bundle (square problems only).
    /// Each lane replicates [`crate::matrix::naive::lu_solve`]'s exact
    /// arithmetic — pivot swaps, forward substitution with unit `L`
    /// (`s -= l·x`, separate mul then sub), back substitution dividing by
    /// `U(i,i)` — so the answers are bitwise identical to solving each
    /// problem one-at-a-time. The lane loop is innermost over a
    /// problem-major buffer, so the compiler vectorizes the substitution
    /// across problems.
    pub fn solve(&self, rhs: &mut [Vec<S>]) {
        assert!(self.factored, "SmallBundle::solve: not factored");
        assert_eq!(self.m, self.n, "SmallBundle::solve: square only");
        assert_eq!(rhs.len(), self.live, "SmallBundle::solve: one rhs per live lane");
        let (n, w) = (self.n, Self::width());
        let mut x = vec![S::ZERO; n * w];
        for (l, b) in rhs.iter().enumerate() {
            assert_eq!(b.len(), n, "SmallBundle::solve: rhs length");
            for (i, &v) in b.iter().enumerate() {
                x[i * w + l] = v;
            }
        }
        // P·b — swaps are per-lane (pivots differ across problems).
        for k in 0..n {
            for l in 0..self.live {
                let p = self.ipiv[k * w + l];
                x.swap(k * w + l, p * w + l);
            }
        }
        // Forward substitution with unit L, lanes innermost.
        for i in 0..n {
            for p in 0..i {
                for l in 0..w {
                    let lu = self.data[(p * n + i) * w + l];
                    let xp = x[p * w + l];
                    let e = i * w + l;
                    x[e] = x[e] - lu * xp;
                }
            }
        }
        // Back substitution with U.
        for i in (0..n).rev() {
            for p in i + 1..n {
                for l in 0..w {
                    let lu = self.data[(p * n + i) * w + l];
                    let xp = x[p * w + l];
                    let e = i * w + l;
                    x[e] = x[e] - lu * xp;
                }
            }
            for l in 0..w {
                let e = i * w + l;
                x[e] = x[e] / self.data[(i * n + i) * w + l];
            }
        }
        for (l, b) in rhs.iter_mut().enumerate() {
            for (i, v) in b.iter_mut().enumerate() {
                *v = x[i * w + l];
            }
        }
    }
}

/// Factor a slice of same-shape small matrices in place through
/// interleaved bundles: full `width()`-wide bundles plus one ragged tail.
/// Returns per-problem pivot vectors in input order. Bitwise identical
/// to calling [`crate::lu::lu_unblocked`] on each matrix.
pub fn lu_unblocked_batch<S: Scalar>(mats: &mut [Mat<S>]) -> Vec<Vec<usize>> {
    let w = SmallBundle::<S>::width();
    let mut out = Vec::with_capacity(mats.len());
    let mut base = 0;
    while base < mats.len() {
        let take = w.min(mats.len() - base);
        let chunk = &mut mats[base..base + take];
        let refs: Vec<&Mat<S>> = chunk.iter().collect();
        let mut bundle = SmallBundle::pack(&refs);
        bundle.factor();
        for (slot, a) in chunk.iter_mut().enumerate() {
            *a = bundle.lane_matrix(slot);
            out.push(bundle.pivots(slot));
        }
        base += take;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::micro::KERNEL_TEST_LOCK;
    use crate::blis::{set_kernel, Kernel};
    use crate::lu::lu_unblocked;
    use crate::matrix::naive;

    fn ref_factor<S: Scalar>(a: &Mat<S>) -> (Mat<S>, Vec<usize>) {
        let mut f = a.clone();
        let ipiv = lu_unblocked(f.view_mut());
        (f, ipiv)
    }

    fn assert_bitwise_eq<S: Scalar>(a: &Mat<S>, b: &Mat<S>, what: &str) {
        assert_eq!(a.rows(), b.rows());
        assert_eq!(a.cols(), b.cols());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits_u64(), y.to_bits_u64(), "{what}: bit mismatch");
        }
    }

    fn agree_case<S: Scalar>(m: usize, n: usize, live: usize, seed: u64) {
        let mats: Vec<Mat<S>> =
            (0..live).map(|l| Mat::random(m, n, seed + l as u64)).collect();
        let refs: Vec<&Mat<S>> = mats.iter().collect();
        let mut bundle = SmallBundle::pack(&refs);
        bundle.factor();
        for (slot, a) in mats.iter().enumerate() {
            let (f, ipiv) = ref_factor(a);
            assert_eq!(bundle.pivots(slot), ipiv, "pivots {m}x{n} slot {slot}");
            assert_bitwise_eq(&bundle.lane_matrix(slot), &f, "factors");
        }
    }

    #[test]
    fn bundle_agrees_bitwise_with_unblocked_f64() {
        let _g = KERNEL_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        for kern in [Kernel::Portable, Kernel::Auto] {
            set_kernel(Some(kern));
            for &n in &[1usize, 2, 3, 5, 8, 16, 24] {
                for live in 1..=SmallBundle::<f64>::width() {
                    agree_case::<f64>(n, n, live, 7 * n as u64 + live as u64);
                }
            }
            agree_case::<f64>(12, 5, 3, 99); // tall
            agree_case::<f64>(5, 12, 2, 98); // wide
        }
        set_kernel(None);
    }

    #[test]
    fn bundle_agrees_bitwise_with_unblocked_f32() {
        let _g = KERNEL_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        for kern in [Kernel::Portable, Kernel::Auto] {
            set_kernel(Some(kern));
            for &n in &[1usize, 2, 7, 16, 31] {
                for live in [1, 3, SmallBundle::<f32>::width()] {
                    agree_case::<f32>(n, n, live, 13 * n as u64 + live as u64);
                }
            }
        }
        set_kernel(None);
    }

    #[test]
    fn zero_pivot_lane_is_skipped_and_flagged() {
        let _g = KERNEL_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        for kern in [Kernel::Portable, Kernel::Auto] {
            set_kernel(Some(kern));
            // Lane 0: a singular matrix (zero column); lane 1: well-conditioned.
            let mut s = Mat::<f64>::zeros(4, 4);
            s[(0, 1)] = 1.0;
            s[(1, 2)] = 2.0;
            s[(2, 3)] = 3.0;
            let good = Mat::<f64>::random_dd(4, 5);
            let mut bundle = SmallBundle::pack(&[&s, &good]);
            bundle.factor();
            let (fs, ps) = ref_factor(&s);
            assert_eq!(bundle.pivots(0), ps);
            assert_bitwise_eq(&bundle.lane_matrix(0), &fs, "singular lane");
            assert_eq!(bundle.zero_pivot_col(0), Some(0));
            assert_eq!(bundle.zero_pivot_col(1), None);
            let (fg, pg) = ref_factor(&good);
            assert_eq!(bundle.pivots(1), pg);
            assert_bitwise_eq(&bundle.lane_matrix(1), &fg, "good lane");
        }
        set_kernel(None);
    }

    #[test]
    fn batch_chunks_full_and_ragged() {
        let _g = KERNEL_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_kernel(None);
        // 11 problems of n=9 → two full f64 bundles + ragged 3.
        let mut mats: Vec<Mat<f64>> = (0..11).map(|i| Mat::random(9, 9, 400 + i)).collect();
        let originals = mats.clone();
        let pivots = lu_unblocked_batch(&mut mats);
        for (i, a0) in originals.iter().enumerate() {
            let (f, ipiv) = ref_factor(a0);
            assert_eq!(pivots[i], ipiv, "problem {i}");
            assert_bitwise_eq(&mats[i], &f, "problem factors");
        }
    }

    #[test]
    fn solve_matches_naive_bitwise() {
        let _g = KERNEL_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_kernel(None);
        let n = 12;
        let mats: Vec<Mat<f64>> = (0..3).map(|i| Mat::random_dd(n, 800 + i)).collect();
        let refs: Vec<&Mat<f64>> = mats.iter().collect();
        let mut bundle = SmallBundle::pack(&refs);
        bundle.factor();
        let mut rhs: Vec<Vec<f64>> = (0..3)
            .map(|l| (0..n).map(|i| (i as f64 + 1.0) * (l as f64 + 0.5)).collect())
            .collect();
        let expect: Vec<Vec<f64>> = mats
            .iter()
            .zip(&rhs)
            .map(|(a, b)| {
                let (f, ipiv) = ref_factor(a);
                naive::lu_solve(&f, &ipiv, b)
            })
            .collect();
        bundle.solve(&mut rhs);
        for (l, (got, want)) in rhs.iter().zip(&expect).enumerate() {
            for (g, w) in got.iter().zip(want) {
                assert_eq!(g.to_bits(), w.to_bits(), "solve lane {l}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "mixed shapes")]
    fn pack_rejects_mixed_shapes() {
        let a = Mat::<f64>::zeros(4, 4);
        let b = Mat::<f64>::zeros(5, 5);
        let _ = SmallBundle::pack(&[&a, &b]);
    }
}
