//! BLIS cache-blocking configuration.
//!
//! `m_c, k_c, n_c` are the cache-blocking parameters of the three outer
//! loops; `MR × NR` is the register-block shape of the micro-kernel
//! (compile-time constants so the inner loops fully unroll and
//! auto-vectorize). Defaults follow the shapes BLIS uses for Haswell-class
//! double precision (paper §2: "`m_r, n_r` in the range 4–16; `m_c, k_c`
//! in the order of a few hundreds; `n_c` up to a few thousands").

/// Micro-kernel rows (register block height).
pub const MR: usize = 8;
/// Micro-kernel columns (register block width).
pub const NR: usize = 6;

/// Cache-blocking parameters for the five-loop GEMM.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct BlisParams {
    /// Loop-3 block (rows of `A_c`, sized for L2 residency).
    pub mc: usize,
    /// Loop-2 block (the shared `k` dimension, sized for L1/L2 residency).
    pub kc: usize,
    /// Loop-1 block (columns of `B_c`, sized for L3 residency).
    pub nc: usize,
}

impl Default for BlisParams {
    fn default() -> Self {
        // Tuned for ~Haswell L2 (256 KiB): m_c·k_c·8B ≈ 96·256·8 = 192 KiB.
        Self {
            mc: 96,
            kc: 256,
            nc: 4092,
        }
    }
}

impl BlisParams {
    /// Parameters scaled down for small unit-test problems (exercises all
    /// edge paths with multiple blocks on tiny matrices).
    pub fn tiny() -> Self {
        Self {
            mc: 2 * MR,
            kc: 8,
            nc: 3 * NR,
        }
    }

    /// Validate invariants (all blocks nonzero; `mc` multiple of `MR` and
    /// `nc` multiple of `NR` keep packing edge-free except at matrix
    /// borders).
    pub fn validated(self) -> Result<Self, String> {
        if self.mc == 0 || self.kc == 0 || self.nc == 0 {
            return Err(format!("BlisParams must be nonzero: {self:?}"));
        }
        if self.mc % MR != 0 {
            return Err(format!("mc={} not a multiple of MR={MR}", self.mc));
        }
        if self.nc % NR != 0 {
            return Err(format!("nc={} not a multiple of NR={NR}", self.nc));
        }
        Ok(self)
    }

    /// Working-set of the packed buffers in bytes (`A_c` + `B_c`).
    pub fn packed_bytes(&self) -> usize {
        (self.mc * self.kc + self.kc * self.nc) * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        BlisParams::default().validated().unwrap();
        BlisParams::tiny().validated().unwrap();
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(BlisParams {
            mc: 0,
            kc: 1,
            nc: NR
        }
        .validated()
        .is_err());
        assert!(BlisParams {
            mc: MR + 1,
            kc: 1,
            nc: NR
        }
        .validated()
        .is_err());
        assert!(BlisParams {
            mc: MR,
            kc: 1,
            nc: NR + 1
        }
        .validated()
        .is_err());
    }

    #[test]
    fn packed_bytes_sane() {
        let p = BlisParams::default();
        // A_c ≈ 192 KiB, B_c ≈ 8 MiB for the default config.
        assert_eq!(p.packed_bytes(), (p.mc * p.kc + p.kc * p.nc) * 8);
        assert!(p.packed_bytes() > 8 * 1024 * 1024);
    }
}
