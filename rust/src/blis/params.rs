//! BLIS cache-blocking configuration.
//!
//! `m_c, k_c, n_c` are the cache-blocking parameters of the three outer
//! loops; `MR × NR` is the register-block shape of the micro-kernel
//! (compile-time constants so the inner loops fully unroll and
//! auto-vectorize). Defaults follow the shapes BLIS uses for Haswell-class
//! double precision (paper §2: "`m_r, n_r` in the range 4–16; `m_c, k_c`
//! in the order of a few hundreds; `n_c` up to a few thousands").
//!
//! [`BlisParams::auto`] derives the parameters from the host's cache
//! topology at startup (Linux sysfs; BLIS's analytical model in
//! simplified form), falling back to the Haswell defaults when the
//! topology is unreadable. `mlu --params mc,kc,nc` overrides both.

/// Micro-kernel rows (register block height). Shared by both sealed
/// scalar types: 8 rows are two AVX2 `f64x4` vectors or one `f32x8`.
pub const MR: usize = 8;
/// Micro-kernel columns (register block width).
pub const NR: usize = 6;

/// Columns per row-swap strip — the single shared definition consumed by
/// [`super::laswp`] and the look-ahead driver's base-relative swap path
/// (`factor::lu::laswp_abs`). A few micro-panels wide: small enough that
/// the pivot rows × strip working set stays cache-resident, large enough
/// to amortize the per-strip pivot-sequence walk.
pub const COL_STRIP: usize = 32;

pub use crate::pool::steal::StealPolicy;

/// Cache-blocking parameters for the five-loop GEMM.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct BlisParams {
    /// Loop-3 block (rows of `A_c`, sized for L2 residency).
    pub mc: usize,
    /// Loop-2 block (the shared `k` dimension, sized for L1/L2 residency).
    pub kc: usize,
    /// Loop-1 block (columns of `B_c`, sized for L3 residency).
    pub nc: usize,
    /// How the macro-kernel's tile grid is scheduled across the crew:
    /// hybrid static/dynamic tile-stealing (DESIGN.md §13) or the
    /// central-ticket baseline. Bitwise-neutral by construction; `mlu
    /// --steal off|auto|<fraction>` overrides.
    pub steal: StealPolicy,
}

impl Default for BlisParams {
    fn default() -> Self {
        // Tuned for ~Haswell L2 (256 KiB): m_c·k_c·8B ≈ 96·256·8 = 192 KiB.
        Self {
            mc: 96,
            kc: 256,
            nc: 4092,
            steal: StealPolicy::default(),
        }
    }
}

impl BlisParams {
    /// Parameters scaled down for small unit-test problems (exercises all
    /// edge paths with multiple blocks on tiny matrices).
    pub fn tiny() -> Self {
        Self {
            mc: 2 * MR,
            kc: 8,
            nc: 3 * NR,
            steal: StealPolicy::default(),
        }
    }

    /// This configuration with a different steal policy (builder-style,
    /// for tests and benches that compare schedules).
    pub fn with_steal(mut self, steal: StealPolicy) -> Self {
        self.steal = steal;
        self
    }

    /// Validate invariants (all blocks nonzero; `mc` multiple of `MR` and
    /// `nc` multiple of `NR` keep packing edge-free except at matrix
    /// borders).
    pub fn validated(self) -> Result<Self, String> {
        if self.mc == 0 || self.kc == 0 || self.nc == 0 {
            return Err(format!("BlisParams must be nonzero: {self:?}"));
        }
        if self.mc % MR != 0 {
            return Err(format!("mc={} not a multiple of MR={MR}", self.mc));
        }
        if self.nc % NR != 0 {
            return Err(format!("nc={} not a multiple of NR={NR}", self.nc));
        }
        Ok(self)
    }

    /// Working-set of the packed buffers in bytes (`A_c` + `B_c`).
    pub fn packed_bytes(&self) -> usize {
        (self.mc * self.kc + self.kc * self.nc) * std::mem::size_of::<f64>()
    }

    /// Parse a `mc,kc,nc` override string (the `mlu --params` syntax).
    pub fn parse(s: &str) -> Result<Self, String> {
        let parts: Vec<&str> = s.split(',').map(str::trim).collect();
        if parts.len() != 3 {
            return Err(format!("expected mc,kc,nc — got {s:?}"));
        }
        let num = |p: &str| -> Result<usize, String> {
            p.parse().map_err(|_| format!("bad block size {p:?}"))
        };
        Self {
            mc: num(parts[0])?,
            kc: num(parts[1])?,
            nc: num(parts[2])?,
            steal: StealPolicy::default(),
        }
        .validated()
    }

    /// Cache-topology-derived parameters for this host, computed once at
    /// first use (BLIS's analytical sizing, simplified):
    ///
    /// - `k_c`: an `MR`-row `A` micro-panel plus an `NR`-column `B`
    ///   micro-panel, both `k_c` deep, fill the L1 data cache;
    /// - `m_c`: `A_c` (`m_c × k_c`) occupies ~¾ of L2 (leaving room for
    ///   the streaming `B` micro-panel and `C` tile);
    /// - `n_c`: `B_c` (`k_c × n_c`) occupies ~half of L3.
    ///
    /// Falls back to [`BlisParams::default`] when the topology cannot be
    /// read (non-Linux hosts, containers hiding sysfs).
    pub fn auto() -> Self {
        static AUTO: std::sync::OnceLock<BlisParams> = std::sync::OnceLock::new();
        *AUTO.get_or_init(|| match CacheInfo::detect() {
            Some(info) => Self::from_cache_info(&info),
            None => Self::default(),
        })
    }

    /// Derive parameters from explicit cache sizes (see [`BlisParams::auto`]).
    pub fn from_cache_info(info: &CacheInfo) -> Self {
        const F: usize = std::mem::size_of::<f64>();
        let kc = (info.l1d / (F * (MR + NR))).clamp(64, 1024) / 8 * 8;
        let mc = (info.l2 * 3 / 4 / (F * kc)).clamp(2 * MR, 4096) / MR * MR;
        let nc = (info.l3 / 2 / (F * kc)).clamp(8 * NR, 16384) / NR * NR;
        Self {
            mc,
            kc,
            nc,
            steal: StealPolicy::default(),
        }
        .validated()
            .unwrap_or_else(|_| Self::default())
    }
}

/// Host cache sizes in bytes (per core for L1/L2, package for L3).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct CacheInfo {
    pub l1d: usize,
    pub l2: usize,
    pub l3: usize,
}

impl CacheInfo {
    /// Read cpu0's cache hierarchy from Linux sysfs. Returns `None` when
    /// the information is unavailable; a missing L3 falls back to 4× L2
    /// (small VMs often hide it).
    pub fn detect() -> Option<Self> {
        let base = "/sys/devices/system/cpu/cpu0/cache";
        let mut l1d = None;
        let mut l2 = None;
        let mut l3 = None;
        for idx in 0..8 {
            let dir = format!("{base}/index{idx}");
            let read = |f: &str| std::fs::read_to_string(format!("{dir}/{f}")).ok();
            let Some(level) = read("level").and_then(|s| s.trim().parse::<u32>().ok()) else {
                continue;
            };
            let ty = read("type").map(|s| s.trim().to_string()).unwrap_or_default();
            let Some(size) = read("size").and_then(|s| parse_cache_size(s.trim())) else {
                continue;
            };
            match (level, ty.as_str()) {
                (1, "Data" | "Unified") => l1d = Some(size),
                (2, _) if ty != "Instruction" => l2 = Some(size),
                (3, _) if ty != "Instruction" => l3 = Some(size),
                _ => {}
            }
        }
        let l1d = l1d?;
        let l2 = l2?;
        Some(Self {
            l1d,
            l2,
            l3: l3.unwrap_or(4 * l2),
        })
    }
}

/// Parse sysfs cache-size strings: `"32K"`, `"1024K"`, `"8M"`, `"32768"`.
fn parse_cache_size(s: &str) -> Option<usize> {
    let (digits, mult) = match s.as_bytes().last()? {
        b'K' | b'k' => (&s[..s.len() - 1], 1024),
        b'M' | b'm' => (&s[..s.len() - 1], 1024 * 1024),
        b'G' | b'g' => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s, 1),
    };
    digits.trim().parse::<usize>().ok().map(|v| v * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        BlisParams::default().validated().unwrap();
        BlisParams::tiny().validated().unwrap();
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(BlisParams {
            mc: 0,
            kc: 1,
            nc: NR,
            ..BlisParams::default()
        }
        .validated()
        .is_err());
        assert!(BlisParams {
            mc: MR + 1,
            kc: 1,
            nc: NR,
            ..BlisParams::default()
        }
        .validated()
        .is_err());
        assert!(BlisParams {
            mc: MR,
            kc: 1,
            nc: NR + 1,
            ..BlisParams::default()
        }
        .validated()
        .is_err());
    }

    #[test]
    fn parse_override_string() {
        assert_eq!(
            BlisParams::parse("96,256,4092").unwrap(),
            BlisParams {
                mc: 96,
                kc: 256,
                nc: 4092,
                ..BlisParams::default()
            }
        );
        assert_eq!(
            BlisParams::parse(" 16 , 8 , 12 ").unwrap(),
            BlisParams {
                mc: 16,
                kc: 8,
                nc: 12,
                ..BlisParams::default()
            }
        );
        assert!(BlisParams::parse("96,256").is_err());
        assert!(BlisParams::parse("a,b,c").is_err());
        assert!(BlisParams::parse("97,256,4092").is_err(), "mc % MR");
    }

    #[test]
    fn cache_sizes_parse() {
        assert_eq!(parse_cache_size("32K"), Some(32 * 1024));
        assert_eq!(parse_cache_size("8M"), Some(8 * 1024 * 1024));
        assert_eq!(parse_cache_size("32768"), Some(32768));
        assert_eq!(parse_cache_size("junk"), None);
    }

    #[test]
    fn derived_params_are_valid_for_plausible_topologies() {
        for info in [
            // Haswell-ish, a big server part, and a tiny VM.
            CacheInfo {
                l1d: 32 * 1024,
                l2: 256 * 1024,
                l3: 8 * 1024 * 1024,
            },
            CacheInfo {
                l1d: 48 * 1024,
                l2: 2 * 1024 * 1024,
                l3: 64 * 1024 * 1024,
            },
            CacheInfo {
                l1d: 16 * 1024,
                l2: 128 * 1024,
                l3: 512 * 1024,
            },
        ] {
            let p = BlisParams::from_cache_info(&info);
            p.validated().unwrap();
            assert!(p.kc >= 64 && p.kc <= 1024, "{info:?} -> {p:?}");
            assert!(p.mc >= 2 * MR, "{info:?} -> {p:?}");
            assert!(p.nc >= 8 * NR, "{info:?} -> {p:?}");
        }
    }

    #[test]
    fn auto_params_always_usable() {
        // Whatever the host (or lack of sysfs), auto() must give valid
        // parameters, and be stable across calls.
        let p = BlisParams::auto();
        p.validated().unwrap();
        assert_eq!(p, BlisParams::auto());
    }

    #[test]
    fn packed_bytes_sane() {
        let p = BlisParams::default();
        // A_c ≈ 192 KiB, B_c ≈ 8 MiB for the default config.
        assert_eq!(p.packed_bytes(), (p.mc * p.kc + p.kc * p.nc) * 8);
        assert!(p.packed_bytes() > 8 * 1024 * 1024);
    }
}
