//! The register-blocked micro-kernel (paper Fig. 1, Loop 5 body).
//!
//! Computes `C(0..MR, 0..NR) += Σ_p a_panel(:,p) · b_panel(p,:)` over the
//! packed micro-panels produced by [`super::pack`]. The accumulator lives
//! in a fixed-size local array so LLVM keeps it in registers and
//! vectorizes the `MR × NR` rank-1 updates (with `-C target-cpu=native`
//! this compiles to FMA on AVX2 hosts).
//!
//! Edge tiles (fewer than `MR` rows / `NR` columns of real `C`) use the
//! same full-size computation — the packed operands are zero-padded — and
//! mask only the final store.

use super::params::{MR, NR};
use crate::matrix::MatMut;

/// `C_tile += alpha * A_panel · B_panel`, where `a_panel`/`b_panel` are
/// `k`-deep packed micro-panels and the live tile is `m_eff × n_eff`
/// (`≤ MR × NR`) at `c`'s origin.
#[inline]
pub fn micro_kernel(
    k: usize,
    alpha: f64,
    a_panel: &[f64],
    b_panel: &[f64],
    c: MatMut,
    m_eff: usize,
    n_eff: usize,
) {
    debug_assert!(a_panel.len() >= k * MR);
    debug_assert!(b_panel.len() >= k * NR);
    debug_assert!(m_eff <= MR && n_eff <= NR);

    let mut acc = [0.0f64; MR * NR];
    // The hot loop: one rank-1 update of the register block per p.
    for p in 0..k {
        let a = &a_panel[p * MR..p * MR + MR];
        let b = &b_panel[p * NR..p * NR + NR];
        for j in 0..NR {
            let bj = b[j];
            for i in 0..MR {
                acc[j * MR + i] += a[i] * bj;
            }
        }
    }

    // Masked store into C.
    if m_eff == MR && n_eff == NR {
        for j in 0..NR {
            let col = c.col_ptr(j);
            for (i, &v) in acc[j * MR..j * MR + MR].iter().enumerate() {
                unsafe { *col.add(i) += alpha * v };
            }
        }
    } else {
        for j in 0..n_eff {
            for i in 0..m_eff {
                c.update(i, j, |x| x + alpha * acc[j * MR + i]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{naive, Matrix};

    fn pack_cols(a: &Matrix) -> Vec<f64> {
        // pack a (MR x k) into column-major-by-p layout
        let k = a.cols();
        let mut v = vec![0.0; k * MR];
        for p in 0..k {
            for i in 0..a.rows() {
                v[p * MR + i] = a[(i, p)];
            }
        }
        v
    }

    fn pack_rows(b: &Matrix) -> Vec<f64> {
        let k = b.rows();
        let mut v = vec![0.0; k * NR];
        for p in 0..k {
            for j in 0..b.cols() {
                v[p * NR + j] = b[(p, j)];
            }
        }
        v
    }

    #[test]
    fn full_tile_matches_naive() {
        let k = 17;
        let a = Matrix::random(MR, k, 1);
        let b = Matrix::random(k, NR, 2);
        let mut c = Matrix::random(MR, NR, 3);
        let mut c_ref = c.clone();

        micro_kernel(k, 1.0, &pack_cols(&a), &pack_rows(&b), c.view_mut(), MR, NR);
        naive::gemm(1.0, a.view(), b.view(), c_ref.view_mut());
        assert!(c.max_abs_diff(&c_ref) < 1e-13);
    }

    #[test]
    fn edge_tile_touches_only_live_region() {
        let k = 5;
        let (m_eff, n_eff) = (3, 2);
        let a = Matrix::random(m_eff, k, 4);
        let b = Matrix::random(k, n_eff, 5);
        // C is the live region embedded in a bigger matrix; the kernel
        // must not write outside it.
        let mut big = Matrix::from_fn(MR + 2, NR + 2, |_, _| -7.0);
        let mut big_ref = big.clone();

        // zero-padded packs
        let mut ap = vec![0.0; k * MR];
        for p in 0..k {
            for i in 0..m_eff {
                ap[p * MR + i] = a[(i, p)];
            }
        }
        let mut bp = vec![0.0; k * NR];
        for p in 0..k {
            for j in 0..n_eff {
                bp[p * NR + j] = b[(p, j)];
            }
        }

        micro_kernel(
            k,
            2.0,
            &ap,
            &bp,
            big.view_mut().sub(1, 1, m_eff, n_eff),
            m_eff,
            n_eff,
        );
        naive::gemm(
            2.0,
            a.view(),
            b.view(),
            big_ref.view_mut().sub(1, 1, m_eff, n_eff),
        );
        assert!(big.max_abs_diff(&big_ref) < 1e-13);
        // Fringe untouched:
        assert_eq!(big[(0, 0)], -7.0);
        assert_eq!(big[(MR + 1, NR + 1)], -7.0);
    }

    #[test]
    fn k_zero_is_noop() {
        let mut c = Matrix::random(MR, NR, 9);
        let before = c.clone();
        micro_kernel(0, 1.0, &[], &[], c.view_mut(), MR, NR);
        assert_eq!(c, before);
    }

    #[test]
    fn alpha_scales() {
        let k = 3;
        let a = Matrix::random(MR, k, 6);
        let b = Matrix::random(k, NR, 7);
        let mut c1 = Matrix::zeros(MR, NR);
        let mut c2 = Matrix::zeros(MR, NR);
        micro_kernel(k, 1.0, &pack_cols(&a), &pack_rows(&b), c1.view_mut(), MR, NR);
        micro_kernel(k, -2.5, &pack_cols(&a), &pack_rows(&b), c2.view_mut(), MR, NR);
        for j in 0..NR {
            for i in 0..MR {
                assert!((c2[(i, j)] + 2.5 * c1[(i, j)]).abs() < 1e-12);
            }
        }
    }
}
