//! The register-blocked micro-kernel (paper Fig. 1, Loop 5 body), one
//! per sealed [`Scalar`] type.
//!
//! Computes `C(0..MR, 0..NR) += Σ_p a_panel(:,p) · b_panel(p,:)` over the
//! packed micro-panels produced by [`super::pack`]. Per scalar type, two
//! implementations share one contract (the **SIMD dispatch contract**,
//! DESIGN.md §9/§12):
//!
//! - an explicit AVX2+FMA `std::arch` kernel —
//!   [`micro_kernel_avx2`] holds the full `f64` `MR × NR = 8 × 6`
//!   accumulator in twelve `__m256d` registers (two `f64x4` vectors per
//!   column); [`micro_kernel_avx2_f32`] holds the same 8 × 6 tile in six
//!   `__m256` registers (one `f32x8` vector per column — the doubled
//!   lane width is where single precision earns its ~2× throughput);
//! - [`micro_kernel_portable`] — one *generic* scalar fallback
//!   performing the same reduction in the same order, with
//!   [`Scalar::mul_add`] as the multiply-accumulate.
//!
//! Within a type, both perform, per output element, the identical chain
//! of IEEE-754 correctly-rounded fused multiply-adds followed by one
//! `alpha·acc` multiply and one add at store time — so their results are
//! **bitwise identical**, and the repo-wide determinism invariant
//! (DESIGN.md §8) extends across kernels in both precisions: a
//! factorization gives the same bits whether it ran SIMD, portable, or a
//! mix.
//!
//! [`micro_kernel`] dispatches at runtime through the type's registry
//! entry ([`Scalar::micro_kernel`]): AVX2+FMA when the CPU has it
//! (detected once, cached), portable otherwise; [`set_kernel`] forces a
//! choice (benchmarking, tests, `mlu --kernel`), and the `MLU_KERNEL`
//! environment variable (`portable` | `simd`) does the same for
//! processes that cannot pass a flag — the CI no-AVX2 job drives the
//! portable path for both scalar types this way.
//!
//! Edge tiles (fewer than `MR` rows / `NR` columns of real `C`) use the
//! same full-size computation — the packed operands are zero-padded — and
//! mask only the final store.

use super::params::{MR, NR};
use crate::matrix::MatMut;
use crate::scalar::Scalar;
use std::sync::atomic::{AtomicU8, Ordering};

/// Micro-kernel selection (see [`set_kernel`]).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Runtime feature detection (the default): SIMD where available.
    Auto,
    /// Force the scalar fallback.
    Portable,
    /// Prefer SIMD; silently degrades to portable on CPUs without
    /// AVX2+FMA (the results are bitwise identical either way).
    Simd,
}

/// 0 = Auto, 1 = Portable, 2 = Simd.
static KERNEL_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Serializes tests that flip [`set_kernel`] and then assert on the
/// dispatch state (the override is process-global; without the lock a
/// concurrent test could flip it between set and assert). Flipping the
/// kernel mid-computation is *correct* — the kernels are bitwise
/// identical — so only the asserting tests need this.
#[cfg(test)]
pub(crate) static KERNEL_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Force a micro-kernel choice process-wide (benches, bitwise tests,
/// `mlu --kernel portable`). Safe to flip at any time: both kernels
/// produce identical bits, so in-flight work is unaffected. An explicit
/// choice overrides the `MLU_KERNEL` environment variable.
pub fn set_kernel(k: Kernel) {
    let v = match k {
        Kernel::Auto => 0,
        Kernel::Portable => 1,
        Kernel::Simd => 2,
    };
    KERNEL_OVERRIDE.store(v, Ordering::Relaxed);
}

/// The `MLU_KERNEL` environment override (`portable` | `simd`), read
/// once: the escape hatch for harnesses that cannot pass `--kernel`
/// (the CI no-AVX2 job exercises the portable path this way).
fn env_kernel() -> Option<Kernel> {
    static ENV: std::sync::OnceLock<Option<Kernel>> = std::sync::OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("MLU_KERNEL") {
        Ok(v) if v.eq_ignore_ascii_case("portable") => Some(Kernel::Portable),
        Ok(v) if v.eq_ignore_ascii_case("simd") => Some(Kernel::Simd),
        _ => None,
    })
}

/// Is the AVX2+FMA kernel available on this host? (One answer for both
/// scalar types: the `f64` and `f32` kernels need the same features.)
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static CACHE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
        *CACHE.get_or_init(|| {
            std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
        })
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Name of the kernel [`micro_kernel`] will dispatch to right now.
pub fn active_kernel_name() -> &'static str {
    if use_simd() {
        "avx2+fma"
    } else {
        "portable"
    }
}

#[inline]
pub(crate) fn use_simd() -> bool {
    match KERNEL_OVERRIDE.load(Ordering::Relaxed) {
        1 => false,
        2 => simd_available(),
        _ => match env_kernel() {
            Some(Kernel::Portable) => false,
            _ => simd_available(),
        },
    }
}

/// `C_tile += alpha * A_panel · B_panel`, where `a_panel`/`b_panel` are
/// `k`-deep packed micro-panels and the live tile is `m_eff × n_eff`
/// (`≤ MR × NR`) at `c`'s origin. Dispatches per the module docs through
/// the scalar type's registry entry.
#[inline]
pub fn micro_kernel<S: Scalar>(
    k: usize,
    alpha: S,
    a_panel: &[S],
    b_panel: &[S],
    c: MatMut<S>,
    m_eff: usize,
    n_eff: usize,
) {
    debug_assert!(a_panel.len() >= k * MR);
    debug_assert!(b_panel.len() >= k * NR);
    debug_assert!(m_eff <= MR && n_eff <= NR);
    S::micro_kernel(use_simd(), k, alpha, a_panel, b_panel, c, m_eff, n_eff);
}

/// Masked store for edge tiles (shared by every kernel of a type so the
/// rounding of the `alpha`-scaling is identical: one multiply, one add).
#[inline]
fn store_edge<S: Scalar>(alpha: S, acc: &[S; MR * NR], c: MatMut<S>, m_eff: usize, n_eff: usize) {
    for j in 0..n_eff {
        for i in 0..m_eff {
            c.update(i, j, |x| x + alpha * acc[j * MR + i]);
        }
    }
}

/// Scalar reference kernel, generic over the sealed types: one
/// correctly-rounded [`Scalar::mul_add`] per multiply-accumulate (the
/// contract each SIMD kernel reproduces).
pub fn micro_kernel_portable<S: Scalar>(
    k: usize,
    alpha: S,
    a_panel: &[S],
    b_panel: &[S],
    c: MatMut<S>,
    m_eff: usize,
    n_eff: usize,
) {
    let mut acc = [S::ZERO; MR * NR];
    // The hot loop: one rank-1 update of the register block per p.
    for p in 0..k {
        let a = &a_panel[p * MR..p * MR + MR];
        let b = &b_panel[p * NR..p * NR + NR];
        for (j, &bj) in b.iter().enumerate() {
            for i in 0..MR {
                acc[j * MR + i] = a[i].mul_add(bj, acc[j * MR + i]);
            }
        }
    }

    // Masked store into C.
    if m_eff == MR && n_eff == NR {
        for j in 0..NR {
            let col = c.col_ptr(j);
            for (i, &v) in acc[j * MR..j * MR + MR].iter().enumerate() {
                unsafe { *col.add(i) += alpha * v };
            }
        }
    } else {
        store_edge(alpha, &acc, c, m_eff, n_eff);
    }
}

// The AVX2 kernels hardcode the 8×6 register block (f64: two f64x4
// vectors per column, twelve accumulators; f32: one f32x8 vector per
// column, six accumulators).
#[cfg(target_arch = "x86_64")]
const _: () = assert!(MR == 8 && NR == 6, "AVX2 micro-kernels assume MR=8, NR=6");

/// AVX2+FMA `f64` micro-kernel.
///
/// # Safety
/// The CPU must support AVX2 and FMA (`simd_available()`), and the
/// packed panels must hold at least `k` full micro-panels (zero-padded
/// at the edges) exactly as [`micro_kernel`]'s debug assertions state.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn micro_kernel_avx2(
    k: usize,
    alpha: f64,
    a_panel: &[f64],
    b_panel: &[f64],
    c: MatMut,
    m_eff: usize,
    n_eff: usize,
) {
    use std::arch::x86_64::*;

    let mut acc = [[_mm256_setzero_pd(); 2]; NR];
    let mut ap = a_panel.as_ptr();
    let mut bp = b_panel.as_ptr();
    for _ in 0..k {
        let a0 = _mm256_loadu_pd(ap);
        let a1 = _mm256_loadu_pd(ap.add(4));
        for (j, acc_j) in acc.iter_mut().enumerate() {
            let bj = _mm256_set1_pd(*bp.add(j));
            acc_j[0] = _mm256_fmadd_pd(a0, bj, acc_j[0]);
            acc_j[1] = _mm256_fmadd_pd(a1, bj, acc_j[1]);
        }
        ap = ap.add(MR);
        bp = bp.add(NR);
    }

    if m_eff == MR && n_eff == NR {
        // Full tile: vector store. mul + add (not fmadd) to match the
        // portable store's two-rounding `c + alpha*v` exactly.
        let av = _mm256_set1_pd(alpha);
        for (j, acc_j) in acc.iter().enumerate() {
            let colp = c.col_ptr(j);
            let c0 = _mm256_loadu_pd(colp);
            let c1 = _mm256_loadu_pd(colp.add(4));
            _mm256_storeu_pd(colp, _mm256_add_pd(c0, _mm256_mul_pd(av, acc_j[0])));
            _mm256_storeu_pd(colp.add(4), _mm256_add_pd(c1, _mm256_mul_pd(av, acc_j[1])));
        }
    } else {
        // Edge tile: spill the accumulator and reuse the scalar masked
        // store (identical rounding by construction).
        let mut tmp = [0.0f64; MR * NR];
        for (j, acc_j) in acc.iter().enumerate() {
            _mm256_storeu_pd(tmp.as_mut_ptr().add(j * MR), acc_j[0]);
            _mm256_storeu_pd(tmp.as_mut_ptr().add(j * MR + 4), acc_j[1]);
        }
        store_edge(alpha, &tmp, c, m_eff, n_eff);
    }
}

/// AVX2+FMA `f32` micro-kernel: the same 8 × 6 tile as the `f64` kernel,
/// but one `f32x8` vector covers a whole column — six accumulators, one
/// `vfmadd` per column per `p`, twice the flops per instruction.
///
/// # Safety
/// As [`micro_kernel_avx2`]: AVX2+FMA must be present and the packed
/// panels must hold `k` full (zero-padded) micro-panels.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn micro_kernel_avx2_f32(
    k: usize,
    alpha: f32,
    a_panel: &[f32],
    b_panel: &[f32],
    c: MatMut<f32>,
    m_eff: usize,
    n_eff: usize,
) {
    use std::arch::x86_64::*;

    let mut acc = [_mm256_setzero_ps(); NR];
    let mut ap = a_panel.as_ptr();
    let mut bp = b_panel.as_ptr();
    for _ in 0..k {
        let a0 = _mm256_loadu_ps(ap);
        for (j, acc_j) in acc.iter_mut().enumerate() {
            let bj = _mm256_set1_ps(*bp.add(j));
            *acc_j = _mm256_fmadd_ps(a0, bj, *acc_j);
        }
        ap = ap.add(MR);
        bp = bp.add(NR);
    }

    if m_eff == MR && n_eff == NR {
        // Full tile: mul + add, matching the portable store's two
        // roundings exactly (same contract as the f64 kernel).
        let av = _mm256_set1_ps(alpha);
        for (j, acc_j) in acc.iter().enumerate() {
            let colp = c.col_ptr(j);
            let c0 = _mm256_loadu_ps(colp);
            _mm256_storeu_ps(colp, _mm256_add_ps(c0, _mm256_mul_ps(av, *acc_j)));
        }
    } else {
        let mut tmp = [0.0f32; MR * NR];
        for (j, acc_j) in acc.iter().enumerate() {
            _mm256_storeu_ps(tmp.as_mut_ptr().add(j * MR), *acc_j);
        }
        store_edge(alpha, &tmp, c, m_eff, n_eff);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{naive, Mat, Matrix};

    fn pack_cols<S: Scalar>(a: &Mat<S>) -> Vec<S> {
        // pack a (m x k, m <= MR) into column-major-by-p layout, zero-padded
        let k = a.cols();
        let mut v = vec![S::ZERO; k * MR];
        for p in 0..k {
            for i in 0..a.rows() {
                v[p * MR + i] = a[(i, p)];
            }
        }
        v
    }

    fn pack_rows<S: Scalar>(b: &Mat<S>) -> Vec<S> {
        let k = b.rows();
        let mut v = vec![S::ZERO; k * NR];
        for p in 0..k {
            for j in 0..b.cols() {
                v[p * NR + j] = b[(p, j)];
            }
        }
        v
    }

    #[test]
    fn full_tile_matches_naive() {
        let k = 17;
        let a = Matrix::random(MR, k, 1);
        let b = Matrix::random(k, NR, 2);
        let mut c = Matrix::random(MR, NR, 3);
        let mut c_ref = c.clone();

        micro_kernel(k, 1.0, &pack_cols(&a), &pack_rows(&b), c.view_mut(), MR, NR);
        naive::gemm(1.0, a.view(), b.view(), c_ref.view_mut());
        assert!(c.max_abs_diff(&c_ref) < 1e-13);
    }

    #[test]
    fn full_tile_matches_naive_f32() {
        let k = 17;
        let a = Mat::<f32>::random(MR, k, 1);
        let b = Mat::<f32>::random(k, NR, 2);
        let mut c = Mat::<f32>::random(MR, NR, 3);
        let mut c_ref = c.clone();

        micro_kernel(
            k,
            1.0f32,
            &pack_cols(&a),
            &pack_rows(&b),
            c.view_mut(),
            MR,
            NR,
        );
        naive::gemm(1.0f32, a.view(), b.view(), c_ref.view_mut());
        assert!(c.max_abs_diff(&c_ref) < 1e-4);
    }

    #[test]
    fn edge_tile_touches_only_live_region() {
        let k = 5;
        let (m_eff, n_eff) = (3, 2);
        let a = Matrix::random(m_eff, k, 4);
        let b = Matrix::random(k, n_eff, 5);
        // C is the live region embedded in a bigger matrix; the kernel
        // must not write outside it.
        let mut big = Matrix::from_fn(MR + 2, NR + 2, |_, _| -7.0);
        let mut big_ref = big.clone();

        micro_kernel(
            k,
            2.0,
            &pack_cols(&a),
            &pack_rows(&b),
            big.view_mut().sub(1, 1, m_eff, n_eff),
            m_eff,
            n_eff,
        );
        naive::gemm(
            2.0,
            a.view(),
            b.view(),
            big_ref.view_mut().sub(1, 1, m_eff, n_eff),
        );
        assert!(big.max_abs_diff(&big_ref) < 1e-13);
        // Fringe untouched:
        assert_eq!(big[(0, 0)], -7.0);
        assert_eq!(big[(MR + 1, NR + 1)], -7.0);
    }

    #[test]
    fn k_zero_is_noop() {
        let mut c = Matrix::random(MR, NR, 9);
        let before = c.clone();
        micro_kernel(0, 1.0, &[], &[], c.view_mut(), MR, NR);
        assert_eq!(c, before);
    }

    #[test]
    fn alpha_scales() {
        let k = 3;
        let a = Matrix::random(MR, k, 6);
        let b = Matrix::random(k, NR, 7);
        let mut c1 = Matrix::zeros(MR, NR);
        let mut c2 = Matrix::zeros(MR, NR);
        micro_kernel(k, 1.0, &pack_cols(&a), &pack_rows(&b), c1.view_mut(), MR, NR);
        micro_kernel(k, -2.5, &pack_cols(&a), &pack_rows(&b), c2.view_mut(), MR, NR);
        for j in 0..NR {
            for i in 0..MR {
                assert!((c2[(i, j)] + 2.5 * c1[(i, j)]).abs() < 1e-12);
            }
        }
    }

    /// Run one kernel flavor on an edge tile embedded in a sentinel
    /// matrix; checks the live region against naive and the fringe for
    /// pollution. `which`: 0 = dispatch, 1 = portable, 2 = simd (via
    /// the scalar registry with the flag forced on).
    fn check_edge_tile<S: Scalar>(m_eff: usize, n_eff: usize, k: usize, which: u8, tol: f64) {
        let seed = (m_eff * 1000 + n_eff * 10 + k) as u64;
        let a = Mat::<S>::random(m_eff, k, seed);
        let b = Mat::<S>::random(k, n_eff, seed + 1);
        let mut big =
            Mat::<S>::from_fn(MR + 3, NR + 3, |i, j| {
                S::from_f64((i * 31 + j) as f64 * 0.25 - 3.0)
            });
        let mut big_ref = big.clone();
        let tile = big.view_mut().sub(2, 1, m_eff, n_eff);
        let (ap, bp) = (pack_cols(&a), pack_rows(&b));
        let neg1 = S::ZERO - S::ONE;
        match which {
            1 => micro_kernel_portable(k, neg1, &ap, &bp, tile, m_eff, n_eff),
            2 => S::micro_kernel(true, k, neg1, &ap, &bp, tile, m_eff, n_eff),
            _ => micro_kernel(k, neg1, &ap, &bp, tile, m_eff, n_eff),
        }
        naive::gemm(
            neg1,
            a.view(),
            b.view(),
            big_ref.view_mut().sub(2, 1, m_eff, n_eff),
        );
        let d = big.max_abs_diff(&big_ref);
        assert!(
            d < tol,
            "{} which={which} m_eff={m_eff} n_eff={n_eff} k={k}: diff {d}",
            S::NAME
        );
    }

    #[test]
    fn exhaustive_edge_tile_sweep_portable() {
        for m_eff in 1..=MR {
            for n_eff in 1..=NR {
                for k in [1usize, 2, 7] {
                    check_edge_tile::<f64>(m_eff, n_eff, k, 1, 1e-12);
                    check_edge_tile::<f32>(m_eff, n_eff, k, 1, 1e-4);
                }
            }
        }
    }

    #[test]
    fn exhaustive_edge_tile_sweep_dispatch() {
        for m_eff in 1..=MR {
            for n_eff in 1..=NR {
                for k in [1usize, 3, 9] {
                    check_edge_tile::<f64>(m_eff, n_eff, k, 0, 1e-12);
                    check_edge_tile::<f32>(m_eff, n_eff, k, 0, 1e-4);
                }
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn exhaustive_edge_tile_sweep_avx2_both_precisions() {
        if !simd_available() {
            eprintln!("skipping: host has no AVX2+FMA");
            return;
        }
        for m_eff in 1..=MR {
            for n_eff in 1..=NR {
                for k in [1usize, 4, 11] {
                    check_edge_tile::<f64>(m_eff, n_eff, k, 2, 1e-12);
                    check_edge_tile::<f32>(m_eff, n_eff, k, 2, 1e-4);
                }
            }
        }
    }

    /// SIMD and portable must agree bit for bit — per scalar type.
    #[cfg(target_arch = "x86_64")]
    fn bitwise_sweep<S: Scalar>() {
        for (m_eff, n_eff, k, alpha) in [
            (MR, NR, 64, 1.0),
            (MR, NR, 1, -1.0),
            (MR - 1, NR, 33, -1.0),
            (MR, NR - 2, 17, 0.5),
            (3, 2, 25, -2.5),
            (1, 1, 9, 1.0),
        ] {
            let alpha = S::from_f64(alpha);
            let seed = (m_eff * 100 + n_eff * 10 + k) as u64;
            let a = Mat::<S>::random(m_eff, k, seed);
            let b = Mat::<S>::random(k, n_eff, seed + 1);
            let c0 = Mat::<S>::random(MR, NR, seed + 2);
            let (ap, bp) = (pack_cols(&a), pack_rows(&b));

            let mut c_simd = c0.clone();
            S::micro_kernel(
                true,
                k,
                alpha,
                &ap,
                &bp,
                c_simd.view_mut().sub(0, 0, m_eff, n_eff),
                m_eff,
                n_eff,
            );
            let mut c_port = c0.clone();
            micro_kernel_portable(
                k,
                alpha,
                &ap,
                &bp,
                c_port.view_mut().sub(0, 0, m_eff, n_eff),
                m_eff,
                n_eff,
            );
            for (x, y) in c_simd.data().iter().zip(c_port.data()) {
                assert_eq!(
                    x.to_bits_u64(),
                    y.to_bits_u64(),
                    "{}: bitwise mismatch at m_eff={m_eff} n_eff={n_eff} k={k}",
                    S::NAME
                );
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn simd_and_portable_are_bitwise_identical() {
        if !simd_available() {
            eprintln!("skipping: host has no AVX2+FMA");
            return;
        }
        bitwise_sweep::<f64>();
        bitwise_sweep::<f32>();
    }

    #[test]
    fn kernel_override_controls_dispatch() {
        let _g = KERNEL_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_kernel(Kernel::Portable);
        assert_eq!(active_kernel_name(), "portable");
        set_kernel(Kernel::Simd);
        if simd_available() {
            assert_eq!(active_kernel_name(), "avx2+fma");
        } else {
            assert_eq!(active_kernel_name(), "portable");
        }
        set_kernel(Kernel::Auto);
        // Under Auto the MLU_KERNEL env (if set) wins, else hardware.
        let expect = match std::env::var("MLU_KERNEL") {
            Ok(v) if v.eq_ignore_ascii_case("portable") => "portable",
            _ => {
                if simd_available() {
                    "avx2+fma"
                } else {
                    "portable"
                }
            }
        };
        assert_eq!(active_kernel_name(), expect);
    }
}
