//! Small BLAS-1/2 kernels used by the unblocked LU panel factorization:
//! `iamax` (pivot search), `scal` (column scaling), `ger` (rank-1
//! update) — generic over the sealed [`Scalar`] layer. The panel lies on
//! the critical path with little concurrency (paper §3.1), so these are
//! sequential except for an optional crew variant of `ger` used when the
//! panel team has more than one thread.

use crate::matrix::MatMut;
use crate::pool::Crew;
use crate::scalar::Scalar;

/// Index of the entry of maximum absolute value in `x[lo..hi]` of column
/// `j` of `a` (returns an absolute row index). Ties resolve to the lowest
/// index, matching LAPACK's IDAMAX.
pub fn iamax_col<S: Scalar>(a: MatMut<S>, j: usize, lo: usize, hi: usize) -> usize {
    debug_assert!(lo < hi && hi <= a.rows());
    let mut best_i = lo;
    let mut best = a.at(lo, j).abs();
    for i in lo + 1..hi {
        let v = a.at(i, j).abs();
        if v > best {
            best = v;
            best_i = i;
        }
    }
    best_i
}

/// Scale `a[lo..hi, j] *= s`.
pub fn scal_col<S: Scalar>(a: MatMut<S>, j: usize, lo: usize, hi: usize, s: S) {
    for i in lo..hi {
        a.update(i, j, |x| x * s);
    }
}

/// Rank-1 update `A[rlo..rhi, clo..chi] -= x[rlo..rhi] · yᵀ[clo..chi]`
/// where `x` is column `xcol` of `a` and `y` is row `yrow` of `a`
/// (exactly the GER shape appearing in the unblocked LU inner loop).
pub fn ger_update<S: Scalar>(
    a: MatMut<S>,
    rlo: usize,
    rhi: usize,
    clo: usize,
    chi: usize,
    xcol: usize,
    yrow: usize,
) {
    for j in clo..chi {
        let yj = a.at(yrow, j);
        if yj == S::ZERO {
            continue;
        }
        for i in rlo..rhi {
            let xi = a.at(i, xcol);
            a.update(i, j, |v| v - xi * yj);
        }
    }
}

/// One column step of the unblocked right-looking LU with partial
/// pivoting — the *shared contract* between the per-problem leaf
/// ([`crate::lu::lu_unblocked`]) and the interleaved small-batch kernel
/// ([`crate::blis::smallbatch`]). Both paths must perform exactly this
/// sequence so they stay bitwise-identical per problem:
///
/// 1. pivot search over `a[k..m, k]` via [`iamax_col`] (ties resolve low,
///    LAPACK IDAMAX),
/// 2. full-width row swap `a[k, 0..n] <-> a[piv, 0..n]`,
/// 3. if the pivot is nonzero: reciprocal scale `a[k+1..m, k] *= 1/akk`
///    (a multiply by the rounded reciprocal, **not** a divide) followed by
///    the rank-1 update `a[k+1..m, k+1..n] -= a[k+1..m, k] · a[k, k+1..n]`
///    via [`ger_update`] (separate mul then sub, **not** fused),
/// 4. an exactly-zero pivot skips step 3 LAPACK-style, leaving the zero
///    on the diagonal.
///
/// Returns the pivot row (absolute index into the panel, `piv >= k`).
/// Any future change to the leaf arithmetic must happen here so the two
/// execution strategies cannot drift apart.
pub fn lu_step_col<S: Scalar>(a: MatMut<S>, k: usize, m: usize, n: usize) -> usize {
    let piv = iamax_col(a, k, k, m);
    a.swap_rows(k, piv, 0, n);
    let akk = a.at(k, k);
    if akk != S::ZERO {
        scal_col(a, k, k + 1, m, S::ONE / akk);
        ger_update(a, k + 1, m, k + 1, n, k, k);
    }
    piv
}

/// Crew-parallel version of [`ger_update`] (columns split across the
/// crew). Used when the panel team has more than one thread.
pub fn ger_update_par<S: Scalar>(
    crew: &mut Crew,
    a: MatMut<S>,
    rlo: usize,
    rhi: usize,
    clo: usize,
    chi: usize,
    xcol: usize,
    yrow: usize,
) {
    if chi <= clo {
        return;
    }
    crew.parallel_ranges(chi - clo, 8, |cols| {
        ger_update(a, rlo, rhi, clo + cols.start, clo + cols.end, xcol, yrow);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{Mat, Matrix};

    #[test]
    fn iamax_finds_largest_and_breaks_ties_low() {
        let mut a = Matrix::from_rows(5, 1, &[1.0, -3.0, 2.0, 3.0, 0.0]);
        let v = a.view_mut();
        assert_eq!(iamax_col(v, 0, 0, 5), 1); // |-3| first among ties
        assert_eq!(iamax_col(v, 0, 2, 5), 3);
        assert_eq!(iamax_col(v, 0, 4, 5), 4);
    }

    #[test]
    fn iamax_f32() {
        let mut a = Mat::<f32>::from_rows(4, 1, &[1.0, -5.0, 5.0, 2.0]);
        assert_eq!(iamax_col(a.view_mut(), 0, 0, 4), 1);
    }

    #[test]
    fn scal_scales_range_only() {
        let mut a = Matrix::from_rows(4, 1, &[1.0, 2.0, 3.0, 4.0]);
        scal_col(a.view_mut(), 0, 1, 3, 10.0);
        assert_eq!(a.data(), &[1.0, 20.0, 30.0, 4.0]);
    }

    #[test]
    fn ger_matches_manual() {
        // A = 4x4; update rows 1..4, cols 2..4 with x=col0, y=row0.
        let mut a = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let a0 = a.clone();
        ger_update(a.view_mut(), 1, 4, 2, 4, 0, 0);
        for i in 1..4 {
            for j in 2..4 {
                let expect = a0[(i, j)] - a0[(i, 0)] * a0[(0, j)];
                assert_eq!(a[(i, j)], expect);
            }
        }
        // Untouched regions:
        for j in 0..2 {
            for i in 0..4 {
                assert_eq!(a[(i, j)], a0[(i, j)]);
            }
        }
        for j in 2..4 {
            assert_eq!(a[(0, j)], a0[(0, j)]);
        }
    }

    #[test]
    fn ger_par_matches_seq() {
        let mut a1 = Matrix::random(30, 25, 1);
        let mut a2 = a1.clone();
        ger_update(a1.view_mut(), 5, 30, 6, 25, 5, 4);
        let mut crew = Crew::new();
        ger_update_par(&mut crew, a2.view_mut(), 5, 30, 6, 25, 5, 4);
        assert_eq!(a1, a2);
    }
}
