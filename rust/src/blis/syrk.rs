//! SYRK — symmetric rank-`k` update, the trailing-update kernel of the
//! right-looking Cholesky factorization; generic over the sealed
//! [`Scalar`] layer.
//!
//! `C := C + α·A·Aᵀ`, writing only the lower trapezoid of `C` (the strict
//! upper triangle of the leading square is never touched, so a symmetric
//! matrix that stores valid data there keeps it). All of the arithmetic
//! is cast into the malleable [`gemm`]: the update is blocked into
//! [`DB`]-column strips; each strip's rectangular part runs `gemm`
//! directly against an explicitly transposed copy of the strip's rows,
//! and the strip's diagonal square is computed by the *same* `gemm` into
//! a scratch square whose lower triangle is then copied back.
//!
//! Routing every element through `gemm` is what makes the kernel
//! **split-invariant**: per output element the floating-point chain is
//! GEMM's (sequential fused multiply-adds over `p`, one `α·acc` fold per
//! `k_c` block), independent of where the caller's column split or the
//! strip boundaries fall. The look-ahead driver relies on this — its `P`/
//! `R` column split must produce bitwise the same trailing matrix as the
//! blocked driver's full-width update (DESIGN.md §8, §11). Malleability
//! comes along for free: the bulk of the flops inherit GEMM's Loop-3
//! Worker-Sharing entry points — and, since the hybrid-scheduling PR,
//! GEMM's static/dynamic tile-stealing macro-loop
//! ([`BlisParams::steal`], DESIGN.md §13), which is likewise
//! bitwise-invisible here because stealing only moves tile ownership.

use super::gemm::gemm;
use super::params::BlisParams;
use crate::matrix::{Mat, MatMut, MatRef};
use crate::pool::Crew;
use crate::scalar::Scalar;
use crate::trace::{span, Kind};

/// Column-strip width of the blocked SYRK (mirrors the TRSM diagonal
/// block: big enough to amortize the transpose copy, small enough that
/// the scratch square stays cache-resident).
pub const DB: usize = 32;

/// Lower-trapezoid symmetric rank-`k` update.
///
/// `A` is `m × k`; `C` is `m × w` with `w <= m`, its row `i` aligned with
/// `A`'s row `i`. For every column `j < w` and row `i` in `j..m`:
///
/// ```text
/// C[i, j] += alpha · Σ_p A[i, p] · A[j, p]
/// ```
///
/// Entries above the diagonal of the leading `w × w` square are left
/// untouched. With `w == m` this is the classic `syrk` on the lower
/// triangle; the Cholesky drivers also use the trapezoidal form to update
/// a block column (`w < m`). The result is bitwise identical for any crew
/// size *and* for any column split of the same update (see module docs).
pub fn syrk_ln<S: Scalar>(
    crew: &mut Crew,
    params: &BlisParams,
    alpha: S,
    a: MatRef<S>,
    c: MatMut<S>,
) {
    let m = a.rows();
    let k = a.cols();
    let w = c.cols();
    assert_eq!(c.rows(), m, "syrk: C rows must match A rows");
    assert!(w <= m, "syrk: C must be a lower trapezoid (cols <= rows)");
    if m == 0 || w == 0 || k == 0 || alpha == S::ZERO {
        return;
    }
    // Scratch reused by every strip: the transposed strip rows and the
    // diagonal square.
    let jb_max = DB.min(w);
    let mut at = Mat::<S>::zeros(k, jb_max);
    let mut sq = Mat::<S>::zeros(jb_max, jb_max);
    let mut j = 0;
    while j < w {
        let jb = DB.min(w - j);
        // Transposed copy of the strip's rows: Aᵀ[0..k, j..j+jb].
        span(Kind::Pack, "syrk_transpose", || {
            for p in 0..k {
                for jj in 0..jb {
                    at[(p, jj)] = a.at(j + jj, p);
                }
            }
        });
        let at_v = at.view().sub(0, 0, k, jb);
        // Diagonal square via gemm into scratch, lower triangle copied
        // back (the strict upper of C's square is never written).
        let tri = c.sub(j, j, jb, jb);
        span(Kind::Gemm, "syrk_diag", || {
            // Stage the square's lower triangle; the strict upper part of
            // the scratch is written by gemm but never copied back, so
            // whatever it holds (zeros, stale strips) is irrelevant.
            for jj in 0..jb {
                for i in jj..jb {
                    sq[(i, jj)] = tri.at(i, jj);
                }
            }
            gemm(
                crew,
                params,
                alpha,
                a.sub(j, 0, jb, k),
                at_v,
                sq.view_mut().sub(0, 0, jb, jb),
            );
            for jj in 0..jb {
                for i in jj..jb {
                    tri.set(i, jj, sq[(i, jj)]);
                }
            }
        });
        // Rectangle below the square: a plain (malleable) GEMM.
        if j + jb < m {
            gemm(
                crew,
                params,
                alpha,
                a.sub(j + jb, 0, m - j - jb, k),
                at_v,
                c.sub(j + jb, j, m - j - jb, jb),
            );
        }
        j += jb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::Matrix;
    use crate::pool::EntryPolicy;

    /// Naive full-trapezoid reference.
    fn reference(alpha: f64, a: &Matrix, c0: &Matrix, w: usize) -> Matrix {
        let (m, k) = (a.rows(), a.cols());
        let mut c = c0.clone();
        for j in 0..w {
            for i in j..m {
                let mut s = 0.0;
                for p in 0..k {
                    s += a[(i, p)] * a[(j, p)];
                }
                c[(i, j)] += alpha * s;
            }
        }
        c
    }

    #[test]
    fn matches_reference_various_shapes() {
        let params = BlisParams::tiny();
        for &(m, k, w) in &[
            (1usize, 1usize, 1usize),
            (8, 4, 8),
            (40, 12, 40),
            (DB + 7, 5, DB + 7),
            (50, 16, 20),
            (2 * DB + 3, 9, DB + 1),
        ] {
            let a = Matrix::random(m, k, (m * 31 + k * 7 + w) as u64);
            let c0 = Matrix::random(m, w, (m + k + w) as u64);
            let mut c = c0.clone();
            let mut crew = Crew::new();
            syrk_ln(&mut crew, &params, -1.0, a.view(), c.view_mut());
            let want = reference(-1.0, &a, &c0, w);
            let d = c.max_abs_diff(&want);
            assert!(d < 1e-11, "m={m} k={k} w={w} diff={d}");
        }
    }

    #[test]
    fn f32_matches_f64_reference_to_f32_accuracy() {
        use crate::matrix::Mat;
        let params = BlisParams::tiny();
        let (m, k, w) = (DB + 5, 9, DB + 5);
        let a = Matrix::random(m, k, 3);
        let c0 = Matrix::random(m, w, 4);
        let want = reference(-1.0, &a, &c0, w);
        let a32: Mat<f32> = a.convert();
        let mut c32: Mat<f32> = c0.convert();
        let mut crew = Crew::new();
        syrk_ln(&mut crew, &params, -1.0f32, a32.view(), c32.view_mut());
        let d = want.max_abs_diff(&c32.convert());
        let tol = 16.0 * f32::EPSILON as f64 * k as f64;
        assert!(d < tol, "f32 syrk diff {d} tol {tol}");
    }

    #[test]
    fn strict_upper_of_leading_square_untouched() {
        let params = BlisParams::tiny();
        let (m, k) = (30usize, 8usize);
        let a = Matrix::random(m, k, 3);
        let c0 = Matrix::random(m, m, 4);
        let mut c = c0.clone();
        let mut crew = Crew::new();
        syrk_ln(&mut crew, &params, 1.0, a.view(), c.view_mut());
        for j in 0..m {
            for i in 0..j {
                assert_eq!(c[(i, j)], c0[(i, j)], "upper entry ({i},{j}) touched");
            }
        }
    }

    #[test]
    fn column_split_does_not_change_bits() {
        // The look-ahead driver applies one panel's SYRK as two disjoint
        // column ranges; the result must be bitwise identical to the
        // full-width update.
        let params = BlisParams::tiny();
        let (m, k) = (77usize, 11usize);
        let a = Matrix::random(m, k, 21);
        let c0 = Matrix::random(m, m, 22);

        let mut c1 = c0.clone();
        let mut crew = Crew::new();
        syrk_ln(&mut crew, &params, -1.0, a.view(), c1.view_mut());

        for split in [1usize, 7, DB - 1, DB, DB + 5, 40] {
            let mut c2 = c0.clone();
            let v = c2.view_mut();
            // Left block: columns 0..split (trapezoid of the same rows).
            syrk_ln(&mut crew, &params, -1.0, a.view(), v.sub(0, 0, m, split));
            // Right block: columns split..m, rows split..m.
            syrk_ln(
                &mut crew,
                &params,
                -1.0,
                a.view().sub(split, 0, m - split, k),
                v.sub(split, split, m - split, m - split),
            );
            for (x, y) in c1.data().iter().zip(c2.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "split={split}");
            }
        }
    }

    #[test]
    fn crew_size_does_not_change_bits() {
        let params = BlisParams::tiny();
        let a = Matrix::random(70, 13, 9);
        let c0 = Matrix::random(70, 70, 10);

        let mut c1 = c0.clone();
        let mut crew1 = Crew::new();
        syrk_ln(&mut crew1, &params, -1.0, a.view(), c1.view_mut());

        let mut c2 = c0.clone();
        let mut crew2 = Crew::new();
        let shared = crew2.shared();
        let hs: Vec<_> = (0..3)
            .map(|_| {
                let s = std::sync::Arc::clone(&shared);
                std::thread::spawn(move || s.member_loop(EntryPolicy::Immediate))
            })
            .collect();
        syrk_ln(&mut crew2, &params, -1.0, a.view(), c2.view_mut());
        crew2.disband();
        for h in hs {
            h.join().unwrap();
        }
        for (x, y) in c1.data().iter().zip(c2.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn steal_policy_does_not_change_bits() {
        use crate::blis::StealPolicy;
        // Cholesky's trailing update must be schedule-invariant too: the
        // hybrid tile-stealing macro-loop under SYRK's gemm routing
        // yields the same bits as the central ticket, across crews.
        let (m, k) = (70usize, 13usize);
        let a = Matrix::random(m, k, 9);
        let c0 = Matrix::random(m, m, 10);
        let run = |steal: StealPolicy, members: usize| -> Matrix {
            let params = BlisParams::tiny().with_steal(steal);
            let mut c = c0.clone();
            let mut crew = Crew::new();
            let shared = crew.shared();
            let hs: Vec<_> = (0..members)
                .map(|_| {
                    let s = std::sync::Arc::clone(&shared);
                    std::thread::spawn(move || s.member_loop(EntryPolicy::Immediate))
                })
                .collect();
            syrk_ln(&mut crew, &params, -1.0, a.view(), c.view_mut());
            crew.disband();
            for h in hs {
                h.join().unwrap();
            }
            c
        };
        let base = run(StealPolicy::Off, 0);
        for (steal, members) in [
            (StealPolicy::Auto, 0),
            (StealPolicy::Auto, 3),
            (StealPolicy::Fraction(1000), 2),
        ] {
            let c = run(steal, members);
            for (x, y) in base.data().iter().zip(c.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "steal={steal:?} members={members}");
            }
        }
    }

    #[test]
    fn empty_and_zero_alpha_are_noops() {
        let params = BlisParams::tiny();
        let a = Matrix::random(6, 3, 1);
        let c0 = Matrix::random(6, 6, 2);
        let mut c = c0.clone();
        let mut crew = Crew::new();
        syrk_ln(&mut crew, &params, 0.0, a.view(), c.view_mut());
        assert_eq!(c, c0);
        let empty = Matrix::zeros(6, 0);
        syrk_ln(&mut crew, &params, 1.0, empty.view(), c.view_mut());
        assert_eq!(c, c0);
    }
}
