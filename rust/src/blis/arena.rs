//! The crew-owned **packing arena** (DESIGN.md §9).
//!
//! The five-loop GEMM packs `A_c`/`B_c` into contiguous buffers on every
//! call, and a blocked LU calls GEMM hundreds of times — before this
//! arena existed each call paid a heap allocation (and a page-fault walk
//! on first touch) for both buffers. The arena turns that into a lease:
//!
//! - every [`crate::pool::Crew`] carries an `Arc<PackArena>` (a fresh one
//!   by default, or a shared one via [`crate::pool::Crew::with_arena`],
//!   which the look-ahead and serve drivers use so that *all* crews of a
//!   factorization — and all requests of a server — draw from one pool);
//! - [`PackArena::lease`] hands out the smallest free buffer that fits,
//!   allocating only when nothing fits; [`PackArena::give_back`] returns
//!   it. Steady-state factorization therefore performs **zero** packed
//!   buffer allocations after the first (largest) trailing update has
//!   been packed once (proven by `tests/perf_invariants.rs`);
//! - buffers are **64-byte aligned** (cache line / full AVX2 vector) and
//!   **size-classed**: requested capacities are rounded up to 64 KiB
//!   multiples so that the shrinking trailing updates of an LU re-use the
//!   same few buffers instead of fragmenting into per-size allocations.
//!
//! Lease discipline (the rules the BLAS layer follows):
//!
//! 1. a lease is taken at kernel entry and returned before the kernel
//!    returns — buffers never outlive the `gemm` call that leased them;
//! 2. leases are per-thread-of-control: concurrent crews (the look-ahead
//!    PF/RU branches, parallel serve leaders) may share one arena because
//!    lease/give-back are `Mutex`-serialized and each branch holds its
//!    own buffers;
//! 3. a leased buffer's contents are unspecified — the packing routines
//!    overwrite every element they later read (edges are zero-padded
//!    explicitly), so no stale data can leak between problems.

use crate::scalar::Scalar;
use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::ptr::NonNull;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Alignment of every arena buffer: one cache line, which is also two
/// AVX2 `f64x4` vectors.
pub const BUF_ALIGN: usize = 64;

/// Size-class granule in elements (64 KiB of `f64`): lease requests are
/// rounded up to a multiple of this, so nearby capacities share buffers.
pub const CLASS_ELEMS: usize = 8 * 1024;

/// A 64-byte-aligned heap buffer of `f64`, the unit the arena leases.
///
/// Deliberately *not* `Clone`: each buffer has exactly one holder (the
/// arena free list or one kernel invocation).
pub struct AlignedBuf {
    ptr: NonNull<f64>,
    len: usize,
}

// SAFETY: the buffer is an owned heap allocation of plain `f64`; sending
// or sharing it moves/shares ordinary memory. Concurrent &mut access is
// prevented by ownership, same as Vec<f64>.
unsafe impl Send for AlignedBuf {}
unsafe impl Sync for AlignedBuf {}

impl AlignedBuf {
    /// Allocate a zero-initialized buffer of `len` elements, 64-byte
    /// aligned. `len == 0` performs no allocation.
    pub fn zeroed(len: usize) -> Self {
        if len == 0 {
            return Self {
                ptr: NonNull::dangling(),
                len: 0,
            };
        }
        let layout = Self::layout(len);
        // SAFETY: layout has non-zero size (len > 0).
        let raw = unsafe { alloc_zeroed(layout) } as *mut f64;
        let Some(ptr) = NonNull::new(raw) else {
            handle_alloc_error(layout);
        };
        Self { ptr, len }
    }

    fn layout(len: usize) -> Layout {
        Layout::from_size_align(len * std::mem::size_of::<f64>(), BUF_ALIGN)
            .expect("AlignedBuf layout overflow")
    }

    /// Capacity in elements.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the buffer holds zero elements.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read pointer to the first element.
    #[inline]
    pub fn as_ptr(&self) -> *const f64 {
        self.ptr.as_ptr()
    }

    /// Write pointer to the first element.
    #[inline]
    pub fn as_mut_ptr(&mut self) -> *mut f64 {
        self.ptr.as_ptr()
    }

    /// Capacity in elements of scalar type `S` (the buffer's granule is
    /// `f64`, so an `f64` buffer holds twice as many `f32`s — one arena
    /// serves both precisions; see [`f64_granules`]).
    #[inline]
    pub fn len_as<S: Scalar>(&self) -> usize {
        self.len * std::mem::size_of::<f64>() / std::mem::size_of::<S>()
    }

    /// View the buffer as a slice of `S`.
    ///
    /// Sound for the sealed scalar types: both are plain-old-data, the
    /// allocation is 64-byte aligned (≥ any scalar's alignment), and
    /// `len_as` never exceeds the allocation (with `len == 0` the
    /// dangling pointer is used with length 0, which is defined).
    #[inline]
    pub fn as_slice_of<S: Scalar>(&self) -> &[S] {
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr() as *const S, self.len_as::<S>()) }
    }

    /// Mutable typed view (see [`AlignedBuf::as_slice_of`]).
    #[inline]
    pub fn as_mut_slice_of<S: Scalar>(&mut self) -> &mut [S] {
        unsafe {
            std::slice::from_raw_parts_mut(self.ptr.as_ptr() as *mut S, self.len_as::<S>())
        }
    }

    /// Typed write pointer to the first element.
    #[inline]
    pub fn as_mut_ptr_of<S: Scalar>(&mut self) -> *mut S {
        self.ptr.as_ptr() as *mut S
    }
}

/// `f64` granules needed to back `elems` elements of `S` — the unit
/// [`PackArena::lease`] works in, so one size-classed free list serves
/// packed buffers of every precision.
#[inline]
pub fn f64_granules<S: Scalar>(elems: usize) -> usize {
    (elems * std::mem::size_of::<S>()).div_ceil(std::mem::size_of::<f64>())
}

impl std::ops::Deref for AlignedBuf {
    type Target = [f64];
    #[inline]
    fn deref(&self) -> &[f64] {
        // SAFETY: ptr/len describe our own allocation (or are dangling
        // with len == 0, for which from_raw_parts is defined).
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl std::ops::DerefMut for AlignedBuf {
    #[inline]
    fn deref_mut(&mut self) -> &mut [f64] {
        // SAFETY: as Deref, plus &mut self guarantees uniqueness.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        if self.len != 0 {
            // SAFETY: allocated in `zeroed` with the identical layout.
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.len)) };
        }
    }
}

impl std::fmt::Debug for AlignedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AlignedBuf({} elems)", self.len)
    }
}

/// Counters exposed for the zero-allocation steady-state test and for
/// `mlu info`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Buffers ever allocated (the number that must stop growing once a
    /// factorization reaches steady state).
    pub allocations: u64,
    /// Leases served (allocating or not).
    pub leases: u64,
    /// Total bytes currently owned by the arena (free + leased).
    pub bytes_allocated: usize,
    /// Buffers currently parked on the free list.
    pub free_buffers: usize,
}

/// A pool of size-classed [`AlignedBuf`]s (module docs above).
#[derive(Default)]
pub struct PackArena {
    free: Mutex<Vec<AlignedBuf>>,
    allocations: AtomicU64,
    leases: AtomicU64,
    bytes_allocated: AtomicUsize,
}

impl PackArena {
    /// Empty arena (no buffers, zeroed counters).
    pub fn new() -> Self {
        Self::default()
    }

    /// Smallest size class holding at least `elems` elements.
    pub fn class_of(elems: usize) -> usize {
        elems.div_ceil(CLASS_ELEMS).max(1) * CLASS_ELEMS
    }

    /// Lease a buffer of at least `min_elems` elements: the smallest free
    /// buffer that fits, or a freshly allocated one of `class_of(min_elems)`
    /// elements when nothing fits.
    pub fn lease(&self, min_elems: usize) -> AlignedBuf {
        self.leases.fetch_add(1, Ordering::Relaxed);
        {
            let mut free = self.free.lock().unwrap();
            let best = free
                .iter()
                .enumerate()
                .filter(|(_, b)| b.len() >= min_elems)
                .min_by_key(|(_, b)| b.len())
                .map(|(i, _)| i);
            if let Some(i) = best {
                return free.swap_remove(i);
            }
        }
        let class = Self::class_of(min_elems);
        self.allocations.fetch_add(1, Ordering::Relaxed);
        self.bytes_allocated
            .fetch_add(class * std::mem::size_of::<f64>(), Ordering::Relaxed);
        AlignedBuf::zeroed(class)
    }

    /// Return a leased buffer to the free list. Foreign buffers (built
    /// with [`AlignedBuf::zeroed`] directly) are adopted, which is why
    /// `bytes_allocated` only ever counts arena-made allocations.
    pub fn give_back(&self, buf: AlignedBuf) {
        if buf.is_empty() {
            return;
        }
        self.free.lock().unwrap().push(buf);
    }

    /// Snapshot of the arena counters.
    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            allocations: self.allocations.load(Ordering::Relaxed),
            leases: self.leases.load(Ordering::Relaxed),
            bytes_allocated: self.bytes_allocated.load(Ordering::Relaxed),
            free_buffers: self.free.lock().unwrap().len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_are_cache_aligned_and_zeroed() {
        let b = AlignedBuf::zeroed(1000);
        assert_eq!(b.as_ptr() as usize % BUF_ALIGN, 0);
        assert_eq!(b.len(), 1000);
        assert!(b.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn empty_buffer_is_fine() {
        let mut b = AlignedBuf::zeroed(0);
        assert!(b.is_empty());
        assert_eq!(&b[..], &[] as &[f64]);
        assert_eq!(&mut b[..], &mut [] as &mut [f64]);
    }

    #[test]
    fn writes_persist_through_deref() {
        let mut b = AlignedBuf::zeroed(16);
        b[3] = 2.5;
        b[15] = -1.0;
        assert_eq!(b[3], 2.5);
        assert_eq!(b[15], -1.0);
    }

    #[test]
    fn typed_views_share_one_allocation() {
        assert_eq!(f64_granules::<f64>(100), 100);
        assert_eq!(f64_granules::<f32>(100), 50);
        assert_eq!(f64_granules::<f32>(101), 51, "odd f32 counts round up");
        let mut b = AlignedBuf::zeroed(8);
        assert_eq!(b.len_as::<f64>(), 8);
        assert_eq!(b.len_as::<f32>(), 16);
        {
            let s32 = b.as_mut_slice_of::<f32>();
            s32[0] = 1.5;
            s32[15] = -2.0;
        }
        assert_eq!(b.as_slice_of::<f32>()[0], 1.5);
        assert_eq!(b.as_slice_of::<f32>()[15], -2.0);
        // Empty buffers give empty typed views.
        let e = AlignedBuf::zeroed(0);
        assert!(e.as_slice_of::<f32>().is_empty());
    }

    #[test]
    fn size_classes_round_up() {
        assert_eq!(PackArena::class_of(1), CLASS_ELEMS);
        assert_eq!(PackArena::class_of(CLASS_ELEMS), CLASS_ELEMS);
        assert_eq!(PackArena::class_of(CLASS_ELEMS + 1), 2 * CLASS_ELEMS);
        assert_eq!(PackArena::class_of(0), CLASS_ELEMS);
    }

    #[test]
    fn lease_reuses_returned_buffers() {
        let arena = PackArena::new();
        let b1 = arena.lease(100);
        let cap = b1.len();
        arena.give_back(b1);
        // Same class, and anything smaller, re-uses the same buffer.
        for req in [100usize, 50, cap] {
            let b = arena.lease(req);
            assert_eq!(b.len(), cap, "req={req}");
            arena.give_back(b);
        }
        let s = arena.stats();
        assert_eq!(s.allocations, 1, "only the first lease allocates");
        assert_eq!(s.leases, 4);
        assert_eq!(s.free_buffers, 1);
    }

    #[test]
    fn lease_picks_smallest_fitting_buffer() {
        let arena = PackArena::new();
        let small = arena.lease(1); // 1 class
        let big = arena.lease(3 * CLASS_ELEMS); // 3 classes
        let (small_len, big_len) = (small.len(), big.len());
        assert!(big_len > small_len);
        arena.give_back(big);
        arena.give_back(small);
        // A small request must take the small buffer, not waste the big one.
        let got = arena.lease(10);
        assert_eq!(got.len(), small_len);
        // The next big request still finds the big one.
        let got2 = arena.lease(2 * CLASS_ELEMS);
        assert_eq!(got2.len(), big_len);
        arena.give_back(got);
        arena.give_back(got2);
        assert_eq!(arena.stats().allocations, 2);
    }

    #[test]
    fn oversized_request_allocates_anew() {
        let arena = PackArena::new();
        let b = arena.lease(100);
        arena.give_back(b);
        let big = arena.lease(10 * CLASS_ELEMS);
        assert!(big.len() >= 10 * CLASS_ELEMS);
        assert_eq!(arena.stats().allocations, 2);
        arena.give_back(big);
    }

    #[test]
    fn concurrent_leases_are_distinct_buffers() {
        use std::sync::Arc;
        let arena = Arc::new(PackArena::new());
        let hs: Vec<_> = (0..4)
            .map(|t| {
                let a = Arc::clone(&arena);
                std::thread::spawn(move || {
                    for _ in 0..50 {
                        let mut b = a.lease(256);
                        b[0] = t as f64;
                        assert_eq!(b[0], t as f64);
                        a.give_back(b);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let s = arena.stats();
        assert_eq!(s.leases, 200);
        // At most one buffer per concurrently live lease.
        assert!(s.allocations <= 4, "allocations={}", s.allocations);
    }
}
