//! The malleable five-loop GEMM (paper Figs. 1, 2 and 10), generic over
//! the sealed [`Scalar`] layer.
//!
//! `C += alpha · A · B`, blocked exactly as BLIS does, executed by a
//! [`Crew`]. Every Loop-3 iteration publishes two crew jobs — "pack
//! `A_c`" and "run the macro-kernel" — so the team roster is effectively
//! re-read at each `i_c` boundary: this is where threads freed from the
//! panel factorization merge into an in-flight update (Worker Sharing).
//!
//! Within a macro-kernel job, a chunk is one `NR`-column micro-panel of
//! `B_c` (Loop 4, the paper's BLIS configuration) — *subdivided along
//! Loop 5's `i_r` direction whenever Loop 4 alone cannot feed the team*:
//! a wide-and-short trailing update (the shape the look-ahead driver
//! produces once the panel narrows) would otherwise publish fewer chunks
//! than there are workers. Chunks are disjoint `C` tiles and each tile's
//! `k`-reduction stays sequential inside one chunk, so the subdivision
//! cannot perturb the bits. Self-scheduling still adapts the split to
//! however many workers are present, and the WS join point stays at the
//! Loop-3 (`i_c`) job boundary.
//!
//! *How* the chunk grid is distributed is governed by
//! [`BlisParams::steal`] (DESIGN.md §13): the default hybrid
//! static/dynamic schedule gives each crew member a statically owned
//! prefix of the grid (contention-free, locality-stable) plus a shared
//! dynamic tail that idle members — including workers freshly absorbed
//! via WS or re-leased by the serve registry — drain and then steal
//! from other members' slices. `StealPolicy::Off` restores the central
//! ticket. Both schedules execute the identical set of chunks, so
//! results are bitwise equal either way (`tests/steal_agree.rs`).
//!
//! Packed `A_c`/`B_c` buffers are leased from the crew's
//! [`super::arena::PackArena`] (and returned before `gemm` exits), so the
//! steady-state factorization stream performs no heap allocation here —
//! in either precision: the arena's granule is `f64` and an `f32` GEMM
//! views the same size-classed buffers at two elements per granule.

use super::arena::f64_granules;
use super::micro::micro_kernel;
use super::pack::{pack_a, pack_b, PackedA, PackedB};
use super::params::{BlisParams, MR, NR};
use crate::matrix::{MatMut, MatRef};
use crate::pool::Crew;
use crate::scalar::Scalar;
use crate::trace::{span, Kind};

/// `C += alpha · A · B` on the given crew.
///
/// Dimensions: `A` is `m × k`, `B` is `k × n`, `C` is `m × n`.
/// The result is bitwise independent of the crew size (the `k` reduction
/// is never split).
pub fn gemm<S: Scalar>(
    crew: &mut Crew,
    params: &BlisParams,
    alpha: S,
    a: MatRef<S>,
    b: MatRef<S>,
    c: MatMut<S>,
) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(b.rows(), k, "gemm: inner dimensions disagree");
    assert_eq!(c.rows(), m, "gemm: C row count");
    assert_eq!(c.cols(), n, "gemm: C column count");
    if m == 0 || n == 0 || k == 0 || alpha == S::ZERO {
        return;
    }

    // Size the packed buffers to the *actual* problem (bounded by the
    // cache-block capacities): a small GEMM must not pay for an
    // nc=4096-column buffer it never uses (§Perf). The buffers are
    // leased from the crew's arena — zero allocations in steady state —
    // and handed back below before returning.
    let arena = std::sync::Arc::clone(crew.arena());
    let mut pa: PackedA<S> = PackedA::from_buf(arena.lease(f64_granules::<S>(
        PackedA::<S>::required_elems(
            params.mc.min(crate::util::round_up(m, MR)),
            params.kc.min(k),
        ),
    )));
    let mut pb: PackedB<S> = PackedB::from_buf(arena.lease(f64_granules::<S>(
        PackedB::<S>::required_elems(
            params.kc.min(k),
            params.nc.min(crate::util::round_up(n, NR)),
        ),
    )));

    // Loop 1: columns of C/B in blocks of n_c.
    let mut jc = 0;
    while jc < n {
        let nc_eff = params.nc.min(n - jc);
        // Loop 2: the k dimension in blocks of k_c (sequential: this is
        // the reduction dimension — splitting it would break determinism).
        let mut pc = 0;
        while pc < k {
            let kc_eff = params.kc.min(k - pc);
            span(Kind::Pack, "pack_b", || {
                pack_b(crew, b.sub(pc, jc, kc_eff, nc_eff), &mut pb);
            });
            // Loop 3: rows of C/A in blocks of m_c. ENTRY POINT: each
            // iteration publishes fresh crew jobs, so joiners take effect
            // here (paper Fig. 10).
            let mut ic = 0;
            while ic < m {
                let mc_eff = params.mc.min(m - ic);
                span(Kind::Pack, "pack_a", || {
                    pack_a(crew, a.sub(ic, pc, mc_eff, kc_eff), &mut pa);
                });
                macro_kernel(crew, params, alpha, &pa, &pb, c.sub(ic, jc, mc_eff, nc_eff));
                ic += mc_eff;
            }
            pc += kc_eff;
        }
        jc += nc_eff;
    }

    arena.give_back(pa.into_buf());
    arena.give_back(pb.into_buf());
}

/// Loops 4+5: sweep the packed `B_c` micro-panels (Loop 4, parallelized)
/// against the packed `A_c` micro-panels (Loop 5, split into blocks when
/// Loop 4 alone has fewer chunks than the team wants — see module docs).
///
/// The tile grid is scheduled by `params.steal` (DESIGN.md §13): under
/// the hybrid policy each current crew member owns a static prefix of
/// the `(j_r, i_r)` grid and the tail is stolen dynamically; under
/// [`crate::blis::StealPolicy::Off`] every chunk is claimed from the
/// central ticket. Either way each chunk is a disjoint set of `C` tiles
/// with sequential `k`-reductions, so the schedule cannot perturb bits.
fn macro_kernel<S: Scalar>(
    crew: &mut Crew,
    params: &BlisParams,
    alpha: S,
    pa: &PackedA<S>,
    pb: &PackedB<S>,
    c: MatMut<S>,
) {
    let (m, n) = (c.rows(), c.cols());
    debug_assert_eq!(pa.m, m);
    debug_assert_eq!(pb.n, n);
    debug_assert_eq!(pa.k, pb.k);
    let kc = pa.k;
    let n_jr = pb.n_panels();
    let n_ir = pa.n_panels();

    // Oversplit to ~4 chunks per current worker so self-scheduling can
    // absorb mid-job joiners; only subdivide Loop 5 when Loop 4 is too
    // narrow, and never below one micro-panel row per chunk.
    let target = 4 * (crew.members() + 1);
    let ir_splits = if n_jr >= target {
        1
    } else {
        target.div_ceil(n_jr).min(n_ir)
    };
    let ir_block = n_ir.div_ceil(ir_splits);
    let n_ib = n_ir.div_ceil(ir_block);

    crew.parallel_steal(n_jr * n_ib, params.steal, |chunk| {
        let jr = chunk / n_ib;
        let ib = chunk % n_ib;
        let j0 = jr * NR;
        let n_eff = NR.min(n - j0);
        let b_panel = pb.panel(jr);
        // Loop 5 over this chunk's block of macro-block rows.
        for ir in ib * ir_block..((ib + 1) * ir_block).min(n_ir) {
            let i0 = ir * MR;
            let m_eff = MR.min(m - i0);
            micro_kernel(
                kc,
                alpha,
                pa.panel(ir),
                b_panel,
                c.sub(i0, j0, m_eff, n_eff),
                m_eff,
                n_eff,
            );
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{naive, Mat, Matrix};
    use crate::pool::EntryPolicy;
    use crate::util::quickcheck_lite::{forall_res, Gen};

    fn check(m: usize, n: usize, k: usize, alpha: f64, params: &BlisParams, seed: u64) {
        let a = Matrix::random(m, k, seed);
        let b = Matrix::random(k, n, seed + 1);
        let mut c = Matrix::random(m, n, seed + 2);
        let mut c_ref = c.clone();
        let mut crew = Crew::new();
        gemm(&mut crew, params, alpha, a.view(), b.view(), c.view_mut());
        naive::gemm(alpha, a.view(), b.view(), c_ref.view_mut());
        let d = c.max_abs_diff(&c_ref);
        let scale = (k as f64).max(1.0);
        assert!(d < 1e-12 * scale, "m={m} n={n} k={k} alpha={alpha} diff={d}");
    }

    #[test]
    fn matches_naive_across_shapes() {
        let tiny = BlisParams::tiny();
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (MR, NR, 8),
            (MR - 1, NR - 1, 3),
            (MR + 1, NR + 1, 9),
            (2 * MR + 3, 3 * NR + 1, 17),
            (40, 40, 40),
            (5, 64, 2),
            (64, 5, 33),
        ] {
            check(m, n, k, 1.0, &tiny, (m * 10000 + n * 100 + k) as u64);
            check(m, n, k, -1.0, &tiny, (m * 10000 + n * 100 + k) as u64);
        }
    }

    #[test]
    fn matches_naive_with_default_params() {
        check(150, 130, 70, 1.0, &BlisParams::default(), 99);
        check(97, 301, 256 + 5, -1.0, &BlisParams::default(), 98);
    }

    #[test]
    fn f32_matches_naive_across_shapes() {
        let tiny = BlisParams::tiny();
        let mut crew = Crew::new();
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (MR, NR, 8),
            (MR + 1, NR + 1, 9),
            (2 * MR + 3, 3 * NR + 1, 17),
            (64, 5, 33),
        ] {
            let seed = (m * 1000 + n * 10 + k) as u64;
            let a = Mat::<f32>::random(m, k, seed);
            let b = Mat::<f32>::random(k, n, seed + 1);
            let mut c = Mat::<f32>::random(m, n, seed + 2);
            let mut c_ref = c.clone();
            gemm(&mut crew, &tiny, -1.0f32, a.view(), b.view(), c.view_mut());
            naive::gemm(-1.0f32, a.view(), b.view(), c_ref.view_mut());
            let d = c.max_abs_diff(&c_ref);
            let tol = 8.0 * f32::EPSILON as f64 * (k as f64).max(1.0);
            assert!(d < tol, "f32 m={m} n={n} k={k} diff={d} tol={tol}");
        }
    }

    #[test]
    fn empty_dims_are_noops() {
        let params = BlisParams::tiny();
        let mut crew = Crew::new();
        let a = Matrix::zeros(0, 0);
        let b = Matrix::zeros(0, 5);
        let mut c = Matrix::zeros(0, 5);
        gemm(&mut crew, &params, 1.0, a.view(), b.view(), c.view_mut());
        // alpha == 0 early-out leaves C untouched:
        let a = Matrix::random(3, 3, 1);
        let b = Matrix::random(3, 3, 2);
        let mut c = Matrix::random(3, 3, 3);
        let before = c.clone();
        gemm(&mut crew, &params, 0.0, a.view(), b.view(), c.view_mut());
        assert_eq!(c, before);
    }

    #[test]
    fn operates_on_subviews() {
        // C embedded in a larger matrix; only the target block changes.
        let params = BlisParams::tiny();
        let mut crew = Crew::new();
        let a = Matrix::random(12, 7, 11);
        let b = Matrix::random(7, 9, 12);
        let mut big = Matrix::from_fn(20, 20, |_, _| 1.25);
        let mut big_ref = big.clone();
        gemm(
            &mut crew,
            &params,
            1.0,
            a.view(),
            b.view(),
            big.view_mut().sub(4, 6, 12, 9),
        );
        naive::gemm(1.0, a.view(), b.view(), big_ref.view_mut().sub(4, 6, 12, 9));
        assert!(big.max_abs_diff(&big_ref) < 1e-12);
        assert_eq!(big[(0, 0)], 1.25);
        assert_eq!(big[(19, 19)], 1.25);
        assert_eq!(big[(3, 6)], 1.25);
    }

    #[test]
    fn bitwise_identical_with_and_without_members() {
        // The determinism invariant that makes WS safe (DESIGN.md §8).
        let a = Matrix::random(67, 45, 21);
        let b = Matrix::random(45, 53, 22);
        let params = BlisParams::tiny();

        let mut c1 = Matrix::zeros(67, 53);
        let mut crew1 = Crew::new();
        gemm(&mut crew1, &params, 1.0, a.view(), b.view(), c1.view_mut());

        let mut c2 = Matrix::zeros(67, 53);
        let mut crew2 = Crew::new();
        let shared = crew2.shared();
        let hs: Vec<_> = (0..3)
            .map(|i| {
                let s = std::sync::Arc::clone(&shared);
                std::thread::spawn(move || {
                    s.member_loop(if i == 0 {
                        EntryPolicy::JobBoundary
                    } else {
                        EntryPolicy::Immediate
                    })
                })
            })
            .collect();
        gemm(&mut crew2, &params, 1.0, a.view(), b.view(), c2.view_mut());
        crew2.disband();
        for h in hs {
            h.join().unwrap();
        }

        assert_eq!(c1.data().len(), c2.data().len());
        for (x, y) in c1.data().iter().zip(c2.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "bitwise mismatch");
        }
    }

    #[test]
    fn f32_bitwise_identical_with_and_without_members() {
        // Crew-size determinism holds per precision (DESIGN.md §12).
        let a = Mat::<f32>::random(67, 45, 21);
        let b = Mat::<f32>::random(45, 53, 22);
        let params = BlisParams::tiny();

        let mut c1 = Mat::<f32>::zeros(67, 53);
        let mut crew1 = Crew::new();
        gemm(&mut crew1, &params, 1.0f32, a.view(), b.view(), c1.view_mut());

        let mut c2 = Mat::<f32>::zeros(67, 53);
        let mut crew2 = Crew::new();
        let shared = crew2.shared();
        let hs: Vec<_> = (0..3)
            .map(|_| {
                let s = std::sync::Arc::clone(&shared);
                std::thread::spawn(move || s.member_loop(EntryPolicy::Immediate))
            })
            .collect();
        gemm(&mut crew2, &params, 1.0f32, a.view(), b.view(), c2.view_mut());
        crew2.disband();
        for h in hs {
            h.join().unwrap();
        }
        for (x, y) in c1.data().iter().zip(c2.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "f32 bitwise mismatch");
        }
    }

    #[test]
    fn wide_and_short_shapes_use_loop5_splitting() {
        // Shapes where Loop 4 alone yields fewer chunks than the team
        // wants (n_jr small, n_ir large) — the look-ahead trailing-update
        // shape this PR's macro-kernel chunking exists for.
        let params = BlisParams::default();
        for &(m, n, k) in &[(300usize, 5usize, 40usize), (257, NR, 13), (512, 1, 7)] {
            check(m, n, k, -1.0, &params, (m + n + k) as u64);
        }
    }

    #[test]
    fn steal_on_and_off_are_bitwise_identical() {
        use crate::blis::StealPolicy;
        // The tentpole invariant at the GEMM level: the hybrid
        // static/dynamic schedule moves tile ownership, never tile
        // content, so every steal policy produces the same bits — with
        // and without members, in the wide-and-short shapes where the
        // static slices actually matter.
        for &(m, n, k) in &[(150usize, 9usize, 33usize), (67, 53, 45)] {
            let a = Matrix::random(m, k, 81);
            let b = Matrix::random(k, n, 82);
            let run = |steal: StealPolicy, members: usize| -> Matrix {
                let params = BlisParams::tiny().with_steal(steal);
                let mut c = Matrix::random(m, n, 83);
                let mut crew = Crew::new();
                let shared = crew.shared();
                let hs: Vec<_> = (0..members)
                    .map(|_| {
                        let s = std::sync::Arc::clone(&shared);
                        std::thread::spawn(move || s.member_loop(EntryPolicy::Immediate))
                    })
                    .collect();
                gemm(&mut crew, &params, -1.0, a.view(), b.view(), c.view_mut());
                crew.disband();
                for h in hs {
                    h.join().unwrap();
                }
                c
            };
            let base = run(StealPolicy::Off, 0);
            for members in [0usize, 3] {
                for steal in [
                    StealPolicy::Off,
                    StealPolicy::Auto,
                    StealPolicy::Fraction(1000),
                    StealPolicy::Fraction(200),
                ] {
                    let c = run(steal, members);
                    for (x, y) in base.data().iter().zip(c.data()) {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "m={m} n={n} k={k} steal={steal:?} members={members}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn steady_state_gemm_leases_do_not_allocate() {
        // Two identical GEMMs on one crew: the second must be served
        // entirely from the arena free list.
        let params = BlisParams::tiny();
        let mut crew = Crew::new();
        let a = Matrix::random(60, 30, 1);
        let b = Matrix::random(30, 50, 2);
        let mut c = Matrix::zeros(60, 50);
        gemm(&mut crew, &params, 1.0, a.view(), b.view(), c.view_mut());
        let after_first = crew.arena().stats();
        assert!(after_first.allocations >= 2, "A and B buffers were leased");
        gemm(&mut crew, &params, 1.0, a.view(), b.view(), c.view_mut());
        let after_second = crew.arena().stats();
        assert_eq!(
            after_first.allocations, after_second.allocations,
            "warm gemm allocated"
        );
        assert_eq!(after_second.free_buffers, after_first.free_buffers);
    }

    #[test]
    fn mixed_precision_stream_shares_one_arena() {
        // An f32 GEMM after a same-shape f64 warm-up must lease from the
        // same size-classed free list without allocating anew.
        let params = BlisParams::tiny();
        let mut crew = Crew::new();
        let a = Matrix::random(60, 30, 1);
        let b = Matrix::random(30, 50, 2);
        let mut c = Matrix::zeros(60, 50);
        gemm(&mut crew, &params, 1.0, a.view(), b.view(), c.view_mut());
        let warm = crew.arena().stats();
        let a32: Mat<f32> = a.convert();
        let b32: Mat<f32> = b.convert();
        let mut c32 = Mat::<f32>::zeros(60, 50);
        gemm(
            &mut crew,
            &params,
            1.0f32,
            a32.view(),
            b32.view(),
            c32.view_mut(),
        );
        let after = crew.arena().stats();
        assert_eq!(
            warm.allocations, after.allocations,
            "f32 gemm allocated despite warm f64 arena"
        );
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn simd_and_portable_gemm_are_bitwise_identical() {
        use crate::blis::micro::{set_kernel, simd_available, Kernel};
        if !simd_available() {
            eprintln!("skipping: host has no AVX2+FMA");
            return;
        }
        let _g = crate::blis::micro::KERNEL_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let a = Matrix::random(67, 45, 31);
        let b = Matrix::random(45, 53, 32);
        let params = BlisParams::tiny();
        let run = |kernel: Kernel| {
            set_kernel(kernel);
            let mut c = Matrix::random(67, 53, 33);
            let mut crew = Crew::new();
            gemm(&mut crew, &params, -1.0, a.view(), b.view(), c.view_mut());
            set_kernel(Kernel::Auto);
            c
        };
        let c_simd = run(Kernel::Simd);
        let c_port = run(Kernel::Portable);
        for (x, y) in c_simd.data().iter().zip(c_port.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "bitwise mismatch");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn simd_and_portable_gemm_are_bitwise_identical_f32() {
        use crate::blis::micro::{set_kernel, simd_available, Kernel};
        if !simd_available() {
            eprintln!("skipping: host has no AVX2+FMA");
            return;
        }
        let _g = crate::blis::micro::KERNEL_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let a = Mat::<f32>::random(67, 45, 31);
        let b = Mat::<f32>::random(45, 53, 32);
        let params = BlisParams::tiny();
        let run = |kernel: Kernel| {
            set_kernel(kernel);
            let mut c = Mat::<f32>::random(67, 53, 33);
            let mut crew = Crew::new();
            gemm(&mut crew, &params, -1.0f32, a.view(), b.view(), c.view_mut());
            set_kernel(Kernel::Auto);
            c
        };
        let c_simd = run(Kernel::Simd);
        let c_port = run(Kernel::Portable);
        for (x, y) in c_simd.data().iter().zip(c_port.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "f32 bitwise mismatch");
        }
    }

    #[test]
    fn property_random_shapes_match_naive() {
        forall_res("gemm == naive gemm", 25, |g: &mut Gen| {
            let m = g.usize_in(1, 70);
            let n = g.usize_in(1, 70);
            let k = g.usize_in(1, 40);
            let alpha = g.choose(&[1.0, -1.0, 0.5]);
            let seed = g.seed();
            g.label(format!("m={m} n={n} k={k} alpha={alpha}"));
            let params = if g.bool_with(0.5) {
                BlisParams::tiny()
            } else {
                BlisParams::default()
            };
            let a = Matrix::random(m, k, seed);
            let b = Matrix::random(k, n, seed ^ 1);
            let mut c = Matrix::random(m, n, seed ^ 2);
            let mut c_ref = c.clone();
            let mut crew = Crew::new();
            gemm(&mut crew, &params, alpha, a.view(), b.view(), c.view_mut());
            naive::gemm(alpha, a.view(), b.view(), c_ref.view_mut());
            let d = c.max_abs_diff(&c_ref);
            if d > 1e-12 * k as f64 {
                return Err(format!("diff {d}"));
            }
            Ok(())
        });
    }
}
