//! Typed numerical-failure taxonomy for the factorization family
//! (DESIGN.md §15).
//!
//! The paper's Early-Termination mechanism is a *controlled-failure*
//! protocol: one branch tells another to abandon work cleanly. This
//! module extends the same discipline to genuine failures — a singular
//! pivot, a NaN in the input, a panicking worker — so that every layer
//! above the drivers (solve, serve, the wire protocol) can distinguish
//! "your matrix is the problem" from "the daemon is the problem"
//! instead of dividing by zero or returning garbage bytes.

use std::fmt;

/// A typed numerical (or supervision) failure of a factorization or
/// solve. Carried by [`super::FactorOutcome::error`], threaded through
/// the fallible naive oracles ([`crate::matrix::naive::try_lu`] et al.)
/// and, for the serve stack, serialized into the wire protocol's
/// `FAILED` frame ([`crate::serve::proto::encode_failed`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FactorError {
    /// An exactly-zero pivot (LU) or zero Cholesky diagonal was
    /// committed at column `col`: the matrix is exactly singular in the
    /// working precision. LAPACK-`info` semantics: LU still completes
    /// the factorization (the zero pivot's column is skipped), so the
    /// partial factors are valid — only a subsequent solve would divide
    /// by zero.
    ExactlySingular {
        /// First column whose pivot/diagonal is exactly zero.
        col: usize,
    },
    /// A non-finite value (NaN or ±∞) was found — in the input before
    /// the factorization started, or on the committed diagonal after an
    /// overflow mid-run.
    NonFinite {
        /// Column-major offset (`j * rows + i`) of the first offending
        /// entry.
        first_offset: usize,
    },
    /// The request asked for something this kind cannot do (e.g. a
    /// Cholesky factorization of a matrix that is not positive
    /// definite).
    Unsupported(
        /// Human-readable description of the unsupported condition.
        String,
    ),
    /// A daemon-side fault: a worker panicked and poisoned the crew, a
    /// leader panicked mid-request, or the supervision layer cancelled
    /// a wedged computation. Never the client's fault.
    Internal(
        /// Human-readable description (panic message or watchdog note).
        String,
    ),
}

impl FactorError {
    /// Stable wire code of this error's category (the first payload
    /// byte of a `FAILED` frame; see DESIGN.md §14.3 and §15.1).
    pub fn wire_code(&self) -> u8 {
        match self {
            FactorError::ExactlySingular { .. } => 1,
            FactorError::NonFinite { .. } => 2,
            FactorError::Unsupported(_) => 3,
            FactorError::Internal(_) => 4,
        }
    }

    /// The numeric detail the wire frame carries alongside the code:
    /// the offending column / offset, or 0 for the string-only kinds.
    pub fn wire_detail(&self) -> u64 {
        match self {
            FactorError::ExactlySingular { col } => *col as u64,
            FactorError::NonFinite { first_offset } => *first_offset as u64,
            _ => 0,
        }
    }

    /// Whether this failure was caused by the daemon rather than the
    /// request (clients may report it as a server fault, not retry with
    /// the same matrix and expect a different answer).
    pub fn is_internal(&self) -> bool {
        matches!(self, FactorError::Internal(_))
    }
}

impl fmt::Display for FactorError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FactorError::ExactlySingular { col } => {
                write!(f, "matrix is exactly singular (zero pivot at column {col})")
            }
            FactorError::NonFinite { first_offset } => {
                write!(f, "non-finite value (first at column-major offset {first_offset})")
            }
            FactorError::Unsupported(msg) => write!(f, "unsupported: {msg}"),
            FactorError::Internal(msg) => write!(f, "internal fault: {msg}"),
        }
    }
}

impl std::error::Error for FactorError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_codes_are_stable() {
        assert_eq!(FactorError::ExactlySingular { col: 3 }.wire_code(), 1);
        assert_eq!(FactorError::NonFinite { first_offset: 9 }.wire_code(), 2);
        assert_eq!(FactorError::Unsupported("x".into()).wire_code(), 3);
        assert_eq!(FactorError::Internal("y".into()).wire_code(), 4);
    }

    #[test]
    fn details_carry_the_location() {
        assert_eq!(FactorError::ExactlySingular { col: 3 }.wire_detail(), 3);
        assert_eq!(FactorError::NonFinite { first_offset: 9 }.wire_detail(), 9);
        assert_eq!(FactorError::Internal("y".into()).wire_detail(), 0);
        assert!(FactorError::Internal("y".into()).is_internal());
        assert!(!FactorError::ExactlySingular { col: 0 }.is_internal());
    }

    #[test]
    fn display_names_the_failure() {
        let s = FactorError::ExactlySingular { col: 7 }.to_string();
        assert!(s.contains("singular") && s.contains('7'), "{s}");
        let s = FactorError::Internal("worker panicked".into()).to_string();
        assert!(s.contains("internal") && s.contains("worker panicked"), "{s}");
    }
}
