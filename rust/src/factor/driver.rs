//! The generic factorization drivers: one blocked right-looking driver
//! with request-level checkpoints, and **one** look-ahead driver carrying
//! the paper's Worker-Sharing and Early-Termination mechanisms — shared
//! by every [`Factorization`] kind (LU, Cholesky, QR). There are no
//! per-kind copies of the scheduling machinery; a kind only supplies its
//! panel and trailing-update kernels through the trait.
//!
//! Per look-ahead iteration the trailing submatrix is split column-wise
//! into `P` (the *next* panel, width `b_n`) and `R` (the remainder):
//!
//! ```text
//!        f      f+bc     f+bc+bn          n
//!        |  cur  |    P    |       R      |
//! ```
//!
//! Team `T_PF` (pool workers `0..t_pf`, worker 0 leading) applies the
//! current panel's transformation to `P` and factorizes it. Team `T_RU`
//! (the calling thread leading workers `t_pf..`) applies it to `R` —
//! concurrently, since the branches touch disjoint columns.
//!
//! - **WS** (`malleable`): when `T_PF` finishes first, its workers enlist
//!   into `T_RU`'s crew and join the in-flight trailing update at the
//!   next Loop-3 entry point. When `R` is empty (tail of the
//!   factorization) the *reverse* sharing happens: `T_RU` enlists into
//!   `T_PF`'s crew.
//! - **ET** (`early_term`): when `T_RU` finishes first it raises
//!   `ru_done`; the left-looking inner panel polls the flag after each
//!   `b_i` block and aborts, returning `k_done < b_n`. The next
//!   iteration's "current panel" is then only `k_done` wide — the block
//!   size self-adjusts (paper §4.2, §5.3).
//!
//! The ET flag is a plain `AtomicBool` with one writer and one reader —
//! the paper's race-free synchronization — and the factors produced are
//! identical (to roundoff) to the plain blocked algorithm for any flag
//! timing, because the left-looking panels leave aborted columns
//! untouched (the per-kind ET contract, DESIGN.md §11).

use super::{FactorCtl, FactorError, FactorKind, Factorization, LaCtl, LaOpts, LaStats, PanelStep};
use crate::blis::{BlisParams, PackArena};
use crate::matrix::{Mat, MatMut};
use crate::pool::{Crew, Pool};
use crate::scalar::Scalar;
use crate::trace::{span, Kind};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Column-major scan for the first non-finite entry (NaN or ±∞) of `a`;
/// returns its offset `j * rows + i`. Both drivers run this before
/// touching the matrix so a poisoned input yields a typed
/// [`FactorError::NonFinite`] instead of NaN-filled factors.
pub(crate) fn first_non_finite<S: Scalar>(a: &MatMut<S>) -> Option<usize> {
    let (m, n) = (a.rows(), a.cols());
    for j in 0..n {
        for i in 0..m {
            if !a.at(i, j).is_finite() {
                return Some(j * m + i);
            }
        }
    }
    None
}

/// Inspect the diagonal of a freshly factorized panel (columns
/// `f..f+bc`) for the kind-specific failure conditions (DESIGN.md §15.2).
/// Returns the error plus whether it is *fatal*: LU treats an
/// exactly-zero pivot with LAPACK-`info` semantics (record the column,
/// keep factoring — the factors stay valid, only a solve would divide by
/// zero) and QR does the same for a zero `R` diagonal (rank deficiency);
/// a Cholesky breakdown or a non-finite diagonal ends the run after this
/// panel's commit.
pub(crate) fn panel_health<S: Scalar>(
    kind: FactorKind,
    a: &MatMut<S>,
    f: usize,
    bc: usize,
) -> Option<(FactorError, bool)> {
    let m = a.rows();
    for j in f..f + bc {
        let d = a.at(j, j);
        if !d.is_finite() {
            // A Cholesky panel goes non-finite exactly when the input
            // was not positive definite (sqrt of a negative leading
            // minor): report the cause, not the symptom.
            let e = match kind {
                FactorKind::Chol => {
                    FactorError::Unsupported(format!(
                        "matrix is not positive definite (breakdown at column {j})"
                    ))
                }
                _ => FactorError::NonFinite {
                    first_offset: j * m + j,
                },
            };
            return Some((e, true));
        }
        if d == S::ZERO {
            return Some((
                FactorError::ExactlySingular { col: j },
                kind == FactorKind::Chol,
            ));
        }
    }
    None
}

/// Record the first error seen; any fatal condition stops the run even
/// if a non-fatal error (LU's zero pivot) was recorded earlier.
fn note(err: &mut Option<FactorError>, fatal: &mut bool, e: FactorError, is_fatal: bool) {
    if err.is_none() {
        *err = Some(e);
    }
    *fatal |= is_fatal;
}

/// Fold a crew's poison state (a member panicked inside a chunk) into
/// the run's error as a fatal [`FactorError::Internal`].
fn note_poison(err: &mut Option<FactorError>, fatal: &mut bool, msg: Option<String>) {
    if let Some(msg) = msg {
        note(
            err,
            fatal,
            FactorError::Internal(format!("crew poisoned: {msg}")),
            true,
        );
    }
}

/// Blocked right-looking factorization with cooperative checkpoints
/// between panel steps (the serve layer's per-request driver).
///
/// Returns the accumulated kind output, the committed column count,
/// whether a cancel flag cut the run short, and the first typed
/// numerical or supervision failure detected (see [`panel_health`] for
/// which errors stop the run and which are recorded LAPACK-`info`
/// style). After `cols_done` committed columns the matrix holds a
/// consistent partial factorization: columns `0..cols_done` carry their
/// final factor entries and the trailing block is fully updated.
pub fn blocked_ctl<S: Scalar, F: Factorization<S>>(
    fk: &F,
    crew: &mut Crew,
    params: &BlisParams,
    a: MatMut<S>,
    bo: usize,
    bi: usize,
    ctl: &FactorCtl,
) -> (F::Acc, usize, bool, Option<FactorError>) {
    let (m, n) = (a.rows(), a.cols());
    let kmax = m.min(n);
    let bo = bo.max(1);
    let mut acc = F::Acc::default();
    let mut cancelled = false;
    let mut error: Option<FactorError> = None;
    let mut fatal = false;
    if let Some(off) = first_non_finite(&a) {
        return (acc, 0, false, Some(FactorError::NonFinite { first_offset: off }));
    }
    let mut k = 0;
    while k < kmax {
        if let Some(c) = ctl.cancel {
            if c.load(Ordering::Acquire) {
                cancelled = true;
                break;
            }
        }
        let b = bo.min(kmax - k);
        let plabel = match ctl.tag {
            None => String::from("panel"),
            Some(tag) => format!("{tag}.panel[{k}]"),
        };
        let st = span(Kind::Panel, &plabel, || {
            fk.panel(crew, params, a, k, b, bi, false, None)
        });
        debug_assert_eq!(st.k_done, b);
        fk.apply_left(crew, params, a, k, b, &st.state);
        if n > k + b {
            let ulabel = match ctl.tag {
                None => String::from("update"),
                Some(tag) => format!("{tag}.update[{k}]"),
            };
            span(Kind::Gemm, &ulabel, || {
                fk.apply(crew, params, a, k, b, &st.state, k + b, n);
            });
        }
        fk.commit(&mut acc, &st.state, st.k_done);
        k += b;
        if let Some((e, is_fatal)) = panel_health(fk.kind(), &a, k - b, b) {
            note(&mut error, &mut fatal, e, is_fatal);
        }
        if crew.is_poisoned() {
            note_poison(&mut error, &mut fatal, crew.poison_message());
        }
        if let Some(cb) = ctl.on_checkpoint {
            cb(k);
        }
        if fatal {
            break;
        }
    }
    (acc, k, cancelled, error)
}

/// The generic look-ahead driver with Worker Sharing and Early
/// Termination (module docs above) and a cooperative cancellation
/// checkpoint between outer panel steps (see [`LaCtl`]).
///
/// The third element of the return value is the first typed failure
/// detected, with the same semantics as [`blocked_ctl`]: non-fatal
/// errors (LU/QR exact singularity) are recorded while the run
/// completes; fatal ones (Cholesky breakdown, mid-run overflow, a
/// panicked crew member or panel branch) commit the current panel and
/// stop, leaving the same clean factored prefix a request-level cancel
/// would.
#[allow(clippy::too_many_arguments)]
pub fn lookahead_ctl<S: Scalar, F: Factorization<S>>(
    fk: &F,
    pool: &Pool,
    params: &BlisParams,
    a: &mut Mat<S>,
    bo: usize,
    bi: usize,
    opts: &LaOpts,
    ctl: Option<&LaCtl>,
) -> (F::Acc, LaStats, Option<FactorError>) {
    let av = a.view_mut();
    let (m, n) = (av.rows(), av.cols());
    let kmax = m.min(n);
    let bo = bo.max(1).min(kmax.max(1));
    let mut stats = LaStats::default();
    let mut acc = F::Acc::default();
    let mut committed = 0usize;
    let mut error: Option<FactorError> = None;
    let mut fatal = false;
    if kmax == 0 {
        return (acc, stats, None);
    }
    if let Some(off) = first_non_finite(&av) {
        return (
            acc,
            stats,
            Some(FactorError::NonFinite { first_offset: off }),
        );
    }
    // One packing arena for every crew this factorization creates (the
    // per-iteration PF/RU crews, prologue, epilogue): packed-buffer
    // leases reach steady state after the first trailing update and
    // allocate nothing thereafter (DESIGN.md §9).
    let arena = Arc::new(PackArena::new());
    if pool.workers() == 0 {
        // A single thread cannot run two branches: degrade to the plain
        // blocked RL algorithm (same factorization, no TP).
        let mut crew = Crew::with_arena(Arc::clone(&arena));
        let fctl = FactorCtl {
            cancel: ctl.map(|c| &c.cancel),
            ..Default::default()
        };
        let (out, cols_done, cancelled, err) =
            blocked_ctl(fk, &mut crew, params, av, bo, bi, &fctl);
        stats.cancelled = cancelled;
        stats.panel_widths = vec![bo.min(kmax); cols_done.div_ceil(bo.max(1))];
        let cs = crew.stats();
        stats.hybrid_tiles = cs.hybrid_tiles;
        stats.stolen_tiles = cs.stolen_tiles;
        if let Some(c) = ctl {
            c.cols_done.store(cols_done, Ordering::Release);
        }
        return (out, stats, err);
    }
    let t_pf = opts.t_pf.max(1).min(pool.workers());

    // ---- Prologue: factorize the first panel with the full team. ----
    let b0 = bo.min(kmax);
    let mut crew_all = Crew::with_arena(Arc::clone(&arena));
    let all_members: Vec<_> = (0..pool.workers())
        .map(|w| {
            let s = crew_all.shared();
            let e = opts.entry;
            pool.submit(w, move || s.member_loop(e))
        })
        .collect();
    let first = span(Kind::Panel, "panel[0]", || {
        fk.panel(&mut crew_all, params, av, 0, b0, bi, false, None)
    });
    crew_all.disband();
    for h in all_members {
        h.wait();
    }
    let cs = crew_all.stats();
    stats.hybrid_tiles += cs.hybrid_tiles;
    stats.stolen_tiles += cs.stolen_tiles;
    if crew_all.is_poisoned() {
        note_poison(&mut error, &mut fatal, crew_all.poison_message());
    }
    if let Some((e, is_fatal)) = panel_health(fk.kind(), &av, 0, first.k_done) {
        note(&mut error, &mut fatal, e, is_fatal);
    }

    // `cur`: the factorized-but-not-yet-applied panel [f, f+bc). Its
    // state is shared read-only between the PF and RU branches.
    let mut f = 0usize;
    let mut bc = first.k_done;
    let mut st_cur: Arc<F::State> = Arc::new(first.state);
    // ET's adaptive block size (paper §4.2: a too-large b_o "will be
    // adjusted for the current (and, possibly, subsequent) iterations").
    // On a cut the attempted width shrinks to what proved sustainable; it
    // regrows by b_i per uncut iteration, bounded by b_o.
    let mut attempt = bo;

    loop {
        let right0 = f + bc;
        let cancel_now = ctl.is_some_and(|c| c.is_cancelled());
        if cancel_now || fatal {
            // Request-level ET (or a fatal error using the same exit):
            // commit the already-factorized current panel (including
            // anything it owes the left block) and stop. The trailing
            // columns keep their pre-update values; see
            // [`LaCtl::request_cancel`].
            stats.cancelled = cancel_now;
            stats.panel_widths.push(bc);
            let mut crew = Crew::with_arena(Arc::clone(&arena));
            fk.apply_left(&mut crew, params, av, f, bc, &st_cur);
            let cs = crew.stats();
            stats.hybrid_tiles += cs.hybrid_tiles;
            stats.stolen_tiles += cs.stolen_tiles;
            if crew.is_poisoned() {
                note_poison(&mut error, &mut fatal, crew.poison_message());
            }
            fk.commit(&mut acc, &st_cur, bc);
            committed += bc;
            if let Some(c) = ctl {
                c.cols_done.store(committed, Ordering::Release);
            }
            break;
        }
        stats.panel_widths.push(bc);

        if right0 >= kmax {
            // ---- Epilogue: no panels left to factor. Apply the current
            // panel's transformation to any remaining right columns
            // (wide matrices) and whatever it owes the left block, then
            // finish.
            let mut crew = Crew::with_arena(Arc::clone(&arena));
            let members: Vec<_> = (0..pool.workers())
                .map(|w| {
                    let s = crew.shared();
                    let e = opts.entry;
                    pool.submit(w, move || s.member_loop(e))
                })
                .collect();
            if right0 < n {
                fk.apply(&mut crew, params, av, f, bc, &st_cur, right0, n);
            }
            fk.apply_left(&mut crew, params, av, f, bc, &st_cur);
            fk.commit(&mut acc, &st_cur, bc);
            committed += bc;
            crew.disband();
            for h in members {
                h.wait();
            }
            let cs = crew.stats();
            stats.hybrid_tiles += cs.hybrid_tiles;
            stats.stolen_tiles += cs.stolen_tiles;
            if crew.is_poisoned() {
                note_poison(&mut error, &mut fatal, crew.poison_message());
            }
            break;
        }

        stats.iters += 1;
        let bn = attempt.min(kmax - right0);
        let r0 = right0 + bn; // first column of R
        let r_cols = n - r0;

        // Per-iteration shared state.
        let ru_done = Arc::new(AtomicBool::new(false));
        let pf_work_done = Arc::new(AtomicBool::new(false));
        let outcome: Arc<Mutex<Option<PanelStep<F::State>>>> = Arc::new(Mutex::new(None));

        let mut crew_ru = Crew::with_arena(Arc::clone(&arena));
        let ru_shared = crew_ru.shared();
        let crew_pf = Crew::with_arena(Arc::clone(&arena));
        let pf_shared = crew_pf.shared();

        // RU members: workers t_pf.. join RU's crew — unless R is empty,
        // in which case they help the panel branch instead (reverse WS).
        let r_empty = r_cols == 0;
        let join_pf_first = r_empty && opts.malleable;
        let mut handles = Vec::new();
        for w in t_pf..pool.workers() {
            let rs = Arc::clone(&ru_shared);
            let ps = Arc::clone(&pf_shared);
            let e = opts.entry;
            let jp = join_pf_first;
            handles.push(pool.submit(w, move || {
                if jp {
                    ps.member_loop(e);
                }
                rs.member_loop(e);
            }));
        }
        // PF members: workers 1..t_pf, chained into RU on WS.
        for w in 1..t_pf {
            let ps = Arc::clone(&pf_shared);
            let rs = Arc::clone(&ru_shared);
            let e = opts.entry;
            let mall = opts.malleable;
            handles.push(pool.submit(w, move || {
                ps.member_loop(e);
                if mall {
                    rs.member_loop(e);
                }
            }));
        }

        // ---- PF branch on worker 0. ----
        let pf_task = {
            let st = Arc::clone(&st_cur);
            let params = *params;
            let fk2 = fk.clone();
            let early = opts.early_term;
            let mall = opts.malleable;
            let entry = opts.entry;
            let ru_done = Arc::clone(&ru_done);
            let pf_work_done = Arc::clone(&pf_work_done);
            let outcome = Arc::clone(&outcome);
            let rs = Arc::clone(&ru_shared);
            // Move the crew (leader handle) into the worker task.
            let mut crew_pf = crew_pf;
            let arm_et = early && !r_empty;
            pool.submit(0, move || {
                // PF1+PF2: current panel's transformation applied to P.
                span(Kind::Gemm, "PF.update", || {
                    fk2.apply(&mut crew_pf, &params, av, f, bc, &st, right0, r0);
                });
                // PF3: factorize the next panel.
                let out = span(Kind::Panel, "PF.panel", || {
                    fk2.panel(
                        &mut crew_pf,
                        &params,
                        av,
                        right0,
                        bn,
                        bi,
                        early,
                        if arm_et { Some(&ru_done) } else { None },
                    )
                });
                *outcome.lock().unwrap() = Some(out);
                pf_work_done.store(true, Ordering::Release);
                crew_pf.disband();
                // Worker Sharing: join the remainder update in flight.
                if mall {
                    rs.member_loop(entry);
                }
            })
        };

        // ---- RU branch on the calling thread. ----
        if r_cols > 0 {
            span(Kind::Gemm, "RU.update", || {
                fk.apply(&mut crew_ru, params, av, f, bc, &st_cur, r0, n);
            });
        }
        // Whatever the current panel owes the left block (disjoint from
        // P and R; LU's lazy left swaps).
        span(Kind::Swap, "RU.left", || {
            fk.apply_left(&mut crew_ru, params, av, f, bc, &st_cur);
        });
        // ET: tell the panel branch the update is finished.
        ru_done.store(true, Ordering::Release);

        // Reverse WS: if R was empty, the leader helps the panel team.
        if join_pf_first {
            stats.ws_reverse += 1;
            pf_shared.member_loop(opts.entry);
        }

        // Wait for the panel result (the PF worker may still be enlisted
        // in our crew afterwards — that is fine, it parks on job waits).
        // A PF task that *dies* never sets `pf_work_done`, so also poll
        // the task handle: its unwind drops `crew_pf`, whose `Drop`
        // disbands the PF crew and releases any enlisted members — the
        // containment path that turns a panel-branch panic into a typed
        // error instead of a wedged spin.
        let backoff = crossbeam_utils::Backoff::new();
        while !pf_work_done.load(Ordering::Acquire) {
            if pf_task.is_done() {
                break;
            }
            backoff.snooze();
        }
        if opts.malleable && crew_ru.stats().max_members > (pool.workers() - t_pf) {
            stats.ws_forward += 1;
        }
        crew_ru.disband();
        for h in handles {
            h.wait();
        }
        let pf_panic = std::panic::catch_unwind(AssertUnwindSafe(|| pf_task.wait()))
            .err()
            .map(|e| crate::pool::panic_message(e.as_ref()));
        // Fold both branches' hybrid-scheduler counters into the run's
        // stats (the PF crew handle moved into its worker task; its
        // shared state carries the counters).
        let cs = crew_ru.stats();
        let (pf_stolen, pf_tiles) = pf_shared.steal_stats();
        stats.hybrid_tiles += cs.hybrid_tiles + pf_tiles;
        stats.stolen_tiles += cs.stolen_tiles + pf_stolen;
        if crew_ru.is_poisoned() {
            note_poison(&mut error, &mut fatal, crew_ru.poison_message());
        }
        if pf_shared.is_poisoned() {
            note_poison(&mut error, &mut fatal, pf_shared.poison_message());
        }
        if let Some(msg) = pf_panic {
            note(
                &mut error,
                &mut fatal,
                FactorError::Internal(format!("look-ahead panel branch panicked: {msg}")),
                true,
            );
        }

        let out = match outcome.lock().unwrap_or_else(|e| e.into_inner()).take() {
            Some(out) => out,
            None => {
                // The panel branch died before producing the next panel:
                // the loop-top stop path commits the *current* panel
                // (still intact) and ends the run with the error above.
                note(
                    &mut error,
                    &mut fatal,
                    FactorError::Internal(String::from(
                        "look-ahead panel branch produced no outcome",
                    )),
                    true,
                );
                // The stop path re-pushes the current panel's width.
                stats.panel_widths.pop();
                continue;
            }
        };
        if out.terminated_early {
            stats.et_cuts += 1;
            attempt = out.k_done.max(bi.max(1));
        } else {
            attempt = (attempt + bi.max(1)).min(bo);
        }
        if let Some((e, is_fatal)) = panel_health(fk.kind(), &av, right0, out.k_done) {
            note(&mut error, &mut fatal, e, is_fatal);
        }

        // Commit the current panel and adopt the next.
        fk.commit(&mut acc, &st_cur, bc);
        committed += bc;
        f = right0;
        bc = out.k_done;
        st_cur = Arc::new(out.state);
        if let Some(c) = ctl {
            c.cols_done.store(committed, Ordering::Release);
        }
    }

    if let Some(c) = ctl {
        c.cols_done.store(committed, Ordering::Release);
    }
    debug_assert!(stats.cancelled || error.is_some() || committed == kmax);
    (acc, stats, error)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::{CholFactor, FactorKind, LuFactor, QrFactor};
    use crate::matrix::{naive, Matrix};

    #[test]
    fn blocked_lu_matches_lu_blocked_rl_bitwise() {
        // The generic blocked driver must perform the exact operation
        // sequence of the LU-specific one it generalizes.
        let a0 = Matrix::random(60, 60, 41);
        let params = BlisParams::tiny();

        let mut f1 = a0.clone();
        let mut crew1 = Crew::new();
        let p1 = crate::lu::lu_blocked_rl(&mut crew1, &params, f1.view_mut(), 16, 4);

        let mut f2 = a0.clone();
        let mut crew2 = Crew::new();
        let (p2, done, cancelled, err) = blocked_ctl(
            &LuFactor,
            &mut crew2,
            &params,
            f2.view_mut(),
            16,
            4,
            &FactorCtl::default(),
        );
        assert!(!cancelled);
        assert_eq!(err, None);
        assert_eq!(done, 60);
        assert_eq!(p1, p2);
        for (x, y) in f1.data().iter().zip(f2.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn blocked_chol_and_qr_reconstruct() {
        let params = BlisParams::tiny();
        let n = 48;

        let a0 = Matrix::random_spd(n, 5);
        let mut f = a0.clone();
        let mut crew = Crew::new();
        let (_, done, cancelled, err) = blocked_ctl(
            &CholFactor,
            &mut crew,
            &params,
            f.view_mut(),
            16,
            4,
            &FactorCtl::default(),
        );
        assert!(!cancelled);
        assert_eq!(err, None);
        assert_eq!(done, n);
        let r = naive::chol_residual(&a0, &f);
        assert!(r < 1e-12, "chol residual {r}");

        let a0 = Matrix::random(n, n, 6);
        let mut f = a0.clone();
        let (tau, done, _, _) = blocked_ctl(
            &QrFactor,
            &mut crew,
            &params,
            f.view_mut(),
            16,
            4,
            &FactorCtl::default(),
        );
        assert_eq!(done, n);
        assert_eq!(tau.len(), n);
        let r = naive::qr_residual(&a0, &f, &tau);
        assert!(r < 1e-11, "qr residual {r}");
    }

    #[test]
    fn lookahead_chol_matches_blocked_bitwise() {
        // Like LU: the look-ahead schedule reorganizes who computes what
        // when, but performs the same per-element operation chains.
        let n = 64;
        let a0 = Matrix::random_spd(n, 7);
        let params = BlisParams::tiny();

        let mut f1 = a0.clone();
        let mut crew = Crew::new();
        let (_, d1, _, _) = blocked_ctl(
            &CholFactor,
            &mut crew,
            &params,
            f1.view_mut(),
            16,
            4,
            &FactorCtl::default(),
        );
        assert_eq!(d1, n);

        let pool = Pool::new(2);
        let mut f2 = a0.clone();
        let (_, stats, _) = lookahead_ctl(
            &CholFactor,
            &pool,
            &params,
            &mut f2,
            16,
            4,
            &LaOpts::default(),
            None,
        );
        assert!(stats.iters > 0);
        // Only the lower triangle is meaningful; the LA driver never
        // touches the upper one either, so full bitwise equality holds.
        for (x, y) in f1.data().iter().zip(f2.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn lookahead_qr_matches_blocked_bitwise() {
        let n = 56;
        let a0 = Matrix::random(n, n, 8);
        let params = BlisParams::tiny();

        let mut f1 = a0.clone();
        let mut crew = Crew::new();
        let (t1, d1, _, _) = blocked_ctl(
            &QrFactor,
            &mut crew,
            &params,
            f1.view_mut(),
            16,
            4,
            &FactorCtl::default(),
        );
        assert_eq!(d1, n);

        let pool = Pool::new(2);
        let mut f2 = a0.clone();
        let (t2, _, _) = lookahead_ctl(
            &QrFactor,
            &pool,
            &params,
            &mut f2,
            16,
            4,
            &LaOpts::default(),
            None,
        );
        assert_eq!(t1.len(), t2.len());
        for (x, y) in t1.iter().zip(&t2) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in f1.data().iter().zip(f2.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn lookahead_steal_on_matches_steal_off_bitwise() {
        // The hybrid tile-stealing schedule threads through both
        // look-ahead branches (PF applies to P, RU to R) without
        // touching a bit — for the WS-enabled configuration where crews
        // actually grow mid-iteration.
        use crate::blis::StealPolicy;
        let n = 72;
        let a0 = Matrix::random(n, n, 55);
        let opts = LaOpts {
            malleable: true,
            ..Default::default()
        };
        let run = |steal: StealPolicy| {
            let pool = Pool::new(3);
            let params = BlisParams::tiny().with_steal(steal);
            let mut f = a0.clone();
            let (p, stats, _) =
                lookahead_ctl(&LuFactor, &pool, &params, &mut f, 16, 4, &opts, None);
            (f, p, stats)
        };
        let (f_off, p_off, s_off) = run(StealPolicy::Off);
        assert_eq!(s_off.hybrid_tiles, 0, "Off must not touch the deques");
        for steal in [StealPolicy::Auto, StealPolicy::Fraction(1000)] {
            let (f_on, p_on, s_on) = run(steal);
            assert_eq!(p_off, p_on, "{steal:?} pivots");
            assert!(
                s_on.hybrid_tiles > 0,
                "{steal:?} must schedule macro-kernel tiles through the deques"
            );
            for (x, y) in f_off.data().iter().zip(f_on.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{steal:?}");
            }
        }
    }

    #[test]
    fn cancel_leaves_clean_prefix_for_every_kind() {
        let n = 64;
        let pool = Pool::new(2);
        let params = BlisParams::tiny();
        for &kind in FactorKind::all() {
            let a0 = match kind {
                FactorKind::Chol => Matrix::random_spd(n, 11),
                _ => Matrix::random(n, n, 11),
            };
            let mut f = a0.clone();
            let ctl = LaCtl::new();
            ctl.request_cancel(); // cancel before the first outer step
            let opts = LaOpts {
                malleable: true,
                ..Default::default()
            };
            let out = crate::factor::factorize_lookahead(
                kind,
                &pool,
                &params,
                &mut f,
                16,
                4,
                &opts,
                Some(&ctl),
            );
            assert!(out.cancelled, "{}", kind.name());
            let done = ctl.cols_done();
            assert_eq!(done, out.cols_done, "{}", kind.name());
            assert!(done > 0 && done < n, "{}: done={done}", kind.name());
        }
    }
}
