//! LU with partial pivoting as a [`Factorization`] instance — the
//! paper's original workload, now one kind among three under the generic
//! drivers, implemented for both sealed [`Scalar`] precisions.
//!
//! The panel kernels are the existing [`crate::lu::panel`] pair
//! (right-looking eager, left-looking lazy with the ET poll); the
//! trailing update is LASWP + TRSM + GEMM; the pivot step is the lazy
//! left row swap. Pivots are absolutized against the panel's top row as
//! soon as the panel returns, so the state shared between the look-ahead
//! branches is a plain `Vec<usize>` of absolute pivot rows.

use super::{FactorKind, Factorization, PanelStep};
use crate::blis::{gemm, trsm_llu, BlisParams};
use crate::lu::panel::{panel_ll, panel_rl};
use crate::matrix::MatMut;
use crate::pool::Crew;
use crate::scalar::Scalar;
use crate::sim::HwModel;
use std::sync::atomic::AtomicBool;

/// The LU-with-partial-pivoting kind (zero-sized dispatch token).
#[derive(Copy, Clone, Debug, Default)]
pub struct LuFactor;

/// `laswp` with pivot indices relative to row `base` (the panel top):
/// swap rows `base+k` and `piv[k]` (absolute) for columns `jlo..jhi`.
/// Reuses [`crate::blis::laswp::for_each_col_strip`]'s chunking (strip
/// width [`crate::blis::params::COL_STRIP`], the definition shared with
/// the plain LASWP): each strip applies the whole pivot sequence while
/// its rows are cache-resident.
pub(crate) fn laswp_abs<S: Scalar>(
    crew: &mut Crew,
    a: MatMut<S>,
    piv: &[usize],
    base: usize,
    jlo: usize,
    jhi: usize,
) {
    if piv.is_empty() || jlo >= jhi {
        return;
    }
    crate::trace::span(crate::trace::Kind::Swap, "laswp", || {
        crate::blis::laswp::for_each_col_strip(crew, jlo, jhi, |lo, hi| {
            for (k, &p) in piv.iter().enumerate() {
                let row = base + k;
                if p != row {
                    a.swap_rows(row, p, lo, hi);
                }
            }
        });
    });
}

impl<S: Scalar> Factorization<S> for LuFactor {
    type State = Vec<usize>;
    type Acc = Vec<usize>;

    fn kind(&self) -> FactorKind {
        FactorKind::Lu
    }

    fn panel(
        &self,
        crew: &mut Crew,
        params: &BlisParams,
        a: MatMut<S>,
        f: usize,
        b: usize,
        bi: usize,
        ll: bool,
        stop: Option<&AtomicBool>,
    ) -> PanelStep<Vec<usize>> {
        let m = a.rows();
        let p = a.sub(f, f, m - f, b);
        let out = if ll {
            panel_ll(crew, params, p, bi, stop)
        } else {
            debug_assert!(stop.is_none());
            panel_rl(crew, params, p, bi)
        };
        PanelStep {
            state: out.ipiv.iter().map(|q| q + f).collect(),
            k_done: out.k_done,
            terminated_early: out.terminated_early,
        }
    }

    fn apply(
        &self,
        crew: &mut Crew,
        params: &BlisParams,
        a: MatMut<S>,
        f: usize,
        bc: usize,
        st: &Vec<usize>,
        j0: usize,
        j1: usize,
    ) {
        if j0 >= j1 {
            return;
        }
        let m = a.rows();
        let w = j1 - j0;
        laswp_abs(crew, a, st, f, j0, j1);
        trsm_llu(
            crew,
            params,
            a.sub(f, f, bc, bc).as_ref(),
            a.sub(f, j0, bc, w),
        );
        let below = f + bc;
        if m > below {
            gemm(
                crew,
                params,
                S::ZERO - S::ONE,
                a.sub(below, f, m - below, bc).as_ref(),
                a.sub(f, j0, bc, w).as_ref(),
                a.sub(below, j0, m - below, w),
            );
        }
    }

    fn apply_left(
        &self,
        crew: &mut Crew,
        _params: &BlisParams,
        a: MatMut<S>,
        f: usize,
        _bc: usize,
        st: &Vec<usize>,
    ) {
        laswp_abs(crew, a, st, f, 0, f);
    }

    fn commit(&self, acc: &mut Vec<usize>, st: &Vec<usize>, k_done: usize) {
        debug_assert_eq!(st.len(), k_done);
        acc.extend_from_slice(st);
    }
}

/// Cost-model estimate of the single-core seconds left in an `m × n` LU
/// after `k` committed columns — the sum of every remaining step's panel,
/// LASWP, TRSM, and GEMM times under `hw`.
pub fn remaining_cost_lu(hw: &HwModel, m: usize, n: usize, k: usize, bo: usize, bi: usize) -> f64 {
    let kmax = m.min(n);
    let bo = bo.max(1);
    let mut total = 0.0;
    let mut kk = k.min(kmax);
    while kk < kmax {
        let b = bo.min(kmax - kk);
        total += hw.panel_time(m - kk, b, bi, 1);
        let rest = n - kk - b;
        if rest > 0 {
            total += hw.laswp_time(b, n, 1);
            total += hw.trsm_time(b, rest, 1);
            total += hw.gemm_time(m - kk - b, rest, b, 1);
        }
        kk += b;
    }
    total
}
