//! §factor — the **malleable factorization family** (DESIGN.md §11).
//!
//! The paper presents Worker Sharing and Early Termination through LU
//! with partial pivoting, but both are properties of the *malleable BLAS*
//! underneath, applicable to any factorization with a panel / trailing-
//! update structure (the follow-up "Programming Parallel Dense Matrix
//! Factorizations with Look-Ahead and OpenMP", Catalán et al. 2018,
//! demonstrates exactly that across Cholesky, LU, and QR). This module
//! factors the scheduling machinery out of the LU driver into a
//! [`Factorization`] trait and keeps **one** generic look-ahead driver
//! ([`driver::lookahead_ctl`], with WS and ET) plus **one** generic
//! blocked driver ([`driver::blocked_ctl`], with request-level
//! checkpoints) shared by all kinds:
//!
//! | Kind | Panel kernel | Trailing update | Pivot/ordering step |
//! |---|---|---|---|
//! | [`FactorKind::Lu`] | blocked LU (`lu::panel`) | LASWP + TRSM + GEMM | partial-pivot row swaps |
//! | [`FactorKind::Chol`] | `potf2` + [`crate::blis::trsm_rltn`] | [`crate::blis::syrk_ln`] | none |
//! | [`FactorKind::Qr`] | Householder `geqr2` | compact-WY [`crate::blis::house::apply_block_qt`] | none |
//!
//! Since the precision-generic redesign (DESIGN.md §12) the trait and
//! both drivers are additionally parameterized by the sealed
//! [`Scalar`] type: `Factorization<S>` is implemented for every kind in
//! both `f32` and `f64`, and one driver instantiation per `(kind, S)`
//! pair shares all of the scheduling machinery.
//!
//! The trait contract (which steps may be worker-shared, where the ET
//! checkpoints sit, and the per-kind determinism invariant) is documented
//! in DESIGN.md §11.

pub mod chol;
pub mod driver;
pub mod error;
pub mod lu;
pub mod qr;

pub use chol::CholFactor;
pub use error::FactorError;
pub use lu::LuFactor;
pub use qr::QrFactor;

// The driver-family selector lives next to the DAG driver it names;
// re-exported here because it dispatches between this module's drivers
// and [`crate::tilert`]'s.
pub use crate::tilert::factor::DriverFamily;

use crate::blis::BlisParams;
use crate::matrix::{Mat, MatMut};
use crate::pool::{Crew, EntryPolicy, Pool};
use crate::scalar::Scalar;
use crate::sim::HwModel;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Which factorization a request or driver runs — the runtime-dispatch
/// counterpart of the [`Factorization`] trait.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum FactorKind {
    /// LU with partial pivoting (`P·A = L·U`).
    Lu,
    /// Cholesky (`A = L·Lᵀ`, symmetric positive definite input).
    Chol,
    /// Blocked Householder QR (`A = Q·R`).
    Qr,
}

impl FactorKind {
    /// Parse a kind name: `lu`, `chol`/`cholesky`/`llt`, `qr`.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "lu" => FactorKind::Lu,
            "chol" | "cholesky" | "llt" => FactorKind::Chol,
            "qr" => FactorKind::Qr,
            _ => return None,
        })
    }

    /// Canonical lowercase name (used in trace tags and bench records).
    pub fn name(&self) -> &'static str {
        match self {
            FactorKind::Lu => "lu",
            FactorKind::Chol => "chol",
            FactorKind::Qr => "qr",
        }
    }

    /// All kinds, in presentation order.
    pub fn all() -> &'static [FactorKind] {
        &[FactorKind::Lu, FactorKind::Chol, FactorKind::Qr]
    }

    /// Flop count of a full `m × n` factorization of this kind.
    pub fn flops(&self, m: usize, n: usize) -> f64 {
        match self {
            FactorKind::Lu => crate::util::lu_flops(m, n),
            FactorKind::Chol => {
                let n = n.min(m) as f64;
                n * n * n / 3.0
            }
            FactorKind::Qr => {
                let (m, n) = (m as f64, n as f64);
                let k = m.min(n);
                2.0 * k * k * (m.max(n) - k / 3.0)
            }
        }
    }

    /// Cost-model estimate of the single-core seconds left after `k`
    /// committed columns — the remaining-work half of the serve layer's
    /// reallocation policy (DESIGN.md §10). The estimate is in `f64`
    /// terms; precision-aware callers divide by
    /// [`Scalar::FLOP_RATE`] (see [`FactorKind::remaining_cost_prec`]).
    pub fn remaining_cost(
        &self,
        hw: &HwModel,
        m: usize,
        n: usize,
        k: usize,
        bo: usize,
        bi: usize,
    ) -> f64 {
        match self {
            FactorKind::Lu => lu::remaining_cost_lu(hw, m, n, k, bo, bi),
            FactorKind::Chol => chol::remaining_cost_chol(hw, m, k, bo, bi),
            FactorKind::Qr => qr::remaining_cost_qr(hw, m, n, k, bo, bi),
        }
    }

    /// [`FactorKind::remaining_cost`] scaled by the working precision's
    /// modeled flop rate: an `f32` problem is priced at half the seconds
    /// of its `f64` twin, so mixed-precision batches share one
    /// starvation metric (DESIGN.md §12).
    #[allow(clippy::too_many_arguments)]
    pub fn remaining_cost_prec<S: Scalar>(
        &self,
        hw: &HwModel,
        m: usize,
        n: usize,
        k: usize,
        bo: usize,
        bi: usize,
    ) -> f64 {
        self.remaining_cost(hw, m, n, k, bo, bi) / S::FLOP_RATE
    }

    /// Check that an `m × n` problem is well-formed for this kind
    /// (Cholesky requires a square matrix).
    pub fn validate(&self, m: usize, n: usize) -> Result<(), String> {
        if *self == FactorKind::Chol && m != n {
            return Err(format!("cholesky requires a square matrix, got {m}x{n}"));
        }
        Ok(())
    }
}

/// One committed panel step: the kind-specific state needed to apply the
/// panel's transformation ([`Factorization::State`]) plus how far the
/// panel factorization got before an Early-Termination cut.
pub struct PanelStep<St> {
    /// Whatever [`Factorization::apply`] needs (pivots, reflector block,
    /// nothing for Cholesky).
    pub state: St,
    /// Columns actually factorized (`< b` only after an ET cut).
    pub k_done: usize,
    /// Whether an ET signal cut the panel short.
    pub terminated_early: bool,
}

/// The panel / trailing-update contract the generic drivers schedule,
/// parameterized by the working precision `S`.
///
/// Implementations describe *what* one factorization step computes; the
/// drivers in [`driver`] own *when and by whom* it runs (team split,
/// Worker Sharing, Early Termination, cancellation checkpoints). Every
/// method must be bitwise deterministic with respect to crew size — the
/// trailing reductions it performs must be sequential per output element
/// (DESIGN.md §8, §11) — in each precision independently.
pub trait Factorization<S: Scalar>: Clone + Send + Sync + 'static {
    /// Per-panel state handed from [`Self::panel`] to [`Self::apply`]
    /// (absolute pivot rows for LU, the compact-WY reflector block for
    /// QR, nothing for Cholesky). Shared read-only across the two
    /// look-ahead branches.
    type State: Send + Sync + 'static;
    /// Accumulated output of a whole factorization (all pivots, all
    /// `tau`s, or a committed-column count).
    type Acc: Default + Send;

    /// The runtime tag of this implementation.
    fn kind(&self) -> FactorKind;

    /// Factorize the panel of width `b` whose top-left corner is
    /// `(f, f)` of the full matrix `a` (rows `f..m`), with inner block
    /// size `bi`.
    ///
    /// With `ll` set the panel must run its **left-looking** (lazy)
    /// variant so that `stop` — the Early-Termination flag, polled
    /// between inner blocks — can cut it short leaving a clean prefix of
    /// `k_done` factorized columns and a suffix that is bitwise exactly
    /// as on entry. `stop` is only ever `Some` when `ll` is set.
    #[allow(clippy::too_many_arguments)]
    fn panel(
        &self,
        crew: &mut Crew,
        params: &BlisParams,
        a: MatMut<S>,
        f: usize,
        b: usize,
        bi: usize,
        ll: bool,
        stop: Option<&AtomicBool>,
    ) -> PanelStep<Self::State>;

    /// Apply the committed panel (corner `(f, f)`, width `bc`, state
    /// `st`) to columns `j0..j1` of the trailing matrix. The drivers call
    /// this concurrently for disjoint column ranges (the look-ahead `P` /
    /// `R` split), so implementations must write only within rows `f..m`
    /// of columns `j0..j1` and read the panel columns immutably.
    #[allow(clippy::too_many_arguments)]
    fn apply(
        &self,
        crew: &mut Crew,
        params: &BlisParams,
        a: MatMut<S>,
        f: usize,
        bc: usize,
        st: &Self::State,
        j0: usize,
        j1: usize,
    );

    /// Apply whatever the committed panel owes the already-factored
    /// columns `0..f` — LU's lazy left row swaps. A no-op for kinds
    /// without a pivoting step.
    fn apply_left(
        &self,
        crew: &mut Crew,
        params: &BlisParams,
        a: MatMut<S>,
        f: usize,
        bc: usize,
        st: &Self::State,
    ) {
        let _ = (crew, params, a, f, bc, st);
    }

    /// Fold a committed panel's state into the factorization's output.
    fn commit(&self, acc: &mut Self::Acc, st: &Self::State, k_done: usize);
}

/// Which look-ahead refinements are active (shared by every
/// [`Factorization`] kind; the paper's `LU_LA` / `LU_MB` / `LU_ET`
/// ladder).
#[derive(Copy, Clone, Debug)]
pub struct LaOpts {
    /// Worker Sharing via the malleable BLAS (paper §4.1).
    pub malleable: bool,
    /// Early termination of the panel factorization (paper §4.2).
    /// Implies the left-looking inner panel.
    pub early_term: bool,
    /// How joining workers enter an in-flight kernel.
    pub entry: EntryPolicy,
    /// Threads dedicated to the panel branch (the paper uses 1).
    pub t_pf: usize,
}

impl Default for LaOpts {
    fn default() -> Self {
        Self {
            malleable: false,
            early_term: false,
            entry: EntryPolicy::JobBoundary,
            t_pf: 1,
        }
    }
}

/// Execution statistics for the look-ahead driver.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LaStats {
    /// Outer iterations executed.
    pub iters: usize,
    /// Iterations whose panel factorization was cut short by ET.
    pub et_cuts: usize,
    /// Iterations in which at least one PF worker joined the RU crew
    /// (forward worker sharing).
    pub ws_forward: usize,
    /// Iterations in which RU workers joined the PF crew (reverse WS;
    /// only when `R` was empty).
    pub ws_reverse: usize,
    /// Effective width of each factorized panel (shrinks under ET).
    pub panel_widths: Vec<usize>,
    /// Whether the run was cut short through [`LaCtl`] (request-level ET).
    pub cancelled: bool,
    /// Macro-kernel tiles executed under the hybrid static/dynamic
    /// scheduler across the run's crews (DESIGN.md §13; zero when
    /// [`crate::blis::StealPolicy::Off`]).
    pub hybrid_tiles: u64,
    /// Hybrid tiles taken from another participant's static slice —
    /// how much within-update rebalancing actually happened.
    pub stolen_tiles: u64,
}

/// Cooperative control threaded through a look-ahead factorization by
/// callers that may cancel it mid-flight — the serve layer's
/// generalization of the paper's ET flag from "cut one iteration's
/// panel" to "cut the whole request". Polled between outer panel steps.
#[derive(Debug, Default)]
pub struct LaCtl {
    pub(crate) cancel: AtomicBool,
    pub(crate) cols_done: AtomicUsize,
}

impl LaCtl {
    /// Fresh control with nothing cancelled and no progress recorded.
    pub fn new() -> Self {
        Self::default()
    }

    /// Ask the factorization to stop at the next outer checkpoint. The
    /// already-factorized current panel is still committed, so the
    /// matrix is left with a clean factored prefix of `cols_done()`
    /// columns; the trailing columns still owe that panel's
    /// transformations.
    pub fn request_cancel(&self) {
        self.cancel.store(true, Ordering::Release);
    }

    /// Whether [`Self::request_cancel`] has been called.
    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::Acquire)
    }

    /// Columns factorized and committed so far (monotone; reaches
    /// `min(m, n)` on an uncancelled run).
    pub fn cols_done(&self) -> usize {
        self.cols_done.load(Ordering::Acquire)
    }
}

/// Cooperative control for the generic blocked driver
/// ([`driver::blocked_ctl`]) — cancellation polled between panel steps,
/// per-request trace tags, and a committed-columns callback. The
/// kind-generic counterpart of [`crate::lu::BlockedCtl`].
#[derive(Default)]
pub struct FactorCtl<'a> {
    /// Polled between panel steps; when set the factorization stops
    /// before the next step, leaving a clean factored prefix.
    pub cancel: Option<&'a AtomicBool>,
    /// Trace label prefix (e.g. `req3:qr:f32`); `None` keeps plain labels.
    pub tag: Option<&'a str>,
    /// Called with the number of committed columns after every step.
    pub on_checkpoint: Option<&'a (dyn Fn(usize) + Sync)>,
}

/// Type-erased result of a factorization of any [`FactorKind`], in
/// working precision `S` (`f64` unless spelled otherwise).
#[derive(Debug, Clone, Default)]
pub struct FactorOutcome<S: Scalar = f64> {
    /// Absolute pivot rows (LU only; empty for Cholesky/QR).
    pub ipiv: Vec<usize>,
    /// Householder scalar factors (QR only; empty otherwise).
    pub tau: Vec<S>,
    /// Columns fully factorized and committed.
    pub cols_done: usize,
    /// Whether the run was cut short by a cancel flag.
    pub cancelled: bool,
    /// Look-ahead statistics (`None` for the blocked driver).
    pub la_stats: Option<LaStats>,
    /// Typed numerical (or supervision) failure, if the drivers detected
    /// one (DESIGN.md §15). LAPACK-`info` semantics for LU: an
    /// [`FactorError::ExactlySingular`] is recorded but the
    /// factorization still completes; every other kind of error stops
    /// the run after the last committed panel.
    pub error: Option<FactorError>,
}

/// Factorize `a` in place with the generic WS+ET look-ahead driver,
/// dispatching on `kind`, in `a`'s own precision. `pool` supplies the
/// workers (total team = `pool.workers() + 1` counting the caller);
/// `ctl` adds request-level cancellation checkpoints.
#[allow(clippy::too_many_arguments)]
pub fn factorize_lookahead<S: Scalar>(
    kind: FactorKind,
    pool: &Pool,
    params: &BlisParams,
    a: &mut Mat<S>,
    bo: usize,
    bi: usize,
    opts: &LaOpts,
    ctl: Option<&LaCtl>,
) -> FactorOutcome<S> {
    match kind {
        FactorKind::Lu => {
            let (ipiv, stats, error) =
                driver::lookahead_ctl(&LuFactor, pool, params, a, bo, bi, opts, ctl);
            FactorOutcome {
                cols_done: ipiv.len(),
                cancelled: stats.cancelled,
                ipiv,
                tau: Vec::new(),
                la_stats: Some(stats),
                error,
            }
        }
        FactorKind::Chol => {
            let (done, stats, error) =
                driver::lookahead_ctl(&CholFactor, pool, params, a, bo, bi, opts, ctl);
            FactorOutcome {
                cols_done: done,
                cancelled: stats.cancelled,
                ipiv: Vec::new(),
                tau: Vec::new(),
                la_stats: Some(stats),
                error,
            }
        }
        FactorKind::Qr => {
            let (tau, stats, error) =
                driver::lookahead_ctl(&QrFactor, pool, params, a, bo, bi, opts, ctl);
            FactorOutcome {
                cols_done: tau.len(),
                cancelled: stats.cancelled,
                ipiv: Vec::new(),
                tau,
                la_stats: Some(stats),
                error,
            }
        }
    }
}

/// Factorize `a` in place with the generic blocked right-looking driver
/// (panel on the critical path, request-level checkpoints), dispatching
/// on `kind`, in `a`'s own precision. This is the serve layer's
/// per-request driver.
pub fn factorize_blocked<S: Scalar>(
    kind: FactorKind,
    crew: &mut Crew,
    params: &BlisParams,
    a: MatMut<S>,
    bo: usize,
    bi: usize,
    ctl: &FactorCtl,
) -> FactorOutcome<S> {
    match kind {
        FactorKind::Lu => {
            let (ipiv, cols_done, cancelled, error) =
                driver::blocked_ctl(&LuFactor, crew, params, a, bo, bi, ctl);
            FactorOutcome {
                ipiv,
                tau: Vec::new(),
                cols_done,
                cancelled,
                la_stats: None,
                error,
            }
        }
        FactorKind::Chol => {
            let (_, cols_done, cancelled, error) =
                driver::blocked_ctl(&CholFactor, crew, params, a, bo, bi, ctl);
            FactorOutcome {
                ipiv: Vec::new(),
                tau: Vec::new(),
                cols_done,
                cancelled,
                la_stats: None,
                error,
            }
        }
        FactorKind::Qr => {
            let (tau, cols_done, cancelled, error) =
                driver::blocked_ctl(&QrFactor, crew, params, a, bo, bi, ctl);
            FactorOutcome {
                ipiv: Vec::new(),
                tau,
                cols_done,
                cancelled,
                la_stats: None,
                error,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for (s, k) in [
            ("lu", FactorKind::Lu),
            ("CHOL", FactorKind::Chol),
            ("cholesky", FactorKind::Chol),
            ("qr", FactorKind::Qr),
        ] {
            assert_eq!(FactorKind::parse(s), Some(k));
            assert_eq!(FactorKind::parse(k.name()), Some(k));
        }
        assert_eq!(FactorKind::parse("svd"), None);
    }

    #[test]
    fn flop_counts_have_the_right_ratios() {
        let n = 512;
        let lu = FactorKind::Lu.flops(n, n);
        let ch = FactorKind::Chol.flops(n, n);
        let qr = FactorKind::Qr.flops(n, n);
        // Chol ≈ LU/2, QR ≈ 2·LU for square matrices.
        assert!((ch / lu - 0.5).abs() < 0.02, "chol/lu = {}", ch / lu);
        assert!((qr / lu - 2.0).abs() < 0.05, "qr/lu = {}", qr / lu);
    }

    #[test]
    fn validate_rejects_rectangular_cholesky() {
        assert!(FactorKind::Chol.validate(8, 8).is_ok());
        assert!(FactorKind::Chol.validate(8, 9).is_err());
        assert!(FactorKind::Lu.validate(8, 9).is_ok());
        assert!(FactorKind::Qr.validate(9, 8).is_ok());
    }

    #[test]
    fn remaining_cost_monotone_for_all_kinds() {
        let hw = HwModel::default();
        for &k in FactorKind::all() {
            let full = k.remaining_cost(&hw, 256, 256, 0, 32, 8);
            let half = k.remaining_cost(&hw, 256, 256, 128, 32, 8);
            let done = k.remaining_cost(&hw, 256, 256, 256, 32, 8);
            assert!(full > half, "{}: full={full} half={half}", k.name());
            assert!(half > 0.0, "{}", k.name());
            assert_eq!(done, 0.0, "{}", k.name());
        }
    }

    #[test]
    fn precision_scales_remaining_cost() {
        let hw = HwModel::default();
        let c64 = FactorKind::Lu.remaining_cost_prec::<f64>(&hw, 256, 256, 0, 32, 8);
        let c32 = FactorKind::Lu.remaining_cost_prec::<f32>(&hw, 256, 256, 0, 32, 8);
        assert!(c64 > 0.0);
        assert!(
            (c32 - c64 / 2.0).abs() < 1e-12 * c64,
            "f32 cost {c32} should be half of f64 cost {c64}"
        );
    }
}
