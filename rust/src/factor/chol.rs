//! Right-looking Cholesky (`A = L·Lᵀ`, lower triangle) as a
//! [`Factorization`] instance.
//!
//! The panel step factorizes a diagonal block with the unblocked
//! [`chol_unblocked`] and solves the block column below it with the
//! malleable right-side TRSM ([`trsm_rltn`]); the trailing update is the
//! lower-trapezoid SYRK ([`syrk_ln`]), whose bulk runs on the packed
//! malleable GEMM and therefore carries the Worker-Sharing entry points.
//! There is no pivot step (`apply_left` is the default no-op) and no
//! per-panel state: applying a committed panel only reads the factored
//! columns themselves.
//!
//! ET contract: the panel is blocked left-looking over `b_i`-column inner
//! blocks — each inner block is first brought up to date with a
//! trapezoidal SYRK against the panel's factored prefix, then factorized
//! — so an ET cut between inner blocks leaves the suffix columns bitwise
//! untouched, exactly like the LU panel (DESIGN.md §11).
//!
//! The input must be symmetric positive definite; only the lower triangle
//! (and the diagonal) is ever read or written, so whatever the caller
//! stores above the diagonal survives the factorization.

use super::{FactorKind, Factorization, PanelStep};
use crate::blis::{syrk_ln, trsm_rltn, BlisParams};
use crate::matrix::MatMut;
use crate::pool::Crew;
use crate::scalar::Scalar;
use crate::sim::HwModel;
use std::sync::atomic::{AtomicBool, Ordering};

/// The Cholesky kind (zero-sized dispatch token).
#[derive(Copy, Clone, Debug, Default)]
pub struct CholFactor;

/// Unblocked lower Cholesky of the square block `a` (LAPACK `potf2`,
/// reciprocal-multiply scaling like the LU reference so blocked and
/// unblocked paths share per-element operation chains). Reads and writes
/// the lower triangle only. The block must be SPD after the caller's
/// left-looking updates — a non-positive diagonal yields NaNs, which the
/// residual checks catch (no pivoting, matching LAPACK semantics).
pub fn chol_unblocked<S: Scalar>(a: MatMut<S>) {
    let n = a.rows();
    debug_assert_eq!(a.cols(), n);
    for k in 0..n {
        let dk = a.at(k, k).sqrt();
        a.set(k, k, dk);
        if dk != S::ZERO {
            let r = S::ONE / dk;
            for i in k + 1..n {
                a.update(i, k, |x| x * r);
            }
        }
        for j in k + 1..n {
            let ajk = a.at(j, k);
            if ajk == S::ZERO {
                continue;
            }
            for i in j..n {
                a.update(i, j, |x| x - a.at(i, k) * ajk);
            }
        }
    }
}

impl<S: Scalar> Factorization<S> for CholFactor {
    type State = ();
    type Acc = usize;

    fn kind(&self) -> FactorKind {
        FactorKind::Chol
    }

    fn panel(
        &self,
        crew: &mut Crew,
        params: &BlisParams,
        a: MatMut<S>,
        f: usize,
        b: usize,
        bi: usize,
        _ll: bool,
        stop: Option<&AtomicBool>,
    ) -> PanelStep<()> {
        let m = a.rows();
        let p = a.sub(f, f, m - f, b); // rows f..m, cols f..f+b
        let mp = p.rows();
        let kmax = mp.min(b);
        let bi = bi.max(1);
        let mut kk = 0;
        let mut terminated_early = false;
        while kk < kmax {
            let bb = bi.min(kmax - kk);
            if kk > 0 {
                // Left-looking: bring columns kk..kk+bb up to date with
                // the panel's factored prefix (trapezoidal SYRK; columns
                // to the right stay untouched — the ET property).
                syrk_ln(
                    crew,
                    params,
                    S::ZERO - S::ONE,
                    p.sub(kk, 0, mp - kk, kk).as_ref(),
                    p.sub(kk, kk, mp - kk, bb),
                );
            }
            // Factorize the diagonal block, then the rows below via the
            // malleable right-side TRSM.
            chol_unblocked(p.sub(kk, kk, bb, bb));
            if kk + bb < mp {
                trsm_rltn(
                    crew,
                    p.sub(kk, kk, bb, bb).as_ref(),
                    p.sub(kk + bb, kk, mp - kk - bb, bb),
                );
            }
            kk += bb;
            // ET poll — end of the inner iteration.
            if kk < kmax {
                if let Some(flag) = stop {
                    if flag.load(Ordering::Acquire) {
                        terminated_early = true;
                        break;
                    }
                }
            }
        }
        PanelStep {
            state: (),
            k_done: kk,
            terminated_early,
        }
    }

    fn apply(
        &self,
        crew: &mut Crew,
        params: &BlisParams,
        a: MatMut<S>,
        f: usize,
        bc: usize,
        _st: &(),
        j0: usize,
        j1: usize,
    ) {
        if j0 >= j1 {
            return;
        }
        let m = a.rows();
        // A[j0.., j0..j1] -= L[j0.., f..f+bc] · L[j0..j1, f..f+bc]ᵀ
        // (lower trapezoid only — the strict upper triangle of the
        // leading square keeps the caller's symmetric data).
        syrk_ln(
            crew,
            params,
            S::ZERO - S::ONE,
            a.sub(j0, f, m - j0, bc).as_ref(),
            a.sub(j0, j0, m - j0, j1 - j0),
        );
    }

    fn commit(&self, acc: &mut usize, _st: &(), k_done: usize) {
        *acc += k_done;
    }
}

/// Cost-model estimate of the single-core seconds left in an `n × n`
/// Cholesky after `k` committed columns: per remaining step, a panel
/// (priced as the unblocked trapezoid) plus a SYRK trailing update
/// (priced as half the equivalent GEMM — only the lower trapezoid is
/// computed).
pub fn remaining_cost_chol(hw: &HwModel, n: usize, k: usize, bo: usize, bi: usize) -> f64 {
    let bo = bo.max(1);
    let mut total = 0.0;
    let mut kk = k.min(n);
    while kk < n {
        let b = bo.min(n - kk);
        total += hw.panel_time(n - kk, b, bi, 1) * 0.5;
        let rest = n - kk - b;
        if rest > 0 {
            total += hw.trsm_time(b, rest, 1);
            total += hw.gemm_time(rest, rest, b, 1) * 0.5;
        }
        kk += b;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{naive, Matrix};

    #[test]
    fn unblocked_matches_naive_reference() {
        for n in [1usize, 2, 7, 16, 33] {
            let a0 = Matrix::random_spd(n, n as u64 + 1);
            let mut f1 = a0.clone();
            chol_unblocked(f1.view_mut());
            let r = naive::chol_residual(&a0, &f1);
            assert!(r < 1e-13, "n={n} residual={r}");
        }
    }

    #[test]
    fn panel_full_width_matches_unblocked_numerically() {
        let params = BlisParams::tiny();
        let n = 24;
        let a0 = Matrix::random_spd(n, 3);
        let mut f = a0.clone();
        let mut crew = Crew::new();
        let out = CholFactor.panel(&mut crew, &params, f.view_mut(), 0, n, 4, true, None);
        assert_eq!(out.k_done, n);
        assert!(!out.terminated_early);
        let r = naive::chol_residual(&a0, &f);
        assert!(r < 1e-12, "residual {r}");
    }

    #[test]
    fn panel_et_cut_leaves_suffix_untouched() {
        let params = BlisParams::tiny();
        let n = 32;
        let bi = 4;
        let a0 = Matrix::random_spd(n, 9);
        let mut f = a0.clone();
        let stop = AtomicBool::new(true); // already set: cut after one block
        let mut crew = Crew::new();
        let out = CholFactor.panel(
            &mut crew,
            &params,
            f.view_mut(),
            0,
            n,
            bi,
            true,
            Some(&stop),
        );
        assert!(out.terminated_early);
        assert_eq!(out.k_done, bi);
        for j in out.k_done..n {
            for i in 0..n {
                assert_eq!(f[(i, j)], a0[(i, j)], "suffix touched at ({i},{j})");
            }
        }
    }
}
