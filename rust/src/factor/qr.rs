//! Blocked Householder QR (`A = Q·R`) as a [`Factorization`] instance.
//!
//! The panel step is a left-looking `geqr2`: reflectors are generated
//! column by column ([`crate::blis::house::reflector`]) and previous
//! reflectors are applied lazily, one inner `b_i` block at a time, so the
//! ET flag can cut the panel leaving untouched suffix columns — the same
//! contract as the LU and Cholesky panels (DESIGN.md §11). When a panel
//! commits, its reflectors are condensed into the compact WY form
//! `Q = I − V·T·Vᵀ` ([`crate::blis::house::larft`]): the panel state
//! carries `tau`, `T`, and clean `V`/`Vᵀ` copies, shared read-only by the
//! two look-ahead branches.
//!
//! The trailing update applies `Qᵀ` with two malleable packed `gemm`s
//! plus a small triangular multiply
//! ([`crate::blis::house::apply_block_qt`]) — per-column arithmetic, so
//! the look-ahead `P`/`R` column split is bitwise invisible, and the bulk
//! of the flops inherit GEMM's Worker-Sharing entry points.

use super::{FactorKind, Factorization, PanelStep};
use crate::blis::house::{apply_block_qt, apply_reflector, larft, reflector};
use crate::blis::BlisParams;
use crate::matrix::{Mat, MatMut};
use crate::pool::Crew;
use crate::scalar::Scalar;
use crate::sim::HwModel;
use std::sync::atomic::{AtomicBool, Ordering};

/// The blocked Householder QR kind (zero-sized dispatch token).
#[derive(Copy, Clone, Debug, Default)]
pub struct QrFactor;

/// Committed-panel state: everything [`apply_block_qt`] needs to apply
/// `Qᵀ` of one panel to a block of trailing columns.
pub struct QrPanel<S: Scalar = f64> {
    /// Householder scalar factors, one per committed column.
    pub tau: Vec<S>,
    /// The `k × k` upper-triangular block-reflector factor.
    t: Mat<S>,
    /// Clean `m_p × k` reflector block (unit diagonal, zeros above).
    v: Mat<S>,
    /// Transpose of `v` (`k × m_p`), precomputed once per panel so both
    /// look-ahead branches share it read-only.
    vt: Mat<S>,
}

impl<S: Scalar> Factorization<S> for QrFactor {
    type State = QrPanel<S>;
    type Acc = Vec<S>;

    fn kind(&self) -> FactorKind {
        FactorKind::Qr
    }

    fn panel(
        &self,
        crew: &mut Crew,
        params: &BlisParams,
        a: MatMut<S>,
        f: usize,
        b: usize,
        bi: usize,
        _ll: bool,
        stop: Option<&AtomicBool>,
    ) -> PanelStep<QrPanel<S>> {
        let m = a.rows();
        let p = a.sub(f, f, m - f, b); // rows f..m, cols f..f+b
        let mp = p.rows();
        let kmax = mp.min(b);
        let bi = bi.max(1);
        let mut tau: Vec<S> = Vec::with_capacity(kmax);
        let mut kk = 0;
        let mut terminated_early = false;
        while kk < kmax {
            let bb = bi.min(kmax - kk);
            // Left-looking: bring columns kk..kk+bb up to date with every
            // previously generated reflector (columns to the right stay
            // untouched — the ET property).
            for (j, &tj) in tau.iter().enumerate() {
                apply_reflector(crew, p, j, j, tj, kk, kk + bb);
            }
            // Factorize the inner block eagerly.
            for j in kk..kk + bb {
                let tj = reflector(p, j);
                if j + 1 < kk + bb {
                    apply_reflector(crew, p, j, j, tj, j + 1, kk + bb);
                }
                tau.push(tj);
            }
            kk += bb;
            // ET poll — end of the inner iteration.
            if kk < kmax {
                if let Some(flag) = stop {
                    if flag.load(Ordering::Acquire) {
                        terminated_early = true;
                        break;
                    }
                }
            }
        }
        let _ = params;
        // Condense the committed reflectors into compact WY form.
        let k = kk;
        let mut v = Mat::<S>::zeros(mp, k);
        for j in 0..k {
            v[(j, j)] = S::ONE;
            for i in j + 1..mp {
                v[(i, j)] = p.at(i, j);
            }
        }
        let vt = v.transposed();
        let t = larft(v.view(), &tau);
        PanelStep {
            state: QrPanel { tau, t, v, vt },
            k_done: k,
            terminated_early,
        }
    }

    fn apply(
        &self,
        crew: &mut Crew,
        params: &BlisParams,
        a: MatMut<S>,
        f: usize,
        _bc: usize,
        st: &QrPanel<S>,
        j0: usize,
        j1: usize,
    ) {
        if j0 >= j1 {
            return;
        }
        let m = a.rows();
        apply_block_qt(
            crew,
            params,
            st.v.view(),
            st.vt.view(),
            st.t.view(),
            a.sub(f, j0, m - f, j1 - j0),
        );
    }

    fn commit(&self, acc: &mut Vec<S>, st: &QrPanel<S>, k_done: usize) {
        debug_assert_eq!(st.tau.len(), k_done);
        acc.extend_from_slice(&st.tau);
    }
}

/// Cost-model estimate of the single-core seconds left in an `m × n` QR
/// after `k` committed columns: per remaining step, a panel (priced at
/// twice the LU panel — reflector generation and application do roughly
/// double the flops) plus the two rank-`b` GEMMs of the block update.
pub fn remaining_cost_qr(hw: &HwModel, m: usize, n: usize, k: usize, bo: usize, bi: usize) -> f64 {
    let kmax = m.min(n);
    let bo = bo.max(1);
    let mut total = 0.0;
    let mut kk = k.min(kmax);
    while kk < kmax {
        let b = bo.min(kmax - kk);
        total += hw.panel_time(m - kk, b, bi, 1) * 2.0;
        let rest = n - kk - b;
        if rest > 0 {
            total += hw.gemm_time(b, rest, m - kk, 1);
            total += hw.gemm_time(m - kk, rest, b, 1);
        }
        kk += b;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{naive, Matrix};

    #[test]
    fn panel_full_width_is_a_valid_qr() {
        let params = BlisParams::tiny();
        for &(m, b, bi) in &[(24usize, 8usize, 4usize), (40, 12, 4), (16, 16, 8)] {
            let a0 = Matrix::random(m, b, (m + b) as u64);
            let mut f = a0.clone();
            let mut crew = Crew::new();
            let out = QrFactor.panel(&mut crew, &params, f.view_mut(), 0, b, bi, true, None);
            assert_eq!(out.k_done, b.min(m));
            assert!(!out.terminated_early);
            let r = naive::qr_residual(&a0, &f, &out.state.tau);
            assert!(r < 1e-12, "m={m} b={b} residual {r}");
        }
    }

    #[test]
    fn panel_et_cut_leaves_suffix_untouched() {
        let params = BlisParams::tiny();
        let (m, b, bi) = (30usize, 16usize, 4usize);
        let a0 = Matrix::random(m, b, 13);
        let mut f = a0.clone();
        let stop = AtomicBool::new(true); // cut after the first inner block
        let mut crew = Crew::new();
        let out = QrFactor.panel(
            &mut crew,
            &params,
            f.view_mut(),
            0,
            b,
            bi,
            true,
            Some(&stop),
        );
        assert!(out.terminated_early);
        assert_eq!(out.k_done, bi);
        assert_eq!(out.state.tau.len(), bi);
        for j in out.k_done..b {
            for i in 0..m {
                assert_eq!(f[(i, j)], a0[(i, j)], "suffix touched at ({i},{j})");
            }
        }
        // The committed prefix is a valid QR of the leading columns.
        let lead0 = Matrix::from_fn(m, out.k_done, |i, j| a0[(i, j)]);
        let leadf = Matrix::from_fn(m, out.k_done, |i, j| f[(i, j)]);
        let r = naive::qr_residual(&lead0, &leadf, &out.state.tau);
        assert!(r < 1e-12, "prefix residual {r}");
    }

    #[test]
    fn panel_state_applies_like_reference() {
        // apply() with the condensed panel state must transform trailing
        // columns exactly as factorizing the wider matrix would.
        let params = BlisParams::tiny();
        let (m, n, b) = (20usize, 14usize, 6usize);
        let a0 = Matrix::random(m, n, 17);

        // Reference: factorize all n columns unblocked (bi >= n).
        let mut whole = a0.clone();
        let mut crew = Crew::new();
        let full = QrFactor.panel(&mut crew, &params, whole.view_mut(), 0, n, 1, true, None);

        // Panel of width b + apply to the rest + factor the rest.
        let mut split = a0.clone();
        let st = QrFactor.panel(&mut crew, &params, split.view_mut(), 0, b, 1, true, None);
        QrFactor.apply(&mut crew, &params, split.view_mut(), 0, b, &st.state, b, n);
        let tail = QrFactor.panel(
            &mut crew,
            &params,
            split.view_mut(),
            b,
            n - b,
            1,
            true,
            None,
        );

        let mut tau = st.state.tau.clone();
        tau.extend_from_slice(&tail.state.tau);
        assert_eq!(tau.len(), full.state.tau.len());
        let r = naive::qr_residual(&a0, &split, &tau);
        assert!(r < 1e-11, "split residual {r}");
        let q = naive::qr_q(&split, &tau);
        assert!(naive::orthogonality(&q) < 1e-12);
        // And numerically close to the unblocked reference.
        let d = whole.max_abs_diff(&split);
        assert!(d < 1e-10, "blocked vs unblocked diff {d}");
    }
}
