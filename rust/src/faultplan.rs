//! Deterministic, seeded fault injection for the chaos test suite
//! (DESIGN.md §15.4).
//!
//! Faults injected at *deterministic checkpoint boundaries* are
//! reproducible, which makes chaos testing seedable like any other
//! property test: a [`FaultPlan`] derived from a seed names one fault
//! (a leader panic at a panel checkpoint, a crew-member panic inside a
//! chunk, a stall, a poisoned input, a dropped connection) and the
//! hooks compiled into the pool and serve layers fire it exactly once.
//!
//! This module only exists under `cfg(any(test, feature = "chaos"))`;
//! release builds carry no hook code at all. Within a chaos build the
//! hooks cost one relaxed atomic load when no plan is armed.
//!
//! Plans are process-global (one armed plan at a time), so tests that
//! arm them serialize through [`FaultPlan::arm`]'s returned guard.
//!
//! Arming comes in two scopes. [`FaultPlan::arm`] is *global*: every
//! hook call in the process can fire the plan. That is only safe in the
//! dedicated chaos integration binary (`tests/chaos.rs`), where every
//! test arms a plan and therefore serializes through the guard. Inside
//! the library's own test binary — where unrelated tests run crews
//! concurrently — use [`FaultPlan::arm_local`], which fires only for
//! hook calls made on the arming thread and leaves every other test's
//! checkpoints and chunks untouched.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// What a plan does, and where it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic on the request's *leader* thread at panel checkpoint `k`
    /// (fires in the serve driver's checkpoint closure). Exercises the
    /// serve loop's `catch_unwind` → typed `FAILED{Internal}` path.
    PanicAtCheckpoint {
        /// Ordinal of the checkpoint (0 = first) at which to panic.
        k: usize,
    },
    /// Panic inside a crew *member/leader chunk* the `nth` time any
    /// chunk hook fires. Exercises the crew poisoning path: the chunk
    /// is marked completed, the crew is poisoned, the driver reports
    /// `FactorError::Internal`, and nothing hangs.
    PanicInChunk {
        /// Ordinal of the chunk-hook call (0 = first) at which to panic.
        nth: usize,
    },
    /// Sleep for `ms` at panel checkpoint `k` — a wedged-but-alive
    /// leader. With a request deadline set this exercises the
    /// checkpoint deadline cut and the daemon watchdog.
    StallAtCheckpoint {
        /// Ordinal of the checkpoint at which to stall.
        k: usize,
        /// Stall duration in milliseconds.
        ms: u64,
    },
    /// No in-process hook: the test injects a NaN into the request
    /// payload itself and expects a typed `FAILED{NonFinite}`.
    PoisonInput,
    /// No in-process hook: the test's client writes a partial frame and
    /// drops the connection (before admission), or vanishes right after
    /// submitting (after admission; the reap path).
    DropConnection {
        /// `true`: drop mid-frame before the request is admitted.
        /// `false`: drop after submitting, orphaning an admitted job.
        mid_frame: bool,
    },
}

/// A seeded fault plan: one [`FaultAction`], fired at most once.
#[derive(Debug)]
pub struct FaultPlan {
    /// The seed this plan was derived from (for failure reports).
    pub seed: u64,
    /// The action the hooks fire.
    pub action: FaultAction,
}

impl FaultPlan {
    /// Derive a plan deterministically from `seed`. Consecutive seeds
    /// cycle through every action family, with the in-family parameters
    /// (checkpoint ordinal, stall length, chunk ordinal) also seeded.
    pub fn from_seed(seed: u64) -> Self {
        // SplitMix64 step — same generator family as `util::rng`.
        let mut z = seed.wrapping_add(0x9e3779b97f4a7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        let r = z ^ (z >> 31);
        let action = match seed % 6 {
            0 => FaultAction::PanicAtCheckpoint {
                k: (r % 3) as usize,
            },
            1 => FaultAction::PanicInChunk {
                nth: (r % 40) as usize,
            },
            2 => FaultAction::StallAtCheckpoint {
                k: (r % 2) as usize,
                ms: 120 + r % 80,
            },
            3 => FaultAction::PoisonInput,
            4 => FaultAction::DropConnection { mid_frame: true },
            _ => FaultAction::DropConnection { mid_frame: false },
        };
        Self { seed, action }
    }

    /// Arm this plan globally: any hook call in the process can fire
    /// it. Only safe where every concurrent test serializes through the
    /// returned guard (the chaos integration binary). The guard disarms
    /// on drop, so a panicking test cannot leave a live fault behind.
    pub fn arm(&self) -> ArmedGuard<'_> {
        self.arm_scoped(Scope::Global)
    }

    /// Arm this plan scoped to the *calling thread*: only hook calls
    /// made on this thread can fire it, so concurrently running tests
    /// in the same binary are untouched. Chunk hooks still fire when
    /// the arming thread leads a crew, because the leader claims and
    /// runs chunks itself.
    pub fn arm_local(&self) -> ArmedGuard<'_> {
        self.arm_scoped(Scope::Thread(std::thread::current().id()))
    }

    fn arm_scoped(&self, scope: Scope) -> ArmedGuard<'_> {
        let slot = state();
        let guard = slot.plan.lock().unwrap_or_else(|e| e.into_inner());
        slot.fired.store(false, Ordering::Release);
        slot.hook_calls.store(false, Ordering::Release);
        CKPT_ORDINAL.store(0, Ordering::Release);
        CHUNK_ORDINAL.store(0, Ordering::Release);
        *slot.current.lock().unwrap_or_else(|e| e.into_inner()) = Some((self.action, scope));
        ARMED.store(true, Ordering::Release);
        ArmedGuard { _serial: guard }
    }
}

/// Which hook calls an armed plan listens to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Scope {
    /// Every hook call in the process (chaos binary only).
    Global,
    /// Only hook calls made on the arming thread.
    Thread(std::thread::ThreadId),
}

impl Scope {
    fn covers_current_thread(self) -> bool {
        match self {
            Scope::Global => true,
            Scope::Thread(tid) => std::thread::current().id() == tid,
        }
    }
}

/// Exclusive hold on the global fault slot; disarms on drop.
pub struct ArmedGuard<'a> {
    _serial: MutexGuard<'a, ()>,
}

impl Drop for ArmedGuard<'_> {
    fn drop(&mut self) {
        ARMED.store(false, Ordering::Release);
        let slot = state();
        *slot.current.lock().unwrap_or_else(|e| e.into_inner()) = None;
    }
}

struct FaultState {
    /// Serializes scenarios: held for the lifetime of an [`ArmedGuard`].
    plan: Mutex<()>,
    current: Mutex<Option<(FaultAction, Scope)>>,
    fired: AtomicBool,
    /// Whether any hook call was observed since arming (for tests that
    /// assert the hook sites are actually wired).
    hook_calls: AtomicBool,
}

static ARMED: AtomicBool = AtomicBool::new(false);
static STATE: OnceLock<FaultState> = OnceLock::new();

fn state() -> &'static FaultState {
    STATE.get_or_init(|| FaultState {
        plan: Mutex::new(()),
        current: Mutex::new(None),
        fired: AtomicBool::new(false),
        hook_calls: AtomicBool::new(false),
    })
}

/// Whether the armed plan (if any) has fired.
pub fn fired() -> bool {
    state().fired.load(Ordering::Acquire)
}

/// Whether any hook site was reached since the plan was armed.
pub fn hooks_reached() -> bool {
    state().hook_calls.load(Ordering::Acquire)
}

/// Counter used by [`FaultAction::PanicInChunk`] to pick its victim.
static CHUNK_ORDINAL: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
static CKPT_ORDINAL: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Hook: called by the serve driver's per-request checkpoint closure
/// with the request tag and committed-column count. Fires
/// [`FaultAction::PanicAtCheckpoint`] / [`FaultAction::StallAtCheckpoint`].
pub fn checkpoint_hook(tag: &str, cols_done: usize) {
    if !ARMED.load(Ordering::Relaxed) {
        return;
    }
    let slot = state();
    let Some((action, scope)) = *slot.current.lock().unwrap_or_else(|e| e.into_inner()) else {
        return;
    };
    if !scope.covers_current_thread() {
        return;
    }
    slot.hook_calls.store(true, Ordering::Release);
    let ordinal = CKPT_ORDINAL.fetch_add(1, Ordering::AcqRel);
    match action {
        FaultAction::PanicAtCheckpoint { k } if ordinal == k => {
            if !slot.fired.swap(true, Ordering::AcqRel) {
                panic!("faultplan: injected leader panic at checkpoint {k} ({tag}, cols={cols_done})");
            }
        }
        FaultAction::StallAtCheckpoint { k, ms } if ordinal == k => {
            if !slot.fired.swap(true, Ordering::AcqRel) {
                std::thread::sleep(Duration::from_millis(ms));
            }
        }
        _ => {}
    }
}

/// Hook: called by the crew chunk-execution paths before running a
/// chunk. Fires [`FaultAction::PanicInChunk`] on its `nth` call.
pub fn chunk_hook(chunk: usize) {
    if !ARMED.load(Ordering::Relaxed) {
        return;
    }
    let slot = state();
    let Some((action, scope)) = *slot.current.lock().unwrap_or_else(|e| e.into_inner()) else {
        return;
    };
    if !scope.covers_current_thread() {
        return;
    }
    slot.hook_calls.store(true, Ordering::Release);
    if let FaultAction::PanicInChunk { nth } = action {
        let ordinal = CHUNK_ORDINAL.fetch_add(1, Ordering::AcqRel);
        if ordinal == nth && !slot.fired.swap(true, Ordering::AcqRel) {
            panic!("faultplan: injected crew-member panic in chunk {chunk} (call #{ordinal})");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_cover_every_action_family() {
        let mut families = std::collections::HashSet::new();
        for seed in 0..12 {
            let p = FaultPlan::from_seed(seed);
            families.insert(std::mem::discriminant(&p.action));
            // Deterministic: same seed, same plan.
            assert_eq!(p.action, FaultPlan::from_seed(seed).action, "seed {seed}");
        }
        assert_eq!(families.len(), 5, "12 seeds must span all 5 action families");
    }

    #[test]
    fn disarmed_hooks_are_inert() {
        checkpoint_hook("req0:lu:f64", 0);
        chunk_hook(3);
        // No plan armed: nothing fires, nothing panics.
        assert!(!fired() || true);
    }

    #[test]
    fn armed_panic_plan_fires_exactly_once() {
        let plan = FaultPlan {
            seed: 0,
            action: FaultAction::PanicAtCheckpoint { k: 0 },
        };
        let _g = plan.arm_local();
        let r = std::panic::catch_unwind(|| checkpoint_hook("t", 0));
        assert!(r.is_err(), "first matching checkpoint must panic");
        assert!(fired());
        assert!(hooks_reached());
        // Once fired the plan is spent: later checkpoints pass through.
        checkpoint_hook("t", 16);
    }
}
