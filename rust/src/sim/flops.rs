//! Analytic flop accounting used by the figures and the cost model —
//! the formulas quoted throughout the paper's §3 and §5.

/// Total flops of the LU factorization of a square matrix of order `n`
/// (`2n³/3`, paper §3.1).
pub fn lu_total(n: usize) -> f64 {
    crate::util::lu_flops(n, n)
}

/// Flops spent in panel factorizations for a square LU of order `n` with
/// block size `bo`, summed exactly over iterations: each panel is
/// `(n − k) × b` costing `(n−k)·b² − b³/3`.
pub fn panel_total(n: usize, bo: usize) -> f64 {
    let bo = bo.max(1);
    let mut total = 0.0;
    let mut k = 0;
    while k < n {
        let b = bo.min(n - k) as f64;
        let m = (n - k) as f64;
        total += m * b * b - b * b * b / 3.0;
        k += bo.min(n - k);
    }
    total
}

/// Ratio of panel flops to total flops — the paper's Fig. 14 (right)
/// series; `≈ b·n²/2 / (2n³/3)` for `n ≫ b`.
pub fn panel_ratio(n: usize, bo: usize) -> f64 {
    panel_total(n, bo) / lu_total(n)
}

/// Fraction of total flops performed by the leading `frac` of iterations
/// (paper §3.1: 25 % → ~58 %, 50 % → 87.5 %, 75 % → >98 %).
pub fn leading_fraction(frac: f64) -> f64 {
    1.0 - (1.0 - frac).powi(3)
}

/// Paper footnote 3: flops performed when the factorization of an
/// `m × n` panel is stopped at column `k` — left-looking variant.
pub fn ll_flops_at_cut(m: usize, k: usize) -> f64 {
    let (m, k) = (m as f64, k as f64);
    m * k * k - k * k * k / 3.0
}

/// Paper footnote 3: same, right-looking variant (the eager extra work).
pub fn rl_flops_at_cut(m: usize, n: usize, k: usize) -> f64 {
    let (mf, nf, kf) = (m as f64, n as f64, k as f64);
    ll_flops_at_cut(m, k) + 2.0 * (nf - kf) * (mf * kf - kf * kf / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panel_ratio_matches_asymptotic_formula() {
        // n ≫ b: ratio ≈ (n²b/2)/(2n³/3) = 3b/(4n).
        let (n, b) = (10_000, 256);
        let exact = panel_ratio(n, b);
        let asym = 3.0 * b as f64 / (4.0 * n as f64);
        assert!((exact - asym).abs() / asym < 0.05, "{exact} vs {asym}");
    }

    #[test]
    fn paper_config_panel_share_is_under_2_percent() {
        // Paper §3.1: n=10000, b_o=256 → "less than 2% of the flops".
        assert!(panel_ratio(10_000, 256) < 0.02);
    }

    #[test]
    fn leading_fraction_matches_paper() {
        assert!((leading_fraction(0.25) - 0.578125).abs() < 1e-12);
        assert!((leading_fraction(0.5) - 0.875).abs() < 1e-12);
        assert!(leading_fraction(0.75) > 0.98);
    }

    #[test]
    fn footnote3_rl_exceeds_ll() {
        let (m, n, k) = (5000, 256, 64);
        assert!(rl_flops_at_cut(m, n, k) > ll_flops_at_cut(m, k));
    }

    #[test]
    fn panel_total_single_block() {
        // bo >= n: one panel, full LU cost.
        let n = 100;
        assert!((panel_total(n, 200) - lu_total(n)).abs() / lu_total(n) < 1e-12);
    }

    #[test]
    fn ratio_decreases_with_n_increases_with_b() {
        assert!(panel_ratio(2000, 256) > panel_ratio(8000, 256));
        assert!(panel_ratio(4000, 384) > panel_ratio(4000, 128));
    }
}
