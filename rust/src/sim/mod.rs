//! Discrete-event simulation of the paper's testbed.
//!
//! **Why this exists** (DESIGN.md §3): the paper's evaluation ran on a
//! 6-core Intel Xeon E5-2603 v3; this container has a single vCPU, so
//! wall-clock multithreaded measurements cannot reproduce the paper's
//! performance figures. Following the substitution rule, this module
//! simulates that testbed: a calibrated [`costmodel::HwModel`] prices
//! every building block (GEPP-shaped GEMM, panel factorization, TRSM,
//! LASWP), and per-variant simulators replay the *exact same scheduling
//! state machines* as the real code in `lu/` and `taskrt/` — team split,
//! WS merges at Loop-3 entry points, ET polls at inner-block boundaries,
//! priority-driven task graphs — over virtual time.
//!
//! The simulators regenerate every performance figure of the paper
//! (Figs. 14–17) and virtual-time versions of the trace figures
//! (Figs. 5, 8, 9, 11). Absolute GFLOPS are model outputs; the claims
//! under reproduction are the *shapes*: orderings, crossovers, and
//! optimal block sizes.

pub mod costmodel;
pub mod figures;
pub mod flops;
pub mod lu_sim;
pub mod os_sim;

pub use costmodel::HwModel;
pub use lu_sim::{simulate, SimOutcome, SimVariant};
