//! Virtual-time simulation of the task-runtime baseline (`LU_OS`).
//!
//! A list-scheduling DES over the same task graph `taskrt::lu_os`
//! builds: `P(k)` (panel, priority) and `U(k,j)` (swap+TRSM+GEMM of panel
//! `j` w.r.t. panel `k`). Tasks run *sequential* kernels (the paper links
//! LU_OS with single-threaded BLIS) and each task pays the runtime's
//! bookkeeping overhead. Adaptive-depth look-ahead emerges from the
//! dependency structure, exactly as in OmpSs.

use super::costmodel::HwModel;
use crate::trace::{Kind, Span};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

#[derive(Clone, Debug)]
struct SimTask {
    cost: f64,
    priority: i32,
    kind: Kind,
    label: String,
    deps_left: usize,
    dependents: Vec<usize>,
}

/// Simulate `LU_OS` on an `n × n` matrix with `t` workers.
pub fn sim_os(
    hw: &HwModel,
    n: usize,
    bo: usize,
    bi: usize,
    t: usize,
    tr: bool,
) -> super::SimOutcome {
    let bo = bo.max(1);
    let n_panels = n.div_ceil(bo);
    let mut tasks: Vec<SimTask> = Vec::new();
    let mut u_prev: Vec<Option<usize>> = vec![None; n_panels];

    let width = |p: usize| (p * bo + bo).min(n) - p * bo;
    for k in 0..n_panels {
        let b = width(k);
        let diag = k * bo;
        let rows = n - diag;
        // P(k)
        let deps: Vec<usize> = u_prev[k].into_iter().collect();
        let pid = tasks.len();
        tasks.push(SimTask {
            cost: hw.panel_time(rows, b, bi, 1) + hw.task_overhead,
            priority: 1,
            kind: Kind::Panel,
            label: format!("P({k})"),
            deps_left: deps.len(),
            dependents: Vec::new(),
        });
        for d in deps {
            tasks[d].dependents.push(pid);
        }
        // U(k, j)
        for j in k + 1..n_panels {
            let w = width(j);
            let id = tasks.len();
            let deps: Vec<usize> = [Some(pid), u_prev[j]].into_iter().flatten().collect();
            tasks.push(SimTask {
                cost: hw.laswp_time(b, w, 1)
                    + hw.trsm_time(b, w, 1)
                    + hw.gemm_time(rows - b, w, b, 1)
                    + hw.task_overhead,
                priority: 0,
                kind: Kind::Gemm,
                label: format!("U({k},{j})"),
                deps_left: deps.len(),
                dependents: Vec::new(),
            });
            for d in deps {
                tasks[d].dependents.push(id);
            }
            u_prev[j] = Some(id);
        }
    }

    // ---- List-scheduling DES over t identical workers. ----
    let mut ready: BinaryHeap<(i32, Reverse<usize>)> = BinaryHeap::new();
    for (id, task) in tasks.iter().enumerate() {
        if task.deps_left == 0 {
            ready.push((task.priority, Reverse(id)));
        }
    }
    // Completion events: (finish_time, task, lane).
    let mut events: BinaryHeap<(Reverse<OrdF64>, usize, usize)> = BinaryHeap::new();
    let mut free_lanes: BinaryHeap<Reverse<usize>> = (0..t.max(1)).map(Reverse).collect();
    let mut now = 0.0f64;
    let mut makespan = 0.0f64;
    let mut done = 0usize;
    let mut spans = Vec::new();
    let mut deps_left: Vec<usize> = tasks.iter().map(|t| t.deps_left).collect();

    while done < tasks.len() {
        // Dispatch while workers and ready tasks are available.
        while !free_lanes.is_empty() && !ready.is_empty() {
            let (_, Reverse(id)) = ready.pop().unwrap();
            let Reverse(lane) = free_lanes.pop().unwrap();
            let fin = now + tasks[id].cost;
            if tr {
                spans.push(Span {
                    lane,
                    kind: tasks[id].kind,
                    label: tasks[id].label.clone(),
                    t0: now,
                    t1: fin,
                });
            }
            events.push((Reverse(OrdF64(fin)), id, lane));
        }
        // Advance to the next completion.
        let Some((Reverse(OrdF64(fin)), id, lane)) = events.pop() else {
            panic!("LU_OS sim stalled: {} of {} tasks done", done, tasks.len());
        };
        now = fin;
        makespan = makespan.max(fin);
        free_lanes.push(Reverse(lane));
        done += 1;
        let deps = tasks[id].dependents.clone();
        for d in deps {
            deps_left[d] -= 1;
            if deps_left[d] == 0 {
                ready.push((tasks[d].priority, Reverse(d)));
            }
        }
    }

    // Deferred left-pivot application (sequential tail, cheap).
    let mut k = 0;
    while k < n {
        let b = bo.min(n - k);
        makespan += hw.laswp_time(b, k, t.min(hw.bw_cores));
        k += b;
    }

    super::SimOutcome {
        time: makespan,
        gflops: crate::util::gflops(super::flops::lu_total(n), makespan),
        iters: n_panels,
        et_cuts: 0,
        spans,
    }
}

/// Total-ordered f64 for the event queue (no NaNs by construction).
#[derive(Copy, Clone, PartialEq)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN in event queue")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, SimVariant};

    fn hw() -> HwModel {
        HwModel::default()
    }

    #[test]
    fn runs_and_produces_plausible_gflops() {
        let out = sim_os(&hw(), 8000, 256, 32, 6, false);
        assert!(out.gflops > 20.0 && out.gflops < hw().machine_peak());
    }

    #[test]
    fn os_beats_plain_lu() {
        // Dynamic look-ahead amortizes the panel cost: LU_OS must beat
        // the BDP-only baseline for midsize problems.
        for n in [4000usize, 8000] {
            let os = sim_os(&hw(), n, 256, 32, 6, false).gflops;
            let lu = simulate(&hw(), SimVariant::Lu, n, 256, 32, 6, 1, false).gflops;
            assert!(os > lu, "n={n}: os={os} lu={lu}");
        }
    }

    #[test]
    fn et_beats_os_for_most_sizes_fixed_blocks() {
        // Paper Fig. 17 (fixed blocks b=192 for ET, b=256 for OS): ET
        // wins for most problem dimensions.
        let mut et_wins = 0;
        let mut total = 0;
        let mut n = 1000;
        while n <= 10000 {
            let et = simulate(&hw(), SimVariant::Et, n, 192, 32, 6, 1, false).gflops;
            let os = sim_os(&hw(), n, 256, 32, 6, false).gflops;
            if et > os {
                et_wins += 1;
            }
            total += 1;
            n += 1500;
        }
        assert!(
            et_wins * 2 > total,
            "ET should win most sizes: {et_wins}/{total}"
        );
    }

    #[test]
    fn os_more_sensitive_to_block_size_than_et() {
        // Paper Fig. 17: a suboptimal b_o hurts LU_OS visibly more than
        // LU_ET (whose ET mechanism adapts on the fly).
        let n = 3000;
        let sens = |f: &dyn Fn(usize) -> f64| {
            let at = |b: usize| f(b);
            let best = (1..=16)
                .map(|i| at(32 * i))
                .fold(0.0f64, f64::max);
            (best - at(448)) / best
        };
        let et_sens = sens(&|b| simulate(&hw(), SimVariant::Et, n, b, 32, 6, 1, false).gflops);
        let os_sens = sens(&|b| sim_os(&hw(), n, b, 32, 6, false).gflops);
        assert!(
            os_sens > et_sens,
            "os_sens={os_sens:.3} et_sens={et_sens:.3}"
        );
    }

    #[test]
    fn trace_spans_one_task_per_slot() {
        let out = sim_os(&hw(), 2000, 256, 32, 6, true);
        assert!(!out.spans.is_empty());
        // No two spans overlap on the same lane.
        let mut by_lane: std::collections::HashMap<usize, Vec<(f64, f64)>> =
            std::collections::HashMap::new();
        for s in &out.spans {
            by_lane.entry(s.lane).or_default().push((s.t0, s.t1));
        }
        for (lane, mut iv) in by_lane {
            iv.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in iv.windows(2) {
                assert!(w[0].1 <= w[1].0 + 1e-12, "overlap on lane {lane}");
            }
        }
    }

    #[test]
    fn single_worker_degrades_gracefully() {
        let out = sim_os(&hw(), 2000, 256, 32, 1, false);
        assert!(out.gflops > 1.0);
        let out6 = sim_os(&hw(), 2000, 256, 32, 6, false);
        assert!(out6.gflops > out.gflops);
    }
}
