//! Cost model of the paper's 6-core Xeon E5-2603 v3 (Haswell, 1.6 GHz).
//!
//! Calibration anchors:
//! - DP peak: 1.6 GHz × 16 flops/cycle (2×256-bit FMA) = 25.6 GFLOPS/core,
//!   153.6 GFLOPS for 6 cores.
//! - BLIS DGEMM sustains ≈ 80 % of peak on Haswell for large square
//!   operands (Van Zee et al., the paper's refs [20, 21]).
//! - GEPP (`m ≈ n ≫ k`, `k = b_o`) ramps with `k` and reaches its
//!   asymptote around `k ≈ 144`, with a mild drop just above `k = 256`
//!   because the optimal `k_c` equals 256 on this architecture (paper
//!   Fig. 14 + footnote 4).
//! - The unblocked panel kernels are latency/bandwidth bound, far from
//!   peak (the whole point of the paper); calibrated to ~1.5 GFLOPS.
//! - LASWP is pure data movement (paper §3.1: embarrassingly parallel,
//!   scales linearly).

/// Hardware + library throughput model. All rates in GFLOPS, times in
/// seconds. Each constant documents its units, where its default comes
/// from, and what to touch when calibrating against a different machine
/// — recalibration changes the simulated figures but never the serve
/// policy, because [`crate::serve::registry::Lease::starvation`] only
/// compares cost ratios.
#[derive(Copy, Clone, Debug)]
pub struct HwModel {
    /// Cores on the socket (count). Paper testbed: 6 (Xeon E5-2603 v3).
    pub cores: usize,
    /// Per-core sustained DGEMM rate for large operands (GFLOPS).
    /// Default 20.5 = 80 % of the 25.6 GFLOPS DP peak (1.6 GHz ×
    /// 16 flops/cycle), the BLIS-on-Haswell efficiency reported in the
    /// paper's refs [20, 21]. First knob to retune on new hardware:
    /// measure a large square DGEMM on one core and divide by 1e9.
    pub core_gemm_peak: f64,
    /// `k`-ramp constant (dimensionless, in units of `k`): GEPP
    /// efficiency `≈ 1 − exp(−k/k_ramp)`. Default 30 places ≥ 94 % of
    /// the asymptote at `k ≈ 144`, matching the paper's Fig. 14 "reaches
    /// its asymptotic peak around k = 144". Lower values sharpen the
    /// ramp; retune if a measured GEPP curve saturates elsewhere.
    pub k_ramp: f64,
    /// Optimal `k_c` (elements). Default 256 = the BLIS blocking for
    /// Haswell DP; `k` slightly above it pays a repacking penalty
    /// (paper footnote 4). Keep equal to the real `k_c` in use
    /// (`--params mc,kc,nc`), or the dip lands at the wrong `k`.
    pub kc: usize,
    /// Multiplicative throughput penalty (dimensionless, `< 1`) applied
    /// for `kc < k ≤ kc + 64` — the second packing pass is barely
    /// amortized there. Default 0.92, eyeballed from the magnitude of
    /// the Fig. 14 dip. Set to 1.0 to disable the effect.
    pub kc_dip: f64,
    /// Per-core rate of the unblocked panel kernels (GFLOPS). Default
    /// 2.5: the latency/bandwidth-bound regime of partial pivoting —
    /// an order of magnitude under `core_gemm_peak`, which is the
    /// premise of the whole paper. Raising it shrinks the panel/update
    /// imbalance and with it every WS/ET win; calibrate from a real
    /// unblocked `m × b_i` factorization, not from BLAS-3 numbers.
    pub unb_rate: f64,
    /// TRSM efficiency relative to GEPP at the same `k` (dimensionless,
    /// `0..1`). Default 0.7: triangular solves have half the ILP of
    /// GEMM per element and a thinner packing. Measured ratio of BLIS
    /// dtrsm/dgemm on Haswell rounds to this.
    pub trsm_eff: f64,
    /// Memory bandwidth per core for row swaps (GB/s), saturating at
    /// `bw_cores` cores. Default 6.0 ≈ 51 GB/s socket DRAM bandwidth
    /// shared by the cores that can usefully issue swap traffic. LASWP
    /// is pure data movement (paper §3.1), so only this pair — not any
    /// flop rate — prices it.
    pub bw_core: f64,
    /// Core count at which the swap bandwidth saturates (count).
    /// Default 4: the E5-2603 v3's DRAM channels saturate before all 6
    /// cores are issuing. `laswp_time` is flat beyond this.
    pub bw_cores: usize,
    /// Parallelization efficiency loss per extra thread (dimensionless
    /// per thread): `t` threads deliver `t / (1 + par_loss·(t−1))`.
    /// Default 0.015 makes 6 threads ≈ 5.6× — "scales well but not
    /// perfectly". Derived by fitting the paper's multi-thread GEPP
    /// points; raise it to model a NUMA or hyperthreaded penalty.
    pub par_loss: f64,
    /// Fixed overhead per kernel invocation (seconds) — job dispatch,
    /// packing setup. Default 2 µs ≈ one crew job publish + pickup on
    /// the real pool (bench_blis dispatch numbers). Only visible for
    /// tiny blocks; it is what makes shrinking `b_i` below ~8 a loss.
    pub kernel_overhead: f64,
    /// Overhead per task in the task-runtime baseline (seconds) —
    /// dependency bookkeeping, scheduling (the paper's "overhead of a
    /// runtime", §1). Default 3 µs, inside the 2–5 µs/task band of
    /// OmpSs-era runtimes. The `LU_OS`-vs-`LU_ET` gap at small `n`
    /// (Fig. 17) is proportional to `task_overhead − kernel_overhead`.
    pub task_overhead: f64,
}

impl Default for HwModel {
    fn default() -> Self {
        Self {
            cores: 6,
            core_gemm_peak: 20.5, // 80 % of 25.6
            k_ramp: 30.0,
            kc: 256,
            kc_dip: 0.92,
            unb_rate: 2.5,
            trsm_eff: 0.7,
            bw_core: 6.0,
            bw_cores: 4,
            par_loss: 0.015,
            kernel_overhead: 2e-6,
            task_overhead: 3e-6,
        }
    }
}

impl HwModel {
    /// Effective thread multiplier: `t` threads deliver slightly less
    /// than `t×` (paper's BLIS scales well but not perfectly).
    fn thread_scale(&self, t: usize) -> f64 {
        let t = t.max(1) as f64;
        t / (1.0 + self.par_loss * (t - 1.0))
    }

    /// GEPP throughput (GFLOPS) for `C(m×n) += A(m×k)·B(k×n)` with
    /// `m, n ≫ k`, on `t` threads — the paper's Fig. 14 (left) curve.
    pub fn gepp_gflops(&self, k: usize, t: usize) -> f64 {
        let k = k.max(1);
        let ramp = 1.0 - (-(k as f64) / self.k_ramp).exp();
        let dip = if k > self.kc && k <= self.kc + 64 {
            self.kc_dip
        } else if k > self.kc + 64 {
            // second k_c pass amortizes again
            0.97
        } else {
            1.0
        };
        self.core_gemm_peak * self.thread_scale(t) * ramp * dip
    }

    /// Efficiency of a GEMM only `n` columns wide: the `A_c` packing is
    /// amortized over fewer micro-panels (the re-packing/data-movement
    /// overhead the paper attributes to chopped-up GEMMs, §4.1.1, §4.3).
    pub fn width_eff(&self, n: usize) -> f64 {
        let n = n as f64;
        n / (n + 24.0)
    }

    /// Time for a GEMM of `m×n×k` on `t` threads at GEPP rate.
    pub fn gemm_time(&self, m: usize, n: usize, k: usize, t: usize) -> f64 {
        if m == 0 || n == 0 || k == 0 {
            return 0.0;
        }
        let fl = crate::util::gemm_flops(m, n, k);
        self.kernel_overhead + fl / (self.gepp_gflops(k, t) * self.width_eff(n) * 1e9)
    }

    /// Time for the unit-lower TRSM `B(k×n) := TRILU(A)⁻¹B` on `t`
    /// threads.
    pub fn trsm_time(&self, k: usize, n: usize, t: usize) -> f64 {
        if k == 0 || n == 0 {
            return 0.0;
        }
        let fl = crate::util::trsm_flops(k, n);
        let rate = self.gepp_gflops(k, t) * self.trsm_eff;
        self.kernel_overhead + fl / (rate * 1e9)
    }

    /// Time to apply `b` row interchanges across `cols` columns on `t`
    /// threads (bandwidth bound; 2 loads + 2 stores per element pair).
    pub fn laswp_time(&self, b: usize, cols: usize, t: usize) -> f64 {
        if b == 0 || cols == 0 {
            return 0.0;
        }
        let bytes = (b * cols * 32) as f64;
        let bw = self.bw_core * 1e9 * t.min(self.bw_cores) as f64;
        self.kernel_overhead + bytes / bw
    }

    /// Time of the *unblocked* factorization of an `m × b` block on one
    /// thread (`≈ m·b²` flops at the latency-bound rate).
    pub fn unblocked_time(&self, m: usize, b: usize) -> f64 {
        if m == 0 || b == 0 {
            return 0.0;
        }
        let b_f = b as f64;
        let fl = (m as f64) * b_f * b_f - b_f * b_f * b_f / 3.0;
        self.kernel_overhead + fl.max(0.0) / (self.unb_rate * 1e9)
    }

    /// Time of a blocked *panel* factorization of `m × b` with inner
    /// block `bi` on `t` threads — the sum of its inner steps (unblocked
    /// leaf + small TRSM + thin GEMM), i.e. exactly the recurrence the
    /// real `panel_rl`/`panel_ll` execute. Only the GEMM/TRSM parts
    /// parallelize; the unblocked leaf is single-threaded (paper Fig. 4:
    /// "less active threads for RL1").
    pub fn panel_time(&self, m: usize, b: usize, bi: usize, t: usize) -> f64 {
        // Thin inner kernels barely scale: the paper's traces (Figs. 4-5)
        // show the panel with "less active threads". The usable team
        // grows with the panel width (paper §5.1: large blocks turn the
        // panel into "a BLAS-3 operation with a mild degree of
        // parallelism").
        let t = t.min(1 + b / 128);
        let bi = bi.max(1).min(b.max(1));
        let mut total = 0.0;
        let mut j = 0;
        while j < b {
            let bb = bi.min(b - j);
            let rows = m.saturating_sub(j);
            if rows == 0 {
                break;
            }
            total += self.unblocked_time(rows, bb);
            let rest = b - j - bb;
            if rest > 0 {
                total += self.trsm_time(bb, rest, t);
                total += self.gemm_time(rows.saturating_sub(bb), rest, bb, t);
                total += self.laswp_time(bb, b, t.min(2));
            }
            j += bb;
        }
        total
    }

    /// Per-inner-block times of a *left-looking* panel factorization —
    /// used by the ET simulator to find where the flag poll cuts.
    /// Returns the time of each `bi` step (step `s` covers columns
    /// `s·bi ..`).
    pub fn panel_ll_steps(&self, m: usize, b: usize, bi: usize, t: usize) -> Vec<f64> {
        let t = t.min(1 + b / 128);
        let bi = bi.max(1).min(b.max(1));
        let mut steps = Vec::new();
        let mut j = 0;
        while j < b {
            let bb = bi.min(b - j);
            let rows = m.saturating_sub(j);
            if rows == 0 {
                break;
            }
            let mut t_step = 0.0;
            if j > 0 {
                t_step += self.laswp_time(j, bb, t.min(2));
                t_step += self.trsm_time(j, bb, t);
                t_step += self.gemm_time(rows, bb, j, t);
            }
            t_step += self.unblocked_time(rows, bb);
            steps.push(t_step);
            j += bb;
        }
        steps
    }

    /// Largest problem size `n` the interleaved small-batch fast path
    /// (DESIGN.md §18) should handle for a bundle of `lanes` problems —
    /// the serve layer's routing threshold between
    /// `Strategy::Interleaved` and `Strategy::PerProblem`.
    ///
    /// Two bounds intersect:
    ///
    /// * **Capacity**: a bundle interleaves every element of `lanes`
    ///   problems into one 256-bit vector, so its working set is
    ///   `n² × 32` bytes regardless of precision (4 `f64` lanes and
    ///   8 `f32` lanes both fill 32 bytes per element). Keeping the
    ///   whole bundle within half of a 256 KiB per-core L2 (the other
    ///   half for pivot traffic and the response path) caps `n` at
    ///   `√(128 KiB / 32) = 64`.
    /// * **Profitability**: the interleaved kernel amortizes one
    ///   dispatch over `lanes` problems, so its per-problem cost is
    ///   `≈ unblocked_time(n, n) / lanes + kernel_overhead / lanes`
    ///   versus `unblocked_time(n, n)` one-at-a-time — a win at every
    ///   `n` below the capacity bound (the scan below keeps the bound
    ///   honest if the overhead constants are recalibrated).
    ///
    /// With the default model this returns 64 for any `lanes ≥ 2`,
    /// matching the ROADMAP's "small systems (n ≤ 64)".
    pub fn small_threshold(&self, lanes: usize) -> usize {
        if lanes < 2 {
            return 0; // no lanes to amortize over — nothing is "small"
        }
        let cap = 64; // √(128 KiB / 32 bytes-per-element-bundle)
        let lanes_f = lanes as f64;
        // Contiguous prefix of profitable sizes: routing must be a single
        // threshold, so stop at the first n where bundling loses.
        (1..=cap)
            .take_while(|&n| {
                let solo = self.unblocked_time(n, n);
                // One dispatch and one pass of pack/unpack copies
                // (priced as a second dispatch) amortize over the lanes.
                let bundled = (solo + self.kernel_overhead) / lanes_f;
                bundled < solo
            })
            .last()
            .unwrap_or(0)
    }

    /// Aggregate DGEMM peak of the machine (`t = cores`).
    pub fn machine_peak(&self) -> f64 {
        self.core_gemm_peak * self.cores as f64
    }

    /// Recalibrate `core_gemm_peak` — the model's first knob (see its
    /// field docs) — from one **measured** GEMM: `C(m×n) += A(m×k)·B(k×n)`
    /// on `t` threads took `measured_secs`. Returns a copy of the model
    /// whose [`HwModel::gemm_time`] reproduces the measurement exactly
    /// at the anchor shape; every other constant keeps its paper-derived
    /// value, so the model's *shape* (k-ramp, `k_c` dip, thread scaling,
    /// width efficiency) is preserved and only the absolute rate moves.
    ///
    /// This is the documented remedy for cost-model drift between the
    /// simulated and the benched GFLOPS: anchor on a measured rate, then
    /// cross-check other shapes against the calibrated model —
    /// `tests/sim_calib.rs` pins both the exact inversion and the
    /// cross-shape agreement, and the counterfactual sweeps of
    /// `mlu replay` (DESIGN.md §16.6) price captured traces through the
    /// same model. Degenerate anchors (zero dims, a measurement at or
    /// under the fixed kernel overhead) leave the model unchanged.
    pub fn calibrate_from_gemm(
        &self,
        m: usize,
        n: usize,
        k: usize,
        t: usize,
        measured_secs: f64,
    ) -> HwModel {
        let mut hw = *self;
        if m == 0 || n == 0 || k == 0 {
            return hw;
        }
        let useful = measured_secs - self.kernel_overhead;
        if useful <= 0.0 {
            return hw;
        }
        let fl = crate::util::gemm_flops(m, n, k);
        let needed = fl / (useful * self.width_eff(n) * 1e9);
        let current = self.gepp_gflops(k, t);
        if needed > 0.0 && current > 0.0 {
            hw.core_gemm_peak = self.core_gemm_peak * needed / current;
        }
        hw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gepp_ramps_and_saturates_near_144() {
        let hw = HwModel::default();
        let g32 = hw.gepp_gflops(32, 6);
        let g96 = hw.gepp_gflops(96, 6);
        let g144 = hw.gepp_gflops(144, 6);
        let g192 = hw.gepp_gflops(192, 6);
        assert!(g32 < g96 && g96 < g144 && g144 < g192);
        // 144 reaches ≥ 94 % of the asymptote (paper: "asymptotic
        // performance peak for k around 144").
        assert!(g144 / hw.gepp_gflops(256, 6) > 0.94);
        // Paper footnote 4: performance drop for k slightly above 256.
        assert!(hw.gepp_gflops(288, 6) < hw.gepp_gflops(256, 6));
    }

    #[test]
    fn six_thread_peak_is_plausible_for_the_xeon() {
        let hw = HwModel::default();
        let peak = hw.gepp_gflops(256, 6);
        assert!(peak > 90.0 && peak < 153.6, "peak={peak}");
    }

    #[test]
    fn threads_scale_sublinearly() {
        let hw = HwModel::default();
        let g1 = hw.gepp_gflops(256, 1);
        let g6 = hw.gepp_gflops(256, 6);
        assert!(g6 > 5.0 * g1 && g6 < 6.0 * g1);
    }

    #[test]
    fn panel_is_far_from_gemm_rate() {
        // The premise of the paper: the panel's effective rate is tiny
        // compared to GEPP.
        let hw = HwModel::default();
        let b = 256;
        let m = 5000;
        let t_panel = hw.panel_time(m, b, 32, 1);
        let fl = (m as f64) * (b as f64) * (b as f64);
        let rate = fl / t_panel / 1e9;
        assert!(rate < 0.5 * hw.gepp_gflops(b, 1), "panel rate {rate}");
    }

    #[test]
    fn panel_ll_steps_sum_close_to_panel_time() {
        let hw = HwModel::default();
        let (m, b, bi) = (4000, 256, 32);
        let steps = hw.panel_ll_steps(m, b, bi, 1);
        assert_eq!(steps.len(), b / bi);
        let sum: f64 = steps.iter().sum();
        let rl = hw.panel_time(m, b, bi, 1);
        // LL re-groups the same flops; totals agree within model slack.
        let ratio = sum / rl;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
        // Later LL steps are more expensive (more accumulated update).
        assert!(steps[steps.len() - 1] > steps[0]);
    }

    #[test]
    fn zero_dims_cost_nothing() {
        let hw = HwModel::default();
        assert_eq!(hw.gemm_time(0, 10, 10, 6), 0.0);
        assert_eq!(hw.trsm_time(10, 0, 6), 0.0);
        assert_eq!(hw.laswp_time(0, 10, 6), 0.0);
        assert_eq!(hw.unblocked_time(10, 0), 0.0);
    }

    #[test]
    fn calibrate_from_gemm_inverts_exactly_and_keeps_the_shape() {
        let hw = HwModel::default();
        let (m, n, k, t) = (256, 256, 64, 1);
        // Pretend the machine measured 10 ms for this GEMM: the
        // calibrated model must reproduce that measurement exactly …
        let measured = 0.010;
        let cal = hw.calibrate_from_gemm(m, n, k, t, measured);
        let predicted = cal.gemm_time(m, n, k, t);
        assert!(
            (predicted - measured).abs() / measured < 1e-9,
            "anchor not inverted: predicted {predicted}, measured {measured}"
        );
        // … while preserving every shape ratio (only the absolute rate
        // moved).
        for kk in [16usize, 96, 256, 320] {
            let before = hw.gepp_gflops(kk, 6) / hw.gepp_gflops(64, 6);
            let after = cal.gepp_gflops(kk, 6) / cal.gepp_gflops(64, 6);
            assert!((before - after).abs() < 1e-12, "shape moved at k={kk}");
        }
        // Degenerate anchors leave the model untouched.
        let same = hw.calibrate_from_gemm(0, 256, 64, 1, measured);
        assert_eq!(same.core_gemm_peak, hw.core_gemm_peak);
        let same = hw.calibrate_from_gemm(m, n, k, t, hw.kernel_overhead / 2.0);
        assert_eq!(same.core_gemm_peak, hw.core_gemm_peak);
    }

    #[test]
    fn small_threshold_matches_roadmap_bound() {
        let hw = HwModel::default();
        // The default model routes n ≤ 64 through the interleaved path
        // for both bundle widths (ROADMAP: "small systems (n ≤ 64)").
        assert_eq!(hw.small_threshold(4), 64);
        assert_eq!(hw.small_threshold(8), 64);
        // A single lane has nothing to amortize over.
        assert_eq!(hw.small_threshold(1), 0);
        assert_eq!(hw.small_threshold(0), 0);
        // The capacity bound caps the threshold no matter how cheap
        // dispatch gets.
        let mut fast = hw;
        fast.kernel_overhead = 0.0;
        assert!(fast.small_threshold(8) <= 64);
    }

    #[test]
    fn laswp_scales_with_threads() {
        let hw = HwModel::default();
        let t1 = hw.laswp_time(256, 10_000, 1);
        let t4 = hw.laswp_time(256, 10_000, 4);
        assert!(t1 / t4 > 3.5 && t1 / t4 < 4.5);
        // saturates beyond bw_cores
        assert_eq!(hw.laswp_time(256, 10_000, 6), t4);
    }
}
