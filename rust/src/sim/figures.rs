//! Generators for every figure of the paper's evaluation (§5), in
//! simulated virtual time. Each returns a structured table plus a CSV
//! rendering, and is exposed through `mlu fig <N>` and the bench harness.

use super::costmodel::HwModel;
use super::lu_sim::{simulate, SimVariant};

/// A generic series table: named columns, numeric rows.
#[derive(Clone, Debug)]
pub struct Table {
    /// Human-readable caption (figure number + axes).
    pub title: String,
    /// Column names, one per entry of each row.
    pub columns: Vec<String>,
    /// Numeric data rows.
    pub rows: Vec<Vec<f64>>,
}

impl Table {
    /// Render as CSV with a `# title` header line.
    pub fn to_csv(&self) -> String {
        let mut s = format!("# {}\n{}\n", self.title, self.columns.join(","));
        for r in &self.rows {
            let cells: Vec<String> = r.iter().map(|v| format!("{v:.4}")).collect();
            s.push_str(&cells.join(","));
            s.push('\n');
        }
        s
    }

    /// Column index by name (panics if missing — generator bug).
    pub fn col(&self, name: &str) -> usize {
        self.columns
            .iter()
            .position(|c| c == name)
            .unwrap_or_else(|| panic!("no column {name}"))
    }
}

/// The sweep grids of the paper (§5: n = 500..12000 step 500;
/// b_o = 32..512 step 32). `scale < 1.0` shrinks the grids for quick
/// runs.
pub struct Grids {
    /// Problem sizes `n` to sweep.
    pub ns: Vec<usize>,
    /// Outer block sizes `b_o` to sweep.
    pub bos: Vec<usize>,
}

impl Grids {
    /// The full grids of the paper's evaluation.
    pub fn paper() -> Self {
        Self {
            ns: (1..=24).map(|i| i * 500).collect(),
            bos: (1..=16).map(|i| i * 32).collect(),
        }
    }

    /// Coarser grid for fast CI runs.
    pub fn quick() -> Self {
        Self {
            ns: vec![500, 1000, 2000, 4000, 6000, 8000, 10000, 12000],
            bos: vec![32, 64, 96, 128, 192, 256, 320, 384, 448, 512],
        }
    }
}

/// Fig. 14 (left): GEPP GFLOPS as a function of `k = b_o`, 6 threads.
pub fn fig14_gepp(hw: &HwModel, grids: &Grids) -> Table {
    let mut rows = Vec::new();
    for &k in &grids.bos {
        rows.push(vec![k as f64, hw.gepp_gflops(k, hw.cores)]);
    }
    Table {
        title: "Fig14-left: GEPP GFLOPS vs k (6 threads)".into(),
        columns: vec!["k".into(), "gflops".into()],
        rows,
    }
}

/// Fig. 14 (right): ratio of panel flops to total flops vs `n`, one
/// series per `b_o` in {32, 128, 256, 512}.
pub fn fig14_ratio(_hw: &HwModel, grids: &Grids) -> Table {
    let bos = [32usize, 128, 256, 512];
    let mut rows = Vec::new();
    for &n in &grids.ns {
        let mut row = vec![n as f64];
        for &b in &bos {
            row.push(super::flops::panel_ratio(n, b));
        }
        rows.push(row);
    }
    Table {
        title: "Fig14-right: panel flops / total flops".into(),
        columns: std::iter::once("n".to_string())
            .chain(bos.iter().map(|b| format!("b{b}")))
            .collect(),
        rows,
    }
}

/// Fig. 15: optimal `b_o` per variant per problem size.
pub fn fig15_optimal_b(hw: &HwModel, grids: &Grids, t: usize) -> Table {
    let variants = [
        SimVariant::Lu,
        SimVariant::La,
        SimVariant::Mb,
        SimVariant::Et,
        SimVariant::Os,
    ];
    let mut rows = Vec::new();
    for &n in &grids.ns {
        let mut row = vec![n as f64];
        for v in variants {
            let (best_b, _) = optimal_block(hw, v, n, &grids.bos, t);
            row.push(best_b as f64);
        }
        rows.push(row);
    }
    Table {
        title: "Fig15: optimal b_o per variant".into(),
        columns: vec![
            "n".into(),
            "LU".into(),
            "LU_LA".into(),
            "LU_MB".into(),
            "LU_ET".into(),
            "LU_OS".into(),
        ],
        rows,
    }
}

/// Best `(b_o, gflops)` over the block grid for one variant/size.
pub fn optimal_block(
    hw: &HwModel,
    v: SimVariant,
    n: usize,
    bos: &[usize],
    t: usize,
) -> (usize, f64) {
    let mut best = (bos[0], f64::MIN);
    for &b in bos {
        let g = simulate(hw, v, n, b, 32, t, 1, false).gflops;
        if g > best.1 {
            best = (b, g);
        }
    }
    best
}

/// Fig. 16: GFLOPS of LU / LU_LA / LU_MB / LU_ET at fixed `b_o = 256`.
pub fn fig16_variants(hw: &HwModel, grids: &Grids, t: usize) -> Table {
    let variants = [
        SimVariant::Lu,
        SimVariant::La,
        SimVariant::Mb,
        SimVariant::Et,
    ];
    let mut rows = Vec::new();
    for &n in &grids.ns {
        let mut row = vec![n as f64];
        for v in variants {
            row.push(simulate(hw, v, n, 256, 32, t, 1, false).gflops);
        }
        rows.push(row);
    }
    Table {
        title: "Fig16: GFLOPS, static look-ahead variants, b_o=256".into(),
        columns: vec![
            "n".into(),
            "LU".into(),
            "LU_LA".into(),
            "LU_MB".into(),
            "LU_ET".into(),
        ],
        rows,
    }
}

/// Fig. 17: LU_ET vs LU_OS — per-size optimal blocks and fixed blocks
/// (192 for ET, 256 for OS), as in the paper.
pub fn fig17_et_vs_os(hw: &HwModel, grids: &Grids, t: usize) -> Table {
    let mut rows = Vec::new();
    for &n in &grids.ns {
        let (_, et_opt) = optimal_block(hw, SimVariant::Et, n, &grids.bos, t);
        let (_, os_opt) = optimal_block(hw, SimVariant::Os, n, &grids.bos, t);
        let et_fixed = simulate(hw, SimVariant::Et, n, 192, 32, t, 1, false).gflops;
        let os_fixed = simulate(hw, SimVariant::Os, n, 256, 32, t, 1, false).gflops;
        rows.push(vec![n as f64, et_opt, os_opt, et_fixed, os_fixed]);
    }
    Table {
        title: "Fig17: LU_ET vs LU_OS (b_opt and fixed b)".into(),
        columns: vec![
            "n".into(),
            "ET(b_opt)".into(),
            "OS(b_opt)".into(),
            "ET(b=192)".into(),
            "OS(b=256)".into(),
        ],
        rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwModel {
        HwModel::default()
    }

    #[test]
    fn fig14_left_monotone_then_flat() {
        let t = fig14_gepp(&hw(), &Grids::quick());
        let g = t.col("gflops");
        // Strictly increasing up to 192.
        for w in t.rows.windows(2) {
            if w[1][0] <= 192.0 {
                assert!(w[1][g] > w[0][g]);
            }
        }
        assert_eq!(t.columns.len(), 2);
        assert!(t.to_csv().contains("gflops"));
    }

    #[test]
    fn fig14_right_series_ordering() {
        let t = fig14_ratio(&hw(), &Grids::quick());
        // Larger b ⇒ larger panel share, every n.
        for r in &t.rows {
            assert!(r[1] < r[2] && r[2] < r[3] && r[3] < r[4], "row {r:?}");
        }
    }

    #[test]
    fn fig15_trends() {
        let grids = Grids {
            ns: vec![2000, 6000, 10000],
            bos: vec![32, 64, 96, 128, 160, 192, 256, 320, 384, 448, 512],
        };
        let t = fig15_optimal_b(&hw(), &grids, 6);
        let (lu, mb) = (t.col("LU"), t.col("LU_MB"));
        // Paper Fig. 15: LU prefers larger blocks than LU_MB for all
        // problem dimensions shown.
        for r in &t.rows {
            assert!(r[lu] >= r[mb], "n={}: LU {} < MB {}", r[0], r[lu], r[mb]);
        }
    }

    #[test]
    fn fig16_orderings() {
        let grids = Grids {
            ns: vec![1000, 4000, 6000, 10000, 12000],
            bos: vec![256],
        };
        let t = fig16_variants(&hw(), &grids, 6);
        let (lu, la, mb, et) = (t.col("LU"), t.col("LU_LA"), t.col("LU_MB"), t.col("LU_ET"));
        for r in &t.rows {
            let n = r[0] as usize;
            if (4000..=10000).contains(&n) {
                assert!(r[la] > r[lu], "n={n}: LA !> LU");
            } else if n > 10000 {
                // The curves converge at the top end (paper Fig. 16:
                // LU keeps rising while LU_LA flattens).
                assert!(r[la] > 0.97 * r[lu], "n={n}: LA ≪ LU");
            }
            if n >= 6000 {
                assert!(r[mb] >= r[la], "n={n}: MB !>= LA");
            }
            // ET never loses to MB (it only cuts when beneficial).
            assert!(r[et] >= r[mb] * 0.995, "n={n}: ET ≪ MB");
        }
        // ET's edge is at the small end.
        let small = &t.rows[0];
        assert!(small[et] > small[la], "small-n: ET !> LA");
    }

    #[test]
    fn fig17_et_robust_to_block_choice() {
        let grids = Grids {
            ns: vec![1500, 3000, 6000, 9000, 12000],
            bos: vec![64, 128, 192, 256, 320, 384],
        };
        let t = fig17_et_vs_os(&hw(), &grids, 6);
        let (eo, oo, ef, of) = (
            t.col("ET(b_opt)"),
            t.col("OS(b_opt)"),
            t.col("ET(b=192)"),
            t.col("OS(b=256)"),
        );
        let mut et_wins = 0;
        for r in &t.rows {
            // Fixed-block ET stays close to its optimum...
            assert!(r[ef] / r[eo] > 0.90, "n={}: ET fixed/opt {}", r[0], r[ef] / r[eo]);
            if r[eo] > r[oo] {
                et_wins += 1;
            }
            // ...and the fixed-block penalty hits OS harder (paper §5.3).
            let et_pen = 1.0 - r[ef] / r[eo];
            let os_pen = 1.0 - r[of] / r[oo];
            assert!(os_pen >= et_pen - 0.02, "n={}", r[0]);
        }
        assert!(et_wins * 2 > t.rows.len(), "ET wins most: {et_wins}/{}", t.rows.len());
    }
}
