//! Virtual-time simulation of the LU variants.
//!
//! Each simulator replays the *same* control flow as its real
//! counterpart in [`crate::lu`] — iteration structure, team split,
//! WS merge points, ET polls at inner-block boundaries — pricing each
//! building block with the [`HwModel`]. Only square matrices are
//! simulated (the paper's workload).

use super::costmodel::HwModel;
use crate::trace::{Kind, Span};

/// Simulated algorithm.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SimVariant {
    /// Blocked RL, BDP only (`LU`).
    Lu,
    /// Static look-ahead (`LU_LA`).
    La,
    /// Look-ahead + malleable BLAS (`LU_MB`).
    Mb,
    /// Look-ahead + malleable BLAS + early termination (`LU_ET`).
    Et,
    /// Task-runtime baseline (`LU_OS`) — see [`super::os_sim`].
    Os,
}

impl SimVariant {
    /// Paper-style display name (`LU`, `LU_LA`, ...).
    pub fn name(&self) -> &'static str {
        match self {
            SimVariant::Lu => "LU",
            SimVariant::La => "LU_LA",
            SimVariant::Mb => "LU_MB",
            SimVariant::Et => "LU_ET",
            SimVariant::Os => "LU_OS",
        }
    }

    /// Parse a variant name (`lu`, `la`, `mb`, `et`, `os`).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "lu" => SimVariant::Lu,
            "la" | "lu_la" => SimVariant::La,
            "mb" | "lu_mb" => SimVariant::Mb,
            "et" | "lu_et" => SimVariant::Et,
            "os" | "lu_os" => SimVariant::Os,
            _ => return None,
        })
    }
}

/// Simulation result.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    /// Virtual makespan in seconds.
    pub time: f64,
    /// `2n³/3 / time` in GFLOPS (the paper's metric).
    pub gflops: f64,
    /// Outer iterations simulated.
    pub iters: usize,
    /// ET cuts (Et variant only).
    pub et_cuts: usize,
    /// Virtual-time trace spans (populated when `with_trace`).
    pub spans: Vec<Span>,
}

/// Simulate a variant on an `n × n` matrix. `t` = total threads,
/// `t_pf` of which form the panel team for the look-ahead variants.
pub fn simulate(
    hw: &HwModel,
    v: SimVariant,
    n: usize,
    bo: usize,
    bi: usize,
    t: usize,
    t_pf: usize,
    with_trace: bool,
) -> SimOutcome {
    match v {
        SimVariant::Lu => sim_lu(hw, n, bo, bi, t, with_trace),
        SimVariant::La => sim_la(hw, n, bo, bi, t, t_pf, false, false, with_trace),
        SimVariant::Mb => sim_la(hw, n, bo, bi, t, t_pf, true, false, with_trace),
        SimVariant::Et => sim_la(hw, n, bo, bi, t, t_pf, true, true, with_trace),
        SimVariant::Os => super::os_sim::sim_os(hw, n, bo, bi, t, with_trace),
    }
}

fn outcome(n: usize, time: f64, iters: usize, et_cuts: usize, spans: Vec<Span>) -> SimOutcome {
    SimOutcome {
        time,
        gflops: crate::util::gflops(super::flops::lu_total(n), time),
        iters,
        et_cuts,
        spans,
    }
}

/// Push a span across lanes `[l0, l1)`.
#[allow(clippy::too_many_arguments)]
fn push_span(
    spans: &mut Vec<Span>,
    on: bool,
    l0: usize,
    l1: usize,
    kind: Kind,
    label: &str,
    t0: f64,
    t1: f64,
) {
    if !on || t1 <= t0 {
        return;
    }
    for lane in l0..l1 {
        spans.push(Span {
            lane,
            kind,
            label: label.to_string(),
            t0,
            t1,
        });
    }
}

/// Plain blocked RL (`LU`): every kernel runs with the full team; the
/// panel sits on the critical path (paper Figs. 4–5).
fn sim_lu(hw: &HwModel, n: usize, bo: usize, bi: usize, t: usize, tr: bool) -> SimOutcome {
    let bo = bo.max(1);
    let mut time = 0.0;
    let mut iters = 0;
    let mut spans = Vec::new();
    let mut k = 0;
    while k < n {
        let b = bo.min(n - k);
        let rows = n - k;
        let rest = n - k - b;
        iters += 1;
        // Panel: the unblocked leaf limits concurrency to ~1 thread; the
        // inner TRSM/GEMM use the team.
        let tp = hw.panel_time(rows, b, bi, t);
        push_span(&mut spans, tr, 0, 1, Kind::Panel, "PANEL", time, time + tp);
        push_span(&mut spans, tr, 1, t, Kind::Wait, "idle", time, time + tp);
        time += tp;
        let ts = hw.laswp_time(b, n - b, t);
        push_span(&mut spans, tr, 0, t, Kind::Swap, "LASWP", time, time + ts);
        time += ts;
        if rest > 0 {
            let tt = hw.trsm_time(b, rest, t);
            push_span(&mut spans, tr, 0, t, Kind::Trsm, "TRSM", time, time + tt);
            time += tt;
            let tg = hw.gemm_time(rows - b, rest, b, t);
            push_span(&mut spans, tr, 0, t, Kind::Gemm, "GEMM", time, time + tg);
            time += tg;
        }
        k += b;
    }
    outcome(n, time, iters, 0, spans)
}

/// Look-ahead family. Replicates `lu::lookahead::lu_lookahead`'s state
/// machine: current panel `[f, f+bc)`, next panel `P`, remainder `R`.
#[allow(clippy::too_many_arguments)]
fn sim_la(
    hw: &HwModel,
    n: usize,
    bo: usize,
    bi: usize,
    t: usize,
    t_pf: usize,
    malleable: bool,
    early_term: bool,
    tr: bool,
) -> SimOutcome {
    let bo = bo.max(1).min(n.max(1));
    let t_pf = t_pf.max(1).min(t.saturating_sub(1).max(1));
    let t_ru = t - t_pf;
    let mut spans = Vec::new();
    let mut iters = 0;
    let mut et_cuts = 0;

    // Prologue: first panel with the full team.
    let b0 = bo.min(n);
    let mut time = hw.panel_time(n, b0, bi, t);
    push_span(&mut spans, tr, 0, t, Kind::Panel, "panel[0]", 0.0, time);

    let mut f = 0usize;
    let mut bc = b0;
    // ET's adaptive attempted width (mirrors lu::lookahead).
    let mut attempt = bo;

    loop {
        let right0 = f + bc;
        if right0 >= n {
            // Epilogue: lazy left swaps of the last panel.
            time += hw.laswp_time(bc, f, t);
            break;
        }
        iters += 1;
        let bn = attempt.min(n - right0);
        let r_cols = n - right0 - bn;
        let rows_below = n - right0;

        // ---- T_PF timeline (t_pf threads) ----
        let pf_swap = hw.laswp_time(bc, bn, t_pf.min(2));
        let pf_trsm = hw.trsm_time(bc, bn, t_pf);
        let pf_gemm = hw.gemm_time(rows_below, bn, bc, t_pf);
        let pf_pre = pf_swap + pf_trsm + pf_gemm;

        // ---- T_RU timeline (t_ru threads) ----
        let ru_swap = hw.laswp_time(bc, r_cols, t_ru.min(hw.bw_cores))
            + hw.laswp_time(bc, f, t_ru.min(hw.bw_cores)); // lazy left swaps
        let ru_trsm = hw.trsm_time(bc, r_cols, t_ru);
        let ru_gemm = hw.gemm_time(rows_below, r_cols, bc, t_ru);
        let ru_total = ru_swap + ru_trsm + ru_gemm;

        // Panel factorization of P.
        let (pf_total, k_done, cut) = if early_term && r_cols > 0 {
            // LL inner; walk the per-block costs and poll the flag
            // (raised at ru_total) at each block boundary.
            let steps = hw.panel_ll_steps(rows_below, bn, bi, t_pf);
            let mut acc = pf_pre;
            let mut done_cols = 0usize;
            let mut cut = false;
            for (s, dt) in steps.iter().enumerate() {
                acc += dt;
                done_cols = ((s + 1) * bi.max(1)).min(bn);
                // Poll: flag set and at least one block done and blocks
                // remain => abort (mirrors `panel_ll`).
                if done_cols < bn && acc >= ru_total {
                    cut = true;
                    break;
                }
            }
            (acc, done_cols, cut)
        } else {
            (pf_pre + hw.panel_time(rows_below, bn, bi, t_pf), bn, false)
        };
        if cut {
            et_cuts += 1;
            attempt = k_done.max(bi.max(1));
        } else if early_term {
            attempt = (attempt + bi.max(1)).min(bo);
        }

        // ---- Merge semantics ----
        let iter_time = if pf_total <= ru_total && malleable {
            // WS: PF threads join RU's GEMM at the next Loop-3 entry.
            // Remaining RU-GEMM work (1-thread-seconds) at join time:
            let g_start = ru_swap + ru_trsm;
            if pf_total <= g_start {
                // Whole GEMM runs with the merged team.
                let merged = hw.gemm_time(rows_below, r_cols, bc, t);
                g_start.max(pf_total) + merged
            } else {
                let g_len = ru_gemm;
                let frac_left = ((ru_total - pf_total) / g_len.max(1e-30)).clamp(0.0, 1.0);
                // Work left, re-rated from t_ru to t threads:
                let left_merged = hw.gemm_time(rows_below, r_cols, bc, t) * frac_left;
                // Entry-point quantization: joiners wait for the next
                // i_c iteration (≈ one mc-row slice of the GEMM).
                let entry_lag = hw.gemm_time(96, r_cols.min(4096), bc, t_ru) * 0.5;
                pf_total + entry_lag.min(ru_total - pf_total) + left_merged
            }
        } else if pf_total <= ru_total {
            // LU_LA: PF team idles until RU completes.
            ru_total
        } else {
            // PF is slower. LA/MB: RU idles (paper Fig. 9). ET: the cut
            // already bounded pf_total near ru_total.
            pf_total
        };

        // Trace spans for this iteration.
        push_span(&mut spans, tr, 0, 1, Kind::Swap, "PF1.swap", time, time + pf_swap);
        let t_pf_trsm = time + pf_swap + pf_trsm;
        push_span(&mut spans, tr, 0, 1, Kind::Trsm, "PF1.trsm", time + pf_swap, t_pf_trsm);
        push_span(&mut spans, tr, 0, 1, Kind::Gemm, "PF2.gemm", t_pf_trsm, time + pf_pre);
        push_span(&mut spans, tr, 0, 1, Kind::Panel, "PF3.panel", time + pf_pre, time + pf_total);
        push_span(&mut spans, tr, t_pf, t, Kind::Swap, "RU1.swap", time, time + ru_swap);
        let t_ru_trsm = time + ru_swap + ru_trsm;
        push_span(&mut spans, tr, t_pf, t, Kind::Trsm, "RU1.trsm", time + ru_swap, t_ru_trsm);
        let ru_end = time + ru_total.min(iter_time);
        push_span(&mut spans, tr, t_pf, t, Kind::Gemm, "RU2.gemm", t_ru_trsm, ru_end);
        if malleable && pf_total < iter_time {
            let (a, b) = (time + pf_total, time + iter_time);
            push_span(&mut spans, tr, 0, 1, Kind::Gemm, "WS:RU2.gemm", a, b);
        } else if pf_total < iter_time {
            push_span(&mut spans, tr, 0, 1, Kind::Wait, "idle", time + pf_total, time + iter_time);
        }
        if ru_total < iter_time {
            let (a, b) = (time + ru_total, time + iter_time);
            push_span(&mut spans, tr, t_pf, t, Kind::Wait, "idle", a, b);
        }

        time += iter_time;
        f = right0;
        bc = k_done;
    }

    outcome(n, time, iters, et_cuts, spans)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwModel {
        HwModel::default()
    }

    fn gf(v: SimVariant, n: usize, bo: usize) -> f64 {
        simulate(&hw(), v, n, bo, 32, 6, 1, false).gflops
    }

    #[test]
    fn lookahead_beats_plain_lu_midrange() {
        // Paper Fig. 16: "except for the smallest problems, integrating
        // look-ahead clearly improves performance" (and for the smallest,
        // plain LU wins — also asserted).
        assert!(gf(SimVariant::Lu, 1000, 256) > gf(SimVariant::La, 1000, 256));
        for n in [4000usize, 6000, 8000, 10000] {
            assert!(
                gf(SimVariant::La, n, 256) > gf(SimVariant::Lu, n, 256),
                "n={n}"
            );
        }
    }

    #[test]
    fn malleable_beats_la_for_large_problems() {
        // Paper Fig. 16: LU_MB > LU_LA for larger problems (T_RU grows
        // cubically vs the panel's quadratic cost).
        for n in [6000usize, 8000, 10000, 12000] {
            assert!(
                gf(SimVariant::Mb, n, 256) > gf(SimVariant::La, n, 256),
                "n={n}"
            );
        }
    }

    #[test]
    fn et_wins_small_problems_ties_large() {
        // Paper Fig. 16: LU_ET outperforms the other static variants for
        // small problems and matches LU_MB for large ones.
        for n in [1000usize, 1500, 2000] {
            assert!(
                gf(SimVariant::Et, n, 256) >= gf(SimVariant::Mb, n, 256) * 0.999,
                "n={n}: {} vs {}",
                gf(SimVariant::Et, n, 256),
                gf(SimVariant::Mb, n, 256)
            );
        }
        let large = 12000;
        let et = gf(SimVariant::Et, large, 256);
        let mb = gf(SimVariant::Mb, large, 256);
        assert!((et - mb).abs() / mb < 0.05, "et={et} mb={mb}");
    }

    #[test]
    fn et_cuts_happen_when_panel_dominates() {
        // Small matrix + big block: T_PF >> T_RU (paper Fig. 9 regime).
        let out = simulate(&hw(), SimVariant::Et, 2000, 256, 32, 6, 1, false);
        assert!(out.et_cuts > 0, "expected ET cuts, got none");
        // And for huge problems at the same block size, cuts fade away.
        let out_big = simulate(&hw(), SimVariant::Et, 12000, 256, 32, 6, 1, false);
        assert!(out_big.et_cuts <= out.et_cuts);
    }

    #[test]
    fn gflops_below_machine_peak_and_positive() {
        for v in [SimVariant::Lu, SimVariant::La, SimVariant::Mb, SimVariant::Et] {
            let g = gf(v, 8000, 256);
            assert!(g > 10.0 && g < hw().machine_peak(), "{}: {g}", v.name());
        }
    }

    #[test]
    fn more_threads_help() {
        let g1 = simulate(&hw(), SimVariant::Mb, 8000, 256, 32, 2, 1, false).gflops;
        let g6 = simulate(&hw(), SimVariant::Mb, 8000, 256, 32, 6, 1, false).gflops;
        assert!(g6 > 2.0 * g1, "g1={g1} g6={g6}");
    }

    #[test]
    fn trace_spans_cover_all_lanes() {
        let out = simulate(&hw(), SimVariant::Mb, 4000, 256, 32, 6, 1, true);
        assert!(!out.spans.is_empty());
        let lanes: std::collections::HashSet<usize> = out.spans.iter().map(|s| s.lane).collect();
        assert!(lanes.len() >= 6);
        // Spans must be within [0, makespan].
        for s in &out.spans {
            assert!(s.t0 >= -1e-9 && s.t1 <= out.time + 1e-9);
        }
    }

    #[test]
    fn et_panel_widths_shrink_effective_iterations() {
        // With ET the same problem takes more (narrower) iterations.
        let et = simulate(&hw(), SimVariant::Et, 2000, 256, 32, 6, 1, false);
        let mb = simulate(&hw(), SimVariant::Mb, 2000, 256, 32, 6, 1, false);
        assert!(et.iters >= mb.iters);
    }

    #[test]
    fn optimal_block_ordering_matches_paper_fig15() {
        // Paper Fig. 15 trends at n = 10000: LU prefers larger b_o than
        // LU_MB; LU_MB's optimum sits near the GEPP saturation point.
        let sweep = |v: SimVariant| -> usize {
            let mut best = (0usize, 0.0f64);
            let mut b = 32;
            while b <= 512 {
                let g = gf(v, 10000, b);
                if g > best.1 {
                    best = (b, g);
                }
                b += 32;
            }
            best.0
        };
        let lu_opt = sweep(SimVariant::Lu);
        let mb_opt = sweep(SimVariant::Mb);
        assert!(lu_opt >= mb_opt, "lu_opt={lu_opt} mb_opt={mb_opt}");
        assert!((96..=288).contains(&mb_opt), "mb_opt={mb_opt}");
    }
}
