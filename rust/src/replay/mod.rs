//! §replay — **deterministic scheduler capture/replay with
//! counterfactual policy sweeps** (DESIGN.md §16).
//!
//! The schedule-invariance property (`tests/steal_agree.rs`, DESIGN.md
//! §8/§13) proves that WS donations, hybrid tile stealing, and crew-size
//! changes never change a result bit. This module turns that test
//! assertion into an ops subsystem:
//!
//! - [`capture`] — a global, opt-in recorder the serve stack feeds at
//!   every scheduling decision point (`mlu serve --capture out.mrb`):
//!   lease grants/revocations, panel checkpoints, per-checkpoint steal
//!   counts, WS joins, ET triggers, daemon admission verdicts.
//! - [`bundle`] — the compact versioned `.mrb` artifact holding the
//!   serve configuration, the request payloads + result digests, and
//!   the decision stream.
//! - [`replayer`] — `mlu replay bundle.mrb`: re-executes the captured
//!   workload, certifies byte-identical results (via the digests below)
//!   and decision-stream equality on the **invariant** subset
//!   (DESIGN.md §16.4), and reports the first divergence with full
//!   context instead of silently continuing.
//! - [`sweep`] — the counterfactual engine: re-prices a captured trace
//!   under alternate [`crate::blis::StealPolicy`] points with the
//!   [`crate::sim`] cost model (`mlu replay --sweep steal=0|250|500|750`),
//!   emitting per-policy predicted GFLOPS/latency deltas into
//!   `BENCH_replay.json`.

pub mod bundle;
pub mod capture;
pub mod replayer;
pub mod sweep;

pub use bundle::{Bundle, BundleCfg, BundleError, ReqRecord};
pub use capture::{Decision, DecisionKind};
pub use replayer::{run_replay, Divergence, ReplayReport};
pub use sweep::{parse_sweep, run_sweep, PolicyPoint};

use crate::scalar::Scalar;
use crate::serve::{JobResult, SolveJobResult};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a/64 over `u64` words — the digest primitive for
/// result certification. Word-wise (not byte-wise) keeps digesting a
/// large factor cheap while remaining order- and value-sensitive.
#[derive(Debug, Copy, Clone)]
pub struct Digest(u64);

impl Default for Digest {
    fn default() -> Self {
        Self::new()
    }
}

impl Digest {
    /// Fresh digest at the FNV offset basis.
    pub fn new() -> Self {
        Self(FNV_OFFSET)
    }

    /// Fold one word.
    pub fn push(&mut self, w: u64) {
        self.0 ^= w;
        self.0 = self.0.wrapping_mul(FNV_PRIME);
    }

    /// The digest value.
    pub fn value(&self) -> u64 {
        self.0
    }
}

/// Digest of a factorization result: every factor element's raw bits
/// (via [`Scalar::to_bits_u64`]) plus pivots, Householder scalars,
/// committed-column count, and the cancelled flag. Two results digest
/// equal iff they are bitwise identical — the §8 invariant reduced to
/// one `u64` the bundle can carry.
pub fn factor_digest<S: Scalar>(res: &JobResult<S>) -> u64 {
    let mut d = Digest::new();
    for &v in res.a.data() {
        d.push(v.to_bits_u64());
    }
    for &p in &res.ipiv {
        d.push(p as u64);
    }
    for &t in &res.tau {
        d.push(t.to_bits_u64());
    }
    d.push(res.cols_done as u64);
    d.push(u64::from(res.cancelled));
    d.value()
}

/// Digest of a solve result: the solution's bits plus refinement
/// count, backward error, and the convergence/cancellation flags.
pub fn solve_digest(res: &SolveJobResult) -> u64 {
    let mut d = Digest::new();
    for &x in &res.x {
        d.push(x.to_bits());
    }
    d.push(res.refine_iters as u64);
    d.push(res.backward_error.to_bits());
    d.push(u64::from(res.converged));
    d.push(u64::from(res.cancelled));
    d.value()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::FactorKind;
    use crate::matrix::Matrix;

    #[test]
    fn digest_is_order_and_value_sensitive() {
        let mut a = Digest::new();
        a.push(1);
        a.push(2);
        let mut b = Digest::new();
        b.push(2);
        b.push(1);
        assert_ne!(a.value(), b.value());
        let mut c = Digest::new();
        c.push(1);
        c.push(2);
        assert_eq!(a.value(), c.value());
    }

    #[test]
    fn factor_digest_tracks_every_field() {
        let base = JobResult::<f64> {
            id: 0,
            kind: FactorKind::Lu,
            a: Matrix::random(8, 8, 3),
            ipiv: vec![1, 2, 3],
            tau: vec![],
            cols_done: 8,
            cancelled: false,
            secs: 0.0,
            error: None,
        };
        let d0 = factor_digest(&base);
        let mut flipped = JobResult::<f64> {
            a: base.a.clone(),
            ipiv: base.ipiv.clone(),
            tau: vec![],
            ..base
        };
        flipped.a.data_mut()[5] += 1e-16;
        assert_ne!(factor_digest(&flipped), d0, "one-ulp change must show");
        let repiv = JobResult::<f64> {
            a: base.a.clone(),
            ipiv: vec![1, 2, 4],
            tau: vec![],
            ..base
        };
        assert_ne!(factor_digest(&repiv), d0);
        let cut = JobResult::<f64> {
            a: base.a.clone(),
            ipiv: base.ipiv.clone(),
            tau: vec![],
            cols_done: 7,
            cancelled: true,
            ..base
        };
        assert_ne!(factor_digest(&cut), d0);
    }
}
