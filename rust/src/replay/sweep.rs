//! The **counterfactual policy engine** (`mlu replay --sweep`,
//! DESIGN.md §16.6): re-price a captured trace under alternate
//! [`StealPolicy`] points with the [`crate::sim`] cost model, without
//! re-executing a single flop.
//!
//! A bundle carries everything the pricing needs: the request shapes,
//! the serve configuration, and the captured per-checkpoint
//! [`DecisionKind::StealDelta`] records — the *observed* steal pressure
//! of the real run. The sweep holds the workload fixed and varies only
//! the scheduling policy, answering "what would this exact trace have
//! cost under `steal=0.25`?" offline. Predictions are cost-model
//! estimates, not measurements — they rank policies; they do not
//! certify bits (that is [`super::replayer`]'s job).
//!
//! Pricing model (per non-cancelled request, `w` workers):
//!
//! - `t_par` — the [`HwModel`] panel/update recurrence on one core,
//!   divided by the model's sublinear thread multiplier
//!   `w / (1 + par_loss·(w−1))`.
//! - `dyn_cost = tiles·(1−s)·task_overhead·contention / w` — every
//!   dynamically scheduled tile pays one shared-ticket claim;
//!   [`StealPolicy::Off`] doubles the contention factor because all
//!   claims hit one central ticket word (DESIGN.md §13).
//! - `imb_cost = s²·p_obs·t_par/2` — statically owned tiles cannot
//!   rebalance, so imbalance grows with the square of the static
//!   fraction, scaled by the steal ratio `p_obs` the capture actually
//!   observed (high observed stealing ⇒ this workload was imbalanced
//!   ⇒ pinning tiles statically hurts it more).
//!
//! The captured policy is always point 0 (the baseline); every other
//! point reports percentage deltas against it in `BENCH_replay.json`.

use super::bundle::{Bundle, ReqRecord, REQ_CHOL, REQ_LU, REQ_QR, REQ_SOLVE};
use super::capture::DecisionKind;
use crate::pool::steal::{auto_static_fraction, StealPolicy};
use crate::sim::costmodel::HwModel;
use crate::util::json::Value;

/// Fallback tile size (elements per side) used to estimate a request's
/// tile-grid population when the capture carries no
/// [`DecisionKind::StealDelta`] records for it (e.g. a dead-on-arrival
/// request): one tile per `64×64` block of the matrix.
pub const FALLBACK_TILE: usize = 64;

/// One policy point of a sweep: a label (as the user spelled it) plus
/// the decoded [`StealPolicy`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PolicyPoint {
    /// Human-readable spelling, used as the JSON `policy` field.
    pub label: String,
    /// The steal policy to price the trace under.
    pub policy: StealPolicy,
}

impl PolicyPoint {
    /// A point labeled with the policy's canonical name.
    pub fn of(policy: StealPolicy) -> Self {
        Self {
            label: policy.name(),
            policy,
        }
    }
}

/// Parse the `--sweep` syntax: comma-separated `key=v|v|…` groups whose
/// points are unioned, e.g. `steal=0|250|500|750,static_frac=0.9`.
///
/// - `steal=` takes `off`, `auto`, or a static fraction in **per-mille**
///   (`0..=1000`) — the bundle's own wire unit, so `steal=250` is the
///   25 %-static hybrid.
/// - `static_frac=` takes fractions in `[0, 1]` (`0.25` ≡ `steal=250`).
pub fn parse_sweep(spec: &str) -> Result<Vec<PolicyPoint>, String> {
    let mut points = Vec::new();
    for group in spec.split(',').filter(|g| !g.is_empty()) {
        let (key, vals) = group
            .split_once('=')
            .ok_or_else(|| format!("sweep group {group:?} is not key=v|v|…"))?;
        for val in vals.split('|').filter(|v| !v.is_empty()) {
            let policy = match key {
                "steal" => match val {
                    "off" => StealPolicy::Off,
                    "auto" => StealPolicy::Auto,
                    pm => {
                        let pm: u16 = pm.parse().map_err(|_| {
                            format!("bad steal point {val:?} (want off|auto|0..=1000 per-mille)")
                        })?;
                        if pm > 1000 {
                            return Err(format!("steal point {pm} exceeds 1000 per-mille"));
                        }
                        StealPolicy::Fraction(pm)
                    }
                },
                "static_frac" => {
                    let f: f64 = val
                        .parse()
                        .map_err(|_| format!("bad static_frac point {val:?}"))?;
                    if !(0.0..=1.0).contains(&f) {
                        return Err(format!("static_frac point {f} outside [0, 1]"));
                    }
                    StealPolicy::Fraction((f * 1000.0).round() as u16)
                }
                other => {
                    return Err(format!(
                        "unknown sweep key {other:?} (want steal|static_frac)"
                    ))
                }
            };
            let point = PolicyPoint {
                label: format!("{key}={val}"),
                policy,
            };
            if !points.contains(&point) {
                points.push(point);
            }
        }
    }
    if points.is_empty() {
        return Err(format!("sweep spec {spec:?} produced no points"));
    }
    Ok(points)
}

/// Per-request observables extracted from the captured decision stream.
struct ReqCost {
    /// Predicted parallel compute seconds on the bundle's worker count
    /// (policy-independent).
    t_par: f64,
    /// Tile-grid population (captured `StealDelta` sum, or the
    /// [`FALLBACK_TILE`] estimate).
    tiles: f64,
    /// Useful flops, for the aggregate GFLOPS figure.
    flops: f64,
}

/// Model flops of one request (the same formulas the bench suite
/// reports against).
fn req_flops(r: &ReqRecord) -> f64 {
    let (m, n) = (r.m as f64, r.n as f64);
    match r.kind {
        REQ_CHOL => n * n * n / 3.0,
        REQ_QR => 2.0 * m * n * n - 2.0 * n * n * n / 3.0,
        // Solves are LU-factor dominated; refinement is O(n²) noise.
        REQ_LU | REQ_SOLVE => crate::util::lu_flops(r.m as usize, r.n as usize),
        _ => 0.0,
    }
}

/// Single-core modeled seconds of one request: the panel recurrence at
/// the latency-bound rate plus the trailing updates at the GEPP rate —
/// the same decomposition [`crate::sim::lu_sim`] walks, collapsed to a
/// closed loop over panels. `f32`/mixed requests factor at twice the
/// double-precision rate (twice the SIMD lanes).
fn req_t1(hw: &HwModel, r: &ReqRecord, cfg_bo: usize, cfg_bi: usize) -> f64 {
    let m = r.m as usize;
    let n = r.n as usize;
    let bo = if r.bo != 0 { r.bo as usize } else { cfg_bo }.max(1);
    let bi = if r.bi != 0 { r.bi as usize } else { cfg_bi }.max(1);
    let prec_scale = if r.kind != REQ_SOLVE && r.prec == 1 {
        2.0
    } else if r.kind == REQ_SOLVE && r.prec != 0 {
        // f32 / mixed solves factor in single precision.
        2.0
    } else {
        1.0
    };
    let mut secs = 0.0;
    let mut panel_fl = 0.0;
    let mut j = 0;
    while j < n.min(m) {
        let b = bo.min(n - j);
        let rows = m - j;
        secs += hw.panel_time(rows, b, bi, 1);
        let bf = b as f64;
        panel_fl += rows as f64 * bf * bf - bf * bf * bf / 3.0;
        j += b;
    }
    let update_fl = (req_flops(r) - panel_fl).max(0.0);
    secs += update_fl / (hw.gepp_gflops(bo, 1) * 1e9);
    secs / prec_scale
}

/// Extract the policy-independent per-request costs plus the global
/// observed steal ratio. Cancelled/failed requests are excluded from
/// the pricing (their real extent is unknowable) but counted in the
/// report.
fn req_costs(bundle: &Bundle, hw: &HwModel) -> (Vec<ReqCost>, f64, f64, usize) {
    let w = (bundle.cfg.workers as usize).max(1);
    let thread_scale = {
        let t = w as f64;
        t / (1.0 + hw.par_loss * (t - 1.0))
    };
    let mut total_tiles = 0.0;
    let mut total_stolen = 0.0;
    let mut costs = Vec::new();
    let mut skipped = 0;
    for r in &bundle.requests {
        if r.cancelled || r.failed {
            skipped += 1;
            continue;
        }
        let mut tiles = 0u64;
        for d in &bundle.decisions {
            if d.kind == DecisionKind::StealDelta && d.req == r.id {
                tiles += d.b & 0xffff_ffff;
                total_stolen += (d.b >> 32) as f64;
            }
        }
        let tiles = if tiles > 0 {
            tiles as f64
        } else {
            ((r.m as usize * r.n as usize) / (FALLBACK_TILE * FALLBACK_TILE)).max(1) as f64
        };
        total_tiles += tiles;
        costs.push(ReqCost {
            t_par: req_t1(hw, r, bundle.cfg.bo as usize, bundle.cfg.bi as usize) / thread_scale,
            tiles,
            flops: req_flops(r),
        });
    }
    let p_obs = if total_tiles > 0.0 {
        (total_stolen / total_tiles).clamp(0.0, 1.0)
    } else {
        0.0
    };
    (costs, p_obs, total_stolen, skipped)
}

/// Price one policy point over the extracted per-request costs.
/// Returns `(mean_latency, makespan, gflops, mean_static_frac)`.
fn price(
    costs: &[ReqCost],
    p_obs: f64,
    policy: StealPolicy,
    workers: usize,
    hw: &HwModel,
) -> (f64, f64, f64, f64) {
    let w = workers.max(1) as f64;
    let mut makespan = 0.0;
    let mut flops = 0.0;
    let mut frac_sum = 0.0;
    for c in costs {
        let (s, contention) = match policy {
            StealPolicy::Off => (0.0, 2.0),
            StealPolicy::Auto => (auto_static_fraction(workers, c.tiles as usize), 1.0),
            StealPolicy::Fraction(pm) => (f64::from(pm) / 1000.0, 1.0),
        };
        let dyn_cost = c.tiles * (1.0 - s) * hw.task_overhead * contention / w;
        let imb_cost = s * s * p_obs * c.t_par * 0.5;
        makespan += c.t_par + dyn_cost + imb_cost;
        flops += c.flops;
        frac_sum += s;
    }
    let n = costs.len().max(1) as f64;
    (
        makespan / n,
        makespan,
        crate::util::gflops(flops, makespan),
        frac_sum / n,
    )
}

/// Run a sweep: price the captured trace under the bundle's own policy
/// (point 0, the baseline) and under each requested point, and return
/// the `BENCH_replay.json` document — per-policy predicted latency,
/// makespan, GFLOPS, and percentage deltas against the baseline.
pub fn run_sweep(bundle: &Bundle, points: &[PolicyPoint]) -> Value {
    let hw = HwModel::default();
    let workers = (bundle.cfg.workers as usize).max(1);
    let (costs, p_obs, stolen, skipped) = req_costs(bundle, &hw);
    let baseline = PolicyPoint {
        label: format!("captured:{}", bundle.cfg.steal.name()),
        policy: bundle.cfg.steal,
    };
    let (base_lat, base_make, base_gf, _) = price(&costs, p_obs, baseline.policy, workers, &hw);
    let mut rows = Vec::new();
    for (i, p) in std::iter::once(&baseline).chain(points.iter()).enumerate() {
        let (lat, make, gf, frac) = price(&costs, p_obs, p.policy, workers, &hw);
        let pct = |new: f64, base: f64| {
            if base > 0.0 {
                (new - base) / base * 100.0
            } else {
                0.0
            }
        };
        rows.push(Value::obj([
            ("policy", Value::Str(p.label.clone())),
            ("baseline", Value::Bool(i == 0)),
            ("static_frac_mean", Value::Num(frac)),
            ("mean_latency_s", Value::Num(lat)),
            ("makespan_s", Value::Num(make)),
            ("gflops", Value::Num(gf)),
            ("delta_gflops_pct", Value::Num(pct(gf, base_gf))),
            ("delta_latency_pct", Value::Num(pct(lat, base_lat))),
        ]));
    }
    Value::obj([
        ("bench", Value::Str("replay_sweep".into())),
        (
            "bundle",
            Value::obj([
                ("requests", Value::Num(bundle.requests.len() as f64)),
                ("priced", Value::Num(costs.len() as f64)),
                ("skipped", Value::Num(skipped as f64)),
                ("decisions", Value::Num(bundle.decisions.len() as f64)),
                ("workers", Value::Num(workers as f64)),
                ("steal", Value::Str(bundle.cfg.steal.name())),
            ]),
        ),
        (
            "observed",
            Value::obj([
                ("stolen_tiles", Value::Num(stolen)),
                ("steal_ratio", Value::Num(p_obs)),
            ]),
        ),
        (
            "baseline",
            Value::obj([
                ("mean_latency_s", Value::Num(base_lat)),
                ("makespan_s", Value::Num(base_make)),
                ("gflops", Value::Num(base_gf)),
            ]),
        ),
        ("points", Value::Arr(rows)),
    ])
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::replay::bundle::{BundleCfg, NO_CLIENT};
    use crate::replay::capture::Decision;

    fn bundle_with(steal: StealPolicy, decisions: Vec<Decision>) -> Bundle {
        Bundle {
            cfg: BundleCfg {
                workers: 4,
                bo: 64,
                bi: 16,
                mc: 176,
                kc: 256,
                nc: 4080,
                steal,
                interleave: false,
            },
            requests: vec![ReqRecord {
                id: 0,
                kind: REQ_LU,
                prec: 0,
                priority: 2,
                cancelled: false,
                failed: false,
                m: 512,
                n: 512,
                bo: 0,
                bi: 0,
                deadline_ms: 0,
                client: NO_CLIENT,
                cols_done: 512,
                digest: 1,
                data: vec![],
                rhs: vec![],
            }],
            decisions,
        }
    }

    #[test]
    fn parse_sweep_unions_groups_and_rejects_garbage() {
        let pts = parse_sweep("steal=off|auto|250,static_frac=0.9").unwrap();
        assert_eq!(pts.len(), 4);
        assert_eq!(pts[0].policy, StealPolicy::Off);
        assert_eq!(pts[1].policy, StealPolicy::Auto);
        assert_eq!(pts[2].policy, StealPolicy::Fraction(250));
        assert_eq!(pts[3].policy, StealPolicy::Fraction(900));
        assert_eq!(pts[3].label, "static_frac=0.9");
        assert!(parse_sweep("steal=1001").is_err());
        assert!(parse_sweep("static_frac=1.5").is_err());
        assert!(parse_sweep("bogus=1").is_err());
        assert!(parse_sweep("steal").is_err());
        assert!(parse_sweep("").is_err());
        // Duplicate points collapse.
        assert_eq!(parse_sweep("steal=250,static_frac=0.25").unwrap().len(), 1);
    }

    #[test]
    fn sweep_report_has_baseline_and_deltas() {
        // Captured run saw heavy stealing: 100 of 200 tiles stolen.
        let d = vec![Decision {
            ordinal: 0,
            kind: DecisionKind::StealDelta,
            req: 0,
            a: 0,
            b: (100 << 32) | 200,
        }];
        let b = bundle_with(StealPolicy::Auto, d);
        let pts = parse_sweep("steal=off|1000").unwrap();
        let v = run_sweep(&b, &pts);
        let rows = v.get("points").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 3, "baseline + two points");
        assert_eq!(rows[0].get("baseline").unwrap(), &Value::Bool(true));
        assert_eq!(
            rows[0].get("delta_gflops_pct").unwrap().as_f64(),
            Some(0.0),
            "baseline deltas are zero by construction"
        );
        // Observed steal ratio reached the report.
        let p = v
            .get("observed")
            .unwrap()
            .get("steal_ratio")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!((p - 0.5).abs() < 1e-12);
        // With p_obs = 0.5 a fully-static policy must price worse
        // (higher latency) than the hybrid baseline.
        let full_static = rows[2].get("delta_latency_pct").unwrap().as_f64().unwrap();
        assert!(full_static > 0.0, "got {full_static}");
        // The report round-trips through the JSON codec.
        assert_eq!(crate::util::json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn fallback_tiles_used_when_no_deltas_captured() {
        let b = bundle_with(StealPolicy::Off, vec![]);
        let v = run_sweep(&b, &[PolicyPoint::of(StealPolicy::Auto)]);
        // 512×512 / 64² = 64 tiles, no stealing observed.
        let p = v
            .get("observed")
            .unwrap()
            .get("steal_ratio")
            .unwrap()
            .as_f64()
            .unwrap();
        assert_eq!(p, 0.0);
        let rows = v.get("points").unwrap().as_arr().unwrap();
        // With zero observed stealing, imbalance costs nothing, so the
        // hybrid point can only save ticket contention: ≥ baseline.
        let gf = rows[1].get("delta_gflops_pct").unwrap().as_f64().unwrap();
        assert!(gf >= 0.0, "got {gf}");
    }

    #[test]
    fn cancelled_requests_are_skipped_not_priced() {
        let mut b = bundle_with(StealPolicy::Auto, vec![]);
        b.requests[0].cancelled = true;
        let v = run_sweep(&b, &[]);
        assert_eq!(
            v.get("bundle").unwrap().get("priced").unwrap().as_f64(),
            Some(0.0)
        );
        assert_eq!(
            v.get("bundle").unwrap().get("skipped").unwrap().as_f64(),
            Some(1.0)
        );
    }
}
