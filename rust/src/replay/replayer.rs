//! The **deterministic replayer**: re-execute a captured bundle and
//! certify it against the capture run (DESIGN.md §16.4).
//!
//! Replay rebuilds the serve configuration from the bundle, re-submits
//! every captured request in its original submission order (ids are
//! dense from 0 in both runs, so captured id `i` maps to replayed id
//! `i` positionally), runs the workload under a fresh capture, and then
//! compares:
//!
//! 1. **Results, bitwise** — the FNV digest of every replayed result
//!    against the digest the capture run recorded
//!    ([`super::factor_digest`] / [`super::solve_digest`]).
//! 2. **Decision streams on the invariant subset** — per request, the
//!    subsequence of [`DecisionKind`]s with `invariant() == true`
//!    (submit, lease grant, checkpoints, lease revoke) must reproduce
//!    operand-for-operand. Environmental records (admission, steal
//!    deltas, WS joins, ET triggers) are timing artifacts of the capture
//!    machine; they are *context*, compared never, reported always.
//!
//! Certification is all-or-nothing: the first mismatch produces a
//! [`Divergence`] naming the exact captured ordinal, and the report
//! refuses to certify. Requests the capture run cancelled or failed are
//! replayed but **skipped** from certification — their outcome depended
//! on wall-clock timing (deadlines, watchdogs, injected faults), which
//! replay deliberately does not reproduce.

use super::bundle::{Bundle, ReqRecord, NO_CLIENT, REQ_SOLVE};
use super::capture::{self, Decision};
use crate::factor::FactorKind;
use crate::matrix::{Mat, Matrix};
use crate::scalar::Scalar;
use crate::serve::{JobHandle, JobResult, LuRequest, LuServer, SolveJobResult, SolveRequest};
use crate::solve::SolvePrec;

/// Why and where a replay stopped matching its capture.
#[derive(Debug, Clone)]
pub struct Divergence {
    /// Ordinal (in the *captured* stream) of the first diverging record.
    pub ordinal: u64,
    /// The request the diverging record belongs to (captured id).
    pub req: u64,
    /// What the capture recorded at that point, rendered.
    pub expected: String,
    /// What the replay produced instead (`None`: the replay's invariant
    /// stream for this request ended early).
    pub got: Option<String>,
    /// The captured decisions around the divergence, rendered as an
    /// event strip with the culprit marked
    /// ([`crate::trace::ascii_event_strip`]).
    pub context: String,
}

impl std::fmt::Display for Divergence {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "first divergence at captured ordinal {} (req{}):",
            self.ordinal, self.req
        )?;
        writeln!(f, "  expected: {}", self.expected)?;
        match &self.got {
            Some(g) => writeln!(f, "  replayed: {g}")?,
            None => writeln!(f, "  replayed: (stream ended)")?,
        }
        write!(f, "context:\n{}", self.context)
    }
}

/// Outcome of [`run_replay`].
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// Requests in the bundle.
    pub requests: usize,
    /// Requests certified bitwise + decision-stream identical.
    pub certified: usize,
    /// Requests skipped (capture run cancelled/failed them).
    pub skipped: usize,
    /// Decisions in the captured stream.
    pub captured_decisions: usize,
    /// Decisions the (last) replay round recorded.
    pub replayed_decisions: usize,
    /// Replay rounds executed.
    pub rounds: usize,
    /// First divergence, if certification failed.
    pub divergence: Option<Divergence>,
}

impl ReplayReport {
    /// Whether every certifiable request reproduced exactly.
    pub fn certified_ok(&self) -> bool {
        self.divergence.is_none()
    }

    /// Human-readable summary for the CLI.
    pub fn render(&self) -> String {
        let mut out = format!(
            "replay: {} requests ({} certified, {} skipped), {} captured / {} replayed decisions, {} round(s)\n",
            self.requests,
            self.certified,
            self.skipped,
            self.captured_decisions,
            self.replayed_decisions,
            self.rounds
        );
        match &self.divergence {
            None => out.push_str("CERTIFIED: results and invariant decision streams identical\n"),
            Some(d) => {
                out.push_str("NOT CERTIFIED\n");
                out.push_str(&format!("{d}\n"));
            }
        }
        out
    }
}

/// What one replay round produced, per request (positional = replayed
/// id).
struct ReplayRound {
    decisions: Vec<Decision>,
    requests: Vec<ReqRecord>,
}

/// Re-execute `bundle` `rounds` times and certify each round against the
/// capture. `workers` overrides the captured worker count (certification
/// must still pass — schedule invariance is the whole point). Returns
/// `Err` only for structural failures (another capture active, malformed
/// bundle); divergence is reported *in* the report, not as an error.
pub fn run_replay(
    bundle: &Bundle,
    rounds: usize,
    workers: Option<usize>,
) -> Result<ReplayReport, String> {
    let rounds = rounds.max(1);
    let mut report = ReplayReport {
        requests: bundle.requests.len(),
        certified: 0,
        skipped: bundle
            .requests
            .iter()
            .filter(|r| r.cancelled || r.failed)
            .count(),
        captured_decisions: bundle.decisions.len(),
        replayed_decisions: 0,
        rounds: 0,
        divergence: None,
    };
    for _ in 0..rounds {
        let round = replay_once(bundle, workers)?;
        report.replayed_decisions = round.decisions.len();
        report.rounds += 1;
        report.certified = 0;
        if let Some(d) = certify_round(bundle, &round) {
            report.divergence = Some(d);
            return Ok(report);
        }
        report.certified = report.requests - report.skipped;
    }
    Ok(report)
}

fn mat_from_le<S: Scalar>(m: usize, n: usize, bytes: &[u8]) -> Mat<S> {
    let mut a = Mat::<S>::zeros(m, n);
    let elem = std::mem::size_of::<S>();
    for (v, chunk) in a.data_mut().iter_mut().zip(bytes.chunks_exact(elem)) {
        *v = if elem == 8 {
            let mut b = [0u8; 8];
            b.copy_from_slice(chunk);
            S::from_f64(f64::from_le_bytes(b))
        } else {
            let mut b = [0u8; 4];
            b.copy_from_slice(chunk);
            S::from_f64(f64::from(f32::from_le_bytes(b)))
        };
    }
    a
}

fn rhs_from_le(bytes: &[u8]) -> Vec<f64> {
    bytes
        .chunks_exact(8)
        .map(|c| {
            let mut b = [0u8; 8];
            b.copy_from_slice(c);
            f64::from_le_bytes(b)
        })
        .collect()
}

enum AnyHandle {
    F64(JobHandle<JobResult<f64>>),
    F32(JobHandle<JobResult<f32>>),
    Solve(JobHandle<SolveJobResult>),
}

impl AnyHandle {
    fn wait(self) {
        match self {
            AnyHandle::F64(h) => {
                h.wait();
            }
            AnyHandle::F32(h) => {
                h.wait();
            }
            AnyHandle::Solve(h) => {
                h.wait();
            }
        }
    }
}

/// One replay execution: fresh server from the bundle's config, captured
/// requests re-submitted in order (deadlines dropped — they are
/// wall-clock, hence environmental), everything recorded under a fresh
/// capture. The replay's own request records carry the digests the same
/// hook path computed in the capture run.
fn replay_once(bundle: &Bundle, workers: Option<usize>) -> Result<ReplayRound, String> {
    if !capture::start() {
        return Err("another capture is active in this process".into());
    }
    let mut cfg = bundle.cfg.to_serve();
    if let Some(w) = workers {
        cfg.workers = w.max(1);
    }
    let server = LuServer::new(cfg);
    // Driver family per request: the `ReqRecord` wire format predates
    // driver families, so the family code travels in bits 24–31 of the
    // Submit decision's second operand instead (0 = look-ahead, which is
    // what pre-§17 bundles carry there). Without this re-routing, a
    // DAG-family capture would replay through the look-ahead driver and
    // mis-certify on the first checkpoint.
    let families: std::collections::HashMap<u64, u8> = bundle
        .decisions
        .iter()
        .filter(|d| d.kind == capture::DecisionKind::Submit)
        .map(|d| (d.req, ((d.b >> 24) & 0xff) as u8))
        .collect();
    let mut handles = Vec::with_capacity(bundle.requests.len());
    for r in &bundle.requests {
        let (m, n) = (r.m as usize, r.n as usize);
        let h = if r.kind == REQ_SOLVE {
            let a = mat_from_le::<f64>(m, n, &r.data);
            let prec = match r.prec {
                0 => SolvePrec::F64,
                1 => SolvePrec::F32,
                _ => SolvePrec::Mixed,
            };
            let mut req = SolveRequest::new(a, rhs_from_le(&r.rhs))
                .with_prec(prec)
                .with_priority(r.priority);
            if r.bo != 0 && r.bi != 0 {
                req.bo = Some(r.bo as usize);
                req.bi = Some(r.bi as usize);
            }
            if r.client != NO_CLIENT {
                req = req.with_client(r.client);
            }
            AnyHandle::Solve(server.submit_solve(req))
        } else {
            let kind = super::bundle::parse_kind(r.kind)
                .unwrap_or(FactorKind::Lu);
            let family = families.get(&r.id).copied().unwrap_or(0);
            if r.prec == 1 {
                let a: Mat<f32> = mat_from_le(m, n, &r.data);
                AnyHandle::F32(server.submit(factor_req(a, kind, r, family)))
            } else {
                let a: Matrix = mat_from_le(m, n, &r.data);
                AnyHandle::F64(server.submit(factor_req(a, kind, r, family)))
            }
        };
        handles.push(h);
    }
    for h in handles {
        h.wait();
    }
    server.shutdown();
    let (decisions, requests) =
        capture::stop().ok_or_else(|| String::from("capture vanished during replay"))?;
    Ok(ReplayRound {
        decisions,
        requests,
    })
}

fn factor_req<S: Scalar>(a: Mat<S>, kind: FactorKind, r: &ReqRecord, family: u8) -> LuRequest<S> {
    let mut req = LuRequest::new(a)
        .with_kind(kind)
        .with_priority(r.priority)
        .with_driver(crate::factor::DriverFamily::from_code(family));
    if r.bo != 0 && r.bi != 0 {
        req = req.with_blocks(r.bo as usize, r.bi as usize);
    }
    if r.client != NO_CLIENT {
        req = req.with_client(r.client);
    }
    req
}

/// Certify one replay round: digests first structural (count) checks,
/// then per-request invariant decision subsequences, then result
/// digests. Returns the first divergence found, in captured-ordinal
/// order.
fn certify_round(bundle: &Bundle, round: &ReplayRound) -> Option<Divergence> {
    // Requests replay positionally; a count mismatch means the bundle
    // and the replay disagree about what was even submitted.
    if round.requests.len() != bundle.requests.len() {
        return Some(structural_divergence(
            bundle,
            0,
            format!(
                "{} captured requests, {} replayed",
                bundle.requests.len(),
                round.requests.len()
            ),
        ));
    }
    for (i, cap_req) in bundle.requests.iter().enumerate() {
        if cap_req.cancelled || cap_req.failed {
            continue; // wall-clock outcome: replayed, never certified
        }
        let cap_inv: Vec<&Decision> = bundle
            .decisions
            .iter()
            .filter(|d| d.kind.invariant() && d.req == cap_req.id)
            .collect();
        let rep_id = round.requests[i].id;
        let rep_inv: Vec<&Decision> = round
            .decisions
            .iter()
            .filter(|d| d.kind.invariant() && d.req == rep_id)
            .collect();
        for (j, cap_d) in cap_inv.iter().enumerate() {
            match rep_inv.get(j) {
                None => {
                    return Some(divergence_at(bundle, cap_d, None));
                }
                Some(rep_d) => {
                    if cap_d.kind != rep_d.kind || cap_d.a != rep_d.a || cap_d.b != rep_d.b {
                        return Some(divergence_at(bundle, cap_d, Some(rep_d)));
                    }
                }
            }
        }
        if rep_inv.len() > cap_inv.len() {
            let extra = rep_inv[cap_inv.len()];
            let anchor = cap_inv
                .last()
                .map(|d| d.ordinal)
                .unwrap_or(0);
            return Some(Divergence {
                ordinal: anchor,
                req: cap_req.id,
                expected: "(invariant stream ends here)".into(),
                got: Some(extra.describe()),
                context: context_strip(bundle, anchor),
            });
        }
        // Streams agree — now the result itself, bit for bit.
        let rep_req = &round.requests[i];
        if rep_req.digest != cap_req.digest
            || rep_req.cols_done != cap_req.cols_done
            || rep_req.cancelled != cap_req.cancelled
            || rep_req.failed != cap_req.failed
        {
            let anchor = cap_inv.last().map(|d| d.ordinal).unwrap_or(0);
            return Some(Divergence {
                ordinal: anchor,
                req: cap_req.id,
                expected: format!(
                    "result digest {:016x} cols_done {} cancelled {} failed {}",
                    cap_req.digest, cap_req.cols_done, cap_req.cancelled, cap_req.failed
                ),
                got: Some(format!(
                    "result digest {:016x} cols_done {} cancelled {} failed {}",
                    rep_req.digest, rep_req.cols_done, rep_req.cancelled, rep_req.failed
                )),
                context: context_strip(bundle, anchor),
            });
        }
    }
    None
}

fn divergence_at(bundle: &Bundle, expected: &Decision, got: Option<&Decision>) -> Divergence {
    Divergence {
        ordinal: expected.ordinal,
        req: expected.req,
        expected: expected.describe(),
        got: got.map(|d| d.describe()),
        context: context_strip(bundle, expected.ordinal),
    }
}

fn structural_divergence(bundle: &Bundle, ordinal: u64, what: String) -> Divergence {
    Divergence {
        ordinal,
        req: u64::MAX,
        expected: what,
        got: None,
        context: context_strip(bundle, ordinal),
    }
}

/// The captured decisions around `ordinal`, rendered with the culprit
/// marked (invariant *and* environmental records — the environmental
/// ones are exactly the context a divergence investigation needs).
fn context_strip(bundle: &Bundle, ordinal: u64) -> String {
    let events: Vec<(u64, String)> = bundle
        .decisions
        .iter()
        .map(|d| (d.ordinal, d.describe()))
        .collect();
    crate::trace::ascii_event_strip(&events, ordinal, 4)
}
