//! The versioned **`.mrb` replay-bundle format** — encode/decode for
//! the capture artifacts of [`super::capture`].
//!
//! **The normative byte-level specification is DESIGN.md §16.3** — the
//! tables there and the codec here must match byte for byte; the
//! golden-bundle test (`tests/replay_bundle.rs`) pins a committed
//! fixture's byte image to keep them honest, exactly as the proto pin
//! tests do for the wire protocol. Summary:
//!
//! ```text
//! bundle   := header config counts request* decision*
//! header   := magic(4 = "MLRB") version(1) flags(1) reserved(2)
//! config   := workers(4) bo(4) bi(4) mc(4) kc(4) nc(4)
//!             steal_tag(1) steal_pm(2) reserved(1)
//! counts   := n_requests(4) n_decisions(4)
//! request  := id(8) kind(1) prec(1) priority(1) flags(1) m(4) n(4)
//!             bo(2) bi(2) deadline_ms(4) client(8) cols_done(4)
//!             digest(8) data_len(4) rhs_len(4) data rhs
//! decision := tag(1) reserved(3) ordinal(8) req(8) a(8) b(8)
//! ```
//!
//! All integers little-endian; matrix `data` is column-major IEEE-754
//! in the request's precision, `rhs` is `f64` (solve requests only).
//! A schema change **must** bump [`VERSION`] and keep this decoder as
//! the v1 path — [`decode`] dispatches on the version byte and rejects
//! unknown versions instead of guessing.

use super::capture::{Decision, DecisionKind};
use crate::blis::{BlisParams, StealPolicy};
use crate::factor::FactorKind;

/// Bundle magic, bytes 0–3 of every `.mrb` file.
pub const MAGIC: [u8; 4] = *b"MLRB";
/// The bundle version this build writes (header byte 4).
pub const VERSION: u8 = 1;
/// Fixed size of the header + config + counts prefix.
pub const PREFIX_LEN: usize = 8 + 28 + 8;
/// Fixed (pre-data) bytes of one request record.
pub const REQ_FIXED: usize = 56;
/// Size of one decision record.
pub const DEC_LEN: usize = 36;

/// Request-kind code for an LU factorization (matches the wire
/// protocol's factor-kind codes for the factor kinds).
pub const REQ_LU: u8 = 0;
/// Request-kind code for a Cholesky factorization.
pub const REQ_CHOL: u8 = 1;
/// Request-kind code for a QR factorization.
pub const REQ_QR: u8 = 2;
/// Request-kind code for a linear-system solve.
pub const REQ_SOLVE: u8 = 3;

/// Sentinel for "no originating network connection" in the `client`
/// field.
pub const NO_CLIENT: u64 = u64::MAX;

/// Map a [`FactorKind`] to its bundle request-kind code.
pub fn kind_code(kind: FactorKind) -> u8 {
    match kind {
        FactorKind::Lu => REQ_LU,
        FactorKind::Chol => REQ_CHOL,
        FactorKind::Qr => REQ_QR,
    }
}

/// Decode a bundle request-kind code into a [`FactorKind`] (`None` for
/// [`REQ_SOLVE`] and unknown codes).
pub fn parse_kind(c: u8) -> Option<FactorKind> {
    match c {
        REQ_LU => Some(FactorKind::Lu),
        REQ_CHOL => Some(FactorKind::Chol),
        REQ_QR => Some(FactorKind::Qr),
        _ => None,
    }
}

/// Precision code of a scalar type: 0 = `f64`, 1 = `f32`.
pub fn prec_code<S: crate::scalar::Scalar>() -> u8 {
    u8::from(std::mem::size_of::<S>() == 4)
}

/// Precision code of a solve request: 0 = `f64`, 1 = `f32`, 2 = mixed.
pub fn solve_prec_code(p: crate::solve::SolvePrec) -> u8 {
    match p {
        crate::solve::SolvePrec::F64 => 0,
        crate::solve::SolvePrec::F32 => 1,
        crate::solve::SolvePrec::Mixed => 2,
    }
}

/// Serialize a matrix column-major, little-endian, in its own precision
/// — the bundle's request-payload encoding. Bit-exact: elements go out
/// as raw IEEE bits, so capture → replay reconstructs the identical
/// matrix.
pub fn mat_to_le<S: crate::scalar::Scalar>(a: &crate::matrix::Mat<S>) -> Vec<u8> {
    let elem = std::mem::size_of::<S>();
    let mut out = Vec::with_capacity(a.data().len() * elem);
    for &v in a.data() {
        let bits = v.to_bits_u64();
        if elem == 4 {
            out.extend_from_slice(&(bits as u32).to_le_bytes());
        } else {
            out.extend_from_slice(&bits.to_le_bytes());
        }
    }
    out
}

/// Serialize a right-hand side (`f64` little-endian).
pub fn rhs_to_le(b: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(b.len() * 8);
    for v in b {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// The serve configuration a capture ran under — enough to rebuild an
/// equivalent [`crate::serve::ServeConfig`] at replay time. The cost
/// model is deliberately *not* in the bundle: it is part of the build
/// (DESIGN.md §16.5), so replaying a bundle under a recalibrated model
/// reports divergence on the lease-sizing records — by design.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub struct BundleCfg {
    /// Pool workers the capture served with.
    pub workers: u32,
    /// Server-default outer block size.
    pub bo: u32,
    /// Server-default inner (panel) block size.
    pub bi: u32,
    /// BLIS `m_c` in effect.
    pub mc: u32,
    /// BLIS `k_c` in effect.
    pub kc: u32,
    /// BLIS `n_c` in effect.
    pub nc: u32,
    /// The steal policy the capture ran under.
    pub steal: StealPolicy,
    /// Whether the interleaved small-problem fast path (DESIGN.md §18)
    /// was enabled. Carried in header flags bit 0; pre-§18 bundles
    /// wrote the byte as 0, so they decode to `false` and replay with
    /// the fast path off — exactly how they were captured.
    pub interleave: bool,
}

impl BundleCfg {
    /// Capture the relevant parts of a live serve configuration.
    pub fn from_serve(cfg: &crate::serve::ServeConfig) -> Self {
        Self {
            workers: cfg.workers as u32,
            bo: cfg.bo as u32,
            bi: cfg.bi as u32,
            mc: cfg.params.mc as u32,
            kc: cfg.params.kc as u32,
            nc: cfg.params.nc as u32,
            steal: cfg.params.steal,
            interleave: cfg.interleave,
        }
    }

    /// Rebuild the serve configuration for a replay (entry policy and
    /// cost model come from the build's defaults — see the type docs).
    pub fn to_serve(&self) -> crate::serve::ServeConfig {
        crate::serve::ServeConfig {
            workers: self.workers as usize,
            bo: self.bo as usize,
            bi: self.bi as usize,
            params: BlisParams {
                mc: self.mc as usize,
                kc: self.kc as usize,
                nc: self.nc as usize,
                steal: self.steal,
            },
            interleave: self.interleave,
            ..Default::default()
        }
    }
}

/// One captured request: the replayable workload payload plus the
/// capture run's outcome (digest + flags).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReqRecord {
    /// Request id assigned by the capture run's server (dense from 0).
    pub id: u64,
    /// Request kind ([`REQ_LU`] … [`REQ_SOLVE`]).
    pub kind: u8,
    /// Precision code: 0 = f64, 1 = f32; for solves the
    /// [`crate::solve::SolvePrec`] code (0 = f64, 1 = f32, 2 = mixed).
    pub prec: u8,
    /// Scheduling priority.
    pub priority: u8,
    /// Whether the capture run cancelled the request (handle, deadline,
    /// malformed shape). Cancelled/failed requests replay but are not
    /// certified — their outcome depended on wall-clock timing
    /// (DESIGN.md §16.4).
    pub cancelled: bool,
    /// Whether the capture run completed it with a typed error.
    pub failed: bool,
    /// Matrix rows.
    pub m: u32,
    /// Matrix columns.
    pub n: u32,
    /// Per-request outer block override (0 = server default).
    pub bo: u16,
    /// Per-request inner block override (0 = server default).
    pub bi: u16,
    /// Captured deadline in ms (0 = none). Replay drops deadlines —
    /// they are wall-clock, hence environmental.
    pub deadline_ms: u32,
    /// Originating connection id, [`NO_CLIENT`] for in-process.
    pub client: u64,
    /// Columns the capture run committed.
    pub cols_done: u32,
    /// FNV-1a digest of the capture run's result bytes
    /// ([`super::factor_digest`] / [`super::solve_digest`]).
    pub digest: u64,
    /// Column-major matrix payload, little-endian in `prec`.
    pub data: Vec<u8>,
    /// Right-hand side (`f64` LE), solve requests only.
    pub rhs: Vec<u8>,
}

/// A decoded replay bundle.
#[derive(Debug, Clone, PartialEq)]
pub struct Bundle {
    /// The serve configuration of the capture run.
    pub cfg: BundleCfg,
    /// Captured requests, in submission order.
    pub requests: Vec<ReqRecord>,
    /// The captured decision stream, in ordinal order.
    pub decisions: Vec<Decision>,
}

/// Decode failure: bad magic, unknown version, truncated or
/// inconsistent records.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BundleError(pub String);

impl std::fmt::Display for BundleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "bundle error: {}", self.0)
    }
}

impl std::error::Error for BundleError {}

fn err<T>(msg: impl Into<String>) -> Result<T, BundleError> {
    Err(BundleError(msg.into()))
}

// ---------------------------------------------------------------------------
// Little-endian primitives (the proto idiom, kept local so the bundle
// codec stays self-contained).

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], BundleError> {
        if self.i + n > self.b.len() {
            return err(format!(
                "truncated bundle: need {} bytes at offset {}, have {}",
                n,
                self.i,
                self.b.len() - self.i
            ));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, BundleError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, BundleError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, BundleError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, BundleError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn done(&self) -> Result<(), BundleError> {
        if self.i != self.b.len() {
            return err(format!(
                "{} trailing bytes after the last record",
                self.b.len() - self.i
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Encode.

/// Serialize a bundle in the current ([`VERSION`]) format.
pub fn encode(bundle: &Bundle) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        PREFIX_LEN
            + bundle
                .requests
                .iter()
                .map(|r| REQ_FIXED + r.data.len() + r.rhs.len())
                .sum::<usize>()
            + bundle.decisions.len() * DEC_LEN,
    );
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(u8::from(bundle.cfg.interleave)); // flags: bit 0 = interleave
    put_u16(&mut out, 0); // reserved
    let c = &bundle.cfg;
    put_u32(&mut out, c.workers);
    put_u32(&mut out, c.bo);
    put_u32(&mut out, c.bi);
    put_u32(&mut out, c.mc);
    put_u32(&mut out, c.kc);
    put_u32(&mut out, c.nc);
    let (steal_tag, steal_pm) = c.steal.wire_tag();
    out.push(steal_tag);
    put_u16(&mut out, steal_pm);
    out.push(0); // reserved
    put_u32(&mut out, bundle.requests.len() as u32);
    put_u32(&mut out, bundle.decisions.len() as u32);
    for r in &bundle.requests {
        put_u64(&mut out, r.id);
        out.push(r.kind);
        out.push(r.prec);
        out.push(r.priority);
        out.push(u8::from(r.cancelled) | (u8::from(r.failed) << 1));
        put_u32(&mut out, r.m);
        put_u32(&mut out, r.n);
        put_u16(&mut out, r.bo);
        put_u16(&mut out, r.bi);
        put_u32(&mut out, r.deadline_ms);
        put_u64(&mut out, r.client);
        put_u32(&mut out, r.cols_done);
        put_u64(&mut out, r.digest);
        put_u32(&mut out, r.data.len() as u32);
        put_u32(&mut out, r.rhs.len() as u32);
        out.extend_from_slice(&r.data);
        out.extend_from_slice(&r.rhs);
    }
    for d in &bundle.decisions {
        out.push(d.kind.tag());
        out.extend_from_slice(&[0, 0, 0]); // reserved
        put_u64(&mut out, d.ordinal);
        put_u64(&mut out, d.req);
        put_u64(&mut out, d.a);
        put_u64(&mut out, d.b);
    }
    out
}

// ---------------------------------------------------------------------------
// Decode.

/// Parse a bundle, dispatching on the header's version byte. Unknown
/// versions are rejected with the version named — never guessed at.
pub fn decode(bytes: &[u8]) -> Result<Bundle, BundleError> {
    if bytes.len() < 5 {
        return err("bundle shorter than its header");
    }
    if bytes[0..4] != MAGIC {
        return err(format!(
            "bad magic {:02x}{:02x}{:02x}{:02x} (want 4d4c5242 \"MLRB\")",
            bytes[0], bytes[1], bytes[2], bytes[3]
        ));
    }
    match bytes[4] {
        1 => decode_v1(bytes),
        v => err(format!("unsupported bundle version {v} (this build reads 1)")),
    }
}

/// The v1 decoder — kept as a distinct entry point so future versions
/// must preserve it (the golden-bundle test pins it).
pub fn decode_v1(bytes: &[u8]) -> Result<Bundle, BundleError> {
    let mut c = Cursor::new(bytes);
    c.take(4)?; // magic (checked by decode; re-verified cheaply here)
    let ver = c.u8()?;
    if ver != 1 {
        return err(format!("decode_v1 fed version {ver}"));
    }
    let hdr_flags = c.u8()?; // bit 0 = interleave; rest reserved
    c.u16()?; // reserved
    let workers = c.u32()?;
    let bo = c.u32()?;
    let bi = c.u32()?;
    let mc = c.u32()?;
    let kc = c.u32()?;
    let nc = c.u32()?;
    let steal_tag = c.u8()?;
    let steal_pm = c.u16()?;
    c.u8()?; // reserved
    let steal = StealPolicy::from_wire(steal_tag, steal_pm)
        .ok_or_else(|| BundleError(format!("bad steal policy tag {steal_tag}/{steal_pm}")))?;
    let n_req = c.u32()? as usize;
    let n_dec = c.u32()? as usize;
    let mut requests = Vec::with_capacity(n_req.min(1 << 16));
    for _ in 0..n_req {
        let id = c.u64()?;
        let kind = c.u8()?;
        if kind > REQ_SOLVE {
            return err(format!("unknown request kind code {kind}"));
        }
        let prec = c.u8()?;
        if prec > 2 || (kind != REQ_SOLVE && prec > 1) {
            return err(format!("bad precision code {prec} for kind {kind}"));
        }
        let priority = c.u8()?;
        let flags = c.u8()?;
        let m = c.u32()?;
        let n = c.u32()?;
        let bo = c.u16()?;
        let bi = c.u16()?;
        let deadline_ms = c.u32()?;
        let client = c.u64()?;
        let cols_done = c.u32()?;
        let digest = c.u64()?;
        let data_len = c.u32()? as usize;
        let rhs_len = c.u32()? as usize;
        let elem = if kind == REQ_SOLVE || prec == 0 { 8 } else { 4 };
        let want = (m as usize)
            .checked_mul(n as usize)
            .and_then(|e| e.checked_mul(elem))
            .ok_or_else(|| BundleError(format!("matrix {m}x{n} overflows")))?;
        if data_len != want {
            return err(format!(
                "request {id}: data length {data_len} does not match {m}x{n} in prec {prec}"
            ));
        }
        if kind == REQ_SOLVE {
            if rhs_len != m as usize * 8 {
                return err(format!("solve request {id}: rhs length {rhs_len} != {}", m * 8));
            }
        } else if rhs_len != 0 {
            return err(format!("factor request {id} carries a {rhs_len}-byte rhs"));
        }
        let data = c.take(data_len)?.to_vec();
        let rhs = c.take(rhs_len)?.to_vec();
        requests.push(ReqRecord {
            id,
            kind,
            prec,
            priority,
            cancelled: flags & 1 != 0,
            failed: flags & 2 != 0,
            m,
            n,
            bo,
            bi,
            deadline_ms,
            client,
            cols_done,
            digest,
            data,
            rhs,
        });
    }
    let mut decisions = Vec::with_capacity(n_dec.min(1 << 20));
    for i in 0..n_dec {
        let tag = c.u8()?;
        c.take(3)?; // reserved
        let ordinal = c.u64()?;
        let req = c.u64()?;
        let a = c.u64()?;
        let b = c.u64()?;
        let kind = DecisionKind::from_tag(tag)
            .ok_or_else(|| BundleError(format!("decision {i}: unknown tag {tag}")))?;
        decisions.push(Decision {
            ordinal,
            kind,
            req,
            a,
            b,
        });
    }
    c.done()?;
    Ok(Bundle {
        cfg: BundleCfg {
            workers,
            bo,
            bi,
            mc,
            kc,
            nc,
            steal,
            interleave: hdr_flags & 1 != 0,
        },
        requests,
        decisions,
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn sample() -> Bundle {
        Bundle {
            cfg: BundleCfg {
                workers: 3,
                bo: 16,
                bi: 4,
                mc: 16,
                kc: 8,
                nc: 18,
                steal: StealPolicy::Fraction(500),
                interleave: false,
            },
            requests: vec![ReqRecord {
                id: 0,
                kind: REQ_LU,
                prec: 0,
                priority: 2,
                cancelled: false,
                failed: false,
                m: 2,
                n: 2,
                bo: 0,
                bi: 0,
                deadline_ms: 0,
                client: NO_CLIENT,
                cols_done: 2,
                digest: 0x1234_5678_9abc_def0,
                data: (0..32).collect(),
                rhs: vec![],
            }],
            decisions: vec![
                Decision {
                    ordinal: 0,
                    kind: DecisionKind::Submit,
                    req: 0,
                    a: (2 << 32) | 2,
                    b: 0,
                },
                Decision {
                    ordinal: 1,
                    kind: DecisionKind::LeaseGrant,
                    req: 0,
                    a: 2,
                    b: 1.5f64.to_bits(),
                },
            ],
        }
    }

    #[test]
    fn roundtrip_and_header_bytes() {
        let b = sample();
        let bytes = encode(&b);
        assert_eq!(&bytes[0..4], b"MLRB");
        assert_eq!(bytes[4], 1);
        assert_eq!(decode(&bytes).unwrap(), b);
        assert_eq!(
            bytes.len(),
            PREFIX_LEN + REQ_FIXED + 32 + 2 * DEC_LEN,
            "fixed sizes drifted from the layout constants"
        );
    }

    #[test]
    fn interleave_flag_rides_header_bit_0() {
        let mut b = sample();
        b.cfg.interleave = true;
        let bytes = encode(&b);
        assert_eq!(bytes[5], 1, "flags byte carries the interleave bit");
        assert_eq!(decode(&bytes).unwrap(), b);
        // Pre-§18 bundles wrote flags = 0; they must decode to "off".
        let off = encode(&sample());
        assert_eq!(off[5], 0);
        assert!(!decode(&off).unwrap().cfg.interleave);
        // And the knob survives the serve-config round trip.
        assert!(b.cfg.to_serve().interleave);
        assert!(!sample().cfg.to_serve().interleave);
    }

    #[test]
    fn bad_magic_version_and_truncation_rejected() {
        let bytes = encode(&sample());
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(decode(&bad).unwrap_err().0.contains("magic"));
        let mut bad = bytes.clone();
        bad[4] = 2;
        assert!(decode(&bad).unwrap_err().0.contains("version 2"));
        assert!(decode(&bytes[..bytes.len() - 1]).is_err());
        let mut long = bytes.clone();
        long.push(0);
        assert!(decode(&long).unwrap_err().0.contains("trailing"));
    }

    #[test]
    fn inconsistent_payload_lengths_rejected() {
        let mut b = sample();
        b.requests[0].data.pop();
        assert!(decode(&encode(&b)).is_err());
        let mut b = sample();
        b.requests[0].rhs = vec![0; 4];
        assert!(decode(&encode(&b)).is_err());
    }

    #[test]
    fn steal_policy_wire_roundtrips() {
        for p in [
            StealPolicy::Off,
            StealPolicy::Auto,
            StealPolicy::Fraction(0),
            StealPolicy::Fraction(750),
        ] {
            let (t, pm) = p.wire_tag();
            assert_eq!(StealPolicy::from_wire(t, pm), Some(p));
        }
        assert_eq!(StealPolicy::from_wire(3, 0), None);
        assert_eq!(StealPolicy::from_wire(2, 1001), None);
    }
}
