//! The **capture recorder**: a global, opt-in decision log the serve
//! stack feeds while it schedules (DESIGN.md §16.2).
//!
//! Mirrors the [`crate::trace`] recorder idiom: one process-wide
//! recorder, armed with [`start`] and drained with [`stop`], observed
//! from the hot paths through a single relaxed atomic load ([`active`])
//! so a disarmed build records nothing and pays nothing. The serve
//! layer calls [`record`] at every scheduling decision point — request
//! submission, admission verdict, lease grant/revocation, panel
//! checkpoint, steal-count fold, floater donation, early-termination
//! trigger — and [`record_request`]/[`record_result`] to capture the
//! workload payloads and result digests that make a bundle replayable.
//!
//! Exactly one capture may be active per process (the decision ordinal
//! space is global); [`start`] returns `false` instead of nesting.

use super::bundle::ReqRecord;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// What kind of scheduling decision a [`Decision`] records. The tag
/// values are the wire encoding (bundle decision records, DESIGN.md
/// §16.3) and must never be renumbered — add new kinds at the end.
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub enum DecisionKind {
    /// A request entered the queue. `a` packs the dims
    /// (`m << 32 | n`), `b` packs the scheduling meta
    /// (`kind | prec << 8 | priority << 16 | bo << 32 | bi << 48`).
    Submit = 1,
    /// The daemon's admission verdict for a wire request. `req` is the
    /// *wire* id, `a` the connection id, `b` packs
    /// `verdict | m << 8 | n << 32` (verdict 0 = admitted, else the
    /// [`crate::serve::proto::RejectCode`] byte; dims saturate at 24
    /// bits).
    Admission = 2,
    /// A crew lease was registered for a request. `a` is the priority,
    /// `b` the initial remaining-cost estimate (`f64` bits).
    LeaseGrant = 3,
    /// A panel checkpoint refreshed the lease's remaining-cost
    /// estimate. `a` is the committed-column count `k`, `b` the
    /// refreshed estimate (`f64` bits).
    Checkpoint = 4,
    /// The per-checkpoint stolen-tile fold (DESIGN.md §13). `a` is
    /// `k`, `b` packs `stolen << 32 | tiles` (the deltas since the
    /// previous checkpoint, each saturating at `u32::MAX`).
    StealDelta = 5,
    /// A floating worker donated itself to the most starved crew (the
    /// WS rule across problems). `a` is the registry epoch at the
    /// join, `b` is 0.
    WsJoin = 6,
    /// Early termination fired. `a` is the checkpoint `k` (0 when
    /// unknown), `b` the trigger: 1 = request deadline expired, 2 =
    /// daemon watchdog force-cancel.
    EtTrigger = 7,
    /// The lease was withdrawn at request completion. `a` packs
    /// `cols_done | cancelled << 32 | poisoned << 33`, `b` is 0.
    LeaseRevoke = 8,
    /// The tile-DAG runtime granted a ready task to an executor
    /// (DESIGN.md §17.5). `a` is the task's submit sequence number,
    /// `b` its priority. **Environmental**: with more than one executor
    /// the grant interleaving is timing-shaped, and the DAG's
    /// determinism argument makes the *result* independent of it — the
    /// invariant records of a DAG-driven request are the same
    /// submit/lease/checkpoint stream the crew drivers emit.
    TaskGrant = 9,
    /// The batch assembler grouped a staged small request into a SIMD
    /// bundle (DESIGN.md §18). `a` is the bundle anchor (the id of the
    /// bundle's first member), `b` packs
    /// `n | prec << 8 | live << 16 | slot << 24`. **Environmental**:
    /// bundle composition is timing-shaped (which requests were staged
    /// when the leader fired), and the interleaved kernel's bitwise
    /// contract makes each member's result independent of its
    /// bundle-mates — the invariant record of a bundled request is its
    /// submit alone.
    BundleForm = 10,
}

impl DecisionKind {
    /// Wire tag byte (bundle decision records).
    pub fn tag(self) -> u8 {
        self as u8
    }

    /// Decode a wire tag byte.
    pub fn from_tag(t: u8) -> Option<Self> {
        match t {
            1 => Some(Self::Submit),
            2 => Some(Self::Admission),
            3 => Some(Self::LeaseGrant),
            4 => Some(Self::Checkpoint),
            5 => Some(Self::StealDelta),
            6 => Some(Self::WsJoin),
            7 => Some(Self::EtTrigger),
            8 => Some(Self::LeaseRevoke),
            9 => Some(Self::TaskGrant),
            10 => Some(Self::BundleForm),
            _ => None,
        }
    }

    /// Short lowercase name for reports and divergence rendering.
    pub fn name(self) -> &'static str {
        match self {
            Self::Submit => "submit",
            Self::Admission => "admission",
            Self::LeaseGrant => "lease-grant",
            Self::Checkpoint => "checkpoint",
            Self::StealDelta => "steal-delta",
            Self::WsJoin => "ws-join",
            Self::EtTrigger => "et-trigger",
            Self::LeaseRevoke => "lease-revoke",
            Self::TaskGrant => "task-grant",
            Self::BundleForm => "bundle-form",
        }
    }

    /// Whether records of this kind are **invariant** (must reproduce
    /// bit-for-bit when the bundle is replayed) or **environmental**
    /// (timing artifacts of the capture run, preserved as context and
    /// consumed by the counterfactual engine). See DESIGN.md §16.4 for
    /// the normative split.
    pub fn invariant(self) -> bool {
        matches!(
            self,
            Self::Submit | Self::LeaseGrant | Self::Checkpoint | Self::LeaseRevoke
        )
    }
}

/// One recorded scheduling decision. `a`/`b` are kind-specific packed
/// operands (see [`DecisionKind`]); `ordinal` is the global capture
/// sequence number (gapless from 0).
#[derive(Debug, Copy, Clone, PartialEq, Eq)]
pub struct Decision {
    /// Global capture ordinal (position in the decision stream).
    pub ordinal: u64,
    /// What was decided.
    pub kind: DecisionKind,
    /// The request id the decision concerns (wire id for
    /// [`DecisionKind::Admission`]).
    pub req: u64,
    /// First kind-specific operand.
    pub a: u64,
    /// Second kind-specific operand.
    pub b: u64,
}

impl Decision {
    /// Render the decision for divergence reports and `mlu replay`
    /// output, decoding the packed operands per kind.
    pub fn describe(&self) -> String {
        let d = match self.kind {
            DecisionKind::Submit => format!(
                "dims {}x{} meta {:#x}",
                self.a >> 32,
                self.a & 0xffff_ffff,
                self.b
            ),
            DecisionKind::Admission => format!(
                "client {} verdict {} dims {}x{}",
                self.a,
                self.b & 0xff,
                (self.b >> 8) & 0xff_ffff,
                (self.b >> 32) & 0xff_ffff
            ),
            DecisionKind::LeaseGrant => format!(
                "priority {} remaining {:.3}s",
                self.a,
                f64::from_bits(self.b)
            ),
            DecisionKind::Checkpoint => {
                format!("k {} remaining {:.3}s", self.a, f64::from_bits(self.b))
            }
            DecisionKind::StealDelta => format!(
                "k {} stolen {} tiles {}",
                self.a,
                self.b >> 32,
                self.b & 0xffff_ffff
            ),
            DecisionKind::WsJoin => format!("epoch {}", self.a),
            DecisionKind::EtTrigger => format!(
                "k {} trigger {}",
                self.a,
                if self.b == 2 { "watchdog" } else { "deadline" }
            ),
            DecisionKind::LeaseRevoke => format!(
                "cols_done {} cancelled {} poisoned {}",
                self.a & 0xffff_ffff,
                (self.a >> 32) & 1,
                (self.a >> 33) & 1
            ),
            DecisionKind::TaskGrant => {
                format!("task {} priority {}", self.a, self.b)
            }
            DecisionKind::BundleForm => format!(
                "anchor {} n {} prec {} live {} slot {}",
                self.a,
                self.b & 0xff,
                (self.b >> 8) & 0xff,
                (self.b >> 16) & 0xff,
                (self.b >> 24) & 0xff
            ),
        };
        format!(
            "#{} {} req{} [{}]: {}",
            self.ordinal,
            self.kind.name(),
            self.req,
            if self.kind.invariant() { "inv" } else { "env" },
            d
        )
    }
}

/// Pack a steal-delta pair into [`DecisionKind::StealDelta`]'s `b`
/// operand (`stolen << 32 | tiles`, each saturating at `u32::MAX`).
pub fn pack_delta(stolen: u64, tiles: u64) -> u64 {
    (stolen.min(u64::from(u32::MAX)) << 32) | tiles.min(u64::from(u32::MAX))
}

struct CapState {
    decisions: Vec<Decision>,
    requests: Vec<ReqRecord>,
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<CapState>> = Mutex::new(None);

/// Whether a capture is currently armed. One relaxed load — this is
/// the only cost a non-capturing run pays at each decision point.
#[inline]
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Arm the process-wide capture. Returns `false` (and records nothing)
/// if a capture is already active — captures do not nest.
pub fn start() -> bool {
    let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
    if ACTIVE.load(Ordering::Relaxed) {
        return false;
    }
    *st = Some(CapState {
        decisions: Vec::new(),
        requests: Vec::new(),
    });
    ACTIVE.store(true, Ordering::Release);
    true
}

/// Disarm the capture and take everything it recorded: the decision
/// stream (in ordinal order) and the request records (in submission
/// order). Returns `None` if no capture was active.
pub fn stop() -> Option<(Vec<Decision>, Vec<ReqRecord>)> {
    let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
    ACTIVE.store(false, Ordering::Release);
    st.take().map(|s| (s.decisions, s.requests))
}

/// Append one decision to the active capture (no-op when disarmed).
/// The ordinal is assigned under the log lock, so the stream is
/// gapless and totally ordered even with concurrent recorders.
pub fn record(kind: DecisionKind, req: u64, a: u64, b: u64) {
    if !active() {
        return;
    }
    let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(s) = st.as_mut() {
        let ordinal = s.decisions.len() as u64;
        s.decisions.push(Decision {
            ordinal,
            kind,
            req,
            a,
            b,
        });
    }
}

/// Capture one request's replayable payload (called by
/// [`crate::serve::LuServer::submit`]/`submit_solve` while a capture is
/// armed). No-op when disarmed.
pub fn record_request(rec: ReqRecord) {
    if !active() {
        return;
    }
    let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(s) = st.as_mut() {
        s.requests.push(rec);
    }
}

/// Attach the completion outcome (result digest, committed columns,
/// flags) to a captured request. No-op when disarmed or when `id` was
/// never captured (e.g. submitted before [`start`]).
pub fn record_result(id: u64, digest: u64, cols_done: u32, cancelled: bool, failed: bool) {
    if !active() {
        return;
    }
    let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(s) = st.as_mut() {
        if let Some(r) = s.requests.iter_mut().find(|r| r.id == id) {
            r.digest = digest;
            r.cols_done = cols_done;
            r.cancelled = cancelled;
            r.failed = failed;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_tags_roundtrip_and_split_is_stable() {
        for tag in 1..=10u8 {
            let k = DecisionKind::from_tag(tag).unwrap();
            assert_eq!(k.tag(), tag);
        }
        assert!(DecisionKind::from_tag(0).is_none());
        assert!(DecisionKind::from_tag(11).is_none());
        // The invariant/environmental split is part of the v1 format
        // contract (DESIGN.md §16.4) — changing it is a version bump.
        // Task grants (tag 9) are environmental by the DAG determinism
        // argument (DESIGN.md §17.5); bundle formations (tag 10) by the
        // interleaved kernel's bitwise contract (DESIGN.md §18).
        let inv: Vec<u8> = (1..=10)
            .filter(|&t| DecisionKind::from_tag(t).unwrap().invariant())
            .collect();
        assert_eq!(inv, vec![1, 3, 4, 8]);
    }

    #[test]
    fn describe_names_every_kind() {
        for tag in 1..=10u8 {
            let d = Decision {
                ordinal: 7,
                kind: DecisionKind::from_tag(tag).unwrap(),
                req: 3,
                a: 1,
                b: 2,
            };
            let s = d.describe();
            assert!(s.contains("req3"), "{s}");
            assert!(s.contains("#7"), "{s}");
        }
    }
}
