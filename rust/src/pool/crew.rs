//! Malleable SPMD crews: the Worker-Sharing (WS) mechanism.
//!
//! A [`Crew`] has one *leader* — the thread that publishes jobs with
//! [`Crew::parallel`] and participates in executing them — and a dynamic
//! set of *members* spinning in [`CrewShared::member_loop`]. Each job is a
//! bag of `n_chunks` independent chunks; every participant (leader and
//! members alike) self-schedules chunks via an atomic ticket, so the work
//! distribution automatically adapts to however many workers are enlisted
//! at the moment — this is what makes the team *malleable*.

use super::steal::{StealPolicy, TileSched, TileSource};
use crate::blis::arena::PackArena;
use crossbeam_utils::{Backoff, CachePadded};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// When a joining worker starts contributing.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum EntryPolicy {
    /// Contribute from the *next published job* onwards. This reproduces
    /// the paper's entry points (Fig. 10): GEMM publishes one job per
    /// Loop-3 iteration, so joins take effect at `i_c` boundaries.
    JobBoundary,
    /// Additionally steal chunks of the job already in flight (ablation;
    /// finer-grained than the paper's mechanism).
    Immediate,
}

/// `(epoch << 32) | next_chunk` — a single word so that "which job" and
/// "which chunk" are claimed together. A member that still holds the
/// function of job `e` can never successfully claim a chunk once the
/// leader has moved to job `e+1`, because the CAS checks the epoch bits.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
struct Ticket(u64);

impl Ticket {
    fn new(epoch: u32, chunk: u32) -> Self {
        Ticket(((epoch as u64) << 32) | chunk as u64)
    }
    fn epoch(self) -> u32 {
        (self.0 >> 32) as u32
    }
    fn chunk(self) -> u32 {
        self.0 as u32
    }
}

/// Raw fat pointer to the job closure. Stored as a raw pointer (not a
/// reference) because stale members may *hold* it after the closure's
/// stack frame died; they provably never *call* it then (the ticket CAS
/// fails), and holding a raw pointer is sound where holding a dangling
/// `&` would not be.
#[derive(Copy, Clone)]
struct JobFn(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is Sync and only dereferenced while the leader is
// parked inside `parallel` (liveness guaranteed by the completion count).
unsafe impl Send for JobFn {}

struct JobSlot {
    f: Option<JobFn>,
    n_chunks: u32,
    /// Hybrid static/dynamic schedule for this job (`None` = central
    /// ticket self-scheduling). Fetched together with `f` under the
    /// lock, so a participant always pulls a job through the scheduler
    /// it was published with.
    sched: Option<Arc<TileSched>>,
}

/// Counters exposed for tests, traces and benchmarks.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CrewStats {
    /// Jobs published over the crew's lifetime.
    pub jobs: u64,
    /// Chunks executed by the leader.
    pub leader_chunks: u64,
    /// Chunks executed by members.
    pub member_chunks: u64,
    /// High-water mark of concurrently enlisted members.
    pub max_members: usize,
    /// Tiles executed under the hybrid scheduler, any source
    /// (DESIGN.md §13).
    pub hybrid_tiles: u64,
    /// Hybrid tiles taken from *another* participant's static slice.
    pub stolen_tiles: u64,
}

/// State shared between the leader and the members.
pub struct CrewShared {
    /// Packed (epoch, next_chunk); epoch 0 means "no job ever published".
    ticket: CachePadded<AtomicU64>,
    /// Chunks of the current job whose execution has finished.
    completed: CachePadded<AtomicUsize>,
    /// Current job closure + chunk count; read by members under the lock
    /// after observing a fresh epoch.
    job: Mutex<JobSlot>,
    /// Currently enlisted members (leader excluded).
    members: AtomicUsize,
    /// Lifetime high-water mark of `members`.
    max_members: AtomicUsize,
    /// Chunks executed by members (for stats/tests).
    member_chunks: AtomicU64,
    /// Lifetime count of tiles executed under the hybrid scheduler.
    hybrid_tiles: AtomicU64,
    /// Lifetime count of hybrid tiles stolen from another participant's
    /// static slice — the signal the serve layer's lease-sizing feedback
    /// reads ([`crate::serve`], DESIGN.md §13).
    stolen_tiles: AtomicU64,
    /// Set by `disband`; members exit their loop.
    disbanded: CachePadded<AtomicU64>, // 0 = live, 1 = disbanded
    /// Set when a participant's chunk panicked (DESIGN.md §15.3). The
    /// chunk is still counted in `completed` — so the leader's
    /// `parallel` wait always terminates — but the job's output is
    /// untrustworthy; drivers poll [`CrewShared::is_poisoned`] at their
    /// next checkpoint and fail the run with a typed internal error.
    poisoned: CachePadded<AtomicU64>, // 0 = healthy, 1 = poisoned
    /// The first panic's message (later panics keep the first).
    poison_msg: Mutex<Option<String>>,
}

impl CrewShared {
    fn new() -> Self {
        Self {
            ticket: CachePadded::new(AtomicU64::new(Ticket::new(0, 0).0)),
            completed: CachePadded::new(AtomicUsize::new(0)),
            job: Mutex::new(JobSlot {
                f: None,
                n_chunks: 0,
                sched: None,
            }),
            members: AtomicUsize::new(0),
            max_members: AtomicUsize::new(0),
            member_chunks: AtomicU64::new(0),
            hybrid_tiles: AtomicU64::new(0),
            stolen_tiles: AtomicU64::new(0),
            disbanded: CachePadded::new(AtomicU64::new(0)),
            poisoned: CachePadded::new(AtomicU64::new(0)),
            poison_msg: Mutex::new(None),
        }
    }

    /// Has `disband` been called?
    pub fn is_disbanded(&self) -> bool {
        self.disbanded.load(Ordering::Acquire) != 0
    }

    /// Whether any participant's chunk panicked during any job of this
    /// crew. A poisoned crew still schedules and completes jobs — the
    /// flag tells the *driver* that results since the poisoning are
    /// untrustworthy and the run must end with a typed internal error.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::Acquire) != 0
    }

    /// The first recorded panic message, when poisoned.
    pub fn poison_message(&self) -> Option<String> {
        self.poison_msg
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// Record a chunk panic: keep the first message, raise the flag.
    fn record_poison(&self, payload: Box<dyn std::any::Any + Send>) {
        let msg = super::panic_message(payload.as_ref());
        let mut slot = self.poison_msg.lock().unwrap_or_else(|e| e.into_inner());
        if slot.is_none() {
            *slot = Some(msg);
        }
        drop(slot);
        self.poisoned.store(1, Ordering::Release);
    }

    /// Number of currently enlisted members (excluding the leader).
    pub fn members(&self) -> usize {
        self.members.load(Ordering::Acquire)
    }

    /// Enter the crew as a member and execute chunks until the crew is
    /// disbanded. Blocks the calling thread for the crew's lifetime; this
    /// is the call a freed `T_PF` worker makes to join `T_RU`'s update
    /// (Worker Sharing).
    pub fn member_loop(self: &Arc<Self>, policy: EntryPolicy) {
        self.member_loop_while(policy, || true);
    }

    /// Like [`CrewShared::member_loop`], but additionally returns when
    /// `lease` reports `false`. The lease is polled only *between* jobs —
    /// a member never abandons chunks mid-job, so revocation takes effect
    /// at job boundaries exactly like enlistment does (no chunk can be
    /// lost or double-executed by a departure). This is the primitive
    /// behind [`crate::serve`]'s crew leases: a floating worker enlists
    /// with a lease that turns false when the registry wants it on a more
    /// starved problem.
    pub fn member_loop_while(self: &Arc<Self>, policy: EntryPolicy, lease: impl Fn() -> bool) {
        self.members.fetch_add(1, Ordering::AcqRel);
        self.max_members
            .fetch_max(self.members.load(Ordering::Acquire), Ordering::AcqRel);

        // Which epoch this member has already handled. JobBoundary: treat
        // the in-flight epoch (if any) as handled, so we only react to the
        // next one. Immediate: react to the in-flight epoch too.
        let mut seen = match policy {
            EntryPolicy::JobBoundary => Ticket(self.ticket.load(Ordering::Acquire)).epoch(),
            EntryPolicy::Immediate => {
                Ticket(self.ticket.load(Ordering::Acquire)).epoch().wrapping_sub(1)
            }
        };

        let backoff = Backoff::new();
        loop {
            if self.is_disbanded() || !lease() {
                break;
            }
            let e = Ticket(self.ticket.load(Ordering::Acquire)).epoch();
            if e != seen && e != 0 {
                seen = e;
                // Fetch the job published for epoch `e` (or a later one —
                // in which case the CAS below simply never succeeds for
                // `e` and we re-observe the newer epoch next iteration).
                let (f, n, sched) = {
                    let slot = self.job.lock().unwrap_or_else(|e| e.into_inner());
                    match slot.f {
                        Some(f) => (f, slot.n_chunks, slot.sched.clone()),
                        None => continue,
                    }
                };
                let mine = match sched {
                    Some(s) => self.pull_hybrid(f, &s),
                    None => self.pull_chunks(e, n, f),
                };
                self.member_chunks.fetch_add(mine, Ordering::Relaxed);
                backoff.reset();
            } else {
                // Cooperative wait: on an oversubscribed host (or 1-core
                // CI) spinning would starve the leader.
                backoff.snooze();
            }
        }
        self.members.fetch_sub(1, Ordering::AcqRel);
    }

    /// Claim-and-run tiles of the current hybrid job until every deque
    /// is drained. Returns the number of tiles executed.
    ///
    /// Exactly-once holds because each tile lives in exactly one deque
    /// and deque pops are linearizable; the closure-liveness argument is
    /// the same as for `pull_chunks` — a popped-but-unfinished tile has
    /// not been counted in `completed`, so the leader is still parked
    /// inside `parallel` and the closure's frame is alive. A *stale*
    /// scheduler (fetched for a job that already drained) hands out no
    /// tiles, so holding one is harmless; re-arming a scheduler for a
    /// new job is only done when no stale holder exists (see the
    /// `Arc::strong_count` gate in [`Crew::parallel_steal`]).
    fn pull_hybrid(&self, f: JobFn, sched: &TileSched) -> u64 {
        let slot = sched.claim_slot();
        let mut ran = 0u64;
        let mut stolen = 0u64;
        while let Some((tile, src)) = sched.next_tile(slot) {
            let r = catch_unwind(AssertUnwindSafe(|| {
                #[cfg(any(test, feature = "chaos"))]
                crate::faultplan::chunk_hook(tile);
                // SAFETY: see the closure-liveness note above.
                unsafe { (*f.0)(tile) };
            }));
            // Count the tile completed even on panic — the leader spins
            // on `completed` and must never wait for a dead worker.
            self.completed.fetch_add(1, Ordering::Release);
            if let Err(payload) = r {
                self.record_poison(payload);
            }
            ran += 1;
            if src == TileSource::Stolen {
                stolen += 1;
            }
        }
        if ran > 0 {
            self.hybrid_tiles.fetch_add(ran, Ordering::Relaxed);
        }
        if stolen > 0 {
            self.stolen_tiles.fetch_add(stolen, Ordering::Relaxed);
        }
        ran
    }

    /// Lifetime hybrid-scheduler counters `(stolen_tiles, hybrid_tiles)`
    /// — read by the serve layer's checkpoint to derive the crew's
    /// steal pressure (DESIGN.md §13).
    pub fn steal_stats(&self) -> (u64, u64) {
        (
            self.stolen_tiles.load(Ordering::Relaxed),
            self.hybrid_tiles.load(Ordering::Relaxed),
        )
    }

    /// Claim-and-run chunks of job `epoch` until none remain (or the
    /// leader has moved on). Returns the number of chunks executed.
    fn pull_chunks(&self, epoch: u32, n_chunks: u32, f: JobFn) -> u64 {
        let mut ran = 0u64;
        loop {
            let cur = Ticket(self.ticket.load(Ordering::Acquire));
            if cur.epoch() != epoch || cur.chunk() >= n_chunks {
                return ran;
            }
            let next = Ticket::new(epoch, cur.chunk() + 1);
            if self
                .ticket
                .compare_exchange_weak(cur.0, next.0, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                let r = catch_unwind(AssertUnwindSafe(|| {
                    #[cfg(any(test, feature = "chaos"))]
                    crate::faultplan::chunk_hook(cur.chunk() as usize);
                    // SAFETY: a successful CAS for `epoch` implies the
                    // leader is still inside `parallel` for this job (it
                    // cannot return before `completed == n_chunks`, and
                    // our increment below has not happened yet), so the
                    // closure is alive.
                    unsafe { (*f.0)(cur.chunk() as usize) };
                }));
                // Count the chunk completed even on panic — the leader
                // spins on `completed` and must never wait for a dead
                // worker (the poison flag carries the failure instead).
                self.completed.fetch_add(1, Ordering::Release);
                if let Err(payload) = r {
                    self.record_poison(payload);
                }
                ran += 1;
            }
        }
    }
}

/// A malleable team handle, owned by the leader thread.
pub struct Crew {
    shared: Arc<CrewShared>,
    epoch: u32,
    jobs: u64,
    leader_chunks: u64,
    /// Packing arena the BLAS kernels lease their `A_c`/`B_c` buffers
    /// from (DESIGN.md §9). Fresh per crew by default; drivers that run
    /// many crews (look-ahead iterations, serve leaders) share one via
    /// [`Crew::with_arena`] so steady-state packing never allocates.
    arena: Arc<PackArena>,
    /// Reusable hybrid schedule for [`Crew::parallel_steal`] jobs. Only
    /// re-armed when nothing else holds it (`Arc::strong_count == 1`),
    /// so a stale member can never pop a new job's tiles through an old
    /// job's closure; otherwise a fresh one is allocated (rare — only
    /// under member churn straddling a publish).
    sched_cache: Option<Arc<TileSched>>,
}

impl Default for Crew {
    fn default() -> Self {
        Self::new()
    }
}

impl Crew {
    /// Create a crew with no members (the leader alone executes jobs until
    /// someone enlists) and a private packing arena.
    pub fn new() -> Self {
        Self::with_arena(Arc::new(PackArena::new()))
    }

    /// Create a crew drawing packed-buffer leases from a shared arena.
    pub fn with_arena(arena: Arc<PackArena>) -> Self {
        Self {
            shared: Arc::new(CrewShared::new()),
            epoch: 0,
            jobs: 0,
            leader_chunks: 0,
            arena,
            sched_cache: None,
        }
    }

    /// The crew's packing arena (clone the `Arc` to hold leases across
    /// `parallel` calls).
    pub fn arena(&self) -> &Arc<PackArena> {
        &self.arena
    }

    /// Handle that members use to enlist (clone freely across threads).
    pub fn shared(&self) -> Arc<CrewShared> {
        Arc::clone(&self.shared)
    }

    /// Number of currently enlisted members (excluding the leader).
    pub fn members(&self) -> usize {
        self.shared.members()
    }

    /// Whether a participant's chunk panicked during any job of this
    /// crew (see [`CrewShared::is_poisoned`]).
    pub fn is_poisoned(&self) -> bool {
        self.shared.is_poisoned()
    }

    /// The first recorded panic message, when poisoned.
    pub fn poison_message(&self) -> Option<String> {
        self.shared.poison_message()
    }

    /// Execute `f(chunk)` for every `chunk in 0..n_chunks`, cooperatively
    /// with all currently enlisted members — *and* any member that enlists
    /// while the job is running (they join this job under
    /// [`EntryPolicy::Immediate`], or the next one under
    /// [`EntryPolicy::JobBoundary`]).
    ///
    /// Returns only when every chunk has finished executing. The leader
    /// itself executes chunks, so a crew with zero members degrades to a
    /// sequential loop with two atomic ops per chunk.
    pub fn parallel<F: Fn(usize) + Sync>(&mut self, n_chunks: usize, f: F) {
        self.publish_and_run(n_chunks, None, f);
    }

    /// Like [`Crew::parallel`], but scheduled by `policy`: under a
    /// hybrid policy (DESIGN.md §13) each current participant owns a
    /// static prefix slice of the chunk grid and the remainder goes into
    /// a shared dynamic tail; participants that run dry — including
    /// workers absorbed mid-run via Worker Sharing or serve leases —
    /// take from the tail and then steal from other participants'
    /// slices. Chunk *ownership* moves; chunk *content* does not, so the
    /// result is bitwise identical to [`Crew::parallel`] for every crew
    /// size and steal timing (`tests/steal_agree.rs`).
    pub fn parallel_steal<F: Fn(usize) + Sync>(
        &mut self,
        n_chunks: usize,
        policy: StealPolicy,
        f: F,
    ) {
        let workers = self.members() + 1;
        match policy.static_fraction(workers, n_chunks) {
            None => self.publish_and_run(n_chunks, None, f),
            Some(frac) => {
                let sched = self.take_sched(workers);
                sched.arm(workers, n_chunks, frac);
                self.publish_and_run(n_chunks, Some(sched), f);
            }
        }
    }

    /// Fetch the cached [`TileSched`] if it is safe to re-arm (nothing
    /// else holds it and it has room for `workers` slots), else allocate
    /// a replacement. The returned `Arc` is also stored back in the
    /// cache, so steady-state hybrid jobs allocate nothing here.
    fn take_sched(&mut self, workers: usize) -> Arc<TileSched> {
        if let Some(s) = &self.sched_cache {
            if Arc::strong_count(s) == 1 && s.capacity() >= workers {
                return Arc::clone(s);
            }
        }
        // Oversize a little so roster growth doesn't reallocate
        // every join.
        let fresh = Arc::new(TileSched::with_capacity(workers + 2));
        self.sched_cache = Some(Arc::clone(&fresh));
        fresh
    }

    fn publish_and_run<F: Fn(usize) + Sync>(
        &mut self,
        n_chunks: usize,
        sched: Option<Arc<TileSched>>,
        f: F,
    ) {
        if n_chunks == 0 {
            return;
        }
        assert!(n_chunks <= u32::MAX as usize, "too many chunks");
        let n = n_chunks as u32;
        self.epoch = match self.epoch.checked_add(1) {
            Some(e) => e,
            None => panic!("crew epoch overflow"),
        };
        self.jobs += 1;

        let f_obj: &(dyn Fn(usize) + Sync) = &f;
        // Erase the lifetime: members only call through this pointer while
        // we are inside this function (see `pull_chunks` SAFETY note).
        #[allow(clippy::useless_transmute, clippy::missing_transmute_annotations)]
        let f_raw = JobFn(unsafe {
            std::mem::transmute::<*const (dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(
                f_obj as *const _,
            )
        });

        let hybrid = sched.clone();
        {
            let mut slot = self.shared.job.lock().unwrap_or_else(|e| e.into_inner());
            slot.f = Some(f_raw);
            slot.n_chunks = n;
            slot.sched = sched;
        }
        self.shared.completed.store(0, Ordering::Relaxed);
        // Publish: epoch bump + chunk counter reset in one store. Hybrid
        // jobs publish an exhausted ticket so the ticket path can never
        // hand out a chunk the deques also own.
        let ticket_chunk = if hybrid.is_some() { n } else { 0 };
        self.shared
            .ticket
            .store(Ticket::new(self.epoch, ticket_chunk).0, Ordering::Release);

        // The leader works too.
        self.leader_chunks += match &hybrid {
            Some(s) => self.shared.pull_hybrid(f_raw, s),
            None => self.shared.pull_chunks(self.epoch, n, f_raw),
        };

        // Wait for stragglers (members still finishing their last chunk).
        let backoff = Backoff::new();
        while self.shared.completed.load(Ordering::Acquire) < n_chunks {
            backoff.snooze();
        }
        // Drop the stored pointer and schedule eagerly (the pointer for
        // hygiene, the schedule so the cache's strong count can return
        // to 1 and the next hybrid job may re-arm it).
        let mut slot = self.shared.job.lock().unwrap_or_else(|e| e.into_inner());
        slot.f = None;
        slot.sched = None;
    }

    /// Convenience: split `0..len` into `chunks_per_worker`-ish chunks and
    /// run `f(range)` per chunk. Chunk count adapts to the *current* crew
    /// size so self-scheduling has enough slack to absorb joiners.
    pub fn parallel_ranges<F: Fn(std::ops::Range<usize>) + Sync>(
        &mut self,
        len: usize,
        min_chunk: usize,
        f: F,
    ) {
        if len == 0 {
            return;
        }
        let workers = self.members() + 1;
        // Oversplit by 4x for load balancing, bounded by min_chunk.
        let target = (workers * 4).max(1);
        let chunk = (len.div_ceil(target)).max(min_chunk.max(1));
        let n_chunks = len.div_ceil(chunk);
        self.parallel(n_chunks, |c| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(len);
            f(lo..hi);
        });
    }

    /// Disband the crew: all members return from
    /// [`CrewShared::member_loop`]. Blocks until every member has left, so
    /// the caller can immediately re-use the worker threads.
    pub fn disband(&mut self) {
        self.shared.disbanded.store(1, Ordering::Release);
        let backoff = Backoff::new();
        while self.shared.members.load(Ordering::Acquire) != 0 {
            backoff.snooze();
        }
    }

    /// Lifetime statistics.
    pub fn stats(&self) -> CrewStats {
        CrewStats {
            jobs: self.jobs,
            leader_chunks: self.leader_chunks,
            member_chunks: self.shared.member_chunks.load(Ordering::Relaxed),
            max_members: self.shared.max_members.load(Ordering::Relaxed),
            hybrid_tiles: self.shared.hybrid_tiles.load(Ordering::Relaxed),
            stolen_tiles: self.shared.stolen_tiles.load(Ordering::Relaxed),
        }
    }
}

impl Drop for Crew {
    fn drop(&mut self) {
        self.disband();
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn leader_alone_executes_all_chunks() {
        let mut crew = Crew::new();
        let counter = AtomicUsize::new(0);
        let hit = (0..64).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>();
        crew.parallel(64, |c| {
            hit[c].fetch_add(1, Ordering::Relaxed);
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 64);
        assert!(hit.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        let s = crew.stats();
        assert_eq!(s.jobs, 1);
        assert_eq!(s.leader_chunks, 64);
        assert_eq!(s.member_chunks, 0);
    }

    #[test]
    fn zero_chunks_is_noop() {
        let mut crew = Crew::new();
        crew.parallel(0, |_| panic!("must not run"));
        assert_eq!(crew.stats().jobs, 0);
    }

    #[test]
    fn members_share_the_work() {
        let mut crew = Crew::new();
        let shared = crew.shared();
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let s = Arc::clone(&shared);
                std::thread::spawn(move || s.member_loop(EntryPolicy::JobBoundary))
            })
            .collect();
        // Wait for everyone to enlist so the test actually exercises
        // member execution.
        while crew.members() != 3 {
            std::thread::yield_now();
        }
        let counter = AtomicUsize::new(0);
        for _ in 0..10 {
            crew.parallel(97, |_| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(counter.load(Ordering::Relaxed), 970);
        crew.disband();
        for h in handles {
            h.join().unwrap();
        }
        let s = crew.stats();
        assert_eq!(s.leader_chunks + s.member_chunks, 970);
        assert_eq!(s.max_members, 3);
    }

    #[test]
    fn job_boundary_joiner_skips_inflight_job() {
        // A member that enlists while a job is running must not execute
        // any chunk of it under JobBoundary, but must execute chunks of
        // the next job.
        let mut crew = Crew::new();
        let shared = crew.shared();
        let gate = Arc::new(AtomicUsize::new(0));

        let g = Arc::clone(&gate);
        let s = Arc::clone(&shared);
        let joiner = std::thread::spawn(move || {
            // Wait until the first job is definitely in flight.
            while g.load(Ordering::Acquire) == 0 {
                std::thread::yield_now();
            }
            s.member_loop(EntryPolicy::JobBoundary);
        });

        // First job: chunks block until we've seen the joiner enlist.
        let shared2 = crew.shared();
        crew.parallel(8, |c| {
            gate.store(1, Ordering::Release);
            if c == 0 {
                // Hold the job open until the member has enlisted, to
                // prove it refrains from stealing in-flight chunks.
                while shared2.members() == 0 {
                    std::thread::yield_now();
                }
            }
        });
        let after_first = crew.stats();
        assert_eq!(
            after_first.member_chunks, 0,
            "JobBoundary member stole an in-flight chunk"
        );

        // Second job: the member participates. With the leader parked on
        // chunk grabs only after the member had enlisted, at least the
        // scheduling opportunity exists; assert total correctness rather
        // than a particular split.
        let counter = AtomicUsize::new(0);
        crew.parallel(64, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
            std::thread::yield_now();
        });
        assert_eq!(counter.load(Ordering::Relaxed), 64);
        crew.disband();
        joiner.join().unwrap();
    }

    #[test]
    fn immediate_joiner_can_steal_inflight_chunks() {
        let mut crew = Crew::new();
        let shared = crew.shared();
        let started = Arc::new(AtomicUsize::new(0));

        let s = Arc::clone(&shared);
        let st = Arc::clone(&started);
        let joiner = std::thread::spawn(move || {
            while st.load(Ordering::Acquire) == 0 {
                std::thread::yield_now();
            }
            s.member_loop(EntryPolicy::Immediate);
        });

        let shared2 = crew.shared();
        let started2 = Arc::clone(&started);
        let counter = AtomicUsize::new(0);
        crew.parallel(256, |c| {
            started2.store(1, Ordering::Release);
            if c == 0 {
                // Keep the leader busy so the joiner gets a window.
                while shared2.members() == 0 {
                    std::thread::yield_now();
                }
            }
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 256);
        crew.disband();
        joiner.join().unwrap();
        // The joiner had the whole job minus chunk 0 available while the
        // leader was blocked; it must have stolen something.
        assert!(
            crew.stats().member_chunks > 0,
            "Immediate member never stole an in-flight chunk"
        );
    }

    #[test]
    fn each_chunk_runs_exactly_once_under_churn() {
        // Members joining at random times; every chunk of every job must
        // run exactly once.
        let mut crew = Crew::new();
        let shared = crew.shared();
        const JOBS: usize = 20;
        const CHUNKS: usize = 101;
        let hits: Vec<Vec<AtomicUsize>> = (0..JOBS)
            .map(|_| (0..CHUNKS).map(|_| AtomicUsize::new(0)).collect())
            .collect();

        let mut joiners = Vec::new();
        for i in 0..4 {
            let s = Arc::clone(&shared);
            joiners.push(std::thread::spawn(move || {
                // Staggered joins.
                std::thread::sleep(std::time::Duration::from_micros(50 * i as u64));
                s.member_loop(if i % 2 == 0 {
                    EntryPolicy::Immediate
                } else {
                    EntryPolicy::JobBoundary
                });
            }));
        }

        for job_hits in hits.iter().take(JOBS) {
            crew.parallel(CHUNKS, |c| {
                job_hits[c].fetch_add(1, Ordering::Relaxed);
            });
        }
        crew.disband();
        for j in joiners {
            j.join().unwrap();
        }
        for (j, job_hits) in hits.iter().enumerate() {
            for (c, h) in job_hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "job {j} chunk {c}");
            }
        }
        let s = crew.stats();
        assert_eq!(s.leader_chunks + s.member_chunks, (JOBS * CHUNKS) as u64);
    }

    #[test]
    fn parallel_ranges_covers_exactly() {
        let mut crew = Crew::new();
        for len in [0usize, 1, 7, 100, 1023] {
            let cover: Vec<AtomicUsize> = (0..len).map(|_| AtomicUsize::new(0)).collect();
            crew.parallel_ranges(len, 8, |r| {
                for i in r {
                    cover[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                cover.iter().all(|c| c.load(Ordering::Relaxed) == 1),
                "len={len}"
            );
        }
    }

    #[test]
    fn disband_releases_members() {
        let mut crew = Crew::new();
        let shared = crew.shared();
        let h = std::thread::spawn({
            let s = Arc::clone(&shared);
            move || s.member_loop(EntryPolicy::JobBoundary)
        });
        while crew.members() != 1 {
            std::thread::yield_now();
        }
        crew.disband();
        h.join().unwrap();
        assert_eq!(crew.members(), 0);
        assert!(shared.is_disbanded());
    }

    #[test]
    fn member_loop_while_leaves_at_job_boundary_without_disband() {
        let mut crew = Crew::new();
        let shared = crew.shared();
        let lease = Arc::new(AtomicUsize::new(1));
        let l = Arc::clone(&lease);
        let s = Arc::clone(&shared);
        let h = std::thread::spawn(move || {
            s.member_loop_while(EntryPolicy::Immediate, || l.load(Ordering::Acquire) == 1)
        });
        while crew.members() != 1 {
            std::thread::yield_now();
        }
        // The member works while the lease holds...
        let counter = AtomicUsize::new(0);
        for _ in 0..5 {
            crew.parallel(64, |_| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(counter.load(Ordering::Relaxed), 320);
        // ...and leaves when it is revoked, with the crew still live.
        lease.store(0, Ordering::Release);
        h.join().unwrap();
        assert_eq!(crew.members(), 0);
        assert!(!shared.is_disbanded());
        // The crew remains usable after the departure.
        crew.parallel(8, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 328);
    }

    #[test]
    fn hybrid_leader_alone_executes_all_chunks() {
        let mut crew = Crew::new();
        let hit = (0..97).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>();
        crew.parallel_steal(97, StealPolicy::Auto, |c| {
            hit[c].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hit.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        let s = crew.stats();
        assert_eq!(s.leader_chunks, 97);
        assert_eq!(s.hybrid_tiles, 97);
        assert_eq!(s.stolen_tiles, 0, "a lone leader has no one to rob");
    }

    #[test]
    fn hybrid_each_chunk_runs_exactly_once_under_churn() {
        // The hybrid counterpart of `each_chunk_runs_exactly_once_under_
        // churn`: members joining and leaving at random times, every
        // chunk of every hybrid job runs exactly once.
        let mut crew = Crew::new();
        let shared = crew.shared();
        const JOBS: usize = 20;
        const CHUNKS: usize = 113;
        let hits: Vec<Vec<AtomicUsize>> = (0..JOBS)
            .map(|_| (0..CHUNKS).map(|_| AtomicUsize::new(0)).collect())
            .collect();
        let stop = Arc::new(AtomicUsize::new(0));
        let joiners: Vec<_> = (0..4)
            .map(|i| {
                let s = Arc::clone(&shared);
                let st = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while st.load(Ordering::Acquire) == 0 {
                        let quota = AtomicUsize::new(0);
                        let st2 = Arc::clone(&st);
                        s.member_loop_while(
                            if i % 2 == 0 {
                                EntryPolicy::Immediate
                            } else {
                                EntryPolicy::JobBoundary
                            },
                            move || {
                                quota.fetch_add(1, Ordering::Relaxed) < 200
                                    && st2.load(Ordering::Acquire) == 0
                            },
                        );
                    }
                })
            })
            .collect();
        for (j, job_hits) in hits.iter().enumerate() {
            let policy = match j % 3 {
                0 => StealPolicy::Auto,
                1 => StealPolicy::Fraction(1000),
                _ => StealPolicy::Fraction(300),
            };
            crew.parallel_steal(CHUNKS, policy, |c| {
                job_hits[c].fetch_add(1, Ordering::Relaxed);
            });
        }
        stop.store(1, Ordering::Release);
        crew.disband();
        for j in joiners {
            j.join().unwrap();
        }
        for (j, job_hits) in hits.iter().enumerate() {
            for (c, h) in job_hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "job {j} chunk {c}");
            }
        }
        let s = crew.stats();
        assert_eq!(s.leader_chunks + s.member_chunks, (JOBS * CHUNKS) as u64);
        assert_eq!(s.hybrid_tiles, (JOBS * CHUNKS) as u64);
    }

    #[test]
    fn hybrid_member_finishes_job_after_midjob_revocation() {
        // The "revoke a worker while its deque is non-empty" scenario:
        // the member's lease is revoked *while the hybrid job is in
        // flight* (leases are polled between jobs), so the member still
        // owns undrained tiles at revocation time. The job must complete
        // with every chunk run exactly once, and the member must leave
        // only at the job boundary.
        let mut crew = Crew::new();
        let shared = crew.shared();
        let lease = Arc::new(AtomicUsize::new(1));
        let l = Arc::clone(&lease);
        let s = Arc::clone(&shared);
        let member = std::thread::spawn(move || {
            s.member_loop_while(EntryPolicy::Immediate, || l.load(Ordering::Acquire) == 1)
        });
        while crew.members() != 1 {
            std::thread::yield_now();
        }
        let hit = (0..64).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>();
        let lease2 = Arc::clone(&lease);
        // Fully static split: both participants own a 32-tile slice, so
        // the revocation (fired by the very first tile either side runs)
        // lands while deques are provably non-empty.
        crew.parallel_steal(64, StealPolicy::Fraction(1000), |c| {
            lease2.store(0, Ordering::Release);
            hit[c].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hit.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        member.join().unwrap();
        assert_eq!(crew.members(), 0);
        // The crew keeps working after the departure.
        let n = AtomicUsize::new(0);
        crew.parallel_steal(16, StealPolicy::Auto, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn hybrid_bitwise_matches_ticket_schedule() {
        // parallel vs parallel_steal on the same data: bitwise equality
        // of every output slot, with and without members.
        let data: Vec<f64> = (0..4096).map(|i| (i as f64 * 0.37).sin()).collect();
        let run = |policy: Option<StealPolicy>, members: usize| -> Vec<u64> {
            let mut crew = Crew::new();
            let shared = crew.shared();
            let hs: Vec<_> = (0..members)
                .map(|_| {
                    let s = Arc::clone(&shared);
                    std::thread::spawn(move || s.member_loop(EntryPolicy::Immediate))
                })
                .collect();
            let out: Vec<std::sync::Mutex<f64>> =
                (0..64).map(|_| std::sync::Mutex::new(0.0)).collect();
            let body = |c: usize| {
                let s: f64 = data[c * 64..(c + 1) * 64]
                    .iter()
                    .fold(0.0, |acc, &x| x.mul_add(1.0000001, acc));
                *out[c].lock().unwrap() = s;
            };
            match policy {
                Some(p) => crew.parallel_steal(64, p, body),
                None => crew.parallel(64, body),
            }
            crew.disband();
            for h in hs {
                h.join().unwrap();
            }
            out.iter().map(|m| m.lock().unwrap().to_bits()).collect()
        };
        let base = run(None, 0);
        for members in [0usize, 2] {
            for policy in [
                StealPolicy::Off,
                StealPolicy::Auto,
                StealPolicy::Fraction(500),
                StealPolicy::Fraction(1000),
            ] {
                assert_eq!(
                    base,
                    run(Some(policy), members),
                    "policy {policy:?} members {members}"
                );
            }
        }
    }

    #[test]
    fn hybrid_sched_cache_is_reused_across_jobs() {
        // Steady state must not allocate a fresh TileSched per job: with
        // a stable roster the cached scheduler's strong count returns to
        // 1 between jobs, so the same Arc is re-armed.
        let mut crew = Crew::new();
        crew.parallel_steal(32, StealPolicy::Auto, |_| {});
        let first = crew
            .sched_cache
            .as_ref()
            .map(|s| Arc::as_ptr(s) as usize)
            .unwrap();
        for _ in 0..10 {
            crew.parallel_steal(32, StealPolicy::Auto, |_| {});
            let now = crew
                .sched_cache
                .as_ref()
                .map(|s| Arc::as_ptr(s) as usize)
                .unwrap();
            assert_eq!(first, now, "steady-state hybrid job reallocated its sched");
        }
    }

    #[test]
    fn chunk_panic_poisons_crew_without_hanging_leader() {
        let mut crew = Crew::new();
        let counter = AtomicUsize::new(0);
        crew.parallel(16, |c| {
            counter.fetch_add(1, Ordering::Relaxed);
            if c == 7 {
                panic!("chunk 7 exploded");
            }
        });
        // The leader returned (no hang), every chunk was accounted for,
        // and the crew is poisoned with the panic's message.
        assert_eq!(counter.load(Ordering::Relaxed), 16);
        assert!(crew.is_poisoned());
        assert!(crew.poison_message().unwrap().contains("chunk 7"));
        // A poisoned crew still schedules later jobs — the *driver*
        // decides what the flag means for the run.
        crew.parallel(4, |_| {});
    }

    #[test]
    fn member_chunk_panic_poisons_without_killing_the_member() {
        let mut crew = Crew::new();
        let shared = crew.shared();
        let h = std::thread::spawn({
            let s = Arc::clone(&shared);
            move || s.member_loop(EntryPolicy::Immediate)
        });
        while crew.members() != 1 {
            std::thread::yield_now();
        }
        let counter = AtomicUsize::new(0);
        crew.parallel(64, |c| {
            counter.fetch_add(1, Ordering::Relaxed);
            if c % 13 == 0 {
                panic!("unlucky chunk {c}");
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 64);
        assert!(crew.is_poisoned());
        // The member survived its chunk panic and leaves via disband —
        // the containment property the serve layer's reabsorption needs.
        crew.disband();
        h.join().unwrap();
    }

    #[test]
    fn hybrid_chunk_panic_poisons_too() {
        let mut crew = Crew::new();
        let counter = AtomicUsize::new(0);
        crew.parallel_steal(32, StealPolicy::Auto, |c| {
            counter.fetch_add(1, Ordering::Relaxed);
            if c == 3 {
                panic!("tile 3 exploded");
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 32);
        assert!(crew.is_poisoned());
        assert!(crew.poison_message().unwrap().contains("tile 3"));
    }

    #[test]
    fn results_identical_regardless_of_member_count() {
        // Determinism invariant (DESIGN.md §8): the *work* is identical no
        // matter how many members run it; verify by computing a
        // order-insensitive reduction both ways.
        let data: Vec<f64> = (0..1000).map(|i| (i as f64).sin()).collect();
        let run = |n_members: usize| -> f64 {
            let mut crew = Crew::new();
            let shared = crew.shared();
            let hs: Vec<_> = (0..n_members)
                .map(|_| {
                    let s = Arc::clone(&shared);
                    std::thread::spawn(move || s.member_loop(EntryPolicy::Immediate))
                })
                .collect();
            let out: Vec<std::sync::Mutex<f64>> =
                (0..10).map(|_| std::sync::Mutex::new(0.0)).collect();
            crew.parallel(10, |c| {
                let s: f64 = data[c * 100..(c + 1) * 100].iter().sum();
                *out[c].lock().unwrap() = s;
            });
            crew.disband();
            for h in hs {
                h.join().unwrap();
            }
            out.iter().map(|m| *m.lock().unwrap()).sum()
        };
        let a = run(0);
        let b = run(3);
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
