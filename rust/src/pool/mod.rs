//! The **malleable worker pool** — the paper's Worker-Sharing substrate.
//!
//! Conventional multi-threaded BLAS fixes the number of threads *before* a
//! kernel starts (paper §1). This module instead treats threads as a pool
//! of workers that can be (re)assigned to a kernel **already in
//! execution**:
//!
//! - [`Pool`] owns persistent worker threads, each with a command mailbox.
//! - [`Crew`] is a *malleable team*: one leader (the thread that publishes
//!   SPMD jobs via [`Crew::parallel`]) plus any number of members that
//!   [`CrewShared::member_loop`] into it. Members self-schedule chunks of
//!   each published job, so a worker that enlists between jobs simply
//!   starts contributing at the next job — exactly the "entry point"
//!   semantics of the paper's Fig. 10 (one job is published per iteration
//!   of GEMM's Loop 3, so joins take effect at `i_c` boundaries).
//! - [`EntryPolicy::Immediate`] additionally lets a joining worker steal
//!   chunks of the job in flight (an ablation the paper could not express
//!   with its static round-robin Loop-4 partitioning).
//! - [`CrewShared::member_loop_while`] makes membership *revocable*: a
//!   worker enlists under a lease and leaves at the next job boundary
//!   once the lease is revoked — the primitive the [`crate::serve`]
//!   registry uses to float workers between concurrent problems.
//!
//! The chunk-grab protocol packs `(epoch, next_chunk)` into one atomic so
//! a stale member can never execute a chunk of a later job with an earlier
//! job's function (see `crew::Ticket`).
//!
//! [`Crew::parallel_steal`] adds a second scheduling mode on top of the
//! same job/epoch protocol: a **hybrid static/dynamic** split
//! ([`steal::TileSched`], DESIGN.md §13) in which each participant owns a
//! static slice of the chunk grid and idle participants drain a shared
//! tail, then steal from other slices — the within-update malleability
//! that lets a crew resized mid-iteration rebalance without waiting for
//! the next job boundary.
//!
//! Since the fault-containment work (DESIGN.md §15) this module is also
//! a *supervision* layer: crew chunks run under `catch_unwind`, a panic
//! poisons the crew instead of wedging its leader, and the whole module
//! forbids `unwrap`/`expect` outside tests — lock poisoning is recovered
//! (`unwrap_or_else(|e| e.into_inner())`) because a panicking worker
//! must never take the daemon down with it.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod crew;
pub mod steal;
pub mod worker;

pub use crew::{Crew, CrewShared, CrewStats, EntryPolicy};
pub use steal::{auto_static_fraction, StealPolicy, TileDeque, TileSched, TileSource};
pub use worker::{current_worker, panic_message, Pool, TaskHandle};
