//! Hybrid static/dynamic tile scheduling for crew jobs (DESIGN.md §13).
//!
//! The crew's baseline self-scheduler ([`super::Crew::parallel`]) is a
//! *central* dynamic queue: every participant claims the next chunk by a
//! CAS on one shared ticket word. That balances load perfectly but makes
//! every chunk grab contend on the same cache line, and it gives a
//! participant no affinity to any part of the tile grid. Donfack et al.
//! ("Hybrid static/dynamic scheduling for already optimized dense matrix
//! factorization") show the sweet spot for trailing updates is a hybrid:
//! give each worker a *statically owned* slice (no contention, stable
//! locality) and keep a *dynamic tail* that whoever runs dry — including
//! workers freshly absorbed via Worker Sharing or re-leased by the serve
//! registry — takes from, stealing from other owners' slices once the
//! tail is empty.
//!
//! The building block is the [`TileDeque`]: a contiguous tile range
//! `[lo, hi)` packed into one atomic word. The owner takes from the
//! front, thieves take from the back, both by CAS on the packed word, so
//! the structure is lock-free and every tile is handed out exactly once.
//! A [`TileSched`] is one job's worth of deques: one per planned
//! participant (the static slices) plus one shared tail. Participants
//! claim a slot on arrival; latecomers beyond the planned roster hold no
//! static slice and live entirely off the tail and steals — this is how
//! a worker absorbed mid-factorization contributes without waiting for
//! the next iteration's re-partition.
//!
//! **Determinism**: tile *ownership* moves, tile *content* does not. A
//! chunk computes the same values no matter which participant runs it
//! (each C tile's `k`-reduction is sequential inside one chunk — the
//! fused-reduction contract of DESIGN.md §8), so the hybrid schedule is
//! bitwise identical to the central ticket schedule for every crew size
//! and every steal timing. `tests/steal_agree.rs` proves this across all
//! factorization kinds, both precisions, and mid-run crew resizes.

use crossbeam_utils::CachePadded;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Whether (and how) the trailing-update macro-loop uses the hybrid
/// static/dynamic scheduler. Lives in the pool layer (the [`TileSched`]
/// consumer) but is carried by [`crate::blis::BlisParams`] as the
/// user-facing knob (`mlu --steal off|auto|<fraction>`).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Default)]
pub enum StealPolicy {
    /// Central dynamic self-scheduling only (the pre-steal baseline):
    /// every chunk is claimed from the shared ticket.
    Off,
    /// Hybrid scheduling with the static fraction derived from the crew
    /// size and the tile-grid size ([`auto_static_fraction`]).
    #[default]
    Auto,
    /// Hybrid scheduling with a fixed static fraction, stored in
    /// per-mille (`0..=1000`) so the knob stays `Eq`/`Copy`.
    Fraction(u16),
}

impl StealPolicy {
    /// Parse the `--steal` syntax: `off`, `auto`, or a fraction in
    /// `[0, 1]` (e.g. `0.7`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "none" => Ok(StealPolicy::Off),
            "auto" | "on" => Ok(StealPolicy::Auto),
            other => {
                let f: f64 = other
                    .parse()
                    .map_err(|_| format!("bad --steal {s:?} (expected off|auto|0..1)"))?;
                if !(0.0..=1.0).contains(&f) {
                    return Err(format!("--steal fraction {f} outside [0, 1]"));
                }
                Ok(StealPolicy::Fraction((f * 1000.0).round() as u16))
            }
        }
    }

    /// Display name (`off`, `auto`, or the fraction).
    pub fn name(&self) -> String {
        match self {
            StealPolicy::Off => "off".into(),
            StealPolicy::Auto => "auto".into(),
            StealPolicy::Fraction(pm) => format!("{:.3}", *pm as f64 / 1000.0),
        }
    }

    /// The static fraction to use for a job of `n_tiles` chunks on
    /// `workers` current participants, or `None` when the policy (or a
    /// degenerate grid) says to stay on the central ticket.
    pub fn static_fraction(&self, workers: usize, n_tiles: usize) -> Option<f64> {
        match self {
            StealPolicy::Off => None,
            StealPolicy::Auto => Some(auto_static_fraction(workers, n_tiles)),
            StealPolicy::Fraction(pm) => Some(*pm as f64 / 1000.0),
        }
    }

    /// Stable wire encoding for replay bundles (DESIGN.md §16.3):
    /// `(tag, per_mille)` with tag 0 = off, 1 = auto, 2 = fixed
    /// fraction. The per-mille operand is 0 unless tag is 2.
    pub fn wire_tag(&self) -> (u8, u16) {
        match self {
            StealPolicy::Off => (0, 0),
            StealPolicy::Auto => (1, 0),
            StealPolicy::Fraction(pm) => (2, *pm),
        }
    }

    /// Decode the [`StealPolicy::wire_tag`] encoding; `None` on an
    /// unknown tag or an out-of-range fraction.
    pub fn from_wire(tag: u8, per_mille: u16) -> Option<Self> {
        match tag {
            0 => Some(StealPolicy::Off),
            1 => Some(StealPolicy::Auto),
            2 if per_mille <= 1000 => Some(StealPolicy::Fraction(per_mille)),
            _ => None,
        }
    }
}

/// Static fraction derived from the crew size and the tile-grid size:
/// leave roughly two tiles per worker in the dynamic tail (enough slack
/// to absorb load imbalance and mid-job joiners), never more than 90%
/// static, and fall to fully dynamic when the grid is too small for
/// static slices to mean anything. A lone worker gets 100% static — the
/// tail would only add CAS traffic, and any late joiner can still steal
/// from the owner's slice back.
pub fn auto_static_fraction(workers: usize, n_tiles: usize) -> f64 {
    if workers <= 1 {
        return 1.0;
    }
    if n_tiles <= 2 * workers {
        return 0.0;
    }
    (1.0 - (2.0 * workers as f64) / n_tiles as f64).clamp(0.0, 0.9)
}

/// `(lo << 32) | hi`: the un-issued tile range `[lo, hi)` of one deque.
#[inline]
fn pack(lo: u32, hi: u32) -> u64 {
    ((lo as u64) << 32) | hi as u64
}

#[inline]
fn unpack(v: u64) -> (u32, u32) {
    ((v >> 32) as u32, v as u32)
}

/// A contiguous tile range with lock-free two-ended retrieval: the owner
/// pops from the front (ascending order, preserving its streaming
/// locality), thieves pop from the back (so an owner and a thief only
/// collide on the very last tile). Both ends are claimed by CAS on one
/// packed word; some participant always makes progress.
#[derive(Default)]
pub struct TileDeque {
    range: CachePadded<AtomicU64>,
}

impl TileDeque {
    /// Empty deque.
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset to the range `[lo, hi)`. Only sound while no participant is
    /// popping (the crew arms deques before publishing the job).
    pub fn reset(&self, lo: u32, hi: u32) {
        debug_assert!(lo <= hi);
        self.range.store(pack(lo, hi), Ordering::Release);
    }

    /// Tiles not yet handed out.
    pub fn len(&self) -> usize {
        let (lo, hi) = unpack(self.range.load(Ordering::Acquire));
        hi.saturating_sub(lo) as usize
    }

    /// Whether every tile has been handed out.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Owner end: take the lowest remaining tile.
    pub fn pop_front(&self) -> Option<usize> {
        let mut cur = self.range.load(Ordering::Acquire);
        loop {
            let (lo, hi) = unpack(cur);
            if lo >= hi {
                return None;
            }
            match self.range.compare_exchange_weak(
                cur,
                pack(lo + 1, hi),
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Some(lo as usize),
                Err(now) => cur = now,
            }
        }
    }

    /// Thief end: take the highest remaining tile.
    pub fn pop_back(&self) -> Option<usize> {
        let mut cur = self.range.load(Ordering::Acquire);
        loop {
            let (lo, hi) = unpack(cur);
            if lo >= hi {
                return None;
            }
            match self.range.compare_exchange_weak(
                cur,
                pack(lo, hi - 1),
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Some((hi - 1) as usize),
                Err(now) => cur = now,
            }
        }
    }
}

/// Where a tile came from, for the steal accounting.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum TileSource {
    /// The participant's own static slice.
    Own,
    /// The shared dynamic tail.
    Shared,
    /// Stolen from another participant's static slice.
    Stolen,
}

/// One job's hybrid schedule: `n_owners` static slices plus the shared
/// dynamic tail (module docs above). Reusable across jobs via
/// [`TileSched::arm`] so steady-state crews allocate nothing here.
pub struct TileSched {
    owners: Vec<TileDeque>,
    shared: TileDeque,
    /// Owner slots active for the current job (`<= owners.len()`).
    n_owners: AtomicUsize,
    /// Participant arrival counter; the first `n_owners` arrivals get
    /// static slices, later ones live off the tail and steals.
    next_slot: AtomicUsize,
}

impl TileSched {
    /// A scheduler with room for `capacity` static owner slots.
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            owners: (0..capacity.max(1)).map(|_| TileDeque::new()).collect(),
            shared: TileDeque::new(),
            n_owners: AtomicUsize::new(0),
            next_slot: AtomicUsize::new(0),
        }
    }

    /// Owner slots this scheduler can arm without reallocating.
    pub fn capacity(&self) -> usize {
        self.owners.len()
    }

    /// Partition `n_tiles` for `workers` participants with the given
    /// static fraction: each of the `workers` owner slots gets an equal
    /// `⌊frac·n/workers⌋`-tile prefix slice, the remainder becomes the
    /// shared tail. Must only be called between jobs (no popper active).
    pub fn arm(&self, workers: usize, n_tiles: usize, static_fraction: f64) {
        let w = workers.clamp(1, self.owners.len());
        assert!(n_tiles <= u32::MAX as usize, "too many tiles");
        let static_total = (n_tiles as f64 * static_fraction.clamp(0.0, 1.0)) as usize;
        let per = static_total / w;
        for (i, d) in self.owners.iter().enumerate() {
            if i < w {
                d.reset((i * per) as u32, ((i + 1) * per) as u32);
            } else {
                d.reset(0, 0);
            }
        }
        self.shared.reset((w * per) as u32, n_tiles as u32);
        self.n_owners.store(w, Ordering::Release);
        self.next_slot.store(0, Ordering::Release);
    }

    /// Claim a participant slot for the current job.
    pub fn claim_slot(&self) -> usize {
        self.next_slot.fetch_add(1, Ordering::AcqRel)
    }

    /// Take the next tile for participant `slot`: own slice first, then
    /// the shared tail, then steal from other owners' backs (scanning
    /// from `slot + 1` so thieves spread out). `None` once every deque
    /// has handed out all of its tiles.
    pub fn next_tile(&self, slot: usize) -> Option<(usize, TileSource)> {
        let n = self.n_owners.load(Ordering::Acquire);
        if slot < n {
            if let Some(t) = self.owners[slot].pop_front() {
                return Some((t, TileSource::Own));
            }
        }
        if let Some(t) = self.shared.pop_front() {
            return Some((t, TileSource::Shared));
        }
        for k in 1..=n {
            let victim = (slot + k) % n.max(1);
            if victim == slot {
                continue;
            }
            if let Some(t) = self.owners[victim].pop_back() {
                return Some((t, TileSource::Stolen));
            }
        }
        None
    }

    /// Un-issued tiles across every deque (diagnostics only; racy).
    pub fn remaining(&self) -> usize {
        let n = self.n_owners.load(Ordering::Acquire);
        self.owners.iter().take(n).map(|d| d.len()).sum::<usize>() + self.shared.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn deque_two_ended_pops_are_disjoint_and_exhaustive() {
        let d = TileDeque::new();
        d.reset(3, 10);
        assert_eq!(d.len(), 7);
        assert_eq!(d.pop_front(), Some(3));
        assert_eq!(d.pop_back(), Some(9));
        let mut got = vec![3, 9];
        while let Some(t) = d.pop_front() {
            got.push(t);
        }
        assert!(d.pop_back().is_none());
        got.sort_unstable();
        assert_eq!(got, (3..10).collect::<Vec<_>>());
        assert!(d.is_empty());
    }

    #[test]
    fn deque_concurrent_pops_hand_out_each_tile_once() {
        let d = Arc::new(TileDeque::new());
        const N: usize = 10_000;
        d.reset(0, N as u32);
        let hits: Arc<Vec<AtomicUsize>> =
            Arc::new((0..N).map(|_| AtomicUsize::new(0)).collect());
        let hs: Vec<_> = (0..4)
            .map(|i| {
                let d = Arc::clone(&d);
                let hits = Arc::clone(&hits);
                std::thread::spawn(move || loop {
                    let t = if i % 2 == 0 { d.pop_front() } else { d.pop_back() };
                    let Some(t) = t else { break };
                    hits[t].fetch_add(1, Ordering::Relaxed);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        for (t, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "tile {t}");
        }
    }

    #[test]
    fn sched_partitions_cover_every_tile() {
        for (w, n, frac) in [
            (1usize, 17usize, 1.0f64),
            (3, 17, 0.7),
            (4, 100, 0.9),
            (2, 5, 0.0),
            (6, 3, 0.5), // fewer tiles than workers
        ] {
            let s = TileSched::with_capacity(w);
            s.arm(w, n, frac);
            let mut got = Vec::new();
            // Single collector draining every source.
            let slot = s.claim_slot();
            while let Some((t, _)) = s.next_tile(slot) {
                got.push(t);
            }
            got.sort_unstable();
            assert_eq!(got, (0..n).collect::<Vec<_>>(), "w={w} n={n} frac={frac}");
        }
    }

    #[test]
    fn latecomer_beyond_roster_steals_from_static_slices() {
        let s = TileSched::with_capacity(2);
        s.arm(2, 20, 1.0); // fully static: nothing in the shared tail
        let owner = s.claim_slot();
        let _other = s.claim_slot();
        let late = s.claim_slot(); // slot 2: no static slice
        assert_eq!(owner, 0);
        assert_eq!(late, 2);
        let (t, src) = s.next_tile(late).expect("latecomer must find work");
        assert_eq!(src, TileSource::Stolen);
        assert!(t < 20);
    }

    #[test]
    fn sources_are_classified() {
        let s = TileSched::with_capacity(2);
        s.arm(2, 10, 0.8); // per-owner 4, shared [8, 10)
        let a = s.claim_slot();
        let b = s.claim_slot();
        let (_, src) = s.next_tile(a).unwrap();
        assert_eq!(src, TileSource::Own);
        // Drain b's slice, then the shared tail, then steal from a.
        let mut own = 0;
        let mut shared = 0;
        let mut stolen = 0;
        while let Some((_, src)) = s.next_tile(b) {
            match src {
                TileSource::Own => own += 1,
                TileSource::Shared => shared += 1,
                TileSource::Stolen => stolen += 1,
            }
        }
        assert_eq!(own, 4);
        assert_eq!(shared, 2);
        assert_eq!(stolen, 3, "a took one of its own 4 tiles first");
    }

    #[test]
    fn sched_concurrent_exactly_once_under_mixed_slots() {
        const N: usize = 5_000;
        let s = Arc::new(TileSched::with_capacity(3));
        s.arm(3, N, 0.8);
        let hits: Arc<Vec<AtomicUsize>> =
            Arc::new((0..N).map(|_| AtomicUsize::new(0)).collect());
        let hs: Vec<_> = (0..5) // two more participants than owner slots
            .map(|_| {
                let s = Arc::clone(&s);
                let hits = Arc::clone(&hits);
                std::thread::spawn(move || {
                    let slot = s.claim_slot();
                    while let Some((t, _)) = s.next_tile(slot) {
                        hits[t].fetch_add(1, Ordering::Relaxed);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        for (t, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "tile {t}");
        }
        assert_eq!(s.remaining(), 0);
    }

    #[test]
    fn arm_reuses_without_allocation_observable_state() {
        let s = TileSched::with_capacity(4);
        s.arm(4, 40, 0.5);
        let slot = s.claim_slot();
        while s.next_tile(slot).is_some() {}
        // Re-arm with a different shape; everything must be re-issued.
        s.arm(2, 7, 0.9);
        let slot = s.claim_slot();
        let mut got = Vec::new();
        while let Some((t, _)) = s.next_tile(slot) {
            got.push(t);
        }
        got.sort_unstable();
        assert_eq!(got, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn policy_parse_and_fraction() {
        assert_eq!(StealPolicy::parse("off").unwrap(), StealPolicy::Off);
        assert_eq!(StealPolicy::parse("auto").unwrap(), StealPolicy::Auto);
        assert_eq!(StealPolicy::parse("0.7").unwrap(), StealPolicy::Fraction(700));
        assert!(StealPolicy::parse("1.5").is_err());
        assert!(StealPolicy::parse("banana").is_err());
        assert_eq!(StealPolicy::Off.static_fraction(4, 100), None);
        assert_eq!(StealPolicy::Fraction(250).static_fraction(4, 100), Some(0.25));
        let auto = StealPolicy::Auto.static_fraction(4, 100).unwrap();
        assert!((0.0..=0.9).contains(&auto));
    }

    #[test]
    fn auto_fraction_shapes() {
        assert_eq!(auto_static_fraction(1, 100), 1.0);
        assert_eq!(auto_static_fraction(4, 8), 0.0, "tiny grids go dynamic");
        let f = auto_static_fraction(4, 100);
        assert!((f - 0.92f64.min(0.9)).abs() < 0.1, "got {f}");
        assert!(auto_static_fraction(2, 1_000_000) <= 0.9);
    }
}
