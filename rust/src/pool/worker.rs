//! Persistent worker threads with command mailboxes.
//!
//! A [`Pool`] spawns `n` workers once; the LU drivers then submit one-shot
//! tasks to specific workers (e.g. "worker 0: run the panel branch") and
//! enlist workers into [`super::Crew`]s. Keeping the threads alive across
//! iterations mirrors how a real threaded BLAS pins a team of threads to
//! cores for the duration of a factorization.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type BoxTask = Box<dyn FnOnce() + Send + 'static>;

std::thread_local! {
    static WORKER_ID: std::cell::Cell<Option<usize>> = const { std::cell::Cell::new(None) };
}

/// The pool worker index of the current thread (`None` on non-pool
/// threads, e.g. the main thread). Used by the tracer to attribute spans.
pub fn current_worker() -> Option<usize> {
    WORKER_ID.with(|w| w.get())
}

struct Mailbox {
    queue: Mutex<VecDeque<BoxTask>>,
    ready: Condvar,
}

impl Mailbox {
    fn new() -> Self {
        Self {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
        }
    }

    // Lock poisoning is recovered throughout: a queue of boxed closures
    // has no invariant a mid-push panic could break, and the supervision
    // layer must keep scheduling after a worker panicked.
    fn push(&self, t: BoxTask) {
        self.queue
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push_back(t);
        self.ready.notify_one();
    }

    fn pop(&self, shutdown: &AtomicBool) -> Option<BoxTask> {
        let mut q = self.queue.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(t) = q.pop_front() {
                return Some(t);
            }
            if shutdown.load(Ordering::Acquire) {
                return None;
            }
            q = self.ready.wait(q).unwrap_or_else(|e| e.into_inner());
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
enum TaskState {
    Pending,
    Done,
    Panicked(String),
}

/// Completion handle for a submitted task.
pub struct TaskHandle {
    state: Arc<(Mutex<TaskState>, Condvar)>,
}

impl TaskHandle {
    fn new() -> (Self, Arc<(Mutex<TaskState>, Condvar)>) {
        let state = Arc::new((Mutex::new(TaskState::Pending), Condvar::new()));
        (
            Self {
                state: Arc::clone(&state),
            },
            state,
        )
    }

    /// Block until the task finishes. Panics (on the *caller*) if the task
    /// panicked, propagating the message — failure injection tests rely on
    /// this.
    pub fn wait(self) {
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap_or_else(|e| e.into_inner());
        while *st == TaskState::Pending {
            st = cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if let TaskState::Panicked(msg) = &*st {
            panic!("pool task panicked: {msg}");
        }
    }

    /// Non-blocking completion check (does not consume the handle).
    pub fn is_done(&self) -> bool {
        *self.state.0.lock().unwrap_or_else(|e| e.into_inner()) != TaskState::Pending
    }
}

/// A fixed set of persistent worker threads.
pub struct Pool {
    mailboxes: Vec<Arc<Mailbox>>,
    shutdown: Arc<AtomicBool>,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

impl Pool {
    /// Spawn `n_workers` threads (ids `0..n_workers`).
    pub fn new(n_workers: usize) -> Self {
        let shutdown = Arc::new(AtomicBool::new(false));
        let mailboxes: Vec<Arc<Mailbox>> =
            (0..n_workers).map(|_| Arc::new(Mailbox::new())).collect();
        let threads = mailboxes
            .iter()
            .enumerate()
            .map(|(id, mb)| {
                let mb = Arc::clone(mb);
                let sd = Arc::clone(&shutdown);
                std::thread::Builder::new()
                    .name(format!("mlu-worker-{id}"))
                    .spawn(move || {
                        WORKER_ID.with(|w| w.set(Some(id)));
                        while let Some(task) = mb.pop(&sd) {
                            task();
                        }
                    })
                    .unwrap_or_else(|e| panic!("failed to spawn pool worker: {e}"))
            })
            .collect();
        Self {
            mailboxes,
            shutdown,
            threads: Mutex::new(threads),
        }
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.mailboxes.len()
    }

    /// Submit a one-shot task to a specific worker. Tasks submitted to the
    /// same worker run in submission order.
    pub fn submit(&self, worker: usize, f: impl FnOnce() + Send + 'static) -> TaskHandle {
        assert!(worker < self.workers(), "no such worker {worker}");
        let (handle, state) = TaskHandle::new();
        self.mailboxes[worker].push(Box::new(move || {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            let (lock, cv) = &*state;
            let mut st = lock.lock().unwrap_or_else(|e| e.into_inner());
            *st = match result {
                Ok(()) => TaskState::Done,
                Err(e) => TaskState::Panicked(panic_message(e.as_ref())),
            };
            cv.notify_all();
        }));
        handle
    }

    /// Submit one task per worker, built by `make(worker_id)` — the way
    /// the serve layer installs its per-worker scheduling loops. Handles
    /// are returned in worker order.
    pub fn broadcast<T: FnOnce() + Send + 'static>(
        &self,
        mut make: impl FnMut(usize) -> T,
    ) -> Vec<TaskHandle> {
        (0..self.workers()).map(|w| self.submit(w, make(w))).collect()
    }

    /// Stop all workers after their queued tasks drain. Called on `Drop`.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        for mb in &self.mailboxes {
            // Wake idle workers so they observe the flag.
            mb.ready.notify_all();
        }
        let mut threads = self.threads.lock().unwrap_or_else(|e| e.into_inner());
        for t in threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Best-effort extraction of a panic payload's message (`&str` and
/// `String` payloads; anything else yields a placeholder). Shared by the
/// pool's task supervision, the crew-poisoning path, and the serve
/// leaders' `catch_unwind` handlers.
pub fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::pool::{Crew, EntryPolicy};
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn submit_runs_on_the_right_worker() {
        let pool = Pool::new(3);
        let ids: Vec<Arc<Mutex<Option<usize>>>> =
            (0..3).map(|_| Arc::new(Mutex::new(None))).collect();
        let handles: Vec<_> = (0..3)
            .map(|w| {
                let slot = Arc::clone(&ids[w]);
                pool.submit(w, move || {
                    *slot.lock().unwrap() = current_worker();
                })
            })
            .collect();
        for h in handles {
            h.wait();
        }
        for (w, slot) in ids.iter().enumerate() {
            assert_eq!(*slot.lock().unwrap(), Some(w));
        }
    }

    #[test]
    fn tasks_on_same_worker_run_in_order() {
        let pool = Pool::new(1);
        let log = Arc::new(Mutex::new(Vec::new()));
        let hs: Vec<_> = (0..10)
            .map(|i| {
                let log = Arc::clone(&log);
                pool.submit(0, move || log.lock().unwrap().push(i))
            })
            .collect();
        for h in hs {
            h.wait();
        }
        assert_eq!(*log.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn main_thread_has_no_worker_id() {
        assert_eq!(current_worker(), None);
    }

    #[test]
    fn broadcast_reaches_every_worker() {
        let pool = Pool::new(3);
        let hits: Vec<Arc<AtomicUsize>> = (0..3).map(|_| Arc::new(AtomicUsize::new(0))).collect();
        let handles = pool.broadcast(|w| {
            let h = Arc::clone(&hits[w]);
            move || {
                h.store(current_worker().unwrap() + 1, Ordering::Release);
            }
        });
        assert_eq!(handles.len(), 3);
        for h in handles {
            h.wait();
        }
        for (w, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Acquire), w + 1);
        }
    }

    #[test]
    fn panicking_task_propagates_to_waiter() {
        let pool = Pool::new(1);
        let h = pool.submit(0, || panic!("injected failure"));
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| h.wait()))
            .expect_err("wait should panic");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("injected failure"), "{msg}");
        // Pool still functional after a task panic.
        let ok = Arc::new(AtomicUsize::new(0));
        let ok2 = Arc::clone(&ok);
        pool.submit(0, move || {
            ok2.store(1, Ordering::Release);
        })
        .wait();
        assert_eq!(ok.load(Ordering::Acquire), 1);
    }

    #[test]
    fn is_done_transitions() {
        let pool = Pool::new(1);
        let gate = Arc::new(AtomicBool::new(false));
        let g = Arc::clone(&gate);
        let h = pool.submit(0, move || {
            while !g.load(Ordering::Acquire) {
                std::thread::yield_now();
            }
        });
        assert!(!h.is_done());
        gate.store(true, Ordering::Release);
        h.wait();
    }

    #[test]
    fn workers_can_enlist_in_crews_via_submit() {
        // The WS wiring used by LU_MB: worker 0 finishes its own task and
        // then enlists into the leader's crew.
        let pool = Pool::new(2);
        let mut crew = Crew::new();
        let shared = crew.shared();

        let pf_done = Arc::new(AtomicBool::new(false));
        let pf_done2 = Arc::clone(&pf_done);
        let h = pool.submit(0, move || {
            // "panel factorization" stand-in
            pf_done2.store(true, Ordering::Release);
            // Worker-sharing: join the update crew.
            shared.member_loop(EntryPolicy::JobBoundary);
        });

        // Leader publishes jobs until the worker has joined, then one more
        // round that the member co-executes.
        let count = AtomicUsize::new(0);
        while crew.members() == 0 {
            crew.parallel(4, |_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        crew.parallel(100, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert!(pf_done.load(Ordering::Acquire));
        crew.disband();
        h.wait();
        assert_eq!(count.load(Ordering::Relaxed) % 4, 0);
    }

    #[test]
    fn shutdown_drains_queued_tasks() {
        let count = Arc::new(AtomicUsize::new(0));
        {
            let pool = Pool::new(2);
            for w in 0..2 {
                for _ in 0..50 {
                    let c = Arc::clone(&count);
                    pool.submit(w, move || {
                        c.fetch_add(1, Ordering::Relaxed);
                    });
                }
            }
            // Drop triggers shutdown; queued tasks must still run.
        }
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    #[should_panic(expected = "no such worker")]
    fn submit_to_missing_worker_panics() {
        let pool = Pool::new(1);
        let _ = pool.submit(5, || {});
    }
}
