//! Tiny command-line argument parser (no `clap` in the offline registry —
//! DESIGN.md §3) plus shared helpers for the `mlu` binary, the examples
//! and the bench harnesses.

use std::collections::HashMap;

/// Parsed `--key value` / `--flag` / positional arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Non-flag arguments in order of appearance.
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(items: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut items = items.into_iter().peekable();
        while let Some(item) = items.next() {
            if let Some(key) = item.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if items
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = items.next().unwrap();
                    out.flags.insert(key.to_string(), v);
                } else {
                    out.flags.insert(key.to_string(), String::from("true"));
                }
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    /// From the process environment.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Typed flag with default.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.flags.get(key) {
            None => default,
            Some(s) => s.parse().unwrap_or_else(|_| {
                eprintln!("warning: bad value for --{key}: {s:?}; using default");
                default
            }),
        }
    }

    /// String flag.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Boolean flag presence.
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

/// Render a [`crate::sim::figures::Table`] for terminal display.
pub fn render_table(t: &crate::sim::figures::Table) -> String {
    let mut s = format!("{}\n", t.title);
    let widths: Vec<usize> = t.columns.iter().map(|c| c.len().max(9)).collect();
    for (c, w) in t.columns.iter().zip(&widths) {
        s.push_str(&format!("{c:>w$} "));
    }
    s.push('\n');
    for row in &t.rows {
        for (v, w) in row.iter().zip(&widths) {
            if v.fract() == 0.0 && v.abs() < 1e9 {
                s.push_str(&format!("{:>w$} ", *v as i64));
            } else {
                s.push_str(&format!("{v:>w$.2} "));
            }
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn parses_kv_and_bools_and_positionals() {
        let a = parse("fig 16 --n 2000 --check --bo=256 --variant et");
        assert_eq!(a.positional, vec!["fig", "16"]);
        assert_eq!(a.get("n", 0usize), 2000);
        assert_eq!(a.get("bo", 0usize), 256);
        assert!(a.has("check"));
        assert!(!a.has("missing"));
        assert_eq!(a.get_str("variant", "lu"), "et");
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get("threads", 6usize), 6);
        assert_eq!(a.get_str("out", "-"), "-");
    }

    #[test]
    fn bad_value_falls_back() {
        let a = parse("--n banana");
        assert_eq!(a.get("n", 7usize), 7);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse("--alpha=-1.5");
        assert_eq!(a.get("alpha", 0.0f64), -1.5);
    }

    #[test]
    fn render_table_formats() {
        let t = crate::sim::figures::Table {
            title: "T".into(),
            columns: vec!["n".into(), "gflops".into()],
            rows: vec![vec![1000.0, 55.5]],
        };
        let s = render_table(&t);
        assert!(s.contains("gflops"));
        assert!(s.contains("1000"));
        assert!(s.contains("55.50"));
    }
}
