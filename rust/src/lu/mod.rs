//! The LU-with-partial-pivoting algorithm family (paper §3–§5).
//!
//! | Variant | Paper name | Parallelism |
//! |---|---|---|
//! | [`Variant::Unblocked`] | Fig. 3 left | none (reference) |
//! | [`Variant::BlockedRl`] | `LU` | BDP only (one crew) |
//! | [`Variant::BlockedLl`] | §4.2 LL | BDP only (one crew) |
//! | [`Variant::LookAhead`] | `LU_LA` | TP+BDP, static teams |
//! | [`Variant::Malleable`] | `LU_MB` | TP+BDP + Worker Sharing |
//! | [`Variant::EarlyTerm`] | `LU_ET` | TP+BDP + WS + ET |
//! | [`Variant::OmpSs`] | `LU_OS` | task runtime (see [`crate::taskrt`]) |
//!
//! All variants compute the same factorization `P·A = L·U` and return
//! pivots in LAPACK convention.

pub mod blocked;
pub mod lookahead;
pub mod panel;
pub mod unblocked;

pub use blocked::{lu_blocked_ll, lu_blocked_rl, lu_blocked_rl_ctl, BlockedCtl, BlockedOutcome};
pub use lookahead::{lu_lookahead, lu_lookahead_ctl, LaCtl, LaOpts, LaStats};
pub use panel::{panel_ll, panel_rl, PanelOutcome};
pub use unblocked::lu_unblocked;

use crate::blis::BlisParams;
use crate::matrix::{naive, Matrix};
use crate::pool::{Crew, EntryPolicy, Pool};

/// Algorithm selector (see module docs).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Unblocked reference (paper Fig. 3 left).
    Unblocked,
    /// Blocked right-looking, BDP only (`LU`).
    BlockedRl,
    /// Blocked left-looking, BDP only (§4.2 LL).
    BlockedLl,
    /// Static look-ahead (`LU_LA`).
    LookAhead,
    /// Look-ahead + Worker Sharing (`LU_MB`).
    Malleable,
    /// Look-ahead + WS + Early Termination (`LU_ET`).
    EarlyTerm,
    /// Task-runtime baseline (`LU_OS`).
    OmpSs,
}

impl Variant {
    /// Parse the paper's names: `lu`, `ll`, `la`, `mb`, `et`, `os`,
    /// `unblocked`.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "unblocked" | "unb" => Variant::Unblocked,
            "lu" | "rl" | "blocked" => Variant::BlockedRl,
            "ll" => Variant::BlockedLl,
            "la" | "lu_la" => Variant::LookAhead,
            "mb" | "lu_mb" => Variant::Malleable,
            "et" | "lu_et" => Variant::EarlyTerm,
            "os" | "lu_os" | "ompss" => Variant::OmpSs,
            _ => return None,
        })
    }

    /// Paper-style display name (`LU`, `LU_LA`, ...).
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Unblocked => "unblocked",
            Variant::BlockedRl => "LU",
            Variant::BlockedLl => "LU_LL",
            Variant::LookAhead => "LU_LA",
            Variant::Malleable => "LU_MB",
            Variant::EarlyTerm => "LU_ET",
            Variant::OmpSs => "LU_OS",
        }
    }

    /// All benchmarkable variants in the paper's presentation order.
    pub fn all() -> &'static [Variant] {
        &[
            Variant::BlockedRl,
            Variant::LookAhead,
            Variant::Malleable,
            Variant::EarlyTerm,
            Variant::OmpSs,
        ]
    }
}

/// Factorization configuration.
#[derive(Copy, Clone, Debug)]
pub struct LuConfig {
    /// Algorithm to run.
    pub variant: Variant,
    /// Outer block size `b_o` (paper default for Fig. 16: 256).
    pub bo: usize,
    /// Inner (panel) block size `b_i` (paper: 16 or 32).
    pub bi: usize,
    /// Total threads `t` = pool workers + the calling thread.
    pub threads: usize,
    /// Threads in the panel team (paper: 1).
    pub t_pf: usize,
    /// BLIS blocking parameters for every kernel.
    pub params: BlisParams,
    /// How joining workers enter an in-flight kernel.
    pub entry: EntryPolicy,
}

impl Default for LuConfig {
    fn default() -> Self {
        Self {
            variant: Variant::EarlyTerm,
            bo: 256,
            bi: 32,
            threads: 6,
            t_pf: 1,
            params: BlisParams::default(),
            entry: EntryPolicy::JobBoundary,
        }
    }
}

/// Result of a factorization.
#[derive(Debug, Clone, Default)]
pub struct LuResult {
    /// Pivot rows (LAPACK convention, absolute indices).
    pub ipiv: Vec<usize>,
    /// Look-ahead statistics (empty for non-look-ahead variants).
    pub la_stats: Option<LaStats>,
}

/// Factorize `a` in place with the configured variant. The pool must have
/// `threads - 1` workers (a fresh one is created if `pool` is `None`).
pub fn factorize(a: &mut Matrix, cfg: &LuConfig, pool: Option<&Pool>) -> LuResult {
    let owned_pool;
    let pool = match pool {
        Some(p) => p,
        None => {
            owned_pool = Pool::new(cfg.threads.saturating_sub(1));
            &owned_pool
        }
    };
    match cfg.variant {
        Variant::Unblocked => LuResult {
            ipiv: lu_unblocked(a.view_mut()),
            la_stats: None,
        },
        Variant::BlockedRl | Variant::BlockedLl => {
            // One crew spanning the whole team (BDP only).
            let mut crew = Crew::new();
            let members: Vec<_> = (0..pool.workers())
                .map(|w| {
                    let s = crew.shared();
                    let e = cfg.entry;
                    pool.submit(w, move || s.member_loop(e))
                })
                .collect();
            let ipiv = if cfg.variant == Variant::BlockedRl {
                lu_blocked_rl(&mut crew, &cfg.params, a.view_mut(), cfg.bo, cfg.bi)
            } else {
                lu_blocked_ll(&mut crew, &cfg.params, a.view_mut(), cfg.bo, cfg.bi)
            };
            crew.disband();
            for h in members {
                h.wait();
            }
            LuResult {
                ipiv,
                la_stats: None,
            }
        }
        Variant::LookAhead | Variant::Malleable | Variant::EarlyTerm => {
            let opts = LaOpts {
                malleable: cfg.variant != Variant::LookAhead,
                early_term: cfg.variant == Variant::EarlyTerm,
                entry: cfg.entry,
                t_pf: cfg.t_pf,
            };
            let (ipiv, stats) = lu_lookahead(pool, &cfg.params, a, cfg.bo, cfg.bi, &opts);
            LuResult {
                ipiv,
                la_stats: Some(stats),
            }
        }
        Variant::OmpSs => crate::taskrt::lu_os::factorize_os(pool, a, cfg),
    }
}

/// Outcome of a cancellable factorization (see [`factorize_cancellable`]).
#[derive(Debug, Clone, Default)]
pub struct CancelOutcome {
    /// The (possibly partial) factorization output.
    pub result: LuResult,
    /// Columns fully factorized and committed.
    pub cols_done: usize,
    /// Whether the run was cut short by the control's cancel flag.
    pub cancelled: bool,
}

/// [`factorize`] with a cooperative cancellation checkpoint between outer
/// panel steps — the request-level generalization of the paper's ET
/// mechanism, used by [`crate::serve`] to abandon superseded or
/// deadline-expired requests. Variants without checkpoint support
/// (`Unblocked`, `BlockedLl`, `OmpSs`) run to completion and report
/// `cancelled = false`.
pub fn factorize_cancellable(
    a: &mut Matrix,
    cfg: &LuConfig,
    pool: Option<&Pool>,
    ctl: &LaCtl,
) -> CancelOutcome {
    let owned_pool;
    let pool = match pool {
        Some(p) => p,
        None => {
            owned_pool = Pool::new(cfg.threads.saturating_sub(1));
            &owned_pool
        }
    };
    let kmax = a.rows().min(a.cols());
    match cfg.variant {
        Variant::BlockedRl => {
            let mut crew = Crew::new();
            let members = pool.broadcast(|_w| {
                let s = crew.shared();
                let e = cfg.entry;
                move || s.member_loop(e)
            });
            let bctl = BlockedCtl {
                cancel: Some(&ctl.cancel),
                ..Default::default()
            };
            let out =
                lu_blocked_rl_ctl(&mut crew, &cfg.params, a.view_mut(), cfg.bo, cfg.bi, &bctl);
            crew.disband();
            for h in members {
                h.wait();
            }
            ctl.cols_done
                .store(out.cols_done, std::sync::atomic::Ordering::Release);
            CancelOutcome {
                result: LuResult {
                    ipiv: out.ipiv,
                    la_stats: None,
                },
                cols_done: out.cols_done,
                cancelled: out.cancelled,
            }
        }
        Variant::LookAhead | Variant::Malleable | Variant::EarlyTerm => {
            let opts = LaOpts {
                malleable: cfg.variant != Variant::LookAhead,
                early_term: cfg.variant == Variant::EarlyTerm,
                entry: cfg.entry,
                t_pf: cfg.t_pf,
            };
            let (ipiv, stats) =
                lu_lookahead_ctl(pool, &cfg.params, a, cfg.bo, cfg.bi, &opts, Some(ctl));
            CancelOutcome {
                cols_done: ipiv.len(),
                cancelled: stats.cancelled,
                result: LuResult {
                    ipiv,
                    la_stats: Some(stats),
                },
            }
        }
        _ => CancelOutcome {
            result: factorize(a, cfg, Some(pool)),
            cols_done: kmax,
            cancelled: false,
        },
    }
}

/// Relative residual `‖P·A − L·U‖_F / ‖A‖_F` (delegates to the naive
/// oracle; intended for verification, not benchmarking).
pub fn residual(a_original: &Matrix, factored: &Matrix, ipiv: &[usize]) -> f64 {
    naive::lu_residual(a_original, factored, ipiv)
}

/// Solve `A·x = b` from a factorization.
pub fn solve(factored: &Matrix, ipiv: &[usize], b: &[f64]) -> Vec<f64> {
    naive::lu_solve(factored, ipiv, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(variant: Variant) -> LuConfig {
        LuConfig {
            variant,
            bo: 16,
            bi: 4,
            threads: 3,
            params: BlisParams::tiny(),
            ..Default::default()
        }
    }

    #[test]
    fn dispatch_all_direct_variants() {
        let a0 = Matrix::random(50, 50, 1);
        let mut piv_ref: Option<Vec<usize>> = None;
        for v in [
            Variant::Unblocked,
            Variant::BlockedRl,
            Variant::BlockedLl,
            Variant::LookAhead,
            Variant::Malleable,
            Variant::EarlyTerm,
        ] {
            let mut f = a0.clone();
            let out = factorize(&mut f, &cfg(v), None);
            let r = residual(&a0, &f, &out.ipiv);
            assert!(r < 1e-11, "{}: residual {r}", v.name());
            match &piv_ref {
                None => piv_ref = Some(out.ipiv),
                Some(p) => assert_eq!(*p, out.ipiv, "{} pivots", v.name()),
            }
        }
    }

    #[test]
    fn cancellable_without_cancel_matches_plain() {
        let a0 = Matrix::random(40, 40, 3);
        for v in [Variant::BlockedRl, Variant::Malleable, Variant::OmpSs] {
            let mut f = a0.clone();
            let ctl = LaCtl::new();
            let out = factorize_cancellable(&mut f, &cfg(v), None, &ctl);
            assert!(!out.cancelled, "{}", v.name());
            assert_eq!(out.cols_done, 40, "{}", v.name());
            let r = residual(&a0, &f, &out.result.ipiv);
            assert!(r < 1e-11, "{}: residual {r}", v.name());
        }
    }

    #[test]
    fn cancellable_blocked_stops_at_checkpoint() {
        let a0 = Matrix::random(48, 48, 4);
        let mut f = a0.clone();
        let ctl = LaCtl::new();
        ctl.request_cancel();
        let out = factorize_cancellable(&mut f, &cfg(Variant::BlockedRl), None, &ctl);
        assert!(out.cancelled);
        assert_eq!(out.cols_done, 0);
        assert_eq!(out.result.ipiv.len(), 0);
        // Matrix untouched: no step ever committed.
        assert_eq!(f, a0);
    }

    #[test]
    fn variant_parse_roundtrip() {
        for (s, v) in [
            ("lu", Variant::BlockedRl),
            ("LA", Variant::LookAhead),
            ("mb", Variant::Malleable),
            ("et", Variant::EarlyTerm),
            ("ompss", Variant::OmpSs),
            ("unb", Variant::Unblocked),
            ("ll", Variant::BlockedLl),
        ] {
            assert_eq!(Variant::parse(s), Some(v));
        }
        assert_eq!(Variant::parse("nope"), None);
    }

    #[test]
    fn solve_through_public_api() {
        let n = 24;
        let a0 = Matrix::random_dd(n, 8);
        let x_true: Vec<f64> = (0..n).map(|i| 1.0 + i as f64).collect();
        let mut b = vec![0.0; n];
        for j in 0..n {
            for i in 0..n {
                b[i] += a0[(i, j)] * x_true[j];
            }
        }
        let mut f = a0.clone();
        let out = factorize(&mut f, &cfg(Variant::EarlyTerm), None);
        let x = solve(&f, &out.ipiv, &b);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-9, "x[{i}]");
        }
    }

    #[test]
    fn lookahead_stats_populated() {
        let a0 = Matrix::random(64, 64, 2);
        let mut f = a0.clone();
        let out = factorize(&mut f, &cfg(Variant::Malleable), None);
        let stats = out.la_stats.expect("stats for look-ahead variant");
        assert!(stats.iters >= 2);
        assert_eq!(stats.panel_widths.iter().sum::<usize>(), 64);
    }
}
