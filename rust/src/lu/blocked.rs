//! Plain blocked LU factorizations (paper Fig. 3 right, and the
//! left-looking variant of §4.2) — BDP-only parallelism: one crew
//! executes every kernel, the panel factorization sits on the critical
//! path (this is the `LU` baseline of the evaluation, Fig. 4).
//!
//! Every GEMM/TRSM below runs on the caller's crew and therefore leases
//! its packed buffers from that crew's arena: after the first (largest)
//! trailing update, a factorization performs zero packed-buffer
//! allocations (`tests/perf_invariants.rs`).

use super::panel::panel_rl;
use crate::blis::{gemm, laswp, trsm_llu, BlisParams};
use crate::matrix::MatMut;
use crate::pool::Crew;
use crate::scalar::Scalar;
use crate::trace::{span, Kind};
use std::sync::atomic::AtomicBool;

/// Cooperative control for a checkpointed blocked factorization — the
/// serve layer's generalization of the paper's ET flag from "cut one
/// iteration's panel" to "cut the whole request". The driver polls
/// `cancel` between outer panel steps, reports committed columns through
/// `on_checkpoint`, and tags trace spans with `tag` so multi-problem
/// traces can tell requests apart.
#[derive(Default)]
pub struct BlockedCtl<'a> {
    /// Polled between panel steps; when set the factorization stops
    /// before the next step, leaving a clean factored prefix and an
    /// eagerly-updated (but unfactored) trailing block.
    pub cancel: Option<&'a AtomicBool>,
    /// Trace label prefix (e.g. `req3`); empty keeps the plain labels.
    pub tag: Option<&'a str>,
    /// Called with the number of committed columns after every step.
    pub on_checkpoint: Option<&'a (dyn Fn(usize) + Sync)>,
}

/// Outcome of a checkpointed blocked factorization.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockedOutcome {
    /// Absolute pivots for the committed columns (length `cols_done`).
    pub ipiv: Vec<usize>,
    /// Columns fully factorized (`min(m, n)` unless cancelled early).
    pub cols_done: usize,
    /// Whether the run was cut short by [`BlockedCtl::cancel`].
    pub cancelled: bool,
    /// First typed failure detected by the driver (DESIGN.md §15); an
    /// exactly-zero pivot is recorded here while the factorization
    /// still completes (LAPACK-`info` semantics).
    pub error: Option<crate::factor::FactorError>,
}

/// Blocked right-looking LU with partial pivoting (`LU` in the paper's
/// evaluation). `bo` = outer block size, `bi` = inner (panel) block size.
/// Returns absolute pivot indices (LAPACK convention).
pub fn lu_blocked_rl<S: Scalar>(
    crew: &mut Crew,
    params: &BlisParams,
    a: MatMut<S>,
    bo: usize,
    bi: usize,
) -> Vec<usize> {
    lu_blocked_rl_ctl(crew, params, a, bo, bi, &BlockedCtl::default()).ipiv
}

/// [`lu_blocked_rl`] with cooperative checkpoints between panel steps.
///
/// After `cols_done` committed columns the matrix holds a consistent
/// partial factorization: columns `0..cols_done` carry their final `L`/`U`
/// entries, the trailing block is fully permuted and updated, and the
/// factorization can be completed later by factorizing only the trailing
/// block (tested in `tests/serve_stress.rs`).
///
/// Since the factorization-family refactor this delegates to the
/// **generic** blocked driver ([`crate::factor::driver::blocked_ctl`])
/// instantiated with [`crate::factor::LuFactor`] — the scheduling loop
/// (panel / left swaps / right swaps+TRSM+GEMM, checkpoints, trace tags)
/// exists exactly once, shared with Cholesky and QR.
pub fn lu_blocked_rl_ctl<S: Scalar>(
    crew: &mut Crew,
    params: &BlisParams,
    a: MatMut<S>,
    bo: usize,
    bi: usize,
    ctl: &BlockedCtl,
) -> BlockedOutcome {
    let fctl = crate::factor::FactorCtl {
        cancel: ctl.cancel,
        tag: ctl.tag,
        on_checkpoint: ctl.on_checkpoint,
    };
    let (ipiv, cols_done, cancelled, error) = crate::factor::driver::blocked_ctl(
        &crate::factor::LuFactor,
        crew,
        params,
        a,
        bo,
        bi,
        &fctl,
    );
    BlockedOutcome {
        ipiv,
        cols_done,
        cancelled,
        error,
    }
}

/// Blocked left-looking LU with partial pivoting (paper §4.2, operations
/// LL1–LL3). Mathematically the same factorization as
/// [`lu_blocked_rl`]; the update order is lazy instead of eager.
pub fn lu_blocked_ll<S: Scalar>(
    crew: &mut Crew,
    params: &BlisParams,
    a: MatMut<S>,
    bo: usize,
    bi: usize,
) -> Vec<usize> {
    let (m, n) = (a.rows(), a.cols());
    let kmax = m.min(n);
    let bo = bo.max(1);
    let mut ipiv: Vec<usize> = Vec::with_capacity(kmax);
    let mut k = 0;
    while k < kmax {
        let b = bo.min(kmax - k);
        let cur = a.sub(0, k, m, b);
        // Bring the current block column up to date:
        laswp(crew, cur, &ipiv, 0, k, 0, b);
        if k > 0 {
            // LL1: A01 := TRILU(A00)^{-1} A01.
            trsm_llu(crew, params, a.sub(0, 0, k, k).as_ref(), a.sub(0, k, k, b));
            // LL2: [A11; A21] -= [A10; A20] · A01.
            gemm(
                crew,
                params,
                S::ZERO - S::ONE,
                a.sub(k, 0, m - k, k).as_ref(),
                a.sub(0, k, k, b).as_ref(),
                a.sub(k, k, m - k, b),
            );
        }
        // LL3: factorize [A11; A21].
        let out = span(Kind::Panel, "panel", || {
            panel_rl(crew, params, a.sub(k, k, m - k, b), bi)
        });
        let lo = ipiv.len();
        ipiv.extend(out.ipiv.iter().map(|p| p + k));
        // Apply the new interchanges to the factored prefix.
        laswp(crew, a, &ipiv, lo, lo + b, 0, k);
        k += b;
    }
    // Trailing columns beyond the kmax-th (wide matrices) still need the
    // accumulated transformations.
    if n > kmax {
        let rest = n - kmax;
        laswp(crew, a, &ipiv, 0, kmax, kmax, n);
        trsm_llu(
            crew,
            params,
            a.sub(0, 0, kmax, kmax).as_ref(),
            a.sub(0, kmax, kmax, rest),
        );
    }
    ipiv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{naive, Matrix};
    use crate::pool::EntryPolicy;
    use crate::util::quickcheck_lite::{forall_res, Gen};

    #[test]
    fn rl_matches_unblocked_bitwise() {
        // Same update order as the naive reference within each element's
        // k-chain? Not exactly (blocked uses GEMM grouping), so compare
        // numerically, and pivots exactly.
        for &(m, n, bo, bi) in &[
            (32usize, 32usize, 8usize, 4usize),
            (48, 48, 16, 4),
            (50, 30, 8, 8),
            (30, 50, 8, 2),
            (7, 7, 16, 16),
            (64, 64, 13, 5),
        ] {
            let a0 = Matrix::random(m, n, (m * 7 + n * 3 + bo + bi) as u64);
            let mut f = a0.clone();
            let mut crew = Crew::new();
            let ipiv = lu_blocked_rl(&mut crew, &BlisParams::tiny(), f.view_mut(), bo, bi);
            assert_eq!(ipiv.len(), m.min(n));
            let r = naive::lu_residual(&a0, &f, &ipiv);
            assert!(r < 1e-12, "m={m} n={n} bo={bo} residual={r}");
            assert!(naive::growth_bounded(&f));
            // Pivot sequence must match the unblocked reference.
            let mut g = a0.clone();
            let piv_ref = naive::lu(g.view_mut());
            assert_eq!(ipiv, piv_ref, "pivots m={m} n={n} bo={bo} bi={bi}");
            let d = f.max_abs_diff(&g);
            assert!(d < 1e-10, "factors diff {d}");
        }
    }

    #[test]
    fn ll_matches_rl() {
        for &(m, n, bo, bi) in &[
            (40usize, 40usize, 8usize, 4usize),
            (33, 57, 16, 8),
            (57, 33, 16, 8),
        ] {
            let a0 = Matrix::random(m, n, (m + n + bo) as u64);
            let mut f_rl = a0.clone();
            let mut f_ll = a0.clone();
            let mut crew = Crew::new();
            let p_rl = lu_blocked_rl(&mut crew, &BlisParams::tiny(), f_rl.view_mut(), bo, bi);
            let p_ll = lu_blocked_ll(&mut crew, &BlisParams::tiny(), f_ll.view_mut(), bo, bi);
            assert_eq!(p_rl, p_ll, "pivots m={m} n={n}");
            let d = f_rl.max_abs_diff(&f_ll);
            assert!(d < 1e-10, "factors m={m} n={n} diff={d}");
            let r = naive::lu_residual(&a0, &f_ll, &p_ll);
            assert!(r < 1e-12, "LL residual {r}");
        }
    }

    #[test]
    fn multithreaded_is_bitwise_identical_to_solo() {
        let a0 = Matrix::random(96, 96, 123);
        let mut f1 = a0.clone();
        let mut crew1 = Crew::new();
        let p1 = lu_blocked_rl(&mut crew1, &BlisParams::tiny(), f1.view_mut(), 16, 4);

        let mut f2 = a0.clone();
        let mut crew2 = Crew::new();
        let shared = crew2.shared();
        let hs: Vec<_> = (0..3)
            .map(|_| {
                let s = std::sync::Arc::clone(&shared);
                std::thread::spawn(move || s.member_loop(EntryPolicy::Immediate))
            })
            .collect();
        let p2 = lu_blocked_rl(&mut crew2, &BlisParams::tiny(), f2.view_mut(), 16, 4);
        crew2.disband();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(p1, p2);
        for (x, y) in f1.data().iter().zip(f2.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn singular_matrix_completes() {
        let mut a = Matrix::zeros(16, 16);
        let mut crew = Crew::new();
        let ipiv = lu_blocked_rl(&mut crew, &BlisParams::tiny(), a.view_mut(), 4, 2);
        assert_eq!(ipiv.len(), 16);
        assert!(a.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn singular_matrix_reports_typed_error_and_still_completes() {
        // LAPACK-`info` semantics: the factorization runs to completion
        // (pinned by `singular_matrix_completes` above) *and* the first
        // zero pivot's column is reported as a typed error.
        let mut a = Matrix::zeros(16, 16);
        let mut crew = Crew::new();
        let out = lu_blocked_rl_ctl(
            &mut crew,
            &BlisParams::tiny(),
            a.view_mut(),
            4,
            2,
            &BlockedCtl::default(),
        );
        assert_eq!(out.cols_done, 16);
        assert!(!out.cancelled);
        assert_eq!(
            out.error,
            Some(crate::factor::FactorError::ExactlySingular { col: 0 })
        );
    }

    #[test]
    fn non_finite_input_is_rejected_before_factoring() {
        let mut a = Matrix::random(16, 16, 3);
        a.view_mut().set(5, 2, f64::NAN);
        let snapshot: Vec<u64> = a.data().iter().map(|x| x.to_bits()).collect();
        let mut crew = Crew::new();
        let out = lu_blocked_rl_ctl(
            &mut crew,
            &BlisParams::tiny(),
            a.view_mut(),
            4,
            2,
            &BlockedCtl::default(),
        );
        assert_eq!(out.cols_done, 0);
        assert_eq!(
            out.error,
            Some(crate::factor::FactorError::NonFinite {
                first_offset: 2 * 16 + 5
            })
        );
        // The input must be untouched: the prescan fails fast instead of
        // smearing NaNs through the factors.
        let after: Vec<u64> = a.data().iter().map(|x| x.to_bits()).collect();
        assert_eq!(snapshot, after);
    }

    #[test]
    fn property_blocked_rl_valid() {
        forall_res("blocked RL LU valid", 15, |g: &mut Gen| {
            let m = g.usize_in(1, 80);
            let n = g.usize_in(1, 80);
            let bo = g.choose(&[2usize, 5, 8, 16, 100]);
            let bi = g.choose(&[1usize, 2, 4, 32]);
            let seed = g.seed();
            g.label(format!("m={m} n={n} bo={bo} bi={bi}"));
            let a0 = Matrix::random(m, n, seed);
            let mut f = a0.clone();
            let mut crew = Crew::new();
            let ipiv = lu_blocked_rl(&mut crew, &BlisParams::tiny(), f.view_mut(), bo, bi);
            let r = naive::lu_residual(&a0, &f, &ipiv);
            if r > 1e-11 {
                return Err(format!("residual {r}"));
            }
            if !naive::growth_bounded(&f) {
                return Err("|L|>1".into());
            }
            Ok(())
        });
    }
}
