//! Blocked right-looking LU with **static look-ahead** (paper Fig. 6) and
//! its malleable (WS, §4.1) and early-termination (ET, §4.2) refinements.
//!
//! Since the factorization-family refactor this module is a thin LU
//! veneer over the **generic** look-ahead driver
//! ([`crate::factor::driver::lookahead_ctl`]), which owns the team split,
//! Worker Sharing, and Early Termination for every
//! [`crate::factor::Factorization`] kind (LU, Cholesky, QR). The LU
//! specifics — panel kernels, LASWP/TRSM/GEMM trailing update, lazy left
//! pivot swaps — live in [`crate::factor::LuFactor`]; the scheduling
//! machinery exists exactly once. The control/statistics types
//! ([`LaOpts`], [`LaStats`], [`LaCtl`]) moved to [`crate::factor`] and
//! are re-exported here unchanged.
//!
//! The factors produced are identical (to roundoff) to the plain blocked
//! algorithm for any ET flag timing, and **bitwise** identical for any
//! crew size *and any steal policy* — the trailing update's hybrid
//! static/dynamic tile schedule ([`crate::blis::BlisParams::steal`],
//! DESIGN.md §13) moves tile ownership between crew members but never a
//! tile's arithmetic. See the determinism notes in `factor/driver.rs`
//! and DESIGN.md §8/§11/§13.

pub use crate::factor::{LaCtl, LaOpts, LaStats};

use crate::blis::BlisParams;
use crate::factor::{driver, LuFactor};
use crate::matrix::Mat;
use crate::pool::Pool;
use crate::scalar::Scalar;

/// Factorize `a` in place with look-ahead. `pool` supplies the worker
/// threads (total team = `pool.workers() + 1` counting the caller).
/// Returns absolute pivots and statistics.
pub fn lu_lookahead<S: Scalar>(
    pool: &Pool,
    params: &BlisParams,
    a: &mut Mat<S>,
    bo: usize,
    bi: usize,
    opts: &LaOpts,
) -> (Vec<usize>, LaStats) {
    lu_lookahead_ctl(pool, params, a, bo, bi, opts, None)
}

/// [`lu_lookahead`] with a cooperative cancellation checkpoint between
/// outer panel steps (see [`LaCtl`]).
pub fn lu_lookahead_ctl<S: Scalar>(
    pool: &Pool,
    params: &BlisParams,
    a: &mut Mat<S>,
    bo: usize,
    bi: usize,
    opts: &LaOpts,
    ctl: Option<&LaCtl>,
) -> (Vec<usize>, LaStats) {
    // Typed-error reporting lives on the generic driver / the
    // `factorize_*` entry points; this LU veneer keeps its historical
    // signature (frozen agreement tests call it) and drops the error.
    let (ipiv, stats, _) = driver::lookahead_ctl(&LuFactor, pool, params, a, bo, bi, opts, ctl);
    (ipiv, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{naive, Matrix};
    use crate::pool::{Crew, EntryPolicy};
    use crate::util::quickcheck_lite::{forall_res, Gen};

    fn run(
        a0: &Matrix,
        bo: usize,
        bi: usize,
        workers: usize,
        opts: &LaOpts,
    ) -> (Matrix, Vec<usize>, LaStats) {
        let pool = Pool::new(workers);
        let mut f = a0.clone();
        let (ipiv, stats) = lu_lookahead(&pool, &BlisParams::tiny(), &mut f, bo, bi, opts);
        (f, ipiv, stats)
    }

    #[test]
    fn la_matches_reference() {
        for &(m, n) in &[(48usize, 48usize), (64, 40), (40, 64), (33, 33)] {
            let a0 = Matrix::random(m, n, (m * 5 + n) as u64);
            let (f, ipiv, stats) = run(&a0, 8, 4, 2, &LaOpts::default());
            assert_eq!(ipiv.len(), m.min(n));
            let r = naive::lu_residual(&a0, &f, &ipiv);
            assert!(r < 1e-12, "m={m} n={n} r={r}");
            assert!(stats.iters > 0);
            // Pivots identical to the unblocked reference.
            let mut g = a0.clone();
            let piv_ref = naive::lu(g.view_mut());
            assert_eq!(ipiv, piv_ref, "m={m} n={n}");
        }
    }

    #[test]
    fn la_bitwise_equals_plain_blocked() {
        // LU_LA reorganizes the schedule but performs the exact same
        // floating-point operations per element => bitwise equality with
        // the plain blocked RL code.
        let a0 = Matrix::random(64, 64, 77);
        let (f_la, p_la, _) = run(&a0, 16, 4, 2, &LaOpts::default());
        let mut f_rl = a0.clone();
        let mut crew = Crew::new();
        let p_rl = super::super::blocked::lu_blocked_rl(
            &mut crew,
            &BlisParams::tiny(),
            f_rl.view_mut(),
            16,
            4,
        );
        assert_eq!(p_la, p_rl);
        for (x, y) in f_la.data().iter().zip(f_rl.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn mb_matches_and_reports_ws() {
        let a0 = Matrix::random(96, 96, 3);
        let opts = LaOpts {
            malleable: true,
            ..Default::default()
        };
        let (f, ipiv, stats) = run(&a0, 16, 4, 3, &opts);
        let r = naive::lu_residual(&a0, &f, &ipiv);
        assert!(r < 1e-12, "r={r}");
        // WS must not change the numbers — bitwise vs LU_LA.
        let (f_la, p_la, _) = run(&a0, 16, 4, 3, &LaOpts::default());
        assert_eq!(ipiv, p_la);
        for (x, y) in f.data().iter().zip(f_la.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let _ = stats; // ws_forward is timing-dependent; just ensure it ran.
    }

    #[test]
    fn et_matches_numerically_and_adapts_block() {
        // Small matrix, large block: T_PF >> T_RU, so ET must kick in and
        // shrink the effective panel width.
        let a0 = Matrix::random(72, 72, 9);
        let opts = LaOpts {
            malleable: true,
            early_term: true,
            ..Default::default()
        };
        let (f, ipiv, stats) = run(&a0, 24, 4, 2, &opts);
        let r = naive::lu_residual(&a0, &f, &ipiv);
        assert!(r < 1e-11, "r={r}");
        assert!(naive::growth_bounded(&f));
        // All columns factorized exactly once.
        assert_eq!(ipiv.len(), 72);
        assert_eq!(stats.panel_widths.iter().sum::<usize>(), 72);
        // Pivot choice must equal the reference (ET changes the schedule,
        // not the math).
        let mut g = a0.clone();
        let piv_ref = naive::lu(g.view_mut());
        assert_eq!(ipiv, piv_ref);
    }

    #[test]
    fn mb_with_stealing_bitwise_equals_mb_without() {
        // WS moves whole workers between branches; the hybrid scheduler
        // additionally moves tiles between workers inside the update.
        // Neither may change a bit of the LU.
        use crate::blis::StealPolicy;
        let a0 = Matrix::random(96, 96, 31);
        let opts = LaOpts {
            malleable: true,
            ..Default::default()
        };
        let run = |steal: StealPolicy| {
            let pool = Pool::new(3);
            let params = BlisParams::tiny().with_steal(steal);
            let mut f = a0.clone();
            let (ipiv, stats) = lu_lookahead(&pool, &params, &mut f, 16, 4, &opts);
            (f, ipiv, stats)
        };
        let (f0, p0, _) = run(StealPolicy::Off);
        let (f1, p1, s1) = run(StealPolicy::Auto);
        assert_eq!(p0, p1);
        assert!(s1.hybrid_tiles > 0);
        for (x, y) in f0.data().iter().zip(f1.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn works_with_zero_workers_pool() {
        // Degenerate: everything on the calling thread (t_pf clamps to
        // pool size... pool of 1 => worker 0 is the PF branch).
        let a0 = Matrix::random(32, 32, 4);
        let (f, ipiv, _) = run(&a0, 8, 4, 1, &LaOpts::default());
        let r = naive::lu_residual(&a0, &f, &ipiv);
        assert!(r < 1e-12);
    }

    #[test]
    fn tiny_matrices() {
        for n in [1usize, 2, 3, 7] {
            let a0 = Matrix::random(n, n, n as u64);
            let (f, ipiv, _) = run(&a0, 4, 2, 2, &LaOpts::default());
            let r = naive::lu_residual(&a0, &f, &ipiv);
            assert!(r < 1e-13, "n={n} r={r}");
        }
    }

    #[test]
    fn et_with_immediate_entry() {
        let a0 = Matrix::random(60, 60, 5);
        let opts = LaOpts {
            malleable: true,
            early_term: true,
            entry: EntryPolicy::Immediate,
            t_pf: 1,
        };
        let (f, ipiv, _) = run(&a0, 16, 4, 3, &opts);
        let r = naive::lu_residual(&a0, &f, &ipiv);
        assert!(r < 1e-11, "r={r}");
    }

    #[test]
    fn t_pf_two_threads() {
        let a0 = Matrix::random(64, 64, 6);
        let opts = LaOpts {
            malleable: true,
            t_pf: 2,
            ..Default::default()
        };
        let (f, ipiv, _) = run(&a0, 16, 4, 4, &opts);
        let r = naive::lu_residual(&a0, &f, &ipiv);
        assert!(r < 1e-12, "r={r}");
    }

    #[test]
    fn ctl_cancel_commits_a_clean_prefix() {
        let a0 = Matrix::random(80, 80, 11);
        let pool = Pool::new(2);
        let mut f = a0.clone();
        let ctl = LaCtl::new();
        ctl.request_cancel(); // cancel before the first outer step
        let opts = LaOpts {
            malleable: true,
            ..Default::default()
        };
        let (ipiv, stats) =
            lu_lookahead_ctl(&pool, &BlisParams::tiny(), &mut f, 16, 4, &opts, Some(&ctl));
        assert!(stats.cancelled);
        let done = ctl.cols_done();
        assert_eq!(done, ipiv.len());
        assert!(done > 0 && done < 80);
        assert_eq!(done, stats.panel_widths.iter().sum::<usize>());
        // The committed pivots are the exact prefix of the reference's.
        let mut g = a0.clone();
        let piv_ref = naive::lu(g.view_mut());
        assert_eq!(ipiv[..], piv_ref[..done]);
    }

    #[test]
    fn ctl_uncancelled_matches_plain_lookahead() {
        let a0 = Matrix::random(64, 64, 12);
        let pool = Pool::new(2);
        let ctl = LaCtl::new();
        let opts = LaOpts::default();
        let mut f1 = a0.clone();
        let (p1, s1) =
            lu_lookahead_ctl(&pool, &BlisParams::tiny(), &mut f1, 16, 4, &opts, Some(&ctl));
        assert!(!s1.cancelled);
        assert_eq!(ctl.cols_done(), 64);
        let mut f2 = a0.clone();
        let (p2, _) = lu_lookahead(&pool, &BlisParams::tiny(), &mut f2, 16, 4, &LaOpts::default());
        assert_eq!(p1, p2);
        for (x, y) in f1.data().iter().zip(f2.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn property_all_variants_agree() {
        forall_res("LA/MB/ET produce valid identical-pivot LUs", 8, |g: &mut Gen| {
            let n = g.usize_in(10, 70);
            let bo = g.choose(&[4usize, 8, 16]);
            let bi = g.choose(&[2usize, 4]);
            let seed = g.seed();
            g.label(format!("n={n} bo={bo} bi={bi}"));
            let a0 = Matrix::random(n, n, seed);
            let mut piv_ref = None;
            for (mall, et) in [(false, false), (true, false), (true, true)] {
                let opts = LaOpts {
                    malleable: mall,
                    early_term: et,
                    ..Default::default()
                };
                let (f, ipiv, _) = run(&a0, bo, bi, 2, &opts);
                let r = naive::lu_residual(&a0, &f, &ipiv);
                if r > 1e-11 {
                    return Err(format!("mall={mall} et={et}: residual {r}"));
                }
                match &piv_ref {
                    None => piv_ref = Some(ipiv),
                    Some(p) => {
                        if *p != ipiv {
                            return Err(format!("mall={mall} et={et}: pivots differ"));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
