//! Blocked right-looking LU with **static look-ahead** (paper Fig. 6) and
//! its malleable (WS, §4.1) and early-termination (ET, §4.2) refinements.
//!
//! Per iteration the trailing submatrix is split column-wise into `P`
//! (the *next* panel, width `b_n`) and `R` (the remainder):
//!
//! ```text
//!        f      f+bc     f+bc+bn          n
//!        |  cur  |    P    |       R      |
//! ```
//!
//! Team `T_PF` (pool workers `0..t_pf`, worker 0 leading) applies the
//! current panel's transformations to `P` (PF1: swaps + TRSM, PF2: GEMM)
//! and factorizes it (PF3). Team `T_RU` (the calling thread leading pool
//! workers `t_pf..`) does the same for `R` (RU1, RU2) — concurrently,
//! since the two branches touch disjoint columns.
//!
//! - **WS** (`malleable`): when `T_PF` finishes first, its workers enlist
//!   into `T_RU`'s crew and join the in-flight RU2 GEMM at the next
//!   Loop-3 entry point. When `R` is empty (tail of the factorization)
//!   the *reverse* sharing happens: `T_RU` enlists into `T_PF`'s crew.
//! - **ET** (`early_term`): when `T_RU` finishes first it raises
//!   `ru_done`; the left-looking inner LU polls the flag after each `b_i`
//!   block and aborts, returning `k_done < b_n`. The next iteration's
//!   "current panel" is then only `k_done` wide — the block size
//!   self-adjusts (paper §4.2, §5.3).
//!
//! The ET flag is a plain `AtomicBool` with one writer and one reader —
//! the paper's race-free synchronization — and the factors produced are
//! identical (to roundoff) to the plain blocked algorithm for any flag
//! timing, because the LL inner leaves aborted columns untouched.

use super::panel::{panel_ll, panel_rl, PanelOutcome};
use crate::blis::{gemm, trsm_llu, BlisParams, PackArena};
use crate::matrix::{MatMut, Matrix};
use crate::pool::{Crew, EntryPolicy, Pool};
use crate::trace::{span, Kind};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Which look-ahead refinements are active.
#[derive(Copy, Clone, Debug)]
pub struct LaOpts {
    /// Worker Sharing via the malleable BLAS (LU_MB, LU_ET).
    pub malleable: bool,
    /// Early termination of the panel factorization (LU_ET). Implies the
    /// left-looking inner LU.
    pub early_term: bool,
    /// How joining workers enter an in-flight kernel.
    pub entry: EntryPolicy,
    /// Threads dedicated to the panel branch (the paper uses 1).
    pub t_pf: usize,
}

impl Default for LaOpts {
    fn default() -> Self {
        Self {
            malleable: false,
            early_term: false,
            entry: EntryPolicy::JobBoundary,
            t_pf: 1,
        }
    }
}

/// Execution statistics for the look-ahead driver.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LaStats {
    /// Outer iterations executed.
    pub iters: usize,
    /// Iterations whose panel factorization was cut short by ET.
    pub et_cuts: usize,
    /// Iterations in which at least one PF worker joined the RU crew
    /// (forward worker sharing).
    pub ws_forward: usize,
    /// Iterations in which RU workers joined the PF crew (reverse WS;
    /// only when `R` was empty).
    pub ws_reverse: usize,
    /// Effective width of each factorized panel (shrinks under ET).
    pub panel_widths: Vec<usize>,
    /// Whether the run was cut short through [`LaCtl`] (request-level ET).
    pub cancelled: bool,
}

/// Cooperative control threaded through a look-ahead factorization by
/// callers that may cancel it mid-flight — the serve layer's
/// generalization of the paper's ET flag from "cut one iteration's
/// panel" to "cut the whole request". Polled between outer panel steps.
#[derive(Debug, Default)]
pub struct LaCtl {
    pub(crate) cancel: AtomicBool,
    pub(crate) cols_done: AtomicUsize,
}

impl LaCtl {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ask the factorization to stop at the next outer checkpoint. The
    /// already-factorized current panel is still committed, so the
    /// matrix is left with a clean factored prefix of `cols_done()`
    /// columns; the trailing columns still owe that panel's
    /// transformations (swaps + TRSM + GEMM).
    pub fn request_cancel(&self) {
        self.cancel.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::Acquire)
    }

    /// Columns factorized and committed so far (monotone; reaches
    /// `min(m, n)` on an uncancelled run).
    pub fn cols_done(&self) -> usize {
        self.cols_done.load(Ordering::Acquire)
    }
}

/// Factorize `a` in place with look-ahead. `pool` supplies the worker
/// threads (total team = `pool.workers() + 1` counting the caller).
/// Returns absolute pivots and statistics.
pub fn lu_lookahead(
    pool: &Pool,
    params: &BlisParams,
    a: &mut Matrix,
    bo: usize,
    bi: usize,
    opts: &LaOpts,
) -> (Vec<usize>, LaStats) {
    lu_lookahead_ctl(pool, params, a, bo, bi, opts, None)
}

/// [`lu_lookahead`] with a cooperative cancellation checkpoint between
/// outer panel steps (see [`LaCtl`]).
pub fn lu_lookahead_ctl(
    pool: &Pool,
    params: &BlisParams,
    a: &mut Matrix,
    bo: usize,
    bi: usize,
    opts: &LaOpts,
    ctl: Option<&LaCtl>,
) -> (Vec<usize>, LaStats) {
    let av = a.view_mut();
    let (m, n) = (av.rows(), av.cols());
    let kmax = m.min(n);
    let bo = bo.max(1).min(kmax.max(1));
    let mut stats = LaStats::default();
    let mut ipiv: Vec<usize> = Vec::with_capacity(kmax);
    if kmax == 0 {
        return (ipiv, stats);
    }
    // One packing arena for every crew this factorization creates (the
    // per-iteration PF/RU crews, prologue, epilogue): packed-buffer
    // leases reach steady state after the first trailing update and
    // allocate nothing thereafter (DESIGN.md §9).
    let arena = Arc::new(PackArena::new());
    if pool.workers() == 0 {
        // A single thread cannot run two branches: degrade to the plain
        // blocked RL algorithm (same factorization, no TP).
        let mut crew = Crew::with_arena(Arc::clone(&arena));
        let bctl = super::blocked::BlockedCtl {
            cancel: ctl.map(|c| &c.cancel),
            ..Default::default()
        };
        let out = super::blocked::lu_blocked_rl_ctl(&mut crew, params, av, bo, bi, &bctl);
        stats.cancelled = out.cancelled;
        stats.panel_widths = vec![bo.min(kmax); out.cols_done.div_ceil(bo.max(1))];
        if let Some(c) = ctl {
            c.cols_done.store(out.cols_done, Ordering::Release);
        }
        return (out.ipiv, stats);
    }
    let t_pf = opts.t_pf.max(1).min(pool.workers());

    // ---- Prologue: factorize the first panel with the full team. ----
    let b0 = bo.min(kmax);
    let mut crew_all = Crew::with_arena(Arc::clone(&arena));
    let all_members: Vec<_> = (0..pool.workers())
        .map(|w| {
            let s = crew_all.shared();
            let e = opts.entry;
            pool.submit(w, move || s.member_loop(e))
        })
        .collect();
    let first = span(Kind::Panel, "panel[0]", || {
        panel_rl(&mut crew_all, params, av.sub(0, 0, m, b0), bi)
    });
    crew_all.disband();
    for h in all_members {
        h.wait();
    }

    // `cur`: the factorized-but-not-yet-applied panel [f, f+bc).
    let mut f = 0usize;
    let mut bc = first.k_done;
    let mut piv_cur: Vec<usize> = first.ipiv; // absolute (f == 0)
    // ET's adaptive block size (paper §4.2: a too-large b_o "will be
    // adjusted for the current (and, possibly, subsequent) iterations").
    // On a cut the attempted width shrinks to what proved sustainable; it
    // regrows by b_i per uncut iteration, bounded by b_o.
    let mut attempt = bo;

    loop {
        let right0 = f + bc;
        if let Some(c) = ctl {
            if c.is_cancelled() {
                // Request-level ET: commit the already-factorized current
                // panel (its pivots and lazy left swaps) and stop. The
                // trailing columns keep their pre-update values; see
                // [`LaCtl::request_cancel`] for the resume contract.
                stats.cancelled = true;
                stats.panel_widths.push(bc);
                let mut crew = Crew::with_arena(Arc::clone(&arena));
                laswp_abs(&mut crew, av, &piv_cur, f, 0, f);
                ipiv.extend_from_slice(&piv_cur);
                c.cols_done.store(ipiv.len(), Ordering::Release);
                break;
            }
        }
        stats.panel_widths.push(bc);

        if right0 >= kmax {
            // ---- Epilogue: no panels left to factor. Apply the current
            // panel's transformations to any remaining right columns
            // (wide matrices) and the lazy left swaps, then finish.
            let mut crew = Crew::with_arena(Arc::clone(&arena));
            let members: Vec<_> = (0..pool.workers())
                .map(|w| {
                    let s = crew.shared();
                    let e = opts.entry;
                    pool.submit(w, move || s.member_loop(e))
                })
                .collect();
            if right0 < n {
                let rest = n - right0;
                laswp_abs(&mut crew, av, &piv_cur, f, right0, n);
                trsm_llu(
                    &mut crew,
                    params,
                    av.sub(f, f, bc, bc).as_ref(),
                    av.sub(f, right0, bc, rest),
                );
                if m > right0 {
                    gemm(
                        &mut crew,
                        params,
                        -1.0,
                        av.sub(right0, f, m - right0, bc).as_ref(),
                        av.sub(f, right0, bc, rest).as_ref(),
                        av.sub(right0, right0, m - right0, rest),
                    );
                }
            }
            laswp_abs(&mut crew, av, &piv_cur, f, 0, f);
            ipiv.extend_from_slice(&piv_cur);
            crew.disband();
            for h in members {
                h.wait();
            }
            break;
        }

        stats.iters += 1;
        let bn = attempt.min(kmax - right0);
        let r0 = right0 + bn; // first column of R
        let r_cols = n - r0;

        // Per-iteration shared state.
        let ru_done = Arc::new(AtomicBool::new(false));
        let pf_work_done = Arc::new(AtomicBool::new(false));
        let outcome: Arc<Mutex<Option<PanelOutcome>>> = Arc::new(Mutex::new(None));

        let mut crew_ru = Crew::with_arena(Arc::clone(&arena));
        let ru_shared = crew_ru.shared();
        let crew_pf = Crew::with_arena(Arc::clone(&arena));
        let pf_shared = crew_pf.shared();

        // RU members: workers t_pf.. join RU's crew — unless R is empty,
        // in which case they help the panel branch instead (reverse WS).
        let r_empty = r_cols == 0;
        let join_pf_first = r_empty && opts.malleable;
        let mut handles = Vec::new();
        for w in t_pf..pool.workers() {
            let rs = Arc::clone(&ru_shared);
            let ps = Arc::clone(&pf_shared);
            let e = opts.entry;
            let jp = join_pf_first;
            handles.push(pool.submit(w, move || {
                if jp {
                    ps.member_loop(e);
                }
                rs.member_loop(e);
            }));
        }
        // PF members: workers 1..t_pf, chained into RU on WS.
        for w in 1..t_pf {
            let ps = Arc::clone(&pf_shared);
            let rs = Arc::clone(&ru_shared);
            let e = opts.entry;
            let mall = opts.malleable;
            handles.push(pool.submit(w, move || {
                ps.member_loop(e);
                if mall {
                    rs.member_loop(e);
                }
            }));
        }

        // ---- PF branch on worker 0. ----
        let pf_task = {
            let piv = piv_cur.clone();
            let params = *params;
            let early = opts.early_term;
            let mall = opts.malleable;
            let entry = opts.entry;
            let ru_done = Arc::clone(&ru_done);
            let pf_work_done = Arc::clone(&pf_work_done);
            let outcome = Arc::clone(&outcome);
            let rs = Arc::clone(&ru_shared);
            // Move the crew (leader handle) into the worker task.
            let mut crew_pf = crew_pf;
            let arm_et = early && !r_empty;
            pool.submit(0, move || {
                // PF1: current panel's swaps + TRSM on P.
                span(Kind::Swap, "PF1.swap", || {
                    laswp_abs(&mut crew_pf, av, &piv, f, right0, r0);
                });
                span(Kind::Trsm, "PF1.trsm", || {
                    trsm_llu(
                        &mut crew_pf,
                        &params,
                        av.sub(f, f, bc, bc).as_ref(),
                        av.sub(f, right0, bc, bn),
                    );
                });
                // PF2: GEMM update of P below the current panel row-block.
                span(Kind::Gemm, "PF2.gemm", || {
                    gemm(
                        &mut crew_pf,
                        &params,
                        -1.0,
                        av.sub(right0, f, m - right0, bc).as_ref(),
                        av.sub(f, right0, bc, bn).as_ref(),
                        av.sub(right0, right0, m - right0, bn),
                    );
                });
                // PF3: factorize the next panel.
                let p = av.sub(right0, right0, m - right0, bn);
                let out = span(Kind::Panel, "PF3.panel", || {
                    if early {
                        panel_ll(
                            &mut crew_pf,
                            &params,
                            p,
                            bi,
                            if arm_et { Some(&ru_done) } else { None },
                        )
                    } else {
                        panel_rl(&mut crew_pf, &params, p, bi)
                    }
                });
                *outcome.lock().unwrap() = Some(out);
                pf_work_done.store(true, Ordering::Release);
                crew_pf.disband();
                // Worker Sharing: join the remainder update in flight.
                if mall {
                    rs.member_loop(entry);
                }
            })
        };

        // ---- RU branch on the calling thread. ----
        if r_cols > 0 {
            span(Kind::Swap, "RU1.swap", || {
                laswp_abs(&mut crew_ru, av, &piv_cur, f, r0, n);
            });
            span(Kind::Trsm, "RU1.trsm", || {
                trsm_llu(
                    &mut crew_ru,
                    params,
                    av.sub(f, f, bc, bc).as_ref(),
                    av.sub(f, r0, bc, r_cols),
                );
            });
            span(Kind::Gemm, "RU2.gemm", || {
                gemm(
                    &mut crew_ru,
                    params,
                    -1.0,
                    av.sub(right0, f, m - right0, bc).as_ref(),
                    av.sub(f, r0, bc, r_cols).as_ref(),
                    av.sub(right0, r0, m - right0, r_cols),
                );
            });
        }
        // Lazy left swaps of the current panel (disjoint from P and R).
        span(Kind::Swap, "RU.left_swap", || {
            laswp_abs(&mut crew_ru, av, &piv_cur, f, 0, f);
        });
        // ET: tell the panel branch the update is finished.
        ru_done.store(true, Ordering::Release);

        // Reverse WS: if R was empty, the leader helps the panel team.
        if join_pf_first {
            stats.ws_reverse += 1;
            pf_shared.member_loop(opts.entry);
        }

        // Wait for the panel result (the PF worker may still be enlisted
        // in our crew afterwards — that is fine, it parks on job waits).
        let backoff = crossbeam_utils::Backoff::new();
        while !pf_work_done.load(Ordering::Acquire) {
            backoff.snooze();
        }
        if opts.malleable && crew_ru.stats().max_members > (pool.workers() - t_pf) {
            stats.ws_forward += 1;
        }
        crew_ru.disband();
        for h in handles {
            h.wait();
        }
        pf_task.wait();

        let out = outcome.lock().unwrap().take().expect("panel outcome");
        if out.terminated_early {
            stats.et_cuts += 1;
            attempt = out.k_done.max(bi.max(1));
        } else {
            attempt = (attempt + bi.max(1)).min(bo);
        }

        // Commit the current panel and adopt the next.
        ipiv.extend_from_slice(&piv_cur);
        f = right0;
        bc = out.k_done;
        piv_cur = out.ipiv.iter().map(|p| p + f).collect();
        if let Some(c) = ctl {
            c.cols_done.store(ipiv.len(), Ordering::Release);
        }
    }

    if let Some(c) = ctl {
        c.cols_done.store(ipiv.len(), Ordering::Release);
    }
    debug_assert!(stats.cancelled || ipiv.len() == kmax);
    (ipiv, stats)
}

/// `laswp` with pivot indices relative to row `base` (the panel top):
/// swap rows `base+k` and `piv[k]` (absolute) for columns `jlo..jhi`.
/// Reuses [`crate::blis::laswp`]'s column-strip chunking: each strip
/// applies the whole pivot sequence while its rows are cache-resident.
fn laswp_abs(crew: &mut Crew, a: MatMut, piv: &[usize], base: usize, jlo: usize, jhi: usize) {
    if piv.is_empty() {
        return;
    }
    crate::blis::laswp::for_each_col_strip(crew, jlo, jhi, |lo, hi| {
        for (k, &p) in piv.iter().enumerate() {
            let row = base + k;
            if p != row {
                a.swap_rows(row, p, lo, hi);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::naive;
    use crate::util::quickcheck_lite::{forall_res, Gen};

    fn run(
        a0: &Matrix,
        bo: usize,
        bi: usize,
        workers: usize,
        opts: &LaOpts,
    ) -> (Matrix, Vec<usize>, LaStats) {
        let pool = Pool::new(workers);
        let mut f = a0.clone();
        let (ipiv, stats) = lu_lookahead(&pool, &BlisParams::tiny(), &mut f, bo, bi, opts);
        (f, ipiv, stats)
    }

    #[test]
    fn la_matches_reference() {
        for &(m, n) in &[(48usize, 48usize), (64, 40), (40, 64), (33, 33)] {
            let a0 = Matrix::random(m, n, (m * 5 + n) as u64);
            let (f, ipiv, stats) = run(&a0, 8, 4, 2, &LaOpts::default());
            assert_eq!(ipiv.len(), m.min(n));
            let r = naive::lu_residual(&a0, &f, &ipiv);
            assert!(r < 1e-12, "m={m} n={n} r={r}");
            assert!(stats.iters > 0);
            // Pivots identical to the unblocked reference.
            let mut g = a0.clone();
            let piv_ref = naive::lu(g.view_mut());
            assert_eq!(ipiv, piv_ref, "m={m} n={n}");
        }
    }

    #[test]
    fn la_bitwise_equals_plain_blocked() {
        // LU_LA reorganizes the schedule but performs the exact same
        // floating-point operations per element => bitwise equality with
        // the plain blocked RL code.
        let a0 = Matrix::random(64, 64, 77);
        let (f_la, p_la, _) = run(&a0, 16, 4, 2, &LaOpts::default());
        let mut f_rl = a0.clone();
        let mut crew = Crew::new();
        let p_rl = super::super::blocked::lu_blocked_rl(
            &mut crew,
            &BlisParams::tiny(),
            f_rl.view_mut(),
            16,
            4,
        );
        assert_eq!(p_la, p_rl);
        for (x, y) in f_la.data().iter().zip(f_rl.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn mb_matches_and_reports_ws() {
        let a0 = Matrix::random(96, 96, 3);
        let opts = LaOpts {
            malleable: true,
            ..Default::default()
        };
        let (f, ipiv, stats) = run(&a0, 16, 4, 3, &opts);
        let r = naive::lu_residual(&a0, &f, &ipiv);
        assert!(r < 1e-12, "r={r}");
        // WS must not change the numbers — bitwise vs LU_LA.
        let (f_la, p_la, _) = run(&a0, 16, 4, 3, &LaOpts::default());
        assert_eq!(ipiv, p_la);
        for (x, y) in f.data().iter().zip(f_la.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let _ = stats; // ws_forward is timing-dependent; just ensure it ran.
    }

    #[test]
    fn et_matches_numerically_and_adapts_block() {
        // Small matrix, large block: T_PF >> T_RU, so ET must kick in and
        // shrink the effective panel width.
        let a0 = Matrix::random(72, 72, 9);
        let opts = LaOpts {
            malleable: true,
            early_term: true,
            ..Default::default()
        };
        let (f, ipiv, stats) = run(&a0, 24, 4, 2, &opts);
        let r = naive::lu_residual(&a0, &f, &ipiv);
        assert!(r < 1e-11, "r={r}");
        assert!(naive::growth_bounded(&f));
        // All columns factorized exactly once.
        assert_eq!(ipiv.len(), 72);
        assert_eq!(stats.panel_widths.iter().sum::<usize>(), 72);
        // Pivot choice must equal the reference (ET changes the schedule,
        // not the math).
        let mut g = a0.clone();
        let piv_ref = naive::lu(g.view_mut());
        assert_eq!(ipiv, piv_ref);
    }

    #[test]
    fn works_with_zero_workers_pool() {
        // Degenerate: everything on the calling thread (t_pf clamps to
        // pool size... pool of 1 => worker 0 is the PF branch).
        let a0 = Matrix::random(32, 32, 4);
        let (f, ipiv, _) = run(&a0, 8, 4, 1, &LaOpts::default());
        let r = naive::lu_residual(&a0, &f, &ipiv);
        assert!(r < 1e-12);
    }

    #[test]
    fn tiny_matrices() {
        for n in [1usize, 2, 3, 7] {
            let a0 = Matrix::random(n, n, n as u64);
            let (f, ipiv, _) = run(&a0, 4, 2, 2, &LaOpts::default());
            let r = naive::lu_residual(&a0, &f, &ipiv);
            assert!(r < 1e-13, "n={n} r={r}");
        }
    }

    #[test]
    fn et_with_immediate_entry() {
        let a0 = Matrix::random(60, 60, 5);
        let opts = LaOpts {
            malleable: true,
            early_term: true,
            entry: EntryPolicy::Immediate,
            t_pf: 1,
        };
        let (f, ipiv, _) = run(&a0, 16, 4, 3, &opts);
        let r = naive::lu_residual(&a0, &f, &ipiv);
        assert!(r < 1e-11, "r={r}");
    }

    #[test]
    fn t_pf_two_threads() {
        let a0 = Matrix::random(64, 64, 6);
        let opts = LaOpts {
            malleable: true,
            t_pf: 2,
            ..Default::default()
        };
        let (f, ipiv, _) = run(&a0, 16, 4, 4, &opts);
        let r = naive::lu_residual(&a0, &f, &ipiv);
        assert!(r < 1e-12, "r={r}");
    }

    #[test]
    fn ctl_cancel_commits_a_clean_prefix() {
        let a0 = Matrix::random(80, 80, 11);
        let pool = Pool::new(2);
        let mut f = a0.clone();
        let ctl = LaCtl::new();
        ctl.request_cancel(); // cancel before the first outer step
        let opts = LaOpts {
            malleable: true,
            ..Default::default()
        };
        let (ipiv, stats) =
            lu_lookahead_ctl(&pool, &BlisParams::tiny(), &mut f, 16, 4, &opts, Some(&ctl));
        assert!(stats.cancelled);
        let done = ctl.cols_done();
        assert_eq!(done, ipiv.len());
        assert!(done > 0 && done < 80);
        assert_eq!(done, stats.panel_widths.iter().sum::<usize>());
        // The committed pivots are the exact prefix of the reference's.
        let mut g = a0.clone();
        let piv_ref = naive::lu(g.view_mut());
        assert_eq!(ipiv[..], piv_ref[..done]);
    }

    #[test]
    fn ctl_uncancelled_matches_plain_lookahead() {
        let a0 = Matrix::random(64, 64, 12);
        let pool = Pool::new(2);
        let ctl = LaCtl::new();
        let opts = LaOpts::default();
        let mut f1 = a0.clone();
        let (p1, s1) =
            lu_lookahead_ctl(&pool, &BlisParams::tiny(), &mut f1, 16, 4, &opts, Some(&ctl));
        assert!(!s1.cancelled);
        assert_eq!(ctl.cols_done(), 64);
        let mut f2 = a0.clone();
        let (p2, _) = lu_lookahead(&pool, &BlisParams::tiny(), &mut f2, 16, 4, &LaOpts::default());
        assert_eq!(p1, p2);
        for (x, y) in f1.data().iter().zip(f2.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn property_all_variants_agree() {
        forall_res("LA/MB/ET produce valid identical-pivot LUs", 8, |g: &mut Gen| {
            let n = g.usize_in(10, 70);
            let bo = g.choose(&[4usize, 8, 16]);
            let bi = g.choose(&[2usize, 4]);
            let seed = g.seed();
            g.label(format!("n={n} bo={bo} bi={bi}"));
            let a0 = Matrix::random(n, n, seed);
            let mut piv_ref = None;
            for (mall, et) in [(false, false), (true, false), (true, true)] {
                let opts = LaOpts {
                    malleable: mall,
                    early_term: et,
                    ..Default::default()
                };
                let (f, ipiv, _) = run(&a0, bo, bi, 2, &opts);
                let r = naive::lu_residual(&a0, &f, &ipiv);
                if r > 1e-11 {
                    return Err(format!("mall={mall} et={et}: residual {r}"));
                }
                match &piv_ref {
                    None => piv_ref = Some(ipiv),
                    Some(p) => {
                        if *p != ipiv {
                            return Err(format!("mall={mall} et={et}: pivots differ"));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
