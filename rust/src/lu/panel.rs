//! Panel factorizations: the *inner LU* of the paper (§4.2, Fig. 12).
//!
//! The outer factorization hands an `m × b` panel to one of these
//! routines, which factorize it with inner block size `b_i`:
//!
//! - [`panel_rl`] — blocked right-looking (eager): each step factorizes a
//!   `b_i`-column sub-panel and immediately updates everything to its
//!   right inside the panel.
//! - [`panel_ll`] — blocked left-looking (lazy): each step first brings
//!   the current `b_i` columns up to date (swaps + TRSM + GEMM of all
//!   previous steps) and then factorizes them; columns to the right are
//!   **never touched early**. This makes Early Termination delay-free: an
//!   abort between steps leaves a clean prefix of fully-factorized
//!   columns and a suffix in the original (un-permuted, un-updated)
//!   state — paper §4.2 and footnote 3.
//!
//! Both return pivots *relative to the panel* and apply row swaps across
//! the full panel width (RL) / the already-factored prefix (LL).

use super::unblocked::lu_unblocked;
use crate::blis::{gemm, laswp, trsm_llu, BlisParams};
use crate::matrix::MatMut;
use crate::pool::Crew;
use crate::scalar::Scalar;
use std::sync::atomic::{AtomicBool, Ordering};

/// Outcome of a panel factorization.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PanelOutcome {
    /// Pivot rows relative to the panel (length = columns factorized).
    pub ipiv: Vec<usize>,
    /// Number of columns actually factorized (`< n` only after an early
    /// termination).
    pub k_done: usize,
    /// Whether an ET signal cut the factorization short.
    pub terminated_early: bool,
}

/// Blocked right-looking panel factorization with inner block `bi`
/// (`bi <= 1` or `bi >= n` degrades to the unblocked algorithm).
/// BDP within the panel comes from the crew (paper: the PANEL "also
/// extracts BDP from the same two kernels").
pub fn panel_rl<S: Scalar>(
    crew: &mut Crew,
    params: &BlisParams,
    a: MatMut<S>,
    bi: usize,
) -> PanelOutcome {
    let (m, n) = (a.rows(), a.cols());
    let kmax = m.min(n);
    if bi <= 1 || bi >= kmax {
        let ipiv = lu_unblocked(a);
        let k_done = ipiv.len();
        return PanelOutcome {
            ipiv,
            k_done,
            terminated_early: false,
        };
    }
    let mut ipiv: Vec<usize> = Vec::with_capacity(kmax);
    let mut k = 0;
    while k < kmax {
        let b = bi.min(kmax - k);
        // Factorize the current sub-panel (rows k.., cols k..k+b).
        let sub = a.sub(k, k, m - k, b);
        let piv_local = lu_unblocked(sub);
        // Absolute (panel-relative) pivots; swap the rest of the panel:
        // left of the sub-panel and right of it.
        let lo = ipiv.len();
        ipiv.extend(piv_local.iter().map(|p| p + k));
        laswp(crew, a, &ipiv, lo, lo + b, 0, k);
        laswp(crew, a, &ipiv, lo, lo + b, k + b, n);
        // Eager (right-looking) update of the trailing panel columns.
        let rest = n - k - b;
        if rest > 0 {
            trsm_llu(
                crew,
                params,
                a.sub(k, k, b, b).as_ref(),
                a.sub(k, k + b, b, rest),
            );
            if m - k - b > 0 {
                gemm(
                    crew,
                    params,
                    S::ZERO - S::ONE,
                    a.sub(k + b, k, m - k - b, b).as_ref(),
                    a.sub(k, k + b, b, rest).as_ref(),
                    a.sub(k + b, k + b, m - k - b, rest),
                );
            }
        }
        k += b;
    }
    PanelOutcome {
        ipiv,
        k_done: kmax,
        terminated_early: false,
    }
}

/// Blocked left-looking panel factorization with inner block `bi`,
/// supporting Early Termination.
///
/// `stop` is the ET flag (paper §4.2): set by the remainder-update team
/// when its work is done; polled here *at the end of every inner
/// iteration*. On observing it, the routine returns immediately with
/// `k_done < n`. At least one inner block is always completed (forward
/// progress). Per the paper, no lock is needed: the flag has a single
/// writer and a single reader, and the reader tolerates staleness.
///
/// Post-conditions on early termination at `k_done`:
/// - columns `0..k_done` hold the final `L\U` factors of the panel's
///   leading `k_done` columns, with all swaps applied within `0..k_done`;
/// - columns `k_done..n` are **exactly as on entry** (no swaps, no
///   updates) — they rejoin the trailing submatrix of the outer
///   factorization.
pub fn panel_ll<S: Scalar>(
    crew: &mut Crew,
    params: &BlisParams,
    a: MatMut<S>,
    bi: usize,
    stop: Option<&AtomicBool>,
) -> PanelOutcome {
    let (m, n) = (a.rows(), a.cols());
    let kmax = m.min(n);
    let bi = bi.max(1);
    let mut ipiv: Vec<usize> = Vec::with_capacity(kmax);
    let mut k = 0;
    let mut terminated_early = false;
    while k < kmax {
        let b = bi.min(kmax - k);
        // Bring columns k..k+b up to date (left-looking):
        // 1. previous swaps,
        let cur = a.sub(0, k, m, b);
        laswp(crew, cur, &ipiv, 0, k, 0, b);
        if k > 0 {
            // 2. TRSM with the already-factored TRILU(A[0..k, 0..k]),
            trsm_llu(
                crew,
                params,
                a.sub(0, 0, k, k).as_ref(),
                a.sub(0, k, k, b),
            );
            // 3. GEMM with the factored block column below it.
            gemm(
                crew,
                params,
                S::ZERO - S::ONE,
                a.sub(k, 0, m - k, k).as_ref(),
                a.sub(0, k, k, b).as_ref(),
                a.sub(k, k, m - k, b),
            );
        }
        // 4. factorize the diagonal block + below.
        let piv_local = lu_unblocked(a.sub(k, k, m - k, b));
        let lo = ipiv.len();
        ipiv.extend(piv_local.iter().map(|p| p + k));
        // 5. apply this block's swaps to the factored prefix only
        //    (columns to the right stay untouched — the LL property).
        laswp(crew, a, &ipiv, lo, lo + b, 0, k);
        k += b;
        // ET poll — end of the inner iteration (paper Fig. 13).
        if k < kmax {
            if let Some(flag) = stop {
                if flag.load(Ordering::Acquire) {
                    terminated_early = true;
                    break;
                }
            }
        }
    }
    PanelOutcome {
        ipiv,
        k_done: k,
        terminated_early,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{naive, Matrix};
    use crate::util::quickcheck_lite::{forall_res, Gen};

    fn residual_of_prefix(a0: &Matrix, f: &Matrix, ipiv: &[usize], k_done: usize) -> f64 {
        // Check PA = LU on the leading k_done columns.
        let m = a0.rows();
        let lead0 = Matrix::from_fn(m, k_done, |i, j| a0[(i, j)]);
        let leadf = Matrix::from_fn(m, k_done, |i, j| f[(i, j)]);
        naive::lu_residual(&lead0, &leadf, ipiv)
    }

    #[test]
    fn panel_rl_matches_unblocked_numerically() {
        let params = BlisParams::tiny();
        for &(m, n, bi) in &[(40usize, 16usize, 4usize), (33, 12, 5), (16, 16, 8), (9, 9, 2)] {
            let a0 = Matrix::random(m, n, (m + n + bi) as u64);
            let mut f1 = a0.clone();
            let mut crew = Crew::new();
            let out = panel_rl(&mut crew, &params, f1.view_mut(), bi);
            assert_eq!(out.k_done, m.min(n));
            assert!(!out.terminated_early);
            let r = naive::lu_residual(&a0, &f1, &out.ipiv);
            assert!(r < 1e-12, "m={m} n={n} bi={bi} r={r}");
            assert!(naive::growth_bounded(&f1));
        }
    }

    #[test]
    fn panel_rl_unblocked_fallback_is_bitwise_exact() {
        let a0 = Matrix::random(30, 8, 3);
        let mut f1 = a0.clone();
        let mut f2 = a0.clone();
        let mut crew = Crew::new();
        let out = panel_rl(&mut crew, &BlisParams::tiny(), f1.view_mut(), 0);
        let piv2 = lu_unblocked(f2.view_mut());
        assert_eq!(out.ipiv, piv2);
        assert_eq!(f1, f2);
    }

    #[test]
    fn panel_ll_full_run_matches_rl_numerically() {
        let params = BlisParams::tiny();
        for &(m, n, bi) in &[(48usize, 24usize, 8usize), (21, 21, 4), (64, 16, 16)] {
            let a0 = Matrix::random(m, n, (m * 3 + n + bi) as u64);
            let mut f_ll = a0.clone();
            let mut f_rl = a0.clone();
            let mut crew = Crew::new();
            let out_ll = panel_ll(&mut crew, &params, f_ll.view_mut(), bi, None);
            let out_rl = panel_rl(&mut crew, &params, f_rl.view_mut(), bi);
            assert_eq!(out_ll.k_done, m.min(n));
            let r = naive::lu_residual(&a0, &f_ll, &out_ll.ipiv);
            assert!(r < 1e-12, "LL residual {r}");
            // Same pivots (generic matrices; FP ties are measure-zero).
            assert_eq!(out_ll.ipiv, out_rl.ipiv);
            let d = f_ll.max_abs_diff(&f_rl);
            assert!(d < 1e-10, "LL vs RL factors diff {d}");
        }
    }

    #[test]
    fn panel_ll_early_termination_leaves_clean_state() {
        let params = BlisParams::tiny();
        let (m, n, bi) = (40usize, 24usize, 4usize);
        let a0 = Matrix::random(m, n, 17);
        let mut f = a0.clone();
        let stop = AtomicBool::new(true); // already set: cut after first block
        let mut crew = Crew::new();
        let out = panel_ll(&mut crew, &params, f.view_mut(), bi, Some(&stop));
        assert!(out.terminated_early);
        assert_eq!(out.k_done, bi, "stops after exactly one inner block");
        assert_eq!(out.ipiv.len(), bi);
        // Prefix is a valid LU of the first k_done columns...
        let r = residual_of_prefix(&a0, &f, &out.ipiv, out.k_done);
        assert!(r < 1e-12, "prefix residual {r}");
        // ...and the suffix columns are EXACTLY as on entry.
        for j in out.k_done..n {
            for i in 0..m {
                assert_eq!(f[(i, j)], a0[(i, j)], "suffix touched at ({i},{j})");
            }
        }
    }

    #[test]
    fn panel_ll_stop_mid_way() {
        // Set the flag from another thread while factorization runs;
        // whatever prefix is factored must be valid and the suffix
        // untouched.
        let params = BlisParams::tiny();
        let (m, n, bi) = (96usize, 64usize, 8usize);
        let a0 = Matrix::random(m, n, 23);
        let mut f = a0.clone();
        let stop = std::sync::Arc::new(AtomicBool::new(false));
        let s2 = std::sync::Arc::clone(&stop);
        let setter = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_micros(200));
            s2.store(true, Ordering::Release);
        });
        let mut crew = Crew::new();
        let out = panel_ll(&mut crew, &params, f.view_mut(), bi, Some(&stop));
        setter.join().unwrap();
        assert!(out.k_done >= bi && out.k_done <= n);
        assert_eq!(out.k_done % bi, 0);
        let r = residual_of_prefix(&a0, &f, &out.ipiv, out.k_done);
        assert!(r < 1e-12, "prefix residual {r}");
        for j in out.k_done..n {
            for i in 0..m {
                assert_eq!(f[(i, j)], a0[(i, j)]);
            }
        }
    }

    #[test]
    fn panel_ll_never_stops_at_zero() {
        let params = BlisParams::tiny();
        let a0 = Matrix::random(16, 8, 31);
        let mut f = a0.clone();
        let stop = AtomicBool::new(true);
        let mut crew = Crew::new();
        let out = panel_ll(&mut crew, &params, f.view_mut(), 4, Some(&stop));
        assert!(out.k_done >= 4, "must complete at least one block");
    }

    #[test]
    fn property_panel_ll_prefix_valid_any_cut() {
        forall_res("panel_ll ET prefix is a valid LU", 15, |g: &mut Gen| {
            let m = g.usize_in(8, 60);
            let n = g.usize_in(4, 32).min(m);
            let bi = g.choose(&[2usize, 4, 8]);
            let seed = g.seed();
            g.label(format!("m={m} n={n} bi={bi}"));
            let a0 = Matrix::random(m, n, seed);
            let mut f = a0.clone();
            let stop = AtomicBool::new(g.bool_with(0.7));
            let mut crew = Crew::new();
            let out = panel_ll(
                &mut crew,
                &BlisParams::tiny(),
                f.view_mut(),
                bi,
                Some(&stop),
            );
            if out.k_done == 0 {
                return Err("no progress".into());
            }
            let r = residual_of_prefix(&a0, &f, &out.ipiv, out.k_done);
            if r > 1e-11 {
                return Err(format!("prefix residual {r}"));
            }
            for j in out.k_done..n {
                for i in 0..m {
                    if f[(i, j)] != a0[(i, j)] {
                        return Err(format!("suffix touched at ({i},{j})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ll_is_lazier_than_rl_flop_accounting() {
        // Paper footnote 3: when stopped at column k of an m×n panel, LL
        // has performed ~m·k² − k³/3 flops vs RL's additional
        // 2(n−k)(mk − k²/2). Sanity-check the formulas' ordering.
        let (m, n, k) = (1000.0f64, 256.0f64, 64.0f64);
        let ll = m * k * k - k * k * k / 3.0;
        let rl = ll + 2.0 * (n - k) * (m * k - k * k / 2.0);
        assert!(rl > ll * 2.0, "RL does much more eager work");
    }
}
