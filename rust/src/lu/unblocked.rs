//! Unblocked right-looking LU with partial pivoting (paper Fig. 3, left)
//! — the leaf of every panel factorization.
//!
//! Operates on a (typically tall, narrow) panel `A` of shape `m × n`:
//! at step `k` it searches the pivot in column `k`, swaps rows across the
//! *whole panel width*, scales the subdiagonal and applies a rank-1
//! update to the trailing columns. Returns pivots as row indices
//! *relative to the panel* (LAPACK convention, `ipiv[k] >= k`).

use crate::blis::small::lu_step_col;
use crate::matrix::MatMut;
use crate::scalar::Scalar;

/// Factorize `a` in place; returns local pivots. Exactly singular columns
/// (pivot == 0) are tolerated LAPACK-style: the column is skipped and the
/// zero stays on the diagonal. Generic over the sealed [`Scalar`] layer —
/// the same leaf runs in both precisions.
///
/// Each column step goes through [`lu_step_col`], the single shared
/// contract also honored (lane-wise) by the interleaved small-batch
/// kernel, so the two execution strategies cannot drift apart.
pub fn lu_unblocked<S: Scalar>(a: MatMut<S>) -> Vec<usize> {
    let (m, n) = (a.rows(), a.cols());
    let kmax = m.min(n);
    let mut ipiv = Vec::with_capacity(kmax);
    for k in 0..kmax {
        ipiv.push(lu_step_col(a, k, m, n));
    }
    ipiv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{naive, Matrix};

    #[test]
    fn matches_naive_reference_bitwise() {
        for &(m, n) in &[(1usize, 1usize), (6, 6), (20, 4), (4, 20), (13, 13)] {
            let a0 = Matrix::random(m, n, (m * 31 + n) as u64);
            let mut a1 = a0.clone();
            let mut a2 = a0.clone();
            let p1 = lu_unblocked(a1.view_mut());
            let p2 = naive::lu(a2.view_mut());
            assert_eq!(p1, p2, "pivots m={m} n={n}");
            assert_eq!(a1, a2, "factors m={m} n={n}");
        }
    }

    #[test]
    fn residual_is_tiny() {
        let a0 = Matrix::random(40, 24, 5);
        let mut f = a0.clone();
        let ipiv = lu_unblocked(f.view_mut());
        let r = naive::lu_residual(&a0, &f, &ipiv);
        assert!(r < 1e-13, "residual {r}");
        assert!(naive::growth_bounded(&f));
    }

    #[test]
    fn zero_pivot_column_is_skipped() {
        let mut a = Matrix::zeros(3, 3);
        a[(0, 1)] = 1.0;
        a[(1, 2)] = 2.0;
        let ipiv = lu_unblocked(a.view_mut());
        assert_eq!(ipiv.len(), 3);
        assert!(a.data().iter().all(|x| x.is_finite()));
    }
}
