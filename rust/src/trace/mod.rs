//! Extrae-like execution tracer.
//!
//! The paper's trace figures (Figs. 5, 8, 9, 11) were produced with
//! Extrae + Paraver. This module reproduces the workflow: kernels wrap
//! their work in [`span`]s tagged with a [`Kind`]; a [`Recorder`]
//! (globally installed for the duration of a traced run) collects
//! `(worker, kind, label, t0, t1)` tuples; renderers emit an ASCII Gantt
//! chart (one lane per worker, like a Paraver timeline) or Chrome
//! `trace_event` JSON for `chrome://tracing` / Perfetto.
//!
//! Tracing is strictly opt-in: with no recorder installed, [`span`] costs
//! one relaxed atomic load.
//!
//! Serve-layer spans carry structured label prefixes: in-process batch
//! requests tag `req{id}:{kind}:{prec}`, and requests arriving through
//! the network daemon tag `req{id}@c{client}:{kind}:{prec}` — so
//! [`ascii_gantt_requests`] attributes lanes to individual network
//! clients as well as to requests.

use crate::pool::current_worker;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Task classes, colored distinctly in the Gantt rendering — mirroring the
/// paper's trace legend (panel factorization, row permutation, triangular
/// solve, matrix multiplication, idle).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Kind {
    /// Panel factorization (paper: PANEL / PF3).
    Panel,
    /// Row interchanges (paper: LASWP).
    Swap,
    /// Triangular solve (paper: TRSM / RL2).
    Trsm,
    /// Matrix multiply (paper: GEMM / RL3 / RU2).
    Gemm,
    /// Packing of `A_c`/`B_c` buffers.
    Pack,
    /// Synchronization / waiting.
    Wait,
    /// Anything else (task runtime bookkeeping etc.).
    Other,
}

impl Kind {
    /// Single-character cell used in the ASCII Gantt.
    pub fn glyph(self) -> char {
        match self {
            Kind::Panel => 'P',
            Kind::Swap => 's',
            Kind::Trsm => 't',
            Kind::Gemm => 'G',
            Kind::Pack => 'k',
            Kind::Wait => '.',
            Kind::Other => 'o',
        }
    }

    /// Lowercase kind name (used as the Chrome-trace category).
    pub fn name(self) -> &'static str {
        match self {
            Kind::Panel => "panel",
            Kind::Swap => "swap",
            Kind::Trsm => "trsm",
            Kind::Gemm => "gemm",
            Kind::Pack => "pack",
            Kind::Wait => "wait",
            Kind::Other => "other",
        }
    }
}

/// One recorded span.
#[derive(Clone, Debug)]
pub struct Span {
    /// Worker lane: pool worker id + 1, or 0 for the main thread.
    pub lane: usize,
    /// Task class (panel, swap, trsm, gemm, ...).
    pub kind: Kind,
    /// Free-form label; serve drivers prefix it with
    /// `req<id>:<kind>:<prec>.`.
    pub label: String,
    /// Seconds since the recorder's origin.
    pub t0: f64,
    /// End time, seconds since the recorder's origin.
    pub t1: f64,
}

/// Collects spans from all threads.
pub struct Recorder {
    origin: Instant,
    spans: Mutex<Vec<Span>>,
}

impl Recorder {
    fn new() -> Self {
        Self {
            origin: Instant::now(),
            spans: Mutex::new(Vec::new()),
        }
    }

    fn record(&self, lane: usize, kind: Kind, label: &str, t0: Instant, t1: Instant) {
        let s = Span {
            lane,
            kind,
            label: label.to_string(),
            t0: t0.duration_since(self.origin).as_secs_f64(),
            t1: t1.duration_since(self.origin).as_secs_f64(),
        };
        self.spans.lock().unwrap().push(s);
    }

    /// Snapshot of all spans recorded so far, sorted by start time.
    pub fn spans(&self) -> Vec<Span> {
        let mut v = self.spans.lock().unwrap().clone();
        v.sort_by(|a, b| a.t0.partial_cmp(&b.t0).unwrap());
        v
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static RECORDER: OnceLock<Mutex<Option<Arc<Recorder>>>> = OnceLock::new();

fn slot() -> &'static Mutex<Option<Arc<Recorder>>> {
    RECORDER.get_or_init(|| Mutex::new(None))
}

/// Install a fresh global recorder and return it. Replaces any previous
/// one. (Tests that trace must not run concurrently with each other; the
/// library itself never installs a recorder.)
pub fn start() -> Arc<Recorder> {
    let rec = Arc::new(Recorder::new());
    *slot().lock().unwrap() = Some(Arc::clone(&rec));
    ENABLED.store(true, Ordering::Release);
    rec
}

/// Uninstall the global recorder.
pub fn stop() {
    ENABLED.store(false, Ordering::Release);
    *slot().lock().unwrap() = None;
}

fn current() -> Option<Arc<Recorder>> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    slot().lock().unwrap().clone()
}

/// Lane index of the calling thread (main thread = 0, worker `w` = `w+1`).
pub fn lane() -> usize {
    current_worker().map(|w| w + 1).unwrap_or(0)
}

/// Run `f`, recording it as a span if a recorder is installed.
pub fn span<T>(kind: Kind, label: &str, f: impl FnOnce() -> T) -> T {
    match current() {
        None => f(),
        Some(rec) => {
            let t0 = Instant::now();
            let out = f();
            rec.record(lane(), kind, label, t0, Instant::now());
            out
        }
    }
}

/// Render spans as an ASCII Gantt chart: one lane per worker, `width`
/// character cells across the full time range. Overlapping spans within a
/// lane keep the later glyph (lanes are effectively serial per worker, so
/// this only matters at cell granularity).
pub fn ascii_gantt(spans: &[Span], width: usize) -> String {
    if spans.is_empty() {
        return String::from("(no spans)\n");
    }
    let tmax = spans.iter().map(|s| s.t1).fold(0.0f64, f64::max);
    let tmin = spans.iter().map(|s| s.t0).fold(f64::INFINITY, f64::min);
    let range = (tmax - tmin).max(1e-12);
    let n_lanes = spans.iter().map(|s| s.lane).max().unwrap() + 1;
    let mut rows = vec![vec![' '; width]; n_lanes];
    for s in spans {
        // A span starting at tmax (zero-duration last event) would map
        // to column `width`; clamp before widening so c0 < c1 <= width.
        let c0 = (((s.t0 - tmin) / range) * width as f64).floor() as usize;
        let c0 = c0.min(width - 1);
        let c1 = (((s.t1 - tmin) / range) * width as f64).ceil() as usize;
        let c1 = c1.clamp(c0 + 1, width);
        for cell in &mut rows[s.lane][c0..c1] {
            *cell = s.kind.glyph();
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "time range: {:.6}s .. {:.6}s  ({} spans)\n",
        tmin,
        tmax,
        spans.len()
    ));
    for (lane, row) in rows.iter().enumerate() {
        let name = if lane == 0 {
            "main ".to_string()
        } else {
            format!("wk{:<3}", lane - 1)
        };
        out.push_str(&name);
        out.push('|');
        out.extend(row.iter());
        out.push_str("|\n");
    }
    out.push_str("legend: P=panel s=swap t=trsm G=gemm k=pack .=wait\n");
    out
}

/// Render spans as a multi-problem Gantt: one lane per *request*, keyed
/// by the label prefix up to the first `.` when it is a request tag
/// (`req<id>:<kind>:<prec>`, as emitted by the serve layer's drivers —
/// the lane label therefore names the factorization kind and working
/// precision, e.g. `req3:qr:f32`, instead
/// of implying every lane is an LU); untagged spans share an `(other)`
/// lane. Where [`ascii_gantt`] answers "what was each worker doing", this
/// view answers "how did each problem's lifetime overlap the others' on
/// the shared pool".
pub fn ascii_gantt_requests(spans: &[Span], width: usize) -> String {
    if spans.is_empty() {
        return String::from("(no spans)\n");
    }
    let key_of = |label: &str| -> String {
        match label.split_once('.') {
            Some((head, _)) if head.starts_with("req") => head.to_string(),
            _ => String::from("(other)"),
        }
    };
    let tmax = spans.iter().map(|s| s.t1).fold(0.0f64, f64::max);
    let tmin = spans.iter().map(|s| s.t0).fold(f64::INFINITY, f64::min);
    let range = (tmax - tmin).max(1e-12);
    let mut keys: Vec<String> = Vec::new();
    for s in spans {
        let k = key_of(&s.label);
        if !keys.contains(&k) {
            keys.push(k);
        }
    }
    let mut rows = vec![vec![' '; width]; keys.len()];
    for s in spans {
        let lane = keys.iter().position(|k| *k == key_of(&s.label)).unwrap();
        // Same column clamp as [`ascii_gantt`]: a span at t == tmax must
        // not index past the last cell.
        let c0 = (((s.t0 - tmin) / range) * width as f64).floor() as usize;
        let c0 = c0.min(width - 1);
        let c1 = (((s.t1 - tmin) / range) * width as f64).ceil() as usize;
        let c1 = c1.clamp(c0 + 1, width);
        for cell in &mut rows[lane][c0..c1] {
            *cell = s.kind.glyph();
        }
    }
    let name_w = keys.iter().map(|k| k.len()).max().unwrap().max(5);
    let mut out = String::new();
    out.push_str(&format!(
        "time range: {:.6}s .. {:.6}s  ({} spans, {} requests)\n",
        tmin,
        tmax,
        spans.len(),
        keys.iter().filter(|k| k.as_str() != "(other)").count()
    ));
    for (key, row) in keys.iter().zip(&rows) {
        out.push_str(&format!("{key:<name_w$}"));
        out.push('|');
        out.extend(row.iter());
        out.push_str("|\n");
    }
    out.push_str("legend: P=panel s=swap t=trsm G=gemm k=pack .=wait\n");
    out
}

/// Render spans as Chrome `trace_event` JSON (open in Perfetto or
/// `chrome://tracing`).
pub fn chrome_json(spans: &[Span]) -> String {
    let mut out = String::from("[\n");
    for (i, s) in spans.iter().enumerate() {
        let comma = if i + 1 == spans.len() { "" } else { "," };
        out.push_str(&format!(
            "  {{\"name\": \"{}\", \"cat\": \"{}\", \"ph\": \"X\", \"ts\": {:.3}, \"dur\": {:.3}, \"pid\": 1, \"tid\": {}}}{}\n",
            escape(&s.label),
            s.kind.name(),
            s.t0 * 1e6,
            (s.t1 - s.t0) * 1e6,
            s.lane,
            comma
        ));
    }
    out.push_str("]\n");
    out
}

/// Per-kind busy time (seconds) per lane — the quantitative counterpart of
/// the trace figures (e.g. "panel time dominates lane 1").
pub fn busy_by_kind(spans: &[Span]) -> Vec<(usize, Kind, f64)> {
    use std::collections::HashMap;
    let mut acc: HashMap<(usize, Kind), f64> = HashMap::new();
    for s in spans {
        *acc.entry((s.lane, s.kind)).or_insert(0.0) += s.t1 - s.t0;
    }
    let mut v: Vec<_> = acc.into_iter().map(|((l, k), t)| (l, k, t)).collect();
    v.sort_by(|a, b| (a.0, a.1.glyph()).cmp(&(b.0, b.1.glyph())));
    v
}

/// Render an ordered event stream as a one-event-per-line strip with a
/// `>>` marker on the highlighted ordinal — the divergence-context view
/// the replay certifier prints (`mlu replay`, DESIGN.md §16.4): the
/// decisions around the first diverging record, each already described
/// by [`crate::replay::Decision::describe`], with the culprit flagged.
/// Events outside `window` ordinals of the highlight are elided.
pub fn ascii_event_strip(events: &[(u64, String)], highlight: u64, window: u64) -> String {
    let mut out = String::new();
    let lo = highlight.saturating_sub(window);
    let hi = highlight.saturating_add(window);
    let mut elided = 0usize;
    for (ordinal, text) in events {
        if *ordinal < lo || *ordinal > hi {
            elided += 1;
            continue;
        }
        let marker = if *ordinal == highlight { ">>" } else { "  " };
        out.push_str(&format!("{marker} {text}\n"));
    }
    if elided > 0 {
        out.push_str(&format!("   ({elided} events outside the ±{window} window elided)\n"));
    }
    out
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: these tests share the global recorder; run serially via the
    // lock below.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn span_without_recorder_is_passthrough() {
        let _g = TEST_LOCK.lock().unwrap();
        stop();
        let v = span(Kind::Gemm, "x", || 42);
        assert_eq!(v, 42);
    }

    #[test]
    fn recorder_collects_spans_with_lanes() {
        let _g = TEST_LOCK.lock().unwrap();
        let rec = start();
        span(Kind::Panel, "p0", || {
            std::thread::sleep(std::time::Duration::from_micros(100))
        });
        span(Kind::Gemm, "g0", || {});
        let pool = crate::pool::Pool::new(2);
        pool.submit(1, || {
            span(Kind::Trsm, "t0", || {});
        })
        .wait();
        stop();
        let spans = rec.spans();
        assert_eq!(spans.len(), 3);
        assert!(spans.iter().any(|s| s.kind == Kind::Panel && s.lane == 0));
        assert!(spans.iter().any(|s| s.kind == Kind::Trsm && s.lane == 2));
        let p = spans.iter().find(|s| s.kind == Kind::Panel).unwrap();
        assert!(p.t1 >= p.t0 + 50e-6);
    }

    #[test]
    fn gantt_renders_all_lanes() {
        let _g = TEST_LOCK.lock().unwrap();
        let spans = vec![
            Span {
                lane: 0,
                kind: Kind::Gemm,
                label: "g".into(),
                t0: 0.0,
                t1: 1.0,
            },
            Span {
                lane: 2,
                kind: Kind::Panel,
                label: "p".into(),
                t0: 0.5,
                t1: 1.0,
            },
        ];
        let g = ascii_gantt(&spans, 40);
        assert!(g.contains("main |GGG"), "{g}");
        assert!(g.contains("wk1  |"), "{g}");
        assert!(g.contains('P'), "{g}");
        let lines: Vec<&str> = g.lines().collect();
        assert_eq!(lines.len(), 1 + 3 + 1); // header + 3 lanes + legend
    }

    #[test]
    fn gantt_empty() {
        assert_eq!(ascii_gantt(&[], 10), "(no spans)\n");
        assert_eq!(ascii_gantt_requests(&[], 10), "(no spans)\n");
    }

    #[test]
    fn gantt_handles_zero_duration_span_at_end() {
        // A zero-duration span exactly at tmax maps to the last column
        // instead of panicking in the clamp.
        let spans = vec![
            Span {
                lane: 0,
                kind: Kind::Gemm,
                label: "g".into(),
                t0: 0.0,
                t1: 1.0,
            },
            Span {
                lane: 1,
                kind: Kind::Other,
                label: "end".into(),
                t0: 1.0,
                t1: 1.0,
            },
        ];
        let g = ascii_gantt(&spans, 20);
        assert!(g.contains('o'), "{g}");
        let gr = ascii_gantt_requests(&spans, 20);
        assert!(gr.contains("(other)"), "{gr}");
    }

    #[test]
    fn request_gantt_groups_by_tag() {
        let spans = vec![
            Span {
                lane: 1,
                kind: Kind::Panel,
                label: "req0.panel[0]".into(),
                t0: 0.0,
                t1: 0.5,
            },
            Span {
                lane: 2,
                kind: Kind::Gemm,
                label: "req1.update[0]".into(),
                t0: 0.25,
                t1: 1.0,
            },
            Span {
                lane: 1,
                kind: Kind::Gemm,
                label: "req0.update[0]".into(),
                t0: 0.5,
                t1: 0.75,
            },
            Span {
                lane: 0,
                kind: Kind::Swap,
                label: "laswp".into(),
                t0: 0.0,
                t1: 0.1,
            },
        ];
        let g = ascii_gantt_requests(&spans, 40);
        assert!(g.contains("2 requests"), "{g}");
        assert!(g.contains("req0"), "{g}");
        assert!(g.contains("req1"), "{g}");
        assert!(g.contains("(other)"), "{g}");
        // req0's lane starts with panel glyphs, then gemm.
        let req0_line = g.lines().find(|l| l.starts_with("req0")).unwrap();
        assert!(req0_line.contains('P'), "{req0_line}");
        assert!(req0_line.contains('G'), "{req0_line}");
        // 1 header + 3 lanes + legend.
        assert_eq!(g.lines().count(), 5);
    }

    #[test]
    fn request_gantt_lane_labels_carry_the_kind() {
        // The serve drivers tag spans `req<id>:<kind>`; each lane label
        // must surface the kind instead of hardcoding one workload.
        let spans = vec![
            Span {
                lane: 0,
                kind: Kind::Panel,
                label: "req0:lu.panel[0]".into(),
                t0: 0.0,
                t1: 0.4,
            },
            Span {
                lane: 1,
                kind: Kind::Gemm,
                label: "req1:chol.update[0]".into(),
                t0: 0.2,
                t1: 0.9,
            },
            Span {
                lane: 2,
                kind: Kind::Gemm,
                label: "req2:qr.update[8]".into(),
                t0: 0.5,
                t1: 1.0,
            },
        ];
        let g = ascii_gantt_requests(&spans, 30);
        assert!(g.contains("3 requests"), "{g}");
        assert!(g.lines().any(|l| l.starts_with("req0:lu")), "{g}");
        assert!(g.lines().any(|l| l.starts_with("req1:chol")), "{g}");
        assert!(g.lines().any(|l| l.starts_with("req2:qr")), "{g}");
    }

    #[test]
    fn chrome_json_is_wellformed_enough() {
        let spans = vec![Span {
            lane: 1,
            kind: Kind::Pack,
            label: "pack \"A_c\"".into(),
            t0: 0.001,
            t1: 0.002,
        }];
        let j = chrome_json(&spans);
        assert!(j.starts_with("[\n"));
        assert!(j.trim_end().ends_with(']'));
        assert!(j.contains("\"cat\": \"pack\""));
        assert!(j.contains("\\\"A_c\\\"")); // quotes escaped
        assert!(j.contains("\"ts\": 1000.000"));
    }

    #[test]
    fn event_strip_marks_highlight_and_elides_far_events() {
        let events: Vec<(u64, String)> = (0..20).map(|i| (i, format!("ev{i}"))).collect();
        let s = ascii_event_strip(&events, 10, 3);
        assert!(s.contains(">> ev10"), "{s}");
        assert!(s.contains("   ev7"), "{s}");
        assert!(s.contains("   ev13"), "{s}");
        assert!(!s.contains("ev3\n"), "{s}");
        assert!(s.contains("13 events outside"), "{s}");
    }

    #[test]
    fn busy_by_kind_accumulates() {
        let spans = vec![
            Span {
                lane: 0,
                kind: Kind::Gemm,
                label: String::new(),
                t0: 0.0,
                t1: 1.0,
            },
            Span {
                lane: 0,
                kind: Kind::Gemm,
                label: String::new(),
                t0: 2.0,
                t1: 2.5,
            },
            Span {
                lane: 1,
                kind: Kind::Panel,
                label: String::new(),
                t0: 0.0,
                t1: 0.25,
            },
        ];
        let b = busy_by_kind(&spans);
        assert!(b
            .iter()
            .any(|&(l, k, t)| l == 0 && k == Kind::Gemm && (t - 1.5).abs() < 1e-12));
        assert!(b
            .iter()
            .any(|&(l, k, t)| l == 1 && k == Kind::Panel && (t - 0.25).abs() < 1e-12));
    }
}
