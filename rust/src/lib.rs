//! # malleable-lu
//!
//! A malleable thread-level linear-algebra library and LU factorization
//! suite, reproducing:
//!
//! > Catalán, Herrero, Quintana-Ortí, Rodríguez-Sánchez, van de Geijn.
//! > *A Case for Malleable Thread-Level Linear Algebra Libraries: The LU
//! > Factorization with Partial Pivoting*, 2016.
//!
//! The crate is organized in layers (see `DESIGN.md`):
//!
//! - [`util`] — PRNG, stats, a small property-testing harness.
//! - [`scalar`] — the **sealed precision layer**: the [`scalar::Scalar`]
//!   trait (`f32` + `f64`) every numeric layer is generic over —
//!   epsilon, SIMD lane width, the fused `mul_add` contract, and the
//!   per-type micro-kernel registry (DESIGN.md §12).
//! - [`matrix`] — column-major dense matrices, views, norms, naive
//!   reference kernels, generic over [`scalar::Scalar`]
//!   ([`matrix::Mat`], with [`matrix::Matrix`] the `f64` alias).
//! - [`pool`] — the **malleable worker pool**: persistent worker threads
//!   organized into [`pool::Crew`]s whose membership can grow *while a
//!   kernel is executing* (the paper's Worker-Sharing mechanism).
//! - [`blis`] — a BLIS-style blocked BLAS substrate (five-loop GEMM with
//!   packing and a micro-kernel, blocked TRSM, LASWP) with malleability
//!   entry points at each Loop-3 iteration.
//! - [`factor`] — the **malleable factorization family**: a
//!   [`factor::Factorization`] trait (panel kernel, trailing update,
//!   pivot step, cost hooks) with one generic blocked driver and one
//!   generic WS+ET look-ahead driver shared by LU, Cholesky, and QR.
//! - [`lu`] — the LU-with-partial-pivoting algorithm family: unblocked,
//!   blocked right-looking (`LU`), blocked left-looking, look-ahead
//!   (`LU_LA`), malleable look-ahead (`LU_MB`), and early-termination
//!   (`LU_ET`) — the look-ahead variants now instantiate the generic
//!   [`factor`] driver.
//! - [`solve`] — linear-system solvers over the precision layer,
//!   including the mixed-precision [`solve::lu_solve_mixed`] (factor in
//!   `f32`, refine the residual in `f64` to double accuracy).
//! - [`serve`] — the **batched multi-problem LU scheduler**: an
//!   [`serve::LuServer`] multiplexes a queue of factorization requests
//!   — in either precision, plus mixed-precision solve requests — over
//!   one shared pool, generalizing Worker Sharing ("donate idle
//!   threads to whichever problem is behind") and Early Termination
//!   (cancel superseded or deadline-expired requests) across problems.
//!   [`serve::net::ServeDaemon`] fronts it with a network daemon (TCP
//!   and Unix sockets) speaking the versioned binary protocol of
//!   [`serve::proto`], with admission control and graceful drain
//!   (DESIGN.md §14); [`serve::client::ServeClient`] is the matching
//!   client library behind `mlu sclient`.
//! - [`replay`] — deterministic scheduler **capture/replay**: record every
//!   scheduling decision a serve run makes into a versioned `.mrb` bundle,
//!   re-execute it offline with byte-identical results and decision-stream
//!   certification, and sweep counterfactual steal policies through the
//!   [`sim`] cost model (DESIGN.md §16).
//! - [`taskrt`] — an OmpSs-like dependency-driven task runtime used by the
//!   `LU_OS` baseline (superseded by [`tilert`] for new code).
//! - [`tilert`] — the **tile-DAG dataflow runtime**: tile views over
//!   [`matrix::Mat`], automatic dependency inference from per-task
//!   `In`/`Out`/`InOut` access declarations, a deterministic ready-queue
//!   scheduler on the [`pool`] substrate, and crew-malleable tiled
//!   LU/Cholesky/QR ([`tilert::factorize_dag`]) — the third driver
//!   family, selectable with `mlu --driver dag` and per serve request
//!   (DESIGN.md §17).
//! - [`trace`] — an Extrae-like execution tracer (ASCII Gantt + Chrome
//!   JSON) used to regenerate the paper's trace figures.
//! - [`sim`] — a discrete-event simulator of the paper's 6-core Xeon
//!   E5-2603 v3 testbed, used to regenerate the performance figures on
//!   hardware we do not have (see DESIGN.md §3).
//! - [`runtime`] — a PJRT/XLA runtime that loads AOT-compiled Pallas/JAX
//!   artifacts (the "rigid vendor BLAS" baseline `LU_XLA`).

#![warn(missing_docs)]

pub mod blis;
pub mod cli;
pub mod factor;
/// Deterministic, seeded fault injection for the chaos suite
/// (DESIGN.md §15.4). Compiled only under `cfg(test)` or the `chaos`
/// feature; release builds carry no hook code.
#[cfg(any(test, feature = "chaos"))]
pub mod faultplan;
pub mod lu;
pub mod matrix;
pub mod pool;
pub mod replay;
pub mod runtime;
pub mod scalar;
pub mod serve;
pub mod sim;
pub mod solve;
pub mod taskrt;
pub mod tilert;
pub mod trace;
pub mod util;
