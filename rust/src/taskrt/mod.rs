//! An OmpSs-like dependency-driven task runtime (the paper's §4.3/§5
//! baseline, `LU_OS`, used OmpSs 16.06).
//!
//! The runtime executes a static task graph: each [`Task`] carries a
//! priority and a closure; edges are data dependencies declared at build
//! time. Ready tasks go into a priority queue (higher priority first,
//! FIFO *by release order* within a priority level — a total, enqueue-
//! sequenced tie-break, so the pop order is deterministic; the paper's
//! OmpSs configuration prioritizes panel-factorization tasks to advance
//! the critical path). Workers (pool threads plus the caller) pull from
//! the queue until the graph drains.
//!
//! Tasks run *sequential* kernels (the paper links LU_OS against
//! single-threaded BLIS): TP only, no nested BDP — that contrast with the
//! crew-based variants is exactly the comparison of Fig. 17.

pub mod lu_os;

use crate::pool::Pool;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Task priority: larger runs earlier among ready tasks.
pub type Priority = i32;

type TaskFn = Box<dyn FnOnce() + Send>;

/// A node of the task graph (builder view).
pub struct Task {
    /// Debug label (e.g. `panel[k]`).
    pub name: String,
    /// Scheduling priority (larger runs earlier).
    pub priority: Priority,
    run: Option<TaskFn>,
    /// Indices of tasks that must finish first.
    deps: Vec<usize>,
}

/// Static task graph builder.
#[derive(Default)]
pub struct GraphBuilder {
    tasks: Vec<Task>,
}

impl GraphBuilder {
    /// Empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a task; returns its id. `deps` are ids of prerequisite tasks
    /// (must already exist — the graph is built in topological order,
    /// which the LU decomposition naturally provides).
    pub fn add(
        &mut self,
        name: impl Into<String>,
        priority: Priority,
        deps: &[usize],
        run: impl FnOnce() + Send + 'static,
    ) -> usize {
        for &d in deps {
            assert!(d < self.tasks.len(), "dependency on future task {d}");
        }
        let id = self.tasks.len();
        self.tasks.push(Task {
            name: name.into(),
            priority,
            run: Some(Box::new(run)),
            deps: deps.to_vec(),
        });
        id
    }

    /// Number of tasks added so far.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// Whether no task has been added.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Finalize into an executable graph.
    pub fn build(self) -> Graph {
        let n = self.tasks.len();
        let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut missing: Vec<AtomicUsize> = Vec::with_capacity(n);
        for (id, t) in self.tasks.iter().enumerate() {
            missing.push(AtomicUsize::new(t.deps.len()));
            for &d in &t.deps {
                dependents[d].push(id);
            }
        }
        Graph {
            tasks: self
                .tasks
                .into_iter()
                .map(|t| TaskSlot {
                    name: t.name,
                    priority: t.priority,
                    run: Mutex::new(t.run),
                })
                .collect(),
            dependents,
            missing,
        }
    }
}

struct TaskSlot {
    name: String,
    priority: Priority,
    run: Mutex<Option<TaskFn>>,
}

/// An executable task graph.
pub struct Graph {
    tasks: Vec<TaskSlot>,
    dependents: Vec<Vec<usize>>,
    missing: Vec<AtomicUsize>,
}

/// Ready-queue entry ordered by (priority, FIFO enqueue sequence).
///
/// The FIFO key is the *enqueue* sequence number — assigned under the
/// queue lock when a task becomes ready — not the task id. Ordering by
/// id looked FIFO but was latently unfair: a task released late by its
/// dependencies would jump ahead of an equal-priority task that had
/// been waiting in the queue, merely because it was *declared* earlier.
/// (And `BinaryHeap` by itself leaves equal keys in unspecified order,
/// so without a total tie-break the pop order would not even be
/// deterministic.) A total (priority, seq) key makes the pop order a
/// pure function of the release order, which is what lets
/// `LU_OS`-schedule comparisons reproduce run over run.
#[derive(PartialEq, Eq)]
struct Ready {
    priority: Priority,
    seq: u64,
    id: usize,
}

impl Ord for Ready {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Max-heap: higher priority first; among equals, earlier
        // enqueue first. The trailing id comparison never decides a pop
        // (seqs are unique); it keeps Ord consistent with the derived
        // Eq over all fields.
        self.priority
            .cmp(&other.priority)
            .then(other.seq.cmp(&self.seq))
            .then(other.id.cmp(&self.id))
    }
}

impl PartialOrd for Ready {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

struct ReadyQueue {
    heap: BinaryHeap<Ready>,
    /// Next FIFO sequence number (monotone; assigned at push).
    next_seq: u64,
}

impl ReadyQueue {
    fn push(&mut self, priority: Priority, id: usize) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Ready { priority, seq, id });
    }
}

struct SchedState {
    queue: Mutex<ReadyQueue>,
    ready_cv: Condvar,
    remaining: AtomicUsize,
}

/// Execution statistics.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Tasks executed by each participant (index 0 = caller, then pool
    /// workers in order).
    pub per_worker: Vec<usize>,
    /// Order in which task ids were *started* (for schedule tests; only
    /// meaningful with one worker).
    pub start_order: Vec<usize>,
}

/// Execute the graph on `pool`'s workers plus the calling thread.
/// Returns when every task has run. Panics if the graph has a cycle
/// (detected as a stall) or if a task panics.
pub fn run(graph: Graph, pool: &Pool) -> RunStats {
    let n = graph.tasks.len();
    // An empty graph is a no-op: return zeroed stats without touching
    // the pool at all (no queue, no worker submissions, no per-worker
    // slots) so degenerate problem sizes cost nothing.
    if n == 0 {
        return RunStats::default();
    }
    let stats = Arc::new(Mutex::new(RunStats {
        per_worker: vec![0; pool.workers() + 1],
        start_order: Vec::with_capacity(n),
    }));
    let graph = Arc::new(graph);
    let sched = Arc::new(SchedState {
        queue: Mutex::new(ReadyQueue {
            heap: BinaryHeap::new(),
            next_seq: 0,
        }),
        ready_cv: Condvar::new(),
        remaining: AtomicUsize::new(n),
    });
    // Seed the queue with dependency-free tasks (in declaration order —
    // their release order, since none has prerequisites).
    {
        let mut q = sched.queue.lock().unwrap();
        for id in 0..n {
            if graph.missing[id].load(Ordering::Relaxed) == 0 {
                q.push(graph.tasks[id].priority, id);
            }
        }
        assert!(!q.heap.is_empty(), "task graph has no entry tasks (cycle?)");
    }

    let handles: Vec<_> = (0..pool.workers())
        .map(|w| {
            let g = Arc::clone(&graph);
            let s = Arc::clone(&sched);
            let st = Arc::clone(&stats);
            pool.submit(w, move || executor_loop(&g, &s, &st, w + 1))
        })
        .collect();
    executor_loop(&graph, &sched, &stats, 0);
    for h in handles {
        h.wait();
    }
    assert_eq!(
        sched.remaining.load(Ordering::Acquire),
        0,
        "task graph stalled (cycle or missing notify)"
    );
    Arc::try_unwrap(stats).unwrap().into_inner().unwrap()
}

fn executor_loop(graph: &Graph, sched: &SchedState, stats: &Mutex<RunStats>, me: usize) {
    loop {
        // Grab the highest-priority ready task, or leave when drained.
        let id = {
            let mut q = sched.queue.lock().unwrap();
            loop {
                if sched.remaining.load(Ordering::Acquire) == 0 {
                    return;
                }
                if let Some(r) = q.heap.pop() {
                    break r.id;
                }
                q = sched.ready_cv.wait(q).unwrap();
            }
        };
        {
            let mut st = stats.lock().unwrap();
            st.per_worker[me] += 1;
            st.start_order.push(id);
        }
        let f = graph.tasks[id]
            .run
            .lock()
            .unwrap()
            .take()
            .unwrap_or_else(|| panic!("task {} ({}) ran twice", id, graph.tasks[id].name));
        f();
        // Release dependents.
        let mut newly_ready = Vec::new();
        for &dep in &graph.dependents[id] {
            if graph.missing[dep].fetch_sub(1, Ordering::AcqRel) == 1 {
                newly_ready.push(dep);
            }
        }
        let finished = sched.remaining.fetch_sub(1, Ordering::AcqRel) == 1;
        if !newly_ready.is_empty() || finished {
            let mut q = sched.queue.lock().unwrap();
            for id in newly_ready {
                q.push(graph.tasks[id].priority, id);
            }
            drop(q);
            sched.ready_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn empty_graph_runs() {
        let pool = Pool::new(1);
        let stats = run(GraphBuilder::new().build(), &pool);
        assert!(stats.start_order.is_empty());
    }

    #[test]
    fn empty_graph_is_a_pool_free_noop() {
        // The zeroed-default stats (empty `per_worker`, not
        // `vec![0; workers+1]`) prove the early return fired before any
        // pool interaction — no queue was built, nothing was submitted.
        let pool = Pool::new(2);
        let stats = run(GraphBuilder::new().build(), &pool);
        assert!(stats.per_worker.is_empty());
        assert!(stats.start_order.is_empty());
    }

    #[test]
    fn chain_executes_in_order() {
        let pool = Pool::new(2);
        let log = Arc::new(Mutex::new(Vec::new()));
        let mut gb = GraphBuilder::new();
        let mut prev: Option<usize> = None;
        for i in 0..10 {
            let log = Arc::clone(&log);
            let deps: Vec<usize> = prev.into_iter().collect();
            prev = Some(gb.add(format!("t{i}"), 0, &deps, move || {
                log.lock().unwrap().push(i)
            }));
        }
        run(gb.build(), &pool);
        assert_eq!(*log.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn diamond_respects_dependencies() {
        let pool = Pool::new(3);
        let seen = Arc::new(Mutex::new(Vec::new()));
        let mut gb = GraphBuilder::new();
        let mk = |seen: &Arc<Mutex<Vec<&'static str>>>, tag: &'static str| {
            let s = Arc::clone(seen);
            move || s.lock().unwrap().push(tag)
        };
        let a = gb.add("a", 0, &[], mk(&seen, "a"));
        let b = gb.add("b", 0, &[a], mk(&seen, "b"));
        let c = gb.add("c", 0, &[a], mk(&seen, "c"));
        let _d = gb.add("d", 0, &[b, c], mk(&seen, "d"));
        run(gb.build(), &pool);
        let order = seen.lock().unwrap().clone();
        assert_eq!(order.len(), 4);
        assert_eq!(order[0], "a");
        assert_eq!(order[3], "d");
    }

    #[test]
    fn priority_wins_among_ready() {
        // Single participant (pool of 0 workers): start order is exactly
        // queue-pop order.
        let pool = Pool::new(0);
        let mut gb = GraphBuilder::new();
        let noop = || {};
        let _low1 = gb.add("low1", 0, &[], noop);
        let _high = gb.add("high", 10, &[], noop);
        let _low2 = gb.add("low2", 0, &[], noop);
        let _mid = gb.add("mid", 5, &[], noop);
        let stats = run(gb.build(), &pool);
        assert_eq!(stats.start_order, vec![1, 3, 0, 2]);
    }

    #[test]
    fn fifo_within_priority() {
        let pool = Pool::new(0);
        let mut gb = GraphBuilder::new();
        for i in 0..5 {
            gb.add(format!("t{i}"), 7, &[], || {});
        }
        let stats = run(gb.build(), &pool);
        assert_eq!(stats.start_order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn fifo_follows_release_order_not_task_id() {
        // The latent-unfairness pin: task 2 (`waits`) becomes ready at
        // seed time, task 1 (`released`) only after the root runs. True
        // FIFO-within-priority must run the longer-waiting task 2 first,
        // even though task 1 has the smaller id. (The old id-ordered
        // tie-break ran [0, 1, 2].)
        let pool = Pool::new(0);
        let mut gb = GraphBuilder::new();
        let root = gb.add("root", 0, &[], || {});
        let _released = gb.add("released", 0, &[root], || {});
        let _waits = gb.add("waits", 0, &[], || {});
        let stats = run(gb.build(), &pool);
        assert_eq!(stats.start_order, vec![0, 2, 1]);
    }

    #[test]
    fn pop_order_is_deterministic_across_runs() {
        // Same graph, same single-participant execution => identical
        // start order, run after run — the reproducibility prerequisite
        // for comparing schedules (e.g. steal-on vs steal-off LU_OS).
        let build = || {
            let mut gb = GraphBuilder::new();
            let root = gb.add("root", 5, &[], || {});
            for i in 0..6 {
                let d = gb.add(format!("u{i}"), 0, &[root], || {});
                if i % 2 == 0 {
                    gb.add(format!("p{i}"), 10, &[d], || {});
                }
            }
            gb.build()
        };
        let pool = Pool::new(0);
        let first = run(build(), &pool).start_order;
        for _ in 0..3 {
            assert_eq!(run(build(), &pool).start_order, first);
        }
    }

    #[test]
    fn wide_fanout_all_run_once() {
        let pool = Pool::new(3);
        let count = Arc::new(AtomicU64::new(0));
        let mut gb = GraphBuilder::new();
        let root = gb.add("root", 0, &[], || {});
        let mids: Vec<usize> = (0..50)
            .map(|i| {
                let c = Arc::clone(&count);
                gb.add(format!("m{i}"), 0, &[root], move || {
                    c.fetch_add(1, Ordering::Relaxed);
                })
            })
            .collect();
        let c2 = Arc::clone(&count);
        gb.add("sink", 0, &mids, move || {
            assert_eq!(c2.load(Ordering::Acquire), 50);
        });
        let stats = run(gb.build(), &pool);
        assert_eq!(stats.start_order.len(), 52);
        assert_eq!(stats.per_worker.iter().sum::<usize>(), 52);
    }

    #[test]
    #[should_panic(expected = "dependency on future task")]
    fn forward_dependency_rejected() {
        let mut gb = GraphBuilder::new();
        gb.add("bad", 0, &[3], || {});
    }

    #[test]
    fn stats_track_participants() {
        let pool = Pool::new(2);
        let mut gb = GraphBuilder::new();
        for i in 0..30 {
            gb.add(format!("t{i}"), 0, &[], || {
                std::thread::sleep(std::time::Duration::from_micros(20));
            });
        }
        let stats = run(gb.build(), &pool);
        assert_eq!(stats.per_worker.len(), 3);
        assert_eq!(stats.per_worker.iter().sum::<usize>(), 30);
    }
}
