//! `LU_OS` — blocked right-looking LU with *adaptive* look-ahead
//! extracted by the task runtime (the paper's OmpSs baseline, §5).
//!
//! Decomposition (paper §5, LU_OS bullet): the matrix is divided into
//! column panels of fixed width `b_o`. All operations performed during
//! iteration `k` on panel `j` — row permutation, triangular solve and
//! matrix multiplication — form one task `U(k,j)`; the factorization of
//! panel `k` is the task `P(k)`, given elevated **priority** so the
//! runtime advances the critical path (look-ahead of dynamic depth
//! emerges from the dependency structure, not from code structure).
//!
//! Dependencies:
//! - `P(k)`   after `U(k-1, k)`
//! - `U(k,j)` after `P(k)` and `U(k-1, j)`     (for `j > k`)
//!
//! Tasks run sequential kernels (single-thread crews): the runtime
//! exploits TP only, matching the paper's "calls to a sequential instance
//! of BLIS". Panels factorize with the **left-looking** inner variant,
//! like the paper's LU_OS configuration ("we integrated the LL variant as
//! well to favor a fair comparison").
//!
//! Pivot application to the *left* of each panel happens after the graph
//! drains (it touches finished columns only, is O(n²) data movement, and
//! keeping it out of the graph spares n² extra edges; LAPACK semantics
//! are preserved).

use super::{run, GraphBuilder, RunStats};
use crate::blis::{gemm, trsm_llu};
use crate::lu::panel::panel_ll;
use crate::lu::{LuConfig, LuResult};
use crate::matrix::Matrix;
use crate::pool::{Crew, Pool};
use crate::trace::{span, Kind};
use std::sync::{Arc, Mutex};

/// Factorize `a` in place via the task runtime. Total team =
/// `pool.workers() + 1` (the caller executes tasks too).
pub fn factorize_os(pool: &Pool, a: &mut Matrix, cfg: &LuConfig) -> LuResult {
    factorize_os_stats(pool, a, cfg).0
}

/// [`factorize_os`] additionally returning the runtime's execution
/// statistics — in particular [`RunStats::start_order`], which with a
/// 0-worker pool is the exact queue-pop order and (since the ready
/// queue's total (priority, release-sequence) ordering) is identical
/// run over run. Schedule-comparison tests pin that determinism here.
pub fn factorize_os_stats(pool: &Pool, a: &mut Matrix, cfg: &LuConfig) -> (LuResult, RunStats) {
    let av = a.view_mut();
    let (m, n) = (av.rows(), av.cols());
    let kmax = m.min(n);
    if kmax == 0 {
        return (LuResult::default(), RunStats::default());
    }
    let bo = cfg.bo.max(1);
    let bi = cfg.bi.max(1);
    let params = cfg.params;
    // Panel column ranges.
    let n_panels = n.div_ceil(bo);
    let n_fact = kmax.div_ceil(bo); // panels that get a P(k) task
    let col0 = |p: usize| p * bo;
    let cols_of = |p: usize| (col0(p), (col0(p) + bo).min(n));

    // Per-panel pivot storage (absolute row indices), filled by P(k).
    let pivots: Arc<Vec<Mutex<Vec<usize>>>> =
        Arc::new((0..n_fact).map(|_| Mutex::new(Vec::new())).collect());

    let mut gb = GraphBuilder::new();
    // task ids of the previous iteration per panel: u_prev[j]
    let mut u_prev: Vec<Option<usize>> = vec![None; n_panels];
    let mut p_task: Vec<usize> = Vec::with_capacity(n_fact);

    for k in 0..n_fact {
        let (jl, jr) = cols_of(k);
        let diag = jl; // first row of the panel's diagonal block
        // P(k): factorize panel k (rows diag.., cols jl..jr).
        let deps: Vec<usize> = u_prev[k].into_iter().collect();
        let pv = Arc::clone(&pivots);
        let pid = gb.add(format!("P({k})"), 1, &deps, move || {
            let mut crew = Crew::new(); // sequential kernels (TP only)
            let sub = av.sub(diag, jl, m - diag, jr - jl);
            let out = span(Kind::Panel, "P", || {
                panel_ll(&mut crew, &params, sub, bi, None)
            });
            *pv[k].lock().unwrap() = out.ipiv.iter().map(|p| p + diag).collect();
        });
        p_task.push(pid);

        // U(k, j) for every panel to the right.
        for j in k + 1..n_panels {
            let (ul, ur) = cols_of(j);
            let deps: Vec<usize> = [Some(pid), u_prev[j]].into_iter().flatten().collect();
            let pv = Arc::clone(&pivots);
            let id = gb.add(format!("U({k},{j})"), 0, &deps, move || {
                let mut crew = Crew::new();
                let piv = pv[k].lock().unwrap().clone();
                let b = piv.len(); // panel width (kmax-clamped on the last)
                // Row permutation of this panel's column range.
                span(Kind::Swap, "U.swap", || {
                    laswp_abs(&mut crew, av, &piv, diag, ul, ur);
                });
                // Triangular solve against the panel's diagonal block.
                span(Kind::Trsm, "U.trsm", || {
                    trsm_llu(
                        &mut crew,
                        &params,
                        av.sub(diag, jl, b, b).as_ref(),
                        av.sub(diag, ul, b, ur - ul),
                    );
                });
                // Trailing GEMM of this panel's column range.
                if m > diag + b {
                    span(Kind::Gemm, "U.gemm", || {
                        gemm(
                            &mut crew,
                            &params,
                            -1.0,
                            av.sub(diag + b, jl, m - diag - b, b).as_ref(),
                            av.sub(diag, ul, b, ur - ul).as_ref(),
                            av.sub(diag + b, ul, m - diag - b, ur - ul),
                        );
                    });
                }
            });
            u_prev[j] = Some(id);
        }
    }

    let run_stats = run(gb.build(), pool);

    // Deferred left-of-panel pivot application + pivot vector assembly.
    let mut crew = Crew::new();
    let mut ipiv: Vec<usize> = Vec::with_capacity(kmax);
    for k in 0..n_fact {
        let (jl, _) = cols_of(k);
        let piv = pivots[k].lock().unwrap().clone();
        laswp_abs(&mut crew, av, &piv, jl, 0, jl);
        ipiv.extend_from_slice(&piv);
    }
    debug_assert_eq!(ipiv.len(), kmax);
    (
        LuResult {
            ipiv,
            la_stats: None,
        },
        run_stats,
    )
}

/// Swap rows `base+i` ↔ `piv[i]` over columns `jlo..jhi` (same convention
/// as [`crate::lu::lookahead`]'s helper; duplicated to keep the task
/// closures self-contained).
fn laswp_abs(
    crew: &mut Crew,
    a: crate::matrix::MatMut,
    piv: &[usize],
    base: usize,
    jlo: usize,
    jhi: usize,
) {
    if piv.is_empty() || jlo >= jhi {
        return;
    }
    let ipiv_abs: Vec<usize> = piv.to_vec();
    crew.parallel_ranges(jhi - jlo, 16, |cols| {
        for (i, &p) in ipiv_abs.iter().enumerate() {
            let row = base + i;
            if p != row {
                a.swap_rows(row, p, jlo + cols.start, jlo + cols.end);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::BlisParams;
    use crate::lu::{residual, Variant};
    use crate::matrix::naive;

    fn cfg(bo: usize, bi: usize) -> LuConfig {
        LuConfig {
            variant: Variant::OmpSs,
            bo,
            bi,
            threads: 3,
            params: BlisParams::tiny(),
            ..Default::default()
        }
    }

    #[test]
    fn factorizes_square_matrices() {
        for &(n, bo, bi) in &[(24usize, 8usize, 4usize), (50, 16, 4), (33, 8, 2), (16, 16, 4)] {
            let a0 = Matrix::random(n, n, (n + bo) as u64);
            let mut f = a0.clone();
            let pool = Pool::new(2);
            let out = factorize_os(&pool, &mut f, &cfg(bo, bi));
            assert_eq!(out.ipiv.len(), n);
            let r = residual(&a0, &f, &out.ipiv);
            assert!(r < 1e-11, "n={n} bo={bo}: residual {r}");
            assert!(naive::growth_bounded(&f));
        }
    }

    #[test]
    fn rectangular_matrices() {
        for &(m, n) in &[(40usize, 24usize), (24, 40)] {
            let a0 = Matrix::random(m, n, (m * 2 + n) as u64);
            let mut f = a0.clone();
            let pool = Pool::new(2);
            let out = factorize_os(&pool, &mut f, &cfg(8, 4));
            let r = residual(&a0, &f, &out.ipiv);
            assert!(r < 1e-11, "m={m} n={n}: residual {r}");
        }
    }

    #[test]
    fn matches_direct_variants_pivots() {
        let n = 48;
        let a0 = Matrix::random(n, n, 9);
        let pool = Pool::new(2);
        let mut f_os = a0.clone();
        let out_os = factorize_os(&pool, &mut f_os, &cfg(8, 4));
        let mut f_ref = a0.clone();
        let piv_ref = naive::lu(f_ref.view_mut());
        assert_eq!(out_os.ipiv, piv_ref);
        let d = f_os.max_abs_diff(&f_ref);
        assert!(d < 1e-10, "factors diff {d}");
    }

    #[test]
    fn single_worker_pool() {
        let a0 = Matrix::random(30, 30, 11);
        let mut f = a0.clone();
        let pool = Pool::new(0); // caller-only execution
        let out = factorize_os(&pool, &mut f, &cfg(8, 4));
        let r = residual(&a0, &f, &out.ipiv);
        assert!(r < 1e-11, "residual {r}");
    }

    #[test]
    fn through_public_dispatch() {
        let a0 = Matrix::random(40, 40, 13);
        let mut f = a0.clone();
        let out = crate::lu::factorize(&mut f, &cfg(8, 4), None);
        let r = residual(&a0, &f, &out.ipiv);
        assert!(r < 1e-11, "residual {r}");
    }

    #[test]
    fn lu_os_task_order_is_deterministic() {
        // With a 0-worker pool the caller is the only executor, so
        // `start_order` is exactly the ready queue's pop order. The
        // (priority, release-sequence) total ordering makes it identical
        // across runs — the reproducibility prerequisite for comparing
        // LU_OS schedules (it did not hold under the old id tie-break
        // once tasks were released out of declaration order).
        let a0 = Matrix::random(40, 40, 17);
        let pool = Pool::new(0);
        let runner = || {
            let mut f = a0.clone();
            let (out, stats) = factorize_os_stats(&pool, &mut f, &cfg(8, 4));
            (out.ipiv, stats.start_order, f)
        };
        let (ipiv0, order0, f0) = runner();
        assert!(!order0.is_empty());
        assert_eq!(order0[0], 0, "P(0) is the only seed task");
        for _ in 0..2 {
            let (ipiv, order, f) = runner();
            assert_eq!(order, order0, "pop order must reproduce exactly");
            assert_eq!(ipiv, ipiv0);
            for (x, y) in f.data().iter().zip(f0.data()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn bo_larger_than_matrix() {
        let a0 = Matrix::random(10, 10, 14);
        let mut f = a0.clone();
        let pool = Pool::new(1);
        let out = factorize_os(&pool, &mut f, &cfg(64, 4));
        let r = residual(&a0, &f, &out.ipiv);
        assert!(r < 1e-12, "residual {r}");
    }
}
