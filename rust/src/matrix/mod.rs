//! Column-major dense matrices and views, generic over the sealed
//! [`Scalar`] precision layer (DESIGN.md §12).
//!
//! Storage follows BLAS/LAPACK conventions: column-major with a leading
//! dimension (`ld`), so every submatrix of a [`Mat`] is itself
//! addressable as a strided view. Parallel kernels operate on [`MatMut`]
//! raw views; the safety discipline is the classic BLAS one — concurrent
//! writers always target disjoint blocks, enforced structurally by the
//! algorithms (each thread owns a distinct column/row range).
//!
//! Precision: the owned matrix is [`Mat<S>`] with `S` one of the sealed
//! scalar types (`f32`, `f64`); [`Matrix`] is the `f64` alias every
//! pre-existing call site uses, and [`Matrix32`] its single-precision
//! sibling. Views carry the same parameter with an `f64` default, so
//! `MatRef`/`MatMut` written without parameters keep meaning double
//! precision.

pub mod naive;

use crate::scalar::Scalar;
use crate::util::Prng;

/// Owned column-major matrix (`ld == rows`) of scalar type `S`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat<S: Scalar> {
    data: Vec<S>,
    rows: usize,
    cols: usize,
}

/// The double-precision owned matrix — the crate's historical `Matrix`
/// type, now an alias of [`Mat<f64>`].
pub type Matrix = Mat<f64>;

/// The single-precision owned matrix.
pub type Matrix32 = Mat<f32>;

impl<S: Scalar> Mat<S> {
    /// Zero-filled `rows × cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            data: vec![S::ZERO; rows * cols],
            rows,
            cols,
        }
    }

    /// Identity matrix of order `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = S::ONE;
        }
        m
    }

    /// Matrix with entries drawn uniformly from `(0,1)` — the paper's
    /// experimental workload (§5). The same seed draws the same `f64`
    /// stream in every precision (entries are rounded into `S`), so
    /// `Mat::<f32>::random(..)` is the rounded image of
    /// `Matrix::random(..)`.
    pub fn random(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Prng::new(seed);
        let mut m = Self::zeros(rows, cols);
        for v in &mut m.data {
            *v = S::from_f64(rng.next_f64());
        }
        m
    }

    /// Diagonally dominant random matrix (well conditioned; handy for
    /// tests that want tiny residuals).
    pub fn random_dd(n: usize, seed: u64) -> Self {
        let mut m = Self::random(n, n, seed);
        for i in 0..n {
            m[(i, i)] += S::from_f64(n as f64);
        }
        m
    }

    /// Symmetric positive-definite random matrix `B·Bᵀ + n·I` — the
    /// well-conditioned workload for the Cholesky factorization.
    pub fn random_spd(n: usize, seed: u64) -> Self {
        let b = Self::random(n, n, seed);
        let mut m = naive::matmul(&b, &b.transposed());
        for j in 0..n {
            m[(j, j)] += S::from_f64(n as f64);
        }
        m
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> S) -> Self {
        let mut m = Self::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Build from row-major slice (convenient for literals in tests).
    pub fn from_rows(rows: usize, cols: usize, vals: &[S]) -> Self {
        assert_eq!(vals.len(), rows * cols);
        Self::from_fn(rows, cols, |i, j| vals[i * cols + j])
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Raw column-major data (length `rows*cols`).
    pub fn data(&self) -> &[S] {
        &self.data
    }

    /// Mutable raw column-major data.
    pub fn data_mut(&mut self) -> &mut [S] {
        &mut self.data
    }

    /// Full-matrix mutable raw view.
    pub fn view_mut(&mut self) -> MatMut<S> {
        MatMut {
            ptr: self.data.as_mut_ptr(),
            rows: self.rows,
            cols: self.cols,
            ld: self.rows,
        }
    }

    /// Full-matrix shared raw view.
    pub fn view(&self) -> MatRef<S> {
        MatRef {
            ptr: self.data.as_ptr(),
            rows: self.rows,
            cols: self.cols,
            ld: self.rows,
        }
    }

    /// Frobenius norm, accumulated in `f64` regardless of `S`.
    pub fn norm_f(&self) -> f64 {
        self.data
            .iter()
            .map(|x| {
                let v = x.to_f64();
                v * v
            })
            .sum::<f64>()
            .sqrt()
    }

    /// Max-abs entry (as `f64`).
    pub fn norm_max(&self) -> f64 {
        self.data.iter().fold(0.0f64, |a, &x| a.max(x.to_f64().abs()))
    }

    /// Elementwise maximum absolute difference (as `f64`).
    pub fn max_abs_diff(&self, other: &Self) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f64, |a, (x, y)| a.max((x.to_f64() - y.to_f64()).abs()))
    }

    /// Transposed copy.
    pub fn transposed(&self) -> Self {
        Self::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Copy entries to row-major order (for XLA literal interchange).
    /// Inverse of [`Mat::from_row_major`] for every shape, square or not
    /// (pinned by a property test below).
    pub fn to_row_major(&self) -> Vec<S> {
        let mut out = Vec::with_capacity(self.rows * self.cols);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.push(self[(i, j)]);
            }
        }
        out
    }

    /// Build from row-major data (for XLA literal interchange). `vals`
    /// must hold exactly `rows * cols` entries laid out row by row;
    /// entry `(i, j)` is read from `vals[i * cols + j]` — note `cols`,
    /// not `rows`, so non-square shapes round-trip through
    /// [`Mat::to_row_major`] exactly.
    pub fn from_row_major(rows: usize, cols: usize, vals: &[S]) -> Self {
        Self::from_rows(rows, cols, vals)
    }

    /// Rounded copy in another precision: `f32 → f64` is exact, `f64 →
    /// f32` rounds each entry to nearest — the demotion the
    /// mixed-precision solver performs (DESIGN.md §12).
    pub fn convert<T: Scalar>(&self) -> Mat<T> {
        Mat::from_fn(self.rows, self.cols, |i, j| {
            T::from_f64(self[(i, j)].to_f64())
        })
    }
}

impl<S: Scalar> std::ops::Index<(usize, usize)> for Mat<S> {
    type Output = S;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &S {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i + j * self.rows]
    }
}

impl<S: Scalar> std::ops::IndexMut<(usize, usize)> for Mat<S> {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut S {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i + j * self.rows]
    }
}

/// Shared (read-only) strided view of scalar type `S` (`f64` unless
/// spelled otherwise).
#[derive(Copy, Clone, Debug)]
pub struct MatRef<S: Scalar = f64> {
    ptr: *const S,
    rows: usize,
    cols: usize,
    ld: usize,
}

// SAFETY: MatRef is a read-only view; the owning Mat outlives all uses
// by construction of the kernels (scoped threads / crew jobs joined
// before the borrow ends).
unsafe impl<S: Scalar> Send for MatRef<S> {}
unsafe impl<S: Scalar> Sync for MatRef<S> {}

impl<S: Scalar> MatRef<S> {
    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }
    /// Leading dimension (column stride).
    pub fn ld(&self) -> usize {
        self.ld
    }

    /// Element at `(i, j)`.
    #[inline(always)]
    pub fn at(&self, i: usize, j: usize) -> S {
        debug_assert!(i < self.rows && j < self.cols);
        unsafe { *self.ptr.add(i + j * self.ld) }
    }

    /// Pointer to the start of column `j`.
    #[inline(always)]
    pub fn col_ptr(&self, j: usize) -> *const S {
        debug_assert!(j <= self.cols);
        unsafe { self.ptr.add(j * self.ld) }
    }

    /// Subview at `(i, j)` of shape `m × n`.
    pub fn sub(&self, i: usize, j: usize, m: usize, n: usize) -> MatRef<S> {
        debug_assert!(i + m <= self.rows && j + n <= self.cols);
        MatRef {
            ptr: unsafe { self.ptr.add(i + j * self.ld) },
            rows: m,
            cols: n,
            ld: self.ld,
        }
    }

    /// Copy into an owned matrix.
    pub fn to_matrix(&self) -> Mat<S> {
        Mat::from_fn(self.rows, self.cols, |i, j| self.at(i, j))
    }
}

/// Mutable strided view used by the parallel kernels (`f64` unless
/// spelled otherwise).
///
/// `Copy` on purpose: kernels hand disjoint-block aliases to worker
/// threads. All element access is bounds-debug-checked; disjointness of
/// concurrent writes is an algorithmic invariant (see module docs).
#[derive(Copy, Clone, Debug)]
pub struct MatMut<S: Scalar = f64> {
    ptr: *mut S,
    rows: usize,
    cols: usize,
    ld: usize,
}

// SAFETY: see module docs — concurrent writers always own disjoint blocks.
unsafe impl<S: Scalar> Send for MatMut<S> {}
unsafe impl<S: Scalar> Sync for MatMut<S> {}

impl<S: Scalar> MatMut<S> {
    /// Construct from raw parts (used by packing buffers).
    ///
    /// # Safety
    /// `ptr` must be valid for `ld*(cols-1)+rows` reads/writes for the
    /// lifetime of all uses of the view.
    pub unsafe fn from_raw(ptr: *mut S, rows: usize, cols: usize, ld: usize) -> Self {
        Self {
            ptr,
            rows,
            cols,
            ld,
        }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }
    /// Leading dimension (column stride).
    pub fn ld(&self) -> usize {
        self.ld
    }

    /// Element at `(i, j)`.
    #[inline(always)]
    pub fn at(&self, i: usize, j: usize) -> S {
        debug_assert!(i < self.rows && j < self.cols);
        unsafe { *self.ptr.add(i + j * self.ld) }
    }

    /// Store `v` at `(i, j)`.
    #[inline(always)]
    pub fn set(&self, i: usize, j: usize, v: S) {
        debug_assert!(i < self.rows && j < self.cols);
        unsafe { *self.ptr.add(i + j * self.ld) = v }
    }

    /// Read-modify-write the element at `(i, j)`.
    #[inline(always)]
    pub fn update(&self, i: usize, j: usize, f: impl FnOnce(S) -> S) {
        self.set(i, j, f(self.at(i, j)));
    }

    /// Pointer to the start of column `j`.
    #[inline(always)]
    pub fn col_ptr(&self, j: usize) -> *mut S {
        debug_assert!(j <= self.cols);
        unsafe { self.ptr.add(j * self.ld) }
    }

    /// Mutable column slice.
    #[inline(always)]
    pub fn col_mut(&self, j: usize) -> &mut [S] {
        debug_assert!(j < self.cols);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(j * self.ld), self.rows) }
    }

    /// Subview at `(i, j)` of shape `m × n`.
    pub fn sub(&self, i: usize, j: usize, m: usize, n: usize) -> MatMut<S> {
        debug_assert!(
            i + m <= self.rows && j + n <= self.cols,
            "sub({i},{j},{m},{n}) out of {}x{}",
            self.rows,
            self.cols
        );
        MatMut {
            ptr: unsafe { self.ptr.add(i + j * self.ld) },
            rows: m,
            cols: n,
            ld: self.ld,
        }
    }

    /// Read-only alias of this view.
    pub fn as_ref(&self) -> MatRef<S> {
        MatRef {
            ptr: self.ptr,
            rows: self.rows,
            cols: self.cols,
            ld: self.ld,
        }
    }

    /// Swap rows `r1` and `r2` across columns `jlo..jhi`.
    pub fn swap_rows(&self, r1: usize, r2: usize, jlo: usize, jhi: usize) {
        debug_assert!(r1 < self.rows && r2 < self.rows && jhi <= self.cols);
        if r1 == r2 {
            return;
        }
        for j in jlo..jhi {
            unsafe {
                let p1 = self.ptr.add(r1 + j * self.ld);
                let p2 = self.ptr.add(r2 + j * self.ld);
                std::ptr::swap(p1, p2);
            }
        }
    }

    /// Copy into an owned matrix.
    pub fn to_matrix(&self) -> Mat<S> {
        self.as_ref().to_matrix()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck_lite::{forall_res, Gen};

    #[test]
    fn zeros_eye_indexing() {
        let mut m = Matrix::zeros(3, 2);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 2);
        m[(2, 1)] = 5.0;
        assert_eq!(m[(2, 1)], 5.0);
        assert_eq!(m.data()[2 + 3], 5.0); // col-major position

        let e = Matrix::eye(3);
        assert_eq!(e[(1, 1)], 1.0);
        assert_eq!(e[(0, 1)], 0.0);
    }

    #[test]
    fn from_rows_is_row_major() {
        let m = Matrix::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(0, 2)], 3.0);
        assert_eq!(m[(1, 0)], 4.0);
        assert_eq!(m[(1, 2)], 6.0);
    }

    #[test]
    fn row_major_roundtrip() {
        let m = Matrix::random(4, 7, 3);
        let rm = m.to_row_major();
        let back = Matrix::from_row_major(4, 7, &rm);
        assert_eq!(m, back);
    }

    #[test]
    fn property_row_major_roundtrips_non_square_both_precisions() {
        // The satellite pin: to_row_major/from_row_major must be exact
        // inverses for every shape (tall, wide, degenerate) in both
        // precisions, and the row-major layout must really be row-major
        // (entry (i, j) at i*cols + j).
        forall_res("row-major roundtrip (f64 + f32)", 40, |g: &mut Gen| {
            let rows = g.usize_in(1, 23);
            let cols = g.usize_in(1, 23);
            let seed = g.seed();
            g.label(format!("rows={rows} cols={cols}"));

            let m = Matrix::random(rows, cols, seed);
            let rm = m.to_row_major();
            if rm.len() != rows * cols {
                return Err(format!("rm.len()={}", rm.len()));
            }
            if rm[cols - 1] != m[(0, cols - 1)] {
                return Err("row-major layout is not row-major".into());
            }
            if Matrix::from_row_major(rows, cols, &rm) != m {
                return Err("f64 roundtrip mismatch".into());
            }

            let m32 = Mat::<f32>::random(rows, cols, seed);
            let rm32 = m32.to_row_major();
            if Mat::<f32>::from_row_major(rows, cols, &rm32) != m32 {
                return Err("f32 roundtrip mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn random_is_deterministic_and_in_unit_interval() {
        let a = Matrix::random(5, 5, 42);
        let b = Matrix::random(5, 5, 42);
        assert_eq!(a, b);
        assert!(a.data().iter().all(|&x| (0.0..1.0).contains(&x)));
        let c = Matrix::random(5, 5, 43);
        assert!(a.max_abs_diff(&c) > 0.0);
    }

    #[test]
    fn random_f32_is_rounded_image_of_f64() {
        let a = Matrix::random(6, 4, 9);
        let a32 = Mat::<f32>::random(6, 4, 9);
        for j in 0..4 {
            for i in 0..6 {
                assert_eq!(a32[(i, j)], a[(i, j)] as f32, "({i},{j})");
            }
        }
        // And convert() performs the same rounding.
        let c: Mat<f32> = a.convert();
        assert_eq!(c, a32);
        // f32 → f64 widening is exact.
        let back: Matrix = a32.convert();
        for (x, y) in back.data().iter().zip(a32.data()) {
            assert_eq!(*x, *y as f64);
        }
    }

    #[test]
    fn views_address_submatrices() {
        let mut m = Matrix::from_fn(6, 6, |i, j| (10 * i + j) as f64);
        let v = m.view_mut();
        let s = v.sub(2, 3, 3, 2);
        assert_eq!(s.rows(), 3);
        assert_eq!(s.cols(), 2);
        assert_eq!(s.at(0, 0), 23.0);
        assert_eq!(s.at(2, 1), 44.0);
        s.set(1, 0, -1.0);
        assert_eq!(m[(3, 3)], -1.0);
    }

    #[test]
    fn nested_sub_composes() {
        let mut m = Matrix::from_fn(8, 8, |i, j| (i * 8 + j) as f64);
        let v = m.view_mut();
        let s1 = v.sub(1, 1, 6, 6);
        let s2 = s1.sub(2, 3, 2, 2);
        assert_eq!(s2.at(0, 0), m[(3, 4)]);
        assert_eq!(s2.at(1, 1), m[(4, 5)]);
    }

    #[test]
    fn f32_views_and_swaps_work() {
        let mut m = Mat::<f32>::from_fn(4, 4, |i, j| (i * 10 + j) as f32);
        let v = m.view_mut();
        v.swap_rows(0, 2, 1, 3);
        assert_eq!(m[(0, 1)], 21.0f32);
        assert_eq!(m[(2, 1)], 1.0f32);
        assert_eq!(m[(0, 0)], 0.0f32); // untouched column
        let s = m.view().sub(1, 1, 2, 2);
        assert_eq!(s.at(0, 0), m[(1, 1)]);
    }

    #[test]
    fn swap_rows_partial_columns() {
        let mut m = Matrix::from_fn(4, 4, |i, j| (i * 10 + j) as f64);
        let v = m.view_mut();
        v.swap_rows(0, 2, 1, 3);
        assert_eq!(m[(0, 0)], 0.0); // untouched column
        assert_eq!(m[(0, 1)], 21.0);
        assert_eq!(m[(2, 1)], 1.0);
        assert_eq!(m[(0, 2)], 22.0);
        assert_eq!(m[(0, 3)], 3.0); // untouched column
    }

    #[test]
    fn swap_same_row_is_noop() {
        let mut m = Matrix::random(4, 4, 1);
        let before = m.clone();
        m.view_mut().swap_rows(2, 2, 0, 4);
        assert_eq!(m, before);
    }

    #[test]
    fn norms() {
        let m = Matrix::from_rows(2, 2, &[3.0, 0.0, 0.0, -4.0]);
        assert!((m.norm_f() - 5.0).abs() < 1e-15);
        assert_eq!(m.norm_max(), 4.0);
    }

    #[test]
    fn transpose() {
        let m = Matrix::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let t = m.transposed();
        assert_eq!(t.rows(), 3);
        assert_eq!(t[(2, 1)], 6.0);
        assert_eq!(t.transposed(), m);
    }

    #[test]
    fn col_mut_is_column() {
        let mut m = Matrix::from_fn(3, 2, |i, j| (i + 10 * j) as f64);
        let v = m.view_mut();
        let c1 = v.col_mut(1);
        assert_eq!(c1, &[10.0, 11.0, 12.0]);
        c1[0] = 99.0;
        assert_eq!(m[(0, 1)], 99.0);
    }
}
