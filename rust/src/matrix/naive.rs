//! Naive reference kernels — the correctness oracles for the BLIS
//! substrate and the LU variants. Triple loops, no blocking, no
//! parallelism; trivially auditable. Generic over the sealed [`Scalar`]
//! layer so the same oracles validate both precisions; residual and
//! norm helpers accumulate in `f64` regardless of the working type and
//! return `f64` (compare against `S::EPSILON`-scaled tolerances).

use super::{Mat, MatMut, MatRef};
use crate::factor::FactorError;
use crate::scalar::Scalar;

/// Column-major offset (`j * rows + i`) of the first non-finite entry
/// of `a`, scanning columns left to right.
fn first_non_finite<S: Scalar>(a: MatRef<S>) -> Option<usize> {
    for j in 0..a.cols() {
        for i in 0..a.rows() {
            if !a.at(i, j).is_finite() {
                return Some(j * a.rows() + i);
            }
        }
    }
    None
}

/// `C += alpha * A * B` (naive triple loop).
pub fn gemm<S: Scalar>(alpha: S, a: MatRef<S>, b: MatRef<S>, c: MatMut<S>) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(b.rows(), k, "gemm: inner dims");
    assert_eq!(c.rows(), m, "gemm: C rows");
    assert_eq!(c.cols(), n, "gemm: C cols");
    for j in 0..n {
        for p in 0..k {
            let bpj = alpha * b.at(p, j);
            if bpj == S::ZERO {
                continue;
            }
            for i in 0..m {
                c.update(i, j, |x| x + a.at(i, p) * bpj);
            }
        }
    }
}

/// Owned-output convenience: `A·B`.
pub fn matmul<S: Scalar>(a: &Mat<S>, b: &Mat<S>) -> Mat<S> {
    let mut c = Mat::zeros(a.rows(), b.cols());
    gemm(S::ONE, a.view(), b.view(), c.view_mut());
    c
}

/// `B := TRILU(A)⁻¹ · B` — left solve with the *unit* lower triangle of
/// `A` (diagonal treated as ones, strictly-upper part ignored). This is
/// the TRSM case appearing in the LU loop body (RL2/LL1).
pub fn trsm_llu<S: Scalar>(a: MatRef<S>, b: MatMut<S>) {
    let m = b.rows();
    assert_eq!(a.rows(), m);
    assert_eq!(a.cols(), m);
    for j in 0..b.cols() {
        for i in 0..m {
            let mut s = b.at(i, j);
            for p in 0..i {
                s -= a.at(i, p) * b.at(p, j);
            }
            b.set(i, j, s);
        }
    }
}

/// `B := A⁻¹ · B` with `A` upper triangular (non-unit diagonal) — used by
/// the linear-system solver after factorization.
pub fn trsm_upper<S: Scalar>(a: MatRef<S>, b: MatMut<S>) {
    let m = b.rows();
    assert_eq!(a.rows(), m);
    assert_eq!(a.cols(), m);
    for j in 0..b.cols() {
        for i in (0..m).rev() {
            let mut s = b.at(i, j);
            for p in i + 1..m {
                s -= a.at(i, p) * b.at(p, j);
            }
            b.set(i, j, s / a.at(i, i));
        }
    }
}

/// Unblocked right-looking LU with partial pivoting (reference).
///
/// Overwrites `a` with the packed `L\U` factors and returns `ipiv` in
/// LAPACK convention: row `i` was swapped with row `ipiv[i]` (`ipiv[i] >=
/// i`).
pub fn lu<S: Scalar>(a: MatMut<S>) -> Vec<usize> {
    let (m, n) = (a.rows(), a.cols());
    let kmax = m.min(n);
    let mut ipiv = Vec::with_capacity(kmax);
    for k in 0..kmax {
        // Pivot search: argmax |A(k..m, k)|.
        let mut piv = k;
        let mut best = a.at(k, k).abs();
        for i in k + 1..m {
            let v = a.at(i, k).abs();
            if v > best {
                best = v;
                piv = i;
            }
        }
        ipiv.push(piv);
        a.swap_rows(k, piv, 0, n);
        let akk = a.at(k, k);
        if akk != S::ZERO {
            // Scale the subdiagonal of column k. LAPACK-style reciprocal
            // multiply (not division) so the blocked kernels can match
            // this reference bitwise.
            let rakk = S::ONE / akk;
            for i in k + 1..m {
                a.update(i, k, |x| x * rakk);
            }
            // Rank-1 update of the trailing submatrix.
            for j in k + 1..n {
                let akj = a.at(k, j);
                if akj == S::ZERO {
                    continue;
                }
                for i in k + 1..m {
                    a.update(i, j, |x| x - a.at(i, k) * akj);
                }
            }
        }
    }
    ipiv
}

/// Checked variant of [`lu`]: identical arithmetic and pivots, but with
/// typed failure reporting instead of silent degradation.
///
/// - Non-finite input is rejected *before* any entry is written
///   ([`FactorError::NonFinite`] carries the column-major offset of the
///   first offender; `a` is untouched).
/// - A zero pivot — which [`lu`] silently skips, LAPACK `getrf`-style —
///   is reported as [`FactorError::ExactlySingular`] naming the first
///   offending column. The factorization still runs to completion first
///   (the packed factors are exactly what [`lu`] produces), mirroring
///   LAPACK's `info > 0` convention.
pub fn try_lu<S: Scalar>(a: MatMut<S>) -> Result<Vec<usize>, FactorError> {
    if let Some(off) = first_non_finite(a.as_ref()) {
        return Err(FactorError::NonFinite { first_offset: off });
    }
    let ipiv = lu(a);
    for k in 0..a.rows().min(a.cols()) {
        if a.at(k, k) == S::ZERO {
            return Err(FactorError::ExactlySingular { col: k });
        }
    }
    Ok(ipiv)
}

/// Apply the pivots produced by [`lu`] to a matrix: `B := P·B` where `P`
/// is the permutation the factorization applied to `A`'s rows.
pub fn apply_pivots<S: Scalar>(b: MatMut<S>, ipiv: &[usize]) {
    for (k, &p) in ipiv.iter().enumerate() {
        b.swap_rows(k, p, 0, b.cols());
    }
}

/// Extract `L` (unit lower trapezoidal, `m × min(m,n)`) from packed
/// factors.
pub fn extract_l<S: Scalar>(lu: &Mat<S>) -> Mat<S> {
    let (m, n) = (lu.rows(), lu.cols());
    let k = m.min(n);
    Mat::from_fn(m, k, |i, j| {
        use std::cmp::Ordering::*;
        match i.cmp(&j) {
            Greater => lu[(i, j)],
            Equal => S::ONE,
            Less => S::ZERO,
        }
    })
}

/// Extract `U` (upper trapezoidal, `min(m,n) × n`) from packed factors.
pub fn extract_u<S: Scalar>(lu: &Mat<S>) -> Mat<S> {
    let (m, n) = (lu.rows(), lu.cols());
    let k = m.min(n);
    Mat::from_fn(k, n, |i, j| if j >= i { lu[(i, j)] } else { S::ZERO })
}

/// Relative residual ‖P·A − L·U‖_F / ‖A‖_F of a factorization of `a`
/// (accumulated in `f64` for both precisions).
pub fn lu_residual<S: Scalar>(a: &Mat<S>, lu_packed: &Mat<S>, ipiv: &[usize]) -> f64 {
    let mut pa = a.clone();
    apply_pivots(pa.view_mut(), ipiv);
    let l = extract_l(lu_packed);
    let u = extract_u(lu_packed);
    let prod = matmul(&l, &u);
    let mut diff = 0.0f64;
    for j in 0..a.cols() {
        for i in 0..a.rows() {
            let d = pa[(i, j)].to_f64() - prod[(i, j)].to_f64();
            diff += d * d;
        }
    }
    diff.sqrt() / a.norm_f().max(f64::MIN_POSITIVE)
}

/// Check |L| entries are ≤ 1 (guaranteed by partial pivoting).
pub fn growth_bounded<S: Scalar>(lu_packed: &Mat<S>) -> bool {
    let (m, n) = (lu_packed.rows(), lu_packed.cols());
    for j in 0..m.min(n) {
        for i in j + 1..m {
            if lu_packed[(i, j)].to_f64().abs() > 1.0 + 1e-12 {
                return false;
            }
        }
    }
    true
}

/// Solve `A·x = b` given packed LU factors and pivots (single RHS), in
/// the factors' own precision — the substitution sweep the
/// mixed-precision refiner runs in `f32` every iteration.
pub fn lu_solve<S: Scalar>(lu_packed: &Mat<S>, ipiv: &[usize], b: &[S]) -> Vec<S> {
    let n = lu_packed.rows();
    assert_eq!(lu_packed.cols(), n, "lu_solve: square only");
    assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    // P·b
    for (k, &p) in ipiv.iter().enumerate() {
        x.swap(k, p);
    }
    // Forward substitution with unit L.
    for i in 0..n {
        let mut s = x[i];
        for p in 0..i {
            s -= lu_packed[(i, p)] * x[p];
        }
        x[i] = s;
    }
    // Back substitution with U.
    for i in (0..n).rev() {
        let mut s = x[i];
        for p in i + 1..n {
            s -= lu_packed[(i, p)] * x[p];
        }
        x[i] = s / lu_packed[(i, i)];
    }
    x
}

/// Checked variant of [`lu_solve`]: refuses to divide by a zero or
/// non-finite pivot. [`lu_solve`]'s back-substitution divides by
/// `U(i,i)` unconditionally, so packed factors of a singular matrix
/// silently yield `inf`/NaN solutions; this variant reports
/// [`FactorError::ExactlySingular`] (or [`FactorError::NonFinite`])
/// instead, naming the first offending diagonal.
pub fn try_lu_solve<S: Scalar>(
    lu_packed: &Mat<S>,
    ipiv: &[usize],
    b: &[S],
) -> Result<Vec<S>, FactorError> {
    let n = lu_packed.rows();
    assert_eq!(lu_packed.cols(), n, "lu_solve: square only");
    assert_eq!(b.len(), n);
    for i in 0..n {
        let d = lu_packed[(i, i)];
        if !d.is_finite() {
            return Err(FactorError::NonFinite {
                first_offset: i * n + i,
            });
        }
        if d == S::ZERO {
            return Err(FactorError::ExactlySingular { col: i });
        }
    }
    Ok(lu_solve(lu_packed, ipiv, b))
}

/// Unblocked Cholesky factorization `A = L·Lᵀ` (lower, left-looking
/// reference). Overwrites the lower triangle of `a` with `L`; the strict
/// upper triangle is neither read nor written. The input must be
/// symmetric positive definite — a non-SPD matrix yields NaNs (no pivoting
/// is performed, matching LAPACK `potf2` semantics).
pub fn cholesky<S: Scalar>(a: MatMut<S>) {
    let n = a.rows();
    assert_eq!(a.cols(), n, "cholesky: square only");
    for j in 0..n {
        let mut d = a.at(j, j);
        for p in 0..j {
            let l = a.at(j, p);
            d -= l * l;
        }
        let dj = d.sqrt();
        a.set(j, j, dj);
        for i in j + 1..n {
            let mut s = a.at(i, j);
            for p in 0..j {
                s -= a.at(i, p) * a.at(j, p);
            }
            a.set(i, j, s / dj);
        }
    }
}

/// Checked variant of [`cholesky`]: identical arithmetic on the happy
/// path (the committed columns match [`cholesky`] bitwise), but
/// breakdown is detected *before* the offending `sqrt`/divide instead
/// of letting NaNs propagate:
///
/// - A non-finite entry in the lower triangle (the only part read) is
///   rejected up front as [`FactorError::NonFinite`]; `a` is untouched.
/// - A zero reduced diagonal is [`FactorError::ExactlySingular`].
/// - A negative or overflowed reduced diagonal (the matrix is not
///   positive definite) is [`FactorError::Unsupported`].
///
/// On error, columns `0..col` hold valid `L` columns and the rest of
/// `a` is unwritten (matching LAPACK `potf2`'s `info > 0` contract).
pub fn try_cholesky<S: Scalar>(a: MatMut<S>) -> Result<(), FactorError> {
    let n = a.rows();
    assert_eq!(a.cols(), n, "cholesky: square only");
    for j in 0..n {
        for i in j..n {
            if !a.at(i, j).is_finite() {
                return Err(FactorError::NonFinite {
                    first_offset: j * n + i,
                });
            }
        }
    }
    for j in 0..n {
        let mut d = a.at(j, j);
        for p in 0..j {
            let l = a.at(j, p);
            d -= l * l;
        }
        if !d.is_finite() || d < S::ZERO {
            return Err(FactorError::Unsupported(format!(
                "matrix is not positive definite (breakdown at column {j})"
            )));
        }
        if d == S::ZERO {
            return Err(FactorError::ExactlySingular { col: j });
        }
        let dj = d.sqrt();
        a.set(j, j, dj);
        for i in j + 1..n {
            let mut s = a.at(i, j);
            for p in 0..j {
                s -= a.at(i, p) * a.at(j, p);
            }
            a.set(i, j, s / dj);
        }
    }
    Ok(())
}

/// Relative residual `‖A − L·Lᵀ‖_F / ‖A‖_F` of a Cholesky factorization;
/// only the lower triangle of `l_packed` is read.
pub fn chol_residual<S: Scalar>(a: &Mat<S>, l_packed: &Mat<S>) -> f64 {
    let n = a.rows();
    let l = Mat::from_fn(n, n, |i, j| if i >= j { l_packed[(i, j)] } else { S::ZERO });
    let lt = l.transposed();
    let prod = matmul(&l, &lt);
    let mut diff = 0.0f64;
    for j in 0..n {
        for i in 0..n {
            let d = a[(i, j)].to_f64() - prod[(i, j)].to_f64();
            diff += d * d;
        }
    }
    diff.sqrt() / a.norm_f().max(f64::MIN_POSITIVE)
}

/// Accumulate the explicit `m × m` orthogonal factor `Q = H_0·H_1⋯H_{k−1}`
/// from packed QR factors (reflector tails below the diagonal of
/// `factored`, scalar factors in `tau`). Test oracle — O(m²·k), applies
/// the reflectors to the identity in reverse order.
pub fn qr_q<S: Scalar>(factored: &Mat<S>, tau: &[S]) -> Mat<S> {
    let m = factored.rows();
    let mut q = Mat::eye(m);
    for j in (0..tau.len()).rev() {
        if tau[j] == S::ZERO {
            continue;
        }
        for c in 0..m {
            let mut w = q[(j, c)];
            for i in j + 1..m {
                w += factored[(i, j)] * q[(i, c)];
            }
            w *= tau[j];
            q[(j, c)] -= w;
            for i in j + 1..m {
                let f = factored[(i, j)] * w;
                q[(i, c)] -= f;
            }
        }
    }
    q
}

/// Extract `R` (upper trapezoidal, `m × n` with zeros below the diagonal)
/// from packed QR factors.
pub fn extract_r<S: Scalar>(factored: &Mat<S>) -> Mat<S> {
    Mat::from_fn(factored.rows(), factored.cols(), |i, j| {
        if j >= i {
            factored[(i, j)]
        } else {
            S::ZERO
        }
    })
}

/// Relative residual `‖A − Q·R‖_F / ‖A‖_F` of a QR factorization.
pub fn qr_residual<S: Scalar>(a: &Mat<S>, factored: &Mat<S>, tau: &[S]) -> f64 {
    let q = qr_q(factored, tau);
    let r = extract_r(factored);
    let prod = matmul(&q, &r);
    let mut diff = 0.0f64;
    for j in 0..a.cols() {
        for i in 0..a.rows() {
            let d = a[(i, j)].to_f64() - prod[(i, j)].to_f64();
            diff += d * d;
        }
    }
    diff.sqrt() / a.norm_f().max(f64::MIN_POSITIVE)
}

/// Max-abs entry of `QᵀQ − I` — the orthogonality defect of an explicit
/// `Q` factor (as `f64`).
pub fn orthogonality<S: Scalar>(q: &Mat<S>) -> f64 {
    let qt = q.transposed();
    let prod = matmul(&qt, q);
    let n = q.cols();
    let mut worst = 0.0f64;
    for j in 0..n {
        for i in 0..n {
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((prod[(i, j)].to_f64() - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::Matrix;
    use crate::util::quickcheck_lite::{forall_res, Gen};

    #[test]
    fn gemm_small_known() {
        let a = Matrix::from_rows(2, 2, &[1., 2., 3., 4.]);
        let b = Matrix::from_rows(2, 2, &[5., 6., 7., 8.]);
        let c = matmul(&a, &b);
        assert_eq!(c, Matrix::from_rows(2, 2, &[19., 22., 43., 50.]));
    }

    #[test]
    fn gemm_accumulates_and_scales() {
        let a = Matrix::from_rows(2, 1, &[1., 2.]);
        let b = Matrix::from_rows(1, 2, &[3., 4.]);
        let mut c = Matrix::eye(2);
        gemm(2.0, a.view(), b.view(), c.view_mut());
        assert_eq!(c, Matrix::from_rows(2, 2, &[7., 8., 12., 17.]));
    }

    #[test]
    fn gemm_identity() {
        let a = Matrix::random(5, 5, 1);
        let i5 = Matrix::eye(5);
        let c = matmul(&a, &i5);
        assert!(a.max_abs_diff(&c) < 1e-15);
        let c2 = matmul(&i5, &a);
        assert!(a.max_abs_diff(&c2) < 1e-15);
    }

    #[test]
    fn gemm_f32_matches_f64_to_f32_accuracy() {
        let a = Matrix::random(9, 7, 31);
        let b = Matrix::random(7, 5, 32);
        let c = matmul(&a, &b);
        let c32 = matmul::<f32>(&a.convert(), &b.convert());
        let d = c.max_abs_diff(&c32.convert());
        let tol = 16.0 * f32::EPSILON as f64 * 7.0;
        assert!(d < tol, "f32 gemm drift {d} > {tol}");
    }

    #[test]
    fn trsm_llu_inverts_gemm() {
        // B0 random; B := TRILU(L)·B0 then solve back.
        let n = 8;
        let l = Matrix::from_fn(n, n, |i, j| {
            use std::cmp::Ordering::*;
            match i.cmp(&j) {
                Greater => 0.3 * ((i * 7 + j * 3) % 5) as f64 - 0.5,
                Equal => 1.0,
                Less => 0.0,
            }
        });
        let b0 = Matrix::random(n, 4, 2);
        let mut b = matmul(&l, &b0);
        trsm_llu(l.view(), b.view_mut());
        assert!(b.max_abs_diff(&b0) < 1e-12);
    }

    #[test]
    fn trsm_llu_ignores_strict_upper_and_diagonal() {
        let n = 6;
        // A has garbage in the upper triangle and diagonal; only the strict
        // lower triangle may be read.
        let mut a = Matrix::random(n, n, 3);
        for i in 0..n {
            a[(i, i)] = 1e30; // must be ignored (unit diag assumed)
        }
        let mut clean = a.clone();
        for j in 0..n {
            for i in 0..=j {
                clean[(i, j)] = if i == j { 1.0 } else { 0.0 };
            }
        }
        let b0 = Matrix::random(n, 3, 4);
        let mut b1 = b0.clone();
        let mut b2 = b0.clone();
        trsm_llu(a.view(), b1.view_mut());
        trsm_llu(clean.view(), b2.view_mut());
        assert!(b1.max_abs_diff(&b2) < 1e-12);
    }

    #[test]
    fn trsm_upper_solves() {
        let n = 7;
        let u = Matrix::from_fn(n, n, |i, j| {
            if j > i {
                0.1 * ((i + 2 * j) % 7) as f64
            } else if j == i {
                2.0 + i as f64
            } else {
                0.0
            }
        });
        let x0 = Matrix::random(n, 2, 5);
        let mut b = matmul(&u, &x0);
        trsm_upper(u.view(), b.view_mut());
        assert!(b.max_abs_diff(&x0) < 1e-12);
    }

    #[test]
    fn lu_2x2_known() {
        // A = [[0, 1], [2, 3]] -> pivot swaps rows; L=[[1,0],[0,1]] ...
        let mut a = Matrix::from_rows(2, 2, &[0., 1., 2., 3.]);
        let ipiv = lu(a.view_mut());
        assert_eq!(ipiv, vec![1, 1]);
        // After swap: [[2,3],[0,1]]; l21 = 0/2 = 0; u = [[2,3],[0,1]].
        assert_eq!(a, Matrix::from_rows(2, 2, &[2., 3., 0., 1.]));
    }

    #[test]
    fn lu_residual_small_square() {
        for n in [1usize, 2, 3, 5, 8, 17, 33] {
            let a = Matrix::random(n, n, 7 + n as u64);
            let mut f = a.clone();
            let ipiv = lu(f.view_mut());
            let r = lu_residual(&a, &f, &ipiv);
            assert!(r < 1e-13, "n={n} residual={r}");
            assert!(growth_bounded(&f));
        }
    }

    #[test]
    fn lu_f32_residual_scales_with_epsilon() {
        use crate::matrix::Mat;
        use crate::scalar::Scalar;
        for n in [4usize, 16, 40] {
            let a = Mat::<f32>::random(n, n, 7 + n as u64);
            let mut f = a.clone();
            let ipiv = lu(f.view_mut());
            let r = lu_residual(&a, &f, &ipiv);
            let tol = 8.0 * n as f64 * <f32 as Scalar>::EPSILON.to_f64();
            assert!(r < tol, "n={n} residual={r} tol={tol}");
            assert!(growth_bounded(&f));
        }
    }

    #[test]
    fn lu_rectangular_tall_and_wide() {
        for (m, n) in [(9usize, 5usize), (5, 9), (12, 3), (3, 12)] {
            let a = Matrix::random(m, n, (m * 100 + n) as u64);
            let mut f = a.clone();
            let ipiv = lu(f.view_mut());
            assert_eq!(ipiv.len(), m.min(n));
            let r = lu_residual(&a, &f, &ipiv);
            assert!(r < 1e-13, "m={m} n={n} residual={r}");
        }
    }

    #[test]
    fn lu_singular_matrix_does_not_panic() {
        let mut a = Matrix::zeros(4, 4);
        let ipiv = lu(a.view_mut());
        assert_eq!(ipiv.len(), 4);
        assert_eq!(a, Matrix::zeros(4, 4));
    }

    #[test]
    fn lu_pivots_pick_largest_magnitude() {
        let mut a = Matrix::from_rows(3, 3, &[1., 0., 0., 4., 1., 0., -9., 0., 1.]);
        let ipiv = lu(a.view_mut());
        assert_eq!(ipiv[0], 2); // row 2 has |−9|
        assert!(growth_bounded(&a));
    }

    #[test]
    fn lu_solve_roundtrip() {
        let n = 12;
        let a = Matrix::random_dd(n, 9);
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 - 3.0) * 0.5).collect();
        let mut b = vec![0.0; n];
        for j in 0..n {
            for i in 0..n {
                b[i] += a[(i, j)] * x_true[j];
            }
        }
        let mut f = a.clone();
        let ipiv = lu(f.view_mut());
        let x = lu_solve(&f, &ipiv, &b);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-10, "x[{i}]");
        }
    }

    #[test]
    fn apply_pivots_matches_permutation_matrix() {
        let n = 6;
        let a = Matrix::random(n, n, 10);
        let mut f = a.clone();
        let ipiv = lu(f.view_mut());
        // Build P explicitly by applying pivots to the identity.
        let mut p = Matrix::eye(n);
        apply_pivots(p.view_mut(), &ipiv);
        let pa = matmul(&p, &a);
        let mut pa2 = a.clone();
        apply_pivots(pa2.view_mut(), &ipiv);
        assert!(pa.max_abs_diff(&pa2) < 1e-15);
    }

    #[test]
    fn cholesky_reconstructs_spd() {
        for n in [1usize, 2, 5, 12, 24] {
            let a = Matrix::random_spd(n, 7 + n as u64);
            let mut f = a.clone();
            cholesky(f.view_mut());
            let r = chol_residual(&a, &f);
            assert!(r < 1e-13, "n={n} residual={r}");
            // Diagonal of L is positive.
            for i in 0..n {
                assert!(f[(i, i)] > 0.0);
            }
        }
    }

    #[test]
    fn cholesky_known_2x2() {
        // A = [[4, 2], [2, 5]] => L = [[2, 0], [1, 2]].
        let mut a = Matrix::from_rows(2, 2, &[4., 2., 2., 5.]);
        cholesky(a.view_mut());
        assert!((a[(0, 0)] - 2.0).abs() < 1e-15);
        assert!((a[(1, 0)] - 1.0).abs() < 1e-15);
        assert!((a[(1, 1)] - 2.0).abs() < 1e-15);
    }

    #[test]
    fn try_lu_matches_lu_on_well_posed_input() {
        let a = Matrix::random(9, 9, 21);
        let mut f1 = a.clone();
        let mut f2 = a.clone();
        let ipiv1 = lu(f1.view_mut());
        let ipiv2 = try_lu(f2.view_mut()).expect("well-posed input");
        assert_eq!(ipiv1, ipiv2);
        assert_eq!(f1, f2, "checked oracle must be bitwise identical");
    }

    #[test]
    fn try_lu_reports_exactly_singular() {
        // All-zero input: first pivot is already zero.
        let mut z = Matrix::zeros(4, 4);
        assert_eq!(
            try_lu(z.view_mut()),
            Err(FactorError::ExactlySingular { col: 0 })
        );
        // Rank-1 matrix: elimination zeroes the second diagonal.
        let mut r1 = Matrix::from_rows(2, 2, &[1., 2., 2., 4.]);
        assert_eq!(
            try_lu(r1.view_mut()),
            Err(FactorError::ExactlySingular { col: 1 })
        );
    }

    #[test]
    fn try_lu_rejects_non_finite_without_touching_input() {
        let a0 = Matrix::random(5, 5, 22);
        let mut a = a0.clone();
        a[(2, 1)] = f64::NAN;
        let before = a.clone();
        let err = try_lu(a.view_mut()).unwrap_err();
        assert_eq!(err, FactorError::NonFinite { first_offset: 5 + 2 });
        // Prescan fires before any write: every finite entry untouched.
        for j in 0..5 {
            for i in 0..5 {
                if (i, j) != (2, 1) {
                    assert_eq!(a[(i, j)].to_bits(), before[(i, j)].to_bits());
                }
            }
        }
    }

    #[test]
    fn try_lu_solve_refuses_zero_pivot_that_lu_solve_divides_by() {
        // Packed factors of a singular matrix: U(1,1) == 0. The raw
        // oracle divides by it and yields non-finite garbage; the
        // checked oracle names the column instead.
        let mut f = Matrix::from_rows(2, 2, &[2., 4., 0.5, 0.]);
        let ipiv = vec![0usize, 1];
        let b = [1.0f64, 1.0];
        let raw = lu_solve(&f, &ipiv, &b);
        assert!(
            raw.iter().any(|x| !x.is_finite()),
            "raw oracle silently produces non-finite solution: {raw:?}"
        );
        assert_eq!(
            try_lu_solve(&f, &ipiv, &b),
            Err(FactorError::ExactlySingular { col: 1 })
        );
        // And a non-finite diagonal is its own typed failure.
        f[(0, 0)] = f64::INFINITY;
        assert_eq!(
            try_lu_solve(&f, &ipiv, &b),
            Err(FactorError::NonFinite { first_offset: 0 })
        );
    }

    #[test]
    fn try_cholesky_matches_cholesky_on_spd_input() {
        for n in [1usize, 3, 8, 17] {
            let a = Matrix::random_spd(n, 40 + n as u64);
            let mut f1 = a.clone();
            let mut f2 = a.clone();
            cholesky(f1.view_mut());
            try_cholesky(f2.view_mut()).expect("SPD input");
            for j in 0..n {
                for i in j..n {
                    assert_eq!(
                        f1[(i, j)].to_bits(),
                        f2[(i, j)].to_bits(),
                        "n={n} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn try_cholesky_reports_typed_breakdown() {
        // Indefinite: d goes negative at column 1.
        let mut ind = Matrix::from_rows(2, 2, &[1., 2., 2., 1.]);
        match try_cholesky(ind.view_mut()) {
            Err(FactorError::Unsupported(msg)) => {
                assert!(msg.contains("column 1"), "{msg}")
            }
            other => panic!("expected Unsupported, got {other:?}"),
        }
        // Exactly singular SPSD: zero reduced diagonal at column 0.
        let mut z = Matrix::zeros(3, 3);
        assert_eq!(
            try_cholesky(z.view_mut()),
            Err(FactorError::ExactlySingular { col: 0 })
        );
        // NaN in the lower triangle is rejected up front; the strict
        // upper triangle is never read, so garbage there is fine.
        let mut a = Matrix::random_spd(4, 44);
        a[(0, 3)] = f64::NAN; // strict upper: ignored
        try_cholesky(a.view_mut()).expect("upper-triangle NaN is not read");
        let mut b = Matrix::random_spd(4, 45);
        b[(3, 1)] = f64::NAN; // lower: offset 1*4 + 3
        assert_eq!(
            try_cholesky(b.view_mut()),
            Err(FactorError::NonFinite { first_offset: 7 })
        );
    }

    #[test]
    fn qr_q_identity_when_no_reflectors() {
        let f = Matrix::random(5, 3, 1);
        let q = qr_q(&f, &[]);
        assert!(q.max_abs_diff(&Matrix::eye(5)) == 0.0);
    }

    #[test]
    fn property_lu_residual_and_growth() {
        forall_res("naive lu: residual tiny, |L|<=1", 30, |g: &mut Gen| {
            let m = g.usize_in(1, 24);
            let n = g.usize_in(1, 24);
            let seed = g.seed();
            g.label(format!("m={m} n={n} seed={seed:#x}"));
            let a = Matrix::random(m, n, seed);
            let mut f = a.clone();
            let ipiv = lu(f.view_mut());
            for (k, &p) in ipiv.iter().enumerate() {
                if p < k || p >= m {
                    return Err(format!("bad pivot ipiv[{k}]={p}"));
                }
            }
            let r = lu_residual(&a, &f, &ipiv);
            if r > 1e-12 {
                return Err(format!("residual {r}"));
            }
            if !growth_bounded(&f) {
                return Err("|L| entry > 1".into());
            }
            Ok(())
        });
    }
}
