//! Leader-side request driver: runs one problem's factorization on a
//! pool worker, with per-request trace tags, cost-model progress
//! accounting, deadline enforcement, and cancellation checkpoints.

use super::registry::Lease;
use crate::blis::BlisParams;
use crate::lu::{lu_blocked_rl_ctl, BlockedCtl, BlockedOutcome};
use crate::matrix::MatMut;
use crate::pool::Crew;
use crate::sim::HwModel;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Cost-model estimate of the single-core seconds left in an `m × n` LU
/// after `k` committed columns — the sum of every remaining step's panel,
/// LASWP, TRSM, and GEMM times under `hw`. This is the remaining-FLOPs
/// half of the reallocation policy (the other half is priority).
pub fn remaining_cost(hw: &HwModel, m: usize, n: usize, k: usize, bo: usize, bi: usize) -> f64 {
    let kmax = m.min(n);
    let bo = bo.max(1);
    let mut total = 0.0;
    let mut kk = k.min(kmax);
    while kk < kmax {
        let b = bo.min(kmax - kk);
        total += hw.panel_time(m - kk, b, bi, 1);
        let rest = n - kk - b;
        if rest > 0 {
            total += hw.laswp_time(b, n, 1);
            total += hw.trsm_time(b, rest, 1);
            total += hw.gemm_time(m - kk - b, rest, b, 1);
        }
        kk += b;
    }
    total
}

/// Everything a leader needs to drive one request.
pub struct DriveCfg<'a> {
    pub params: &'a BlisParams,
    pub hw: &'a HwModel,
    pub bo: usize,
    pub bi: usize,
    /// The request's registry entry; its remaining-work estimate is
    /// refreshed at every panel checkpoint.
    pub lease: &'a Lease,
    /// Cancel flag shared with the request's [`crate::serve::JobHandle`].
    pub cancel: &'a AtomicBool,
    /// Absolute deadline, folded into `cancel` at every checkpoint.
    pub deadline: Option<Instant>,
}

/// Factorize `a` on the calling thread, leading `crew`. Trace spans are
/// tagged `req{id}` so multi-problem traces can tell requests apart.
pub fn drive(crew: &mut Crew, a: MatMut, cfg: &DriveCfg) -> BlockedOutcome {
    let (m, n) = (a.rows(), a.cols());
    let tag = format!("req{}", cfg.lease.id);
    let checkpoint = |k: usize| {
        cfg.lease
            .set_remaining(remaining_cost(cfg.hw, m, n, k, cfg.bo, cfg.bi));
        if let Some(d) = cfg.deadline {
            if Instant::now() >= d {
                cfg.cancel.store(true, Ordering::Release);
            }
        }
    };
    let ctl = BlockedCtl {
        cancel: Some(cfg.cancel),
        tag: Some(&tag),
        on_checkpoint: Some(&checkpoint),
    };
    lu_blocked_rl_ctl(crew, cfg.params, a, cfg.bo, cfg.bi, &ctl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{naive, Matrix};
    use std::sync::Arc;

    #[test]
    fn remaining_cost_is_monotone_in_progress() {
        let hw = HwModel::default();
        let full = remaining_cost(&hw, 512, 512, 0, 64, 16);
        let half = remaining_cost(&hw, 512, 512, 256, 64, 16);
        let done = remaining_cost(&hw, 512, 512, 512, 64, 16);
        assert!(full > half, "full={full} half={half}");
        assert!(half > 0.0);
        assert_eq!(done, 0.0);
        // Front-loading (paper §3.1): the first half of the columns
        // carries well over half of the work.
        assert!(half < 0.4 * full, "half={half} full={full}");
    }

    #[test]
    fn drive_factorizes_and_reports_progress() {
        let hw = HwModel::default();
        let params = BlisParams::tiny();
        let a0 = Matrix::random(48, 48, 21);
        let mut f = a0.clone();
        let mut crew = Crew::new();
        let lease = Arc::new(Lease::new(
            3,
            0,
            crew.shared(),
            remaining_cost(&hw, 48, 48, 0, 8, 4),
        ));
        let cancel = AtomicBool::new(false);
        let cfg = DriveCfg {
            params: &params,
            hw: &hw,
            bo: 8,
            bi: 4,
            lease: &lease,
            cancel: &cancel,
            deadline: None,
        };
        let out = drive(&mut crew, f.view_mut(), &cfg);
        assert!(!out.cancelled);
        assert_eq!(out.cols_done, 48);
        assert_eq!(lease.remaining(), 0.0);
        let r = naive::lu_residual(&a0, &f, &out.ipiv);
        assert!(r < 1e-12, "residual {r}");
    }

    #[test]
    fn expired_deadline_cancels_at_first_checkpoint() {
        let hw = HwModel::default();
        let params = BlisParams::tiny();
        let mut f = Matrix::random(64, 64, 22);
        let mut crew = Crew::new();
        let lease = Arc::new(Lease::new(4, 0, crew.shared(), 1.0));
        let cancel = AtomicBool::new(false);
        let cfg = DriveCfg {
            params: &params,
            hw: &hw,
            bo: 8,
            bi: 4,
            lease: &lease,
            cancel: &cancel,
            deadline: Some(Instant::now()),
        };
        let out = drive(&mut crew, f.view_mut(), &cfg);
        assert!(out.cancelled);
        // One step commits before the first checkpoint notices.
        assert_eq!(out.cols_done, 8);
        assert!(cancel.load(Ordering::Acquire));
    }
}
