//! Leader-side request driver: runs one problem's factorization on a
//! pool worker, with per-request trace tags, cost-model progress
//! accounting, deadline enforcement, and cancellation checkpoints.
//!
//! Since the factorization-family refactor the driver is kind-generic —
//! it dispatches through [`crate::factor::factorize_blocked`] — and
//! since the precision redesign it is *scalar*-generic too: LU,
//! Cholesky, and QR requests in either precision flow through the same
//! queue, crew leases, and checkpoints. Trace spans are tagged
//! `req{id}:{kind}:{prec}` so the per-request Gantt lanes show what each
//! problem was and in which precision it ran
//! ([`crate::trace::ascii_gantt_requests`]), and the cost model prices
//! remaining work at the precision's modeled flop rate
//! ([`crate::scalar::Scalar::FLOP_RATE`]).

use super::registry::Lease;
use crate::blis::BlisParams;
use crate::factor::{factorize_blocked, DriverFamily, FactorCtl, FactorKind, FactorOutcome};
use crate::matrix::MatMut;
use crate::pool::Crew;
use crate::replay::capture::{self, DecisionKind};
use crate::scalar::Scalar;
use crate::sim::HwModel;
use crate::tilert;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Cost-model estimate of the single-core seconds left in an `m × n` LU
/// after `k` committed columns. Kept as the LU-specialized, `f64`-rate
/// shorthand of [`FactorKind::remaining_cost`], which the scheduler now
/// uses (precision-scaled) for all kinds.
pub fn remaining_cost(hw: &HwModel, m: usize, n: usize, k: usize, bo: usize, bi: usize) -> f64 {
    FactorKind::Lu.remaining_cost(hw, m, n, k, bo, bi)
}

/// Execution strategy chosen for an admitted request — the
/// admission/execution split (DESIGN.md §18): [`crate::serve::LuServer`]
/// admits a request (id, capture record, typed handle) *before* deciding
/// how it will run, then routes it by this enum. Adding a strategy means
/// adding a variant here, not another ad-hoc branch in `submit`.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Classic per-problem path: the request leads its own crew under a
    /// revocable lease and runs a blocked (or tile-DAG) driver.
    PerProblem,
    /// Interleaved small-batch path: the request is staged into a
    /// same-shape same-precision bundle and factored lane-parallel by
    /// the register-resident kernel ([`crate::blis::smallbatch`]) — no
    /// crew, no lease, no packing arena.
    Interleaved,
}

/// Decide how an admitted factorization request executes. The
/// interleaved path takes square LU requests no larger than the cost
/// model's [`HwModel::small_threshold`] when the server's `interleave`
/// knob is on; everything else — other kinds, rectangular shapes,
/// explicit driver-family or deadline requirements — keeps the
/// per-problem path. The threshold moves *placement only*: both
/// strategies produce bitwise-identical factors per problem
/// (`tests/smallbatch_agree.rs`).
pub fn choose_strategy<S: Scalar>(
    cfg: &crate::serve::ServeConfig,
    req: &crate::serve::LuRequest<S>,
) -> Strategy {
    let n = req.a.cols();
    let small = n >= 1 && n <= cfg.hw.small_threshold(S::SIMD_LANES);
    if cfg.interleave
        && req.kind == FactorKind::Lu
        && req.a.rows() == n
        && small
        && req.driver == DriverFamily::Lookahead
        && req.deadline.is_none()
    {
        Strategy::Interleaved
    } else {
        Strategy::PerProblem
    }
}

/// Everything a leader needs to drive one request.
pub struct DriveCfg<'a> {
    /// BLIS blocking parameters for every kernel of the request.
    pub params: &'a BlisParams,
    /// Cost model pricing the remaining work.
    pub hw: &'a HwModel,
    /// Outer block size.
    pub bo: usize,
    /// Inner (panel) block size.
    pub bi: usize,
    /// Which factorization to run.
    pub kind: FactorKind,
    /// The request's registry entry; its remaining-work estimate is
    /// refreshed at every panel checkpoint.
    pub lease: &'a Lease,
    /// Cancel flag shared with the request's [`crate::serve::JobHandle`].
    pub cancel: &'a AtomicBool,
    /// Absolute deadline, folded into `cancel` at every checkpoint.
    pub deadline: Option<Instant>,
    /// Originating network connection id when the request came through
    /// the daemon ([`crate::serve::net`]); folds into the trace tag as
    /// `req{id}@c{client}:{kind}:{prec}`.
    pub client: Option<u64>,
    /// Which driver family executes the request: the crew-malleable
    /// blocked driver (default), or the tile-DAG runtime — in which
    /// case the leader publishes its drain in the lease's
    /// [`crate::tilert::DagSlot`] so floaters join as DAG executors.
    pub driver: DriverFamily,
}

/// Factorize `a` on the calling thread, leading `crew`, in `a`'s own
/// precision. Trace spans are tagged `req{id}:{kind}:{prec}` so
/// multi-problem traces can tell requests (kind *and* precision) apart.
pub fn drive<S: Scalar>(crew: &mut Crew, a: MatMut<S>, cfg: &DriveCfg) -> FactorOutcome<S> {
    let (m, n) = (a.rows(), a.cols());
    let tag = match cfg.client {
        Some(c) => format!("req{}@c{c}:{}:{}", cfg.lease.id, cfg.kind.name(), S::NAME),
        None => format!("req{}:{}:{}", cfg.lease.id, cfg.kind.name(), S::NAME),
    };
    // Steal-pressure feedback (DESIGN.md §13): at every panel checkpoint
    // the stolen-tile fraction of the hybrid-scheduled work done since
    // the previous checkpoint is folded into the lease, where the
    // floater policy's starvation score reads it.
    let shared = crew.shared();
    let prev_stolen = std::sync::atomic::AtomicU64::new(0);
    let prev_tiles = std::sync::atomic::AtomicU64::new(0);
    let checkpoint = |k: usize| {
        // Chaos hook (DESIGN.md §15.4): inert unless a fault plan is
        // armed; a stall injected here is observed by the deadline fold
        // below, a panic unwinds to the serve loop's `catch_unwind`.
        #[cfg(any(test, feature = "chaos"))]
        crate::faultplan::checkpoint_hook(&tag, k);
        let rem = cfg
            .kind
            .remaining_cost_prec::<S>(cfg.hw, m, n, k, cfg.bo, cfg.bi);
        cfg.lease.set_remaining(rem);
        let (ds, dt) = cfg
            .lease
            .fold_steal_delta(&shared, &prev_stolen, &prev_tiles);
        // Capture (DESIGN.md §16.2): the lease-sizing refresh is an
        // invariant record, the steal fold an environmental one.
        if capture::active() {
            capture::record(DecisionKind::Checkpoint, cfg.lease.id, k as u64, rem.to_bits());
            capture::record(
                DecisionKind::StealDelta,
                cfg.lease.id,
                k as u64,
                capture::pack_delta(ds, dt),
            );
        }
        if let Some(d) = cfg.deadline {
            if Instant::now() >= d && !cfg.cancel.swap(true, Ordering::Release) {
                capture::record(DecisionKind::EtTrigger, cfg.lease.id, k as u64, 1);
            }
        }
    };
    let ctl = FactorCtl {
        cancel: Some(cfg.cancel),
        tag: Some(&tag),
        on_checkpoint: Some(&checkpoint),
    };
    let out = match cfg.driver {
        DriverFamily::Lookahead => {
            factorize_blocked(cfg.kind, crew, cfg.params, a, cfg.bo, cfg.bi, &ctl)
        }
        // Tile-DAG family: the leader drives the drain and publishes it
        // in the lease; floaters that pick this lease attach as DAG
        // executors and retire at task boundaries when revoked. The
        // checkpoint closure (cost refresh, capture records, deadline
        // fold) is the same one the blocked path uses.
        DriverFamily::Dag => tilert::factorize_dag_shared(
            cfg.kind,
            &cfg.lease.dag,
            cfg.params,
            a,
            cfg.bo,
            cfg.bi,
            &ctl,
            cfg.lease.id,
        ),
    };
    // A crew panic surfaces as `FactorError::Internal` and leaves the
    // crew poisoned; poison the lease too so the floater policy stops
    // routing helpers at a doomed request while it is wound down.
    if let Some(e) = &out.error {
        if e.is_internal() {
            cfg.lease.poison();
        }
    }
    out
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::faultplan::{FaultAction, FaultPlan};
    use crate::matrix::{naive, Mat, Matrix};
    use std::sync::Arc;

    #[test]
    fn remaining_cost_is_monotone_in_progress() {
        let hw = HwModel::default();
        let full = remaining_cost(&hw, 512, 512, 0, 64, 16);
        let half = remaining_cost(&hw, 512, 512, 256, 64, 16);
        let done = remaining_cost(&hw, 512, 512, 512, 64, 16);
        assert!(full > half, "full={full} half={half}");
        assert!(half > 0.0);
        assert_eq!(done, 0.0);
        // Front-loading (paper §3.1): the first half of the columns
        // carries well over half of the work.
        assert!(half < 0.4 * full, "half={half} full={full}");
    }

    #[test]
    fn choose_strategy_routes_by_size_shape_and_knob() {
        use crate::serve::{LuRequest, ServeConfig};
        use std::time::Duration;
        let on = ServeConfig {
            interleave: true,
            ..Default::default()
        };
        let off = ServeConfig::default();
        let small = || LuRequest::new(Matrix::zeros(16, 16));
        assert_eq!(choose_strategy(&on, &small()), Strategy::Interleaved);
        // The knob gates the path entirely.
        assert_eq!(choose_strategy(&off, &small()), Strategy::PerProblem);
        // Above the threshold: per-problem.
        let thr = on.hw.small_threshold(f64::SIMD_LANES);
        let big = LuRequest::new(Matrix::zeros(thr + 1, thr + 1));
        assert_eq!(choose_strategy(&on, &big), Strategy::PerProblem);
        // At the threshold: interleaved (the bound is inclusive).
        let edge = LuRequest::new(Matrix::zeros(thr, thr));
        assert_eq!(choose_strategy(&on, &edge), Strategy::Interleaved);
        // Non-LU kinds, rectangular shapes, explicit driver families,
        // and deadlines all keep the per-problem path.
        let chol = small().with_kind(FactorKind::Chol);
        assert_eq!(choose_strategy(&on, &chol), Strategy::PerProblem);
        let rect = LuRequest::new(Matrix::zeros(16, 8));
        assert_eq!(choose_strategy(&on, &rect), Strategy::PerProblem);
        let dag = small().with_driver(DriverFamily::Dag);
        assert_eq!(choose_strategy(&on, &dag), Strategy::PerProblem);
        let dl = small().with_deadline(Duration::from_secs(1));
        assert_eq!(choose_strategy(&on, &dl), Strategy::PerProblem);
        // f32 routes by its own (wider) lane count but the same bound.
        let s32 = LuRequest::new(Mat::<f32>::zeros(16, 16));
        assert_eq!(choose_strategy(&on, &s32), Strategy::Interleaved);
        // Degenerate 0×0 requests stay per-problem.
        let empty = LuRequest::new(Matrix::zeros(0, 0));
        assert_eq!(choose_strategy(&on, &empty), Strategy::PerProblem);
    }

    #[test]
    fn drive_factorizes_and_reports_progress() {
        let hw = HwModel::default();
        let params = BlisParams::tiny();
        let a0 = Matrix::random(48, 48, 21);
        let mut f = a0.clone();
        let mut crew = Crew::new();
        let lease = Arc::new(Lease::new(
            3,
            0,
            crew.shared(),
            remaining_cost(&hw, 48, 48, 0, 8, 4),
        ));
        let cancel = AtomicBool::new(false);
        let cfg = DriveCfg {
            params: &params,
            hw: &hw,
            bo: 8,
            bi: 4,
            kind: FactorKind::Lu,
            lease: &lease,
            cancel: &cancel,
            deadline: None,
            client: None,
            driver: DriverFamily::Lookahead,
        };
        let out = drive(&mut crew, f.view_mut(), &cfg);
        assert!(!out.cancelled);
        assert!(out.error.is_none(), "clean run: {:?}", out.error);
        assert_eq!(out.cols_done, 48);
        assert_eq!(lease.remaining(), 0.0);
        assert!(!lease.is_poisoned());
        let r = naive::lu_residual(&a0, &f, &out.ipiv);
        assert!(r < 1e-12, "residual {r}");
    }

    #[test]
    fn injected_chunk_panic_poisons_crew_and_lease() {
        let hw = HwModel::default();
        let params = BlisParams::tiny();
        let mut f = Matrix::random(48, 48, 5);
        let mut crew = Crew::new();
        let lease = Arc::new(Lease::new(13, 0, crew.shared(), 1.0));
        let cancel = AtomicBool::new(false);
        let plan = FaultPlan {
            seed: 0,
            action: FaultAction::PanicInChunk { nth: 0 },
        };
        let _g = plan.arm_local();
        let cfg = DriveCfg {
            params: &params,
            hw: &hw,
            bo: 8,
            bi: 4,
            kind: FactorKind::Lu,
            lease: &lease,
            cancel: &cancel,
            deadline: None,
            client: None,
            driver: DriverFamily::Lookahead,
        };
        let out = drive(&mut crew, f.view_mut(), &cfg);
        assert!(crate::faultplan::fired(), "plan must have fired");
        let err = out.error.expect("crew panic must surface as an error");
        assert!(err.is_internal(), "{err}");
        assert!(!out.cancelled, "typed failure is not a cancellation");
        assert!(lease.is_poisoned(), "doomed request must repel floaters");
    }

    #[test]
    fn injected_checkpoint_panic_unwinds_to_caller() {
        use std::panic::AssertUnwindSafe;
        let hw = HwModel::default();
        let params = BlisParams::tiny();
        let mut f = Matrix::random(48, 48, 6);
        let mut crew = Crew::new();
        let lease = Arc::new(Lease::new(17, 0, crew.shared(), 1.0));
        let cancel = AtomicBool::new(false);
        let plan = FaultPlan {
            seed: 0,
            action: FaultAction::PanicAtCheckpoint { k: 0 },
        };
        let _g = plan.arm_local();
        let cfg = DriveCfg {
            params: &params,
            hw: &hw,
            bo: 8,
            bi: 4,
            kind: FactorKind::Lu,
            lease: &lease,
            cancel: &cancel,
            deadline: None,
            client: None,
            driver: DriverFamily::Lookahead,
        };
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| drive(&mut crew, f.view_mut(), &cfg)));
        assert!(r.is_err(), "leader panic must unwind to the serve loop");
        assert!(crate::faultplan::fired());
    }

    #[test]
    fn drive_runs_every_kind_through_one_driver() {
        let hw = HwModel::default();
        let params = BlisParams::tiny();
        let mut crew = Crew::new();
        for &kind in FactorKind::all() {
            let n = 40;
            let a0 = match kind {
                FactorKind::Chol => Matrix::random_spd(n, 31),
                _ => Matrix::random(n, n, 31),
            };
            let mut f = a0.clone();
            let lease = Arc::new(Lease::new(7, 0, crew.shared(), 1.0));
            let cancel = AtomicBool::new(false);
            let cfg = DriveCfg {
                params: &params,
                hw: &hw,
                bo: 8,
                bi: 4,
                kind,
                lease: &lease,
                cancel: &cancel,
                deadline: None,
                client: None,
                driver: DriverFamily::Lookahead,
            };
            let out = drive(&mut crew, f.view_mut(), &cfg);
            assert!(!out.cancelled, "{}", kind.name());
            assert_eq!(out.cols_done, n, "{}", kind.name());
            let r = match kind {
                FactorKind::Lu => naive::lu_residual(&a0, &f, &out.ipiv),
                FactorKind::Chol => naive::chol_residual(&a0, &f),
                FactorKind::Qr => naive::qr_residual(&a0, &f, &out.tau),
            };
            assert!(r < 1e-11, "{}: residual {r}", kind.name());
        }
    }

    #[test]
    fn drive_runs_f32_requests_with_scaled_cost() {
        let hw = HwModel::default();
        let params = BlisParams::tiny();
        let mut crew = Crew::new();
        let n = 40;
        let a0 = Mat::<f32>::random(n, n, 33);
        let mut f = a0.clone();
        let start_cost = FactorKind::Lu.remaining_cost_prec::<f32>(&hw, n, n, 0, 8, 4);
        let lease = Arc::new(Lease::new(9, 0, crew.shared(), start_cost));
        let cancel = AtomicBool::new(false);
        let cfg = DriveCfg {
            params: &params,
            hw: &hw,
            bo: 8,
            bi: 4,
            kind: FactorKind::Lu,
            lease: &lease,
            cancel: &cancel,
            deadline: None,
            client: None,
            driver: DriverFamily::Lookahead,
        };
        let out = drive(&mut crew, f.view_mut(), &cfg);
        assert!(!out.cancelled);
        assert_eq!(out.cols_done, n);
        assert_eq!(lease.remaining(), 0.0);
        let r = naive::lu_residual(&a0, &f, &out.ipiv);
        let tol = 8.0 * n as f64 * f32::EPSILON as f64;
        assert!(r < tol, "f32 residual {r} tol {tol}");
    }

    #[test]
    fn drive_updates_steal_pressure_signal() {
        // A lone leader steals nothing from itself: after driving a
        // hybrid-scheduled request to completion the lease's pressure
        // signal must have been refreshed to 0 (not left at a stale
        // preset), while the crew demonstrably ran the tiles through
        // the hybrid scheduler.
        use crate::blis::StealPolicy;
        let hw = HwModel::default();
        let params = BlisParams::tiny().with_steal(StealPolicy::Fraction(800));
        let a0 = Matrix::random(48, 48, 77);
        let mut f = a0.clone();
        let mut crew = Crew::new();
        let lease = Arc::new(Lease::new(11, 0, crew.shared(), 1.0));
        lease.set_steal_pressure(0.9); // stale preset the drive must overwrite
        let cancel = AtomicBool::new(false);
        let cfg = DriveCfg {
            params: &params,
            hw: &hw,
            bo: 8,
            bi: 4,
            kind: FactorKind::Lu,
            lease: &lease,
            cancel: &cancel,
            deadline: None,
            client: None,
            driver: DriverFamily::Lookahead,
        };
        let out = drive(&mut crew, f.view_mut(), &cfg);
        assert!(!out.cancelled);
        assert_eq!(lease.steal_pressure(), 0.0);
        let (stolen, tiles) = crew.shared().steal_stats();
        assert_eq!(stolen, 0);
        assert!(tiles > 0, "hybrid scheduler must have run the update tiles");
    }

    #[test]
    fn drive_dag_family_matches_blocked_bitwise() {
        let hw = HwModel::default();
        let params = BlisParams::tiny();
        let a0 = Matrix::random(48, 48, 55);
        let mut reference = a0.clone();
        let mut crew = Crew::new();
        let rout = factorize_blocked(
            FactorKind::Lu,
            &mut crew,
            &params,
            reference.view_mut(),
            8,
            4,
            &FactorCtl::default(),
        );
        let mut f = a0.clone();
        let lease = Arc::new(Lease::new(5, 0, crew.shared(), 1.0));
        let cancel = AtomicBool::new(false);
        let cfg = DriveCfg {
            params: &params,
            hw: &hw,
            bo: 8,
            bi: 4,
            kind: FactorKind::Lu,
            lease: &lease,
            cancel: &cancel,
            deadline: None,
            client: None,
            driver: DriverFamily::Dag,
        };
        let out = drive(&mut crew, f.view_mut(), &cfg);
        assert!(!out.cancelled);
        assert!(out.error.is_none(), "dag drive: {:?}", out.error);
        assert_eq!(out.cols_done, 48);
        assert_eq!(out.ipiv, rout.ipiv, "pivot sequences must agree");
        assert_eq!(f.data(), reference.data(), "factors must agree bitwise");
        assert_eq!(lease.remaining(), 0.0);
        assert!(!lease.is_poisoned());
    }

    #[test]
    fn expired_deadline_cancels_at_first_checkpoint() {
        let hw = HwModel::default();
        let params = BlisParams::tiny();
        let mut f = Matrix::random(64, 64, 22);
        let mut crew = Crew::new();
        let lease = Arc::new(Lease::new(4, 0, crew.shared(), 1.0));
        let cancel = AtomicBool::new(false);
        let cfg = DriveCfg {
            params: &params,
            hw: &hw,
            bo: 8,
            bi: 4,
            kind: FactorKind::Lu,
            lease: &lease,
            cancel: &cancel,
            deadline: Some(Instant::now()),
            client: None,
            driver: DriverFamily::Lookahead,
        };
        let out = drive(&mut crew, f.view_mut(), &cfg);
        assert!(out.cancelled);
        // One step commits before the first checkpoint notices.
        assert_eq!(out.cols_done, 8);
        assert!(cancel.load(Ordering::Acquire));
    }
}
