//! Crew-lease registry: which problems are in flight, how starved each
//! one is, and where a floating worker should go next.
//!
//! The paper's Worker-Sharing rule is "the branch that finishes first
//! donates its threads to the branch that is behind". Lifted to many
//! concurrent problems, "behind" needs a number: every in-flight
//! factorization registers a [`Lease`] carrying its crew handle, its
//! priority, and a cost-model estimate of the work it has left
//! ([`crate::serve::driver::remaining_cost`]). Idle workers consult
//! [`CrewRegistry::most_starved`] and enlist where the priority-weighted
//! remaining work per enlisted worker is highest.

use crate::pool::CrewShared;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One in-flight problem's entry: its crew plus the scheduling signals
/// the reallocation policy reads.
pub struct Lease {
    /// Request id (matches the trace span tag `req{id}`).
    pub id: u64,
    /// Scheduling priority (higher = more urgent).
    pub priority: u8,
    /// The problem's crew, open for members.
    pub shared: Arc<CrewShared>,
    /// Modeled single-core seconds of work left, stored as `f64` bits.
    /// Updated by the leader at every panel checkpoint.
    remaining: AtomicU64,
    /// Fraction of this crew's recent macro-kernel tiles that were
    /// *stolen* (taken from another member's static slice), in `[0, 1]`,
    /// stored as `f64` bits. Updated by the leader at every panel
    /// checkpoint from the crew's hybrid-scheduler counters
    /// ([`CrewShared::steal_stats`]). High pressure means the static
    /// partition is under-provisioned for the problem's current team —
    /// donated workers are absorbed productively — so the starvation
    /// score weights it up (DESIGN.md §13).
    steal_pressure: AtomicU64,
    /// Set when the problem's crew suffered a fault (a chunk panicked,
    /// or the leader died). A poisoned lease never attracts floaters —
    /// donating workers to a dying problem wastes them — and is
    /// unregistered by its leader's cleanup path shortly after.
    poisoned: AtomicBool,
    /// Attachment point for the tile-DAG driver family: when the
    /// request runs on [`crate::tilert`] the leader publishes its DAG
    /// drain here, and floaters enter it as donated executors instead
    /// of enlisting in the crew ([`crate::tilert::DagSlot::attach`]).
    /// Closed (attaches find nothing) for crew-family requests.
    pub dag: crate::tilert::DagSlot,
}

impl Lease {
    /// New lease with an initial remaining-work estimate.
    pub fn new(id: u64, priority: u8, shared: Arc<CrewShared>, remaining: f64) -> Self {
        Self {
            id,
            priority,
            shared,
            remaining: AtomicU64::new(remaining.to_bits()),
            steal_pressure: AtomicU64::new(0.0f64.to_bits()),
            poisoned: AtomicBool::new(false),
            dag: crate::tilert::DagSlot::new(),
        }
    }

    /// Mark the lease faulted (crew poisoned or leader panicked); see
    /// the `poisoned` field docs. Idempotent.
    pub fn poison(&self) {
        self.poisoned.store(true, Ordering::Release);
    }

    /// Whether [`Lease::poison`] was called.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::Acquire)
    }

    /// Cost-model estimate of the problem's remaining work (modeled
    /// single-core seconds).
    pub fn remaining(&self) -> f64 {
        f64::from_bits(self.remaining.load(Ordering::Relaxed))
    }

    /// Refresh the remaining-work estimate (leader, at checkpoints).
    pub fn set_remaining(&self, secs: f64) {
        self.remaining.store(secs.to_bits(), Ordering::Relaxed);
    }

    /// Recent stolen-tile fraction of this crew's hybrid schedule.
    ///
    /// **Units**: dimensionless, in `[0, 1]` — the fraction of
    /// macro-kernel tiles completed since the last panel checkpoint that
    /// were *stolen* rather than executed by their static owner
    /// (`Δstolen / Δtiles` over [`CrewShared::steal_stats`], computed by
    /// [`Lease::fold_steal_delta`]). `0.0` means the static partition
    /// matched the team perfectly (or no hybrid tiles ran); `1.0` means
    /// every tile moved, i.e. the static slices are badly sized for the
    /// crew that actually showed up.
    ///
    /// **Interpretation**: pressure is a *demand* signal, not a health
    /// problem — stealing is how the hybrid schedule absorbs a team that
    /// grew mid-iteration (DESIGN.md §13). A high-pressure crew is
    /// demonstrably converting extra hands into progress, which is why
    /// [`Lease::starvation`] weights it up. The window is one panel
    /// step, so the signal tracks the current iteration rather than the
    /// problem's history.
    pub fn steal_pressure(&self) -> f64 {
        f64::from_bits(self.steal_pressure.load(Ordering::Relaxed))
    }

    /// Refresh the steal-pressure signal (leader, at checkpoints);
    /// clamped into `[0, 1]`.
    pub fn set_steal_pressure(&self, p: f64) {
        self.steal_pressure
            .store(p.clamp(0.0, 1.0).to_bits(), Ordering::Relaxed);
    }

    /// Fold the crew's hybrid-scheduler progress since the previous
    /// checkpoint into the steal-pressure signal: reads
    /// [`CrewShared::steal_stats`], diffs against the caller-held
    /// `prev_stolen`/`prev_tiles` cursors (updating them), and stores
    /// `Δstolen / Δtiles` (0 when no hybrid tiles ran). The one shared
    /// implementation both the factor and solve lead checkpoints call.
    /// Returns the `(Δstolen, Δtiles)` pair so the caller can feed the
    /// capture recorder ([`crate::replay::capture`]) without re-reading
    /// the counters.
    pub fn fold_steal_delta(
        &self,
        shared: &CrewShared,
        prev_stolen: &AtomicU64,
        prev_tiles: &AtomicU64,
    ) -> (u64, u64) {
        let (stolen, tiles) = shared.steal_stats();
        let ds = stolen.saturating_sub(prev_stolen.swap(stolen, Ordering::Relaxed));
        let dt = tiles.saturating_sub(prev_tiles.swap(tiles, Ordering::Relaxed));
        self.set_steal_pressure(if dt == 0 { 0.0 } else { ds as f64 / dt as f64 });
        (ds, dt)
    }

    /// Work-conserving starvation score:
    ///
    /// ```text
    /// (priority + 1) · remaining · (1 + steal_pressure) / team
    /// ```
    ///
    /// **Units**: modeled single-core seconds per enlisted worker — how
    /// much priority-weighted work each current team member would still
    /// have to carry. The floater policy sends idle workers to the
    /// highest score: the paper's WS rule ("donate to whoever is
    /// behind") generalized from two branches to N concurrent problems.
    ///
    /// **Derivation of each factor**:
    /// - `priority + 1` — the `+1` keeps priority-0 requests schedulable
    ///   (a plain multiply would zero them out); each priority level
    ///   scales the problem's bid linearly.
    /// - `remaining` — the cost-model estimate
    ///   ([`crate::serve::driver::remaining_cost`]), refreshed at panel
    ///   checkpoints, so the score decays as the problem progresses.
    /// - `1 + steal_pressure` — the lease-sizing feedback of DESIGN.md
    ///   §13: a crew whose static slices are being actively stolen from
    ///   can demonstrably convert extra workers into progress *within*
    ///   the current iteration, so it out-bids an otherwise equal crew
    ///   whose update is already balanced. Bounded in `[1, 2]`, it
    ///   re-orders comparable bids without drowning priority or size.
    /// - `/ team` (members + leader) — work-conservation: doubling a
    ///   team halves its bid, spreading floaters instead of herding
    ///   them onto the single largest problem.
    ///
    /// **Tuning**: the score is deliberately scale-free — only ratios
    /// between in-flight leases matter, so recalibrating the cost model
    /// (see [`crate::sim::costmodel::HwModel`]) does not perturb the
    /// policy. If high-priority work must preempt harder, widen the
    /// priority gap at submission time rather than reshaping the
    /// formula; the `u8` priority gives 256 levels of headroom.
    pub fn starvation(&self) -> f64 {
        let team = self.shared.members() + 1; // members + the leader
        (self.priority as f64 + 1.0) * self.remaining() * (1.0 + self.steal_pressure())
            / team as f64
    }
}

/// Registry of all in-flight problems. Registration changes bump an
/// epoch; floating workers watch it to know when the picture changed and
/// the pick policy should re-run.
pub struct CrewRegistry {
    slots: Mutex<Vec<Arc<Lease>>>,
    epoch: AtomicU64,
}

impl Default for CrewRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl CrewRegistry {
    /// Empty registry at epoch 0.
    pub fn new() -> Self {
        Self {
            slots: Mutex::new(Vec::new()),
            epoch: AtomicU64::new(0),
        }
    }

    /// Monotone counter bumped on every register/unregister.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Number of in-flight problems.
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Whether no problem is in flight.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Announce a problem as open for donated workers.
    pub fn register(&self, lease: Arc<Lease>) {
        self.slots
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(lease);
        self.epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// Withdraw a finished (or cancelled) problem. Floaters enlisted in
    /// its crew leave at the next job boundary (epoch change), before
    /// the leader disbands it.
    pub fn unregister(&self, id: u64) {
        self.slots
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .retain(|l| l.id != id);
        self.epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// The lease with the highest starvation score, if any problem is in
    /// flight. Concurrent callers may briefly herd onto the same lease;
    /// the score self-corrects as each enlistment raises the team count.
    /// Poisoned leases ([`Lease::poison`]) are skipped — a faulted
    /// problem is being torn down and must not absorb floaters.
    pub fn most_starved(&self) -> Option<Arc<Lease>> {
        let slots = self.slots.lock().unwrap_or_else(|e| e.into_inner());
        slots
            .iter()
            .filter(|l| !l.is_poisoned())
            .max_by(|a, b| {
                a.starvation()
                    .partial_cmp(&b.starvation())
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .cloned()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::pool::Crew;

    fn lease(id: u64, priority: u8, remaining: f64) -> (Crew, Arc<Lease>) {
        let crew = Crew::new();
        let l = Arc::new(Lease::new(id, priority, crew.shared(), remaining));
        (crew, l)
    }

    #[test]
    fn register_unregister_bumps_epoch() {
        let reg = CrewRegistry::new();
        assert!(reg.is_empty());
        let e0 = reg.epoch();
        let (_c, l) = lease(7, 0, 1.0);
        reg.register(Arc::clone(&l));
        assert_eq!(reg.len(), 1);
        assert!(reg.epoch() > e0);
        let e1 = reg.epoch();
        reg.unregister(7);
        assert!(reg.is_empty());
        assert!(reg.epoch() > e1);
    }

    #[test]
    fn most_starved_prefers_more_remaining_work() {
        let reg = CrewRegistry::new();
        let (_c1, l1) = lease(1, 0, 1.0);
        let (_c2, l2) = lease(2, 0, 5.0);
        reg.register(l1);
        reg.register(Arc::clone(&l2));
        assert_eq!(reg.most_starved().unwrap().id, 2);
        // Progress on problem 2 flips the pick.
        l2.set_remaining(0.1);
        assert_eq!(reg.most_starved().unwrap().id, 1);
    }

    #[test]
    fn most_starved_weighs_priority() {
        let reg = CrewRegistry::new();
        let (_c1, l1) = lease(1, 0, 1.0);
        let (_c2, l2) = lease(2, 3, 0.5);
        reg.register(l1);
        reg.register(l2);
        // 0.5 × (3+1) = 2.0 beats 1.0 × 1.
        assert_eq!(reg.most_starved().unwrap().id, 2);
    }

    #[test]
    fn most_starved_empty_is_none() {
        let reg = CrewRegistry::new();
        assert!(reg.most_starved().is_none());
    }

    #[test]
    fn poisoned_lease_attracts_no_floaters() {
        let reg = CrewRegistry::new();
        let (_c1, l1) = lease(1, 0, 1.0);
        let (_c2, l2) = lease(2, 7, 100.0); // by score, the clear winner
        reg.register(Arc::clone(&l1));
        reg.register(Arc::clone(&l2));
        assert_eq!(reg.most_starved().unwrap().id, 2);
        l2.poison();
        assert!(l2.is_poisoned());
        // The faulted problem is skipped even though it out-bids l1.
        assert_eq!(reg.most_starved().unwrap().id, 1);
        l1.poison();
        assert!(reg.most_starved().is_none(), "all poisoned: no pick");
    }

    #[test]
    fn steal_pressure_breaks_ties_toward_the_stealing_crew() {
        // Two otherwise identical problems: the one whose crew shows
        // active within-update stealing attracts the floater.
        let reg = CrewRegistry::new();
        let (_c1, l1) = lease(1, 0, 1.0);
        let (_c2, l2) = lease(2, 0, 1.0);
        reg.register(Arc::clone(&l1));
        reg.register(Arc::clone(&l2));
        l2.set_steal_pressure(0.6);
        assert_eq!(reg.most_starved().unwrap().id, 2);
        // The signal is clamped and symmetric.
        l1.set_steal_pressure(7.0); // clamps to 1.0
        assert_eq!(l1.steal_pressure(), 1.0);
        assert_eq!(reg.most_starved().unwrap().id, 1);
        l1.set_steal_pressure(-3.0);
        assert_eq!(l1.steal_pressure(), 0.0);
    }
}
